//! Run the KVmix profiler end-to-end *in Rust*: execute the AOT-lowered
//! loss/gradient graph over sampled prompts through PJRT, rank the layers,
//! print the Fig.-6-style plan at several high-bit fractions, and compare
//! against the python profiler's plan shipped in importance.json.
//!
//!     cargo run --release --example profile_and_configure [-- --prompts 16]

use anyhow::Result;
use kvmix::config::QuantPlan;
use kvmix::profiler;
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::cli::Args;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]);
    let n = args.usize_or("prompts", 16)?;

    let dir = default_artifacts_dir();
    let rt = Runtime::load(&dir)?; // includes the profiler executable
    let t0 = std::time::Instant::now();
    let imp = profiler::profile(&rt, n, 42)?;
    println!("profiled {} prompts in {:.2}s (mean loss {:.4})",
             imp.n_prompts, t0.elapsed().as_secs_f64(), imp.mean_loss);

    for frac in [0.25, 0.375, 0.5] {
        let plan = profiler::allocate(&imp, frac);
        println!("\n--- high-bit fraction {frac} ---");
        print!("{}", profiler::plan_report(&imp, &plan));
    }

    // cross-check against the python (build-time) profiler
    match QuantPlan::from_importance_file(&dir.join("importance.json")) {
        Ok(py_plan) => {
            let rust_plan = profiler::allocate(&imp, 0.25);
            let same_k = rust_plan.k_bits.iter().zip(&py_plan.k_bits)
                .filter(|(a, b)| a == b).count();
            let same_v = rust_plan.v_bits.iter().zip(&py_plan.v_bits)
                .filter(|(a, b)| a == b).count();
            println!("\nagreement with python profiler: K {}/{} layers, V {}/{}",
                     same_k, rust_plan.k_bits.len(), same_v, rust_plan.v_bits.len());
        }
        Err(e) => println!("(no python plan to compare: {e})"),
    }
    Ok(())
}
