//! Long-context demo: watch the dynamic Recent-Pivotal-Context windows
//! shrink relative to the growing quantized history while generation
//! quality holds (paper Fig. 4 + the RPC contribution).
//!
//!     cargo run --release --example longcontext_rpc [-- --steps 256]

use anyhow::Result;
use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::harness::workload::{self, Task};
use kvmix::model::{sampler::argmax, DecodeScratch, Forward};
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::cli::Args;
use kvmix::util::Rng;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]);
    let steps = args.usize_or("steps", 256)?;

    let dir = default_artifacts_dir();
    let rt = Runtime::load_with(&dir, false)?;
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))?;
    println!("plan {} — per-layer RPC ratios K {:?} V {:?}", plan.name, plan.k_rpc, plan.v_rpc);

    let method = Method::Kvmix(plan);
    let mut cache = method.make_cache(&rt.model);
    let fwd = Forward::new(&rt);

    let mut rng = Rng::new(9);
    let (toks, _) = workload::generate(Task::Lm, &mut rng, 48);
    fwd.prefill(&toks[..32], &mut cache)?;

    println!("{:>6} {:>8} | {:>10} {:>10} {:>10} | {:>12} {:>12}",
             "step", "ctx", "fp K (hi)", "fp K (lo)", "quantized", "kv KiB", "fp16 KiB");
    let mut scratch = DecodeScratch::default();
    let mut input = toks[32];
    // pick one high-bit and one low-bit layer to trace
    let hi = (0..rt.model.n_layers).max_by(|&a, &b| {
        method_rpc(&method, a).partial_cmp(&method_rpc(&method, b)).unwrap()
    }).unwrap();
    let lo = (0..rt.model.n_layers).min_by(|&a, &b| {
        method_rpc(&method, a).partial_cmp(&method_rpc(&method, b)).unwrap()
    }).unwrap();
    for step in 0..steps {
        if step % 16 == 0 {
            let total = cache.len();
            let fp16_equiv = total * rt.model.kv_dim() * 2 * 2 * rt.model.n_layers;
            println!("{:>6} {:>8} | {:>10} {:>10} {:>10} | {:>12.2} {:>12.2}",
                     step, total,
                     cache.layers[hi].k_fp_tokens(), cache.layers[lo].k_fp_tokens(),
                     cache.layers[lo].k_hist,
                     cache.modeled_bytes() as f64 / 1024.0,
                     fp16_equiv as f64 / 1024.0);
        }
        let mut refs = vec![&mut cache];
        let logits = fwd.decode_step(&[input], &mut refs, &mut scratch)?;
        input = argmax(&logits[..rt.model.vocab]) as i32;
    }
    let total = cache.len();
    let fp16_equiv = total * rt.model.kv_dim() * 2 * 2 * rt.model.n_layers;
    println!("final compression vs fp16: {:.2}x",
             fp16_equiv as f64 / cache.modeled_bytes() as f64);
    Ok(())
}

fn method_rpc(m: &Method, layer: usize) -> f64 {
    match m {
        Method::Kvmix(p) => p.k_rpc[layer],
        _ => 0.0,
    }
}
