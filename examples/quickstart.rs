//! Quickstart: load the AOT artifacts, build a KVmix-quantized cache from
//! the profiled plan, and generate tokens from a prompt.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::coordinator::{Engine, EngineCfg, Request};
use kvmix::harness::workload;
use kvmix::model::Sampler;
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::Rng;

fn main() -> Result<()> {
    let dir = default_artifacts_dir();
    println!("loading artifacts from {} ...", dir.display());
    let rt = Runtime::load_with(&dir, false)?;
    println!("model: {} layers, d_model {}, vocab {} ({} params)",
             rt.model.n_layers, rt.model.d_model, rt.model.vocab,
             rt.weights.param_count());

    // The profiled mixed-precision plan produced by `make artifacts`
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))?;
    println!("quant plan: {} (K bits {:?}, V bits {:?})", plan.name, plan.k_bits, plan.v_bits);

    let mut engine = Engine::new(&rt, EngineCfg {
        method: Method::Kvmix(plan),
        max_batch: 1,
        kv_budget: None,
        threads: 1,
        page_tokens: 0, // monolithic accounting; see DESIGN.md §Memory-Manager
        prefix_cache: false,
        step_tokens: 0, // legacy whole-prefill scheduling; see DESIGN.md §Scheduler
    })?;

    // a recall-task prompt: bindings ... SEP QRY key -> the model should
    // emit the bound value
    let mut rng = Rng::new(7);
    let (prompt_full, mask) = workload::gen_recall(&mut rng, 96, Some(0), 1);
    let q_pos = mask.iter().position(|&m| m > 0.0).unwrap();
    let prompt: Vec<i32> = prompt_full[..=q_pos].to_vec();
    let expected = prompt_full[q_pos + 1];

    engine.submit(Request {
        id: 1, prompt, max_new_tokens: 8,
        sampler: Sampler::Greedy, stop_token: Some(workload::EOS),
        priority: 0, deadline_ms: None, submitted_ns: 0,
    });
    let done = engine.run_to_completion()?;
    println!("generated: {:?}", done[0].tokens);
    println!("expected first token (bound value): {expected} -> got {}",
             done[0].tokens[0]);
    println!("kv cache (modeled): {:.1} KiB peak",
             engine.metrics.peak_kv_bytes as f64 / 1024.0);
    println!("{}", engine.metrics.report());
    Ok(())
}
