//! End-to-end serving driver (the repo's E2E validation, README.md):
//! loads the trained reproduction model through the PJRT runtime, serves a
//! batched mixed workload through the continuous-batching engine with the
//! KVmix cache, and reports latency/throughput + memory vs the FP16
//! baseline.
//!
//! With `--prefix-cache` (which implies `--page-tokens 64` unless set)
//! every request shares a synthetic system prompt, the shape prefix
//! sharing deduplicates (DESIGN.md §Prefix-Sharing): the report then
//! shows `prefix hits N (T tok reused)`.
//!
//! With `--deadline-ms N` every request carries a per-request deadline:
//! the engine's sweep retires late requests with `finish: "deadline"`,
//! and the finish-reason breakdown below shows the split — the same
//! lifecycle the NDJSON serving protocol streams to clients
//! (DESIGN.md §Serving-Protocol).
//!
//!     cargo run --release --example serve_batch [-- --requests 24 --batch 8 --threads 4 --page-tokens 64 --prefix-cache --deadline-ms 0]

use std::collections::BTreeMap;

use anyhow::Result;
use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::coordinator::{proto, Engine, EngineCfg, Request};
use kvmix::harness::workload;
use kvmix::model::Sampler;
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::cli::Args;
use kvmix::util::{Rng, WorkerPool};

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["prefix-cache"]);
    let n_requests = args.usize_or("requests", 24)?;
    let batch = args.usize_or("batch", 8)?;
    let max_new = args.usize_or("max-new", 48)?;
    let threads = args.usize_or("threads", 1)?;
    let prefix_cache = args.flag("prefix-cache");
    // 0 = monolithic; e.g. --page-tokens 64 enables the paged KV pool.
    // --prefix-cache needs pages, so it defaults the page size on.
    let page_tokens = match args.usize_or("page-tokens", 0)? {
        0 if prefix_cache => 64,
        pt => pt,
    };
    // 0 = legacy whole-prefill scheduling; e.g. --step-tokens 64 chunks
    // prompt prefill across steps (DESIGN.md §Scheduler)
    let step_tokens = args.usize_or("step-tokens", 0)?;
    // 0 = no deadline; otherwise every request must finish within N ms
    // of submission or the engine retires it early (finish: "deadline")
    let deadline_ms = match args.usize_or("deadline-ms", 0)? {
        0 => None,
        ms => Some(ms as u64),
    };

    let dir = default_artifacts_dir();
    let rt = Runtime::load_with(&dir, false)?;
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))?;

    // shared system prompt for the prefix-cache workload: exactly one
    // page of tokens every request starts with (sized to --page-tokens,
    // else a larger page size would make the prefix sub-page and unshared)
    let mut sys_rng = Rng::new(7);
    let (system, _) = workload::sample_mixture(&mut sys_rng, page_tokens.max(1));

    for method in [Method::Fp16, Method::Kvmix(plan)] {
        let name = method.name();
        // long-lived scoped workers for the decode attention fan-out;
        // generated tokens are bit-identical for any --threads value
        WorkerPool::scoped(threads, |pool| -> Result<()> {
            let mut engine = Engine::with_pool(&rt, EngineCfg {
                method: method.clone(), max_batch: batch, kv_budget: None, threads,
                page_tokens, prefix_cache, step_tokens,
            }, Some(pool))?;
            let mut rng = Rng::new(42);
            for id in 0..n_requests {
                let plen = 32 + rng.below(64);
                let (tail, _) = workload::sample_mixture(&mut rng, plen);
                let prompt = if prefix_cache {
                    let mut p = system.clone();
                    p.extend_from_slice(&tail);
                    p
                } else {
                    tail
                };
                engine.submit(Request {
                    id: id as u64, prompt, max_new_tokens: max_new,
                    sampler: Sampler::TopK { k: 4, temperature: 0.8 },
                    stop_token: None, priority: 0, deadline_ms,
                    submitted_ns: 0,
                });
            }
            let t0 = std::time::Instant::now();
            let done = engine.run_to_completion()?;
            let secs = t0.elapsed().as_secs_f64();
            let gen_tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
            println!("== {name} ({} worker thread(s)) ==", pool.threads());
            println!("  {} requests, batch {}, {:.2}s wall", done.len(), batch, secs);
            println!("  decode throughput: {:.1} tok/s ({gen_tokens} tokens)",
                     gen_tokens as f64 / secs);
            let mut by_finish: BTreeMap<&'static str, usize> = BTreeMap::new();
            for c in &done {
                *by_finish.entry(c.finish.as_str()).or_default() += 1;
            }
            let breakdown: Vec<String> =
                by_finish.iter().map(|(k, v)| format!("{k} {v}")).collect();
            println!("  finish reasons: {}", breakdown.join(", "));
            // what a streaming client would see as this request's final
            // frame on the NDJSON wire (DESIGN.md §Serving-Protocol)
            if let Some(c) = done.first() {
                println!("  sample final frame: {}", proto::final_frame(c.id, c));
            }
            println!("  {}", engine.metrics.report());
            Ok(())
        })?;
    }
    Ok(())
}
