#!/usr/bin/env bash
# Tier-1 verification gate (documented in README.md):
#   build, run the full test suite, and build rustdoc with warnings denied.
# Artifact-gated tests (integration/parity/threading) skip with a notice
# when artifacts/manifest.json is absent, so this also passes pre-build.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
echo "verify: OK"
