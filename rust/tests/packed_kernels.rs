//! Bit-exactness pins for the integer-domain packed decode kernels
//! (DESIGN.md §Quantized-Kernels): `key_scores_packed` /
//! `value_accum_packed` must produce outputs whose f32 bit patterns are
//! **identical** to the unpack-based fused reference — not merely within
//! an epsilon — across every supported width, unaligned token counts,
//! nonzero channel offsets, outlier-carrying blocks and pre-accumulated
//! outputs.  The same assertions hold with and without the `simd` cargo
//! feature (the SIMD lanes use strict mul-then-add, never FMA), so
//! `cargo test` and `cargo +nightly test --features simd` pin the same
//! contract.  Hand-rolled generator loop as in rust/tests/props.rs.

use kvmix::quant::{fused, packed_dot_supported, FusedScratch, PackedBlock};
use kvmix::util::Rng;

fn for_cases(n: usize, seed0: u64, mut f: impl FnMut(u64, &mut Rng)) {
    for i in 0..n {
        let seed = seed0.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// Channel-major Key block (stream `c*tokens + t`, group = tokens).
fn key_block(rng: &mut Rng, kv_dim: usize, tokens: usize, bits: u8,
             outlier_frac: f32) -> PackedBlock {
    let data = rng.normal_vec(kv_dim * tokens);
    let mut block = PackedBlock::default();
    block.quantize_outliers_into(&data, bits, tokens, outlier_frac, &mut Vec::new());
    block
}

/// Token-major Value block (stream `t*kv_dim + c`, group = channel group).
fn value_block(rng: &mut Rng, kv_dim: usize, tokens: usize, group: usize,
               bits: u8, outlier_frac: f32) -> PackedBlock {
    let data = rng.normal_vec(tokens * kv_dim);
    let mut block = PackedBlock::default();
    block.quantize_outliers_into(&data, bits, group, outlier_frac, &mut Vec::new());
    block
}

/// Both kernels accumulate (`+=`): seed the two outputs with the *same*
/// nonzero garbage so the exactness check also pins the accumulation
/// semantics, then compare bit patterns.
fn assert_bit_identical(a: &[f32], b: &[f32], ctx: &str) {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{ctx}: out[{i}] packed {x:?} != fused {y:?}");
    }
}

#[test]
fn packed_key_bit_exact_across_shapes() {
    // every supported width x unaligned/word-aligned token counts x
    // zero and nonzero chan_offset x with/without outliers
    let kv_dim = 64;
    for_cases(60, 101, |seed, rng| {
        let bits = [1u8, 2, 4, 8][rng.below(4)];
        let tokens = [32usize, 33, 40, 352][rng.below(4)];
        let chan_offset = [0usize, 32][rng.below(2)];
        let head_dim = 32;
        let frac = [0.0f32, 0.05][rng.below(2)];
        assert!(packed_dot_supported(bits));
        let block = key_block(rng, kv_dim, tokens, bits, frac);
        let q = rng.normal_vec(head_dim);
        let seeded: Vec<f32> = (0..tokens).map(|_| rng.normal_f32()).collect();

        let mut out_p = seeded.clone();
        fused::key_scores_packed(&q, &block, tokens, chan_offset, &mut out_p);

        let mut out_f = seeded.clone();
        let mut scratch = FusedScratch::default();
        fused::key_scores_fused(&q, &block, tokens, chan_offset, &mut scratch, &mut out_f);

        assert_bit_identical(&out_p, &out_f,
            &format!("seed {seed} key bits {bits} tokens {tokens} \
                      off {chan_offset} frac {frac}"));
    });
}

#[test]
fn packed_value_bit_exact_across_shapes() {
    // configs include group-unaligned widths (group 12 is not a multiple
    // of any elems-per-word) and partial last tokens via p.len() < tokens
    for_cases(60, 202, |seed, rng| {
        let bits = [1u8, 2, 4, 8][rng.below(4)];
        // (kv_dim, group, head_dim, chan_offset)
        let (kv_dim, group, head_dim, chan_offset) =
            [(64usize, 32usize, 32usize, 0usize), (64, 32, 32, 32),
             (48, 12, 24, 0), (48, 12, 24, 12)][rng.below(4)];
        let tokens = [32usize, 33][rng.below(2)];
        let frac = [0.0f32, 0.05][rng.below(2)];
        let block = value_block(rng, kv_dim, tokens, group, bits, frac);
        let p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
        let seeded: Vec<f32> = (0..head_dim).map(|_| rng.normal_f32()).collect();

        let mut out_p = seeded.clone();
        fused::value_accum_packed(&p, &block, kv_dim, chan_offset, head_dim, &mut out_p);

        let mut out_f = seeded.clone();
        let mut scratch = FusedScratch::default();
        fused::value_accum_fused(&p, &block, kv_dim, chan_offset, head_dim,
                                 &mut scratch, &mut out_f);

        assert_bit_identical(&out_p, &out_f,
            &format!("seed {seed} value bits {bits} kv_dim {kv_dim} \
                      group {group} off {chan_offset} tokens {tokens} frac {frac}"));
    });
}

#[test]
fn dispatch_bit_exact_at_every_ladder_width() {
    // the dispatcher must be a pure router: packed where supported,
    // fused at 3-bit (Eq. 12's 11-per-word layout has no aligned words)
    let (kv_dim, tokens, head_dim) = (64usize, 33usize, 32usize);
    for_cases(40, 303, |seed, rng| {
        let bits = [1u8, 2, 3, 4][rng.below(4)];
        let kblock = key_block(rng, kv_dim, tokens, bits, 0.05);
        let q = rng.normal_vec(head_dim);

        let mut out_d = vec![0f32; tokens];
        let mut sd = FusedScratch::default();
        fused::key_scores_dispatch(&q, &kblock, tokens, 0, &mut sd, &mut out_d);
        let mut out_f = vec![0f32; tokens];
        let mut sf = FusedScratch::default();
        fused::key_scores_fused(&q, &kblock, tokens, 0, &mut sf, &mut out_f);
        assert_bit_identical(&out_d, &out_f, &format!("seed {seed} key bits {bits}"));
        if packed_dot_supported(bits) {
            assert!(sd.ints.is_empty(),
                    "packed dispatch must not touch the unpack scratch");
        }

        let vblock = value_block(rng, kv_dim, tokens, 32, bits, 0.05);
        let p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
        let mut out_d = vec![0f32; head_dim];
        let mut sd = FusedScratch::default();
        fused::value_accum_dispatch(&p, &vblock, kv_dim, 0, head_dim, &mut sd, &mut out_d);
        let mut out_f = vec![0f32; head_dim];
        let mut sf = FusedScratch::default();
        fused::value_accum_fused(&p, &vblock, kv_dim, 0, head_dim, &mut sf, &mut out_f);
        assert_bit_identical(&out_d, &out_f, &format!("seed {seed} value bits {bits}"));
    });
}

#[test]
fn packed_key_repeated_calls_keep_accumulating() {
    // three stacked calls == fused's three stacked calls, bit for bit —
    // the decode loop relies on += across heads sharing an out row
    let (kv_dim, tokens) = (64usize, 40usize);
    let mut rng = Rng::new(7);
    let block = key_block(&mut rng, kv_dim, tokens, 2, 0.0);
    let qs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(32)).collect();
    let mut out_p = vec![0f32; tokens];
    let mut out_f = vec![0f32; tokens];
    let mut scratch = FusedScratch::default();
    for q in &qs {
        fused::key_scores_packed(q, &block, tokens, 0, &mut out_p);
        fused::key_scores_fused(q, &block, tokens, 0, &mut scratch, &mut out_f);
    }
    assert_bit_identical(&out_p, &out_f, "stacked accumulation");
}
