//! Bit-exactness pins for the integer-domain packed decode kernels
//! (DESIGN.md §Quantized-Kernels): `key_scores_packed` /
//! `value_accum_packed` must produce outputs whose f32 bit patterns are
//! **identical** to the unpack-based fused reference — not merely within
//! an epsilon — across every supported width (including 3-bit Eq. 12),
//! unaligned token counts, nonzero channel offsets, outlier-carrying
//! blocks, pre-accumulated outputs, and both Key word layouts (linear
//! and channel-interleaved).  The three-way wall additionally pins the
//! default backend (SWAR on stable, `std::simd` under the `simd`
//! feature) against the word-scalar reference leg, so `cargo test` and
//! `cargo +nightly test --features simd` enforce the same contract —
//! every backend uses strict mul-then-add, never FMA.  Hand-rolled
//! generator loop as in rust/tests/props.rs.

use kvmix::quant::{fused, interleave_supported, packed_dot_supported, FusedScratch,
                   PackedBlock, TileScratch};
use kvmix::util::Rng;

fn for_cases(n: usize, seed0: u64, mut f: impl FnMut(u64, &mut Rng)) {
    for i in 0..n {
        let seed = seed0.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// Channel-major Key block (stream `c*tokens + t`, group = tokens).
fn key_block(rng: &mut Rng, kv_dim: usize, tokens: usize, bits: u8,
             outlier_frac: f32, interleave: bool) -> PackedBlock {
    let data = rng.normal_vec(kv_dim * tokens);
    let mut block = PackedBlock::default();
    block.quantize_outliers_into_layout(&data, bits, tokens, outlier_frac,
                                        interleave, &mut Vec::new());
    block
}

/// Token-major Value block (stream `t*kv_dim + c`, group = channel group).
fn value_block(rng: &mut Rng, kv_dim: usize, tokens: usize, group: usize,
               bits: u8, outlier_frac: f32) -> PackedBlock {
    let data = rng.normal_vec(tokens * kv_dim);
    let mut block = PackedBlock::default();
    block.quantize_outliers_into(&data, bits, group, outlier_frac, &mut Vec::new());
    block
}

/// Both kernels accumulate (`+=`): seed the two outputs with the *same*
/// nonzero garbage so the exactness check also pins the accumulation
/// semantics, then compare bit patterns.
fn assert_bit_identical(a: &[f32], b: &[f32], ctx: &str) {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{ctx}: out[{i}] {x:?} != {y:?}");
    }
}

#[test]
fn packed_key_bit_exact_across_shapes() {
    // every supported width x word/group-boundary-straddling token counts
    // x zero and nonzero chan_offset x with/without outliers x both word
    // layouts (interleave drawn whenever the (bits, group) shape admits it)
    let kv_dim = 64;
    for_cases(80, 101, |seed, rng| {
        let bits = [1u8, 2, 3, 4, 8][rng.below(5)];
        let tokens = [22usize, 32, 33, 40, 352][rng.below(5)];
        let chan_offset = [0usize, 32][rng.below(2)];
        let head_dim = 32;
        let frac = [0.0f32, 0.05][rng.below(2)];
        let inter = rng.below(2) == 1 && interleave_supported(bits, tokens);
        assert!(packed_dot_supported(bits));
        let block = key_block(rng, kv_dim, tokens, bits, frac, inter);
        assert_eq!(block.interleaved, inter);
        let q = rng.normal_vec(head_dim);
        let seeded: Vec<f32> = (0..tokens).map(|_| rng.normal_f32()).collect();

        let mut out_p = seeded.clone();
        fused::key_scores_packed(&q, &block, tokens, chan_offset, &mut out_p);

        let mut out_f = seeded.clone();
        let mut scratch = FusedScratch::default();
        fused::key_scores_fused(&q, &block, tokens, chan_offset, &mut scratch, &mut out_f);

        assert_bit_identical(&out_p, &out_f,
            &format!("seed {seed} key bits {bits} tokens {tokens} \
                      off {chan_offset} frac {frac} inter {inter}"));
    });
}

#[test]
fn packed_value_bit_exact_across_shapes() {
    // configs include group-unaligned widths (group 12 is not a multiple
    // of any elems-per-word) and partial last tokens via p.len() < tokens
    for_cases(80, 202, |seed, rng| {
        let bits = [1u8, 2, 3, 4, 8][rng.below(5)];
        // (kv_dim, group, head_dim, chan_offset)
        let (kv_dim, group, head_dim, chan_offset) =
            [(64usize, 32usize, 32usize, 0usize), (64, 32, 32, 32),
             (48, 12, 24, 0), (48, 12, 24, 12)][rng.below(4)];
        let tokens = [32usize, 33][rng.below(2)];
        let frac = [0.0f32, 0.05][rng.below(2)];
        let block = value_block(rng, kv_dim, tokens, group, bits, frac);
        let mut p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
        p[tokens / 2] = 0.0; // exact-zero weight: pins the skip-row guard
        let seeded: Vec<f32> = (0..head_dim).map(|_| rng.normal_f32()).collect();

        let mut out_p = seeded.clone();
        fused::value_accum_packed(&p, &block, kv_dim, chan_offset, head_dim, &mut out_p);

        let mut out_f = seeded.clone();
        let mut scratch = FusedScratch::default();
        fused::value_accum_fused(&p, &block, kv_dim, chan_offset, head_dim,
                                 &mut scratch, &mut out_f);

        assert_bit_identical(&out_p, &out_f,
            &format!("seed {seed} value bits {bits} kv_dim {kv_dim} \
                      group {group} off {chan_offset} tokens {tokens} frac {frac}"));
    });
}

#[test]
fn three_way_backends_bit_identical() {
    // SWAR/simd default leg == word-scalar reference leg == unpack-based
    // fused oracle, bit for bit.  Without `--features simd` this pins
    // SWAR == scalar; with it, the identical assertions pin the
    // `std::simd` backend against the same scalar reference.
    let kv_dim = 64;
    for_cases(60, 404, |seed, rng| {
        let bits = [1u8, 2, 3, 4, 8][rng.below(5)];
        let tokens = [32usize, 33, 40, 352][rng.below(4)];
        let inter = rng.below(2) == 1 && interleave_supported(bits, tokens);
        let frac = 0.03;
        let ctx = format!("seed {seed} bits {bits} tokens {tokens} inter {inter}");

        let kblock = key_block(rng, kv_dim, tokens, bits, frac, inter);
        let q = rng.normal_vec(32);
        let seeded: Vec<f32> = (0..tokens).map(|_| rng.normal_f32()).collect();
        let mut out_default = seeded.clone();
        fused::key_scores_packed(&q, &kblock, tokens, 0, &mut out_default);
        let mut out_ref = seeded.clone();
        fused::key_scores_packed_ref(&q, &kblock, tokens, 0, &mut out_ref);
        let mut out_fused = seeded.clone();
        let mut s = FusedScratch::default();
        fused::key_scores_fused(&q, &kblock, tokens, 0, &mut s, &mut out_fused);
        assert_bit_identical(&out_default, &out_ref, &format!("{ctx} key default/ref"));
        assert_bit_identical(&out_ref, &out_fused, &format!("{ctx} key ref/fused"));

        let vblock = value_block(rng, kv_dim, tokens, 32, bits, frac);
        let mut p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
        p[0] = 0.0;
        let vseed: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
        let mut v_default = vseed.clone();
        fused::value_accum_packed(&p, &vblock, kv_dim, 32, 32, &mut v_default);
        let mut v_ref = vseed.clone();
        fused::value_accum_packed_ref(&p, &vblock, kv_dim, 32, 32, &mut v_ref);
        let mut v_fused = vseed.clone();
        let mut s = FusedScratch::default();
        fused::value_accum_fused(&p, &vblock, kv_dim, 32, 32, &mut s, &mut v_fused);
        assert_bit_identical(&v_default, &v_ref, &format!("{ctx} value default/ref"));
        assert_bit_identical(&v_ref, &v_fused, &format!("{ctx} value ref/fused"));
    });
}

#[test]
fn tiled_group_kernels_match_per_head_calls() {
    // head tiling (decode each packed field once per KV group) must be a
    // pure reassociation of the loop nest: rep per-head kernel calls and
    // one group call produce identical bit patterns, both layouts
    let (kv_dim, head_dim, tokens) = (64usize, 32usize, 40usize);
    for_cases(60, 505, |seed, rng| {
        let bits = [1u8, 2, 3, 4, 8][rng.below(5)];
        let rep = [1usize, 2, 4][rng.below(3)];
        let inter = rng.below(2) == 1 && interleave_supported(bits, tokens);
        let chan_offset = [0usize, head_dim][rng.below(2)];
        let stride = tokens + 3; // rows deliberately non-contiguous
        let ctx = format!("seed {seed} bits {bits} rep {rep} inter {inter} \
                           off {chan_offset}");

        let kblock = key_block(rng, kv_dim, tokens, bits, 0.04, inter);
        let q = rng.normal_vec(rep * head_dim);
        let seeded: Vec<f32> = (0..rep * stride).map(|_| rng.normal_f32()).collect();
        let mut out_g = seeded.clone();
        let mut tile = TileScratch::default();
        fused::key_scores_group_packed(&q, rep, &kblock, tokens, chan_offset,
                                       &mut out_g, stride, &mut tile);
        let mut out_h = seeded.clone();
        for r in 0..rep {
            fused::key_scores_packed(&q[r * head_dim..(r + 1) * head_dim], &kblock,
                                     tokens, chan_offset,
                                     &mut out_h[r * stride..r * stride + tokens]);
        }
        assert_bit_identical(&out_g, &out_h, &format!("{ctx} key group/per-head"));

        // the reference leg of the group kernel must agree too
        let mut out_r = seeded.clone();
        fused::key_scores_group_ref(&q, rep, &kblock, tokens, chan_offset,
                                    &mut out_r, stride, &mut tile);
        assert_bit_identical(&out_r, &out_g, &format!("{ctx} key group ref"));

        let vblock = value_block(rng, kv_dim, tokens, 32, bits, 0.04);
        let mut p: Vec<f32> = (0..rep * stride).map(|_| rng.f32()).collect();
        p[stride / 2] = 0.0; // one head skips a token the others keep
        let vseed: Vec<f32> = (0..rep * head_dim).map(|_| rng.normal_f32()).collect();
        let mut v_g = vseed.clone();
        fused::value_accum_group_packed(&p, stride, rep, &vblock, kv_dim, chan_offset,
                                        head_dim, &mut v_g, &mut tile);
        let mut v_h = vseed.clone();
        for r in 0..rep {
            fused::value_accum_packed(&p[r * stride..r * stride + tokens], &vblock,
                                      kv_dim, chan_offset, head_dim,
                                      &mut v_h[r * head_dim..(r + 1) * head_dim]);
        }
        assert_bit_identical(&v_g, &v_h, &format!("{ctx} value group/per-head"));

        let mut v_r = vseed.clone();
        fused::value_accum_group_ref(&p, stride, rep, &vblock, kv_dim, chan_offset,
                                     head_dim, &mut v_r, &mut tile);
        assert_bit_identical(&v_r, &v_g, &format!("{ctx} value group ref"));
    });
}

#[test]
fn interleaved_key_layout_bit_identical_to_linear() {
    // the channel-interleaved word order is a pure permutation: same
    // data quantized under both layouts must score identically, bit for
    // bit, through single-head and group kernels alike
    let (kv_dim, head_dim) = (64usize, 16usize);
    for_cases(40, 606, |seed, rng| {
        let bits = [1u8, 2, 4, 8][rng.below(4)];
        let tokens = [32usize, 64, 352][rng.below(3)];
        assert!(interleave_supported(bits, tokens));
        let data = rng.normal_vec(kv_dim * tokens);
        let mut lin = PackedBlock::default();
        lin.quantize_outliers_into_layout(&data, bits, tokens, 0.02, false,
                                          &mut Vec::new());
        let mut ilv = PackedBlock::default();
        ilv.quantize_outliers_into_layout(&data, bits, tokens, 0.02, true,
                                          &mut Vec::new());
        assert!(!lin.interleaved && ilv.interleaved);
        assert_eq!(lin.scales, ilv.scales, "layout must not change quantization");

        let q = rng.normal_vec(head_dim);
        let mut out_lin = vec![0f32; tokens];
        fused::key_scores_packed(&q, &lin, tokens, 16, &mut out_lin);
        let mut out_ilv = vec![0f32; tokens];
        fused::key_scores_packed(&q, &ilv, tokens, 16, &mut out_ilv);
        assert_bit_identical(&out_lin, &out_ilv,
                             &format!("seed {seed} bits {bits} tokens {tokens}"));

        let rep = 2;
        let qg = rng.normal_vec(rep * head_dim);
        let mut tile = TileScratch::default();
        let mut g_lin = vec![0f32; rep * tokens];
        fused::key_scores_group_packed(&qg, rep, &lin, tokens, 0, &mut g_lin,
                                       tokens, &mut tile);
        let mut g_ilv = vec![0f32; rep * tokens];
        fused::key_scores_group_packed(&qg, rep, &ilv, tokens, 0, &mut g_ilv,
                                       tokens, &mut tile);
        assert_bit_identical(&g_lin, &g_ilv,
                             &format!("seed {seed} group bits {bits} tokens {tokens}"));
    });
}

#[test]
fn dispatch_bit_exact_at_every_ladder_width() {
    // the dispatcher must be a pure router: every ladder width — 3-bit
    // Eq. 12 included since its cursor-walking packed rows landed — goes
    // packed and must never touch the unpack scratch
    let (kv_dim, tokens, head_dim) = (64usize, 33usize, 32usize);
    for_cases(40, 303, |seed, rng| {
        let bits = [1u8, 2, 3, 4, 8][rng.below(5)];
        let kblock = key_block(rng, kv_dim, tokens, bits, 0.05, false);
        let q = rng.normal_vec(head_dim);

        let mut out_d = vec![0f32; tokens];
        let mut sd = FusedScratch::default();
        fused::key_scores_dispatch(&q, &kblock, tokens, 0, &mut sd, &mut out_d);
        let mut out_f = vec![0f32; tokens];
        let mut sf = FusedScratch::default();
        fused::key_scores_fused(&q, &kblock, tokens, 0, &mut sf, &mut out_f);
        assert_bit_identical(&out_d, &out_f, &format!("seed {seed} key bits {bits}"));
        assert!(packed_dot_supported(bits));
        assert!(sd.ints.is_empty(),
                "packed dispatch must not touch the unpack scratch");

        let vblock = value_block(rng, kv_dim, tokens, 32, bits, 0.05);
        let p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
        let mut out_d = vec![0f32; head_dim];
        let mut sd = FusedScratch::default();
        fused::value_accum_dispatch(&p, &vblock, kv_dim, 0, head_dim, &mut sd, &mut out_d);
        let mut out_f = vec![0f32; head_dim];
        let mut sf = FusedScratch::default();
        fused::value_accum_fused(&p, &vblock, kv_dim, 0, head_dim, &mut sf, &mut out_f);
        assert_bit_identical(&out_d, &out_f, &format!("seed {seed} value bits {bits}"));
        assert!(sd.ints.is_empty(),
                "packed value dispatch must not touch the unpack scratch");
    });
}

#[test]
fn packed_key_repeated_calls_keep_accumulating() {
    // three stacked calls == fused's three stacked calls, bit for bit —
    // the decode loop relies on += across heads sharing an out row
    let (kv_dim, tokens) = (64usize, 40usize);
    let mut rng = Rng::new(7);
    let block = key_block(&mut rng, kv_dim, tokens, 2, 0.0, false);
    let qs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(32)).collect();
    let mut out_p = vec![0f32; tokens];
    let mut out_f = vec![0f32; tokens];
    let mut scratch = FusedScratch::default();
    for q in &qs {
        fused::key_scores_packed(q, &block, tokens, 0, &mut out_p);
        fused::key_scores_fused(q, &block, tokens, 0, &mut scratch, &mut out_f);
    }
    assert_bit_identical(&out_p, &out_f, "stacked accumulation");
}
