//! Property-based tests (hand-rolled generator loop — proptest is not
//! available offline).  Each property runs a few hundred randomized cases
//! seeded deterministically; failures print the seed for replay.

use kvmix::config::{ModelConfig, QuantPlan};
use kvmix::kvcache::pages::page_frame_bytes;
use kvmix::kvcache::pressure::{downshift_one, downshift_one_side, reclaimable_bytes};
use kvmix::kvcache::{
    AttnScratch, KeyRepr, KvSide, LayerCacheCfg, LayerKvCache, PagePool,
    PressureCfg, SeqKvCache, ValueRepr, WindowPolicy, KV_SIDES,
};
use kvmix::quant::{pack_stream, qmax_at, unpack_stream, words_for, PackedBlock};
use kvmix::util::json;
use kvmix::util::Rng;

fn for_cases(n: usize, seed0: u64, mut f: impl FnMut(u64, &mut Rng)) {
    for i in 0..n {
        let seed = seed0.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

#[test]
fn prop_pack_roundtrip() {
    for_cases(300, 1, |seed, rng| {
        let bits = [1u8, 2, 3, 4][rng.below(4)];
        let n = rng.range(1, 600);
        let q: Vec<u32> = (0..n).map(|i| rng.below(qmax_at(bits, i) as usize + 1) as u32).collect();
        let mut words = Vec::new();
        pack_stream(&q, bits, &mut words);
        assert_eq!(words.len(), words_for(n, bits), "seed {seed}");
        let mut out = vec![0u32; n];
        unpack_stream(&words, bits, n, &mut out);
        assert_eq!(out, q, "seed {seed} bits {bits} n {n}");
    });
}

#[test]
fn prop_quant_error_bounded() {
    // per-element |x - x~| <= s/2 except 3-bit Eq.12 2-bit slots
    for_cases(150, 2, |seed, rng| {
        let bits = [1u8, 2, 4][rng.below(3)];
        let groups = rng.range(1, 6);
        let scale = rng.uniform(0.01, 20.0) as f32;
        let data: Vec<f32> = (0..groups * 32).map(|_| rng.normal_f32() * scale).collect();
        let b = PackedBlock::quantize(&data, bits, 32);
        let mut out = vec![0f32; data.len()];
        b.dequantize_into(&mut out, &mut Vec::new());
        for (g, chunk) in data.chunks(32).enumerate() {
            let s = b.scales[g];
            for (i, &x) in chunk.iter().enumerate() {
                let err = (out[g * 32 + i] - x).abs();
                assert!(err <= s / 2.0 + s * 1e-3 + 1e-6,
                        "seed {seed} bits {bits} err {err} s {s}");
            }
        }
    });
}

#[test]
fn prop_quant_idempotent() {
    // quantizing an already-dequantized stream is exact (fixed point)
    for_cases(80, 3, |seed, rng| {
        let bits = [1u8, 2, 4][rng.below(3)];
        let data: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let b1 = PackedBlock::quantize(&data, bits, 32);
        let mut d1 = vec![0f32; 64];
        b1.dequantize_into(&mut d1, &mut Vec::new());
        let b2 = PackedBlock::quantize(&d1, bits, 32);
        let mut d2 = vec![0f32; 64];
        b2.dequantize_into(&mut d2, &mut Vec::new());
        for (a, b) in d1.iter().zip(&d2) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "seed {seed}: {a} vs {b}");
        }
    });
}

#[test]
fn prop_outliers_reduce_error() {
    for_cases(60, 4, |seed, rng| {
        let mut data: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
        // inject heavy outliers
        for _ in 0..4 {
            let i = rng.below(128);
            data[i] = (rng.normal_f32()) * 40.0;
        }
        let plain = kvmix::quant::quant_error(&PackedBlock::quantize(&data, 3, 32), &data);
        let mut ob = PackedBlock::default();
        ob.quantize_outliers_into(&data, 3, 32, 0.05, &mut Vec::new());
        let with_out = kvmix::quant::quant_error(&ob, &data);
        assert!(with_out.mse <= plain.mse + 1e-9,
                "seed {seed}: outlier mse {} > plain {}", with_out.mse, plain.mse);
    });
}

#[test]
fn prop_window_policies() {
    for_cases(200, 5, |seed, rng| {
        let ratio = rng.f64();
        let current = rng.below(4096);
        let keep = WindowPolicy::Rpc { ratio }.keep(current);
        assert!(keep <= current, "seed {seed}");
        assert_eq!(keep, ((ratio * current as f64).floor() as usize).min(current));
        let blocks = WindowPolicy::Rpc { ratio }.blocks_to_quantize(current, 32);
        assert!(blocks * 32 <= current - keep, "seed {seed}");
        // fixed residual never goes below min(tokens, current)
        let t = rng.below(256);
        assert_eq!(WindowPolicy::FixedResidual { tokens: t }.keep(current), t.min(current));
    });
}

#[test]
fn prop_cache_token_accounting() {
    // k_hist + k_fp == v_hist + v_fp == total appended, hist % group == 0
    for_cases(40, 6, |seed, rng| {
        let kv_dim = 64;
        let cfg = LayerCacheCfg {
            kv_dim, head_dim: 32, group: 32,
            key: KeyRepr::PerChannel { bits: [1u8, 2, 3, 4][rng.below(4)] },
            value: ValueRepr::PerToken { bits: [1u8, 2, 4][rng.below(3)] },
            k_window: WindowPolicy::Rpc { ratio: rng.f64() * 0.5 },
            v_window: WindowPolicy::Rpc { ratio: rng.f64() * 0.5 },
            outlier_frac: 0.0,
            k_interleave: rng.below(2) == 1,
        };
        let mut cache = LayerKvCache::new(cfg);
        let mut total = 0usize;
        for _ in 0..rng.range(1, 30) {
            let n = rng.range(1, 40);
            let k = rng.normal_vec(n * kv_dim);
            let v = rng.normal_vec(n * kv_dim);
            cache.append(&k, &v, n);
            total += n;
            assert_eq!(cache.k_hist + cache.k_fp_tokens(), total, "seed {seed}");
            assert_eq!(cache.v_hist + cache.v_fp_tokens(), total, "seed {seed}");
            assert_eq!(cache.k_hist % 32, 0, "seed {seed}");
            assert_eq!(cache.len(), total);
        }
    });
}

#[test]
fn prop_cache_bytes_bounded_by_fp16_equivalent() {
    // quantized bytes never exceed the fp16-modeled cache, and the
    // long-run average bytes/token stays near the bit-plan prediction
    for_cases(20, 7, |seed, rng| {
        let kv_dim = 64;
        let bits = [2u8, 3, 4][rng.below(3)];
        let cfg = LayerCacheCfg {
            kv_dim, head_dim: 32, group: 32,
            key: KeyRepr::PerChannel { bits },
            value: ValueRepr::PerToken { bits },
            k_window: WindowPolicy::Rpc { ratio: 0.1 },
            v_window: WindowPolicy::Rpc { ratio: 0.1 },
            outlier_frac: 0.0,
            k_interleave: rng.below(2) == 1,
        };
        let mut cache = LayerKvCache::new(cfg);
        let mut total = 0usize;
        for _ in 0..30 {
            let n = rng.range(8, 24);
            cache.append(&rng.normal_vec(n * kv_dim), &rng.normal_vec(n * kv_dim), n);
            total += n;
            let fp16 = total * kv_dim * 2 * 2;
            assert!(cache.modeled_bytes() <= fp16, "seed {seed}");
        }
        // steady state (>=240 tokens): compression within ~half of the
        // ideal 16/bits (fp RPC window + group remainder eat the rest)
        let ratio = (total * kv_dim * 2 * 2) as f64 / cache.modeled_bytes() as f64;
        let floor = 16.0 / bits as f64 * 0.45;
        assert!(ratio > floor, "seed {seed}: compression only {ratio:.2}x at {bits} bits ({total} tokens)");
    });
}

#[test]
fn prop_attend_probability_simplex() {
    // with v == all-ones the attention output must be exactly ones
    for_cases(30, 8, |seed, rng| {
        let kv_dim = 64;
        let n = rng.range(33, 128);
        let cfg = LayerCacheCfg {
            kv_dim, head_dim: 32, group: 32,
            key: KeyRepr::PerChannel { bits: [2u8, 4][rng.below(2)] },
            value: ValueRepr::PerToken { bits: 4 },
            k_window: WindowPolicy::Rpc { ratio: 0.2 },
            v_window: WindowPolicy::Rpc { ratio: 0.2 },
            outlier_frac: 0.0,
            k_interleave: rng.below(2) == 1,
        };
        let mut cache = LayerKvCache::new(cfg);
        let k = rng.normal_vec(n * kv_dim);
        let v = vec![1f32; n * kv_dim];
        cache.append(&k, &v, n);
        let q = rng.normal_vec(4 * 32);
        let mut out = vec![0f32; 4 * 32];
        cache.attend(&q, 4, &mut out, &mut AttnScratch::default());
        for x in out {
            // constant-value groups quantize losslessly, so ones survive
            assert!((x - 1.0).abs() < 1e-4, "seed {seed}: {x}");
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    for_cases(100, 9, |seed, rng| {
        // random float vectors survive serialize->parse
        let v: Vec<f64> = (0..rng.range(0, 50)).map(|_| (rng.normal() * 100.0).round() / 16.0).collect();
        let j = json::Json::from_f64s(&v);
        let back = json::parse(&j.to_string()).unwrap();
        assert_eq!(back.f64_vec().unwrap(), v, "seed {seed}");
    });
}

#[test]
fn prop_page_pool_accounting_under_random_interleaving() {
    // ROADMAP 5b: drive the paged pool through seeded random interleavings
    // of admit (with prefix adoption), decode append, pressure downshift,
    // cancel/preempt (free_owner), prefix registration, LRU eviction,
    // disk spill, and fault-back — auditing after every op that the O(1)
    // byte counter matches a full frame scan, refcounts equal their
    // mappings (never underflow), free lists are duplicate-free, spilled
    // bytes leave `modeled_bytes` exactly, the disk tier's used bytes
    // equal the live spilled extents, and cancellation frees exactly the
    // bytes of the frames the request's table owned exclusively.
    const PT: usize = 64;
    let spill_dir = std::env::temp_dir()
        .join(format!("kvmix-spill-props-{}", std::process::id()));
    for_cases(25, 11, |seed, rng| {
        let m = ModelConfig::test_small();
        // eager 4-bit plan: whole groups quantize at append (maximally
        // shareable), with downshift headroom above the 2-bit floor
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let pcfg = PressureCfg::uniform(m.n_layers, 2);
        let kv = m.kv_dim();
        let mut pool = PagePool::new(PT, kv, m.group).unwrap();
        pool.enable_prefix_cache();
        pool.enable_spill(&spill_dir, 0).unwrap();
        let audit = |pool: &PagePool, op: &str| {
            if let Err(e) = pool.verify_accounting() {
                panic!("seed {seed} after {op}: {e}");
            }
        };
        // shared head all admissions draw from, so page-aligned prefixes
        // collide across sequences and adoption actually happens
        let base: Vec<i32> = (0..(2 * PT) as i32)
            .map(|i| (seed % 251) as i32 + i)
            .collect();
        let mut live: Vec<(u64, SeqKvCache, Vec<i32>)> = Vec::new();
        let mut next_owner = 0u64;
        let free_and_check = |pool: &mut PagePool, id: u64, seed: u64| {
            let before = pool.modeled_bytes();
            let exclusive = pool.owner_exclusive_bytes(id);
            pool.free_owner(id);
            assert_eq!(before - pool.modeled_bytes(), exclusive,
                       "seed {seed}: freeing owner {id} must reclaim exactly \
                        its exclusively-owned frames");
            assert_eq!(pool.owner_pages(id), 0, "seed {seed}");
        };
        for op in 0..40 {
            match rng.below(10) {
                // admit a fresh sequence, adopting any registered prefix
                0 | 1 => {
                    next_owner += 1;
                    let id = next_owner;
                    let mut prompt = base[..(1 + rng.below(2)) * PT].to_vec();
                    for j in 0..rng.below(2) * PT + rng.below(32) {
                        prompt.push(100_000 + id as i32 * 500 + j as i32);
                    }
                    let total = prompt.len();
                    let mut cache = SeqKvCache::new(&m, &plan);
                    let cap = cache.max_shareable_prefix(total, PT);
                    let adopted = pool.adopt_prefix(id, &prompt, cap, &mut cache);
                    assert!(adopted <= cap && adopted % PT == 0, "seed {seed}");
                    let k = rng.normal_vec(total * kv);
                    let v = rng.normal_vec(total * kv);
                    for l in &mut cache.layers {
                        if adopted > 0 {
                            l.append_prefill_suffix(&k[adopted * kv..],
                                                    &v[adopted * kv..],
                                                    total - adopted, adopted);
                        } else {
                            l.append(&k, &v, total);
                        }
                    }
                    pool.sync(id, &cache);
                    live.push((id, cache, prompt));
                    audit(&pool, &format!("admit #{op}"));
                }
                // decode: append a few tokens and reconcile the table
                2 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.below(live.len());
                    let n = rng.range(1, 8);
                    let k = rng.normal_vec(n * kv);
                    let v = rng.normal_vec(n * kv);
                    for l in &mut live[i].1.layers {
                        l.append(&k, &v, n);
                    }
                    pool.sync(live[i].0, &live[i].1);
                    audit(&pool, &format!("decode #{op}"));
                }
                // pressure: one downshift rung (shared pages are exempt)
                3 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.below(live.len());
                    let _ = downshift_one(&mut live[i].1, PT, &pcfg);
                    pool.sync(live[i].0, &live[i].1);
                    audit(&pool, &format!("downshift #{op}"));
                }
                // cancel / preempt: both retire through free_owner
                4 => {
                    if live.is_empty() {
                        continue;
                    }
                    let (id, _, _) = live.remove(rng.below(live.len()));
                    free_and_check(&mut pool, id, seed);
                    audit(&pool, &format!("cancel #{op}"));
                }
                // prefix index churn: register a donor or evict the LRU
                5 => {
                    if rng.bool(0.6) && !live.is_empty() {
                        let (id, cache, prompt) = &live[rng.below(live.len())];
                        let cap = cache.max_shareable_prefix(prompt.len(), PT);
                        let _ = pool.register_prefix(*id, prompt, cap, cache);
                    } else {
                        let _ = pool.evict_lru_prefix();
                    }
                    audit(&pool, &format!("prefix #{op}"));
                }
                // side-restricted pressure: one K-only / V-only rung
                // (DESIGN.md §Pressure-Ladder)
                6 => {
                    if live.is_empty() {
                        continue;
                    }
                    let side = if rng.bool(0.5) { KvSide::Key } else { KvSide::Value };
                    let i = rng.below(live.len());
                    if let Some(d) = downshift_one_side(&mut live[i].1, PT, &pcfg, side) {
                        assert_eq!(d.side, side, "seed {seed}");
                    }
                    pool.sync(live[i].0, &live[i].1);
                    audit(&pool, &format!("side-downshift #{op}"));
                }
                // spill: push one sealed cold page to the disk tier
                // (DESIGN.md §Spill-Tier)
                7 | 8 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.below(live.len());
                    let before = pool.modeled_bytes();
                    let parked = pool.spilled_pages();
                    if let Some(freed) =
                        pool.spill_one(live[i].0, &mut live[i].1, rng.bool(0.5))
                    {
                        assert_eq!(pool.modeled_bytes(), before - freed,
                                   "seed {seed}: spilled bytes must leave \
                                    modeled_bytes exactly");
                        assert_eq!(pool.spilled_pages(), parked + 1, "seed {seed}");
                        assert!(live[i].1.layers.iter().any(|l| l.any_spilled()),
                                "seed {seed}: spill must leave a cache stub");
                    }
                    audit(&pool, &format!("spill #{op}"));
                }
                // fault-back: restore every spilled page of one owner
                _ => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.below(live.len());
                    let before = pool.modeled_bytes();
                    let n = pool.fault_back_owner(live[i].0, &mut live[i].1);
                    assert!(pool.modeled_bytes() >= before, "seed {seed}");
                    assert!(!live[i].1.layers.iter().any(|l| l.any_spilled()),
                            "seed {seed}: fault-back ({n} pages) must clear \
                             every stub of the owner");
                    audit(&pool, &format!("fault-back #{op}"));
                }
            }
            // per-side floor invariant: no live page may ever sit below
            // its (layer, side) floor, whatever interleaving got us here
            for (_, cache, _) in &live {
                for (li, l) in cache.layers.iter().enumerate() {
                    for &s in &KV_SIDES {
                        for p in 0..l.sealed_quant_pages(s, PT) {
                            assert!(l.quant_page_bits(s, p, PT) >= pcfg.floor(li, s),
                                    "seed {seed} op {op}: page below side floor");
                        }
                    }
                }
            }
        }
        // teardown drains to zero: every sequence retires, then the
        // index — nothing may leak and no refcount may dangle
        for (id, _, _) in live.drain(..) {
            free_and_check(&mut pool, id, seed);
            audit(&pool, "teardown free");
        }
        while pool.evict_lru_prefix().is_some() {
            audit(&pool, "teardown evict");
        }
        assert_eq!(pool.modeled_bytes(), 0, "seed {seed}: pool must drain");
        assert_eq!(pool.allocated_pages(), 0, "seed {seed}");
        assert_eq!(pool.spilled_pages(), 0,
                   "seed {seed}: freeing owners must release spilled frames");
        assert_eq!(pool.spill_used_bytes(), 0,
                   "seed {seed}: the disk tier must drain with the pool");
    });
    let _ = std::fs::remove_dir_all(&spill_dir);
}

#[test]
fn prop_spill_fault_back_is_bit_identical() {
    // DESIGN.md §Spill-Tier: a spill→fault-back round trip restores every
    // packed block field-for-field — words, scales, mins, outliers, bits,
    // n — so attention after a fault is bit-identical to never having
    // spilled.  Only the unpack-cache uid is fresh (stale-cache safety).
    const PT: usize = 64;
    let dir = std::env::temp_dir()
        .join(format!("kvmix-spill-rt-{}", std::process::id()));
    for_cases(20, 13, |seed, rng| {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let kv = m.kv_dim();
        let mut pool = PagePool::new(PT, kv, m.group).unwrap();
        pool.enable_spill(&dir, 0).unwrap();
        let tokens = PT * rng.range(1, 4);
        let mut cache = SeqKvCache::new(&m, &plan);
        let k = rng.normal_vec(tokens * kv);
        let v = rng.normal_vec(tokens * kv);
        for l in &mut cache.layers {
            l.append(&k, &v, tokens);
        }
        pool.sync(7, &cache);
        // snapshot Arcs before spilling: take_spill_page swaps in stub
        // Arcs, so these still hold the original payloads
        let snap: Vec<Vec<_>> = cache.layers.iter()
            .map(|l| KV_SIDES.iter()
                .flat_map(|&s| l.quant_blocks(s).iter().cloned())
                .collect())
            .collect();
        let mut spilled = 0usize;
        while pool.spill_one(7, &mut cache, rng.bool(0.5)).is_some() {
            spilled += 1;
        }
        assert!(spilled > 0, "seed {seed}: sealed exclusive pages must spill");
        assert_eq!(pool.fault_back_owner(7, &mut cache), spilled, "seed {seed}");
        assert!(pool.verify_accounting().is_ok(), "seed {seed}");
        for (li, l) in cache.layers.iter().enumerate() {
            let now: Vec<_> = KV_SIDES.iter()
                .flat_map(|&s| l.quant_blocks(s).iter().cloned())
                .collect();
            assert_eq!(now.len(), snap[li].len(), "seed {seed}");
            for (a, b) in snap[li].iter().zip(&now) {
                assert_eq!((a.bits, a.n, a.group), (b.bits, b.n, b.group),
                           "seed {seed}: block geometry must round-trip");
                assert_eq!(a.words, b.words, "seed {seed}: packed words differ");
                assert_eq!(a.scales, b.scales, "seed {seed}: scales differ");
                assert_eq!(a.mins, b.mins, "seed {seed}: mins differ");
                assert_eq!(a.outliers, b.outliers, "seed {seed}: outliers differ");
            }
        }
        pool.free_owner(7);
        assert_eq!(pool.modeled_bytes(), 0, "seed {seed}");
        assert_eq!(pool.spill_used_bytes(), 0, "seed {seed}");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_shared_and_adopted_pages_are_spill_exempt() {
    // DESIGN.md §Spill-Tier / ADR-008: only sealed, exclusively-owned
    // pages may spill.  Frames pinned by the prefix index or shared with
    // an adopter must never leave memory — other sequences attend to
    // them — while a third owner's exclusive pages spill freely.
    const PT: usize = 64;
    let dir = std::env::temp_dir()
        .join(format!("kvmix-spill-shared-{}", std::process::id()));
    for_cases(15, 14, |seed, rng| {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let kv = m.kv_dim();
        let mut pool = PagePool::new(PT, kv, m.group).unwrap();
        pool.enable_prefix_cache();
        pool.enable_spill(&dir, 0).unwrap();
        // donor: page-aligned prompt registered in the prefix index
        let prompt: Vec<i32> =
            (0..(2 * PT) as i32).map(|i| (seed % 97) as i32 + i).collect();
        let mut donor = SeqKvCache::new(&m, &plan);
        let k = rng.normal_vec(prompt.len() * kv);
        let v = rng.normal_vec(prompt.len() * kv);
        for l in &mut donor.layers {
            l.append(&k, &v, prompt.len());
        }
        pool.sync(1, &donor);
        let cap = donor.max_shareable_prefix(prompt.len(), PT);
        assert!(pool.register_prefix(1, &prompt, cap, &donor), "seed {seed}");
        // adopter: same head plus a private suffix — donor pages now shared
        let mut ext = prompt.clone();
        for j in 0..PT + rng.below(32) {
            ext.push(100_000 + j as i32);
        }
        let mut adopter = SeqKvCache::new(&m, &plan);
        let cap2 = adopter.max_shareable_prefix(ext.len(), PT);
        let adopted = pool.adopt_prefix(2, &ext, cap2, &mut adopter);
        assert_eq!(adopted, prompt.len(), "seed {seed}: whole head adopts");
        let k2 = rng.normal_vec(ext.len() * kv);
        let v2 = rng.normal_vec(ext.len() * kv);
        for l in &mut adopter.layers {
            l.append_prefill_suffix(&k2[adopted * kv..], &v2[adopted * kv..],
                                    ext.len() - adopted, adopted);
        }
        pool.sync(2, &adopter);
        assert!(pool.spill_one(1, &mut donor, rng.bool(0.5)).is_none(),
                "seed {seed}: index-pinned donor frames must be spill-exempt");
        // the adopter's own suffix pages (if any sealed) may spill, but
        // its adopted head pages may not: spill everything it will give
        // up, then verify the shared head is still resident
        while pool.spill_one(2, &mut adopter, rng.bool(0.5)).is_some() {}
        for l in &adopter.layers {
            for &s in &KV_SIDES {
                for p in 0..adopted / PT {
                    assert!(!l.quant_page_spilled(s, p, PT),
                            "seed {seed}: adopted head page {p} spilled");
                }
            }
        }
        // a third owner with exclusive sealed pages spills immediately
        let mut third = SeqKvCache::new(&m, &plan);
        let k3 = rng.normal_vec(PT * kv);
        let v3 = rng.normal_vec(PT * kv);
        for l in &mut third.layers {
            l.append(&k3, &v3, PT);
        }
        pool.sync(3, &third);
        assert!(pool.spill_one(3, &mut third, rng.bool(0.5)).is_some(),
                "seed {seed}: exclusive sealed pages must spill");
        if let Err(e) = pool.verify_accounting() {
            panic!("seed {seed}: {e}");
        }
        for id in [1, 2, 3] {
            pool.free_owner(id);
        }
        while pool.evict_lru_prefix().is_some() {}
        assert_eq!(pool.modeled_bytes(), 0, "seed {seed}");
        assert_eq!(pool.spill_used_bytes(), 0, "seed {seed}");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_spill_relieves_pressure_without_preemption_and_respects_cap() {
    // The ladder-ordering pin at pool level (DESIGN.md §Spill-Tier): an
    // over-budget pool with spill headroom sheds modeled bytes page by
    // page WITHOUT freeing any owner — every sequence keeps its table and
    // its tokens — and a byte-capped tier stops exactly at the cap
    // instead of overrunning it, leaving the rest for preemption.
    const PT: usize = 64;
    let dir = std::env::temp_dir()
        .join(format!("kvmix-spill-cap-{}", std::process::id()));
    for_cases(15, 15, |seed, rng| {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let kv = m.kv_dim();
        let mut pool = PagePool::new(PT, kv, m.group).unwrap();
        pool.enable_spill(&dir, 0).unwrap();
        let mut owners: Vec<(u64, SeqKvCache)> = Vec::new();
        for id in 1..=3u64 {
            let tokens = PT * rng.range(1, 3);
            let mut cache = SeqKvCache::new(&m, &plan);
            let k = rng.normal_vec(tokens * kv);
            let v = rng.normal_vec(tokens * kv);
            for l in &mut cache.layers {
                l.append(&k, &v, tokens);
            }
            pool.sync(id, &cache);
            owners.push((id, cache));
        }
        let before = pool.modeled_bytes();
        let mut freed = 0usize;
        loop {
            let i = rng.below(owners.len());
            let (id, cache) = &mut owners[i];
            match pool.spill_one(*id, cache, false) {
                Some(b) => freed += b,
                // this owner drained: sweep the rest, stop when nobody
                // has headroom (crediting any page the sweep spills)
                None => {
                    let mut any = false;
                    for (id, c) in owners.iter_mut() {
                        if let Some(b) = pool.spill_one(*id, c, false) {
                            freed += b;
                            any = true;
                        }
                    }
                    if !any {
                        break;
                    }
                }
            }
        }
        assert!(freed > 0, "seed {seed}");
        assert_eq!(pool.modeled_bytes(), before - freed, "seed {seed}");
        for (id, _) in &owners {
            assert!(pool.owner_pages(*id) > 0,
                    "seed {seed}: spill relief must not preempt owner {id}");
        }
        // uncapped tier: every sealed exclusive page made it to disk
        assert_eq!(pool.spilled_pages(),
                   owners.iter().map(|(_, c)| c.layers.iter()
                       .map(|l| KV_SIDES.iter()
                           .map(|&s| l.sealed_quant_pages(s, PT))
                           .sum::<usize>())
                       .sum::<usize>())
                   .sum::<usize>(),
                   "seed {seed}: uncapped spill must drain every sealed page");
        for (id, cache) in &mut owners {
            pool.fault_back_owner(*id, cache);
        }
        assert_eq!(pool.modeled_bytes(), before,
                   "seed {seed}: fault-back must restore the exact charge");
        // capped tier: a cap below one serialized page admits nothing
        let mut tiny = PagePool::new(PT, kv, m.group).unwrap();
        tiny.enable_spill(&dir.join("tiny"), 8).unwrap();
        let (_, cache0) = &mut owners[0];
        tiny.sync(9, cache0);
        assert!(tiny.spill_one(9, cache0, false).is_none(),
                "seed {seed}: an 8-byte cap must reject every page");
        assert_eq!(tiny.spill_used_bytes(), 0, "seed {seed}");
        tiny.free_owner(9);
        for (id, _) in &owners {
            pool.free_owner(*id);
        }
        assert_eq!(pool.modeled_bytes(), 0, "seed {seed}");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_per_side_downshift_floors_and_accounting() {
    // The per-side pressure-ladder wall (DESIGN.md §Pressure-Ladder):
    // >=1000 randomized interleavings of whole-cache, K-only, and V-only
    // downshift steps against random per-layer per-side floors and
    // weights.  After every step no page sits below its side floor, and
    // the bytes actually reclaimed telescope to exactly the upfront
    // `reclaimable_bytes` claim, path-independently — whichever order the
    // rungs were taken in, every page lands exactly on its floor.
    const PT: usize = 64;
    for_cases(1000, 12, |seed, rng| {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let kv = m.kv_dim();
        let pcfg = PressureCfg {
            k_floor: (0..m.n_layers).map(|_| [1u8, 2, 3][rng.below(3)]).collect(),
            v_floor: (0..m.n_layers).map(|_| [1u8, 2, 3][rng.below(3)]).collect(),
            k_weight: (0..m.n_layers).map(|_| rng.uniform(0.1, 10.0)).collect(),
            v_weight: (0..m.n_layers).map(|_| rng.uniform(0.1, 10.0)).collect(),
        };
        let tokens = PT * rng.range(1, 4); // 1-3 sealed pages per side
        let mut cache = SeqKvCache::new(&m, &plan);
        let k = rng.normal_vec(tokens * kv);
        let v = rng.normal_vec(tokens * kv);
        for l in &mut cache.layers {
            l.append(&k, &v, tokens);
        }
        let claim = reclaimable_bytes(&cache, PT, &pcfg);
        assert!(claim > 0, "seed {seed}: 4-bit pages above floors <= 3");
        let check_floors = |cache: &SeqKvCache, what: &str| {
            for (li, l) in cache.layers.iter().enumerate() {
                for &s in &KV_SIDES {
                    for p in 0..l.sealed_quant_pages(s, PT) {
                        assert!(l.quant_page_bits(s, p, PT) >= pcfg.floor(li, s),
                                "seed {seed} {what}: page below side floor");
                    }
                }
            }
        };
        let mut actual = 0usize;
        let mut iters = 0usize;
        loop {
            iters += 1;
            assert!(iters < 10_000, "seed {seed}: ladder must terminate");
            let choice = rng.below(3);
            let step = match choice {
                0 => downshift_one(&mut cache, PT, &pcfg),
                1 => downshift_one_side(&mut cache, PT, &pcfg, KvSide::Key),
                _ => downshift_one_side(&mut cache, PT, &pcfg, KvSide::Value),
            };
            match step {
                Some(d) => {
                    assert!(d.to_bits < d.from_bits, "seed {seed}");
                    assert!(d.to_bits >= pcfg.floor(d.layer, d.side),
                            "seed {seed}: rung stepped through the floor");
                    if choice == 1 {
                        assert_eq!(d.side, KvSide::Key, "seed {seed}");
                    } else if choice == 2 {
                        assert_eq!(d.side, KvSide::Value, "seed {seed}");
                    }
                    actual += page_frame_bytes(PT, kv, m.group, d.from_bits)
                        - page_frame_bytes(PT, kv, m.group, d.to_bits);
                    check_floors(&cache, "mid-ladder");
                }
                // one exhausted side must not hide the other side's
                // headroom: only stop once the whole claim is spent
                None => {
                    if reclaimable_bytes(&cache, PT, &pcfg) == 0 {
                        break;
                    }
                }
            }
        }
        assert_eq!(actual, claim,
                   "seed {seed}: reclaimed bytes must telescope to the claim");
        check_floors(&cache, "drained");
        for (li, l) in cache.layers.iter().enumerate() {
            for &s in &KV_SIDES {
                for p in 0..l.sealed_quant_pages(s, PT) {
                    assert_eq!(l.quant_page_bits(s, p, PT), pcfg.floor(li, s),
                               "seed {seed}: drained ladder must land on the floor");
                }
            }
        }
    });
}

#[test]
fn prop_rng_shuffle_is_permutation() {
    for_cases(100, 10, |seed, rng| {
        let n = rng.range(1, 60);
        let mut xs: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "seed {seed}");
    });
}
