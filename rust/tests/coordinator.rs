//! Coordinator invariants: batcher admission, window policies under
//! adversarial sequences, metrics, server protocol — plus the NDJSON
//! serving lifecycle over a real socket (delta-before-final streaming,
//! queue-full load shedding, cancellation, deadlines, disconnects;
//! DESIGN.md §Serving-Protocol), session park/resume bit-identity and
//! the spill rung of the pressure ladder (DESIGN.md §Spill-Tier), and
//! prefix-affinity dispatch across replicas (DESIGN.md §Replication).
//! The socket/engine tests need the PJRT runtime and are gated on
//! `make artifacts` like tests/integration.rs.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::coordinator::batcher::Batcher;
use kvmix::coordinator::request::Request;
use kvmix::coordinator::server::{parse_gen_line, serve_on};
use kvmix::coordinator::{proto, Engine, EngineCfg, FinishReason, Histogram, ServeCfg};
use kvmix::kvcache::{MemoryBudget, WindowPolicy};
use kvmix::model::Sampler;
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::json::{self, Json};
use kvmix::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

fn req(id: u64, prompt: usize, new: usize) -> Request {
    Request { id, prompt: vec![1; prompt], max_new_tokens: new,
              sampler: Sampler::Greedy, stop_token: None, priority: 0,
              deadline_ms: None, submitted_ns: 0, session: None }
}

#[test]
fn batcher_never_exceeds_budget_randomized() {
    let mut rng = Rng::new(100);
    for case in 0..50 {
        let capacity = rng.range(10_000, 200_000);
        let bpt = rng.uniform(2.0, 64.0);
        let mut budget = MemoryBudget::new(capacity, 0).unwrap();
        let mut b = Batcher::new(rng.range(1, 16), bpt);
        for id in 0..rng.range(1, 40) {
            b.submit(req(id as u64, rng.range(1, 100), rng.range(1, 100)));
        }
        let mut active = 0usize;
        let mut admitted_bytes = 0usize;
        while let Some(r) = b.admit(active, &budget) {
            let projected = b.projected_bytes(&r);
            assert!(projected <= budget.free(), "case {case}: admitted over budget");
            budget.alloc(projected).unwrap();
            admitted_bytes += projected;
            active += 1;
        }
        assert!(admitted_bytes <= capacity, "case {case}");
        assert!(active <= b.max_batch, "case {case}");
    }
}

#[test]
fn batcher_preserves_fifo_under_interleaving() {
    let mut b = Batcher::new(4, 1.0);
    let budget = MemoryBudget::new(1_000_000, 0).unwrap();
    for id in 0..10 {
        b.submit(req(id, 2, 2));
    }
    let mut seen = Vec::new();
    let mut active = 0;
    while let Some(r) = b.admit(active, &budget) {
        seen.push(r.id);
        active += 1;
        if active == 4 {
            active = 0; // simulate retirements
        }
    }
    assert_eq!(seen, (0..10).collect::<Vec<u64>>());
}

#[test]
fn oom_requeue_front_preserves_order() {
    // eviction pushes to the *front* so the evicted request restarts first
    let mut b = Batcher::new(8, 1.0);
    b.submit(req(1, 2, 2));
    b.submit(req(2, 2, 2));
    let budget = MemoryBudget::new(1_000_000, 0).unwrap();
    let r1 = b.admit(0, &budget).unwrap();
    b.queue.push_front(r1); // engine OOM path
    assert_eq!(b.admit(0, &budget).unwrap().id, 1);
    assert_eq!(b.admit(0, &budget).unwrap().id, 2);
}

#[test]
fn window_policy_no_starvation() {
    // RPC keep is strictly less than current for ratio < 1, so quantization
    // always catches up — the fp window cannot grow unboundedly
    let p = WindowPolicy::Rpc { ratio: 0.3 };
    let mut fp = 0usize;
    for _ in 0..10_000 {
        fp += 1; // append one token
        let blocks = p.blocks_to_quantize(fp, 32);
        fp -= blocks * 32;
        assert!(fp <= (0.3 * 10_000f64) as usize + 64);
    }
    // steady state: keep ratio ~0.3 of current context but bounded by
    // group granularity above the keep line
    assert!(fp <= (0.3 * 10_000f64) as usize + 33, "fp={fp}");
}

#[test]
fn histogram_monotone_quantiles() {
    let mut h = Histogram::default();
    let mut rng = Rng::new(5);
    for _ in 0..1000 {
        h.record(rng.normal().abs() * 10.0);
    }
    let q50 = h.quantile(0.5);
    let q95 = h.quantile(0.95);
    let q99 = h.quantile(0.99);
    assert!(q50 <= q95 && q95 <= q99);
}

#[test]
fn server_protocol_fuzz() {
    let mut rng = Rng::new(6);
    // valid lines parse; mangled lines error but never panic
    for _ in 0..200 {
        let n = rng.range(1, 64);
        let toks: Vec<String> = (0..rng.range(1, 20)).map(|_| rng.below(512).to_string()).collect();
        let line = format!("GEN {n} {}", toks.join(","));
        let (pn, pt) = parse_gen_line(&line).unwrap();
        assert_eq!(pn, n);
        assert_eq!(pt.len(), toks.len());

        // mangle
        let mut chars: Vec<char> = line.chars().collect();
        let i = rng.below(chars.len());
        chars[i] = ['@', 'x', '-', ' '][rng.below(4)];
        let mangled: String = chars.into_iter().collect();
        let _ = parse_gen_line(&mangled); // must not panic
    }
}

#[test]
fn memory_budget_peak_tracking() {
    let mut m = MemoryBudget::new(10_000, 1_000).unwrap();
    m.set_kv(4_000).unwrap();
    m.set_kv(2_000).unwrap();
    assert_eq!(m.peak, 5_000);
    assert!(m.set_kv(9_500).is_err()); // over capacity
    assert_eq!(m.peak, 10_500);        // attempted peak recorded
}

// ---------------- NDJSON serving lifecycle (socket-level) ----------------

fn engine_cfg(rt: &Runtime, max_batch: usize) -> EngineCfg {
    EngineCfg {
        method: Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2).without_rpc()),
        max_batch, kv_budget: None, threads: 1, page_tokens: 0,
        prefix_cache: false, step_tokens: 0,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }
}

/// Bind an ephemeral port, run `serve_on` on a scoped thread, and drive
/// it with `client`; returns after the server exits (via `max_requests`).
fn with_server(rt: &Runtime, cfg: EngineCfg, mut scfg: ServeCfg,
               max_requests: usize, client: impl FnOnce(TcpStream)) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local addr");
    scfg.max_requests = Some(max_requests);
    std::thread::scope(|s| {
        let server = s.spawn(move || serve_on(rt, cfg, listener, scfg));
        client(TcpStream::connect(addr).expect("connect"));
        server.join().expect("server thread").expect("serve_on");
    });
}

fn read_frame(r: &mut impl BufRead) -> Json {
    let mut line = String::new();
    assert!(r.read_line(&mut line).expect("read frame") > 0,
            "server closed the stream mid-conversation");
    json::parse(line.trim()).expect("server emitted unparseable frame")
}

fn is_final(frame: &Json) -> bool {
    frame.opt("done").is_some() || frame.opt("error").is_some()
}

#[test]
fn socket_streams_deltas_strictly_before_final() {
    // the ISSUE 7 acceptance bar: any generation of >= 2 tokens yields at
    // least one {"delta":…} frame before the terminal frame, and a
    // {"stats":true} query is answered from the same stream
    let Some(rt) = runtime() else { return };
    with_server(&rt, engine_cfg(&rt, 4), ServeCfg::new(""), 1, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        write!(w, "{}\n{{\"id\":7,\"prompt\":[1,2,3,4],\"max_new\":4}}\n",
               proto::stats_request_frame()).unwrap();
        let mut deltas: Vec<i32> = Vec::new();
        let mut stats_seen = false;
        let fin = loop {
            let f = read_frame(&mut r);
            if let Some(stats) = f.opt("stats") {
                for key in ["queue_depth", "active", "shed", "completions",
                            "throughput_tok_s", "ttft_p50_ms"] {
                    assert!(stats.opt(key).is_some(), "stats missing {key}");
                }
                stats_seen = true;
                continue;
            }
            assert_eq!(f.get("id").unwrap().as_usize().unwrap(), 7);
            if is_final(&f) {
                break f;
            }
            let d = f.get("delta").unwrap().f64_vec().unwrap();
            assert!(!d.is_empty(), "empty delta frame");
            deltas.extend(d.iter().map(|&x| x as i32));
        };
        assert!(!deltas.is_empty(),
                "no delta frame arrived strictly before the final frame");
        assert!(stats_seen, "stats query went unanswered");
        assert_eq!(fin.get("finish").unwrap().as_str().unwrap(), "length");
        assert_eq!(fin.get("n").unwrap().as_usize().unwrap(), 4);
        assert_eq!(deltas.len(), 4, "deltas must cover the whole generation");
        assert!(fin.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);
    });
}

#[test]
fn socket_sheds_load_with_retry_hint_when_admission_queue_full() {
    // admit_queue 1 + max_batch 1 and a slow first request: the pipeline
    // absorbs at most 1 active + 1 waiting + 1 in-channel, so of 5
    // requests at least 2 must be shed with a retry_after_ms hint —
    // and every request still gets exactly one terminal frame
    let Some(rt) = runtime() else { return };
    let mut scfg = ServeCfg::new("");
    scfg.admit_queue = 1;
    with_server(&rt, engine_cfg(&rt, 1), scfg, 5, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        // the head request holds the single lane for 64 decode steps
        write!(w, "{{\"id\":1,\"prompt\":[1,2,3],\"max_new\":64}}\n").unwrap();
        // wait for its first delta so request 1 is provably active...
        let first = read_frame(&mut r);
        assert!(first.opt("delta").is_some());
        // ...then pipeline 4 more in one write: 2 absorbed, >= 2 shed
        let mut burst = String::new();
        for id in 2..=5u64 {
            burst.push_str(&format!(
                "{{\"id\":{id},\"prompt\":[1,2,3],\"max_new\":1}}\n"));
        }
        w.write_all(burst.as_bytes()).unwrap();
        let (mut finals, mut sheds) = (0usize, 0usize);
        while finals + sheds < 5 {
            let f = read_frame(&mut r);
            if f.opt("delta").is_some() {
                continue;
            }
            if f.opt("done").is_some() {
                finals += 1;
            } else {
                assert_eq!(f.get("error").unwrap().as_str().unwrap(),
                           "admission queue full");
                assert!(f.get("retry_after_ms").unwrap().as_f64().unwrap() >= 25.0);
                sheds += 1;
            }
        }
        assert!(sheds >= 2, "expected >= 2 load-sheds, got {sheds}");
        assert!(finals >= 2, "expected >= 2 completions, got {finals}");
        assert_eq!(finals + sheds, 5);
    });
}

#[test]
fn socket_cancel_frame_retires_mid_decode() {
    let Some(rt) = runtime() else { return };
    with_server(&rt, engine_cfg(&rt, 2), ServeCfg::new(""), 1, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        write!(w, "{{\"id\":3,\"prompt\":[1,2,3],\"max_new\":512}}\n").unwrap();
        let first = read_frame(&mut r);
        assert!(first.opt("delta").is_some(), "expected a streaming delta first");
        write!(w, "{}\n", proto::cancel_frame(3)).unwrap();
        let fin = loop {
            let f = read_frame(&mut r);
            if is_final(&f) {
                break f;
            }
        };
        assert_eq!(fin.get("id").unwrap().as_usize().unwrap(), 3);
        assert_eq!(fin.get("finish").unwrap().as_str().unwrap(), "cancelled");
        let n = fin.get("n").unwrap().as_usize().unwrap();
        assert!(n >= 1 && n < 512, "partial generation expected, got n={n}");
    });
}

#[test]
fn socket_cancel_racing_admission_still_cancels() {
    // the request and its cancel land in ONE write, so the cancel can
    // reach the serve loop's control channel while the request is still
    // buffered in the admission sync_channel — the orphan-cancel path
    // must retire it at admission time; in the other interleaving the
    // routed path retires it mid-decode.  Either way the client gets
    // exactly one terminal frame with finish "cancelled".
    let Some(rt) = runtime() else { return };
    with_server(&rt, engine_cfg(&rt, 2), ServeCfg::new(""), 1, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        write!(w, "{{\"id\":8,\"prompt\":[1,2,3],\"max_new\":4096}}\n{}\n",
               proto::cancel_frame(8)).unwrap();
        let fin = loop {
            let f = read_frame(&mut r);
            if is_final(&f) {
                break f;
            }
        };
        assert_eq!(fin.get("id").unwrap().as_usize().unwrap(), 8);
        assert_eq!(fin.get("finish").unwrap().as_str().unwrap(), "cancelled");
        assert!(fin.get("n").unwrap().as_usize().unwrap() < 4096,
                "generation must not have run to completion");
    });
}

#[test]
fn socket_duplicate_inflight_id_rejected() {
    // two live streams sharing one client id cannot be demultiplexed, so
    // a Gen frame reusing an in-flight id gets a terminal reject (no
    // retry_after_ms) while the original stream keeps running
    let Some(rt) = runtime() else { return };
    with_server(&rt, engine_cfg(&rt, 2), ServeCfg::new(""), 2, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        write!(w, "{{\"id\":9,\"prompt\":[1,2,3],\"max_new\":4096}}\n").unwrap();
        let first = read_frame(&mut r);
        assert!(first.opt("delta").is_some(), "id 9 must be provably active");
        write!(w, "{{\"id\":9,\"prompt\":[1,2,3],\"max_new\":1}}\n").unwrap();
        let rej = loop {
            let f = read_frame(&mut r);
            if f.opt("error").is_some() {
                break f;
            }
            assert!(f.opt("delta").is_some(), "id 9's stream must survive");
        };
        assert_eq!(rej.get("id").unwrap().as_usize().unwrap(), 9);
        assert_eq!(rej.get("error").unwrap().as_str().unwrap(),
                   "duplicate in-flight id");
        assert!(rej.opt("retry_after_ms").is_none(), "reject is terminal");
        // the original stream is intact: cancel retires it normally
        write!(w, "{}\n", proto::cancel_frame(9)).unwrap();
        let fin = loop {
            let f = read_frame(&mut r);
            if f.opt("done").is_some() {
                break f;
            }
        };
        assert_eq!(fin.get("finish").unwrap().as_str().unwrap(), "cancelled");
    });
}

#[test]
fn socket_deadline_retires_with_deadline_finish() {
    let Some(rt) = runtime() else { return };
    with_server(&rt, engine_cfg(&rt, 2), ServeCfg::new(""), 1, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        write!(w, "{{\"id\":4,\"prompt\":[1,2,3],\"max_new\":4096,\
                   \"deadline_ms\":1}}\n").unwrap();
        let fin = loop {
            let f = read_frame(&mut r);
            if is_final(&f) {
                break f;
            }
        };
        assert_eq!(fin.get("finish").unwrap().as_str().unwrap(), "deadline");
        assert!(fin.get("n").unwrap().as_usize().unwrap() < 4096);
    });
}

#[test]
fn socket_disconnect_cancels_and_server_exits() {
    // dropping the connection mid-stream must retire the request (the
    // reader's Gone control) and count it toward max_requests — the
    // with_server scope only returns when serve_on does
    let Some(rt) = runtime() else { return };
    with_server(&rt, engine_cfg(&rt, 2), ServeCfg::new(""), 1, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        write!(w, "{{\"id\":5,\"prompt\":[1,2,3],\"max_new\":4096}}\n").unwrap();
        let first = read_frame(&mut r);
        assert!(first.opt("delta").is_some());
        // both halves drop here: the server sees EOF and cancels id 5
    });
}

#[test]
fn socket_malformed_lines_answer_structured_errors_and_resync() {
    let Some(rt) = runtime() else { return };
    with_server(&rt, engine_cfg(&rt, 2), ServeCfg::new(""), 1, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        write!(w, "{{\"id\":1,\"prompt\":[1,2,\n\
                   GEN 4 1,2,3\n\
                   \n\
                   {{\"id\":1,\"prompt\":[1,2],\"max_new\":2}}\n").unwrap();
        let e1 = read_frame(&mut r);
        assert!(e1.get("error").unwrap().as_str().unwrap()
                    .starts_with("parse error at byte"), "{e1:?}");
        let e2 = read_frame(&mut r);
        assert!(e2.get("error").unwrap().as_str().unwrap()
                    .starts_with("parse error at byte"), "{e2:?}");
        // the blank line is a keepalive no-op; the valid frame after the
        // garbage still serves — the connection survived resync
        let fin = loop {
            let f = read_frame(&mut r);
            if is_final(&f) {
                break f;
            }
        };
        assert_eq!(fin.get("finish").unwrap().as_str().unwrap(), "length");
        assert_eq!(fin.get("n").unwrap().as_usize().unwrap(), 2);
    });
}

#[test]
fn engine_cancel_frees_exactly_the_owned_pool_pages() {
    // ROADMAP 5b at the engine level: cancelling an active lane releases
    // its page-table frames before the next step and the pool's audited
    // accounting stays consistent throughout
    let Some(rt) = runtime() else { return };
    let mut cfg = engine_cfg(&rt, 2);
    cfg.page_tokens = 64;
    let mut engine = Engine::new(&rt, cfg).unwrap();
    engine.submit(Request { id: 11, prompt: (1..=130).collect(), max_new_tokens: 64,
                            sampler: Sampler::Greedy, stop_token: None, priority: 0,
                            deadline_ms: None, submitted_ns: 0, session: None });
    for _ in 0..3 {
        engine.step().unwrap();
    }
    let pool = engine.page_pool().expect("paged mode");
    pool.verify_accounting().unwrap();
    assert!(pool.owner_pages(11) > 0, "decode must have mapped pages");
    let exclusive = pool.owner_exclusive_bytes(11);
    let before = pool.modeled_bytes();
    assert_eq!(exclusive, before, "sole owner: every mapped page is exclusive");

    let c = engine.cancel(11).unwrap().expect("active lane cancels");
    assert_eq!(c.finish, FinishReason::Cancelled);
    assert!(!c.tokens.is_empty(), "partial generation is returned");
    let pool = engine.page_pool().unwrap();
    pool.verify_accounting().unwrap();
    assert_eq!(pool.owner_pages(11), 0);
    assert_eq!(before - pool.modeled_bytes(), exclusive,
               "cancel must free exactly the owned pages");
    assert!(engine.idle());
    assert_eq!(engine.metrics.cancellations, 1);
    assert_eq!(engine.metrics.completions, 0, "a cancel is not a completion");
    assert!(engine.cancel(11).unwrap().is_none(), "second cancel is a no-op");
}

// ------------- session park/resume + spill tier + replication -------------

fn sreq(id: u64, prompt: Vec<i32>, new: usize, session: Option<u64>) -> Request {
    Request { id, prompt, max_new_tokens: new, sampler: Sampler::Greedy,
              stop_token: None, priority: 0, deadline_ms: None,
              submitted_ns: 0, session }
}

#[test]
fn session_resume_is_bit_identical_to_full_reprefill() {
    // ISSUE 9 acceptance bar: a parked-then-resumed session produces the
    // same tokens as a fresh engine full-prefilling the concatenated
    // conversation, while skipping most of the turn-2 prefill
    // (DESIGN.md §Serving-Protocol).  Chunked mode so the prefill saving
    // is observable: the first chunk starts at the adoption boundary.
    let Some(rt) = runtime() else { return };
    let mut cfg = engine_cfg(&rt, 2);
    cfg.page_tokens = 64;
    cfg.step_tokens = 64;

    // turn 1 under session 42 parks instead of freeing
    let mut engine = Engine::new(&rt, cfg.clone()).unwrap();
    let p1: Vec<i32> = (1..=130).collect();
    engine.submit(sreq(1, p1.clone(), 16, Some(42)));
    let done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish, FinishReason::Length);
    let g1 = done[0].tokens.clone();
    assert_eq!(g1.len(), 16);
    assert_eq!(engine.parked_sessions(), 1, "finished session must park");
    assert_eq!(engine.metrics.sessions_parked, 1);
    let pool = engine.page_pool().unwrap();
    pool.verify_accounting().unwrap();
    assert!(pool.owner_pages(1) > 0, "parked pages stay in the pool");
    let t1_prefill = engine.metrics.prefill_tokens;

    // turn 2: prompt strictly extends conversation so far + a user turn
    let mut p2 = p1;
    p2.extend_from_slice(&g1);
    p2.extend(200..214);
    engine.submit(sreq(2, p2.clone(), 16, Some(42)));
    let resumed = engine.run_to_completion().unwrap();
    assert_eq!(resumed.len(), 1);
    assert_eq!(engine.metrics.sessions_resumed, 1,
               "turn 2 must resume the parked session, not admit cold");
    let reused = engine.metrics.resume_tokens_reused;
    assert!(reused >= 64,
            "at least one whole page must be adopted, got {reused}");
    let t2_prefill = engine.metrics.prefill_tokens - t1_prefill;
    assert_eq!(t2_prefill, p2.len() - reused,
               "resume must skip exactly the adopted prefix's prefill");
    assert_eq!(engine.parked_sessions(), 1, "turn 2 re-parks on finish");
    engine.page_pool().unwrap().verify_accounting().unwrap();

    // reference: a cold engine prefills the whole concatenated prompt
    let mut cold = Engine::new(&rt, cfg).unwrap();
    cold.submit(sreq(3, p2, 16, None));
    let base = cold.run_to_completion().unwrap();
    assert_eq!(resumed[0].tokens, base[0].tokens,
               "resume must be bit-identical to a full re-prefill");
}

#[test]
fn pressure_ladder_spills_parked_pages_before_preempting_or_dropping() {
    // ISSUE 9 acceptance bar: with a spill tier configured the pressure
    // ladder spills before it preempts.  The plan is uniform 2-bit (no
    // downshift rung below the floor) and no prefix index exists, so a
    // budget below the measured peak forces relief straight onto the
    // spill rung — and spilling the parked session's sealed pages must
    // fully cover the shortfall: no preemption, no OOM, the parked
    // session survives (drop-parked is a rung below spill).
    let Some(rt) = runtime() else { return };
    let mut cfg = engine_cfg(&rt, 2);
    cfg.page_tokens = 64;
    let p1: Vec<i32> = (1..=130).collect();
    let p2: Vec<i32> = (301..=430).collect();

    // probe run: same workload, unlimited budget, measures the peak
    let mut probe = Engine::new(&rt, cfg.clone()).unwrap();
    probe.submit(sreq(1, p1.clone(), 32, Some(9)));
    probe.run_to_completion().unwrap();
    probe.submit(sreq(2, p2.clone(), 32, None));
    probe.run_to_completion().unwrap();
    let peak = probe.metrics.peak_kv_bytes;
    assert!(peak > 0, "paged run must model KV bytes");

    let dir = std::env::temp_dir()
        .join(format!("kvmix-spill-ladder-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    cfg.kv_budget = Some(peak - peak / 8);
    cfg.spill_dir = Some(dir.clone());
    let mut engine = Engine::new(&rt, cfg).unwrap();
    engine.submit(sreq(1, p1, 32, Some(9)));
    engine.run_to_completion().unwrap();
    assert_eq!(engine.parked_sessions(), 1);
    engine.submit(sreq(2, p2, 32, None));
    engine.run_to_completion().unwrap();
    assert!(engine.metrics.pages_spilled > 0,
            "the spill rung must engage below the measured peak");
    assert_eq!(engine.metrics.preemptions, 0,
               "spill must relieve pressure before preemption");
    assert_eq!(engine.metrics.oom_events, 0,
               "spill must fully cover the budget shortfall");
    assert_eq!(engine.parked_sessions(), 1,
               "the parked session survives: drop-parked sits below spill");
    let pool = engine.page_pool().unwrap();
    pool.verify_accounting().unwrap();
    assert!(pool.spilled_pages() > 0, "spilled pages stay in the table");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn socket_two_replicas_prefix_affinity_lands_family_on_one_replica() {
    // ISSUE 9 acceptance bar: with --replicas 2, requests sharing a
    // whole-page prompt head hash to the same replica, so later family
    // members hit that replica's prefix cache — the merged stats frame
    // reports replicas=2 and nonzero prefix_hits (DESIGN.md §Replication).
    let Some(rt) = runtime() else { return };
    let mut cfg = engine_cfg(&rt, 2);
    cfg.page_tokens = 64;
    cfg.prefix_cache = true;
    let mut scfg = ServeCfg::new("");
    scfg.replicas = 2;
    with_server(&rt, cfg, scfg, 5, |sock| {
        let mut r = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        let head = (1..=64).map(|t: i32| t.to_string())
            .collect::<Vec<_>>().join(",");
        // one prefix family, served sequentially so each finished
        // member's prefix is registered before the next one admits
        for id in 1..=4u64 {
            write!(w, "{{\"id\":{id},\"prompt\":[{head},{}],\"max_new\":2}}\n",
                   100 + id).unwrap();
            loop {
                let f = read_frame(&mut r);
                if is_final(&f) {
                    assert_eq!(f.get("id").unwrap().as_usize().unwrap(),
                               id as usize);
                    assert!(f.opt("done").is_some(), "unexpected reject {f:?}");
                    break;
                }
            }
        }
        write!(w, "{}\n", proto::stats_request_frame()).unwrap();
        loop {
            let f = read_frame(&mut r);
            if let Some(s) = f.opt("stats") {
                assert_eq!(s.get("replicas").unwrap().as_usize().unwrap(), 2);
                assert!(s.get("prefix_hits").unwrap().as_usize().unwrap() >= 1,
                        "affinity must land the family on one replica's \
                         prefix cache: {f:?}");
                break;
            }
        }
        // one last request lets the server reach max_requests and exit
        write!(w, "{{\"id\":9,\"prompt\":[1,2,3],\"max_new\":1}}\n").unwrap();
        loop {
            let f = read_frame(&mut r);
            if is_final(&f) {
                break;
            }
        }
    });
}
