//! Coordinator invariants that need no PJRT runtime: batcher admission,
//! window policies under adversarial sequences, metrics, server protocol.

use kvmix::coordinator::batcher::Batcher;
use kvmix::coordinator::request::Request;
use kvmix::coordinator::server::parse_gen_line;
use kvmix::coordinator::Histogram;
use kvmix::kvcache::{MemoryBudget, WindowPolicy};
use kvmix::model::Sampler;
use kvmix::util::Rng;

fn req(id: u64, prompt: usize, new: usize) -> Request {
    Request { id, prompt: vec![1; prompt], max_new_tokens: new,
              sampler: Sampler::Greedy, stop_token: None, submitted_ns: 0 }
}

#[test]
fn batcher_never_exceeds_budget_randomized() {
    let mut rng = Rng::new(100);
    for case in 0..50 {
        let capacity = rng.range(10_000, 200_000);
        let bpt = rng.uniform(2.0, 64.0);
        let mut budget = MemoryBudget::new(capacity, 0).unwrap();
        let mut b = Batcher::new(rng.range(1, 16), bpt);
        for id in 0..rng.range(1, 40) {
            b.submit(req(id as u64, rng.range(1, 100), rng.range(1, 100)));
        }
        let mut active = 0usize;
        let mut admitted_bytes = 0usize;
        while let Some(r) = b.admit(active, &budget) {
            let projected = b.projected_bytes(&r);
            assert!(projected <= budget.free(), "case {case}: admitted over budget");
            budget.alloc(projected).unwrap();
            admitted_bytes += projected;
            active += 1;
        }
        assert!(admitted_bytes <= capacity, "case {case}");
        assert!(active <= b.max_batch, "case {case}");
    }
}

#[test]
fn batcher_preserves_fifo_under_interleaving() {
    let mut b = Batcher::new(4, 1.0);
    let budget = MemoryBudget::new(1_000_000, 0).unwrap();
    for id in 0..10 {
        b.submit(req(id, 2, 2));
    }
    let mut seen = Vec::new();
    let mut active = 0;
    while let Some(r) = b.admit(active, &budget) {
        seen.push(r.id);
        active += 1;
        if active == 4 {
            active = 0; // simulate retirements
        }
    }
    assert_eq!(seen, (0..10).collect::<Vec<u64>>());
}

#[test]
fn oom_requeue_front_preserves_order() {
    // eviction pushes to the *front* so the evicted request restarts first
    let mut b = Batcher::new(8, 1.0);
    b.submit(req(1, 2, 2));
    b.submit(req(2, 2, 2));
    let budget = MemoryBudget::new(1_000_000, 0).unwrap();
    let r1 = b.admit(0, &budget).unwrap();
    b.queue.push_front(r1); // engine OOM path
    assert_eq!(b.admit(0, &budget).unwrap().id, 1);
    assert_eq!(b.admit(0, &budget).unwrap().id, 2);
}

#[test]
fn window_policy_no_starvation() {
    // RPC keep is strictly less than current for ratio < 1, so quantization
    // always catches up — the fp window cannot grow unboundedly
    let p = WindowPolicy::Rpc { ratio: 0.3 };
    let mut fp = 0usize;
    for _ in 0..10_000 {
        fp += 1; // append one token
        let blocks = p.blocks_to_quantize(fp, 32);
        fp -= blocks * 32;
        assert!(fp <= (0.3 * 10_000f64) as usize + 64);
    }
    // steady state: keep ratio ~0.3 of current context but bounded by
    // group granularity above the keep line
    assert!(fp <= (0.3 * 10_000f64) as usize + 33, "fp={fp}");
}

#[test]
fn histogram_monotone_quantiles() {
    let mut h = Histogram::default();
    let mut rng = Rng::new(5);
    for _ in 0..1000 {
        h.record(rng.normal().abs() * 10.0);
    }
    let q50 = h.quantile(0.5);
    let q95 = h.quantile(0.95);
    let q99 = h.quantile(0.99);
    assert!(q50 <= q95 && q95 <= q99);
}

#[test]
fn server_protocol_fuzz() {
    let mut rng = Rng::new(6);
    // valid lines parse; mangled lines error but never panic
    for _ in 0..200 {
        let n = rng.range(1, 64);
        let toks: Vec<String> = (0..rng.range(1, 20)).map(|_| rng.below(512).to_string()).collect();
        let line = format!("GEN {n} {}", toks.join(","));
        let (pn, pt) = parse_gen_line(&line).unwrap();
        assert_eq!(pn, n);
        assert_eq!(pt.len(), toks.len());

        // mangle
        let mut chars: Vec<char> = line.chars().collect();
        let i = rng.below(chars.len());
        chars[i] = ['@', 'x', '-', ' '][rng.below(4)];
        let mangled: String = chars.into_iter().collect();
        let _ = parse_gen_line(&mangled); // must not panic
    }
}

#[test]
fn memory_budget_peak_tracking() {
    let mut m = MemoryBudget::new(10_000, 1_000).unwrap();
    m.set_kv(4_000).unwrap();
    m.set_kv(2_000).unwrap();
    assert_eq!(m.peak, 5_000);
    assert!(m.set_kv(9_500).is_err()); // over capacity
    assert_eq!(m.peak, 10_500);        // attempted peak recorded
}
