//! Parallel/sequential parity for the decode fan-out (DESIGN.md
//! §Threading-Model): the pooled paths must produce **bit-identical**
//! results to the sequential ones for any thread count.
//!
//! The cache-level test runs without artifacts; the full `decode_step`
//! test is gated on `make artifacts` like the other integration tests.

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::harness::workload;
use kvmix::kvcache::{AttnScratch, KeyRepr, LayerCacheCfg, LayerKvCache, ValueRepr, WindowPolicy};
use kvmix::model::{DecodeScratch, Forward};
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::{Rng, WorkerPool};

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load_with(&dir, false).expect("runtime load"))
}

/// Per-lane decode attention fanned out across the pool, mirroring the
/// chunking in `Forward::decode_step`, must be bit-identical to the
/// sequential loop — per policy, and without needing the PJRT runtime.
#[test]
fn pooled_lane_attend_bit_identical_no_runtime() {
    let (n_heads, hd, kv_dim) = (4usize, 32usize, 64usize);
    let qd = n_heads * hd;
    let policies: [(&str, KeyRepr, ValueRepr, WindowPolicy); 3] = [
        ("kvmix", KeyRepr::PerChannel { bits: 2 }, ValueRepr::PerToken { bits: 2 },
         WindowPolicy::Rpc { ratio: 0.1 }),
        ("kivi", KeyRepr::PerChannel { bits: 2 }, ValueRepr::PerToken { bits: 2 },
         WindowPolicy::FixedResidual { tokens: 64 }),
        ("fp16", KeyRepr::Fp, ValueRepr::Fp, WindowPolicy::All),
    ];
    for (name, key, value, window) in policies {
        let bsz = 7usize; // deliberately not a multiple of the thread count
        let build_lanes = || -> Vec<LayerKvCache> {
            (0..bsz).map(|b| {
                let mut c = LayerKvCache::new(LayerCacheCfg {
                    kv_dim, head_dim: hd, group: 32, key, value,
                    k_window: window, v_window: window, outlier_frac: 0.0,
                    k_interleave: false,
                });
                let mut rng = Rng::new(100 + b as u64);
                c.append(&rng.normal_vec(80 * kv_dim), &rng.normal_vec(80 * kv_dim), 80);
                c
            }).collect()
        };
        let mut rng = Rng::new(7);
        let qs = rng.normal_vec(bsz * qd);
        let ks = rng.normal_vec(bsz * kv_dim);
        let vs = rng.normal_vec(bsz * kv_dim);

        // sequential reference (one scratch, lane order 0..bsz)
        let mut seq_lanes = build_lanes();
        let mut seq_out = vec![0f32; bsz * qd];
        let mut ws = AttnScratch::default();
        for b in 0..bsz {
            let lc = &mut seq_lanes[b];
            lc.append(&ks[b * kv_dim..(b + 1) * kv_dim],
                      &vs[b * kv_dim..(b + 1) * kv_dim], 1);
            lc.attend(&qs[b * qd..(b + 1) * qd], n_heads,
                      &mut seq_out[b * qd..(b + 1) * qd], &mut ws);
        }

        for threads in [2usize, 4] {
            let mut lanes = build_lanes();
            let mut out = vec![0f32; bsz * qd];
            WorkerPool::scoped(threads, |pool| {
                let nw = pool.threads().min(bsz);
                let per = bsz.div_ceil(nw);
                let mut scratches: Vec<AttnScratch> = Vec::new();
                scratches.resize_with(nw, AttnScratch::default);
                let chunks = lanes.chunks_mut(per)
                    .zip(out.chunks_mut(per * qd))
                    .zip(scratches.iter_mut())
                    .enumerate()
                    .map(|(ci, ((lc, o), ws))| (ci * per, lc, o, ws));
                pool.run_tasks(chunks, |_w, (lane0, lanes, out, ws)| {
                    for (i, lc) in lanes.iter_mut().enumerate() {
                        let b = lane0 + i;
                        lc.append(&ks[b * kv_dim..(b + 1) * kv_dim],
                                  &vs[b * kv_dim..(b + 1) * kv_dim], 1);
                        lc.attend(&qs[b * qd..(b + 1) * qd], n_heads,
                                  &mut out[i * qd..(i + 1) * qd], ws);
                    }
                });
            });
            assert!(out == seq_out,
                    "{name}: pooled attend (threads={threads}) not bit-identical");
            for (a, b) in lanes.iter().zip(&seq_lanes) {
                assert_eq!(a.modeled_bytes(), b.modeled_bytes(),
                           "{name}: modeled_bytes diverged (threads={threads})");
            }
        }
    }
}

/// Full `decode_step` parity through the PJRT runtime: `threads=4` must
/// produce bit-identical logits and identical `modeled_bytes()` to
/// `threads=1` across the kvmix / kivi / fp16 policies.
#[test]
fn decode_step_parity_across_thread_counts() {
    let Some(rt) = runtime() else { return };
    let methods = [
        Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2)),
        Method::Kivi { bits: 2, residual: 64 },
        Method::Fp16,
    ];
    for method in methods {
        let run = |threads: usize| -> (Vec<Vec<f32>>, Vec<usize>) {
            WorkerPool::scoped(threads, |pool| {
                let fwd = Forward::with_pool(&rt, Some(pool));
                let mut rng = Rng::new(9);
                let bsz = 4usize;
                let mut caches: Vec<_> = (0..bsz).map(|_| {
                    let mut c = method.make_cache(&rt.model);
                    let (toks, _) = workload::sample_mixture(&mut rng, 40);
                    fwd.prefill(&toks, &mut c).expect("prefill");
                    c
                }).collect();
                let mut scratch = DecodeScratch::default();
                let inputs = vec![workload::BOS; bsz];
                let mut per_step = Vec::new();
                for _ in 0..6 {
                    let mut refs: Vec<_> = caches.iter_mut().collect();
                    per_step.push(fwd.decode_step(&inputs, &mut refs, &mut scratch)
                                     .expect("decode"));
                }
                let bytes = caches.iter().map(|c| c.modeled_bytes()).collect();
                (per_step, bytes)
            })
        };
        let (seq_logits, seq_bytes) = run(1);
        let (par_logits, par_bytes) = run(4);
        assert_eq!(seq_bytes, par_bytes, "{}: modeled_bytes diverged", method.name());
        for (step, (a, b)) in seq_logits.iter().zip(&par_logits).enumerate() {
            assert!(a == b, "{}: logits at step {step} not bit-identical",
                    method.name());
        }
    }
}

/// `DecodeScratch` worker buffers must grow once and then be reused —
/// the steady-state decode path may not allocate new scratches.
#[test]
fn decode_scratch_lane_count_is_stable() {
    let Some(rt) = runtime() else { return };
    WorkerPool::scoped(4, |pool| {
        let fwd = Forward::with_pool(&rt, Some(pool));
        let method = Method::Fp16;
        let mut rng = Rng::new(3);
        let mut caches: Vec<_> = (0..4).map(|_| {
            let mut c = method.make_cache(&rt.model);
            let (toks, _) = workload::sample_mixture(&mut rng, 16);
            fwd.prefill(&toks, &mut c).expect("prefill");
            c
        }).collect();
        let mut scratch = DecodeScratch::default();
        let inputs = vec![workload::BOS; 4];
        for _ in 0..3 {
            let mut refs: Vec<_> = caches.iter_mut().collect();
            fwd.decode_step(&inputs, &mut refs, &mut scratch).expect("decode");
        }
        assert_eq!(scratch.lanes.len(), 4, "one scratch per worker, reused");
    });
}
