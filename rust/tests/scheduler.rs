//! Iteration-level scheduler coverage (DESIGN.md §Scheduler), pinned at
//! two levels:
//!
//! * pure tests (no PJRT needed) prove the plan-level invariants on top
//!   of the in-module unit tests: the scheduler's admission gate closes
//!   when the step budget is spent, and the budget/alignment arithmetic
//!   composes with the batcher's slot/memory mechanics;
//! * artifact-gated engine tests (skip with a notice pre-`make
//!   artifacts`, like `rust/tests/prefix.rs`) prove the end-to-end
//!   claims: `--step-tokens 0` generates **bit-identical** tokens to the
//!   pre-refactor engine (pinned against a raw `Forward`
//!   prefill+decode reference, which is exactly what that engine
//!   executed), chunked prefill keeps every chunk boundary
//!   group-aligned while decode lanes emit one token per step
//!   (decode-first, no prefill starvation), and a never-admittable
//!   request is rejected alone instead of tearing the engine down.

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::coordinator::{Batcher, Engine, EngineCfg, Lifecycle, Request, Scheduler};
use kvmix::kvcache::MemoryBudget;
use kvmix::model::{DecodeScratch, Forward, Sampler};
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::Rng;

fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
    Request { id, prompt, max_new_tokens: max_new, sampler: Sampler::Greedy,
              stop_token: None, priority: 0, deadline_ms: None, submitted_ns: 0, session: None }
}

// ---------------------------------------------------------------------------
// pure plan-level tests (no runtime)
// ---------------------------------------------------------------------------

#[test]
fn admission_gate_closes_when_budget_spent() {
    let s = Scheduler::new(64, 32, 256).unwrap();
    let mut b = Batcher::new(8, 1.0);
    b.submit(req(1, vec![1; 8], 8));
    let budget = MemoryBudget::new(1_000_000, 0).unwrap();
    // decode lanes alone fill the budget: no admission this step
    let mut plan = s.begin_step(64);
    assert!(!s.can_admit(&plan));
    assert!(s.admit(&mut plan, &mut b, 0, &budget, &|_| 0).is_none());
    assert_eq!(b.waiting(), 1, "gated admission must not pop the queue");
    // an open budget admits through the batcher's mechanics
    let mut plan = s.begin_step(0);
    let r = s.admit(&mut plan, &mut b, 0, &budget, &|_| 0).unwrap();
    assert_eq!(r.id, 1);
    assert_eq!(plan.admissions, 1);
}

#[test]
fn legacy_scheduler_never_gates_admission() {
    let s = Scheduler::new(0, 32, 256).unwrap();
    let mut b = Batcher::new(8, 1.0);
    b.submit(req(1, vec![1; 8], 8));
    let budget = MemoryBudget::new(1_000_000, 0).unwrap();
    let mut plan = s.begin_step(1_000);
    assert!(s.can_admit(&plan));
    assert!(s.admit(&mut plan, &mut b, 0, &budget, &|_| 0).is_some());
}

#[test]
fn admission_still_respects_slots_and_memory() {
    let s = Scheduler::new(256, 32, 256).unwrap();
    let mut b = Batcher::new(2, 100.0);
    b.submit(req(1, vec![1; 50], 50)); // projected 10_000
    let budget = MemoryBudget::new(5_000, 0).unwrap();
    let mut plan = s.begin_step(0);
    assert!(s.can_admit(&plan), "budget open...");
    assert!(s.admit(&mut plan, &mut b, 0, &budget, &|_| 0).is_none(),
            "...but the memory projection still blocks");
    assert!(s.admit(&mut plan, &mut b, 2, &budget, &|_| 0).is_none(),
            "...and so does a full batch");
}

// ---------------------------------------------------------------------------
// artifact-gated engine tests (skip with a notice pre-`make artifacts`)
// ---------------------------------------------------------------------------

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load_with(&dir, false).expect("runtime load"))
}

/// What the pre-refactor engine executed for one request: dense
/// whole-prompt prefill, then one decode step per token — the
/// `--step-tokens 0` bit-identity reference.  Uses the engine's RNG seed
/// so non-greedy samplers would see the same stream.
fn reference_generate(rt: &Runtime, method: &Method, prompt: &[i32],
                      max_new: usize) -> Vec<i32> {
    let fwd = Forward::new(rt);
    let mut cache = method.make_cache(&rt.model);
    let logits = fwd.prefill(prompt, &mut cache).expect("prefill");
    let vocab = rt.model.vocab;
    let mut rng = Rng::new(0xE161);
    let last = &logits[(prompt.len() - 1) * vocab..prompt.len() * vocab];
    let mut toks = vec![Sampler::Greedy.sample(last, &mut rng) as i32];
    let mut scratch = DecodeScratch::default();
    while toks.len() < max_new {
        let input = *toks.last().unwrap();
        let mut refs = vec![&mut cache];
        let l = fwd.decode_step(&[input], &mut refs, &mut scratch).expect("decode");
        toks.push(Sampler::Greedy.sample(&l[..vocab], &mut rng) as i32);
    }
    toks
}

fn engine_generate(rt: &Runtime, method: &Method, prompt: &[i32], max_new: usize,
                   step_tokens: usize) -> Vec<i32> {
    let mut engine = Engine::new(rt, EngineCfg {
        method: method.clone(), max_batch: 1, kv_budget: None, threads: 1,
        page_tokens: 0, prefix_cache: false, step_tokens,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).expect("engine");
    engine.submit(req(7, prompt.to_vec(), max_new));
    let done = engine.run_to_completion().expect("serve");
    assert_eq!(done.len(), 1);
    done.into_iter().next().unwrap().tokens
}

#[test]
fn step_tokens_zero_is_bit_identical_to_prerefactor_engine() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(12);
    let (prompt, _) = kvmix::harness::workload::sample_mixture(&mut rng, 48);
    let methods = [
        Method::Fp16,
        Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2)),
        Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2).without_rpc()),
        Method::Kivi { bits: 2, residual: 64 },
    ];
    for method in methods {
        let reference = reference_generate(&rt, &method, &prompt, 16);
        let engine = engine_generate(&rt, &method, &prompt, 16, 0);
        assert_eq!(engine, reference,
                   "--step-tokens 0 must match the pre-refactor engine ({})",
                   method.name());
    }
}

#[test]
fn chunked_engine_completes_with_aligned_boundaries() {
    let Some(rt) = runtime() else { return };
    let group = rt.model.group;
    let long = 3 * group + group / 2; // deliberately not group-aligned
    let max_bucket = *rt.buckets.iter().max().unwrap();
    if long > max_bucket {
        eprintln!("SKIP: buckets too small for the long prompt");
        return;
    }
    let method = Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2));
    let mut engine = Engine::new(&rt, EngineCfg {
        method, max_batch: 4, kv_budget: None, threads: 1, page_tokens: 0,
        prefix_cache: false, step_tokens: group + 1, // tightest legal budget
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).expect("engine");
    let mut rng = Rng::new(5);
    let (prompt, _) = kvmix::harness::workload::sample_mixture(&mut rng, long);
    engine.submit(req(1, prompt, 4));
    let mut completed = Vec::new();
    let mut prefill_steps = 0;
    for _ in 0..64 {
        completed.extend(engine.step().expect("step"));
        for a in &engine.active {
            if let Lifecycle::Prefilling { done: boundary } = a.state {
                assert_eq!(boundary % group, 0,
                           "chunk boundary {boundary} must be group-aligned");
                prefill_steps += 1;
            }
        }
        if engine.idle() {
            break;
        }
    }
    assert_eq!(completed.len(), 1, "request must complete");
    assert_eq!(completed[0].tokens.len(), 4);
    assert!(prefill_steps >= 2,
            "a {long}-token prompt under a {group}-token budget must span steps");
    assert!(!engine.metrics.budget_util.is_empty(),
            "chunked mode must record budget utilization");
}

#[test]
fn decode_first_no_starvation_under_sustained_decode() {
    let Some(rt) = runtime() else { return };
    let group = rt.model.group;
    let long = 4 * group;
    if long > *rt.buckets.iter().max().unwrap() {
        eprintln!("SKIP: buckets too small for the long prompt");
        return;
    }
    let method = Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2));
    // budget = 2 decoders + one group + the promotion token: both
    // cohorts progress every step AND the final group-sized remainder
    // can complete (DESIGN.md §Scheduler's sizing rule)
    let mut engine = Engine::new(&rt, EngineCfg {
        method, max_batch: 4, kv_budget: None, threads: 1, page_tokens: 0,
        prefix_cache: false, step_tokens: 2 + group + 1,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).expect("engine");
    let mut rng = Rng::new(6);
    for id in 0..2u64 {
        let (p, _) = kvmix::harness::workload::sample_mixture(&mut rng, 24);
        engine.submit(req(id, p, 64)); // long-running decoders
    }
    // admit + settle the decoders, then land the long prompt
    engine.step().expect("step");
    let (p, _) = kvmix::harness::workload::sample_mixture(&mut rng, long);
    engine.submit(req(9, p, 2));
    let mut last_done = 0usize;
    for _ in 0..(long / group + 2) {
        let gen_before: Vec<usize> = engine.active.iter()
            .filter(|a| a.is_decoding())
            .map(|a| a.generated.len())
            .collect();
        engine.step().expect("step");
        // decode-first: every lane that was decoding got exactly one token
        let gen_after: Vec<usize> = engine.active.iter()
            .filter(|a| a.is_decoding())
            .map(|a| a.generated.len())
            .take(gen_before.len())
            .collect();
        for (b, a) in gen_before.iter().zip(&gen_after) {
            assert_eq!(a - b, 1, "a decoding lane must emit one token per step");
        }
        // no starvation: the long prefill advances every step it exists
        if let Some(a) = engine.active.iter().find(|a| a.req.id == 9) {
            match a.state {
                Lifecycle::Prefilling { done } => {
                    assert!(done > last_done || done == 0 && last_done == 0,
                            "prefill stalled at {done}");
                    last_done = done;
                }
                Lifecycle::Decoding => break, // promoted: prefill finished
            }
        }
    }
    assert!(engine.active.iter().any(|a| a.req.id == 9 && a.is_decoding())
            || engine.completions.iter().any(|c| c.id == 9),
            "long prompt must finish prefilling under sustained decode load");
}

#[test]
fn oversized_request_is_rejected_alone_engine_keeps_stepping() {
    let Some(rt) = runtime() else { return };
    let method = Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2));
    let mut engine = Engine::new(&rt, EngineCfg {
        method: method.clone(), max_batch: 4, kv_budget: Some(32 << 10),
        threads: 1, page_tokens: 0, prefix_cache: false, step_tokens: 0,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).expect("engine");
    // an absurd projection: prompt 32 + 1M new tokens >> 32 KiB budget
    engine.submit(req(1, vec![1; 32], 1_000_000));
    let done = engine.step().expect("step must not tear down");
    assert!(done.is_empty());
    let rejections = engine.take_rejections();
    assert_eq!(rejections.len(), 1);
    assert_eq!(rejections[0].id, 1);
    assert!(rejections[0].reason.contains("cannot admit"), "{}", rejections[0].reason);
    assert_eq!(engine.metrics.oom_events, 1);
    // the engine is still serviceable for reasonable requests
    let mut rng = Rng::new(3);
    let (p, _) = kvmix::harness::workload::sample_mixture(&mut rng, 24);
    engine.submit(req(2, p, 4));
    let done = engine.run_to_completion().expect("engine must keep serving");
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 2);

    // one-shot harness semantics preserved: run_to_completion surfaces
    // a rejection as an error (fig8's OOM rows rely on this)
    engine.submit(req(3, vec![1; 32], 1_000_000));
    assert!(engine.run_to_completion().is_err());
}

#[test]
fn over_bucket_prompt_rejected_legacy_but_served_chunked() {
    // a prompt longer than the largest compiled bucket cannot run through
    // the legacy whole-prompt prefill: it must be rejected alone (not
    // tear the engine down mid-step) — and the SAME prompt must be
    // servable under chunking, whose grants clamp to the bucket
    let Some(rt) = runtime() else { return };
    let group = rt.model.group;
    let max_bucket = *rt.buckets.iter().max().unwrap();
    let long = max_bucket + group;
    let (prompt, _) = kvmix::harness::workload::gen_lm(&mut Rng::new(2), long);
    let method = Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2));

    let mut legacy = Engine::new(&rt, EngineCfg {
        method: method.clone(), max_batch: 2, kv_budget: None, threads: 1,
        page_tokens: 0, prefix_cache: false, step_tokens: 0,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).expect("engine");
    legacy.submit(req(1, prompt.clone(), 4));
    let rejections = legacy.take_rejections();
    assert_eq!(rejections.len(), 1, "over-bucket prompt must be rejected at submit");
    assert!(rejections[0].reason.contains("largest compiled bucket"),
            "{}", rejections[0].reason);
    assert!(legacy.idle(), "the rejected request must not occupy the engine");

    let mut chunked = Engine::new(&rt, EngineCfg {
        method, max_batch: 2, kv_budget: None, threads: 1,
        page_tokens: 0, prefix_cache: false, step_tokens: 2 * group,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).expect("engine");
    chunked.submit(req(1, prompt, 4));
    let done = chunked.run_to_completion().expect("chunking makes it servable");
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].tokens.len(), 4);
}

#[test]
fn chunked_vs_legacy_same_completion_shape() {
    // chunked generations are deliberately NOT bit-identical to legacy
    // (chunks attend quantized earlier chunks —
    // docs/adr/004-iteration-level-scheduling.md); pin what IS promised:
    // same completion set, same token counts, same prompt coverage
    let Some(rt) = runtime() else { return };
    let group = rt.model.group;
    let method = Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2));
    let mut rng = Rng::new(31);
    let (prompt, _) = kvmix::harness::workload::sample_mixture(&mut rng, 3 * group);
    let legacy = engine_generate(&rt, &method, &prompt, 8, 0);
    let chunked = engine_generate(&rt, &method, &prompt, 8, group + 1);
    assert_eq!(legacy.len(), 8);
    assert_eq!(chunked.len(), 8);
}
