//! Copy-on-write prefix sharing (DESIGN.md §Prefix-Sharing), pinned at
//! two levels:
//!
//! * pure-Rust pool/cache tests (no PJRT needed) prove the acceptance
//!   invariants — adopted state is bit-identical to an exclusive build,
//!   shared pages are charged once, a pressure downshift on a shared
//!   page copy-on-writes and never mutates the other owner, preemption
//!   and retirement never free shared frames, and the whole machinery is
//!   inert when the prefix cache is off;
//! * artifact-gated engine tests prove the end-to-end claim: two
//!   sequences sharing a ≥ 1-page prompt prefix generate **bit-identical
//!   tokens** with `--prefix-cache` on vs off, while the pool reuses the
//!   prefix pages (skip with a notice when `make artifacts` hasn't run).

use kvmix::baselines::Method;
use kvmix::config::{ModelConfig, QuantPlan};
use kvmix::coordinator::{Engine, EngineCfg, Request};
use kvmix::kvcache::{pressure, KvSide, PagePool, SeqKvCache, SharedDownshift, KV_SIDES};
use kvmix::model::Sampler;
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::Rng;

const PT: usize = 64;

/// Deterministic K/V rows for a `tokens`-long prompt: the shared-prefix
/// analog of what a prefill writes (same seed ⇒ same prefix rows).
fn kv_rows(m: &ModelConfig, tokens: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (rng.normal_vec(tokens * m.kv_dim()), rng.normal_vec(tokens * m.kv_dim()))
}

fn filled(m: &ModelConfig, plan: &QuantPlan, tokens: usize, seed: u64) -> SeqKvCache {
    let (k, v) = kv_rows(m, tokens, seed);
    let mut c = SeqKvCache::new(m, plan);
    for l in &mut c.layers {
        l.append(&k, &v, tokens);
    }
    c
}

/// Engine-admission sequence at the pool level: donor prefill + sync +
/// register, then recipient adopt + suffix append + sync.  The donor and
/// recipient share the first `shared` tokens of the same `total`-token
/// prompt (seeded rows stand in for the deterministic forward pass).
fn donor_and_recipient(m: &ModelConfig, plan: &QuantPlan, pool: &mut PagePool,
                       prompt: &[i32], shared: usize, seed: u64)
                       -> (SeqKvCache, SeqKvCache) {
    let total = prompt.len();
    let (k, v) = kv_rows(m, total, seed);
    let mut donor = SeqKvCache::new(m, plan);
    for l in &mut donor.layers {
        l.append(&k, &v, total);
    }
    pool.sync(1, &donor);
    let cap = donor.max_shareable_prefix(total, PT);
    assert!(cap >= shared, "fixture prefix {shared} must be adoptable (cap {cap})");
    pool.register_prefix(1, prompt, shared, &donor);

    let mut rec = SeqKvCache::new(m, plan);
    let adopted = pool.adopt_prefix(2, prompt, shared, &mut rec);
    assert_eq!(adopted, shared, "registered prefix must hit");
    let kvd = m.kv_dim();
    for l in &mut rec.layers {
        l.append_prefill_suffix(&k[shared * kvd..], &v[shared * kvd..],
                                total - shared, shared);
    }
    pool.sync(2, &rec);
    (donor, rec)
}

#[test]
fn adopted_state_bit_identical_to_exclusive_build() {
    // acceptance (a), cache half: the adopt+suffix path must land in the
    // exact cache state a cold full prefill produces — for the eager plan
    // and for the dynamic-RPC plan whose fp tail bounds the adoptable cap
    let m = ModelConfig::test_small();
    for plan in [QuantPlan::uniform(m.n_layers, 2).without_rpc(),
                 QuantPlan::uniform(m.n_layers, 2)] {
        let prompt: Vec<i32> = (0..192).collect();
        let probe = SeqKvCache::new(&m, &plan);
        let shared = probe.max_shareable_prefix(prompt.len(), PT);
        assert!(shared >= PT, "plan {} must share at least one page", plan.name);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.enable_prefix_cache();
        let (_donor, rec) = donor_and_recipient(&m, &plan, &mut pool, &prompt,
                                                shared, 42);
        let exclusive = filled(&m, &plan, 192, 42);
        assert_eq!(rec.len(), exclusive.len());
        assert_eq!(rec.modeled_bytes(), exclusive.modeled_bytes());
        for (lr, le) in rec.layers.iter().zip(&exclusive.layers) {
            assert_eq!(lr.k_fp(), le.k_fp());
            assert_eq!(lr.v_fp(), le.v_fp());
            for &s in &KV_SIDES {
                let (br, be) = (lr.quant_blocks(s), le.quant_blocks(s));
                assert_eq!(br.len(), be.len());
                for (x, y) in br.iter().zip(be) {
                    assert_eq!(x.words, y.words, "packed words must be bit-identical");
                    assert_eq!(x.scales, y.scales);
                    assert_eq!(x.mins, y.mins);
                    assert_eq!(x.bits, y.bits);
                }
            }
        }
    }
}

#[test]
fn shared_pool_bytes_below_exclusive_sum() {
    // acceptance (b): pool bytes with sharing < the sum of exclusive costs
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
    let prompt: Vec<i32> = (7..199).collect(); // 192 tokens, 128 shared
    let mut shared_pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    shared_pool.enable_prefix_cache();
    let (donor, rec) = donor_and_recipient(&m, &plan, &mut shared_pool, &prompt,
                                           128, 9);

    let mut exclusive_pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    exclusive_pool.sync(1, &donor);
    exclusive_pool.sync(2, &rec);
    // the recipient maps the same number of pages either way...
    assert_eq!(shared_pool.owner_pages(2), exclusive_pool.owner_pages(2));
    // ...but the shared pool charges each shared frame once
    let shared_frames = m.n_layers * 2 * (128 / PT);
    assert_eq!(shared_pool.modeled_bytes(),
               exclusive_pool.modeled_bytes() - shared_frames * shared_pool.page_bytes(2));
    assert!(shared_pool.modeled_bytes() < exclusive_pool.modeled_bytes());
    assert_eq!(shared_pool.stats.prefix_hits, 1);
}

#[test]
fn downshift_on_shared_page_cow_splits_and_preserves_owner() {
    // acceptance (c): a pressure downshift landing on a shared frame must
    // split, never mutate the other owner's bytes; the pool observes the
    // split at sync and the exempt policy refuses to pick shared pages
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
    let floors = Method::Kvmix(plan.clone()).pressure_floors(m.n_layers);
    let prompt: Vec<i32> = (0..128).collect();
    let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    pool.enable_prefix_cache();
    let (donor, mut rec) = donor_and_recipient(&m, &plan, &mut pool, &prompt, 128, 5);

    // every sealed page of the recipient is shared: the engine's policy
    // (Exempt) must find nothing to grind
    assert!(pressure::downshift_one(&mut rec, PT, &floors).is_none(),
            "shared pages are downshift-exempt");
    assert_eq!(pressure::reclaimable_bytes(&rec, PT, &floors), 0);

    // snapshot the donor, then force the split path
    let donor_words: Vec<Vec<u32>> = donor.layers.iter()
        .flat_map(|l| KV_SIDES.iter().flat_map(move |&s| {
            l.quant_blocks(s).iter().map(|b| b.words.clone())
        }))
        .collect();
    let bytes_before = pool.modeled_bytes();
    let d = pressure::downshift_one_with(&mut rec, PT, &floors, SharedDownshift::CowSplit)
        .expect("CowSplit must downshift");
    assert!(d.cow);
    assert_eq!((d.layer, d.side, d.page), (0, KvSide::Key, 0));
    assert_eq!((d.from_bits, d.to_bits), (4, 2));

    // donor bytes bit-identical, donor still at plan width
    let donor_words_after: Vec<Vec<u32>> = donor.layers.iter()
        .flat_map(|l| KV_SIDES.iter().flat_map(move |&s| {
            l.quant_blocks(s).iter().map(|b| b.words.clone())
        }))
        .collect();
    assert_eq!(donor_words, donor_words_after, "other owner must be untouched");
    assert_eq!(donor.layers[0].quant_page_bits(KvSide::Key, 0, PT), 4);
    assert_eq!(rec.layers[0].quant_page_bits(KvSide::Key, 0, PT), 2);

    // the pool swaps the recipient's mapping to a private frame and the
    // split costs one extra (narrower) frame — CoW is de-sharing, not
    // memory relief, which is exactly why the ladder exempts shared pages
    pool.sync(2, &rec);
    assert_eq!(pool.stats.cow_splits, 1);
    assert_eq!(pool.modeled_bytes(), bytes_before + pool.page_bytes(2));
}

#[test]
fn retirement_and_preemption_never_free_shared_frames() {
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
    let prompt: Vec<i32> = (0..128).collect();
    let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    pool.enable_prefix_cache();
    let (_donor, _rec) = donor_and_recipient(&m, &plan, &mut pool, &prompt, 64, 3);
    let shared_frames = m.n_layers * 2 * (64 / PT);

    // preempt the recipient (free_owner is what the engine calls): the
    // shared frames must survive via the donor's and the index's refs
    pool.free_owner(2);
    assert_eq!(pool.owner_pages(2), 0);
    let donor_only = pool.modeled_bytes();
    assert_eq!(donor_only, pool.owner_pages(1) * pool.page_bytes(2));

    // donor retires too: the index alone keeps the prefix warm
    pool.free_owner(1);
    assert_eq!(pool.modeled_bytes(), shared_frames * pool.page_bytes(2));
    assert_eq!(pool.prefix_entries(), 1);

    // a re-admission of the same prompt still hits, donor gone and all
    let mut back = SeqKvCache::new(&m, &plan);
    assert_eq!(pool.adopt_prefix(9, &prompt, 64, &mut back), 64);
    assert_eq!(back.len(), 64);
    pool.free_owner(9);

    // eviction is the only way those frames die
    assert!(pool.evict_lru_prefix().unwrap() > 0);
    assert_eq!(pool.modeled_bytes(), 0);

    // and with the entry gone, the pages of a surviving holder would be
    // sole-owned again — which is what re-arms the downshift ladder.
    // (one-page prompt: the recipient's cache is entirely shared)
    let plan4 = QuantPlan::uniform(m.n_layers, 4).without_rpc();
    let (donor2, mut rec2) = donor_and_recipient(&m, &plan4, &mut pool,
                                                 &prompt[..64], 64, 4);
    let floors = Method::Kvmix(plan4).pressure_floors(m.n_layers);
    assert!(pressure::downshift_one(&mut rec2, PT, &floors).is_none(),
            "fully shared cache: exempt scan finds nothing");
    pool.free_owner(1);
    drop(donor2);
    assert!(pool.evict_lru_prefix().is_some());
    assert!(pressure::downshift_one(&mut rec2, PT, &floors).is_some(),
            "sole-owned pages must be downshiftable again");
}

#[test]
fn prefix_cache_off_is_byte_identical_to_paged_baseline() {
    // acceptance (d), pool half: with the prefix cache off every sharing
    // entry point is a no-op and the allocator behaves exactly as the
    // exclusive-ownership pool — same frames, same bytes, same stats
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
    let a = filled(&m, &plan, 192, 11);
    let b = filled(&m, &plan, 192, 12);

    let mut off = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    assert!(!off.prefix_cache_enabled());
    let prompt: Vec<i32> = (0..192).collect();
    off.sync(1, &a);
    assert!(!off.register_prefix(1, &prompt, 192, &a));
    let mut c = SeqKvCache::new(&m, &plan);
    assert_eq!(off.adopt_prefix(2, &prompt, 192, &mut c), 0);
    assert!(c.is_empty());
    assert!(off.evict_lru_prefix().is_none());
    off.sync(2, &b);

    let mut plain = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    plain.sync(1, &a);
    plain.sync(2, &b);
    assert_eq!(off.modeled_bytes(), plain.modeled_bytes());
    assert_eq!(off.allocated_pages(), plain.allocated_pages());
    assert_eq!(off.stats.allocs, plain.stats.allocs);
    assert_eq!(off.stats.prefix_hits + off.stats.prefix_insertions
               + off.stats.cow_splits + off.stats.prefix_evictions, 0);
}

#[test]
fn suffix_append_with_zero_adopted_is_plain_append() {
    // acceptance (d), cache half: the shared code path the off-engine
    // runs (`append_prefill_suffix(.., 0)`) is byte-for-byte `append`
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 2); // RPC window, the subtle case
    let (k, v) = kv_rows(&m, 160, 33);
    let mut plain = SeqKvCache::new(&m, &plan);
    let mut zero = SeqKvCache::new(&m, &plan);
    for l in &mut plain.layers {
        l.append(&k, &v, 160);
    }
    for l in &mut zero.layers {
        l.append_prefill_suffix(&k, &v, 160, 0);
    }
    assert_eq!(plain.modeled_bytes(), zero.modeled_bytes());
    for (lp, lz) in plain.layers.iter().zip(&zero.layers) {
        assert_eq!(lp.k_fp(), lz.k_fp());
        assert_eq!(lp.v_fp(), lz.v_fp());
        for &s in &KV_SIDES {
            for (x, y) in lp.quant_blocks(s).iter().zip(lz.quant_blocks(s)) {
                assert_eq!(x.words, y.words);
                assert_eq!(x.scales, y.scales);
                assert_eq!(x.mins, y.mins);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// artifact-gated engine tests (skip with a notice pre-`make artifacts`)
// ---------------------------------------------------------------------------

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

/// Two requests sharing a one-page (64-token) system prefix + distinct
/// 32-token tails, served under `prefix_cache` on/off.
fn serve_shared_pair(rt: &Runtime, prefix_cache: bool)
                     -> (Vec<kvmix::coordinator::Completion>, Engine<'_>) {
    let plan = QuantPlan::uniform(rt.model.n_layers, 2).without_rpc();
    let mut engine = Engine::new(rt, EngineCfg {
        method: Method::Kvmix(plan), max_batch: 4, kv_budget: None, threads: 1,
        page_tokens: PT, prefix_cache, step_tokens: 0,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).unwrap();
    let mut rng = Rng::new(8);
    let (system, _) = kvmix::harness::workload::sample_mixture(&mut rng, PT);
    for id in 0..2u64 {
        let (tail, _) = kvmix::harness::workload::sample_mixture(&mut rng, 32);
        let mut prompt = system.clone();
        prompt.extend_from_slice(&tail);
        engine.submit(Request { id, prompt, max_new_tokens: 16,
                                sampler: Sampler::Greedy, stop_token: None, priority: 0,
                                deadline_ms: None, submitted_ns: 0, session: None });
    }
    let mut done = engine.run_to_completion().unwrap();
    done.sort_by_key(|c| c.id);
    (done, engine)
}

#[test]
fn engine_prefix_hit_generates_bit_identical_tokens() {
    // acceptance (a), end to end: --prefix-cache on vs off, same two
    // shared-prefix requests, bit-identical generations — and the on-run
    // actually reused the prefix (hits + tokens + fewer pool bytes)
    let Some(rt) = runtime() else { return };
    let (off, off_engine) = serve_shared_pair(&rt, false);
    let (on, on_engine) = serve_shared_pair(&rt, true);
    assert_eq!(off.len(), 2);
    assert_eq!(on.len(), 2);
    for (a, b) in off.iter().zip(&on) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "request {} must generate bit-identically on a prefix hit", a.id);
    }
    assert_eq!(off_engine.metrics.prefix_hits, 0);
    assert_eq!(on_engine.metrics.prefix_hits, 1, "second request must hit");
    assert_eq!(on_engine.metrics.prefix_tokens_reused, PT);
    assert_eq!(on_engine.metrics.cow_splits, 0, "no pressure, no splits");
    // acceptance (b) at the serving level: the shared page is charged once
    assert!(on_engine.metrics.peak_kv_bytes < off_engine.metrics.peak_kv_bytes,
            "peak {} (on) must undercut {} (off)",
            on_engine.metrics.peak_kv_bytes, off_engine.metrics.peak_kv_bytes);
    let pool = on_engine.page_pool().expect("paged");
    // both prompts align to the same 64-token system prefix: one entry,
    // registered by the first admission and refreshed by the second
    assert_eq!(pool.stats.prefix_insertions, 1);
    assert_eq!(pool.prefix_entries(), 1);
}

#[test]
fn engine_prefix_cache_on_without_sharing_matches_off() {
    // --prefix-cache with disjoint prompts: zero hits, and byte-identical
    // behavior to the off engine (acceptance (d) behaviorally)
    let Some(rt) = runtime() else { return };
    let plan = QuantPlan::uniform(rt.model.n_layers, 2).without_rpc();
    let run = |prefix_cache: bool| {
        let mut engine = Engine::new(&rt, EngineCfg {
            method: Method::Kvmix(plan.clone()), max_batch: 4, kv_budget: None,
            threads: 1, page_tokens: PT, prefix_cache, step_tokens: 0,
            pressure_weights: None, spill_dir: None, spill_bytes: 0,
        }).unwrap();
        let mut rng = Rng::new(17);
        for id in 0..3u64 {
            let (toks, _) = kvmix::harness::workload::sample_mixture(&mut rng, 48);
            engine.submit(Request { id, prompt: toks, max_new_tokens: 12,
                                    sampler: Sampler::Greedy, stop_token: None, priority: 0,
                                    deadline_ms: None, submitted_ns: 0, session: None });
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        let hits = engine.metrics.prefix_hits;
        let peak = engine.metrics.peak_kv_bytes;
        (done, hits, peak)
    };
    let (off, off_hits, off_peak) = run(false);
    let (on, on_hits, on_peak) = run(true);
    assert_eq!(off_hits, 0);
    assert_eq!(on_hits, 0, "48-token prompts are sub-page: no sharing");
    assert_eq!(on_peak, off_peak, "no sharing -> identical page charges");
    for (a, b) in off.iter().zip(&on) {
        assert_eq!(a.tokens, b.tokens);
    }
}

#[test]
fn engine_rejects_prefix_cache_without_pages() {
    let Some(rt) = runtime() else { return };
    let err = Engine::new(&rt, EngineCfg {
        method: Method::Fp16, max_batch: 1, kv_budget: None, threads: 1,
        page_tokens: 0, prefix_cache: true, step_tokens: 0,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    });
    assert!(err.is_err(), "--prefix-cache without --page-tokens must be rejected");
}
