//! Property wall around the offline Pareto plan search
//! (`profiler/search.rs`, docs/adr/007-asymmetric-bit-allocation.md):
//! determinism, frontier validity, budget monotonicity, and bit-exact
//! JSON round-trips through a real file.

use kvmix::profiler::search::{
    fp16_bytes_per_token, modeled_ppl, plan_bytes_per_token, search_modeled,
    search_plans_with_budget, synthetic_importance, SearchCfg, SearchResult,
};

const KV_DIM: usize = 64;
const GROUP: usize = 32;

#[test]
fn search_is_deterministic() {
    // same importance + config: byte-identical canonical serialization
    let imp = synthetic_importance(6, 17);
    let cfg = SearchCfg::default();
    let a = search_modeled(&imp, &cfg, KV_DIM, GROUP).unwrap();
    let b = search_modeled(&imp, &cfg, KV_DIM, GROUP).unwrap();
    assert!(!a.frontier.is_empty());
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    // and the seed actually flows into the importance profile: a
    // different profile must not be silently identical
    let other = synthetic_importance(6, 18);
    assert!(imp.k != other.k || imp.v != other.v);
}

#[test]
fn frontier_is_valid_and_undominated() {
    let imp = synthetic_importance(8, 23);
    let res = search_modeled(&imp, &SearchCfg::default(), KV_DIM, GROUP).unwrap();
    assert!(!res.frontier.is_empty());
    for p in &res.frontier {
        p.plan.validate().unwrap();
        assert!(p.bytes_per_token <= res.budget_bytes_per_token + 1e-9);
        assert!((p.bytes_per_token
                 - plan_bytes_per_token(&p.plan, KV_DIM, GROUP)).abs() < 1e-9,
                "recorded bytes must match the byte model");
    }
    // pairwise: no frontier point weakly dominates another on both axes
    for (i, a) in res.frontier.iter().enumerate() {
        for (j, b) in res.frontier.iter().enumerate() {
            if i != j {
                assert!(a.bytes_per_token > b.bytes_per_token || a.ppl > b.ppl,
                        "{i} dominates {j}");
            }
        }
    }
    // the frontier tail is the minimum-perplexity plan
    let best = res.best().unwrap();
    for p in &res.frontier {
        assert!(best.ppl <= p.ppl);
    }
}

#[test]
fn tighter_budget_never_raises_bits_or_bytes() {
    // With rpc_high == rpc_low, modeled bytes/token is affine in total
    // bits, so the best plan under a tighter budget can spend
    // no more bytes — and hence no more mean bits — than under a looser
    // one.  Sweep budgets descending and pin both monotonicities.
    let imp = synthetic_importance(6, 29);
    let cfg = SearchCfg { rpc_high: 0.1, rpc_low: 0.1, ..SearchCfg::default() };
    let mut prev_bytes = f64::INFINITY;
    let mut prev_bits = f64::INFINITY;
    for frac in [0.6, 0.5, 0.4, 0.35, 0.3, 0.27, 0.25] {
        let budget = frac * fp16_bytes_per_token(KV_DIM);
        let res = search_plans_with_budget(&imp, &cfg, KV_DIM, GROUP, budget,
                                           &mut |p| Ok(modeled_ppl(&imp, p)))
            .unwrap();
        let best = res.best()
            .unwrap_or_else(|| panic!("budget frac {frac} must be feasible"));
        assert!(best.bytes_per_token <= prev_bytes + 1e-9,
                "frac {frac}: best bytes went up under a tighter budget");
        let bits = (best.plan.avg_k_bits() + best.plan.avg_v_bits()) / 2.0;
        assert!(bits <= prev_bits + 1e-9,
                "frac {frac}: mean bits went up under a tighter budget");
        prev_bytes = best.bytes_per_token;
        prev_bits = bits;
    }
    // the sweep actually tightened something
    assert!(prev_bits < 2.0 + 1e-9, "0.25x fp16 forces below-uniform-2 bits");
}

#[test]
fn file_round_trip_is_bit_exact() {
    let imp = synthetic_importance(4, 31);
    let res = search_modeled(&imp, &SearchCfg::default(), KV_DIM, GROUP).unwrap();
    let path = std::env::temp_dir()
        .join(format!("kvmix_plan_search_{}.json", std::process::id()));
    res.write_file(&path).unwrap();
    let raw = std::fs::read_to_string(&path).unwrap();
    assert_eq!(raw, res.to_json().to_string() + "\n",
               "emitted file must be the canonical serialization");
    let back = SearchResult::read_file(&path).unwrap();
    assert_eq!(back.to_json().to_string() + "\n", raw,
               "read -> re-serialize must be byte-identical");
    assert_eq!(back.n_layers, res.n_layers);
    assert_eq!(back.frontier.len(), res.frontier.len());
    assert_eq!(back.best().unwrap().plan, res.best().unwrap().plan);
    std::fs::remove_file(&path).ok();
}

#[test]
fn infeasible_budget_gives_empty_frontier_and_no_best() {
    let imp = synthetic_importance(4, 37);
    let res = search_plans_with_budget(&imp, &SearchCfg::default(), KV_DIM, GROUP,
                                       0.0, &mut |p| Ok(modeled_ppl(&imp, p)))
        .unwrap();
    assert!(res.frontier.is_empty());
    assert!(res.best().is_none());
    // an empty frontier still round-trips canonically
    let s = res.to_json().to_string();
    let back = SearchResult::from_json(&kvmix::util::json::parse(&s).unwrap()).unwrap();
    assert_eq!(back.to_json().to_string(), s);
}
