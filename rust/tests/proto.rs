//! Property + fuzz wall around the NDJSON serving protocol
//! (DESIGN.md §Serving-Protocol): round-trip encode→scan for randomized
//! valid frames, a ≥10k-case byte-mutation harness over the scanner, and
//! the differential bound that scanner acceptance is a strict subset of
//! the tree parser's.  Hand-rolled generator loop (proptest is not
//! available offline); every case prints its seed on failure for replay.

use kvmix::coordinator::proto::{
    self, scan_client_frame, ClientFrame, GenReq, MAX_PROMPT_TOKENS,
};
use kvmix::util::json;
use kvmix::util::Rng;

fn for_cases(n: usize, seed0: u64, mut f: impl FnMut(u64, &mut Rng)) {
    for i in 0..n {
        let seed = seed0.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// A random *valid* generation frame (validation-range fields only).
fn gen_req(rng: &mut Rng) -> GenReq {
    let prompt: Vec<i32> = (0..rng.range(1, 40))
        .map(|_| rng.below(2_000_000) as i32 - 1_000_000)
        .collect();
    GenReq {
        id: rng.next_u64() >> rng.below(64),
        prompt,
        max_new: rng.range(1, 4096),
        priority: rng.below(11) as i32 - 5,
        deadline_ms: rng.bool(0.4).then(|| rng.next_u64() >> 34),
        temperature: rng.bool(0.4).then(|| rng.uniform(0.05, 4.0)),
        top_k: rng.bool(0.4).then(|| rng.range(1, 200)),
        stop: rng.bool(0.3).then(|| rng.below(1_000_000) as i32 - 500_000),
    }
}

#[test]
fn prop_gen_roundtrip() {
    // scan(encode(g)) == Gen(g), bit-exactly, for randomized frames —
    // including the f64 temperature (shortest-repr Display round-trips)
    for_cases(400, 0xA11CE, |seed, rng| {
        let g = gen_req(rng);
        let line = g.encode();
        match scan_client_frame(line.as_bytes()) {
            Ok(ClientFrame::Gen(back)) => assert_eq!(back, g, "seed {seed}"),
            other => panic!("seed {seed}: {other:?} for {line}"),
        }
    });
}

#[test]
fn prop_roundtrip_survives_reordering_whitespace_and_unknown_keys() {
    // the canonical encoding is only one spelling: keys in any order,
    // random inter-token whitespace, and validated-but-ignored unknown
    // keys must scan to the same frame
    for_cases(300, 0xB0B, |seed, rng| {
        let g = gen_req(rng);
        let mut fields: Vec<String> = vec![
            format!("\"id\":{}", g.id),
            format!("\"prompt\":[{}]",
                    g.prompt.iter().map(|t| t.to_string())
                        .collect::<Vec<_>>().join(",")),
            format!("\"max_new\":{}", g.max_new),
        ];
        if g.priority != 0 {
            fields.push(format!("\"priority\":{}", g.priority));
        }
        if let Some(d) = g.deadline_ms {
            fields.push(format!("\"deadline_ms\":{d}"));
        }
        if let Some(t) = g.temperature {
            fields.push(format!("\"temperature\":{t}"));
        }
        if let Some(k) = g.top_k {
            fields.push(format!("\"top_k\":{k}"));
        }
        if let Some(t) = g.stop {
            fields.push(format!("\"stop\":{t}"));
        }
        for _ in 0..rng.below(3) {
            let junk = [
                "\"x\":null", "\"meta\":{\"a\":[1,{\"b\":false}]}",
                "\"tag\":\"g\\u00e9n\\n\"", "\"w\":[[],[1.5e3],true]",
                "\"neg\":-0.25",
            ][rng.below(5)];
            fields.push(junk.to_string());
        }
        rng.shuffle(&mut fields);
        let ws = |rng: &mut Rng| " \t".repeat(rng.below(2));
        let mut line = String::from("{");
        for (i, f) in fields.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&ws(rng));
            line.push_str(f);
            line.push_str(&ws(rng));
        }
        line.push('}');
        match scan_client_frame(line.as_bytes()) {
            Ok(ClientFrame::Gen(back)) => assert_eq!(back, g, "seed {seed}: {line}"),
            other => panic!("seed {seed}: {other:?} for {line}"),
        }
    });
}

#[test]
fn prop_mutation_harness_never_panics_and_errors_stay_in_bounds() {
    // ≥10k randomized malformed inputs (ISSUE 7 acceptance bar): take a
    // valid encoding or raw random bytes, truncate / insert / flip at a
    // random offset, and require (a) no panic, (b) every error offset
    // lands inside the input, (c) the differential bound below
    let mut cases = 0usize;
    let mut accepted = 0usize;
    for_cases(10_500, 0xF022, |seed, rng| {
        cases += 1;
        let mut bytes: Vec<u8> = if rng.bool(0.7) {
            match rng.below(3) {
                0 => gen_req(rng).encode().into_bytes(),
                1 => proto::cancel_frame(rng.next_u64()).into_bytes(),
                _ => proto::stats_request_frame().into_bytes(),
            }
        } else {
            (0..rng.range(0, 64)).map(|_| rng.below(256) as u8).collect()
        };
        for _ in 0..rng.range(1, 4) {
            if bytes.is_empty() {
                bytes.push(rng.below(256) as u8);
                continue;
            }
            let at = rng.below(bytes.len());
            match rng.below(3) {
                0 => bytes.truncate(at),
                1 => bytes.insert(at, rng.below(256) as u8),
                _ => bytes[at] ^= 1 << rng.below(8),
            }
        }
        match scan_client_frame(&bytes) {
            Ok(_) => {
                accepted += 1;
                // differential property: anything the lazy scanner
                // admits, the tree parser must admit too (the scanner
                // may be stricter, never more lenient)
                let s = std::str::from_utf8(&bytes)
                    .unwrap_or_else(|e| panic!("seed {seed}: accepted non-utf8 {e}"));
                assert!(json::parse(s).is_ok(),
                        "seed {seed}: scanner accepted what json::parse rejects: {s}");
            }
            Err(e) => {
                assert!(e.at <= bytes.len(),
                        "seed {seed}: error offset {} beyond len {}",
                        e.at, bytes.len());
                assert!(!e.msg.is_empty(), "seed {seed}");
            }
        }
    });
    assert!(cases >= 10_000, "harness must run ≥10k cases, ran {cases}");
    // sanity on the harness itself: single-bit flips leave some frames
    // intact, so acceptance is nonzero — but most mutations must break
    assert!(accepted > 0 && accepted < cases / 2,
            "mutation harness degenerate: {accepted}/{cases} accepted");
}

#[test]
fn prop_scanner_matches_tree_parser_on_random_json_like_bytes() {
    // pure-noise differential sweep, independent of any valid seed frame
    for_cases(4_000, 0xD1FF, |seed, rng| {
        let alphabet = b"{}[]\",:0123456789.eE+-truefalsnl \t\\u00";
        let bytes: Vec<u8> = (0..rng.range(0, 48))
            .map(|_| alphabet[rng.below(alphabet.len())])
            .collect();
        if let Ok(frame) = scan_client_frame(&bytes) {
            let s = std::str::from_utf8(&bytes).expect("alphabet is ascii");
            assert!(json::parse(s).is_ok(),
                    "seed {seed}: scanner-only acceptance of {s} -> {frame:?}");
        }
    });
}

#[test]
fn scanner_enforces_protocol_limits() {
    // over-long prompt arrays are rejected mid-scan (bounded allocation),
    // not after materializing the whole vector
    let mut line = String::from("{\"id\":1,\"prompt\":[");
    for i in 0..=MAX_PROMPT_TOKENS {
        if i > 0 {
            line.push(',');
        }
        line.push('1');
    }
    line.push_str("],\"max_new\":4}");
    let e = scan_client_frame(line.as_bytes()).unwrap_err();
    assert_eq!(e.msg, "prompt exceeds MAX_PROMPT_TOKENS");
    assert!(e.at <= line.len());

    // boundary values survive
    let ok = format!("{{\"id\":1,\"prompt\":[5],\"max_new\":{}}}",
                     proto::MAX_NEW_TOKENS);
    assert!(scan_client_frame(ok.as_bytes()).is_ok());
    let over = format!("{{\"id\":1,\"prompt\":[5],\"max_new\":{}}}",
                       proto::MAX_NEW_TOKENS + 1);
    assert!(scan_client_frame(over.as_bytes()).is_err());
}

#[test]
fn server_frames_are_single_line_parseable_json() {
    // every server-side encoder emits exactly one line of JSON the tree
    // parser accepts — streamed frames can never corrupt the NDJSON
    // framing, whatever ends up in the error string
    let frames = [
        proto::delta_frame(3, &[1, -2, 3]),
        proto::reject_frame(Some(9), "admission queue full \"now\"\n", Some(120)),
        proto::reject_frame(None, "bad\tframe", None),
        proto::error_frame("parse error at byte 3: expected ':' after key"),
        proto::cancel_frame(17),
        proto::stats_request_frame(),
    ];
    for f in frames {
        assert!(!f.contains('\n'), "frame has embedded newline: {f}");
        assert!(json::parse(&f).is_ok(), "unparseable frame: {f}");
    }
}
