//! Paged KV pool + pressure controller invariants that need no PJRT
//! runtime: the downshift-before-preempt ordering, floor enforcement,
//! page lifecycle across preemption, and page-granular budget charging
//! (DESIGN.md §Memory-Manager).

use kvmix::baselines::Method;
use kvmix::config::{ModelConfig, QuantPlan};
use kvmix::kvcache::{pressure, KvSide, MemoryBudget, PagePool, SeqKvCache};
use kvmix::util::Rng;

const PT: usize = 64;

fn filled(m: &ModelConfig, plan: &QuantPlan, tokens: usize, seed: u64) -> SeqKvCache {
    let mut c = SeqKvCache::new(m, plan);
    let kv = m.kv_dim();
    let mut rng = Rng::new(seed);
    let k = rng.normal_vec(tokens * kv);
    let v = rng.normal_vec(tokens * kv);
    for l in &mut c.layers {
        l.append(&k, &v, tokens);
    }
    c
}

/// Drive the engine's pressure policy against a budget: sync + charge;
/// on failure downshift (oldest sequence first), and only when no page
/// can move preempt the youngest sequence.  Returns the event log.
fn relieve_until_fit(caches: &mut Vec<(u64, SeqKvCache)>, pool: &mut PagePool,
                     budget: &mut MemoryBudget,
                     floors: &kvmix::kvcache::PressureCfg) -> Vec<char> {
    let mut events = Vec::new();
    loop {
        for (id, c) in caches.iter() {
            pool.sync(*id, c);
        }
        if budget.set_kv(pool.modeled_bytes()).is_ok() {
            return events;
        }
        let mut moved = false;
        for (_, c) in caches.iter_mut() {
            if pressure::downshift_one(c, PT, floors).is_some() {
                events.push('D');
                moved = true;
                break;
            }
        }
        if moved {
            continue;
        }
        assert!(caches.len() > 1, "budget unsatisfiable even after preempting all but one");
        events.push('P');
        let (id, _) = caches.pop().unwrap();
        pool.free_owner(id);
    }
}

#[test]
fn downshift_satisfies_budget_without_preemption() {
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
    let floors = Method::Kvmix(plan.clone()).pressure_floors(m.n_layers);
    let mut caches: Vec<(u64, SeqKvCache)> = (0..2u64)
        .map(|i| (i, filled(&m, &plan, 256, i + 1)))
        .collect();
    let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    for (id, c) in &caches {
        pool.sync(*id, c);
    }
    let full = pool.modeled_bytes();
    let reclaimable: usize = caches.iter()
        .map(|(_, c)| pressure::reclaimable_bytes(c, PT, &floors))
        .sum();
    assert!(reclaimable > 0);
    // budget = exactly the all-at-floor footprint: downshift alone must
    // cover it, with zero preemptions before (or at) the floors
    let mut budget = MemoryBudget::new(full - reclaimable, 0).unwrap();
    let events = relieve_until_fit(&mut caches, &mut pool, &mut budget, &floors);
    assert!(events.contains(&'D'), "pages must downshift");
    assert!(!events.contains(&'P'), "no preemption before the floors are reached");
    assert_eq!(caches.len(), 2);
    assert!(pool.stats.retags > 0, "sync must observe the downshifts");
    // every sealed page of every sequence now sits at its floor
    for (_, c) in &caches {
        for (li, l) in c.layers.iter().enumerate() {
            for &s in &[KvSide::Key, KvSide::Value] {
                for p in 0..l.sealed_quant_pages(s, PT) {
                    assert_eq!(l.quant_page_bits(s, p, PT), floors.floor(li, s));
                }
            }
        }
    }
}

#[test]
fn preemption_only_after_floors_exhausted() {
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
    let floors = Method::Kvmix(plan.clone()).pressure_floors(m.n_layers);
    let mut caches: Vec<(u64, SeqKvCache)> = (0..2u64)
        .map(|i| (i, filled(&m, &plan, 256, i + 10)))
        .collect();
    let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    for (id, c) in &caches {
        pool.sync(*id, c);
    }
    let full = pool.modeled_bytes();
    let reclaimable: usize = caches.iter()
        .map(|(_, c)| pressure::reclaimable_bytes(c, PT, &floors))
        .sum();
    // budget below the two-sequence floor footprint but above one
    // sequence's: every page must downshift first, then exactly one
    // preemption closes the gap
    let floor_total = full - reclaimable;
    let mut budget = MemoryBudget::new(floor_total * 3 / 4, 0).unwrap();
    let events = relieve_until_fit(&mut caches, &mut pool, &mut budget, &floors);
    let first_p = events.iter().position(|&e| e == 'P').expect("preemption required");
    assert!(events[..first_p].iter().all(|&e| e == 'D'),
            "all downshifts must precede the first preemption: {events:?}");
    assert_eq!(events.iter().filter(|&&e| e == 'P').count(), 1);
    assert_eq!(caches.len(), 1);
    // the preempted sequence's frames went back to the free lists
    assert_eq!(pool.allocated_pages(), pool.owner_pages(0));
    assert!(pool.stats.frees > 0);
}

#[test]
fn fp16_pages_cannot_downshift_only_preempt() {
    let m = ModelConfig::test_small();
    let plan = QuantPlan::fp16(m.n_layers);
    let floors = Method::Fp16.pressure_floors(m.n_layers);
    let mut caches: Vec<(u64, SeqKvCache)> = (0..3u64)
        .map(|i| (i, filled(&m, &plan, 128, i + 20)))
        .collect();
    let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
    for (id, c) in &caches {
        pool.sync(*id, c);
    }
    let one_seq = pool.modeled_bytes() / 3;
    let mut budget = MemoryBudget::new(one_seq * 3 / 2, 0).unwrap();
    let events = relieve_until_fit(&mut caches, &mut pool, &mut budget, &floors);
    assert!(events.iter().all(|&e| e == 'P'), "fp16 has no downshift rungs: {events:?}");
    assert_eq!(caches.len(), 1);
}

#[test]
fn preempted_sequence_recomputes_to_identical_pages() {
    // preempt-restart recomputes the cache from the same tokens: the
    // rebuilt page layout and modeled footprint must match bit-for-bit
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
    let a = filled(&m, &plan, 192, 77);
    let b = filled(&m, &plan, 192, 77); // same seed = same appended K/V
    assert_eq!(a.modeled_bytes(), b.modeled_bytes());
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        for &s in &[KvSide::Key, KvSide::Value] {
            let (ba, bb) = (la.quant_blocks(s), lb.quant_blocks(s));
            assert_eq!(ba.len(), bb.len());
            for (x, y) in ba.iter().zip(bb) {
                assert_eq!(x.words, y.words, "packed words must be bit-identical");
                assert_eq!(x.scales, y.scales);
                assert_eq!(x.mins, y.mins);
            }
        }
    }
}
