//! Integration tests over the real PJRT runtime + artifacts.  Gated on
//! `make artifacts` having run (skip with a notice otherwise).

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::coordinator::{Engine, EngineCfg, Request};
use kvmix::harness::eval::{evaluate, EvalCfg};
use kvmix::harness::workload::{self, Task};
use kvmix::model::{DecodeScratch, Forward, Sampler};
use kvmix::profiler;
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::json::parse_file;
use kvmix::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

#[test]
fn decode_matches_prefill_teacher_forcing() {
    // fp16 cache: prefill(t) last logits == prefill(t-1) + decode_step(t-1th token)
    let Some(rt) = runtime() else { return };
    let fwd = Forward::new(&rt);
    let mut rng = Rng::new(1);
    let (toks, _) = workload::generate(Task::Lm, &mut rng, 24);
    let vocab = rt.model.vocab;

    let mut c1 = Method::Fp16.make_cache(&rt.model);
    let full = fwd.prefill(&toks, &mut c1).unwrap();
    let last_full = &full[(toks.len() - 1) * vocab..toks.len() * vocab];

    let mut c2 = Method::Fp16.make_cache(&rt.model);
    fwd.prefill(&toks[..toks.len() - 1], &mut c2).unwrap();
    let mut refs = vec![&mut c2];
    let dec = fwd.decode_step(&[toks[toks.len() - 1]], &mut refs, &mut DecodeScratch::default()).unwrap();

    for (i, (a, b)) in dec[..vocab].iter().zip(last_full).enumerate() {
        assert!((a - b).abs() < 2e-3 * b.abs().max(1.0), "logit {i}: {a} vs {b}");
    }
}

#[test]
fn quantized_decode_close_to_fp_at_4bit() {
    let Some(rt) = runtime() else { return };
    let fwd = Forward::new(&rt);
    let mut rng = Rng::new(2);
    let (toks, _) = workload::generate(Task::Recall, &mut rng, 64);
    let vocab = rt.model.vocab;

    let run = |method: &Method| -> Vec<f32> {
        let mut cache = method.make_cache(&rt.model);
        fwd.prefill(&toks[..63], &mut cache).unwrap();
        let mut refs = vec![&mut cache];
        fwd.decode_step(&[toks[63]], &mut refs, &mut DecodeScratch::default()).unwrap()
    };
    let fp = run(&Method::Fp16);
    let q4 = run(&Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 4).without_rpc()));
    let q1 = run(&Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 1).without_rpc()));
    let err = |a: &[f32], b: &[f32]| -> f64 {
        a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / vocab as f64
    };
    let e4 = err(&q4, &fp);
    let e1 = err(&q1, &fp);
    assert!(e4 < e1, "4-bit ({e4}) should beat 1-bit ({e1})");
    assert!(e4 < 0.5, "4-bit logit mse too large: {e4}");
}

#[test]
fn rpc_improves_over_no_rpc_under_2bit() {
    let Some(rt) = runtime() else { return };
    let plan = QuantPlan::uniform(rt.model.n_layers, 2);
    let cfg = EvalCfg { n_seqs: 4, seq_len: 96, prefill_len: 32, batch: 4,
                        seed: 7, query_offset: None };
    let with_rpc = evaluate(&rt, &Method::Kvmix(plan.clone()), Task::Lm, &cfg).unwrap();
    let without = evaluate(&rt, &Method::Kvmix(plan.without_rpc()), Task::Lm, &cfg).unwrap();
    // RPC keeps recent tokens fp -> never worse by a margin
    assert!(with_rpc.ppl() <= without.ppl() * 1.10,
            "rpc {} vs w/o {}", with_rpc.ppl(), without.ppl());
}

#[test]
fn profiler_grads_match_python() {
    let Some(rt) = runtime() else { return };
    let imp = profiler::profile(&rt, 6, 42).unwrap();
    assert!(imp.k.iter().all(|&x| x > 0.0));
    assert!(imp.v.iter().all(|&x| x > 0.0));
    // compare layer ranking against the python profiler's scores
    let j = parse_file(&default_artifacts_dir().join("importance.json")).unwrap();
    let pk = j.get("plan").unwrap().get("k_scores").unwrap().f64_vec().unwrap();
    let pv = j.get("plan").unwrap().get("v_scores").unwrap().f64_vec().unwrap();
    let ck = profiler::rank_correlation(&imp.k, &pk);
    let cv = profiler::rank_correlation(&imp.v, &pv);
    assert!(ck > 0.5, "K rank correlation with python profiler: {ck}");
    assert!(cv > 0.5, "V rank correlation with python profiler: {cv}");
}

#[test]
fn engine_serves_batch_with_budget() {
    let Some(rt) = runtime() else { return };
    let plan = QuantPlan::from_importance_file(
        &default_artifacts_dir().join("importance.json")).unwrap();
    let mut engine = Engine::new(&rt, EngineCfg {
        method: Method::Kvmix(plan), max_batch: 4, kv_budget: Some(64 << 20),
        threads: 1, page_tokens: 0, prefix_cache: false, step_tokens: 0,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).unwrap();
    let mut rng = Rng::new(3);
    for id in 0..6 {
        let (toks, _) = workload::sample_mixture(&mut rng, 40);
        engine.submit(Request { id, prompt: toks, max_new_tokens: 12,
                                sampler: Sampler::Greedy, stop_token: None, priority: 0,
                                deadline_ms: None, submitted_ns: 0, session: None });
    }
    let done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 6);
    for c in &done {
        assert_eq!(c.tokens.len(), 12);
    }
    assert!(engine.metrics.peak_kv_bytes > 0);
    assert!(engine.metrics.throughput() > 0.0);
}

#[test]
fn engine_oom_eviction_still_completes() {
    let Some(rt) = runtime() else { return };
    // tiny budget: only ~1-2 requests fit at once; eviction must requeue
    let method = Method::Fp16;
    let bpt = kvmix::coordinator::estimate_bytes_per_token(&rt, &method);
    let budget = (bpt * 140.0) as usize; // fits ~1 seq of 40+24 comfortably
    let mut engine = Engine::new(&rt, EngineCfg {
        method, max_batch: 4, kv_budget: Some(budget), threads: 1, page_tokens: 0,
        prefix_cache: false, step_tokens: 0,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    }).unwrap();
    let mut rng = Rng::new(4);
    for id in 0..3 {
        let (toks, _) = workload::sample_mixture(&mut rng, 40);
        engine.submit(Request { id, prompt: toks, max_new_tokens: 24,
                                sampler: Sampler::Greedy, stop_token: None, priority: 0,
                                deadline_ms: None, submitted_ns: 0, session: None });
    }
    let done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 3, "all requests must eventually finish");
}

#[test]
fn paged_preemption_resumes_bit_identically() {
    // paged mode, fp16 policy (floors = 16, so the pressure controller
    // has no downshift rungs and must go straight to preempt-restart):
    // a preempted request recomputes from its original tokens, so with
    // greedy sampling its completion must be bit-identical to an
    // unconstrained run.  (Per-lane decode is independent of batch
    // composition — the bucketized executables compute each row
    // identically — so the comparison across the two runs is exact; the
    // pure-cache half of this property is pinned without PJRT in
    // tests/paging.rs::preempted_sequence_recomputes_to_identical_pages.)
    let Some(rt) = runtime() else { return };
    let method = Method::Fp16;
    let bpt = kvmix::coordinator::estimate_bytes_per_token(&rt, &method);
    // 3 requests of 40+40 = 80 tokens, i.e. two 64-token pages each at
    // the end.  230 token-equivalents admit all three while their caches
    // are one page each (192), but cannot hold two grown sequences
    // (2 x 128 = 256): preemption must kick in as they cross a page
    // boundary, and one grown sequence (128) always fits -> no hard OOM.
    let budget = (bpt * 230.0) as usize;
    let run = |kv_budget: Option<usize>| {
        let mut engine = Engine::new(&rt, EngineCfg {
            method: Method::Fp16, max_batch: 4, kv_budget, threads: 1,
            page_tokens: 64, prefix_cache: false, step_tokens: 0,
            pressure_weights: None, spill_dir: None, spill_bytes: 0,
        }).unwrap();
        let mut rng = Rng::new(4);
        for id in 0..3 {
            let (toks, _) = workload::sample_mixture(&mut rng, 40);
            engine.submit(Request { id, prompt: toks, max_new_tokens: 40,
                                    sampler: Sampler::Greedy, stop_token: None, priority: 0,
                                    deadline_ms: None, submitted_ns: 0, session: None });
        }
        let mut done = engine.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        (done, engine.metrics.preemptions, engine.metrics.oom_events)
    };
    let (unconstrained, p0, _) = run(None);
    assert_eq!(p0, 0);
    let (tight, preempts, ooms) = run(Some(budget));
    assert!(preempts > 0, "tight budget must force preemption");
    assert_eq!(ooms, 0, "paged preemption is not an OOM");
    assert_eq!(unconstrained.len(), 3);
    assert_eq!(tight.len(), 3);
    for (a, b) in unconstrained.iter().zip(&tight) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "request {} must resume bit-identically after preemption", a.id);
    }
}

#[test]
fn paged_pressure_downshifts_under_budget() {
    // kvmix plan in paged mode under a budget squeezed well below the
    // unconstrained peak: the run must complete with pages_requantized>0
    // and no hard OOM — downshift-then-preempt in action
    let Some(rt) = runtime() else { return };
    let plan = QuantPlan::from_importance_file(
        &default_artifacts_dir().join("importance.json")).unwrap();
    let method = Method::Kvmix(plan);
    let run = |kv_budget: Option<usize>| {
        let mut engine = Engine::new(&rt, EngineCfg {
            method: method.clone(), max_batch: 4, kv_budget, threads: 1,
            page_tokens: 64, prefix_cache: false, step_tokens: 0,
            pressure_weights: None, spill_dir: None, spill_bytes: 0,
        }).unwrap();
        let mut rng = Rng::new(6);
        for id in 0..4 {
            let (toks, _) = workload::sample_mixture(&mut rng, 48);
            engine.submit(Request { id, prompt: toks, max_new_tokens: 48,
                                    sampler: Sampler::Greedy, stop_token: None, priority: 0,
                                    deadline_ms: None, submitted_ns: 0, session: None });
        }
        let done = engine.run_to_completion().unwrap();
        (done.len(), engine.metrics.peak_kv_bytes, engine.metrics.pages_requantized,
         engine.metrics.oom_events)
    };
    let (n, peak, _, _) = run(None);
    assert_eq!(n, 4);
    let (n2, _, requants, ooms) = run(Some(peak * 55 / 100));
    assert_eq!(n2, 4, "squeezed run must still complete");
    assert!(requants > 0, "pressure must requantize pages before anything drastic");
    assert_eq!(ooms, 0);
}

#[test]
fn generation_above_chance_on_tasks() {
    // E2E sanity: trained model + kvmix cache predicts task answers far
    // above chance.  chain is fully learned (~99% at build time); recall
    // only partially (see DESIGN.md §3's corpus notes) so it is scored by log-prob.
    let Some(rt) = runtime() else { return };
    let plan = QuantPlan::from_importance_file(
        &default_artifacts_dir().join("importance.json")).unwrap();
    let fwd = Forward::new(&rt);
    let vocab = rt.model.vocab;
    let mut rng = Rng::new(11);

    // chain: argmax accuracy at masked positions
    let mut hits = 0usize;
    let mut total = 0usize;
    let (toks, mask) = workload::gen_chain(&mut rng, 96);
    let mut cache = Method::Kvmix(plan.clone()).make_cache(&rt.model);
    let logits = fwd.prefill(&toks, &mut cache).unwrap();
    for p in 4..95 {
        if mask[p] > 0.0 {
            let pred = kvmix::model::sampler::argmax(&logits[p * vocab..(p + 1) * vocab]);
            hits += (pred as i32 == toks[p + 1]) as usize;
            total += 1;
        }
    }
    assert!(hits * 2 > total, "chain hits {hits}/{total}");

    // recall: mean log-prob of the bound value clearly above uniform
    let mut lp_sum = 0f64;
    let mut n = 0usize;
    for _ in 0..4 {
        let (toks, mask) = workload::gen_recall(&mut rng, 96, None, 4);
        let mut cache = Method::Kvmix(plan.clone()).make_cache(&rt.model);
        let logits = fwd.prefill(&toks, &mut cache).unwrap();
        for p in 1..95 {
            if mask[p] > 0.0 {
                lp_sum += kvmix::model::sampler::log_prob(
                    &logits[p * vocab..(p + 1) * vocab], toks[p + 1] as usize);
                n += 1;
            }
        }
    }
    let mean_lp = lp_sum / n as f64;
    let uniform = -(vocab as f64).ln(); // ~ -6.24
    assert!(mean_lp > uniform + 1.0, "recall mean log-prob {mean_lp:.2} vs uniform {uniform:.2}");
}

// ---------------------------------------------------------------------------
// failure injection (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("kvmix_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    // weight entry pointing past the end of weights.bin
    std::fs::write(dir.join("weights.bin"), [0u8; 16]).unwrap();
    let manifest = r#"{
        "model": {"vocab": 8, "d_model": 4, "n_layers": 1, "n_heads": 1,
                   "n_kv_heads": 1, "head_dim": 4, "d_ff": 8, "group": 32},
        "weights": [{"name": "embed", "shape": [2, 4], "offset": 0, "numel": 8}],
        "buckets": [1],
        "executables": {"pre": {}, "post": {}, "logits": {},
                         "profiler": {"file": "x.hlo.txt", "seq_len": 8}}
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let j = parse_file(&dir.join("manifest.json")).unwrap();
    let err = match kvmix::runtime::Weights::load(&dir, &j) {
        Err(e) => e,
        Ok(_) => panic!("corrupt manifest accepted"),
    };
    assert!(format!("{err}").contains("extends past"), "{err}");
}

#[test]
fn manifest_shape_numel_mismatch_rejected() {
    let dir = std::env::temp_dir().join("kvmix_badshape");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("weights.bin"), [0u8; 64]).unwrap();
    let manifest = r#"{"weights": [{"name": "w", "shape": [2, 2], "offset": 0, "numel": 8}]}"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let j = parse_file(&dir.join("manifest.json")).unwrap();
    assert!(kvmix::runtime::Weights::load(&dir, &j).is_err());
}

#[test]
fn missing_importance_file_errors() {
    let p = std::path::PathBuf::from("/nonexistent/importance.json");
    assert!(QuantPlan::from_importance_file(&p).is_err());
}

#[test]
fn importance_with_bad_bits_rejected_by_validate() {
    let dir = std::env::temp_dir().join("kvmix_badplan");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("importance.json"), r#"{
        "plan": {"name": "x", "k_bits": [7, 2], "v_bits": [2, 2],
                  "k_rpc": [0.1, 0.1], "v_rpc": [0.1, 0.1],
                  "k_scores": [1, 2], "v_scores": [1, 2]}
    }"#).unwrap();
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json")).unwrap();
    assert!(plan.validate().is_err());
}
