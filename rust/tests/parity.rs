//! Golden parity vs the python oracles (artifacts/goldens/*.json, emitted
//! by `make artifacts`).  Gated: tests no-op with a notice when artifacts
//! are absent so `cargo test` works pre-build.

use std::path::PathBuf;

use kvmix::kvcache::{AttnScratch, KeyRepr, LayerCacheCfg, LayerKvCache, ValueRepr, WindowPolicy};
use kvmix::quant::{pack_stream, unpack_stream, PackedBlock};
use kvmix::util::json::{parse_file, Json};

fn goldens_dir() -> Option<PathBuf> {
    let d = kvmix::runtime::default_artifacts_dir().join("goldens");
    if d.join("quant.json").exists() {
        Some(d)
    } else {
        eprintln!("SKIP: goldens not found at {} (run `make artifacts`)", d.display());
        None
    }
}

/// Quantization is discontinuous at rounding boundaries; two fp pipelines
/// may pick adjacent buckets for boundary elements.  Require >=99.5% exact
/// and the rest within one step.
fn assert_quant_close(got: &[f32], want: &[f32], step_bound: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    let mut exact = 0usize;
    for (a, b) in got.iter().zip(want) {
        let d = (a - b).abs();
        if d < 1e-5 {
            exact += 1;
        }
        assert!(d <= step_bound, "{ctx}: diff {d} > step {step_bound}");
    }
    let frac = exact as f64 / got.len() as f64;
    assert!(frac >= 0.995, "{ctx}: only {frac:.4} exact");
}

#[test]
fn quant_goldens() {
    let Some(dir) = goldens_dir() else { return };
    let g = parse_file(&dir.join("quant.json")).unwrap();
    let t = g.get("t").unwrap().as_usize().unwrap();
    let hkv = g.get("hkv").unwrap().as_usize().unwrap();
    let hd = g.get("hd").unwrap().as_usize().unwrap();
    let group = g.get("group").unwrap().as_usize().unwrap();
    let kv_dim = hkv * hd;
    let k = g.get("k").unwrap().f32_vec().unwrap(); // [t][kv_dim]
    let v = g.get("v").unwrap().f32_vec().unwrap();

    for bits in [1u8, 2, 4] {
        // Key per-channel: python groups `group` consecutive tokens per
        // channel -> equal to our per-block channel-major layout
        let want_k = g.get(&format!("k_fq_{bits}")).unwrap().f32_vec().unwrap();
        let mut got_k = vec![0f32; t * kv_dim];
        let mut stream = vec![0f32; kv_dim * group];
        let mut deq = vec![0f32; kv_dim * group];
        for blk in 0..t / group {
            for c in 0..kv_dim {
                for tt in 0..group {
                    stream[c * group + tt] = k[(blk * group + tt) * kv_dim + c];
                }
            }
            let b = PackedBlock::quantize(&stream, bits, group);
            b.dequantize_into(&mut deq, &mut Vec::new());
            for c in 0..kv_dim {
                for tt in 0..group {
                    got_k[(blk * group + tt) * kv_dim + c] = deq[c * group + tt];
                }
            }
        }
        let range = want_k.iter().fold((f32::MAX, f32::MIN), |acc, &x| (acc.0.min(x), acc.1.max(x)));
        let step = (range.1 - range.0) / ((1u32 << bits) - 1).max(1) as f32;
        assert_quant_close(&got_k, &want_k, step + 1e-4, &format!("k_fq_{bits}"));

        // Value per-token
        let want_v = g.get(&format!("v_fq_{bits}")).unwrap().f32_vec().unwrap();
        let mut got_v = vec![0f32; t * kv_dim];
        let mut deqv = vec![0f32; group * kv_dim];
        for blk in 0..t / group {
            let rows = &v[blk * group * kv_dim..(blk + 1) * group * kv_dim];
            let b = PackedBlock::quantize(rows, bits, group);
            b.dequantize_into(&mut deqv, &mut Vec::new());
            got_v[blk * group * kv_dim..(blk + 1) * group * kv_dim].copy_from_slice(&deqv);
        }
        assert_quant_close(&got_v, &want_v, step + 1e-4, &format!("v_fq_{bits}"));
    }
}

#[test]
fn pack3_golden_layout() {
    let Some(dir) = goldens_dir() else { return };
    let g = parse_file(&dir.join("quant.json")).unwrap();
    let q: Vec<u32> = g.get("pack3_q").unwrap().usize_vec().unwrap()
        .iter().map(|&x| x as u32).collect();
    let want: Vec<u32> = g.get("pack3_words").unwrap().f64_vec().unwrap()
        .iter().map(|&x| x as i64 as u32).collect();
    let mut words = Vec::new();
    pack_stream(&q, 3, &mut words);
    assert_eq!(words, want, "3-bit packed words differ from python layout");
    let mut out = vec![0u32; q.len()];
    unpack_stream(&want, 3, q.len(), &mut out);
    assert_eq!(out, q);
}

#[test]
fn attention_golden() {
    let Some(dir) = goldens_dir() else { return };
    let g = parse_file(&dir.join("attn.json")).unwrap();
    let h = g.get("h").unwrap().as_usize().unwrap();
    let hd = g.get("hd").unwrap().as_usize().unwrap();
    let t = g.get("t").unwrap().as_usize().unwrap();
    let hkv = g.get("hkv").unwrap().as_usize().unwrap();
    let boundary = g.get("boundary").unwrap().as_usize().unwrap();
    let k_bits = g.get("k_bits").unwrap().as_usize().unwrap() as u8;
    let v_bits = g.get("v_bits").unwrap().as_usize().unwrap() as u8;
    let q = g.get("q").unwrap().f32_vec().unwrap();
    let k = g.get("k").unwrap().f32_vec().unwrap();
    let v = g.get("v").unwrap().f32_vec().unwrap();
    let want = g.get("out").unwrap().f32_vec().unwrap();

    // build a cache whose quantized history covers exactly `boundary`
    // tokens: append the first `boundary` with WindowPolicy::None, then
    // keep the tail fp
    let kv_dim = hkv * hd;
    let mut cache = LayerKvCache::new(LayerCacheCfg {
        kv_dim, head_dim: hd, group: 32,
        key: KeyRepr::PerChannel { bits: k_bits },
        value: ValueRepr::PerToken { bits: v_bits },
        k_window: WindowPolicy::FixedResidual { tokens: t - boundary },
        v_window: WindowPolicy::FixedResidual { tokens: t - boundary },
        outlier_frac: 0.0,
        k_interleave: false,
    });
    cache.append(&k, &v, t);
    assert_eq!(cache.k_hist, boundary, "history boundary");

    let mut out = vec![0f32; h * hd];
    cache.attend(&q, h, &mut out, &mut AttnScratch::default());
    for (i, (a, b)) in out.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 5e-3, "attn[{i}]: {a} vs {b}");
    }
}

#[test]
fn fq3_blockwise_golden() {
    let Some(dir) = goldens_dir() else { return };
    let g = parse_file(&dir.join("quant.json")).unwrap();
    let input = g.get("fq3_block_in").unwrap().f32_vec().unwrap();   // [4][33]
    let want = g.get("fq3_block_out").unwrap().f32_vec().unwrap();
    let mut got = vec![0f32; input.len()];
    for r in 0..4 {
        let row = &input[r * 33..(r + 1) * 33];
        let b = PackedBlock::quantize(row, 3, 33);
        b.dequantize_into(&mut got[r * 33..(r + 1) * 33], &mut Vec::new());
    }
    let mx = want.iter().cloned().fold(f32::MIN, f32::max);
    let mn = want.iter().cloned().fold(f32::MAX, f32::min);
    assert_quant_close(&got, &want, (mx - mn) / 3.0 + 1e-4, "fq3_blockwise");
}

#[test]
fn model_forward_golden() {
    let Some(_) = goldens_dir() else { return };
    let dir = kvmix::runtime::default_artifacts_dir();
    let g = parse_file(&dir.join("goldens").join("model.json")).unwrap();
    let tokens: Vec<i32> = g.get("tokens").unwrap().usize_vec().unwrap()
        .iter().map(|&x| x as i32).collect();
    let want_last = g.get("logits_last").unwrap().f32_vec().unwrap();
    let want_greedy: Vec<usize> = g.get("greedy").unwrap().usize_vec().unwrap();

    let rt = kvmix::runtime::Runtime::load_with(&dir, false).unwrap();
    let fwd = kvmix::model::Forward::new(&rt);
    let mut cache = kvmix::baselines::Method::Fp16.make_cache(&rt.model);
    let logits = fwd.prefill(&tokens, &mut cache).unwrap();
    let vocab = rt.model.vocab;
    let t = tokens.len();
    // last-position logits close to the jnp forward
    let last = &logits[(t - 1) * vocab..t * vocab];
    for (i, (a, b)) in last.iter().zip(&want_last).enumerate() {
        assert!((a - b).abs() < 2e-2 * b.abs().max(1.0),
                "logit[{i}]: rust {a} vs python {b}");
    }
    // greedy argmax agrees at every position
    let mut agree = 0;
    for p in 0..t {
        let row = &logits[p * vocab..(p + 1) * vocab];
        if kvmix::model::sampler::argmax(row) == want_greedy[p] {
            agree += 1;
        }
    }
    assert!(agree as f64 >= 0.95 * t as f64, "greedy agreement {agree}/{t}");
}

#[test]
fn what_json_says_matches_modelconfig() {
    let dir = kvmix::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no manifest");
        return;
    }
    let manifest = parse_file(&dir.join("manifest.json")).unwrap();
    let m = kvmix::config::ModelConfig::from_json(manifest.get("model").unwrap()).unwrap();
    assert!(m.n_layers >= 2);
    assert_eq!(m.q_dim(), m.n_heads * m.head_dim);
    // importance plan layer count matches
    if let Ok(plan) = kvmix::config::QuantPlan::from_importance_file(&dir.join("importance.json")) {
        assert_eq!(plan.n_layers(), m.n_layers);
        plan.validate().unwrap();
    }
    let _ = Json::Null;
}
