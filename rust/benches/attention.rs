//! Decode-attention benchmark over the mixed cache: tokens/s as a
//! function of context length, bit width and RPC ratio — the L3 hot path
//! that the paper accelerates with fused CUDA kernels — plus the
//! worker-pool fan-out rows (threads={1,2,4,8}) for batched decode and
//! head-parallel prefill (DESIGN.md §Threading-Model).

use kvmix::attention::prefill_attention_with;
use kvmix::kvcache::{AttnScratch, KeyRepr, LayerCacheCfg, LayerKvCache, ValueRepr, WindowPolicy};
use kvmix::util::bench::{bench, black_box, JsonSink};
use kvmix::util::{Rng, WorkerPool};

fn build_cache(key: KeyRepr, value: ValueRepr, window: WindowPolicy,
               ctx: usize, kv_dim: usize) -> LayerKvCache {
    build_cache_layout(key, value, window, ctx, kv_dim, false)
}

fn build_cache_layout(key: KeyRepr, value: ValueRepr, window: WindowPolicy,
                      ctx: usize, kv_dim: usize, k_interleave: bool) -> LayerKvCache {
    let mut cache = LayerKvCache::new(LayerCacheCfg {
        kv_dim, head_dim: 32, group: 32, key, value,
        k_window: window, v_window: window, outlier_frac: 0.0,
        k_interleave,
    });
    let mut rng = Rng::new(9);
    let k = rng.normal_vec(ctx * kv_dim);
    let v = rng.normal_vec(ctx * kv_dim);
    cache.append(&k, &v, ctx);
    cache
}

fn main() {
    let mut sink = JsonSink::from_env("attention");
    println!("# decode attention over the mixed cache (4 heads, kv_dim 64)");
    let kv_dim = 64;
    let mut rng = Rng::new(1);
    let q = rng.normal_vec(4 * 32);
    let mut out = vec![0f32; 4 * 32];
    let mut scratch = AttnScratch::default();

    for ctx in [128usize, 512, 2048] {
        // fp16 baseline
        let fp = build_cache(KeyRepr::Fp, ValueRepr::Fp, WindowPolicy::All, ctx, kv_dim);
        let s = bench(&format!("attend/fp/ctx{ctx}"), 50, || {
            fp.attend(black_box(&q), 4, &mut out, &mut scratch);
            black_box(&out);
        });
        println!("{}  ({:.1} Mtok/s)", s.line(), s.throughput(ctx as f64) / 1e6);
        sink.record(&s, Some(ctx as f64));

        for bits in [2u8, 3, 4] {
            let cache = build_cache(KeyRepr::PerChannel { bits },
                                    ValueRepr::PerToken { bits },
                                    WindowPolicy::Rpc { ratio: 0.1 }, ctx, kv_dim);
            let s = bench(&format!("attend/kvmix{bits}bit/ctx{ctx}"), 50, || {
                cache.attend(black_box(&q), 4, &mut out, &mut scratch);
                black_box(&out);
            });
            println!("{}  ({:.1} Mtok/s, {} fp tokens)",
                     s.line(), s.throughput(ctx as f64) / 1e6, cache.k_fp_tokens());
            sink.record(&s, Some(ctx as f64));
        }

        // channel-interleaved K word layout (ADR-009): same arithmetic,
        // sequential word loads — attend outputs are bit-identical
        let inter = build_cache_layout(KeyRepr::PerChannel { bits: 2 },
                                       ValueRepr::PerToken { bits: 2 },
                                       WindowPolicy::Rpc { ratio: 0.1 }, ctx, kv_dim,
                                       true);
        let s = bench(&format!("attend/kvmix2bit_inter/ctx{ctx}"), 50, || {
            inter.attend(black_box(&q), 4, &mut out, &mut scratch);
            black_box(&out);
        });
        println!("{}  ({:.1} Mtok/s)", s.line(), s.throughput(ctx as f64) / 1e6);
        sink.record(&s, Some(ctx as f64));
    }

    println!("\n# batched decode attend fan-out (8 lanes, ctx 512, kvmix 2-bit)");
    {
        let (n_heads, hd) = (4usize, 32usize);
        let qd = n_heads * hd;
        let bsz = 8usize;
        let lanes: Vec<LayerKvCache> = (0..bsz).map(|_| {
            build_cache(KeyRepr::PerChannel { bits: 2 }, ValueRepr::PerToken { bits: 2 },
                        WindowPolicy::Rpc { ratio: 0.1 }, 512, kv_dim)
        }).collect();
        let mut rngb = Rng::new(4);
        let qs = rngb.normal_vec(bsz * qd);
        let mut outs = vec![0f32; bsz * qd];
        for threads in [1usize, 2, 4, 8] {
            WorkerPool::scoped(threads, |pool| {
                let nw = pool.threads().min(bsz);
                let per = bsz.div_ceil(nw);
                let mut scratches: Vec<AttnScratch> = Vec::new();
                scratches.resize_with(nw, AttnScratch::default);
                let s = bench(&format!("attend/batch{bsz}/threads{threads}"), 40, || {
                    let chunks = outs.chunks_mut(per * qd)
                        .zip(scratches.iter_mut())
                        .enumerate()
                        .map(|(ci, (o, ws))| (ci * per, o, ws));
                    pool.run_tasks(chunks, |_w, (lane0, o, ws)| {
                        for i in 0..o.len() / qd {
                            let b = lane0 + i;
                            lanes[b].attend(black_box(&qs[b * qd..(b + 1) * qd]),
                                            n_heads, &mut o[i * qd..(i + 1) * qd], ws);
                        }
                    });
                    black_box(&outs);
                });
                println!("{}  ({:.1} Mtok/s over all lanes)",
                         s.line(), s.throughput((bsz * 512) as f64) / 1e6);
                sink.record(&s, Some((bsz * 512) as f64));
            });
        }
    }

    println!("\n# head-parallel prefill attention (t=256, 8 heads, hd 32)");
    {
        let (t, h, n_kv, hd) = (256usize, 8usize, 4usize, 32usize);
        let mut rngp = Rng::new(5);
        let q = rngp.normal_vec(t * h * hd);
        let k = rngp.normal_vec(t * n_kv * hd);
        let v = rngp.normal_vec(t * n_kv * hd);
        for threads in [1usize, 2, 4, 8] {
            WorkerPool::scoped(threads, |pool| {
                let s = bench(&format!("prefill/t{t}/threads{threads}"), 20, || {
                    let o = prefill_attention_with(black_box(&q), &k, &v, t, h, n_kv,
                                                   hd, Some(pool));
                    black_box(&o);
                });
                println!("{}  ({:.2} Mtok/s)", s.line(), s.throughput(t as f64) / 1e6);
                sink.record(&s, Some(t as f64));
            });
        }
    }

    println!("\n# quantize+append (fused) — cost of pushing 1 token with block flush amortized");
    for bits in [2u8, 3, 4] {
        let mut cache = build_cache(KeyRepr::PerChannel { bits },
                                    ValueRepr::PerToken { bits },
                                    WindowPolicy::Rpc { ratio: 0.1 }, 64, kv_dim);
        let mut rng2 = Rng::new(2);
        let k1 = rng2.normal_vec(kv_dim);
        let v1 = rng2.normal_vec(kv_dim);
        let s = bench(&format!("append/{bits}bit"), 40, || {
            cache.append(black_box(&k1), black_box(&v1), 1);
        });
        println!("{}", s.line());
        sink.record(&s, None);
    }

    sink.finish();
}
