//! Decode-attention benchmark over the mixed cache: tokens/s as a
//! function of context length, bit width and RPC ratio — the L3 hot path
//! that the paper accelerates with fused CUDA kernels.

use kvmix::kvcache::{AttnScratch, KeyRepr, LayerCacheCfg, LayerKvCache, ValueRepr, WindowPolicy};
use kvmix::util::bench::{bench, black_box};
use kvmix::util::Rng;

fn build_cache(key: KeyRepr, value: ValueRepr, window: WindowPolicy,
               ctx: usize, kv_dim: usize) -> LayerKvCache {
    let mut cache = LayerKvCache::new(LayerCacheCfg {
        kv_dim, head_dim: 32, group: 32, key, value,
        k_window: window, v_window: window, outlier_frac: 0.0,
    });
    let mut rng = Rng::new(9);
    let k = rng.normal_vec(ctx * kv_dim);
    let v = rng.normal_vec(ctx * kv_dim);
    cache.append(&k, &v, ctx);
    cache
}

fn main() {
    println!("# decode attention over the mixed cache (4 heads, kv_dim 64)");
    let kv_dim = 64;
    let mut rng = Rng::new(1);
    let q = rng.normal_vec(4 * 32);
    let mut out = vec![0f32; 4 * 32];
    let mut scratch = AttnScratch::default();

    for ctx in [128usize, 512, 2048] {
        // fp16 baseline
        let fp = build_cache(KeyRepr::Fp, ValueRepr::Fp, WindowPolicy::All, ctx, kv_dim);
        let s = bench(&format!("attend/fp/ctx{ctx}"), 50, || {
            fp.attend(black_box(&q), 4, &mut out, &mut scratch);
            black_box(&out);
        });
        println!("{}  ({:.1} Mtok/s)", s.line(), s.throughput(ctx as f64) / 1e6);

        for bits in [2u8, 3, 4] {
            let cache = build_cache(KeyRepr::PerChannel { bits },
                                    ValueRepr::PerToken { bits },
                                    WindowPolicy::Rpc { ratio: 0.1 }, ctx, kv_dim);
            let s = bench(&format!("attend/kvmix{bits}bit/ctx{ctx}"), 50, || {
                cache.attend(black_box(&q), 4, &mut out, &mut scratch);
                black_box(&out);
            });
            println!("{}  ({:.1} Mtok/s, {} fp tokens)",
                     s.line(), s.throughput(ctx as f64) / 1e6, cache.k_fp_tokens());
        }
    }

    println!("\n# quantize+append (fused) — cost of pushing 1 token with block flush amortized");
    for bits in [2u8, 3, 4] {
        let mut cache = build_cache(KeyRepr::PerChannel { bits },
                                    ValueRepr::PerToken { bits },
                                    WindowPolicy::Rpc { ratio: 0.1 }, 64, kv_dim);
        let mut rng2 = Rng::new(2);
        let k1 = rng2.normal_vec(kv_dim);
        let v1 = rng2.normal_vec(kv_dim);
        let s = bench(&format!("append/{bits}bit"), 40, || {
            cache.append(black_box(&k1), black_box(&v1), 1);
        });
        println!("{}", s.line());
    }
}
