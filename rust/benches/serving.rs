//! Serving-path benchmarks for the multi-replica router, the disk spill
//! tier, and session resume (DESIGN.md §Replication, §Spill-Tier,
//! docs/adr/008-replica-router-and-spill-tier.md).
//!
//! Artifact-free sections (always run):
//!   * `router/*` — [`route_replica`] dispatch throughput over a bursty
//!     heavy-tailed arrival trace at 2/4/8 replicas.  Routing is a pure
//!     hash + argmax over per-replica loads, so this prices the
//!     per-request coordinator overhead of `--replicas N`.
//!   * `workload/*` — the seeded workload generators themselves
//!     (multi-turn chat, bursty Poisson arrivals, reasoning prompts).
//!   * `spill/*` — a full spill→fault-back cycle over every sealed page
//!     of a synthetic cache through [`PagePool`]'s file tier: pages/s is
//!     the spill fault service rate.
//!
//! The `resume/*` section needs the PJRT runtime (gated on
//! `make artifacts` like benches/e2e_decode.rs): it compares turn-2 TTFT
//! of a parked-then-resumed session against a cold engine full-prefilling
//! the concatenated conversation — the resume row skips the adopted
//! prefix's prefill and re-quantization.

use kvmix::baselines::Method;
use kvmix::config::{ModelConfig, QuantPlan};
use kvmix::coordinator::{route_replica, Engine, EngineCfg, Request};
use kvmix::harness::workload;
use kvmix::kvcache::{PagePool, SeqKvCache};
use kvmix::model::Sampler;
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::bench::{bench, black_box, JsonSink};
use kvmix::util::Rng;

fn main() {
    let mut sink = JsonSink::from_env("serving");

    // -- router dispatch throughput (artifact-free) --
    let mut rng = Rng::new(71);
    let trace = workload::bursty_poisson(&mut rng, 1024, 200.0, 8.0, 1.2, 8, 256);
    let prompts: Vec<&[i32]> = trace.iter().map(|(_, p)| p.as_slice()).collect();
    println!("# route_replica dispatch ({} bursty prompts, page 64, slack 8)",
             prompts.len());
    for n in [2usize, 4, 8] {
        let s = bench(&format!("router/route/replicas{n}"), 80, || {
            let mut loads = vec![0usize; n];
            for p in &prompts {
                let r = route_replica(n, &loads, p, 64, None, 8);
                loads[black_box(r)] += 1;
            }
        });
        println!("{}", s.line());
        sink.record(&s, Some(prompts.len() as f64));
    }

    // -- workload generators (artifact-free; seeded-deterministic) --
    println!();
    println!("# workload generators");
    let s = bench("workload/multi_turn_chat/8x32", 40, || {
        let mut rng = Rng::new(72);
        black_box(workload::multi_turn_chat(&mut rng, 8, 32, 16));
    });
    println!("{}", s.line());
    sink.record(&s, Some(8.0));
    let s = bench("workload/bursty_poisson/256", 40, || {
        let mut rng = Rng::new(73);
        black_box(workload::bursty_poisson(&mut rng, 256, 100.0, 10.0, 1.1, 8, 512));
    });
    println!("{}", s.line());
    sink.record(&s, Some(256.0));
    let s = bench("workload/reasoning_prompts/64", 40, || {
        let mut rng = Rng::new(74);
        black_box(workload::reasoning_prompts(&mut rng, 64, 32, 48, 96));
    });
    println!("{}", s.line());
    sink.record(&s, Some(64.0));

    // -- spill tier round trip (artifact-free): spill every sealed page
    //    to disk, fault them all back; pages/s is the fault service rate --
    println!();
    let dir = std::env::temp_dir()
        .join(format!("kvmix-bench-spill-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("spill dir");
    let m = ModelConfig::test_small();
    let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
    let kv = m.kv_dim();
    let tokens = 4 * 64;
    let mut cache = SeqKvCache::new(&m, &plan);
    let mut srng = Rng::new(75);
    let k = srng.normal_vec(tokens * kv);
    let v = srng.normal_vec(tokens * kv);
    for l in &mut cache.layers {
        l.append(&k, &v, tokens);
    }
    let mut pool = PagePool::new(64, kv, m.group).expect("page pool");
    pool.enable_spill(&dir, 0).expect("spill tier");
    pool.sync(1, &cache);
    let mut pages = 0usize;
    println!("# spill round trip ({} tokens x {} layers, page 64)",
             tokens, m.n_layers);
    let s = bench("spill/roundtrip/pages", 80, || {
        let mut n = 0usize;
        while pool.spill_one(1, &mut cache, false).is_some() {
            n += 1;
        }
        n += pool.fault_back_owner(1, &mut cache);
        pages = black_box(n / 2);
    });
    println!("{}  ({pages} pages/cycle)", s.line());
    sink.record(&s, Some(pages as f64));
    pool.free_owner(1);
    drop(pool);
    let _ = std::fs::remove_dir_all(&dir);

    // -- session resume vs full re-prefill TTFT (needs artifacts) --
    let adir = default_artifacts_dir();
    if !adir.join("manifest.json").exists() {
        println!();
        println!("SKIP resume section: artifacts not built");
        sink.finish();
        return;
    }
    let rt = Runtime::load_with(&adir, false).expect("runtime");
    let plan = QuantPlan::from_importance_file(&adir.join("importance.json"))
        .unwrap_or_else(|_| QuantPlan::uniform(rt.model.n_layers, 2));
    let cfg = EngineCfg {
        method: Method::Kvmix(plan.without_rpc()), max_batch: 2,
        kv_budget: None, threads: 1, page_tokens: 64, prefix_cache: false,
        step_tokens: 64, pressure_weights: None, spill_dir: None,
        spill_bytes: 0,
    };
    let sreq = |id: u64, prompt: Vec<i32>, session: Option<u64>| Request {
        id, prompt, max_new_tokens: 16, sampler: Sampler::Greedy,
        stop_token: None, priority: 0, deadline_ms: None, submitted_ns: 0,
        session,
    };
    let iters = 5usize;
    let (mut ttft_resume, mut ttft_cold, mut reused) = (0.0f64, 0.0f64, 0usize);
    let mut warm = Engine::new(&rt, cfg.clone()).expect("engine");
    let mut cold = Engine::new(&rt, cfg).expect("engine");
    for i in 0..iters as u64 {
        let p1: Vec<i32> = (1..=130).map(|t| t + i as i32).collect();
        warm.submit(sreq(2 * i, p1.clone(), Some(i)));
        let done = warm.run_to_completion().expect("turn 1");
        let mut p2 = p1;
        p2.extend_from_slice(&done[0].tokens);
        p2.extend(300..314);
        let before = warm.metrics.resume_tokens_reused;
        warm.submit(sreq(2 * i + 1, p2.clone(), Some(i)));
        let done = warm.run_to_completion().expect("turn 2");
        ttft_resume += done[0].ttft_ms();
        reused += warm.metrics.resume_tokens_reused - before;
        cold.submit(sreq(i, p2, None));
        let done = cold.run_to_completion().expect("cold");
        ttft_cold += done[0].ttft_ms();
    }
    assert_eq!(warm.metrics.sessions_resumed as usize, iters);
    println!();
    println!("# session resume vs full re-prefill (turn-2 TTFT, {iters} sessions, \
              {} tokens adopted/turn)", reused / iters);
    println!("{:<24} {:>12.3} ms", "resume", ttft_resume / iters as f64);
    println!("{:<24} {:>12.3} ms", "reprefill", ttft_cold / iters as f64);
    sink.record_value("resume/ttft_ms/resume",
                      ttft_resume / iters as f64 * 1e6, None);
    sink.record_value("resume/ttft_ms/reprefill",
                      ttft_cold / iters as f64 * 1e6, None);
    sink.finish();
}
