//! Bench harness for paper Fig. 8: decode throughput vs batch size under
//! the simulated HBM budget (OOM ceilings included).

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::harness::tables::run_serving;
use kvmix::kvcache::fp16_kv_bytes;
use kvmix::runtime::{default_artifacts_dir, Runtime};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP fig8_throughput: artifacts not built");
        return;
    }
    let rt = Runtime::load_with(&dir, false).expect("runtime");
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))
        .unwrap_or_else(|_| QuantPlan::uniform(rt.model.n_layers, 2));

    let prompt = 48;
    let gen = 64;
    let budget = 6 * fp16_kv_bytes(prompt + gen, rt.model.kv_dim(), rt.model.n_layers);
    println!("# Fig 8 bench — tok/s by batch (budget {:.0} KiB of KV)", budget as f64 / 1024.0);
    print!("{:<22}", "method");
    for b in [1usize, 2, 4, 8, 16, 32] {
        print!(" {:>9}", format!("b={b}"));
    }
    println!();
    for method in Method::comparison_set(&plan) {
        print!("{:<22}", method.name());
        for b in [1usize, 2, 4, 8, 16, 32] {
            match run_serving(&rt, &method, b, prompt, gen, Some(budget), 0) {
                Ok(s) => print!(" {:>9.1}", s.tok_per_s),
                Err(_) => print!(" {:>9}", "OOM"),
            }
        }
        println!();
    }
}
