//! Bench harness for paper Fig. 7: peak KV memory by method at batch 4
//! (the same numbers as `kvmix repro fig7`, in bench form), plus the
//! paged-vs-monolithic pressure rows: under a budget that OOMs the
//! monolithic engine at batch 4, the paged pool downshifts old pages down
//! the bit ladder (then preempts, only past the floors) and sustains a
//! strictly larger decode batch (DESIGN.md §Memory-Manager).  The
//! trailing shared-prefix rows serve a common-system-prompt workload
//! with `--prefix-cache` off vs on and print the page deduplication
//! (DESIGN.md §Prefix-Sharing).  The final asymmetric rows compare a
//! searched per-layer (k_bits, v_bits) plan against the symmetric 2-bit
//! ladder at equal modeled bytes (modeled scorer only;
//! docs/adr/007-asymmetric-bit-allocation.md).

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::harness::tables::{run_serving, run_serving_prefixed};
use kvmix::profiler::{search, Importance};
use kvmix::runtime::{default_artifacts_dir, Runtime};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP fig7_memory: artifacts not built");
        return;
    }
    let rt = Runtime::load_with(&dir, false).expect("runtime");
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))
        .unwrap_or_else(|_| QuantPlan::uniform(rt.model.n_layers, 2));

    println!("# Fig 7 bench — peak modeled KV bytes (batch 4, prompt 48, gen 64)");
    println!("{:<22} {:>14} {:>10}", "method", "peak KiB", "vs FP16");
    let mut fp16 = 0f64;
    for method in Method::comparison_set(&plan) {
        let s = run_serving(&rt, &method, 4, 48, 64, None, 0).expect("serve");
        let kib = s.peak_kv_bytes as f64 / 1024.0;
        if matches!(method, Method::Fp16) {
            fp16 = kib;
        }
        println!("{:<22} {:>14.2} {:>9.2}x", method.name(), kib, fp16 / kib);
    }

    // -- pressure section: paged vs monolithic under a squeezed budget --
    let kvmix = Method::Kvmix(plan);
    let base = run_serving(&rt, &kvmix, 4, 48, 64, None, 0)
        .expect("unbudgeted baseline").peak_kv_bytes;
    let budget = base * 55 / 100; // tight enough that monolithic batch 4 OOMs
    println!();
    println!("# paged vs monolithic, kvmix plan, budget {:.1} KiB \
              (55% of the monolithic batch-4 peak)",
             budget as f64 / 1024.0);
    println!("{:<12} {:>6} {:>8} {:>12} {:>14} {:>9} {:>10}",
             "mode", "batch", "status", "peak KiB", "pages_requant", "preempt", "tok/s");
    let cases: [(&str, usize, &[usize]); 2] =
        [("monolithic", 0, &[4]), ("paged-64", 64, &[4, 6, 8])];
    for (mode, page_tokens, batches) in cases {
        for &b in batches {
            match run_serving(&rt, &kvmix, b, 48, 64, Some(budget), page_tokens) {
                Ok(s) => println!("{:<12} {:>6} {:>8} {:>12.2} {:>14} {:>9} {:>10.1}",
                                  mode, b, "ok", s.peak_kv_bytes as f64 / 1024.0,
                                  s.pages_requantized, s.preemptions, s.tok_per_s),
                Err(_) => println!("{:<12} {:>6} {:>8} {:>12} {:>14} {:>9} {:>10}",
                                   mode, b, "OOM", "-", "-", "-", "-"),
            }
        }
    }

    // -- shared-prefix rows: common 64-token system prompt, batch 4/8 --
    // (eager kvmix-2bit plan so the whole prefix is page-shareable; the
    // off/on delta is the pool-level deduplication of the shared pages)
    let eager = Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2).without_rpc());
    println!();
    println!("# shared-prefix serving — 64-token system prompt + 32-token tails, \
              paged-64 (DESIGN.md §Prefix-Sharing)");
    println!("{:<14} {:>6} {:>12} {:>8} {:>12} {:>10}",
             "prefix-cache", "batch", "peak KiB", "hits", "tok reused", "tok/s");
    for b in [4usize, 8] {
        for on in [false, true] {
            match run_serving_prefixed(&rt, &eager, b, 64, 32, 32, None, 64, on) {
                Ok(s) => println!("{:<14} {:>6} {:>12.2} {:>8} {:>12} {:>10.1}",
                                  if on { "on" } else { "off" }, b,
                                  s.peak_kv_bytes as f64 / 1024.0,
                                  s.prefix_hits, s.prefix_tokens_reused, s.tok_per_s),
                Err(e) => println!("{:<14} {:>6} failed: {e}",
                                   if on { "on" } else { "off" }, b),
            }
        }
    }

    // -- asymmetric plan-search rows: searched per-layer (k_bits, v_bits)
    // vs the symmetric 2-bit ladder at the same modeled byte budget,
    // modeled scorer only so the bench stays cheap (the measured-ppl
    // version is `kvmix repro fig7`;
    // docs/adr/007-asymmetric-bit-allocation.md) --
    let imp = match QuantPlan::scores_from_importance_file(&dir.join("importance.json")) {
        Ok(Some((k, v))) => Importance { k, v, mean_loss: 1.0, n_prompts: 0 },
        _ => search::synthetic_importance(rt.model.n_layers, 7),
    };
    let (kv_dim, group) = (rt.model.kv_dim(), rt.model.group);
    let symmetric = QuantPlan::uniform(rt.model.n_layers, 2);
    let sym_bytes = search::plan_bytes_per_token(&symmetric, kv_dim, group);
    let res = search::search_plans_with_budget(
        &imp, &search::SearchCfg::default(), kv_dim, group, sym_bytes,
        &mut |p| Ok(search::modeled_ppl(&imp, p))).expect("plan search");
    println!();
    println!("# asymmetric plan search vs symmetric ladder at equal modeled bytes \
              (budget {sym_bytes:.1} B/token, modeled scorer)");
    println!("{:<24} {:>12} {:>12} {:>12}",
             "plan", "bytes/token", "modeled_ppl", "peak KiB");
    let sym_peak = run_serving(&rt, &Method::Kvmix(symmetric.clone()), 4, 48, 64, None, 0)
        .expect("serve").peak_kv_bytes;
    println!("{:<24} {:>12.1} {:>12.4} {:>12.2}",
             format!("{} (symmetric)", symmetric.name), sym_bytes,
             search::modeled_ppl(&imp, &symmetric), sym_peak as f64 / 1024.0);
    if let Some(best) = res.best() {
        let peak = run_serving(&rt, &Method::Kvmix(best.plan.clone()), 4, 48, 64, None, 0)
            .expect("serve").peak_kv_bytes;
        println!("{:<24} {:>12.1} {:>12.4} {:>12.2}",
                 best.plan.name, best.bytes_per_token, best.ppl, peak as f64 / 1024.0);
    }
}
