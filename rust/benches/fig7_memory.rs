//! Bench harness for paper Fig. 7: peak KV memory by method at batch 4.
//! (The same numbers as `kvmix repro fig7`, in bench form.)

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::harness::tables::run_serving;
use kvmix::runtime::{default_artifacts_dir, Runtime};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP fig7_memory: artifacts not built");
        return;
    }
    let rt = Runtime::load_with(&dir, false).expect("runtime");
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))
        .unwrap_or_else(|_| QuantPlan::uniform(rt.model.n_layers, 2));

    println!("# Fig 7 bench — peak modeled KV bytes (batch 4, prompt 48, gen 64)");
    println!("{:<22} {:>14} {:>10}", "method", "peak KiB", "vs FP16");
    let mut fp16 = 0f64;
    for method in Method::comparison_set(&plan) {
        let (peak, _) = run_serving(&rt, &method, 4, 48, 64, None).expect("serve");
        let kib = peak as f64 / 1024.0;
        if matches!(method, Method::Fp16) {
            fp16 = kib;
        }
        println!("{:<22} {:>14.2} {:>9.2}x", method.name(), kib, fp16 / kib);
    }
}
