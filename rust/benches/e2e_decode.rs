//! End-to-end decode-step benchmark through the real PJRT runtime:
//! ms/step and tokens/s by batch size, worker-thread count and policy.
//! Skips (exit 0) when artifacts are missing so `cargo bench` works
//! pre-build.
//!
//! The threads={1,2,4,8} rows measure the decode attention fan-out
//! (DESIGN.md §Threading-Model); logits are bit-identical across rows,
//! only the wall time changes.  The trailing `+paged64` rows re-run the
//! threads=1 sweep with a per-step page-table reconcile + page-granular
//! byte charge against a [`kvmix::kvcache::PagePool`] — i.e. they price
//! the paged pool's accounting overhead on the decode hot path
//! (DESIGN.md §Memory-Manager); the arithmetic is identical.

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::harness::workload;
use kvmix::kvcache::PagePool;
use kvmix::model::{DecodeScratch, Forward};
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::{Rng, WorkerPool};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP e2e_decode: artifacts not built");
        return;
    }
    let rt = Runtime::load_with(&dir, false).expect("runtime");
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))
        .unwrap_or_else(|_| QuantPlan::uniform(rt.model.n_layers, 2));

    println!("# e2e decode step (prefill 48, then timed decode)");
    println!("{:<22} {:>6} {:>8} {:>12} {:>12}",
             "method", "batch", "threads", "ms/step", "tok/s");
    for method in [Method::Fp16, Method::Kvmix(plan)] {
        for batch in [1usize, 4, 8, 16] {
            for threads in [1usize, 2, 4, 8] {
                WorkerPool::scoped(threads, |pool| {
                    let fwd = Forward::with_pool(&rt, Some(pool));
                    let mut rng = Rng::new(3);
                    let mut caches: Vec<_> = (0..batch).map(|_| {
                        let mut c = method.make_cache(&rt.model);
                        let (toks, _) = workload::sample_mixture(&mut rng, 48);
                        fwd.prefill(&toks, &mut c).expect("prefill");
                        c
                    }).collect();
                    let mut scratch = DecodeScratch::default();
                    let inputs = vec![workload::BOS; batch];
                    // warmup
                    for _ in 0..3 {
                        let mut refs: Vec<_> = caches.iter_mut().collect();
                        fwd.decode_step(&inputs, &mut refs, &mut scratch).unwrap();
                    }
                    let steps = 40;
                    let t0 = std::time::Instant::now();
                    for _ in 0..steps {
                        let mut refs: Vec<_> = caches.iter_mut().collect();
                        fwd.decode_step(&inputs, &mut refs, &mut scratch).unwrap();
                    }
                    let secs = t0.elapsed().as_secs_f64();
                    println!("{:<22} {:>6} {:>8} {:>12.3} {:>12.1}",
                             method.name(), batch, threads,
                             secs / steps as f64 * 1e3,
                             (steps * batch) as f64 / secs);
                });
            }
            // paged accounting overhead: identical decode, plus per-step
            // page-table sync + page-granular charge (engine-thread work)
            let fwd = Forward::new(&rt);
            let mut rng = Rng::new(3);
            let mut caches: Vec<_> = (0..batch).map(|_| {
                let mut c = method.make_cache(&rt.model);
                let (toks, _) = workload::sample_mixture(&mut rng, 48);
                fwd.prefill(&toks, &mut c).expect("prefill");
                c
            }).collect();
            let mut pool = PagePool::new(64, rt.model.kv_dim(), rt.model.group)
                .expect("page pool");
            let mut scratch = DecodeScratch::default();
            let inputs = vec![workload::BOS; batch];
            for _ in 0..3 {
                let mut refs: Vec<_> = caches.iter_mut().collect();
                fwd.decode_step(&inputs, &mut refs, &mut scratch).unwrap();
            }
            let steps = 40;
            let t0 = std::time::Instant::now();
            let mut charged = 0usize;
            for _ in 0..steps {
                let mut refs: Vec<_> = caches.iter_mut().collect();
                fwd.decode_step(&inputs, &mut refs, &mut scratch).unwrap();
                for (id, c) in caches.iter().enumerate() {
                    pool.sync(id as u64, c);
                }
                charged = pool.modeled_bytes();
            }
            let secs = t0.elapsed().as_secs_f64();
            println!("{:<22} {:>6} {:>8} {:>12.3} {:>12.1}   (pages {} / {:.1} KiB)",
                     format!("{} +paged64", method.name()), batch, 1,
                     secs / steps as f64 * 1e3,
                     (steps * batch) as f64 / secs,
                     pool.allocated_pages(), charged as f64 / 1024.0);
        }
    }
}
