//! End-to-end decode-step benchmark through the real PJRT runtime:
//! ms/step and tokens/s by batch size, worker-thread count and policy.
//! Skips (exit 0) when artifacts are missing so `cargo bench` works
//! pre-build.
//!
//! The threads={1,2,4,8} rows measure the decode attention fan-out
//! (DESIGN.md §Threading-Model); logits are bit-identical across rows,
//! only the wall time changes.  The trailing `+paged64` rows re-run the
//! threads=1 sweep with a per-step page-table reconcile + page-granular
//! byte charge against a [`kvmix::kvcache::PagePool`] — i.e. they price
//! the paged pool's accounting overhead on the decode hot path
//! (DESIGN.md §Memory-Manager); the arithmetic is identical.  The
//! `prefix` section times shared-system-prompt admission through the
//! engine with `--prefix-cache` off vs on (DESIGN.md §Prefix-Sharing):
//! generated tokens are bit-identical; the on rows skip re-quantizing
//! the shared pages and dedup their memory.  The final `interference`
//! section stages one bucket-length prompt arriving mid-stream of short
//! decoders and compares `--step-tokens 0` (whole-prompt prefill stalls
//! every decoder) against chunked budgets (DESIGN.md §Scheduler):
//! short-cohort p99 TBT should drop sharply while the long prompt's
//! TTFT regresses by the chunking serialization it pays for.

use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::coordinator::{Engine, EngineCfg, Request};
use kvmix::harness::workload;
use kvmix::kvcache::PagePool;
use kvmix::model::{DecodeScratch, Forward, Sampler};
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::bench::JsonSink;
use kvmix::util::{Rng, WorkerPool};

fn main() {
    let mut sink = JsonSink::from_env("e2e_decode");
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP e2e_decode: artifacts not built");
        sink.finish(); // empty-entry file: ran but skipped
        return;
    }
    let rt = Runtime::load_with(&dir, false).expect("runtime");
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))
        .unwrap_or_else(|_| QuantPlan::uniform(rt.model.n_layers, 2));

    println!("# e2e decode step (prefill 48, then timed decode)");
    println!("{:<22} {:>6} {:>8} {:>12} {:>12}",
             "method", "batch", "threads", "ms/step", "tok/s");
    for method in [Method::Fp16, Method::Kvmix(plan)] {
        for batch in [1usize, 4, 8, 16] {
            for threads in [1usize, 2, 4, 8] {
                WorkerPool::scoped(threads, |pool| {
                    let fwd = Forward::with_pool(&rt, Some(pool));
                    let mut rng = Rng::new(3);
                    let mut caches: Vec<_> = (0..batch).map(|_| {
                        let mut c = method.make_cache(&rt.model);
                        let (toks, _) = workload::sample_mixture(&mut rng, 48);
                        fwd.prefill(&toks, &mut c).expect("prefill");
                        c
                    }).collect();
                    let mut scratch = DecodeScratch::default();
                    let inputs = vec![workload::BOS; batch];
                    // warmup
                    for _ in 0..3 {
                        let mut refs: Vec<_> = caches.iter_mut().collect();
                        fwd.decode_step(&inputs, &mut refs, &mut scratch).unwrap();
                    }
                    let steps = 40;
                    let t0 = std::time::Instant::now();
                    for _ in 0..steps {
                        let mut refs: Vec<_> = caches.iter_mut().collect();
                        fwd.decode_step(&inputs, &mut refs, &mut scratch).unwrap();
                    }
                    let secs = t0.elapsed().as_secs_f64();
                    println!("{:<22} {:>6} {:>8} {:>12.3} {:>12.1}",
                             method.name(), batch, threads,
                             secs / steps as f64 * 1e3,
                             (steps * batch) as f64 / secs);
                    sink.record_value(
                        &format!("decode/{}/batch{batch}/threads{threads}", method.name()),
                        secs / steps as f64 * 1e9,
                        Some((steps * batch) as f64 / secs));
                });
            }
            // paged accounting overhead: identical decode, plus per-step
            // page-table sync + page-granular charge (engine-thread work)
            let fwd = Forward::new(&rt);
            let mut rng = Rng::new(3);
            let mut caches: Vec<_> = (0..batch).map(|_| {
                let mut c = method.make_cache(&rt.model);
                let (toks, _) = workload::sample_mixture(&mut rng, 48);
                fwd.prefill(&toks, &mut c).expect("prefill");
                c
            }).collect();
            let mut pool = PagePool::new(64, rt.model.kv_dim(), rt.model.group)
                .expect("page pool");
            let mut scratch = DecodeScratch::default();
            let inputs = vec![workload::BOS; batch];
            for _ in 0..3 {
                let mut refs: Vec<_> = caches.iter_mut().collect();
                fwd.decode_step(&inputs, &mut refs, &mut scratch).unwrap();
            }
            let steps = 40;
            let t0 = std::time::Instant::now();
            let mut charged = 0usize;
            for _ in 0..steps {
                let mut refs: Vec<_> = caches.iter_mut().collect();
                fwd.decode_step(&inputs, &mut refs, &mut scratch).unwrap();
                for (id, c) in caches.iter().enumerate() {
                    pool.sync(id as u64, c);
                }
                charged = pool.modeled_bytes();
            }
            let secs = t0.elapsed().as_secs_f64();
            println!("{:<22} {:>6} {:>8} {:>12.3} {:>12.1}   (pages {} / {:.1} KiB)",
                     format!("{} +paged64", method.name()), batch, 1,
                     secs / steps as f64 * 1e3,
                     (steps * batch) as f64 / secs,
                     pool.allocated_pages(), charged as f64 / 1024.0);
            sink.record_value(
                &format!("decode/{}+paged64/batch{batch}/threads1", method.name()),
                secs / steps as f64 * 1e9,
                Some((steps * batch) as f64 / secs));
        }
    }

    // -- shared-prefix admission: batchfuls of common-system-prompt
    //    requests through the engine, --prefix-cache off vs on --
    let plan = QuantPlan::from_importance_file(&dir.join("importance.json"))
        .unwrap_or_else(|_| QuantPlan::uniform(rt.model.n_layers, 2));
    let eager = Method::Kvmix(plan.without_rpc());
    println!();
    println!("# shared-prefix admission (64-token system prompt + 32-token tails, \
              gen 8, paged-64 — DESIGN.md §Prefix-Sharing)");
    println!("{:<14} {:>6} {:>12} {:>8} {:>12} {:>12}",
             "prefix-cache", "batch", "ms/request", "hits", "tok reused", "peak KiB");
    for batch in [4usize, 8, 16] {
        for on in [false, true] {
            let mut engine = Engine::new(&rt, EngineCfg {
                method: eager.clone(), max_batch: batch, kv_budget: None,
                threads: 1, page_tokens: 64, prefix_cache: on, step_tokens: 0,
                pressure_weights: None, spill_dir: None, spill_bytes: 0,
            }).expect("engine");
            let mut rng = Rng::new(11);
            let (system, _) = workload::sample_mixture(&mut rng, 64);
            for id in 0..batch {
                let (tail, _) = workload::sample_mixture(&mut rng, 32);
                let mut prompt = system.clone();
                prompt.extend_from_slice(&tail);
                engine.submit(Request {
                    id: id as u64, prompt, max_new_tokens: 8,
                    sampler: Sampler::Greedy, stop_token: None, priority: 0,
                    deadline_ms: None, submitted_ns: 0, session: None,
                });
            }
            let t0 = std::time::Instant::now();
            let done = engine.run_to_completion().expect("serve");
            let secs = t0.elapsed().as_secs_f64();
            assert_eq!(done.len(), batch);
            println!("{:<14} {:>6} {:>12.3} {:>8} {:>12} {:>12.2}",
                     if on { "on" } else { "off" }, batch,
                     secs / batch as f64 * 1e3,
                     engine.metrics.prefix_hits, engine.metrics.prefix_tokens_reused,
                     engine.metrics.peak_kv_bytes as f64 / 1024.0);
            sink.record_value(
                &format!("prefix/{}/batch{batch}", if on { "on" } else { "off" }),
                secs / batch as f64 * 1e9,
                Some(batch as f64 / secs));
        }
    }

    // -- long-prompt interference: a bucket-length prompt arrives while
    //    short requests are mid-decode; --step-tokens 0 (whole prefill,
    //    inline) vs chunked budgets (DESIGN.md §Scheduler) --
    let group = rt.model.group;
    let long_len = *rt.buckets.iter().max().expect("buckets");
    let n_short = 6usize;
    println!();
    println!("# long-prompt interference ({n_short} short decoders + one \
              {long_len}-token prompt arriving at step 8, gen 96/16)");
    println!("{:<12} {:>12} {:>10} {:>10} {:>10} {:>12}",
             "step-tokens", "long_ttft_ms", "tbt_p50", "tbt_p99", "tok/s",
             "budget_util");
    for step_tokens in [0usize, 2 * group, 4 * group] {
        let mut engine = Engine::new(&rt, EngineCfg {
            method: eager.clone(), max_batch: n_short + 2, kv_budget: None,
            threads: 1, page_tokens: 0, prefix_cache: false, step_tokens,
            pressure_weights: None, spill_dir: None, spill_bytes: 0,
        }).expect("engine");
        let mut rng = Rng::new(21);
        let (shorts, long) = workload::interference_prompts(&mut rng, n_short,
                                                            32, long_len);
        for (id, prompt) in shorts.into_iter().enumerate() {
            engine.submit(Request { id: id as u64, prompt, max_new_tokens: 96,
                                    sampler: Sampler::Greedy, stop_token: None, priority: 0,
                                    deadline_ms: None, submitted_ns: 0, session: None });
        }
        // let the short cohort reach steady-state decode, then land the
        // long prompt mid-stream
        let t0 = std::time::Instant::now();
        let mut done = Vec::new();
        for _ in 0..8 {
            done.extend(engine.step().expect("step"));
        }
        engine.submit(Request { id: 99, prompt: long, max_new_tokens: 16,
                                sampler: Sampler::Greedy, stop_token: None, priority: 0,
                                deadline_ms: None, submitted_ns: 0, session: None });
        done.extend(engine.run_to_completion().expect("serve"));
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(done.len(), n_short + 1);
        let long_ttft = done.iter().find(|c| c.id == 99).expect("long done").ttft_ms();
        let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
        let util = if engine.metrics.budget_util.is_empty() {
            "-".to_string()
        } else {
            format!("{:.0}%", engine.metrics.budget_util.mean() * 100.0)
        };
        println!("{:<12} {:>12.1} {:>10.2} {:>10.2} {:>10.1} {:>12}",
                 step_tokens, long_ttft,
                 engine.metrics.tbt_ms.quantile(0.5),
                 engine.metrics.tbt_ms.quantile(0.99),
                 tokens as f64 / secs, util);
        sink.record_value(
            &format!("interference/step_tokens{step_tokens}/long_ttft"),
            long_ttft * 1e6, None);
        sink.record_value(
            &format!("interference/step_tokens{step_tokens}/tbt_p99"),
            engine.metrics.tbt_ms.quantile(0.99) * 1e6,
            Some(tokens as f64 / secs));
    }
    println!("(tbt quantiles cover all lanes; the p99 spike at step-tokens 0 \
              is the short cohort stalling behind the inline long prefill)");

    sink.finish();
}
