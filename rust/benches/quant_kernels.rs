//! Microbenchmarks for the quantization hot path: pack/unpack at every
//! bit width, group quantization, and the decode kernel tiers — the
//! integer-domain **packed** kernels vs the unpack-based **fused**
//! reference vs the dequantize-then-matvec **unfused** baseline
//! (DESIGN.md §Quantized-Kernels).
//!
//! The `*_fused` rows invalidate the unpack cache every call: that is the
//! per-(block, lane) cache-miss cost the decode loop pays whenever the
//! context holds more blocks than the scratch can cache (i.e. always,
//! beyond one block).  The `*_fused_hot` rows keep the cache warm — the
//! best case the old path ever achieved, amortized across a block's
//! heads.  The headline multiple recorded in `BENCH_kernels.json`
//! (`scripts/bench_to_json.py --check`) is packed vs cold fused.

use kvmix::quant::{fused, pack_stream, qmax_at, unpack_stream, FusedScratch, PackedBlock,
                   TileScratch};
use kvmix::util::bench::{bench, black_box, JsonSink};
use kvmix::util::Rng;

fn main() {
    let mut sink = JsonSink::from_env("quant_kernels");
    println!("# quant kernel microbenchmarks (4096-element blocks, group 32)");
    let mut rng = Rng::new(1);
    let n = 4096;
    let data = rng.normal_vec(n);

    for bits in [1u8, 2, 3, 4, 8] {
        let q: Vec<u32> = (0..n).map(|i| rng.below(qmax_at(bits, i) as usize + 1) as u32).collect();
        let mut words = Vec::new();
        pack_stream(&q, bits, &mut words);
        let mut out = vec![0u32; n];

        let s = bench(&format!("pack_stream/{bits}bit"), 60, || {
            let mut w = Vec::new();
            pack_stream(black_box(&q), bits, &mut w);
            black_box(&w);
        });
        println!("{}  ({:.2} Gelem/s)", s.line(), s.throughput(n as f64) / 1e9);
        sink.record(&s, Some(n as f64));

        let s = bench(&format!("unpack_stream/{bits}bit"), 60, || {
            unpack_stream(black_box(&words), bits, n, &mut out);
            black_box(&out);
        });
        println!("{}  ({:.2} Gelem/s)", s.line(), s.throughput(n as f64) / 1e9);
        sink.record(&s, Some(n as f64));

        let s = bench(&format!("quantize_block/{bits}bit"), 60, || {
            black_box(PackedBlock::quantize(black_box(&data), bits, 32));
        });
        println!("{}  ({:.2} Gelem/s)", s.line(), s.throughput(n as f64) / 1e9);
        sink.record(&s, Some(n as f64));
    }

    // key kernels: packed (integer-domain) vs fused (unpack-based,
    // cold + hot) vs unfused (dequantize-then-matvec)
    println!("\n# key scores: packed/tiled/interleaved vs fused(cold/hot) vs unfused \
              (K block 64ch x 32tok)");
    let kv_dim = 64;
    let tokens = 32;
    let kdata = rng.normal_vec(kv_dim * tokens);
    let q32 = rng.normal_vec(32);
    let rep = 4; // GQA tile width for the head-tiled rows
    let q_tile = rng.normal_vec(rep * 32);
    let mut tile = TileScratch::default();
    for bits in [1u8, 2, 3, 4, 8] {
        let block = PackedBlock::quantize(&kdata, bits, tokens);
        let mut scores = vec![0f32; tokens];
        let mut scratch = FusedScratch::default();
        let s_p = bench(&format!("key_scores_packed/{bits}bit"), 40, || {
            scores.fill(0.0);
            fused::key_scores_dispatch(black_box(&q32), &block, tokens, 0,
                                       &mut scratch, &mut scores);
            black_box(&scores);
        });
        // head-tiled: decode each field once for the whole KV group
        let mut tile_out = vec![0f32; rep * tokens];
        let s_t = bench(&format!("key_scores_packed_tiled/{bits}bit"), 40, || {
            tile_out.fill(0.0);
            fused::key_scores_group_packed(black_box(&q_tile), rep, &block, tokens, 0,
                                           &mut tile_out, tokens, &mut tile);
            black_box(&tile_out);
        });
        // interleaved K layout: sequential word loads (no Eq. 12 variant —
        // 3-bit words have no uniform sub-lane to interleave)
        let s_i = (bits != 3).then(|| {
            let mut iblock = PackedBlock::default();
            iblock.quantize_into_layout(&kdata, bits, tokens, true, &mut Vec::new());
            bench(&format!("key_scores_packed_inter/{bits}bit"), 40, || {
                scores.fill(0.0);
                fused::key_scores_packed(black_box(&q32), &iblock, tokens, 0, &mut scores);
                black_box(&scores);
            })
        });
        let mut scratch_cold = FusedScratch::default();
        let s_f = bench(&format!("key_scores_fused/{bits}bit"), 40, || {
            scores.fill(0.0);
            scratch_cold.invalidate(); // per-block cache miss, the decode norm
            fused::key_scores_fused(black_box(&q32), &block, tokens, 0,
                                    &mut scratch_cold, &mut scores);
            black_box(&scores);
        });
        let mut scratch_hot = FusedScratch::default();
        let s_h = bench(&format!("key_scores_fused_hot/{bits}bit"), 40, || {
            scores.fill(0.0);
            fused::key_scores_fused(black_box(&q32), &block, tokens, 0,
                                    &mut scratch_hot, &mut scores);
            black_box(&scores);
        });
        let s_u = bench(&format!("key_scores_unfused/{bits}bit"), 40, || {
            scores.fill(0.0);
            fused::unfused::key_scores(black_box(&q32), &block, tokens, 0,
                                       &mut scratch, &mut scores);
            black_box(&scores);
        });
        println!("{}", s_p.line());
        println!("{}", s_t.line());
        if let Some(s) = &s_i {
            println!("{}", s.line());
        }
        println!("{}", s_f.line());
        println!("{}", s_h.line());
        println!("{}", s_u.line());
        println!("  packed vs fused(cold): {:.2}x   vs fused(hot): {:.2}x   \
                  tiled vs {rep}x packed: {:.2}x   fused vs unfused: {:.2}x",
                 s_f.mean / s_p.mean, s_h.mean / s_p.mean,
                 rep as f64 * s_p.mean / s_t.mean, s_u.mean / s_f.mean);
        sink.record(&s_p, Some(tokens as f64));
        sink.record(&s_t, Some((rep * tokens) as f64));
        if let Some(s) = &s_i {
            sink.record(s, Some(tokens as f64));
        }
        for s in [&s_f, &s_h, &s_u] {
            sink.record(s, Some(tokens as f64));
        }
    }

    // value side
    println!("\n# weighted values: packed/tiled vs fused(cold/hot) vs unfused \
              (V block 32tok x 64ch)");
    let vdata = rng.normal_vec(tokens * kv_dim);
    let p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
    let p_tile: Vec<f32> = (0..rep * tokens).map(|_| rng.f32()).collect();
    for bits in [1u8, 2, 3, 4, 8] {
        let block = PackedBlock::quantize(&vdata, bits, 32);
        let mut out = vec![0f32; 32];
        let mut scratch = FusedScratch::default();
        let s_p = bench(&format!("value_accum_packed/{bits}bit"), 40, || {
            out.fill(0.0);
            fused::value_accum_dispatch(black_box(&p), &block, kv_dim, 0, 32,
                                        &mut scratch, &mut out);
            black_box(&out);
        });
        let mut tile_out = vec![0f32; rep * 32];
        let s_t = bench(&format!("value_accum_packed_tiled/{bits}bit"), 40, || {
            tile_out.fill(0.0);
            fused::value_accum_group_packed(black_box(&p_tile), tokens, rep, &block,
                                            kv_dim, 0, 32, &mut tile_out, &mut tile);
            black_box(&tile_out);
        });
        let mut scratch_cold = FusedScratch::default();
        let s_f = bench(&format!("value_accum_fused/{bits}bit"), 40, || {
            out.fill(0.0);
            scratch_cold.invalidate();
            fused::value_accum_fused(black_box(&p), &block, kv_dim, 0, 32,
                                     &mut scratch_cold, &mut out);
            black_box(&out);
        });
        let mut scratch_hot = FusedScratch::default();
        let s_h = bench(&format!("value_accum_fused_hot/{bits}bit"), 40, || {
            out.fill(0.0);
            fused::value_accum_fused(black_box(&p), &block, kv_dim, 0, 32,
                                     &mut scratch_hot, &mut out);
            black_box(&out);
        });
        let s_u = bench(&format!("value_accum_unfused/{bits}bit"), 40, || {
            out.fill(0.0);
            fused::unfused::value_accum(black_box(&p), &block, kv_dim, 0, 32,
                                        &mut scratch, &mut out);
            black_box(&out);
        });
        println!("{}", s_p.line());
        println!("{}", s_t.line());
        println!("{}", s_f.line());
        println!("{}", s_h.line());
        println!("{}", s_u.line());
        println!("  packed vs fused(cold): {:.2}x   vs fused(hot): {:.2}x   \
                  tiled vs {rep}x packed: {:.2}x   fused vs unfused: {:.2}x",
                 s_f.mean / s_p.mean, s_h.mean / s_p.mean,
                 rep as f64 * s_p.mean / s_t.mean, s_u.mean / s_f.mean);
        sink.record(&s_p, Some(tokens as f64));
        sink.record(&s_t, Some((rep * tokens) as f64));
        for s in [&s_f, &s_h, &s_u] {
            sink.record(s, Some(tokens as f64));
        }
    }

    sink.finish();
}
