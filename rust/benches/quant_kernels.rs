//! Microbenchmarks for the quantization hot path: pack/unpack at every
//! bit width, group quantization, and fused vs unfused dequant·matvec —
//! the paper's kernel-fusion claim (§CUDA Implementation) measured on the
//! Rust analogs.

use kvmix::quant::{fused, pack_stream, qmax_at, unpack_stream, FusedScratch, PackedBlock};
use kvmix::util::bench::{bench, black_box};
use kvmix::util::Rng;

fn main() {
    println!("# quant kernel microbenchmarks (4096-element blocks, group 32)");
    let mut rng = Rng::new(1);
    let n = 4096;
    let data = rng.normal_vec(n);

    for bits in [1u8, 2, 3, 4] {
        let q: Vec<u32> = (0..n).map(|i| rng.below(qmax_at(bits, i) as usize + 1) as u32).collect();
        let mut words = Vec::new();
        pack_stream(&q, bits, &mut words);
        let mut out = vec![0u32; n];

        let s = bench(&format!("pack_stream/{bits}bit"), 60, || {
            let mut w = Vec::new();
            pack_stream(black_box(&q), bits, &mut w);
            black_box(&w);
        });
        println!("{}  ({:.2} Gelem/s)", s.line(), s.throughput(n as f64) / 1e9);

        let s = bench(&format!("unpack_stream/{bits}bit"), 60, || {
            unpack_stream(black_box(&words), bits, n, &mut out);
            black_box(&out);
        });
        println!("{}  ({:.2} Gelem/s)", s.line(), s.throughput(n as f64) / 1e9);

        let s = bench(&format!("quantize_block/{bits}bit"), 60, || {
            black_box(PackedBlock::quantize(black_box(&data), bits, 32));
        });
        println!("{}  ({:.2} Gelem/s)", s.line(), s.throughput(n as f64) / 1e9);
    }

    // fused vs unfused key scores (the paper's dequant+matvec fusion)
    println!("\n# fused dequant·matvec vs dequantize-then-matvec (K block 64ch x 32tok)");
    let kv_dim = 64;
    let tokens = 32;
    let kdata = rng.normal_vec(kv_dim * tokens);
    let q32 = rng.normal_vec(32);
    for bits in [2u8, 3, 4] {
        let block = PackedBlock::quantize(&kdata, bits, tokens);
        let mut scores = vec![0f32; tokens];
        let mut scratch = FusedScratch::default();
        let s_f = bench(&format!("key_scores_fused/{bits}bit"), 40, || {
            scores.fill(0.0);
            fused::key_scores_fused(black_box(&q32), &block, tokens, 0, &mut scratch, &mut scores);
            black_box(&scores);
        });
        let s_u = bench(&format!("key_scores_unfused/{bits}bit"), 40, || {
            scores.fill(0.0);
            fused::unfused::key_scores(black_box(&q32), &block, tokens, 0, &mut scratch, &mut scores);
            black_box(&scores);
        });
        println!("{}", s_f.line());
        println!("{}", s_u.line());
        println!("  fusion speedup: {:.2}x", s_u.mean / s_f.mean);
    }

    // value side
    println!("\n# fused weighted-value (V block 32tok x 64ch)");
    let vdata = rng.normal_vec(tokens * kv_dim);
    let p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
    for bits in [2u8, 4] {
        let block = PackedBlock::quantize(&vdata, bits, 32);
        let mut out = vec![0f32; 32];
        let mut scratch = FusedScratch::default();
        let s_f = bench(&format!("value_accum_fused/{bits}bit"), 40, || {
            out.fill(0.0);
            fused::value_accum_fused(black_box(&p), &block, kv_dim, 0, 32, &mut scratch, &mut out);
            black_box(&out);
        });
        let s_u = bench(&format!("value_accum_unfused/{bits}bit"), 40, || {
            out.fill(0.0);
            fused::unfused::value_accum(black_box(&p), &block, kv_dim, 0, 32, &mut scratch, &mut out);
            black_box(&out);
        });
        println!("{}", s_f.line());
        println!("{}", s_u.line());
        println!("  fusion speedup: {:.2}x", s_u.mean / s_f.mean);
    }
}
