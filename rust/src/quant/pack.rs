//! Bit packing for quantized KV elements.
//!
//! Uniform widths (1/2/4 bits) pack `32/bits` elements per `u32` word.
//! 3-bit uses the paper's Eq. 12 scheme: **11 elements per word** — ten
//! 3-bit fields in bits 0..30 plus one 2-bit field in bits 30..32
//! (`q_max` = 7 for indices 0..9, 3 for index 10) — a 10% density win over
//! the naive 10-per-word layout.  Layout is pinned by the python oracle
//! (kernels/ref.py::pack3) and the goldens.

/// Elements per u32 word for a given bit width.
pub const fn elems_per_word(bits: u8) -> usize {
    match bits {
        3 => 11,
        b => 32 / b as usize,
    }
}

/// Words needed to pack `n` elements.
pub const fn words_for(n: usize, bits: u8) -> usize {
    let per = elems_per_word(bits);
    n.div_ceil(per)
}

/// Byte-repeating SWAR spread mask for a sub-lane of width `bits`
/// (1/2/4/8): the low `bits` of every byte set.  Shifting a packed
/// 64-bit wide-word right by `bits*l` and AND-ing with this mask spreads
/// fields `l, l+R, l+2R, …` (R = `8/bits`) into the bytes of one lane,
/// so all fields of the word are extracted with R shift/mask pairs
/// instead of `64/bits` (DESIGN.md §Quantized-Kernels).
#[inline]
pub const fn swar_mask(bits: u8) -> u64 {
    ((1u64 << bits) - 1) * 0x0101_0101_0101_0101
}

/// Extract field `f` (0..11) of an Eq. 12 3-bit packed word.
#[inline]
pub fn eq12_field(w: u32, f: usize) -> u32 {
    if f == 10 {
        (w >> 30) & 0x3
    } else {
        (w >> (3 * f)) & 0x7
    }
}

/// Max quantized value for element index `i` within its pack-block
/// (only 3-bit is index-dependent — paper Eq. 12).
#[inline]
pub fn qmax_at(bits: u8, i: usize) -> u32 {
    match bits {
        3 => {
            if i % 11 == 10 {
                3
            } else {
                7
            }
        }
        b => (1u32 << b) - 1,
    }
}

/// Largest qmax for the width (group scale uses this: s = range / qmax).
#[inline]
pub const fn qmax(bits: u8) -> u32 {
    match bits {
        3 => 7,
        b => (1u32 << b) - 1,
    }
}

/// Pack a stream of already-clipped quantized values.  `out` is cleared.
pub fn pack_stream(q: &[u32], bits: u8, out: &mut Vec<u32>) {
    out.clear();
    out.reserve(words_for(q.len(), bits));
    match bits {
        3 => {
            for chunk in q.chunks(11) {
                let mut w = 0u32;
                for (i, &v) in chunk.iter().enumerate() {
                    if i == 10 {
                        w |= (v & 0x3) << 30;
                    } else {
                        w |= (v & 0x7) << (3 * i);
                    }
                }
                out.push(w);
            }
        }
        b => {
            let per = elems_per_word(b);
            let mask = (1u32 << b) - 1;
            for chunk in q.chunks(per) {
                let mut w = 0u32;
                for (i, &v) in chunk.iter().enumerate() {
                    w |= (v & mask) << (b as usize * i);
                }
                out.push(w);
            }
        }
    }
}

/// Unpack `n` elements from a packed stream into `out[..n]`.
pub fn unpack_stream(words: &[u32], bits: u8, n: usize, out: &mut [u32]) {
    debug_assert!(out.len() >= n);
    match bits {
        3 => {
            let mut idx = 0usize;
            'outer: for &w in words {
                for i in 0..11 {
                    if idx == n {
                        break 'outer;
                    }
                    out[idx] = if i == 10 { (w >> 30) & 0x3 } else { (w >> (3 * i)) & 0x7 };
                    idx += 1;
                }
            }
            debug_assert_eq!(idx, n);
        }
        b => {
            let per = elems_per_word(b);
            let mask = (1u32 << b) - 1;
            let bu = b as usize;
            let full_words = n / per;
            let mut idx = 0usize;
            for &w in &words[..full_words] {
                // fixed-trip inner loop — autovectorizes cleanly
                for i in 0..per {
                    out[idx + i] = (w >> (bu * i)) & mask;
                }
                idx += per;
            }
            if idx < n {
                let w = words[full_words];
                for i in 0..(n - idx) {
                    out[idx + i] = (w >> (bu * i)) & mask;
                }
            }
        }
    }
}

/// Extract one field from a packed stream without unpacking anything
/// else — the sparse outlier side path of the packed kernels
/// (quant/fused.rs) dequantizes single elements through this.
#[inline]
pub fn get_at(words: &[u32], bits: u8, idx: usize) -> u32 {
    match bits {
        3 => eq12_field(words[idx / 11], idx % 11),
        b => {
            let per = elems_per_word(b);
            (words[idx / per] >> (b as usize * (idx % per))) & ((1u32 << b) - 1)
        }
    }
}

/// Word-at-a-time view of the contiguous field range `[start, start+len)`
/// of a **uniform-width** packed stream (`32 % bits == 0`; 3-bit's
/// 11-per-word layout has no aligned word view and stays on the unpack
/// path — DESIGN.md §Quantized-Kernels).
///
/// Yields one `(word, first_field, n_fields)` triple per `u32` the range
/// overlaps: the raw packed word, the field index of the range's next
/// element within it, and how many of its fields belong to the range.
/// The packed kernels walk unaligned rows through this without ever
/// materializing the unpacked stream.
pub struct FieldRange<'a> {
    words: &'a [u32],
    per: usize,
    /// absolute field index of the next element
    next: usize,
    end: usize,
}

/// View `[start, start+len)` of a uniform-width stream (see [`FieldRange`]).
#[inline]
pub fn field_range(words: &[u32], bits: u8, start: usize, len: usize) -> FieldRange<'_> {
    debug_assert!(bits != 3 && 32 % bits as usize == 0, "uniform widths only");
    let per = elems_per_word(bits);
    debug_assert!(start + len <= words.len() * per);
    FieldRange { words, per, next: start, end: start + len }
}

impl Iterator for FieldRange<'_> {
    type Item = (u32, usize, usize);

    #[inline]
    fn next(&mut self) -> Option<Self::Item> {
        if self.next >= self.end {
            return None;
        }
        let w = self.words[self.next / self.per];
        let f0 = self.next % self.per;
        let n = (self.per - f0).min(self.end - self.next);
        self.next += n;
        Some((w, f0, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn density_claims() {
        assert_eq!(elems_per_word(3), 11); // paper's +10% over 10/word
        assert_eq!(elems_per_word(2), 16);
        assert_eq!(elems_per_word(4), 8);
        assert_eq!(elems_per_word(1), 32);
        assert_eq!(words_for(2048, 3), 187); // vs 205 naive
    }

    #[test]
    fn qmax_schedule() {
        assert_eq!(qmax_at(3, 0), 7);
        assert_eq!(qmax_at(3, 9), 7);
        assert_eq!(qmax_at(3, 10), 3);
        assert_eq!(qmax_at(3, 21), 3);
        assert_eq!(qmax_at(2, 10), 3);
        assert_eq!(qmax_at(4, 5), 15);
    }

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(1);
        for bits in [1u8, 2, 3, 4] {
            for n in [1usize, 7, 11, 32, 33, 352, 1000] {
                let q: Vec<u32> = (0..n).map(|i| rng.below(qmax_at(bits, i) as usize + 1) as u32).collect();
                let mut words = Vec::new();
                pack_stream(&q, bits, &mut words);
                assert_eq!(words.len(), words_for(n, bits));
                let mut out = vec![0u32; n];
                unpack_stream(&words, bits, n, &mut out);
                assert_eq!(out, q, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn get_at_matches_unpack() {
        let mut rng = Rng::new(2);
        for bits in [1u8, 2, 3, 4, 8] {
            let n = 353; // word-tail at every width
            let q: Vec<u32> =
                (0..n).map(|i| rng.below(qmax_at(bits, i) as usize + 1) as u32).collect();
            let mut words = Vec::new();
            pack_stream(&q, bits, &mut words);
            for (i, &want) in q.iter().enumerate() {
                assert_eq!(get_at(&words, bits, i), want, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn field_range_covers_unaligned_rows() {
        let mut rng = Rng::new(3);
        for bits in [1u8, 2, 4, 8] {
            let n = 352;
            let q: Vec<u32> =
                (0..n).map(|i| rng.below(qmax_at(bits, i) as usize + 1) as u32).collect();
            let mut words = Vec::new();
            pack_stream(&q, bits, &mut words);
            let mask = (1u32 << bits) - 1;
            // unaligned starts and lengths, including word-straddling rows
            for (start, len) in [(0usize, n), (1, 33), (7, 40), (31, 64), (333, 19)] {
                let mut got = Vec::new();
                for (w, f0, k) in field_range(&words, bits, start, len) {
                    for f in f0..f0 + k {
                        got.push((w >> (bits as usize * f)) & mask);
                    }
                }
                assert_eq!(got, q[start..start + len], "bits={bits} start={start} len={len}");
            }
        }
    }

    #[test]
    fn swar_mask_spreads_every_field() {
        // fusing two words and applying the R shift/mask lanes must
        // recover exactly the per-field shift/mask extraction
        let mut rng = Rng::new(4);
        for bits in [1u8, 2, 4, 8] {
            let per = elems_per_word(bits);
            let q: Vec<u32> =
                (0..2 * per).map(|_| rng.below(qmax(bits) as usize + 1) as u32).collect();
            let mut words = Vec::new();
            pack_stream(&q, bits, &mut words);
            let wide = words[0] as u64 | (words[1] as u64) << 32;
            let r = 8 / bits as usize;
            let mask = swar_mask(bits);
            for l in 0..r {
                let lane = (wide >> (bits as usize * l)) & mask;
                for j in 0..8 {
                    let field = j * r + l; // byte j of lane l
                    assert_eq!(((lane >> (8 * j)) & 0xFF) as u32, q[field],
                               "bits={bits} lane={l} byte={j}");
                }
            }
        }
    }

    #[test]
    fn eq12_field_matches_get_at() {
        let mut rng = Rng::new(5);
        let q: Vec<u32> = (0..33).map(|i| rng.below(qmax_at(3, i) as usize + 1) as u32).collect();
        let mut words = Vec::new();
        pack_stream(&q, 3, &mut words);
        for i in 0..33 {
            assert_eq!(eq12_field(words[i / 11], i % 11), get_at(&words, 3, i));
        }
    }

    #[test]
    fn pack3_matches_python_layout() {
        // fixed vector with in-range fields: ten 3-bit values + one 2-bit
        let q: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 0, 1, 2, 2];
        let mut words = Vec::new();
        pack_stream(&q, 3, &mut words);
        let mut expect = 0u32;
        for (i, &v) in q[..10].iter().enumerate() {
            expect |= v << (3 * i);
        }
        expect |= 2 << 30;
        assert_eq!(words, vec![expect]);
    }
}
