//! Low-bit quantization: packing, group-wise asymmetric quant, fused
//! dequantize·matvec kernels (the paper's CUDA-kernel contribution mapped
//! to CPU — see DESIGN.md §Hardware-Adaptation).

pub mod fused;
pub mod groupq;
pub mod pack;

pub use fused::{key_scores_fused, value_accum_fused, FusedScratch};
pub use groupq::{quant_error, PackedBlock, QuantError};
pub use pack::{elems_per_word, pack_stream, qmax, qmax_at, unpack_stream, words_for};
