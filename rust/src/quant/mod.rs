//! Low-bit quantization: packing, group-wise asymmetric quant, and the
//! decode-attention kernels over packed blocks — integer-domain
//! (unpack-free) for every ladder width, including 3-bit's Eq. 12
//! layout, with SWAR wide-words on stable Rust and head-tiled group
//! kernels on the attend path (the paper's CUDA-kernel contribution
//! mapped to CPU — see DESIGN.md §Hardware-Adaptation and
//! §Quantized-Kernels, and docs/adr/009-swar-and-interleaved-layout.md).

pub mod fused;
pub mod groupq;
pub mod pack;

pub use fused::{key_scores_dispatch, key_scores_fused, key_scores_group_dispatch,
                key_scores_group_packed, key_scores_group_ref, key_scores_packed,
                key_scores_packed_ref, packed_dot_supported, value_accum_dispatch,
                value_accum_fused, value_accum_group_dispatch,
                value_accum_group_packed, value_accum_group_ref, value_accum_packed,
                value_accum_packed_ref, FusedScratch, TileScratch};
pub use groupq::{interleave_supported, quant_error, PackedBlock, QuantError};
pub use pack::{elems_per_word, eq12_field, field_range, get_at, pack_stream, qmax,
               qmax_at, swar_mask, unpack_stream, words_for, FieldRange};
