//! Low-bit quantization: packing, group-wise asymmetric quant, and the
//! decode-attention kernels over packed blocks — integer-domain
//! (unpack-free) for uniform widths, unpack-based fused for 3-bit (the
//! paper's CUDA-kernel contribution mapped to CPU — see DESIGN.md
//! §Hardware-Adaptation and §Quantized-Kernels).

pub mod fused;
pub mod groupq;
pub mod pack;

pub use fused::{key_scores_dispatch, key_scores_fused, key_scores_packed,
                packed_dot_supported, value_accum_dispatch, value_accum_fused,
                value_accum_packed, FusedScratch};
pub use groupq::{quant_error, PackedBlock, QuantError};
pub use pack::{elems_per_word, field_range, get_at, pack_stream, qmax, qmax_at,
               unpack_stream, words_for, FieldRange};
