//! Group-wise asymmetric quantization (paper §Asymmetric Low-Bit
//! Quantization) over packed streams.
//!
//! A [`PackedBlock`] holds one quantized cache block as a contiguous
//! element stream: consecutive runs of `group` elements share one
//! (scale, min) pair.  The *stream order* encodes the paper's asymmetric
//! strategy (decided by the cache layer, [`crate::kvcache`]):
//!
//! * Key blocks    — channel-major: each channel's `group` tokens are one
//!   group  ⇒ per-channel quantization.
//! * Value blocks  — token-major: each token's channels split into groups
//!   of `group` ⇒ per-token quantization.
//!
//! Numerics match python/compile/kernels/ref.py exactly:
//! `s = (max-min)/qmax` (s<1e-6 ⇒ 1.0), `q = clip(floor((x-min)/s + .5))`,
//! `x~ = q·s + min`, with 3-bit clipping index-dependent per Eq. 12.

use std::sync::atomic::{AtomicU64, Ordering};

use super::pack::{elems_per_word, get_at, pack_stream, qmax, qmax_at, unpack_stream, words_for};

pub const EPS: f32 = 1e-6;

/// True when `bits`/`group` admit the channel-interleaved Key word layout
/// (DESIGN.md §Quantized-Kernels): uniform widths whose groups span whole
/// words.  3-bit's 11-per-word Eq. 12 layout never interleaves, nor do
/// groups that straddle word boundaries.
#[inline]
pub fn interleave_supported(bits: u8, group: usize) -> bool {
    bits != 0 && bits != 3 && bits <= 16 && 32 % bits as usize == 0
        && group % elems_per_word(bits) == 0
}

/// Monotonic source for [`PackedBlock::uid`] (0 = never quantized).
static NEXT_UID: AtomicU64 = AtomicU64::new(1);

/// One quantized block: packed words + per-group (scale, min).
///
/// `outliers` optionally holds KVQuant-style full-precision exceptions:
/// the largest-|x| fraction of elements is excluded from the group
/// statistics and stored exactly as (stream index, value); the fused
/// kernels apply them as corrections after the packed pass.
#[derive(Debug, Clone, Default)]
pub struct PackedBlock {
    pub bits: u8,
    /// total elements in the stream
    pub n: usize,
    /// elements per (scale, min) group; groups are stream-consecutive
    pub group: usize,
    pub words: Vec<u32>,
    pub scales: Vec<f32>,
    pub mins: Vec<f32>,
    /// KVQuant-style exact exceptions, **sorted by stream index** — an
    /// invariant established at (re)quantize time and relied on by the
    /// kernels' binary-searched outlier side path (quant/fused.rs): a
    /// head's contiguous stream range is located with `partition_point`
    /// instead of scanning every outlier per head per block.
    pub outliers: Vec<(u32, f32)>,
    /// Channel-interleaved word layout (Key blocks only, opt-in —
    /// docs/adr/009-swar-and-interleaved-layout.md): word `w` of group
    /// `g` lives at `words[w * n_groups + g]` instead of the linear
    /// `words[g * wpg + w]`, so the head-tiled score kernels stream one
    /// token chunk across every channel with a fixed word stride.  A pure
    /// word permutation: scales/mins/outliers and every dequant entry
    /// point ([`Self::code_at`], [`Self::unpack_into`]) are layout-aware,
    /// so `to_bits`-level results never change.  Only ever set when
    /// [`interleave_supported`]; Value blocks stay linear.
    pub interleaved: bool,
    /// Identity of the current packed contents, refreshed on every
    /// (re)quantization.  The fused kernels' unpack cache keys on this,
    /// so an in-place requantization (or a new block whose buffers reuse
    /// a freed allocation) can never be served stale integers.
    pub uid: u64,
}

impl PackedBlock {
    /// Rebuild a block from previously serialized parts (the spill tier's
    /// fault-back path — DESIGN.md §Spill-Tier).  A **fresh** uid is
    /// assigned: the bytes are identical to what was spilled, but the
    /// fused kernels' unpack cache may have recycled the old uid for a
    /// different block in the meantime, so restored contents must never
    /// alias a cached unpack.
    pub fn from_parts(bits: u8, n: usize, group: usize, interleaved: bool,
                      words: Vec<u32>, scales: Vec<f32>, mins: Vec<f32>,
                      outliers: Vec<(u32, f32)>) -> Self {
        debug_assert!(!interleaved || interleave_supported(bits, group));
        PackedBlock {
            bits, n, group, words, scales, mins, outliers, interleaved,
            uid: NEXT_UID.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Quantize `data` (stream order) into a new block.
    pub fn quantize(data: &[f32], bits: u8, group: usize) -> Self {
        let mut b = PackedBlock::default();
        b.quantize_into(data, bits, group, &mut Vec::new());
        b
    }

    /// Quantize reusing `scratch` for the intermediate integer stream
    /// (the fused quantize+append path calls this in a loop).
    pub fn quantize_into(&mut self, data: &[f32], bits: u8, group: usize,
                         scratch: &mut Vec<u32>) {
        assert!(data.len() % group == 0, "stream {} not a multiple of group {group}", data.len());
        let n_groups = data.len() / group;
        self.bits = bits;
        self.n = data.len();
        self.group = group;
        self.interleaved = false; // plain path always re-encodes linear
        self.uid = NEXT_UID.fetch_add(1, Ordering::Relaxed);
        self.scales.clear();
        self.mins.clear();
        self.outliers.clear();
        self.scales.reserve(n_groups);
        self.mins.reserve(n_groups);
        scratch.clear();
        scratch.resize(data.len(), 0);

        let qm = qmax(bits) as f32;
        for (g, chunk) in data.chunks(group).enumerate() {
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for &x in chunk {
                mn = mn.min(x);
                mx = mx.max(x);
            }
            let mut s = (mx - mn) / qm;
            if s < EPS {
                s = 1.0;
            }
            self.scales.push(s);
            self.mins.push(mn);
            let inv = 1.0 / s;
            let base = g * group;
            for (i, &x) in chunk.iter().enumerate() {
                let q = ((x - mn) * inv + 0.5).floor();
                let cap = qmax_at(bits, base + i) as f32;
                scratch[base + i] = q.clamp(0.0, cap) as u32;
            }
        }
        pack_stream(scratch, bits, &mut self.words);
    }

    /// Quantize with a KVQuant-style outlier budget: the `frac` largest-|x|
    /// elements per block are excluded from group statistics and stored
    /// exactly in `self.outliers`.
    pub fn quantize_outliers_into(&mut self, data: &[f32], bits: u8, group: usize,
                                  frac: f64, scratch: &mut Vec<u32>) {
        let n_out = ((data.len() as f64 * frac).ceil() as usize).min(data.len());
        if n_out == 0 {
            self.quantize_into(data, bits, group, scratch);
            return;
        }
        // indices of the n_out largest |x|
        let mut idx: Vec<u32> = (0..data.len() as u32).collect();
        idx.select_nth_unstable_by(n_out - 1, |&a, &b| {
            data[b as usize].abs().partial_cmp(&data[a as usize].abs()).unwrap()
        });
        let mut keep: Vec<(u32, f32)> =
            idx[..n_out].iter().map(|&i| (i, data[i as usize])).collect();
        // sorted by stream index: the kernels binary-search a head's range
        keep.sort_unstable_by_key(|&(i, _)| i);
        // neutralize outliers: replace with the mean of their group's
        // remaining elements so stats tighten around the inliers
        let mut tmp = data.to_vec();
        for &(i, _) in &keep {
            let g = i as usize / group;
            let gslice = &data[g * group..(g + 1) * group];
            let inlier_sum: f32 = gslice.iter().sum::<f32>()
                - keep.iter().filter(|&&(j, _)| (j as usize) / group == g)
                    .map(|&(_, v)| v).sum::<f32>();
            let n_in = group - keep.iter().filter(|&&(j, _)| (j as usize) / group == g).count();
            tmp[i as usize] = if n_in > 0 { inlier_sum / n_in as f32 } else { 0.0 };
        }
        self.quantize_into(&tmp, bits, group, scratch);
        self.outliers = keep;
    }

    /// [`Self::quantize_into`] plus opt-in channel interleaving (Key
    /// blocks; falls back to linear when the width/group can't
    /// interleave — [`interleave_supported`]).
    pub fn quantize_into_layout(&mut self, data: &[f32], bits: u8, group: usize,
                                interleave: bool, scratch: &mut Vec<u32>) {
        self.quantize_into(data, bits, group, scratch);
        if interleave {
            self.apply_interleave(scratch);
        }
    }

    /// [`Self::quantize_outliers_into`] plus opt-in channel interleaving.
    pub fn quantize_outliers_into_layout(&mut self, data: &[f32], bits: u8,
                                         group: usize, frac: f64, interleave: bool,
                                         scratch: &mut Vec<u32>) {
        self.quantize_outliers_into(data, bits, group, frac, scratch);
        if interleave {
            self.apply_interleave(scratch);
        }
    }

    /// Permute freshly packed (linear) words into the interleaved layout;
    /// stays linear when the width/group can't interleave.  The stream
    /// order (and thus outlier indices, scales, mins) is untouched — only
    /// the physical word placement changes.
    fn apply_interleave(&mut self, scratch: &mut Vec<u32>) {
        if !interleave_supported(self.bits, self.group) || self.n == 0 {
            self.interleaved = false;
            return;
        }
        let wpg = self.group / elems_per_word(self.bits);
        let ng = self.n / self.group;
        scratch.clear();
        scratch.extend_from_slice(&self.words);
        for g in 0..ng {
            for w in 0..wpg {
                self.words[w * ng + g] = scratch[g * wpg + w];
            }
        }
        self.interleaved = true;
    }

    /// Physical index in `words` of *linear* word `lw` (identity for the
    /// linear layout) — the kernels' layout seam.
    #[inline]
    pub fn word_index(&self, lw: usize) -> usize {
        if !self.interleaved {
            return lw;
        }
        let wpg = self.group / elems_per_word(self.bits);
        (lw % wpg) * (self.n / self.group) + lw / wpg
    }

    /// Packed code of stream element `idx`, layout-aware.
    #[inline]
    pub fn code_at(&self, idx: usize) -> u32 {
        if !self.interleaved {
            return get_at(&self.words, self.bits, idx);
        }
        let per = elems_per_word(self.bits);
        let w = self.words[self.word_index(idx / per)];
        (w >> (self.bits as usize * (idx % per))) & ((1u32 << self.bits) - 1)
    }

    /// Unpack the full integer stream (stream order) into `out[..n]`,
    /// layout-aware — the unpack-based fused kernels and
    /// [`Self::dequantize_into`] stage through this.
    pub fn unpack_into(&self, out: &mut [u32]) {
        if !self.interleaved {
            unpack_stream(&self.words, self.bits, self.n, out);
            return;
        }
        // interleaved ⇒ group % per == 0 ⇒ n % per == 0: no ragged tail
        let per = elems_per_word(self.bits);
        let bu = self.bits as usize;
        let mask = (1u32 << self.bits) - 1;
        for lw in 0..self.n / per {
            let w = self.words[self.word_index(lw)];
            for i in 0..per {
                out[lw * per + i] = (w >> (bu * i)) & mask;
            }
        }
    }

    /// Dequantized value of a single stream element given the unpacked
    /// integer stream (the unpack-based fused kernels' outlier path).
    #[inline]
    pub fn dequant_one(&self, idx: usize, ints: &[u32]) -> f32 {
        let g = idx / self.group;
        ints[idx] as f32 * self.scales[g] + self.mins[g]
    }

    /// Dequantized value of a single stream element straight from the
    /// packed words — no unpacked stream required (the packed kernels'
    /// outlier path).  Bit-identical to [`Self::dequant_one`].
    #[inline]
    pub fn dequant_at(&self, idx: usize) -> f32 {
        let g = idx / self.group;
        self.code_at(idx) as f32 * self.scales[g] + self.mins[g]
    }

    /// Dequantize the full stream into `out[..n]`.
    pub fn dequantize_into(&self, out: &mut [f32], scratch: &mut Vec<u32>) {
        assert!(out.len() >= self.n);
        scratch.clear();
        scratch.resize(self.n, 0);
        self.unpack_into(scratch);
        for (g, chunk) in scratch[..self.n].chunks(self.group).enumerate() {
            let (s, m) = (self.scales[g], self.mins[g]);
            let base = g * self.group;
            for (i, &q) in chunk.iter().enumerate() {
                out[base + i] = q as f32 * s + m;
            }
        }
        for &(i, v) in &self.outliers {
            out[i as usize] = v;
        }
    }

    /// Requantize this block in place to a narrower width (the paged
    /// pool's pressure-controller downshift — DESIGN.md §Memory-Manager):
    /// dequantize the current stream (outliers applied exactly), then
    /// re-encode it at `to_bits` with the same group size.  Outliers are
    /// folded into the narrower encoding rather than kept, so the
    /// downshifted block is pure packed words + group params.
    ///
    /// No-op (returns 0) unless `to_bits < self.bits`.  Otherwise returns
    /// the modeled bytes saved.  Quantization error compounds across
    /// downshifts — by design: this trades the oldest pages' fidelity for
    /// admission headroom, exactly the paper's dynamic long-context
    /// policy under memory pressure.
    pub fn requantize(&mut self, to_bits: u8, f32s: &mut Vec<f32>,
                      ints: &mut Vec<u32>) -> usize {
        if to_bits >= self.bits || self.n == 0 {
            return 0;
        }
        let before = self.modeled_bytes();
        let n = self.n;
        let group = self.group;
        let keep_interleave = self.interleaved;
        f32s.clear();
        f32s.resize(n, 0.0);
        self.dequantize_into(f32s, ints);
        let data = std::mem::take(f32s);
        self.quantize_into(&data[..n], to_bits, group, ints);
        *f32s = data;
        // a downshifted Key block keeps its layout (when the narrower
        // width still supports it — 3-bit drops to linear)
        if keep_interleave {
            self.apply_interleave(ints);
        }
        before.saturating_sub(self.modeled_bytes())
    }

    /// Modeled memory footprint in bytes, counting scale/min at fp16 as a
    /// production implementation would store them (paper Fig. 7 metric).
    pub fn modeled_bytes(&self) -> usize {
        // fp16 scale+min per group; outliers as (u32 idx, fp16 value)
        self.words.len() * 4 + self.scales.len() * 2 * 2 + self.outliers.len() * 6
    }

    /// Actual resident bytes of this block's buffers.
    pub fn resident_bytes(&self) -> usize {
        self.words.capacity() * 4 + (self.scales.capacity() + self.mins.capacity()) * 4
    }
}

/// Quant error statistics for a block vs the original stream.
#[derive(Debug, Clone, Copy, Default)]
pub struct QuantError {
    pub mse: f64,
    pub max_abs: f32,
}

pub fn quant_error(block: &PackedBlock, original: &[f32]) -> QuantError {
    let mut out = vec![0f32; block.n];
    block.dequantize_into(&mut out, &mut Vec::new());
    let mut mse = 0f64;
    let mut max_abs = 0f32;
    for (a, b) in out.iter().zip(original) {
        let d = (a - b).abs();
        mse += (d as f64) * (d as f64);
        max_abs = max_abs.max(d);
    }
    QuantError { mse: mse / original.len() as f64, max_abs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_error_bound() {
        let mut rng = Rng::new(3);
        for bits in [1u8, 2, 3, 4] {
            let data = rng.normal_vec(256);
            let block = PackedBlock::quantize(&data, bits, 32);
            let mut out = vec![0f32; 256];
            block.dequantize_into(&mut out, &mut Vec::new());
            for (g, chunk) in data.chunks(32).enumerate() {
                let s = block.scales[g];
                for (i, &x) in chunk.iter().enumerate() {
                    let err = (out[g * 32 + i] - x).abs();
                    // 3-bit Eq.12: every 11th *stream* element only has 2
                    // bits -> its clip point is 3s not 7s; error can reach
                    // (qmax - cap)*s + s/2 there.
                    let cap = qmax_at(bits, g * 32 + i) as f32;
                    let qm = qmax(bits) as f32;
                    let bound = if cap < qm { (qm - cap) * s + s / 2.0 } else { s / 2.0 };
                    assert!(err <= bound + 1e-4, "bits={bits} err={err} bound={bound}");
                }
            }
        }
    }

    #[test]
    fn constant_group_lossless() {
        let data = vec![2.5f32; 64];
        let block = PackedBlock::quantize(&data, 2, 32);
        let mut out = vec![0f32; 64];
        block.dequantize_into(&mut out, &mut Vec::new());
        assert_eq!(out, data);
    }

    #[test]
    fn endpoints_exact() {
        let mut rng = Rng::new(9);
        let data = rng.normal_vec(32);
        let block = PackedBlock::quantize(&data, 2, 32);
        let mut out = vec![0f32; 32];
        block.dequantize_into(&mut out, &mut Vec::new());
        let imn = data.iter().enumerate().min_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        let imx = data.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert!((out[imn] - data[imn]).abs() < 1e-6);
        assert!((out[imx] - data[imx]).abs() < 1e-5);
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Rng::new(5);
        let data = rng.normal_vec(1024);
        let errs: Vec<f64> = [1u8, 2, 3, 4].iter()
            .map(|&b| quant_error(&PackedBlock::quantize(&data, b, 32), &data).mse)
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2] && errs[2] > errs[3], "{errs:?}");
    }

    #[test]
    fn requantize_down_the_ladder() {
        // 8 -> 4 -> 2: bytes shrink and error grows monotonically at
        // every rung of the pressure controller's bit ladder
        let mut rng = Rng::new(7);
        let data = rng.normal_vec(512);
        let mut block = PackedBlock::quantize(&data, 8, 32);
        let mut f32s = Vec::new();
        let mut ints = Vec::new();
        let mut prev_bytes = block.modeled_bytes();
        let mut prev_err = quant_error(&block, &data).mse;
        let mut prev_uid = block.uid;
        for to in [4u8, 2] {
            let saved = block.requantize(to, &mut f32s, &mut ints);
            assert!(saved > 0, "downshift to {to} must save bytes");
            assert_eq!(block.modeled_bytes(), prev_bytes - saved);
            assert_eq!(block.bits, to);
            assert_eq!(block.n, 512);
            assert_ne!(block.uid, prev_uid, "requantize must refresh uid");
            let err = quant_error(&block, &data).mse;
            assert!(err > prev_err, "error must grow: {prev_err} -> {err}");
            prev_bytes = block.modeled_bytes();
            prev_err = err;
            prev_uid = block.uid;
        }
    }

    #[test]
    fn requantize_same_or_wider_is_noop() {
        let mut rng = Rng::new(8);
        let data = rng.normal_vec(64);
        let mut block = PackedBlock::quantize(&data, 2, 32);
        let uid = block.uid;
        let words = block.words.clone();
        assert_eq!(block.requantize(2, &mut Vec::new(), &mut Vec::new()), 0);
        assert_eq!(block.requantize(4, &mut Vec::new(), &mut Vec::new()), 0);
        assert_eq!(block.uid, uid);
        assert_eq!(block.words, words);
    }

    #[test]
    fn requantize_folds_outliers() {
        // a block with exact outliers downshifts to a pure packed block
        let mut rng = Rng::new(14);
        let data = rng.normal_vec(256);
        let mut block = PackedBlock::default();
        block.quantize_outliers_into(&data, 4, 32, 0.05, &mut Vec::new());
        assert!(!block.outliers.is_empty());
        block.requantize(2, &mut Vec::new(), &mut Vec::new());
        assert!(block.outliers.is_empty());
        assert_eq!(block.bits, 2);
        // still decodes to something finite and sane
        let e = quant_error(&block, &data);
        assert!(e.mse.is_finite() && e.max_abs.is_finite());
    }

    #[test]
    fn dequant_at_matches_dequant_one() {
        // the packed kernels' outlier path must agree bit-for-bit with
        // the unpack-based one at every width and stream index
        // 360 elements: ragged final word at 1-bit (360 % 32) and 3-bit
        // (360 % 11), group 24 keeps every group whole
        let mut rng = Rng::new(17);
        let data = rng.normal_vec(360);
        for bits in [1u8, 2, 3, 4, 8] {
            let block = PackedBlock::quantize(&data, bits, 24);
            let mut ints = vec![0u32; block.n];
            crate::quant::unpack_stream(&block.words, bits, block.n, &mut ints);
            for idx in 0..block.n {
                assert_eq!(block.dequant_at(idx).to_bits(),
                           block.dequant_one(idx, &ints).to_bits(),
                           "bits {bits} idx {idx}");
            }
        }
    }

    #[test]
    fn uids_are_unique_per_quantization() {
        let data = vec![1.0f32; 32];
        let a = PackedBlock::quantize(&data, 2, 32);
        let b = PackedBlock::quantize(&data, 2, 32);
        assert_ne!(a.uid, 0);
        assert_ne!(a.uid, b.uid);
    }

    #[test]
    fn from_parts_round_trips_with_fresh_uid() {
        let mut rng = Rng::new(21);
        let data = rng.normal_vec(128);
        let a = PackedBlock::quantize(&data, 3, 32);
        let b = PackedBlock::from_parts(a.bits, a.n, a.group, a.interleaved,
                                        a.words.clone(), a.scales.clone(),
                                        a.mins.clone(), a.outliers.clone());
        assert_ne!(b.uid, a.uid, "restored block must not alias the unpack cache");
        assert_ne!(b.uid, 0);
        let (mut oa, mut ob) = (vec![0f32; a.n], vec![0f32; a.n]);
        a.dequantize_into(&mut oa, &mut Vec::new());
        b.dequantize_into(&mut ob, &mut Vec::new());
        assert_eq!(oa.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   ob.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_layout_is_a_pure_word_permutation() {
        // same data, both layouts: every dequant entry point must agree
        // bit-for-bit (the to_bits/dequant_at round-trip contract)
        let mut rng = Rng::new(23);
        let data = rng.normal_vec(256); // 8 channel-groups of 32
        for bits in [1u8, 2, 4, 8] {
            let lin = PackedBlock::quantize(&data, bits, 32);
            let mut inter = PackedBlock::default();
            inter.quantize_into_layout(&data, bits, 32, true, &mut Vec::new());
            assert!(inter.interleaved, "bits={bits}");
            assert_eq!(lin.words.len(), inter.words.len());
            // word_index maps linear positions onto the permuted store
            for lw in 0..lin.words.len() {
                assert_eq!(lin.words[lw], inter.words[inter.word_index(lw)],
                           "bits={bits} lw={lw}");
            }
            for idx in 0..lin.n {
                assert_eq!(lin.dequant_at(idx).to_bits(),
                           inter.dequant_at(idx).to_bits(), "bits={bits} idx={idx}");
            }
            let (mut oa, mut ob) = (vec![0f32; lin.n], vec![0f32; lin.n]);
            lin.dequantize_into(&mut oa, &mut Vec::new());
            inter.dequantize_into(&mut ob, &mut Vec::new());
            assert_eq!(oa.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                       ob.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn interleave_requires_uniform_whole_word_groups() {
        assert!(!interleave_supported(3, 33)); // Eq. 12 never interleaves
        assert!(!interleave_supported(1, 24)); // group straddles words
        assert!(interleave_supported(2, 32) && interleave_supported(8, 32));
        let mut rng = Rng::new(24);
        let data = rng.normal_vec(66);
        let mut b = PackedBlock::default();
        b.quantize_into_layout(&data, 3, 33, true, &mut Vec::new());
        assert!(!b.interleaved, "unsupported layouts silently stay linear");
        let lin = PackedBlock::quantize(&data, 3, 33);
        assert_eq!(b.words, lin.words);
    }

    #[test]
    fn requantize_preserves_interleave() {
        let mut rng = Rng::new(25);
        let data = rng.normal_vec(512);
        let mut lin = PackedBlock::default();
        lin.quantize_outliers_into(&data, 4, 32, 0.02, &mut Vec::new());
        let mut inter = PackedBlock::default();
        inter.quantize_outliers_into_layout(&data, 4, 32, 0.02, true, &mut Vec::new());
        assert!(inter.interleaved && !inter.outliers.is_empty());
        lin.requantize(2, &mut Vec::new(), &mut Vec::new());
        inter.requantize(2, &mut Vec::new(), &mut Vec::new());
        assert!(inter.interleaved, "downshift must keep the layout");
        assert_eq!(inter.bits, 2);
        let (mut oa, mut ob) = (vec![0f32; lin.n], vec![0f32; lin.n]);
        lin.dequantize_into(&mut oa, &mut Vec::new());
        inter.dequantize_into(&mut ob, &mut Vec::new());
        assert_eq!(oa.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   ob.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   "layouts must downshift to identical values");
    }

    #[test]
    fn modeled_bytes_compression() {
        let data = vec![0.5f32; 4096];
        let b2 = PackedBlock::quantize(&data, 2, 32);
        // 4096 elts at 2 bit = 1024 bytes + 128 groups * 4B overhead
        assert_eq!(b2.modeled_bytes(), 4096 / 16 * 4 + 128 * 4);
        let ratio = (4096.0 * 2.0) / b2.modeled_bytes() as f64; // vs fp16
        assert!(ratio > 5.0, "2-bit compression vs fp16 = {ratio}");
    }
}
