//! Decode-attention kernels over packed KV blocks — the Rust analog of
//! the paper's CUDA contribution (§CUDA Implementation ②③), in two tiers
//! (DESIGN.md §Quantized-Kernels):
//!
//! * **Packed (integer-domain, unpack-free)** — [`key_scores_packed`] /
//!   [`value_accum_packed`] and their head-tiled group forms
//!   [`key_scores_group_packed`] / [`value_accum_group_packed`]: dot
//!   products computed directly on the packed words for every ladder
//!   width (1/2/3/4/8-bit, plus 16).  Uniform widths extract all fields
//!   of a 64-bit wide-word (two consecutive `u32`s) at once with SWAR
//!   shift/mask spreads into byte sub-lanes (`pack::swar_mask`) — the
//!   default on stable Rust — or into `std::simd` lanes behind the
//!   nightly-only `simd` cargo feature; 3-bit walks the Eq. 12
//!   11-per-word layout with a field cursor.  Each group's affine
//!   `(scale, min)` is folded into the accumulator once per group; no
//!   `u32` scratch is ever materialized; outliers are applied through
//!   [`PackedBlock::dequant_at`] on a binary-searched sparse side path.
//!   The group kernels additionally decode each field once and fan it
//!   out across all query heads of a KV group, and understand the
//!   channel-interleaved Key layout (`PackedBlock::interleaved`).
//!
//! * **Fused (unpack-based reference)** — [`key_scores_fused`] /
//!   [`value_accum_fused`]: unpack the block's integer stream into a
//!   reusable scratch, then fold the dequantization into the dot
//!   products algebraically.  Since the 3-bit layout went packed this is
//!   no longer on the decode path for any ladder width; it remains the
//!   escape hatch for irregular widths and the oracle the packed kernels
//!   are pinned bit-exact against (`rust/tests/packed_kernels.rs`).
//!   A second, structural reference exists inside the packed tier
//!   itself: [`key_scores_packed_ref`] / [`value_accum_packed_ref`] run
//!   the identical traversal with per-field scalar extraction instead of
//!   SWAR lanes — the word-scalar leg of the three-way identity wall.
//!
//! Both tiers share the same algebra:
//!
//!   Key  (per-channel groups): score[t] = Σ_c q[c]·(Q[c,t]·s_c + m_c)
//!        = Σ_c (q[c]·s_c)·Q[c,t]  +  Σ_c q[c]·m_c
//!     — the bias term is token-independent and hoisted out of the loop;
//!       the weighted sum runs channel-outer/token-inner so the inner loop
//!       is a contiguous fused-multiply-add over the block's tokens.
//!
//!   Value (per-token groups):  out[c] += Σ_t p[t]·(Q[t,c]·s_{t,g} + m_{t,g})
//!        = Σ_t (p[t]·s_{t,g})·Q[t,c]  +  bias_g(c∈g)
//!     — token-outer/channel-inner, again contiguous in the stream.
//!
//! Every backend keeps strict mul-then-add (no FMA contraction) and the
//! identical per-output-slot accumulation order, so SWAR, word-scalar,
//! `std::simd`, tiled, and interleaved paths all produce bit-identical
//! f32s.  [`key_scores_dispatch`] / [`value_accum_dispatch`] (and the
//! `_group_` forms used by `kvcache/cache.rs::attend`) pick the tier per
//! block width; the per-thread unpack scratch only fills on the
//! irregular-width fallback.

use super::groupq::PackedBlock;
use super::pack::{elems_per_word, eq12_field, field_range, swar_mask};

/// True if the packed (unpack-free) kernels handle this width: the
/// word-aligned uniform layouts plus 3-bit's Eq. 12 11-per-word layout
/// (DESIGN.md §Quantized-Kernels).
#[inline]
pub const fn packed_dot_supported(bits: u8) -> bool {
    bits == 3 || (bits != 0 && bits <= 16 && 32 % bits as usize == 0)
}

/// Reusable scratch buffers for the unpack-based fused kernels (one per
/// worker thread: the decode fan-out carries a `FusedScratch` inside each
/// worker's `AttnScratch`, never sharing one across threads).  The packed
/// kernels take no scratch at all, so on ladder-width plans the `ints`
/// buffer never allocates.
///
/// The unpack-cache `tag` stores the [`PackedBlock::uid`] of the block
/// currently staged in `ints`.  The uid is refreshed on every
/// (re)quantization, so a pressure-controller downshift that rewrites a
/// block in place — or a new block whose buffers reuse a freed
/// allocation — can never match a stale unpack.  Prefix sharing
/// (DESIGN.md §Prefix-Sharing) preserves the invariant from the other
/// direction: a shared block's `Arc` clones keep its uid, and a
/// copy-on-write clone is always requantized (fresh uid) before anyone
/// reads it — identical uid therefore always means identical bytes,
/// even across lanes that share a block.  The cache only elides
/// re-unpacking, never changes results.
#[derive(Default)]
pub struct FusedScratch {
    pub ints: Vec<u32>,
    pub f32s: Vec<f32>,
    /// uid of the block currently unpacked in `ints` (0 = none) — lets
    /// per-head loops skip redundant unpacks
    tag: u64,
}

impl FusedScratch {
    /// Invalidate the unpack cache (call if `ints` is clobbered by hand).
    pub fn invalidate(&mut self) {
        self.tag = 0;
    }
}

/// Reusable buffers for the head-tiled group kernels: the per-(channel,
/// head) `q·scale` table precomputed once per block, per-head bias
/// accumulators, and per-head `(p, p·s, p·m)` triples for value tiling.
/// Small (at most `rep·head_dim` f32s) and reused across blocks; lives
/// inside each worker's `AttnScratch` next to [`FusedScratch`].
#[derive(Default)]
pub struct TileScratch {
    /// `q·scale` per (channel, head), transposed — `qs[d*rep + r]` — so
    /// one channel's head weights are a contiguous slice
    qs: Vec<f32>,
    /// per-head scalars: key bias Σ q·min, or the gathered `p_t` column
    acc: Vec<f32>,
    /// per-head `p·min` products (value tiling)
    pm: Vec<f32>,
}

/// Sorted-outlier invariant the binary-searched side paths rely on
/// (established by `PackedBlock::quantize_outliers_into`).
#[inline]
fn debug_assert_outliers_sorted(block: &PackedBlock) {
    debug_assert!(block.outliers.windows(2).all(|w| w[0].0 < w[1].0),
                  "outliers must be sorted by stream index");
}

// ---------------------------------------------------------------------------
// SWAR row primitives (stable-Rust wide path)
//
// Two consecutive u32 words fuse into one u64 wide-word — fields never
// straddle a u32 boundary when 32 % bits == 0, so the concatenation is
// seamless.  R = 8/bits shift/mask pairs spread the wide-word into byte
// sub-lanes (pack::swar_mask); byte j of lane l is field j*R + l.  Each
// field is extracted exactly once and multiply-added exactly once per
// output slot, so results are bit-identical to the per-field scalar loop.
// 16-bit fields don't fit a byte sub-lane and stay on the scalar loop.
// ---------------------------------------------------------------------------

/// `out[j*R + l] += qs * byte_j(lane_l)` for one u32 (bytes 0..4).
#[inline(always)]
fn swar_dot_word1<const BITS: usize, const R: usize>(w: u32, qs: f32, out: &mut [f32]) {
    let mask = swar_mask(BITS as u8);
    let w = w as u64;
    let mut lanes = [0u64; R];
    for (l, lane) in lanes.iter_mut().enumerate() {
        *lane = (w >> (BITS * l)) & mask;
    }
    for j in 0..4 {
        for (l, &lane) in lanes.iter().enumerate() {
            out[j * R + l] += qs * ((lane >> (8 * j)) & 0xFF) as f32;
        }
    }
}

/// `out[i] += qs * field[i]` over whole packed words, SWAR backend.
#[inline(always)]
fn swar_dot_words<const BITS: usize, const R: usize>(words: &[u32], qs: f32,
                                                     out: &mut [f32]) {
    debug_assert_eq!(BITS * R, 8);
    let mask = swar_mask(BITS as u8);
    let per = 32 / BITS;
    let mut i = 0;
    let mut t = 0;
    while i + 1 < words.len() {
        let w = words[i] as u64 | (words[i + 1] as u64) << 32;
        let mut lanes = [0u64; R];
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane = (w >> (BITS * l)) & mask;
        }
        for j in 0..8 {
            for (l, &lane) in lanes.iter().enumerate() {
                out[t + j * R + l] += qs * ((lane >> (8 * j)) & 0xFF) as f32;
            }
        }
        t += 2 * per;
        i += 2;
    }
    if i < words.len() {
        swar_dot_word1::<BITS, R>(words[i], qs, &mut out[t..t + per]);
    }
}

/// `out[i] += ps * field[i] + pm` over whole packed words, SWAR backend.
#[inline(always)]
fn swar_accum_words<const BITS: usize, const R: usize>(words: &[u32], ps: f32,
                                                       pm: f32, out: &mut [f32]) {
    debug_assert_eq!(BITS * R, 8);
    let mask = swar_mask(BITS as u8);
    let per = 32 / BITS;
    let mut i = 0;
    let mut t = 0;
    while i + 1 < words.len() {
        let w = words[i] as u64 | (words[i + 1] as u64) << 32;
        let mut lanes = [0u64; R];
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane = (w >> (BITS * l)) & mask;
        }
        for j in 0..8 {
            for (l, &lane) in lanes.iter().enumerate() {
                out[t + j * R + l] += ps * ((lane >> (8 * j)) & 0xFF) as f32 + pm;
            }
        }
        t += 2 * per;
        i += 2;
    }
    if i < words.len() {
        let w = words[i] as u64;
        let mut lanes = [0u64; R];
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane = (w >> (BITS * l)) & mask;
        }
        for j in 0..4 {
            for (l, &lane) in lanes.iter().enumerate() {
                out[t + j * R + l] += ps * ((lane >> (8 * j)) & 0xFF) as f32 + pm;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Eq. 12 3-bit row primitives
//
// The 11-per-word layout has no byte-aligned sub-lanes, so SWAR and the
// word-scalar reference share this cursor walk: one cached word, field
// index advanced mod 11 (pack::eq12_field handles the 2-bit tail field).
// ---------------------------------------------------------------------------

/// `out[t] += qs * field[start+t]` over an Eq. 12 3-bit row.
#[inline]
fn eq12_dot_row(words: &[u32], start: usize, qs: f32, out: &mut [f32]) {
    let mut wi = start / 11;
    let mut f = start % 11;
    let mut w = words.get(wi).copied().unwrap_or(0);
    for slot in out.iter_mut() {
        *slot += qs * eq12_field(w, f) as f32;
        f += 1;
        if f == 11 {
            wi += 1;
            f = 0;
            w = words.get(wi).copied().unwrap_or(0);
        }
    }
}

/// `out[i] += ps * field[start+i] + pm` over an Eq. 12 3-bit group row.
#[inline]
fn eq12_accum_row(words: &[u32], start: usize, ps: f32, pm: f32, out: &mut [f32]) {
    let mut wi = start / 11;
    let mut f = start % 11;
    let mut w = words.get(wi).copied().unwrap_or(0);
    for slot in out.iter_mut() {
        *slot += ps * eq12_field(w, f) as f32 + pm;
        f += 1;
        if f == 11 {
            wi += 1;
            f = 0;
            w = words.get(wi).copied().unwrap_or(0);
        }
    }
}

// ---------------------------------------------------------------------------
// Backend-dispatching row kernels.  `swar=false` is the word-scalar
// reference backend: identical traversal, per-field shift/mask
// extraction — the structural oracle for the SWAR and simd lanes.
// ---------------------------------------------------------------------------

/// `out[i] += qs * field[i]` over one word-aligned row.
#[inline]
fn dot_row_aligned(row_words: &[u32], bits: u8, qs: f32, out: &mut [f32], swar: bool) {
    if swar {
        #[cfg(feature = "simd")]
        if simd::dot_row(row_words, bits, qs, out) {
            return;
        }
        match bits {
            1 => return swar_dot_words::<1, 8>(row_words, qs, out),
            2 => return swar_dot_words::<2, 4>(row_words, qs, out),
            4 => return swar_dot_words::<4, 2>(row_words, qs, out),
            8 => return swar_dot_words::<8, 1>(row_words, qs, out),
            _ => {} // 16-bit fields don't fit byte sub-lanes
        }
    }
    let per = elems_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let b = bits as usize;
    for (w, o) in row_words.iter().zip(out.chunks_exact_mut(per)) {
        for (i, slot) in o.iter_mut().enumerate() {
            *slot += qs * ((w >> (b * i)) & mask) as f32;
        }
    }
}

/// `out[i] += qs * field[i]` for the `per` fields of a single word (the
/// interleaved layout's strided walk visits one u32 at a time).
#[inline]
fn dot_word1(w: u32, bits: u8, qs: f32, out: &mut [f32], swar: bool) {
    if swar {
        match bits {
            1 => return swar_dot_word1::<1, 8>(w, qs, out),
            2 => return swar_dot_word1::<2, 4>(w, qs, out),
            4 => return swar_dot_word1::<4, 2>(w, qs, out),
            8 => return swar_dot_word1::<8, 1>(w, qs, out),
            _ => {}
        }
    }
    let per = elems_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let b = bits as usize;
    for (i, slot) in out[..per].iter_mut().enumerate() {
        *slot += qs * ((w >> (b * i)) & mask) as f32;
    }
}

/// `out[i] += qs * field[start+i]` over a row that straddles words
/// (word-scalar on every backend: these shapes never hit the hot path).
#[inline]
fn dot_row_unaligned(words: &[u32], bits: u8, start: usize, qs: f32, out: &mut [f32]) {
    let b = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for (w, f0, n) in field_range(words, bits, start, out.len()) {
        for (j, slot) in out[t..t + n].iter_mut().enumerate() {
            *slot += qs * ((w >> (b * (f0 + j))) & mask) as f32;
        }
        t += n;
    }
}

/// `out[i] += ps * field[i] + pm` over one word-aligned group row.
#[inline]
fn accum_row_aligned(row_words: &[u32], bits: u8, ps: f32, pm: f32, out: &mut [f32],
                     swar: bool) {
    if swar {
        #[cfg(feature = "simd")]
        if simd::accum_row(row_words, bits, ps, pm, out) {
            return;
        }
        match bits {
            1 => return swar_accum_words::<1, 8>(row_words, ps, pm, out),
            2 => return swar_accum_words::<2, 4>(row_words, ps, pm, out),
            4 => return swar_accum_words::<4, 2>(row_words, ps, pm, out),
            8 => return swar_accum_words::<8, 1>(row_words, ps, pm, out),
            _ => {}
        }
    }
    let per = elems_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let b = bits as usize;
    for (w, o) in row_words.iter().zip(out.chunks_exact_mut(per)) {
        for (i, slot) in o.iter_mut().enumerate() {
            *slot += ps * ((w >> (b * i)) & mask) as f32 + pm;
        }
    }
}

/// `out[i] += ps * field[start+i] + pm` over a word-straddling group row.
#[inline]
fn accum_row_unaligned(words: &[u32], bits: u8, start: usize, ps: f32, pm: f32,
                       out: &mut [f32]) {
    let b = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for (w, f0, n) in field_range(words, bits, start, out.len()) {
        for (j, slot) in out[t..t + n].iter_mut().enumerate() {
            *slot += ps * ((w >> (b * (f0 + j))) & mask) as f32 + pm;
        }
        t += n;
    }
}

// ---------------------------------------------------------------------------
// Packed (integer-domain, unpack-free) single-head kernels
// ---------------------------------------------------------------------------

/// Attention scores of one query head against a **Key block**, computed
/// directly on the packed words — no unpacked stream is ever
/// materialized.  Bit-exact with [`key_scores_fused`] and with
/// [`key_scores_packed_ref`] (pinned by `rust/tests/packed_kernels.rs`).
///
/// * `q` — the query slice for this KV head (`head_dim` f32s, RoPE'd).
/// * `block` — channel-major Key block (stream index `c*tokens + t`),
///   width must satisfy [`packed_dot_supported`]; either word layout.
/// * `tokens` — tokens in the block (= the per-channel group size).
/// * `out[t] +=` raw (unscaled) dot products — caller applies 1/sqrt(hd).
pub fn key_scores_packed(q: &[f32], block: &PackedBlock, tokens: usize,
                         chan_offset: usize, out: &mut [f32]) {
    key_scores_packed_impl(q, block, tokens, chan_offset, out, true);
}

/// Word-scalar reference backend of [`key_scores_packed`]: identical
/// traversal with per-field shift/mask extraction instead of SWAR lanes.
/// The three-way identity wall pins SWAR (and `--features simd`) against
/// this.
pub fn key_scores_packed_ref(q: &[f32], block: &PackedBlock, tokens: usize,
                             chan_offset: usize, out: &mut [f32]) {
    key_scores_packed_impl(q, block, tokens, chan_offset, out, false);
}

fn key_scores_packed_impl(q: &[f32], block: &PackedBlock, tokens: usize,
                          chan_offset: usize, out: &mut [f32], swar: bool) {
    debug_assert_eq!(block.group, tokens);
    debug_assert!(out.len() >= tokens);
    debug_assert!(chan_offset + q.len() <= block.scales.len());
    debug_assert!(packed_dot_supported(block.bits));
    debug_assert_outliers_sorted(block);
    let bits = block.bits;
    let out = &mut out[..tokens];

    let mut bias = 0f32;
    if bits == 3 {
        for (d, &qd) in q.iter().enumerate() {
            let c = chan_offset + d;
            let qs = qd * block.scales[c];
            bias += qd * block.mins[c];
            eq12_dot_row(&block.words, c * tokens, qs, out);
        }
    } else {
        let per = elems_per_word(bits);
        if block.interleaved {
            // interleave guarantees tokens % per == 0; word w of channel
            // c sits at words[w*n_chan + c]
            let wpr = tokens / per;
            let n_chan = block.n / block.group;
            for (d, &qd) in q.iter().enumerate() {
                let c = chan_offset + d;
                let qs = qd * block.scales[c];
                bias += qd * block.mins[c];
                for w in 0..wpr {
                    dot_word1(block.words[w * n_chan + c], bits, qs,
                              &mut out[w * per..(w + 1) * per], swar);
                }
            }
        } else if tokens % per == 0 {
            // every channel row starts word-aligned: whole-word path
            let wpr = tokens / per; // words per row
            for (d, &qd) in q.iter().enumerate() {
                let c = chan_offset + d;
                let qs = qd * block.scales[c];
                bias += qd * block.mins[c];
                dot_row_aligned(&block.words[c * wpr..(c + 1) * wpr], bits, qs, out, swar);
            }
        } else {
            // rows straddle word boundaries: word-at-a-time view
            for (d, &qd) in q.iter().enumerate() {
                let c = chan_offset + d;
                let qs = qd * block.scales[c];
                bias += qd * block.mins[c];
                dot_row_unaligned(&block.words, bits, c * tokens, qs, out);
            }
        }
    }
    for s in out.iter_mut() {
        *s += bias;
    }
    // outlier corrections: the head's channels are the contiguous stream
    // range [chan_offset·tokens, (chan_offset+hd)·tokens), binary-searched
    // in the index-sorted list instead of scanning every outlier per head
    let lo = block.outliers.partition_point(|&(i, _)| (i as usize) < chan_offset * tokens);
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < (chan_offset + q.len()) * tokens);
    for &(i, v) in &block.outliers[lo..hi] {
        let c = i as usize / tokens;
        let t = i as usize % tokens;
        out[t] += q[c - chan_offset] * (v - block.dequant_at(i as usize));
    }
}

/// Weighted-value accumulation of one head's probabilities against a
/// **Value block**, computed directly on the packed words.  Bit-exact
/// with [`value_accum_fused`] and [`value_accum_packed_ref`].
///
/// * `p[t]` — softmax probabilities for this block's tokens.
/// * `block` — token-major Value block (stream index `t*kv_dim + c`),
///   width must satisfy [`packed_dot_supported`]; always linear layout
///   (the channel interleave is Key-only).
/// * `kv_dim` — full channel count per token; `chan_offset` selects this
///   head's `head_dim` channels (must be group-aligned).
/// * `out[d] +=` accumulated weighted values for d in 0..head_dim.
pub fn value_accum_packed(p: &[f32], block: &PackedBlock, kv_dim: usize,
                          chan_offset: usize, head_dim: usize, out: &mut [f32]) {
    value_accum_packed_impl(p, block, kv_dim, chan_offset, head_dim, out, true);
}

/// Word-scalar reference backend of [`value_accum_packed`] — see
/// [`key_scores_packed_ref`].
pub fn value_accum_packed_ref(p: &[f32], block: &PackedBlock, kv_dim: usize,
                              chan_offset: usize, head_dim: usize, out: &mut [f32]) {
    value_accum_packed_impl(p, block, kv_dim, chan_offset, head_dim, out, false);
}

fn value_accum_packed_impl(p: &[f32], block: &PackedBlock, kv_dim: usize,
                           chan_offset: usize, head_dim: usize, out: &mut [f32],
                           swar: bool) {
    debug_assert_eq!(chan_offset % block.group, 0);
    debug_assert_eq!(head_dim % block.group, 0);
    debug_assert!(chan_offset + head_dim <= kv_dim);
    debug_assert!((chan_offset + head_dim).div_ceil(block.group) <= block.scales.len());
    debug_assert!(packed_dot_supported(block.bits));
    debug_assert!(!block.interleaved, "Value blocks stay linear");
    debug_assert_outliers_sorted(block);
    let bits = block.bits;
    let per = elems_per_word(bits);
    let tokens = block.n / kv_dim;
    let groups_per_token = kv_dim / block.group;
    let g0 = chan_offset / block.group;
    let gn = head_dim / block.group;
    // every token row is word-aligned iff a group spans whole words and
    // token strides land on word boundaries (true for the standard
    // group=32 layouts at 1/2/4/8-bit); 3-bit always walks the cursor
    let aligned = bits != 3 && block.group % per == 0 && kv_dim % per == 0
        && chan_offset % per == 0;
    let wpg = if aligned { block.group / per } else { 0 }; // words per group

    for (t, &pt) in p.iter().enumerate().take(tokens) {
        if pt == 0.0 {
            continue;
        }
        let base = t * kv_dim + chan_offset;
        for g in 0..gn {
            let gi = t * groups_per_token + g0 + g;
            let ps = pt * block.scales[gi];
            let pm = pt * block.mins[gi];
            let o = &mut out[g * block.group..(g + 1) * block.group];
            let e0 = base + g * block.group;
            if aligned {
                let w0 = e0 / per;
                accum_row_aligned(&block.words[w0..w0 + wpg], bits, ps, pm, o, swar);
            } else if bits == 3 {
                eq12_accum_row(&block.words, e0, ps, pm, o);
            } else {
                accum_row_unaligned(&block.words, bits, e0, ps, pm, o);
            }
        }
    }
    // outlier corrections: index-sorted, so the scan is bounded to the
    // tokens `p` covers; the head's channels are strided per token, so
    // membership stays a predicate inside the bounded range
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < p.len().min(tokens) * kv_dim);
    for &(i, v) in &block.outliers[..hi] {
        let t = i as usize / kv_dim;
        let c = i as usize % kv_dim;
        if c >= chan_offset && c < chan_offset + head_dim && p[t] != 0.0 {
            out[c - chan_offset] += p[t] * (v - block.dequant_at(i as usize));
        }
    }
}

// ---------------------------------------------------------------------------
// Head-tiled group kernels: one KV group's `rep` query heads per call.
// Each packed field is decoded once and fanned out across the tile; the
// per-(channel, head) q·scale products are precomputed once per block.
// Per-output-slot accumulation chains are the same adds in the same
// order as `rep` successive single-head calls, so results are
// bit-identical (pinned by rust/tests/packed_kernels.rs).
// ---------------------------------------------------------------------------

/// Head-tiled key kernel: scores of `rep` query heads sharing one KV
/// head against a Key block.
///
/// * `q` — `rep * head_dim` f32s, head-major (the query group).
/// * `out` — `rep` rows spaced `stride` apart: row `r` receives
///   `out[r*stride .. r*stride + tokens] +=` scores.
#[allow(clippy::too_many_arguments)]
pub fn key_scores_group_packed(q: &[f32], rep: usize, block: &PackedBlock,
                               tokens: usize, chan_offset: usize, out: &mut [f32],
                               stride: usize, tile: &mut TileScratch) {
    key_scores_group_impl(q, rep, block, tokens, chan_offset, out, stride, tile, true);
}

/// Word-scalar reference backend of [`key_scores_group_packed`].
#[allow(clippy::too_many_arguments)]
pub fn key_scores_group_ref(q: &[f32], rep: usize, block: &PackedBlock,
                            tokens: usize, chan_offset: usize, out: &mut [f32],
                            stride: usize, tile: &mut TileScratch) {
    key_scores_group_impl(q, rep, block, tokens, chan_offset, out, stride, tile, false);
}

#[allow(clippy::too_many_arguments)]
fn key_scores_group_impl(q: &[f32], rep: usize, block: &PackedBlock, tokens: usize,
                         chan_offset: usize, out: &mut [f32], stride: usize,
                         tile: &mut TileScratch, swar: bool) {
    debug_assert_eq!(block.group, tokens);
    debug_assert!(rep >= 1 && q.len() % rep == 0);
    let hd = q.len() / rep;
    debug_assert!(chan_offset + hd <= block.scales.len());
    debug_assert!(stride >= tokens);
    debug_assert!(out.len() >= (rep - 1) * stride + tokens);
    debug_assert!(packed_dot_supported(block.bits));
    debug_assert_outliers_sorted(block);
    let bits = block.bits;

    // per-(channel, head) q·scale table + per-head bias, once per block;
    // the bias sums run d-ascending exactly like the single-head kernel
    tile.qs.clear();
    tile.qs.resize(rep * hd, 0.0);
    tile.acc.clear();
    tile.acc.resize(rep, 0.0);
    for r in 0..rep {
        let qh = &q[r * hd..(r + 1) * hd];
        let mut bias = 0f32;
        for (d, &qd) in qh.iter().enumerate() {
            let c = chan_offset + d;
            tile.qs[d * rep + r] = qd * block.scales[c];
            bias += qd * block.mins[c];
        }
        tile.acc[r] = bias;
    }

    if bits == 3 {
        for d in 0..hd {
            let c = chan_offset + d;
            eq12_dot_row_multi(&block.words, c * tokens, tokens,
                               &tile.qs[d * rep..(d + 1) * rep], out, stride);
        }
    } else {
        let per = elems_per_word(bits);
        if block.interleaved {
            // the layout's payoff: walk words sequentially — one token
            // chunk across every channel of the tile per stride step
            let wpr = tokens / per;
            let n_chan = block.n / block.group;
            for w in 0..wpr {
                let base = w * n_chan + chan_offset;
                for d in 0..hd {
                    dot_word1_multi(block.words[base + d], bits,
                                    &tile.qs[d * rep..(d + 1) * rep],
                                    &mut out[w * per..], stride, swar);
                }
            }
        } else if tokens % per == 0 {
            let wpr = tokens / per;
            for d in 0..hd {
                let c = chan_offset + d;
                dot_row_multi(&block.words[c * wpr..(c + 1) * wpr], bits,
                              &tile.qs[d * rep..(d + 1) * rep], out, stride, swar);
            }
        } else {
            for d in 0..hd {
                let c = chan_offset + d;
                dot_row_unaligned_multi(&block.words, bits, c * tokens, tokens,
                                        &tile.qs[d * rep..(d + 1) * rep], out, stride);
            }
        }
    }
    // per-head bias, then outliers — the same per-slot positions in the
    // accumulation chain as the single-head kernel
    for r in 0..rep {
        let bias = tile.acc[r];
        for s in out[r * stride..r * stride + tokens].iter_mut() {
            *s += bias;
        }
    }
    let lo = block.outliers.partition_point(|&(i, _)| (i as usize) < chan_offset * tokens);
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < (chan_offset + hd) * tokens);
    for &(i, v) in &block.outliers[lo..hi] {
        let c = i as usize / tokens;
        let t = i as usize % tokens;
        let corr = v - block.dequant_at(i as usize);
        for r in 0..rep {
            out[r * stride + t] += q[r * hd + (c - chan_offset)] * corr;
        }
    }
}

/// Head-tiled value kernel: weighted-value accumulation for `rep` heads
/// sharing one KV head.  Row `r`'s probabilities are
/// `p[r*p_stride .. r*p_stride + tokens]`; its output accumulates into
/// `out[r*head_dim .. (r+1)*head_dim]`.  Per-head `p[t] == 0.0` skips are
/// preserved exactly (adding a zero term would flip `-0.0` accumulators).
#[allow(clippy::too_many_arguments)]
pub fn value_accum_group_packed(p: &[f32], p_stride: usize, rep: usize,
                                block: &PackedBlock, kv_dim: usize,
                                chan_offset: usize, head_dim: usize,
                                out: &mut [f32], tile: &mut TileScratch) {
    value_accum_group_impl(p, p_stride, rep, block, kv_dim, chan_offset, head_dim,
                           out, tile, true);
}

/// Word-scalar reference backend of [`value_accum_group_packed`].
#[allow(clippy::too_many_arguments)]
pub fn value_accum_group_ref(p: &[f32], p_stride: usize, rep: usize,
                             block: &PackedBlock, kv_dim: usize,
                             chan_offset: usize, head_dim: usize,
                             out: &mut [f32], tile: &mut TileScratch) {
    value_accum_group_impl(p, p_stride, rep, block, kv_dim, chan_offset, head_dim,
                           out, tile, false);
}

#[allow(clippy::too_many_arguments)]
fn value_accum_group_impl(p: &[f32], p_stride: usize, rep: usize,
                          block: &PackedBlock, kv_dim: usize, chan_offset: usize,
                          head_dim: usize, out: &mut [f32], tile: &mut TileScratch,
                          swar: bool) {
    debug_assert_eq!(chan_offset % block.group, 0);
    debug_assert_eq!(head_dim % block.group, 0);
    debug_assert!(chan_offset + head_dim <= kv_dim);
    debug_assert!((chan_offset + head_dim).div_ceil(block.group) <= block.scales.len());
    debug_assert!(packed_dot_supported(block.bits));
    debug_assert!(!block.interleaved, "Value blocks stay linear");
    debug_assert_outliers_sorted(block);
    debug_assert!(out.len() >= rep * head_dim);
    let bits = block.bits;
    let per = elems_per_word(bits);
    let tokens = block.n / kv_dim;
    debug_assert!(rep >= 1 && p.len() >= (rep - 1) * p_stride + tokens);
    let groups_per_token = kv_dim / block.group;
    let g0 = chan_offset / block.group;
    let gn = head_dim / block.group;
    let aligned = bits != 3 && block.group % per == 0 && kv_dim % per == 0
        && chan_offset % per == 0;
    let wpg = if aligned { block.group / per } else { 0 };

    tile.acc.clear();
    tile.acc.resize(rep, 0.0); // p_t column
    tile.qs.clear();
    tile.qs.resize(rep, 0.0); // p_t·scale
    tile.pm.clear();
    tile.pm.resize(rep, 0.0); // p_t·min
    for t in 0..tokens {
        let mut any = false;
        for r in 0..rep {
            let pt = p[r * p_stride + t];
            tile.acc[r] = pt;
            any |= pt != 0.0;
        }
        if !any {
            continue;
        }
        let base = t * kv_dim + chan_offset;
        for g in 0..gn {
            let gi = t * groups_per_token + g0 + g;
            let (s, m) = (block.scales[gi], block.mins[gi]);
            for r in 0..rep {
                tile.qs[r] = tile.acc[r] * s;
                tile.pm[r] = tile.acc[r] * m;
            }
            let e0 = base + g * block.group;
            let o = &mut out[g * block.group..];
            if aligned {
                let w0 = e0 / per;
                accum_row_multi(&block.words[w0..w0 + wpg], bits, &tile.acc,
                                &tile.qs, &tile.pm, o, head_dim, swar);
            } else if bits == 3 {
                eq12_accum_row_multi(&block.words, e0, block.group, &tile.acc,
                                     &tile.qs, &tile.pm, o, head_dim);
            } else {
                accum_row_unaligned_multi(&block.words, bits, e0, block.group,
                                          &tile.acc, &tile.qs, &tile.pm, o, head_dim);
            }
        }
    }
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < tokens * kv_dim);
    for &(i, v) in &block.outliers[..hi] {
        let t = i as usize / kv_dim;
        let c = i as usize % kv_dim;
        if c >= chan_offset && c < chan_offset + head_dim {
            let corr = v - block.dequant_at(i as usize);
            for r in 0..rep {
                let pt = p[r * p_stride + t];
                if pt != 0.0 {
                    out[r * head_dim + (c - chan_offset)] += pt * corr;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-head row primitives: decode each field once, fan it out across
// the tile.  `qs`/`ps`/`pm` hold one weight per head; output rows are
// `stride` apart.  Each slot still receives exactly one add of exactly
// the single-head value, so any backend is bit-identical to per-head.
// ---------------------------------------------------------------------------

/// `out[r*stride + t] += qs[r] * field[t]` over a contiguous word row.
#[inline]
fn dot_row_multi(row_words: &[u32], bits: u8, qs: &[f32], out: &mut [f32],
                 stride: usize, swar: bool) {
    if swar {
        #[cfg(feature = "simd")]
        if simd::dot_row_multi(row_words, bits, qs, out, stride) {
            return;
        }
        match bits {
            1 => return swar_dot_words_multi::<1, 8>(row_words, qs, out, stride),
            2 => return swar_dot_words_multi::<2, 4>(row_words, qs, out, stride),
            4 => return swar_dot_words_multi::<4, 2>(row_words, qs, out, stride),
            8 => return swar_dot_words_multi::<8, 1>(row_words, qs, out, stride),
            _ => {}
        }
    }
    let per = elems_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let b = bits as usize;
    for (wi, w) in row_words.iter().enumerate() {
        let t0 = wi * per;
        for i in 0..per {
            let fv = ((w >> (b * i)) & mask) as f32;
            for (r, &qsr) in qs.iter().enumerate() {
                out[r * stride + t0 + i] += qsr * fv;
            }
        }
    }
}

/// SWAR backend of [`dot_row_multi`].
#[inline(always)]
fn swar_dot_words_multi<const BITS: usize, const R: usize>(words: &[u32], qs: &[f32],
                                                           out: &mut [f32],
                                                           stride: usize) {
    debug_assert_eq!(BITS * R, 8);
    let mask = swar_mask(BITS as u8);
    let per = 32 / BITS;
    let mut i = 0;
    let mut t = 0;
    while i + 1 < words.len() {
        let w = words[i] as u64 | (words[i + 1] as u64) << 32;
        let mut lanes = [0u64; R];
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane = (w >> (BITS * l)) & mask;
        }
        for j in 0..8 {
            for (l, &lane) in lanes.iter().enumerate() {
                let fv = ((lane >> (8 * j)) & 0xFF) as f32;
                let slot = t + j * R + l;
                for (r, &qsr) in qs.iter().enumerate() {
                    out[r * stride + slot] += qsr * fv;
                }
            }
        }
        t += 2 * per;
        i += 2;
    }
    if i < words.len() {
        swar_dot_word1_multi::<BITS, R>(words[i], qs, &mut out[t..], stride);
    }
}

/// `out[r*stride + i] += qs[r] * field[i]` for one u32's fields.
#[inline]
fn dot_word1_multi(w: u32, bits: u8, qs: &[f32], out: &mut [f32], stride: usize,
                   swar: bool) {
    if swar {
        match bits {
            1 => return swar_dot_word1_multi::<1, 8>(w, qs, out, stride),
            2 => return swar_dot_word1_multi::<2, 4>(w, qs, out, stride),
            4 => return swar_dot_word1_multi::<4, 2>(w, qs, out, stride),
            8 => return swar_dot_word1_multi::<8, 1>(w, qs, out, stride),
            _ => {}
        }
    }
    let per = elems_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let b = bits as usize;
    for i in 0..per {
        let fv = ((w >> (b * i)) & mask) as f32;
        for (r, &qsr) in qs.iter().enumerate() {
            out[r * stride + i] += qsr * fv;
        }
    }
}

/// SWAR backend of [`dot_word1_multi`].
#[inline(always)]
fn swar_dot_word1_multi<const BITS: usize, const R: usize>(w: u32, qs: &[f32],
                                                           out: &mut [f32],
                                                           stride: usize) {
    let mask = swar_mask(BITS as u8);
    let w = w as u64;
    let mut lanes = [0u64; R];
    for (l, lane) in lanes.iter_mut().enumerate() {
        *lane = (w >> (BITS * l)) & mask;
    }
    for j in 0..4 {
        for (l, &lane) in lanes.iter().enumerate() {
            let fv = ((lane >> (8 * j)) & 0xFF) as f32;
            let slot = j * R + l;
            for (r, &qsr) in qs.iter().enumerate() {
                out[r * stride + slot] += qsr * fv;
            }
        }
    }
}

/// `out[r*stride + t] += qs[r] * field[start+t]` over a word-straddling row.
#[inline]
fn dot_row_unaligned_multi(words: &[u32], bits: u8, start: usize, len: usize,
                           qs: &[f32], out: &mut [f32], stride: usize) {
    let b = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for (w, f0, n) in field_range(words, bits, start, len) {
        for j in 0..n {
            let fv = ((w >> (b * (f0 + j))) & mask) as f32;
            for (r, &qsr) in qs.iter().enumerate() {
                out[r * stride + t + j] += qsr * fv;
            }
        }
        t += n;
    }
}

/// `out[r*stride + t] += qs[r] * field[start+t]` over an Eq. 12 row.
#[inline]
fn eq12_dot_row_multi(words: &[u32], start: usize, tokens: usize, qs: &[f32],
                      out: &mut [f32], stride: usize) {
    let mut wi = start / 11;
    let mut f = start % 11;
    let mut w = words.get(wi).copied().unwrap_or(0);
    for t in 0..tokens {
        let fv = eq12_field(w, f) as f32;
        for (r, &qsr) in qs.iter().enumerate() {
            out[r * stride + t] += qsr * fv;
        }
        f += 1;
        if f == 11 {
            wi += 1;
            f = 0;
            w = words.get(wi).copied().unwrap_or(0);
        }
    }
}

/// `out[r*stride + i] += ps[r] * field[i] + pm[r]` over one group row,
/// skipping heads whose `pt[r] == 0.0`.
#[allow(clippy::too_many_arguments)]
#[inline]
fn accum_row_multi(row_words: &[u32], bits: u8, pt: &[f32], ps: &[f32], pm: &[f32],
                   out: &mut [f32], stride: usize, swar: bool) {
    if swar {
        #[cfg(feature = "simd")]
        if simd::accum_row_multi(row_words, bits, pt, ps, pm, out, stride) {
            return;
        }
        match bits {
            1 => return swar_accum_words_multi::<1, 8>(row_words, pt, ps, pm, out, stride),
            2 => return swar_accum_words_multi::<2, 4>(row_words, pt, ps, pm, out, stride),
            4 => return swar_accum_words_multi::<4, 2>(row_words, pt, ps, pm, out, stride),
            8 => return swar_accum_words_multi::<8, 1>(row_words, pt, ps, pm, out, stride),
            _ => {}
        }
    }
    let per = elems_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let b = bits as usize;
    for (wi, w) in row_words.iter().enumerate() {
        let c0 = wi * per;
        for i in 0..per {
            let fv = ((w >> (b * i)) & mask) as f32;
            for r in 0..pt.len() {
                if pt[r] == 0.0 {
                    continue;
                }
                out[r * stride + c0 + i] += ps[r] * fv + pm[r];
            }
        }
    }
}

/// SWAR backend of [`accum_row_multi`].
#[inline(always)]
fn swar_accum_words_multi<const BITS: usize, const R: usize>(
    words: &[u32], pt: &[f32], ps: &[f32], pm: &[f32], out: &mut [f32], stride: usize) {
    debug_assert_eq!(BITS * R, 8);
    let mask = swar_mask(BITS as u8);
    let per = 32 / BITS;
    for (wi, &word) in words.iter().enumerate() {
        let c0 = wi * per;
        let w = word as u64;
        let mut lanes = [0u64; R];
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane = (w >> (BITS * l)) & mask;
        }
        for j in 0..4 {
            for (l, &lane) in lanes.iter().enumerate() {
                let fv = ((lane >> (8 * j)) & 0xFF) as f32;
                let slot = c0 + j * R + l;
                for r in 0..pt.len() {
                    if pt[r] == 0.0 {
                        continue;
                    }
                    out[r * stride + slot] += ps[r] * fv + pm[r];
                }
            }
        }
    }
}

/// Eq. 12 backend of the multi-head value row.
#[allow(clippy::too_many_arguments)]
#[inline]
fn eq12_accum_row_multi(words: &[u32], start: usize, len: usize, pt: &[f32],
                        ps: &[f32], pm: &[f32], out: &mut [f32], stride: usize) {
    let mut wi = start / 11;
    let mut f = start % 11;
    let mut w = words.get(wi).copied().unwrap_or(0);
    for i in 0..len {
        let fv = eq12_field(w, f) as f32;
        for r in 0..pt.len() {
            if pt[r] == 0.0 {
                continue;
            }
            out[r * stride + i] += ps[r] * fv + pm[r];
        }
        f += 1;
        if f == 11 {
            wi += 1;
            f = 0;
            w = words.get(wi).copied().unwrap_or(0);
        }
    }
}

/// Word-straddling backend of the multi-head value row.
#[allow(clippy::too_many_arguments)]
#[inline]
fn accum_row_unaligned_multi(words: &[u32], bits: u8, start: usize, len: usize,
                             pt: &[f32], ps: &[f32], pm: &[f32], out: &mut [f32],
                             stride: usize) {
    let b = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for (w, f0, n) in field_range(words, bits, start, len) {
        for j in 0..n {
            let fv = ((w >> (b * (f0 + j))) & mask) as f32;
            for r in 0..pt.len() {
                if pt[r] == 0.0 {
                    continue;
                }
                out[r * stride + t + j] += ps[r] * fv + pm[r];
            }
        }
        t += n;
    }
}

// ---------------------------------------------------------------------------
// Width dispatch
// ---------------------------------------------------------------------------

/// Width-dispatching key kernel: integer-domain packed path for every
/// ladder width, unpack-based fused fallback for irregular widths.  Same
/// contract as [`key_scores_fused`]; `scratch` is only touched on the
/// fallback.
#[inline]
pub fn key_scores_dispatch(q: &[f32], block: &PackedBlock, tokens: usize,
                           chan_offset: usize, scratch: &mut FusedScratch,
                           out: &mut [f32]) {
    if packed_dot_supported(block.bits) {
        key_scores_packed(q, block, tokens, chan_offset, out);
    } else {
        key_scores_fused(q, block, tokens, chan_offset, scratch, out);
    }
}

/// Width-dispatching value kernel — see [`key_scores_dispatch`].
#[inline]
pub fn value_accum_dispatch(p: &[f32], block: &PackedBlock, kv_dim: usize,
                            chan_offset: usize, head_dim: usize,
                            scratch: &mut FusedScratch, out: &mut [f32]) {
    if packed_dot_supported(block.bits) {
        value_accum_packed(p, block, kv_dim, chan_offset, head_dim, out);
    } else {
        value_accum_fused(p, block, kv_dim, chan_offset, head_dim, scratch, out);
    }
}

/// Head-tiled width-dispatching key kernel (the attend hot path): packed
/// widths go through [`key_scores_group_packed`]; anything else falls
/// back to per-head [`key_scores_fused`] calls.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn key_scores_group_dispatch(q: &[f32], rep: usize, block: &PackedBlock,
                                 tokens: usize, chan_offset: usize,
                                 scratch: &mut FusedScratch, out: &mut [f32],
                                 stride: usize, tile: &mut TileScratch) {
    if packed_dot_supported(block.bits) {
        key_scores_group_packed(q, rep, block, tokens, chan_offset, out, stride, tile);
    } else {
        let hd = q.len() / rep;
        for r in 0..rep {
            key_scores_fused(&q[r * hd..(r + 1) * hd], block, tokens, chan_offset,
                             scratch, &mut out[r * stride..r * stride + tokens]);
        }
    }
}

/// Head-tiled width-dispatching value kernel — see
/// [`key_scores_group_dispatch`].
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn value_accum_group_dispatch(p: &[f32], p_stride: usize, rep: usize,
                                  block: &PackedBlock, kv_dim: usize,
                                  chan_offset: usize, head_dim: usize,
                                  scratch: &mut FusedScratch, out: &mut [f32],
                                  tile: &mut TileScratch) {
    if packed_dot_supported(block.bits) {
        value_accum_group_packed(p, p_stride, rep, block, kv_dim, chan_offset,
                                 head_dim, out, tile);
    } else {
        let tokens = block.n / kv_dim;
        for r in 0..rep {
            value_accum_fused(&p[r * p_stride..r * p_stride + tokens], block, kv_dim,
                              chan_offset, head_dim, scratch,
                              &mut out[r * head_dim..(r + 1) * head_dim]);
        }
    }
}

/// `std::simd` lanes for the aligned word rows (`--features simd`,
/// nightly only — `portable_simd`).  Each packed word's fields are
/// extracted with a per-lane shift/mask into a `u32` vector, cast to
/// f32 lanes, and multiply-added into the accumulator slice.  Lane
/// arithmetic is plain mul-then-add (no FMA contraction), so every lane
/// computes exactly the scalar path's `acc + qs*field` — the feature
/// changes wall time, never results (DESIGN.md §Quantized-Kernels).
#[cfg(feature = "simd")]
mod simd {
    use std::simd::prelude::*;
    use std::simd::{LaneCount, SupportedLaneCount};

    #[inline]
    fn dot_word<const N: usize>(w: u32, bits: u32, qs: f32, out: &mut [f32])
    where
        LaneCount<N>: SupportedLaneCount,
    {
        let shifts = Simd::<u32, N>::from_array(std::array::from_fn(|i| i as u32 * bits));
        let mask = Simd::splat((1u32 << bits) - 1);
        let f = ((Simd::splat(w) >> shifts) & mask).cast::<f32>();
        let acc = Simd::<f32, N>::from_slice(out) + Simd::splat(qs) * f;
        acc.copy_to_slice(out);
    }

    #[inline]
    fn accum_word<const N: usize>(w: u32, bits: u32, ps: f32, pm: f32, out: &mut [f32])
    where
        LaneCount<N>: SupportedLaneCount,
    {
        let shifts = Simd::<u32, N>::from_array(std::array::from_fn(|i| i as u32 * bits));
        let mask = Simd::splat((1u32 << bits) - 1);
        let f = ((Simd::splat(w) >> shifts) & mask).cast::<f32>();
        let acc = Simd::<f32, N>::from_slice(out) + (Simd::splat(ps) * f + Simd::splat(pm));
        acc.copy_to_slice(out);
    }

    /// Returns false when no lane count fits this width (caller falls
    /// back to the SWAR/scalar word loop).
    pub fn dot_row(row_words: &[u32], bits: u8, qs: f32, out: &mut [f32]) -> bool {
        macro_rules! rows {
            ($n:literal) => {
                for (i, &w) in row_words.iter().enumerate() {
                    dot_word::<$n>(w, bits as u32, qs, &mut out[i * $n..(i + 1) * $n]);
                }
            };
        }
        match 32 / bits as usize {
            32 => rows!(32),
            16 => rows!(16),
            8 => rows!(8),
            4 => rows!(4),
            _ => return false,
        }
        true
    }

    pub fn accum_row(row_words: &[u32], bits: u8, ps: f32, pm: f32, out: &mut [f32]) -> bool {
        macro_rules! rows {
            ($n:literal) => {
                for (i, &w) in row_words.iter().enumerate() {
                    accum_word::<$n>(w, bits as u32, ps, pm, &mut out[i * $n..(i + 1) * $n]);
                }
            };
        }
        if out.len() % (32 / bits as usize) != 0 {
            return false; // group narrower than a word: scalar handles it
        }
        match 32 / bits as usize {
            32 => rows!(32),
            16 => rows!(16),
            8 => rows!(8),
            4 => rows!(4),
            _ => return false,
        }
        true
    }

    /// Head-tiled form: decode each word's lanes once, multiply-add into
    /// every head row of the tile.
    pub fn dot_row_multi(row_words: &[u32], bits: u8, qs: &[f32], out: &mut [f32],
                         stride: usize) -> bool {
        macro_rules! rows {
            ($n:literal) => {
                for (i, &w) in row_words.iter().enumerate() {
                    let shifts = Simd::<u32, $n>::from_array(
                        std::array::from_fn(|k| k as u32 * bits as u32));
                    let mask = Simd::splat((1u32 << bits) - 1);
                    let f = ((Simd::splat(w) >> shifts) & mask).cast::<f32>();
                    for (r, &qsr) in qs.iter().enumerate() {
                        let o = &mut out[r * stride + i * $n..r * stride + (i + 1) * $n];
                        let acc = Simd::<f32, $n>::from_slice(o) + Simd::splat(qsr) * f;
                        acc.copy_to_slice(o);
                    }
                }
            };
        }
        match 32 / bits as usize {
            32 => rows!(32),
            16 => rows!(16),
            8 => rows!(8),
            4 => rows!(4),
            _ => return false,
        }
        true
    }

    /// Head-tiled value form, preserving per-head `p == 0` skips.
    pub fn accum_row_multi(row_words: &[u32], bits: u8, pt: &[f32], ps: &[f32],
                           pm: &[f32], out: &mut [f32], stride: usize) -> bool {
        macro_rules! rows {
            ($n:literal) => {
                for (i, &w) in row_words.iter().enumerate() {
                    let shifts = Simd::<u32, $n>::from_array(
                        std::array::from_fn(|k| k as u32 * bits as u32));
                    let mask = Simd::splat((1u32 << bits) - 1);
                    let f = ((Simd::splat(w) >> shifts) & mask).cast::<f32>();
                    for r in 0..pt.len() {
                        if pt[r] == 0.0 {
                            continue;
                        }
                        let o = &mut out[r * stride + i * $n..r * stride + (i + 1) * $n];
                        let acc = Simd::<f32, $n>::from_slice(o)
                            + (Simd::splat(ps[r]) * f + Simd::splat(pm[r]));
                        acc.copy_to_slice(o);
                    }
                }
            };
        }
        match 32 / bits as usize {
            32 => rows!(32),
            16 => rows!(16),
            8 => rows!(8),
            4 => rows!(4),
            _ => return false,
        }
        true
    }
}

// ---------------------------------------------------------------------------
// Fused (unpack-based) reference kernels — the irregular-width escape
// hatch and the oracle the packed kernels are pinned against
// ---------------------------------------------------------------------------

/// Attention scores of one query head against a **Key block**, via the
/// unpack-based fused path (see module docs for when this runs).
///
/// * `q` — the query slice for this KV head (`head_dim` f32s, RoPE'd).
/// * `block` — channel-major Key block: stream index `c*tokens + t`,
///   channels are the *full* kv_dim; `chan_offset` selects this head's
///   `head_dim` channels.
/// * `tokens` — tokens in the block (= the per-channel group size).
/// * `out[t] +=` raw (unscaled) dot products — caller applies 1/sqrt(hd).
pub fn key_scores_fused(q: &[f32], block: &PackedBlock, tokens: usize,
                        chan_offset: usize, scratch: &mut FusedScratch,
                        out: &mut [f32]) {
    debug_assert_eq!(block.group, tokens);
    debug_assert!(out.len() >= tokens);
    debug_assert!(chan_offset + q.len() <= block.scales.len());
    debug_assert_outliers_sorted(block);
    // Unpack just once per (block); callers iterating heads pass the same
    // scratch so `ensure_unpacked` skips redundant work.
    ensure_unpacked(block, scratch);
    let ints = &scratch.ints;

    let mut bias = 0f32;
    for (d, &qd) in q.iter().enumerate() {
        let c = chan_offset + d;
        let s = block.scales[c];
        let m = block.mins[c];
        let qs = qd * s;
        bias += qd * m;
        let row = &ints[c * tokens..c * tokens + tokens];
        for t in 0..tokens {
            out[t] += qs * row[t] as f32;
        }
    }
    for t in 0..tokens {
        out[t] += bias;
    }
    // outlier corrections (KVQuant baseline): exact value replaces the
    // packed approximation for its (channel, token) element; the head's
    // channels are a contiguous stream range in the index-sorted list
    let lo = block.outliers.partition_point(|&(i, _)| (i as usize) < chan_offset * tokens);
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < (chan_offset + q.len()) * tokens);
    for &(i, v) in &block.outliers[lo..hi] {
        let c = i as usize / tokens;
        let t = i as usize % tokens;
        out[t] += q[c - chan_offset] * (v - block.dequant_one(i as usize, ints));
    }
}

/// Weighted-value accumulation of one head's probabilities against a
/// **Value block**, via the unpack-based fused path.
///
/// * `p[t]` — softmax probabilities for this block's tokens.
/// * `block` — token-major Value block: stream index `t*kv_dim + c`,
///   groups of `block.group` consecutive channels per token.
/// * `kv_dim` — full channel count per token; `chan_offset` selects this
///   head's `head_dim` channels (must be group-aligned).
/// * `out[d] +=` accumulated weighted values for d in 0..head_dim.
pub fn value_accum_fused(p: &[f32], block: &PackedBlock, kv_dim: usize,
                         chan_offset: usize, head_dim: usize,
                         scratch: &mut FusedScratch, out: &mut [f32]) {
    debug_assert_eq!(chan_offset % block.group, 0);
    debug_assert_eq!(head_dim % block.group, 0);
    debug_assert!(chan_offset + head_dim <= kv_dim);
    debug_assert!((chan_offset + head_dim).div_ceil(block.group) <= block.scales.len());
    debug_assert_outliers_sorted(block);
    ensure_unpacked(block, scratch);
    let ints = &scratch.ints;
    let tokens = block.n / kv_dim;
    let groups_per_token = kv_dim / block.group;
    let g0 = chan_offset / block.group;
    let gn = head_dim / block.group;

    for (t, &pt) in p.iter().enumerate().take(tokens) {
        if pt == 0.0 {
            continue;
        }
        let base = t * kv_dim + chan_offset;
        let row = &ints[base..base + head_dim];
        for g in 0..gn {
            let gi = t * groups_per_token + g0 + g;
            let ps = pt * block.scales[gi];
            let pm = pt * block.mins[gi];
            let o = &mut out[g * block.group..(g + 1) * block.group];
            let r = &row[g * block.group..(g + 1) * block.group];
            for i in 0..block.group {
                o[i] += ps * r[i] as f32 + pm;
            }
        }
    }
    // outlier corrections for this head's channel range, bounded to the
    // tokens `p` covers via the index-sorted invariant
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < p.len().min(tokens) * kv_dim);
    for &(i, v) in &block.outliers[..hi] {
        let t = i as usize / kv_dim;
        let c = i as usize % kv_dim;
        if c >= chan_offset && c < chan_offset + head_dim && p[t] != 0.0 {
            out[c - chan_offset] += p[t] * (v - block.dequant_one(i as usize, ints));
        }
    }
}

/// Unpack the block's integer stream into `scratch.ints`, skipping if the
/// scratch already holds this block's data (tagged by the block uid).
/// Layout-aware via [`PackedBlock::unpack_into`].
fn ensure_unpacked(block: &PackedBlock, scratch: &mut FusedScratch) {
    if block.uid != 0 && scratch.tag == block.uid && scratch.ints.len() >= block.n {
        return;
    }
    scratch.ints.resize(block.n, 0);
    block.unpack_into(&mut scratch.ints);
    scratch.tag = block.uid;
}

/// Reference (unfused) implementations for tests/benches: dequantize the
/// whole block to f32, then plain matvec.
pub mod unfused {
    use super::*;

    pub fn key_scores(q: &[f32], block: &PackedBlock, tokens: usize,
                      chan_offset: usize, scratch: &mut FusedScratch,
                      out: &mut [f32]) {
        scratch.f32s.resize(block.n, 0.0);
        let mut ints = std::mem::take(&mut scratch.ints);
        block.dequantize_into(&mut scratch.f32s, &mut ints);
        scratch.ints = ints;
        scratch.invalidate(); // ints no longer matches the cached tag
        for (d, &qd) in q.iter().enumerate() {
            let c = chan_offset + d;
            for t in 0..tokens {
                out[t] += qd * scratch.f32s[c * tokens + t];
            }
        }
    }

    pub fn value_accum(p: &[f32], block: &PackedBlock, kv_dim: usize,
                       chan_offset: usize, head_dim: usize,
                       scratch: &mut FusedScratch, out: &mut [f32]) {
        scratch.f32s.resize(block.n, 0.0);
        let mut ints = std::mem::take(&mut scratch.ints);
        block.dequantize_into(&mut scratch.f32s, &mut ints);
        scratch.ints = ints;
        scratch.invalidate();
        let tokens = block.n / kv_dim;
        for (t, &pt) in p.iter().enumerate().take(tokens) {
            for d in 0..head_dim {
                out[d] += pt * scratch.f32s[t * kv_dim + chan_offset + d];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const HD: usize = 16;

    /// Channel-major Key block: kv_dim channels × `tokens` tokens,
    /// group == tokens (the per-channel layout).
    fn key_block(bits: u8, kv_dim: usize, tokens: usize, frac: f64, seed: u64)
                 -> PackedBlock {
        let mut rng = Rng::new(seed);
        let data = rng.normal_vec(kv_dim * tokens);
        let mut b = PackedBlock::default();
        b.quantize_outliers_into(&data, bits, tokens, frac, &mut Vec::new());
        b
    }

    /// Token-major Value block: `tokens` tokens × kv_dim channels,
    /// channel groups of 32.
    fn value_block(bits: u8, kv_dim: usize, tokens: usize, frac: f64, seed: u64)
                   -> PackedBlock {
        let mut rng = Rng::new(seed);
        let data = rng.normal_vec(kv_dim * tokens);
        let mut b = PackedBlock::default();
        b.quantize_outliers_into(&data, bits, 32, frac, &mut Vec::new());
        b
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: slot {i}: {x} vs {y}");
        }
    }

    #[test]
    fn fused_key_matches_unfused() {
        let mut rng = Rng::new(10);
        let tokens = 32;
        let block = key_block(4, 2 * HD, tokens, 0.0, 11);
        let q = rng.normal_vec(HD);
        let mut s = FusedScratch::default();
        let mut fused = vec![0f32; tokens];
        let mut plain = vec![0f32; tokens];
        key_scores_fused(&q, &block, tokens, HD, &mut s, &mut fused);
        unfused::key_scores(&q, &block, tokens, HD, &mut s, &mut plain);
        for (a, b) in fused.iter().zip(&plain) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn fused_value_matches_unfused() {
        let mut rng = Rng::new(12);
        let tokens = 32;
        let block = value_block(4, 2 * HD, tokens, 0.0, 13);
        let p: Vec<f32> = (0..tokens).map(|_| rng.uniform(0.0, 0.1) as f32).collect();
        let mut s = FusedScratch::default();
        let mut fused = vec![0f32; HD];
        let mut plain = vec![0f32; HD];
        value_accum_fused(&p, &block, 2 * HD, HD, HD, &mut s, &mut fused);
        unfused::value_accum(&p, &block, 2 * HD, HD, HD, &mut s, &mut plain);
        for (a, b) in fused.iter().zip(&plain) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn packed_key_matches_fused_bitwise() {
        let mut rng = Rng::new(14);
        for bits in [1u8, 2, 3, 4, 8] {
            for tokens in [32usize, 33, 40] {
                let block = key_block(bits, 2 * HD, tokens, 0.02, 15 + bits as u64);
                let q = rng.normal_vec(HD);
                let mut s = FusedScratch::default();
                let mut packed = vec![0f32; tokens];
                let mut fused = vec![0f32; tokens];
                key_scores_packed(&q, &block, tokens, HD, &mut packed);
                key_scores_fused(&q, &block, tokens, HD, &mut s, &mut fused);
                assert_bits_eq(&packed, &fused, &format!("key bits={bits} tokens={tokens}"));
            }
        }
    }

    #[test]
    fn packed_value_matches_fused_bitwise() {
        let mut rng = Rng::new(16);
        for bits in [1u8, 2, 3, 4, 8] {
            let tokens = 40;
            let block = value_block(bits, 2 * HD, tokens, 0.02, 17 + bits as u64);
            let mut p: Vec<f32> = (0..tokens).map(|_| rng.uniform(0.0, 0.1) as f32).collect();
            p[3] = 0.0; // exercise the zero-probability skip
            let mut s = FusedScratch::default();
            let mut packed = vec![0f32; HD];
            let mut fused = vec![0f32; HD];
            value_accum_packed(&p, &block, 2 * HD, HD, HD, &mut packed);
            value_accum_fused(&p, &block, 2 * HD, HD, HD, &mut s, &mut fused);
            assert_bits_eq(&packed, &fused, &format!("value bits={bits}"));
        }
    }

    #[test]
    fn swar_matches_word_scalar_reference() {
        // the stable three-way wall's in-module leg: SWAR lanes vs the
        // per-field word-scalar traversal, bit for bit
        let mut rng = Rng::new(18);
        for bits in [1u8, 2, 3, 4, 8] {
            for tokens in [32usize, 64] {
                let kb = key_block(bits, 2 * HD, tokens, 0.02, 19 + bits as u64);
                let q = rng.normal_vec(HD);
                let mut a = vec![0f32; tokens];
                let mut b = vec![0f32; tokens];
                key_scores_packed(&q, &kb, tokens, HD, &mut a);
                key_scores_packed_ref(&q, &kb, tokens, HD, &mut b);
                assert_bits_eq(&a, &b, &format!("key swar-vs-ref bits={bits}"));

                let vb = value_block(bits, 2 * HD, tokens, 0.02, 20 + bits as u64);
                let p: Vec<f32> = (0..tokens).map(|_| rng.uniform(0.0, 0.1) as f32).collect();
                let mut va = vec![0f32; HD];
                let mut vr = vec![0f32; HD];
                value_accum_packed(&p, &vb, 2 * HD, HD, HD, &mut va);
                value_accum_packed_ref(&p, &vb, 2 * HD, HD, HD, &mut vr);
                assert_bits_eq(&va, &vr, &format!("value swar-vs-ref bits={bits}"));
            }
        }
    }

    #[test]
    fn group_kernels_match_per_head_bitwise() {
        // head tiling is a pure reordering of independent slots: the
        // tiled kernels must equal `rep` single-head calls bit for bit
        let mut rng = Rng::new(22);
        for bits in [1u8, 2, 3, 4, 8] {
            for rep in [1usize, 2, 4] {
                let tokens = 32;
                let stride = tokens + 5; // strided rows like the scores buffer
                let kb = key_block(bits, 2 * HD, tokens, 0.02, 23 + bits as u64);
                let q = rng.normal_vec(rep * HD);
                let mut tile = TileScratch::default();
                let mut tiled = vec![0f32; (rep - 1) * stride + tokens];
                let mut per_head = vec![0f32; (rep - 1) * stride + tokens];
                key_scores_group_packed(&q, rep, &kb, tokens, HD, &mut tiled, stride,
                                        &mut tile);
                for r in 0..rep {
                    key_scores_packed(&q[r * HD..(r + 1) * HD], &kb, tokens, HD,
                                      &mut per_head[r * stride..r * stride + tokens]);
                }
                assert_bits_eq(&tiled, &per_head,
                               &format!("key group bits={bits} rep={rep}"));

                let vb = value_block(bits, 2 * HD, tokens, 0.02, 24 + bits as u64);
                let mut p: Vec<f32> =
                    (0..rep * stride).map(|_| rng.uniform(0.0, 0.1) as f32).collect();
                p[1] = 0.0; // per-head zero-skip must survive tiling
                let mut tv = vec![0f32; rep * HD];
                let mut pv = vec![0f32; rep * HD];
                value_accum_group_packed(&p, stride, rep, &vb, 2 * HD, HD, HD, &mut tv,
                                         &mut tile);
                for r in 0..rep {
                    value_accum_packed(&p[r * stride..r * stride + tokens], &vb, 2 * HD,
                                       HD, HD, &mut pv[r * HD..(r + 1) * HD]);
                }
                assert_bits_eq(&tv, &pv, &format!("value group bits={bits} rep={rep}"));
            }
        }
    }

    #[test]
    fn group_ref_matches_group_packed() {
        let mut rng = Rng::new(26);
        for bits in [2u8, 4] {
            let (tokens, rep) = (32, 4);
            let kb = key_block(bits, 2 * HD, tokens, 0.02, 27 + bits as u64);
            let q = rng.normal_vec(rep * HD);
            let mut tile = TileScratch::default();
            let mut a = vec![0f32; rep * tokens];
            let mut b = vec![0f32; rep * tokens];
            key_scores_group_packed(&q, rep, &kb, tokens, HD, &mut a, tokens, &mut tile);
            key_scores_group_ref(&q, rep, &kb, tokens, HD, &mut b, tokens, &mut tile);
            assert_bits_eq(&a, &b, &format!("group ref bits={bits}"));
        }
    }

    #[test]
    fn interleaved_key_matches_linear_bitwise() {
        let mut rng = Rng::new(28);
        for bits in [1u8, 2, 4, 8] {
            let tokens = 64;
            let mut data_rng = Rng::new(29 + bits as u64);
            let data = data_rng.normal_vec(2 * HD * tokens);
            let mut lin = PackedBlock::default();
            lin.quantize_outliers_into_layout(&data, bits, tokens, 0.02, false,
                                              &mut Vec::new());
            let mut inter = PackedBlock::default();
            inter.quantize_outliers_into_layout(&data, bits, tokens, 0.02, true,
                                                &mut Vec::new());
            assert!(inter.interleaved);
            let q = rng.normal_vec(2 * HD);
            for rep in [1usize, 2] {
                let hd = 2 * HD / rep;
                let mut tile = TileScratch::default();
                let mut a = vec![0f32; rep * tokens];
                let mut b = vec![0f32; rep * tokens];
                key_scores_group_packed(&q, rep, &lin, tokens, 0, &mut a, tokens, &mut tile);
                key_scores_group_packed(&q, rep, &inter, tokens, 0, &mut b, tokens,
                                        &mut tile);
                assert_bits_eq(&a, &b, &format!("interleave bits={bits} rep={rep} hd={hd}"));
            }
            let mut sa = vec![0f32; tokens];
            let mut sb = vec![0f32; tokens];
            key_scores_packed(&q[..HD], &lin, tokens, HD, &mut sa);
            key_scores_packed(&q[..HD], &inter, tokens, HD, &mut sb);
            assert_bits_eq(&sa, &sb, &format!("interleave single-head bits={bits}"));
        }
    }

    #[test]
    fn dispatch_runs_3bit_packed() {
        // Eq. 12 joined the packed tier: dispatch must not touch the
        // unpack scratch for any ladder width, 3-bit included
        assert!(packed_dot_supported(3));
        let mut rng = Rng::new(30);
        let tokens = 33; // 3 Eq.12 words per channel row
        let block = key_block(3, 2 * HD, tokens, 0.02, 31);
        let q = rng.normal_vec(HD);
        let mut s = FusedScratch::default();
        let mut via_dispatch = vec![0f32; tokens];
        key_scores_dispatch(&q, &block, tokens, HD, &mut s, &mut via_dispatch);
        assert!(s.ints.is_empty(), "3-bit dispatch must stay unpack-free");
        let mut fused = vec![0f32; tokens];
        key_scores_fused(&q, &block, tokens, HD, &mut s, &mut fused);
        assert_bits_eq(&via_dispatch, &fused, "3-bit dispatch");
    }

    #[test]
    fn unpack_cache_tracks_inplace_requantization() {
        // requantize() rewrites words in place and bumps the uid; a stale
        // unpack must never be reused
        let mut rng = Rng::new(32);
        let tokens = 32;
        let mut block = key_block(8, 2 * HD, tokens, 0.0, 33);
        let q = rng.normal_vec(HD);
        let mut s = FusedScratch::default();
        let mut before = vec![0f32; tokens];
        key_scores_fused(&q, &block, tokens, HD, &mut s, &mut before);
        block.requantize(2, &mut Vec::new(), &mut Vec::new());
        let mut stale = vec![0f32; tokens];
        key_scores_fused(&q, &block, tokens, HD, &mut s, &mut stale);
        let mut fresh = vec![0f32; tokens];
        key_scores_fused(&q, &block, tokens, HD, &mut FusedScratch::default(), &mut fresh);
        assert_bits_eq(&stale, &fresh, "uid cache");
        assert_ne!(before, stale, "requantization must change results");
    }

    #[test]
    fn fused_key_accumulates() {
        // += contract: callers accumulate scores across cache blocks
        let mut rng = Rng::new(34);
        let tokens = 32;
        let block = key_block(4, HD, tokens, 0.0, 35);
        let q = rng.normal_vec(HD);
        let mut s = FusedScratch::default();
        let mut out = vec![1.0f32; tokens];
        let mut delta = vec![0f32; tokens];
        key_scores_fused(&q, &block, tokens, 0, &mut s, &mut out);
        key_scores_fused(&q, &block, tokens, 0, &mut s, &mut delta);
        for (o, d) in out.iter().zip(&delta) {
            assert!((o - (1.0 + d)).abs() < 1e-5);
        }
    }

    #[test]
    fn packed_outlier_side_path_is_binary_searched_range() {
        // heavy outlier block + nonzero chan_offset: the packed side path
        // must apply exactly the fused path's corrections
        let mut rng = Rng::new(36);
        let tokens = 32;
        let block = key_block(2, 4 * HD, tokens, 0.1, 37);
        assert!(!block.outliers.is_empty());
        let q = rng.normal_vec(HD);
        let mut s = FusedScratch::default();
        let mut packed = vec![0f32; tokens];
        let mut fused = vec![0f32; tokens];
        key_scores_packed(&q, &block, tokens, 2 * HD, &mut packed);
        key_scores_fused(&q, &block, tokens, 2 * HD, &mut s, &mut fused);
        assert_bits_eq(&packed, &fused, "outlier side path");
    }
}
