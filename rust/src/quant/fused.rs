//! Decode-attention kernels over packed KV blocks — the Rust analog of
//! the paper's CUDA contribution (§CUDA Implementation ②③), in two tiers
//! (DESIGN.md §Quantized-Kernels):
//!
//! * **Packed (integer-domain, unpack-free)** — [`key_scores_packed`] /
//!   [`value_accum_packed`]: dot products computed directly on the packed
//!   `u32` words for uniform widths (1/2/4/8-bit).  One word at a time,
//!   `elems_per_word` fields are extracted with shift/mask — into
//!   `std::simd` lanes behind the `simd` cargo feature, or a
//!   word-at-a-time scalar loop otherwise — and each group's affine
//!   `(scale, min)` is folded into the accumulator once per group.  No
//!   `u32` scratch is ever materialized; outliers are applied through
//!   [`PackedBlock::dequant_at`] on a binary-searched sparse side path.
//!
//! * **Fused (unpack-based reference)** — [`key_scores_fused`] /
//!   [`value_accum_fused`]: unpack the block's integer stream into a
//!   reusable scratch, then fold the dequantization into the dot products
//!   algebraically.  This is the execution path for 3-bit blocks (the
//!   11-per-word Eq. 12 layout has no aligned word view) and the oracle
//!   the packed kernels are pinned bit-exact against
//!   (`rust/tests/packed_kernels.rs`).
//!
//! Both tiers share the same algebra:
//!
//!   Key  (per-channel groups): score[t] = Σ_c q[c]·(Q[c,t]·s_c + m_c)
//!        = Σ_c (q[c]·s_c)·Q[c,t]  +  Σ_c q[c]·m_c
//!     — the bias term is token-independent and hoisted out of the loop;
//!       the weighted sum runs channel-outer/token-inner so the inner loop
//!       is a contiguous fused-multiply-add over the block's tokens.
//!
//!   Value (per-token groups):  out[c] += Σ_t p[t]·(Q[t,c]·s_{t,g} + m_{t,g})
//!        = Σ_t (p[t]·s_{t,g})·Q[t,c]  +  bias_g(c∈g)
//!     — token-outer/channel-inner, again contiguous in the stream.
//!
//! [`key_scores_dispatch`] / [`value_accum_dispatch`] pick the tier per
//! block width; `kvcache/cache.rs::attend` routes through them, so the
//! per-thread unpack scratch only ever fills for 3-bit blocks.

use super::groupq::PackedBlock;
use super::pack::{elems_per_word, field_range, unpack_stream};

/// True if `bits` has the word-aligned uniform field layout the packed
/// (unpack-free) kernels handle.  3-bit's 11-per-word layout stays on the
/// unpack-based fused path (DESIGN.md §Quantized-Kernels).
#[inline]
pub const fn packed_dot_supported(bits: u8) -> bool {
    bits != 0 && bits != 3 && bits <= 16 && 32 % bits as usize == 0
}

/// Reusable scratch buffers for the unpack-based fused kernels (one per
/// worker thread: the decode fan-out carries a `FusedScratch` inside each
/// worker's `AttnScratch`, never sharing one across threads).  The packed
/// kernels take no scratch at all, so on plans without 3-bit layers the
/// `ints` buffer never allocates.
///
/// The unpack-cache `tag` stores the [`PackedBlock::uid`] of the block
/// currently staged in `ints`.  The uid is refreshed on every
/// (re)quantization, so a pressure-controller downshift that rewrites a
/// block in place — or a new block whose buffers reuse a freed
/// allocation — can never match a stale unpack.  Prefix sharing
/// (DESIGN.md §Prefix-Sharing) preserves the invariant from the other
/// direction: a shared block's `Arc` clones keep its uid, and a
/// copy-on-write clone is always requantized (fresh uid) before anyone
/// reads it — identical uid therefore always means identical bytes,
/// even across lanes that share a block.  The cache only elides
/// re-unpacking, never changes results.
#[derive(Default)]
pub struct FusedScratch {
    pub ints: Vec<u32>,
    pub f32s: Vec<f32>,
    /// uid of the block currently unpacked in `ints` (0 = none) — lets
    /// per-head loops skip redundant unpacks
    tag: u64,
}

impl FusedScratch {
    /// Invalidate the unpack cache (call if `ints` is clobbered by hand).
    pub fn invalidate(&mut self) {
        self.tag = 0;
    }
}

/// Sorted-outlier invariant the binary-searched side paths rely on
/// (established by `PackedBlock::quantize_outliers_into`).
#[inline]
fn debug_assert_outliers_sorted(block: &PackedBlock) {
    debug_assert!(block.outliers.windows(2).all(|w| w[0].0 < w[1].0),
                  "outliers must be sorted by stream index");
}

// ---------------------------------------------------------------------------
// Packed (integer-domain, unpack-free) kernels
// ---------------------------------------------------------------------------

/// Attention scores of one query head against a **Key block**, computed
/// directly on the packed words — no unpacked stream is ever
/// materialized.  Bit-exact with [`key_scores_fused`] (pinned by
/// `rust/tests/packed_kernels.rs`).
///
/// * `q` — the query slice for this KV head (`head_dim` f32s, RoPE'd).
/// * `block` — channel-major Key block (stream index `c*tokens + t`),
///   width must satisfy [`packed_dot_supported`].
/// * `tokens` — tokens in the block (= the per-channel group size).
/// * `out[t] +=` raw (unscaled) dot products — caller applies 1/sqrt(hd).
pub fn key_scores_packed(q: &[f32], block: &PackedBlock, tokens: usize,
                         chan_offset: usize, out: &mut [f32]) {
    debug_assert_eq!(block.group, tokens);
    debug_assert!(out.len() >= tokens);
    debug_assert!(chan_offset + q.len() <= block.scales.len());
    debug_assert!(packed_dot_supported(block.bits));
    debug_assert_outliers_sorted(block);
    let bits = block.bits;
    let per = elems_per_word(bits);
    let out = &mut out[..tokens];

    let mut bias = 0f32;
    if tokens % per == 0 {
        // every channel row starts word-aligned: word-per-lane-group path
        let wpr = tokens / per; // words per row
        for (d, &qd) in q.iter().enumerate() {
            let c = chan_offset + d;
            let qs = qd * block.scales[c];
            bias += qd * block.mins[c];
            dot_row_aligned(&block.words[c * wpr..(c + 1) * wpr], bits, qs, out);
        }
    } else {
        // rows straddle word boundaries: word-at-a-time view
        for (d, &qd) in q.iter().enumerate() {
            let c = chan_offset + d;
            let qs = qd * block.scales[c];
            bias += qd * block.mins[c];
            dot_row_unaligned(&block.words, bits, c * tokens, qs, out);
        }
    }
    for s in out.iter_mut() {
        *s += bias;
    }
    // outlier corrections: the head's channels are the contiguous stream
    // range [chan_offset·tokens, (chan_offset+hd)·tokens), binary-searched
    // in the index-sorted list instead of scanning every outlier per head
    let lo = block.outliers.partition_point(|&(i, _)| (i as usize) < chan_offset * tokens);
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < (chan_offset + q.len()) * tokens);
    for &(i, v) in &block.outliers[lo..hi] {
        let c = i as usize / tokens;
        let t = i as usize % tokens;
        out[t] += q[c - chan_offset] * (v - block.dequant_at(i as usize));
    }
}

/// Weighted-value accumulation of one head's probabilities against a
/// **Value block**, computed directly on the packed words.  Bit-exact
/// with [`value_accum_fused`].
///
/// * `p[t]` — softmax probabilities for this block's tokens.
/// * `block` — token-major Value block (stream index `t*kv_dim + c`),
///   width must satisfy [`packed_dot_supported`].
/// * `kv_dim` — full channel count per token; `chan_offset` selects this
///   head's `head_dim` channels (must be group-aligned).
/// * `out[d] +=` accumulated weighted values for d in 0..head_dim.
pub fn value_accum_packed(p: &[f32], block: &PackedBlock, kv_dim: usize,
                          chan_offset: usize, head_dim: usize, out: &mut [f32]) {
    debug_assert_eq!(chan_offset % block.group, 0);
    debug_assert_eq!(head_dim % block.group, 0);
    debug_assert!(chan_offset + head_dim <= kv_dim);
    debug_assert!((chan_offset + head_dim).div_ceil(block.group) <= block.scales.len());
    debug_assert!(packed_dot_supported(block.bits));
    debug_assert_outliers_sorted(block);
    let bits = block.bits;
    let per = elems_per_word(bits);
    let tokens = block.n / kv_dim;
    let groups_per_token = kv_dim / block.group;
    let g0 = chan_offset / block.group;
    let gn = head_dim / block.group;
    // every token row is word-aligned iff a group spans whole words and
    // token strides land on word boundaries (true for the standard
    // group=32 layouts at 1/2/4/8-bit)
    let aligned = block.group % per == 0 && kv_dim % per == 0 && chan_offset % per == 0;
    let wpg = if aligned { block.group / per } else { 0 }; // words per group

    for (t, &pt) in p.iter().enumerate().take(tokens) {
        if pt == 0.0 {
            continue;
        }
        let base = t * kv_dim + chan_offset;
        for g in 0..gn {
            let gi = t * groups_per_token + g0 + g;
            let ps = pt * block.scales[gi];
            let pm = pt * block.mins[gi];
            let o = &mut out[g * block.group..(g + 1) * block.group];
            let e0 = base + g * block.group;
            if aligned {
                let w0 = e0 / per;
                accum_row_aligned(&block.words[w0..w0 + wpg], bits, ps, pm, o);
            } else {
                accum_row_unaligned(&block.words, bits, e0, ps, pm, o);
            }
        }
    }
    // outlier corrections: index-sorted, so the scan is bounded to the
    // tokens `p` covers; the head's channels are strided per token, so
    // membership stays a predicate inside the bounded range
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < p.len().min(tokens) * kv_dim);
    for &(i, v) in &block.outliers[..hi] {
        let t = i as usize / kv_dim;
        let c = i as usize % kv_dim;
        if c >= chan_offset && c < chan_offset + head_dim && p[t] != 0.0 {
            out[c - chan_offset] += p[t] * (v - block.dequant_at(i as usize));
        }
    }
}

/// Width-dispatching key kernel: integer-domain packed path for uniform
/// widths, unpack-based fused fallback for 3-bit.  Same contract as
/// [`key_scores_fused`]; `scratch` is only touched on the fallback.
#[inline]
pub fn key_scores_dispatch(q: &[f32], block: &PackedBlock, tokens: usize,
                           chan_offset: usize, scratch: &mut FusedScratch,
                           out: &mut [f32]) {
    if packed_dot_supported(block.bits) {
        key_scores_packed(q, block, tokens, chan_offset, out);
    } else {
        key_scores_fused(q, block, tokens, chan_offset, scratch, out);
    }
}

/// Width-dispatching value kernel — see [`key_scores_dispatch`].
#[inline]
pub fn value_accum_dispatch(p: &[f32], block: &PackedBlock, kv_dim: usize,
                            chan_offset: usize, head_dim: usize,
                            scratch: &mut FusedScratch, out: &mut [f32]) {
    if packed_dot_supported(block.bits) {
        value_accum_packed(p, block, kv_dim, chan_offset, head_dim, out);
    } else {
        value_accum_fused(p, block, kv_dim, chan_offset, head_dim, scratch, out);
    }
}

/// `out[i] += qs * field[i]` over one word-aligned row.
#[inline]
fn dot_row_aligned(row_words: &[u32], bits: u8, qs: f32, out: &mut [f32]) {
    #[cfg(feature = "simd")]
    if simd::dot_row(row_words, bits, qs, out) {
        return;
    }
    let per = elems_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let b = bits as usize;
    for (w, o) in row_words.iter().zip(out.chunks_exact_mut(per)) {
        for (i, slot) in o.iter_mut().enumerate() {
            *slot += qs * ((w >> (b * i)) & mask) as f32;
        }
    }
}

/// `out[i] += qs * field[start+i]` over a row that straddles words.
#[inline]
fn dot_row_unaligned(words: &[u32], bits: u8, start: usize, qs: f32, out: &mut [f32]) {
    let b = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for (w, f0, n) in field_range(words, bits, start, out.len()) {
        for (j, slot) in out[t..t + n].iter_mut().enumerate() {
            *slot += qs * ((w >> (b * (f0 + j))) & mask) as f32;
        }
        t += n;
    }
}

/// `out[i] += ps * field[i] + pm` over one word-aligned group row.
#[inline]
fn accum_row_aligned(row_words: &[u32], bits: u8, ps: f32, pm: f32, out: &mut [f32]) {
    #[cfg(feature = "simd")]
    if simd::accum_row(row_words, bits, ps, pm, out) {
        return;
    }
    let per = elems_per_word(bits);
    let mask = (1u32 << bits) - 1;
    let b = bits as usize;
    for (w, o) in row_words.iter().zip(out.chunks_exact_mut(per)) {
        for (i, slot) in o.iter_mut().enumerate() {
            *slot += ps * ((w >> (b * i)) & mask) as f32 + pm;
        }
    }
}

/// `out[i] += ps * field[start+i] + pm` over a word-straddling group row.
#[inline]
fn accum_row_unaligned(words: &[u32], bits: u8, start: usize, ps: f32, pm: f32,
                       out: &mut [f32]) {
    let b = bits as usize;
    let mask = (1u32 << bits) - 1;
    let mut t = 0usize;
    for (w, f0, n) in field_range(words, bits, start, out.len()) {
        for (j, slot) in out[t..t + n].iter_mut().enumerate() {
            *slot += ps * ((w >> (b * (f0 + j))) & mask) as f32 + pm;
        }
        t += n;
    }
}

/// `std::simd` lanes for the aligned word rows (`--features simd`,
/// nightly only — `portable_simd`).  Each packed word's fields are
/// extracted with a per-lane shift/mask into a `u32` vector, cast to
/// f32 lanes, and multiply-added into the accumulator slice.  Lane
/// arithmetic is plain mul-then-add (no FMA contraction), so every lane
/// computes exactly the scalar path's `acc + qs*field` — the feature
/// changes wall time, never results (DESIGN.md §Quantized-Kernels).
#[cfg(feature = "simd")]
mod simd {
    use std::simd::prelude::*;
    use std::simd::{LaneCount, SupportedLaneCount};

    #[inline]
    fn dot_word<const N: usize>(w: u32, bits: u32, qs: f32, out: &mut [f32])
    where
        LaneCount<N>: SupportedLaneCount,
    {
        let shifts = Simd::<u32, N>::from_array(std::array::from_fn(|i| i as u32 * bits));
        let mask = Simd::splat((1u32 << bits) - 1);
        let f = ((Simd::splat(w) >> shifts) & mask).cast::<f32>();
        let acc = Simd::<f32, N>::from_slice(out) + Simd::splat(qs) * f;
        acc.copy_to_slice(out);
    }

    #[inline]
    fn accum_word<const N: usize>(w: u32, bits: u32, ps: f32, pm: f32, out: &mut [f32])
    where
        LaneCount<N>: SupportedLaneCount,
    {
        let shifts = Simd::<u32, N>::from_array(std::array::from_fn(|i| i as u32 * bits));
        let mask = Simd::splat((1u32 << bits) - 1);
        let f = ((Simd::splat(w) >> shifts) & mask).cast::<f32>();
        let acc = Simd::<f32, N>::from_slice(out) + (Simd::splat(ps) * f + Simd::splat(pm));
        acc.copy_to_slice(out);
    }

    /// Returns false when no lane count fits this width (caller falls
    /// back to the scalar word loop).
    pub fn dot_row(row_words: &[u32], bits: u8, qs: f32, out: &mut [f32]) -> bool {
        macro_rules! rows {
            ($n:literal) => {
                for (i, &w) in row_words.iter().enumerate() {
                    dot_word::<$n>(w, bits as u32, qs, &mut out[i * $n..(i + 1) * $n]);
                }
            };
        }
        match 32 / bits as usize {
            32 => rows!(32),
            16 => rows!(16),
            8 => rows!(8),
            4 => rows!(4),
            _ => return false,
        }
        true
    }

    pub fn accum_row(row_words: &[u32], bits: u8, ps: f32, pm: f32, out: &mut [f32]) -> bool {
        macro_rules! rows {
            ($n:literal) => {
                for (i, &w) in row_words.iter().enumerate() {
                    accum_word::<$n>(w, bits as u32, ps, pm, &mut out[i * $n..(i + 1) * $n]);
                }
            };
        }
        if out.len() % (32 / bits as usize) != 0 {
            return false; // group narrower than a word: scalar handles it
        }
        match 32 / bits as usize {
            32 => rows!(32),
            16 => rows!(16),
            8 => rows!(8),
            4 => rows!(4),
            _ => return false,
        }
        true
    }
}

// ---------------------------------------------------------------------------
// Fused (unpack-based) reference kernels — the 3-bit execution path and
// the oracle the packed kernels are pinned against
// ---------------------------------------------------------------------------

/// Attention scores of one query head against a **Key block**, via the
/// unpack-based fused path (see module docs for when this runs).
///
/// * `q` — the query slice for this KV head (`head_dim` f32s, RoPE'd).
/// * `block` — channel-major Key block: stream index `c*tokens + t`,
///   channels are the *full* kv_dim; `chan_offset` selects this head's
///   `head_dim` channels.
/// * `tokens` — tokens in the block (= the per-channel group size).
/// * `out[t] +=` raw (unscaled) dot products — caller applies 1/sqrt(hd).
pub fn key_scores_fused(q: &[f32], block: &PackedBlock, tokens: usize,
                        chan_offset: usize, scratch: &mut FusedScratch,
                        out: &mut [f32]) {
    debug_assert_eq!(block.group, tokens);
    debug_assert!(out.len() >= tokens);
    debug_assert!(chan_offset + q.len() <= block.scales.len());
    debug_assert_outliers_sorted(block);
    // Unpack just once per (block); callers iterating heads pass the same
    // scratch so `ensure_unpacked` skips redundant work.
    ensure_unpacked(block, scratch);
    let ints = &scratch.ints;

    let mut bias = 0f32;
    for (d, &qd) in q.iter().enumerate() {
        let c = chan_offset + d;
        let s = block.scales[c];
        let m = block.mins[c];
        let qs = qd * s;
        bias += qd * m;
        let row = &ints[c * tokens..c * tokens + tokens];
        for t in 0..tokens {
            out[t] += qs * row[t] as f32;
        }
    }
    for t in 0..tokens {
        out[t] += bias;
    }
    // outlier corrections (KVQuant baseline): exact value replaces the
    // packed approximation for its (channel, token) element; the head's
    // channels are a contiguous stream range in the index-sorted list
    let lo = block.outliers.partition_point(|&(i, _)| (i as usize) < chan_offset * tokens);
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < (chan_offset + q.len()) * tokens);
    for &(i, v) in &block.outliers[lo..hi] {
        let c = i as usize / tokens;
        let t = i as usize % tokens;
        out[t] += q[c - chan_offset] * (v - block.dequant_one(i as usize, ints));
    }
}

/// Weighted-value accumulation of one head's probabilities against a
/// **Value block**, via the unpack-based fused path.
///
/// * `p[t]` — softmax probabilities for this block's tokens.
/// * `block` — token-major Value block: stream index `t*kv_dim + c`,
///   groups of `block.group` consecutive channels per token.
/// * `kv_dim` — full channel count per token; `chan_offset` selects this
///   head's `head_dim` channels (must be group-aligned).
/// * `out[d] +=` accumulated weighted values for d in 0..head_dim.
pub fn value_accum_fused(p: &[f32], block: &PackedBlock, kv_dim: usize,
                         chan_offset: usize, head_dim: usize,
                         scratch: &mut FusedScratch, out: &mut [f32]) {
    debug_assert_eq!(chan_offset % block.group, 0);
    debug_assert_eq!(head_dim % block.group, 0);
    debug_assert!(chan_offset + head_dim <= kv_dim);
    debug_assert!((chan_offset + head_dim).div_ceil(block.group) <= block.scales.len());
    debug_assert_outliers_sorted(block);
    ensure_unpacked(block, scratch);
    let ints = &scratch.ints;
    let tokens = block.n / kv_dim;
    let groups_per_token = kv_dim / block.group;
    let g0 = chan_offset / block.group;
    let gn = head_dim / block.group;

    for (t, &pt) in p.iter().enumerate().take(tokens) {
        if pt == 0.0 {
            continue;
        }
        let base = t * kv_dim + chan_offset;
        let row = &ints[base..base + head_dim];
        for g in 0..gn {
            let gi = t * groups_per_token + g0 + g;
            let ps = pt * block.scales[gi];
            let pm = pt * block.mins[gi];
            let o = &mut out[g * block.group..(g + 1) * block.group];
            let r = &row[g * block.group..(g + 1) * block.group];
            for i in 0..block.group {
                o[i] += ps * r[i] as f32 + pm;
            }
        }
    }
    // outlier corrections for this head's channel range, bounded to the
    // tokens `p` covers via the index-sorted invariant
    let hi = block.outliers
        .partition_point(|&(i, _)| (i as usize) < p.len().min(tokens) * kv_dim);
    for &(i, v) in &block.outliers[..hi] {
        let t = i as usize / kv_dim;
        let c = i as usize % kv_dim;
        if c >= chan_offset && c < chan_offset + head_dim && p[t] != 0.0 {
            out[c - chan_offset] += p[t] * (v - block.dequant_one(i as usize, ints));
        }
    }
}

/// Unpack the block's integer stream into `scratch.ints`, skipping if the
/// scratch already holds this block's data (tagged by the block uid).
fn ensure_unpacked(block: &PackedBlock, scratch: &mut FusedScratch) {
    if block.uid != 0 && scratch.tag == block.uid && scratch.ints.len() >= block.n {
        return;
    }
    scratch.ints.resize(block.n, 0);
    unpack_stream(&block.words, block.bits, block.n, &mut scratch.ints);
    scratch.tag = block.uid;
}

/// Reference (unfused) implementations for tests/benches: dequantize the
/// whole block to f32, then plain matvec.
pub mod unfused {
    use super::*;

    pub fn key_scores(q: &[f32], block: &PackedBlock, tokens: usize,
                      chan_offset: usize, scratch: &mut FusedScratch,
                      out: &mut [f32]) {
        scratch.f32s.resize(block.n, 0.0);
        let mut ints = std::mem::take(&mut scratch.ints);
        block.dequantize_into(&mut scratch.f32s, &mut ints);
        scratch.ints = ints;
        scratch.invalidate(); // ints no longer matches the cached tag
        for (d, &qd) in q.iter().enumerate() {
            let c = chan_offset + d;
            for t in 0..tokens {
                out[t] += qd * scratch.f32s[c * tokens + t];
            }
        }
    }

    pub fn value_accum(p: &[f32], block: &PackedBlock, kv_dim: usize,
                       chan_offset: usize, head_dim: usize,
                       scratch: &mut FusedScratch, out: &mut [f32]) {
        scratch.f32s.resize(block.n, 0.0);
        let mut ints = std::mem::take(&mut scratch.ints);
        block.dequantize_into(&mut scratch.f32s, &mut ints);
        scratch.ints = ints;
        scratch.invalidate();
        let tokens = block.n / kv_dim;
        for (t, &pt) in p.iter().enumerate().take(tokens) {
            for d in 0..head_dim {
                out[d] += pt * scratch.f32s[t * kv_dim + chan_offset + d];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn key_block(rng: &mut Rng, kv_dim: usize, tokens: usize, bits: u8) -> (Vec<f32>, PackedBlock) {
        // channel-major stream
        let data = rng.normal_vec(kv_dim * tokens);
        let b = PackedBlock::quantize(&data, bits, tokens);
        (data, b)
    }

    #[test]
    fn fused_key_matches_unfused() {
        let mut rng = Rng::new(11);
        for bits in [1u8, 2, 3, 4] {
            let (_, block) = key_block(&mut rng, 64, 32, bits);
            let q = rng.normal_vec(32);
            let mut a = vec![0f32; 32];
            let mut b = vec![0f32; 32];
            let mut s = FusedScratch::default();
            key_scores_fused(&q, &block, 32, 16, &mut s, &mut a);
            unfused::key_scores(&q, &block, 32, 16, &mut s, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "bits={bits}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn fused_value_matches_unfused() {
        let mut rng = Rng::new(12);
        for bits in [1u8, 2, 3, 4] {
            let kv_dim = 64;
            let tokens = 32;
            let data = rng.normal_vec(tokens * kv_dim); // token-major
            let block = PackedBlock::quantize(&data, bits, 32);
            let p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
            let mut a = vec![0f32; 32];
            let mut b = vec![0f32; 32];
            let mut s = FusedScratch::default();
            value_accum_fused(&p, &block, kv_dim, 32, 32, &mut s, &mut a);
            unfused::value_accum(&p, &block, kv_dim, 32, 32, &mut s, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "bits={bits}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn packed_key_matches_fused_bitwise() {
        // quick in-module smoke of the exactness contract; the full
        // property sweep lives in rust/tests/packed_kernels.rs
        let mut rng = Rng::new(31);
        for bits in [1u8, 2, 4, 8] {
            let (_, block) = key_block(&mut rng, 64, 32, bits);
            let q = rng.normal_vec(32);
            let mut a = vec![0f32; 32];
            let mut b = vec![0f32; 32];
            key_scores_packed(&q, &block, 32, 16, &mut a);
            key_scores_fused(&q, &block, 32, 16, &mut FusedScratch::default(), &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "bits={bits}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn packed_value_matches_fused_bitwise() {
        let mut rng = Rng::new(32);
        for bits in [1u8, 2, 4, 8] {
            let kv_dim = 64;
            let tokens = 32;
            let data = rng.normal_vec(tokens * kv_dim);
            let block = PackedBlock::quantize(&data, bits, 32);
            let p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
            let mut a = vec![0f32; 32];
            let mut b = vec![0f32; 32];
            value_accum_packed(&p, &block, kv_dim, 32, 32, &mut a);
            value_accum_fused(&p, &block, kv_dim, 32, 32, &mut FusedScratch::default(), &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "bits={bits}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn dispatch_routes_3bit_to_fused() {
        assert!(!packed_dot_supported(3));
        assert!(packed_dot_supported(1) && packed_dot_supported(2)
                && packed_dot_supported(4) && packed_dot_supported(8));
        let mut rng = Rng::new(33);
        let (_, block) = key_block(&mut rng, 32, 32, 3);
        let q = rng.normal_vec(32);
        let mut a = vec![0f32; 32];
        let mut b = vec![0f32; 32];
        let mut s = FusedScratch::default();
        key_scores_dispatch(&q, &block, 32, 0, &mut s, &mut a);
        key_scores_fused(&q, &block, 32, 0, &mut FusedScratch::default(), &mut b);
        assert_eq!(a, b);
        assert!(!s.ints.is_empty(), "3-bit fallback stages the unpack scratch");
    }

    #[test]
    fn unpack_cache_tracks_inplace_requantization() {
        // an in-place downshift must invalidate a scratch that still
        // holds the block's old integers (uid-keyed cache)
        let mut rng = Rng::new(21);
        let (_, mut block) = key_block(&mut rng, 32, 32, 4);
        let q = rng.normal_vec(32);
        let mut s = FusedScratch::default();
        let mut before = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut before);
        block.requantize(2, &mut Vec::new(), &mut Vec::new());
        let mut after = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut after);
        let mut fresh = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut FusedScratch::default(), &mut fresh);
        assert_eq!(after, fresh, "stale unpack served after requantize");
        assert_ne!(after, before, "2-bit scores should differ from 4-bit");
    }

    #[test]
    fn fused_key_accumulates() {
        // out is += so two calls double
        let mut rng = Rng::new(13);
        let (_, block) = key_block(&mut rng, 32, 32, 2);
        let q = rng.normal_vec(32);
        let mut s = FusedScratch::default();
        let mut once = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut once);
        let mut twice = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut twice);
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut twice);
        for (x, y) in once.iter().zip(&twice) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn packed_outlier_side_path_is_binary_searched_range() {
        // an outlier-carrying block: packed and fused must agree exactly
        // for heads at every chan_offset (the partition_point range must
        // select precisely the head's outliers)
        let mut rng = Rng::new(34);
        let (kv_dim, tokens) = (64usize, 32usize);
        let data = rng.normal_vec(kv_dim * tokens);
        let mut block = PackedBlock::default();
        block.quantize_outliers_into(&data, 2, tokens, 0.05, &mut Vec::new());
        assert!(!block.outliers.is_empty());
        let q = rng.normal_vec(32);
        for chan_offset in [0usize, 32] {
            let mut a = vec![0f32; tokens];
            let mut b = vec![0f32; tokens];
            key_scores_packed(&q, &block, tokens, chan_offset, &mut a);
            key_scores_fused(&q, &block, tokens, chan_offset,
                             &mut FusedScratch::default(), &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "chan_offset={chan_offset}");
            }
        }
    }
}
