//! Fused dequantize·matvec kernels — the Rust analog of the paper's CUDA
//! contribution (§CUDA Implementation ②③).
//!
//! Never materializes a dequantized f32 cache block.  Each call unpacks a
//! block's integer stream into a reusable scratch (the "shared memory"
//! staging of the CUDA version), then folds the affine dequantization into
//! the dot products algebraically:
//!
//!   Key  (per-channel groups): score[t] = Σ_c q[c]·(Q[c,t]·s_c + m_c)
//!        = Σ_c (q[c]·s_c)·Q[c,t]  +  Σ_c q[c]·m_c
//!     — the bias term is token-independent and hoisted out of the loop;
//!       the weighted sum runs channel-outer/token-inner so the inner loop
//!       is a contiguous fused-multiply-add over the block's tokens.
//!
//!   Value (per-token groups):  out[c] += Σ_t p[t]·(Q[t,c]·s_{t,g} + m_{t,g})
//!        = Σ_t (p[t]·s_{t,g})·Q[t,c]  +  bias_g(c∈g)
//!     — token-outer/channel-inner, again contiguous in the stream.

use super::groupq::PackedBlock;
use super::pack::unpack_stream;

/// Reusable scratch buffers for the fused kernels (one per worker thread:
/// the decode fan-out carries a `FusedScratch` inside each worker's
/// `AttnScratch`, never sharing one across threads).
///
/// The unpack-cache `tag` stores the [`PackedBlock::uid`] of the block
/// currently staged in `ints`.  The uid is refreshed on every
/// (re)quantization, so a pressure-controller downshift that rewrites a
/// block in place — or a new block whose buffers reuse a freed
/// allocation — can never match a stale unpack.  Prefix sharing
/// (DESIGN.md §Prefix-Sharing) preserves the invariant from the other
/// direction: a shared block's `Arc` clones keep its uid, and a
/// copy-on-write clone is always requantized (fresh uid) before anyone
/// reads it — identical uid therefore always means identical bytes,
/// even across lanes that share a block.  The cache only elides
/// re-unpacking, never changes results.
#[derive(Default)]
pub struct FusedScratch {
    pub ints: Vec<u32>,
    pub f32s: Vec<f32>,
    /// uid of the block currently unpacked in `ints` (0 = none) — lets
    /// per-head loops skip redundant unpacks
    tag: u64,
}

impl FusedScratch {
    /// Invalidate the unpack cache (call if `ints` is clobbered by hand).
    pub fn invalidate(&mut self) {
        self.tag = 0;
    }
}

/// Attention scores of one query head against a **Key block**.
///
/// * `q` — the query slice for this KV head (`head_dim` f32s, RoPE'd).
/// * `block` — channel-major Key block: stream index `c*tokens + t`,
///   channels are the *full* kv_dim; `chan_offset` selects this head's
///   `head_dim` channels.
/// * `tokens` — tokens in the block (= the per-channel group size).
/// * `out[t] +=` raw (unscaled) dot products — caller applies 1/sqrt(hd).
pub fn key_scores_fused(q: &[f32], block: &PackedBlock, tokens: usize,
                        chan_offset: usize, scratch: &mut FusedScratch,
                        out: &mut [f32]) {
    debug_assert_eq!(block.group, tokens);
    debug_assert!(out.len() >= tokens);
    let hd = q.len();
    // Unpack just once per (block); callers iterating heads pass the same
    // scratch so `ensure_unpacked` skips redundant work.
    ensure_unpacked(block, scratch);
    let ints = &scratch.ints;

    let mut bias = 0f32;
    for (d, &qd) in q.iter().enumerate() {
        let c = chan_offset + d;
        let s = block.scales[c];
        let m = block.mins[c];
        let qs = qd * s;
        bias += qd * m;
        let row = &ints[c * tokens..c * tokens + tokens];
        for t in 0..tokens {
            out[t] += qs * row[t] as f32;
        }
    }
    let _ = hd;
    for t in 0..tokens {
        out[t] += bias;
    }
    // outlier corrections (KVQuant baseline): exact value replaces the
    // packed approximation for its (channel, token) element
    for &(i, v) in &block.outliers {
        let c = i as usize / tokens;
        if c >= chan_offset && c < chan_offset + q.len() {
            let t = i as usize % tokens;
            out[t] += q[c - chan_offset] * (v - block.dequant_one(i as usize, ints));
        }
    }
}

/// Weighted-value accumulation of one head's probabilities against a
/// **Value block**.
///
/// * `p[t]` — softmax probabilities for this block's tokens.
/// * `block` — token-major Value block: stream index `t*kv_dim + c`,
///   groups of `block.group` consecutive channels per token.
/// * `kv_dim` — full channel count per token; `chan_offset` selects this
///   head's `head_dim` channels (must be group-aligned).
/// * `out[d] +=` accumulated weighted values for d in 0..head_dim.
pub fn value_accum_fused(p: &[f32], block: &PackedBlock, kv_dim: usize,
                         chan_offset: usize, head_dim: usize,
                         scratch: &mut FusedScratch, out: &mut [f32]) {
    debug_assert_eq!(chan_offset % block.group, 0);
    debug_assert_eq!(head_dim % block.group, 0);
    ensure_unpacked(block, scratch);
    let ints = &scratch.ints;
    let tokens = block.n / kv_dim;
    let groups_per_token = kv_dim / block.group;
    let g0 = chan_offset / block.group;
    let gn = head_dim / block.group;

    for (t, &pt) in p.iter().enumerate().take(tokens) {
        if pt == 0.0 {
            continue;
        }
        let base = t * kv_dim + chan_offset;
        let row = &ints[base..base + head_dim];
        for g in 0..gn {
            let gi = t * groups_per_token + g0 + g;
            let ps = pt * block.scales[gi];
            let pm = pt * block.mins[gi];
            let o = &mut out[g * block.group..(g + 1) * block.group];
            let r = &row[g * block.group..(g + 1) * block.group];
            for i in 0..block.group {
                o[i] += ps * r[i] as f32 + pm;
            }
        }
    }
    // outlier corrections for this head's channel range
    for &(i, v) in &block.outliers {
        let t = i as usize / kv_dim;
        let c = i as usize % kv_dim;
        if c >= chan_offset && c < chan_offset + head_dim && t < p.len() && p[t] != 0.0 {
            out[c - chan_offset] += p[t] * (v - block.dequant_one(i as usize, ints));
        }
    }
}

/// Unpack the block's integer stream into `scratch.ints`, skipping if the
/// scratch already holds this block's data (tagged by the block uid).
fn ensure_unpacked(block: &PackedBlock, scratch: &mut FusedScratch) {
    if block.uid != 0 && scratch.tag == block.uid && scratch.ints.len() >= block.n {
        return;
    }
    scratch.ints.resize(block.n, 0);
    unpack_stream(&block.words, block.bits, block.n, &mut scratch.ints);
    scratch.tag = block.uid;
}

/// Reference (unfused) implementations for tests/benches: dequantize the
/// whole block to f32, then plain matvec.
pub mod unfused {
    use super::*;

    pub fn key_scores(q: &[f32], block: &PackedBlock, tokens: usize,
                      chan_offset: usize, scratch: &mut FusedScratch,
                      out: &mut [f32]) {
        scratch.f32s.resize(block.n, 0.0);
        let mut ints = std::mem::take(&mut scratch.ints);
        block.dequantize_into(&mut scratch.f32s, &mut ints);
        scratch.ints = ints;
        scratch.invalidate(); // ints no longer matches the cached tag
        for (d, &qd) in q.iter().enumerate() {
            let c = chan_offset + d;
            for t in 0..tokens {
                out[t] += qd * scratch.f32s[c * tokens + t];
            }
        }
    }

    pub fn value_accum(p: &[f32], block: &PackedBlock, kv_dim: usize,
                       chan_offset: usize, head_dim: usize,
                       scratch: &mut FusedScratch, out: &mut [f32]) {
        scratch.f32s.resize(block.n, 0.0);
        let mut ints = std::mem::take(&mut scratch.ints);
        block.dequantize_into(&mut scratch.f32s, &mut ints);
        scratch.ints = ints;
        scratch.invalidate();
        let tokens = block.n / kv_dim;
        for (t, &pt) in p.iter().enumerate().take(tokens) {
            for d in 0..head_dim {
                out[d] += pt * scratch.f32s[t * kv_dim + chan_offset + d];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn key_block(rng: &mut Rng, kv_dim: usize, tokens: usize, bits: u8) -> (Vec<f32>, PackedBlock) {
        // channel-major stream
        let data = rng.normal_vec(kv_dim * tokens);
        let b = PackedBlock::quantize(&data, bits, tokens);
        (data, b)
    }

    #[test]
    fn fused_key_matches_unfused() {
        let mut rng = Rng::new(11);
        for bits in [1u8, 2, 3, 4] {
            let (_, block) = key_block(&mut rng, 64, 32, bits);
            let q = rng.normal_vec(32);
            let mut a = vec![0f32; 32];
            let mut b = vec![0f32; 32];
            let mut s = FusedScratch::default();
            key_scores_fused(&q, &block, 32, 16, &mut s, &mut a);
            unfused::key_scores(&q, &block, 32, 16, &mut s, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "bits={bits}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn fused_value_matches_unfused() {
        let mut rng = Rng::new(12);
        for bits in [1u8, 2, 3, 4] {
            let kv_dim = 64;
            let tokens = 32;
            let data = rng.normal_vec(tokens * kv_dim); // token-major
            let block = PackedBlock::quantize(&data, bits, 32);
            let p: Vec<f32> = (0..tokens).map(|_| rng.f32()).collect();
            let mut a = vec![0f32; 32];
            let mut b = vec![0f32; 32];
            let mut s = FusedScratch::default();
            value_accum_fused(&p, &block, kv_dim, 32, 32, &mut s, &mut a);
            unfused::value_accum(&p, &block, kv_dim, 32, 32, &mut s, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "bits={bits}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn unpack_cache_tracks_inplace_requantization() {
        // an in-place downshift must invalidate a scratch that still
        // holds the block's old integers (uid-keyed cache)
        let mut rng = Rng::new(21);
        let (_, mut block) = key_block(&mut rng, 32, 32, 4);
        let q = rng.normal_vec(32);
        let mut s = FusedScratch::default();
        let mut before = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut before);
        block.requantize(2, &mut Vec::new(), &mut Vec::new());
        let mut after = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut after);
        let mut fresh = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut FusedScratch::default(), &mut fresh);
        assert_eq!(after, fresh, "stale unpack served after requantize");
        assert_ne!(after, before, "2-bit scores should differ from 4-bit");
    }

    #[test]
    fn fused_key_accumulates() {
        // out is += so two calls double
        let mut rng = Rng::new(13);
        let (_, block) = key_block(&mut rng, 32, 32, 2);
        let q = rng.normal_vec(32);
        let mut s = FusedScratch::default();
        let mut once = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut once);
        let mut twice = vec![0f32; 32];
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut twice);
        key_scores_fused(&q, &block, 32, 0, &mut s, &mut twice);
        for (x, y) in once.iter().zip(&twice) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
    }
}
