//! Full-precision window policies (paper §Dynamic Pivotal Context
//! Selection + the baselines' residual strategies).
//!
//! After appending `n` new tokens the window holds `current` fp tokens;
//! the policy decides how many to *keep* fp.  Quantization then proceeds
//! in whole groups (32 tokens) from the oldest end, so the kept count is
//! a lower bound — the actual fp count is `current - floor((current -
//! keep)/group)*group`.
//!
//! Window policies only decide when tokens *leave* the fp tail.  What
//! happens to already-quantized history under memory pressure — the
//! bit-ladder downshift of the oldest out-of-window pages — is the
//! pressure controller's job (`kvcache/pressure.rs`,
//! DESIGN.md §Memory-Manager).

/// How the full-precision tail is managed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WindowPolicy {
    /// Everything stays fp (fp16 baseline).
    All,
    /// KVmix dynamic RPC: keep `floor(ratio * current)` (paper:
    /// `num_RPC = floor(r × current_RPC)`).
    Rpc { ratio: f64 },
    /// KIVI-style fixed residual: keep exactly `tokens` fp, regardless of
    /// context length (never shrinks — the paper's Fig. 7 contrast).
    FixedResidual { tokens: usize },
    /// Quantize everything that forms a complete group (Atom / uniform
    /// k-T,v-T baselines, and KVmix w/oRPC).
    None,
}

impl WindowPolicy {
    /// fp tokens to keep given the current fp window size.
    pub fn keep(&self, current: usize) -> usize {
        match *self {
            WindowPolicy::All => current,
            WindowPolicy::Rpc { ratio } => ((ratio * current as f64).floor() as usize).min(current),
            WindowPolicy::FixedResidual { tokens } => tokens.min(current),
            WindowPolicy::None => 0,
        }
    }

    /// Number of whole `group`-token blocks to quantize now.
    pub fn blocks_to_quantize(&self, current: usize, group: usize) -> usize {
        (current - self.keep(current)) / group
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rpc_shrinks_dynamically() {
        let p = WindowPolicy::Rpc { ratio: 0.2 };
        assert_eq!(p.keep(10), 2);
        assert_eq!(p.keep(100), 20);
        // grows sublinearly vs FixedResidual which stays constant
        let f = WindowPolicy::FixedResidual { tokens: 64 };
        assert_eq!(f.keep(100), 64);
        assert_eq!(f.keep(10), 10);
    }

    #[test]
    fn block_granularity() {
        let p = WindowPolicy::Rpc { ratio: 0.1 };
        // current=40: keep 4 -> overflow 36 -> 1 block of 32
        assert_eq!(p.blocks_to_quantize(40, 32), 1);
        // current=33: keep 3 -> overflow 30 -> 0 blocks
        assert_eq!(p.blocks_to_quantize(33, 32), 0);
    }

    #[test]
    fn none_quantizes_full_blocks() {
        assert_eq!(WindowPolicy::None.blocks_to_quantize(70, 32), 2);
        assert_eq!(WindowPolicy::All.blocks_to_quantize(1000, 32), 0);
    }
}
