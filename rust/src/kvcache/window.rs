//! Full-precision window policies (paper §Dynamic Pivotal Context
//! Selection + the baselines' residual strategies).
//!
//! After appending `n` new tokens the window holds `current` fp tokens;
//! the policy decides how many to *keep* fp.  Quantization then proceeds
//! in whole groups (32 tokens) from the oldest end, so the kept count is
//! a lower bound — the actual fp count is `current - floor((current -
//! keep)/group)*group`.
//!
//! Window policies only decide when tokens *leave* the fp tail.  What
//! happens to already-quantized history afterwards is split between two
//! other mechanisms: under memory pressure the bit-ladder downshift of
//! the oldest out-of-window pages is the pressure controller's job
//! (`kvcache/pressure.rs`, DESIGN.md §Memory-Manager) — with
//! shared-prefix pages *exempt* from that ladder until they are
//! sole-owned, and copy-on-write split otherwise — and the cross-sequence
//! reuse of quantized prefix pages is the pool's prefix index
//! (`kvcache/pages.rs`, DESIGN.md §Prefix-Sharing).  Prefix sharing also
//! leans on this module's arithmetic: `blocks_to_quantize(prompt_len)`
//! bounds the adoptable prefix (`SeqKvCache::max_shareable_prefix`), so
//! the rounding pinned by the tests below is part of the bit-identity
//! contract.

/// How the full-precision tail is managed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WindowPolicy {
    /// Everything stays fp (fp16 baseline).
    All,
    /// KVmix dynamic RPC: keep `floor(ratio * current)` (paper:
    /// `num_RPC = floor(r × current_RPC)`).
    Rpc { ratio: f64 },
    /// KIVI-style fixed residual: keep exactly `tokens` fp, regardless of
    /// context length (never shrinks — the paper's Fig. 7 contrast).
    FixedResidual { tokens: usize },
    /// Quantize everything that forms a complete group (Atom / uniform
    /// k-T,v-T baselines, and KVmix w/oRPC).
    None,
}

impl WindowPolicy {
    /// fp tokens to keep given the current fp window size.
    pub fn keep(&self, current: usize) -> usize {
        match *self {
            WindowPolicy::All => current,
            WindowPolicy::Rpc { ratio } => ((ratio * current as f64).floor() as usize).min(current),
            WindowPolicy::FixedResidual { tokens } => tokens.min(current),
            WindowPolicy::None => 0,
        }
    }

    /// Number of whole `group`-token blocks to quantize now.
    pub fn blocks_to_quantize(&self, current: usize, group: usize) -> usize {
        (current - self.keep(current)) / group
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rpc_shrinks_dynamically() {
        let p = WindowPolicy::Rpc { ratio: 0.2 };
        assert_eq!(p.keep(10), 2);
        assert_eq!(p.keep(100), 20);
        // grows sublinearly vs FixedResidual which stays constant
        let f = WindowPolicy::FixedResidual { tokens: 64 };
        assert_eq!(f.keep(100), 64);
        assert_eq!(f.keep(10), 10);
    }

    #[test]
    fn block_granularity() {
        let p = WindowPolicy::Rpc { ratio: 0.1 };
        // current=40: keep 4 -> overflow 36 -> 1 block of 32
        assert_eq!(p.blocks_to_quantize(40, 32), 1);
        // current=33: keep 3 -> overflow 30 -> 0 blocks
        assert_eq!(p.blocks_to_quantize(33, 32), 0);
    }

    #[test]
    fn none_quantizes_full_blocks() {
        assert_eq!(WindowPolicy::None.blocks_to_quantize(70, 32), 2);
        assert_eq!(WindowPolicy::All.blocks_to_quantize(1000, 32), 0);
    }

    #[test]
    fn rpc_keep_zero_rounding() {
        // ratio*current < 1 floors keep to 0: the whole window is then
        // quantizable in group granularity, exactly like ::None
        let p = WindowPolicy::Rpc { ratio: 0.01 };
        for current in [1usize, 31, 32, 63, 64, 99] {
            assert_eq!(p.keep(current), 0, "keep({current})");
            assert_eq!(p.blocks_to_quantize(current, 32),
                       WindowPolicy::None.blocks_to_quantize(current, 32),
                       "current={current}");
        }
        // first current where keep becomes nonzero: 1/0.01 = 100
        assert_eq!(p.keep(100), 1);
        assert_eq!(p.blocks_to_quantize(100, 32), 3);
    }

    #[test]
    fn sub_group_window_never_quantizes() {
        // current < group can never form a whole block, for any policy
        for current in 0..32 {
            for p in [WindowPolicy::None, WindowPolicy::Rpc { ratio: 0.5 },
                      WindowPolicy::FixedResidual { tokens: 0 }] {
                assert_eq!(p.blocks_to_quantize(current, 32), 0,
                           "{p:?} current={current}");
            }
        }
    }

    #[test]
    fn rpc_group_boundary_rounding() {
        // exactly at a group boundary the overflow rounds down, one token
        // past it a fresh block seals — the boundary arithmetic
        // `max_shareable_prefix` builds on
        let p = WindowPolicy::Rpc { ratio: 0.1 };
        // current=64: keep 6 -> overflow 58 -> 1 block
        assert_eq!(p.blocks_to_quantize(64, 32), 1);
        // current=70: keep 7 -> overflow 63 -> still 1 block
        assert_eq!(p.blocks_to_quantize(70, 32), 1);
        // current=71: keep 7 -> overflow 64 -> 2 blocks
        assert_eq!(p.blocks_to_quantize(71, 32), 2);
        // keep is clamped to current (ratio >= 1 keeps everything)
        let all = WindowPolicy::Rpc { ratio: 1.0 };
        assert_eq!(all.keep(50), 50);
        assert_eq!(all.blocks_to_quantize(50, 32), 0);
    }
}
