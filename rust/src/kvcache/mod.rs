//! Mixed-precision KV cache: packed history blocks + dynamic
//! full-precision windows (RPC), per-layer representations, memory
//! accounting, the HBM budget simulator, and the paged KV pool with its
//! pressure controller and copy-on-write prefix sharing
//! (DESIGN.md §Memory-Manager, §Prefix-Sharing).

pub mod cache;
pub mod jl;
pub mod memory;
pub mod pages;
pub mod pressure;
pub mod spill;
pub mod window;

pub use cache::{AttnScratch, KeyRepr, LayerCacheCfg, LayerKvCache, ValueRepr};
pub use memory::{fp16_kv_bytes, MemoryBudget};
pub use pages::{KvSide, PageId, PagePool, PoolStats, DEFAULT_PAGE_TOKENS, KV_SIDES};
pub use pressure::{PressureCfg, SharedDownshift};
pub use spill::SpillTier;
pub use window::WindowPolicy;

use crate::config::{ModelConfig, QuantPlan};

/// All layers' caches for one sequence, built from a [`QuantPlan`].
pub struct SeqKvCache {
    pub layers: Vec<LayerKvCache>,
}

impl SeqKvCache {
    pub fn new(model: &ModelConfig, plan: &QuantPlan) -> Self {
        Self::with_policy(model, plan, 0.0, None)
    }

    /// Fully explicit construction (used by the QJL/Atom baselines whose
    /// representations aren't expressible as a bit plan).
    pub fn from_cfgs(cfgs: Vec<LayerCacheCfg>) -> Self {
        SeqKvCache { layers: cfgs.into_iter().map(LayerKvCache::new).collect() }
    }

    /// `outlier_frac` / `fixed_residual` support the KVQuant and KIVI
    /// baselines (see baselines/mod.rs).
    pub fn with_policy(model: &ModelConfig, plan: &QuantPlan, outlier_frac: f64,
                       fixed_residual: Option<usize>) -> Self {
        let layers = (0..model.n_layers).map(|i| {
            let kb = plan.k_bits[i];
            let vb = plan.v_bits[i];
            let key = if kb == 16 { KeyRepr::Fp } else { KeyRepr::PerChannel { bits: kb } };
            let value = if vb == 16 { ValueRepr::Fp } else { ValueRepr::PerToken { bits: vb } };
            let k_window = window_for(kb, plan.k_rpc[i], fixed_residual);
            let v_window = window_for(vb, plan.v_rpc[i], fixed_residual);
            LayerKvCache::new(LayerCacheCfg {
                kv_dim: model.kv_dim(),
                head_dim: model.head_dim,
                group: model.group,
                key,
                value,
                k_window,
                v_window,
                outlier_frac,
                k_interleave: false,
            })
        }).collect();
        SeqKvCache { layers }
    }

    /// Switch Key-side history to the channel-interleaved word layout
    /// (or back).  Safe mid-stream: the layout is a per-block property
    /// selected at quantize time, so existing blocks keep their layout
    /// and only blocks quantized after the call pick up the new one —
    /// attend handles mixed layouts block by block and outputs stay
    /// bit-identical either way (docs/adr/009-swar-and-interleaved-layout.md).
    pub fn set_k_interleave(&mut self, on: bool) {
        for l in &mut self.layers {
            l.cfg.k_interleave = on;
        }
    }

    pub fn len(&self) -> usize {
        self.layers.first().map(|l| l.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn modeled_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.modeled_bytes()).sum()
    }

    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.resident_bytes()).sum()
    }

    /// Longest whole-page prompt prefix eligible for shared-page adoption
    /// (prefix sharing, DESIGN.md §Prefix-Sharing): the page-aligned token
    /// count that a single `prompt_len`-token prefill would leave
    /// *quantized at the plan's width* on **every** layer and side — the
    /// precondition for adopting shared quantized pages while staying
    /// bit-identical to a cold prefill.  Returns 0 when any layer cannot
    /// share (fp16 or sign-JL representations, which produce no packed
    /// page blocks) or when the window policies keep the candidate prefix
    /// full-precision.
    pub fn max_shareable_prefix(&self, prompt_len: usize, page_tokens: usize) -> usize {
        if self.layers.is_empty() || page_tokens == 0 {
            return 0;
        }
        let mut cap = usize::MAX;
        for l in &self.layers {
            let shareable_k = matches!(l.cfg.key,
                                       KeyRepr::PerChannel { .. } | KeyRepr::PerToken { .. });
            let shareable_v = matches!(l.cfg.value, ValueRepr::PerToken { .. });
            if !shareable_k || !shareable_v {
                return 0;
            }
            let g = l.cfg.group;
            let kq = l.cfg.k_window.blocks_to_quantize(prompt_len, g) * g;
            let vq = l.cfg.v_window.blocks_to_quantize(prompt_len, g) * g;
            cap = cap.min(kq).min(vq);
        }
        cap / page_tokens * page_tokens
    }
}

fn window_for(bits: u8, rpc: f64, fixed_residual: Option<usize>) -> WindowPolicy {
    if bits == 16 {
        return WindowPolicy::All;
    }
    if let Some(tokens) = fixed_residual {
        return WindowPolicy::FixedResidual { tokens };
    }
    if rpc <= 0.0 {
        WindowPolicy::None
    } else {
        WindowPolicy::Rpc { ratio: rpc }
    }
}

/// Shared fixtures for the in-crate kvcache test modules (pages,
/// pressure).  Integration tests under `rust/tests/` keep their own copy
/// — `#[cfg(test)]` items don't cross the crate boundary.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::config::{ModelConfig, QuantPlan};
    use crate::util::Rng;

    use super::SeqKvCache;

    /// A cache with `tokens` seeded-random tokens appended to every layer.
    pub(crate) fn filled_cache(m: &ModelConfig, plan: &QuantPlan, tokens: usize,
                               seed: u64) -> SeqKvCache {
        let mut c = SeqKvCache::new(m, plan);
        let kv = m.kv_dim();
        let mut rng = Rng::new(seed);
        let k = rng.normal_vec(tokens * kv);
        let v = rng.normal_vec(tokens * kv);
        for l in &mut c.layers {
            l.append(&k, &v, tokens);
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_cache_from_plan() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2);
        let mut c = SeqKvCache::new(&m, &plan);
        assert_eq!(c.layers.len(), 2);
        let kv = m.kv_dim();
        let mut rng = crate::util::Rng::new(1);
        for l in &mut c.layers {
            let k = rng.normal_vec(kv * 4);
            let v = rng.normal_vec(kv * 4);
            l.append(&k, &v, 4);
        }
        assert_eq!(c.len(), 4);
        assert!(c.modeled_bytes() > 0);
    }

    #[test]
    fn shareable_prefix_caps() {
        let m = ModelConfig::test_small();
        let pt = 64;
        // eager plan: everything group-aligned quantizes -> page-aligned cap
        let eager = SeqKvCache::new(&m, &QuantPlan::uniform(m.n_layers, 2).without_rpc());
        assert_eq!(eager.max_shareable_prefix(192, pt), 192);
        assert_eq!(eager.max_shareable_prefix(130, pt), 128);
        assert_eq!(eager.max_shareable_prefix(63, pt), 0, "sub-page prompt");
        // RPC window: the kept fp tail shrinks the quantizable run
        let rpc = SeqKvCache::new(&m, &QuantPlan::uniform(m.n_layers, 2));
        let cap = rpc.max_shareable_prefix(192, pt);
        assert!(cap <= 128 && cap % pt == 0, "cap {cap} must exclude the fp tail");
        // fp16 has no packed pages to share
        let fp = SeqKvCache::new(&m, &QuantPlan::fp16(m.n_layers));
        assert_eq!(fp.max_shareable_prefix(512, pt), 0);
    }
}
