//! Mixed-precision KV cache: packed history blocks + dynamic
//! full-precision windows (RPC), per-layer representations, memory
//! accounting, the HBM budget simulator, and the paged KV pool with its
//! pressure controller (DESIGN.md §Memory-Manager).

pub mod cache;
pub mod jl;
pub mod memory;
pub mod pages;
pub mod pressure;
pub mod window;

pub use cache::{AttnScratch, KeyRepr, LayerCacheCfg, LayerKvCache, ValueRepr};
pub use memory::{fp16_kv_bytes, MemoryBudget};
pub use pages::{KvSide, PageId, PagePool, PoolStats, DEFAULT_PAGE_TOKENS};
pub use pressure::PressureCfg;
pub use window::WindowPolicy;

use crate::config::{ModelConfig, QuantPlan};

/// All layers' caches for one sequence, built from a [`QuantPlan`].
pub struct SeqKvCache {
    pub layers: Vec<LayerKvCache>,
}

impl SeqKvCache {
    pub fn new(model: &ModelConfig, plan: &QuantPlan) -> Self {
        Self::with_policy(model, plan, 0.0, None)
    }

    /// Fully explicit construction (used by the QJL/Atom baselines whose
    /// representations aren't expressible as a bit plan).
    pub fn from_cfgs(cfgs: Vec<LayerCacheCfg>) -> Self {
        SeqKvCache { layers: cfgs.into_iter().map(LayerKvCache::new).collect() }
    }

    /// `outlier_frac` / `fixed_residual` support the KVQuant and KIVI
    /// baselines (see baselines/mod.rs).
    pub fn with_policy(model: &ModelConfig, plan: &QuantPlan, outlier_frac: f64,
                       fixed_residual: Option<usize>) -> Self {
        let layers = (0..model.n_layers).map(|i| {
            let kb = plan.k_bits[i];
            let vb = plan.v_bits[i];
            let key = if kb == 16 { KeyRepr::Fp } else { KeyRepr::PerChannel { bits: kb } };
            let value = if vb == 16 { ValueRepr::Fp } else { ValueRepr::PerToken { bits: vb } };
            let k_window = window_for(kb, plan.k_rpc[i], fixed_residual);
            let v_window = window_for(vb, plan.v_rpc[i], fixed_residual);
            LayerKvCache::new(LayerCacheCfg {
                kv_dim: model.kv_dim(),
                head_dim: model.head_dim,
                group: model.group,
                key,
                value,
                k_window,
                v_window,
                outlier_frac,
            })
        }).collect();
        SeqKvCache { layers }
    }

    pub fn len(&self) -> usize {
        self.layers.first().map(|l| l.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn modeled_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.modeled_bytes()).sum()
    }

    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.resident_bytes()).sum()
    }
}

fn window_for(bits: u8, rpc: f64, fixed_residual: Option<usize>) -> WindowPolicy {
    if bits == 16 {
        return WindowPolicy::All;
    }
    if let Some(tokens) = fixed_residual {
        return WindowPolicy::FixedResidual { tokens };
    }
    if rpc <= 0.0 {
        WindowPolicy::None
    } else {
        WindowPolicy::Rpc { ratio: rpc }
    }
}

/// Shared fixtures for the in-crate kvcache test modules (pages,
/// pressure).  Integration tests under `rust/tests/` keep their own copy
/// — `#[cfg(test)]` items don't cross the crate boundary.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::config::{ModelConfig, QuantPlan};
    use crate::util::Rng;

    use super::SeqKvCache;

    /// A cache with `tokens` seeded-random tokens appended to every layer.
    pub(crate) fn filled_cache(m: &ModelConfig, plan: &QuantPlan, tokens: usize,
                               seed: u64) -> SeqKvCache {
        let mut c = SeqKvCache::new(m, plan);
        let kv = m.kv_dim();
        let mut rng = Rng::new(seed);
        let k = rng.normal_vec(tokens * kv);
        let v = rng.normal_vec(tokens * kv);
        for l in &mut c.layers {
            l.append(&k, &v, tokens);
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_cache_from_plan() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2);
        let mut c = SeqKvCache::new(&m, &plan);
        assert_eq!(c.layers.len(), 2);
        let kv = m.kv_dim();
        let mut rng = crate::util::Rng::new(1);
        for l in &mut c.layers {
            let k = rng.normal_vec(kv * 4);
            let v = rng.normal_vec(kv * 4);
            l.append(&k, &v, 4);
        }
        assert_eq!(c.len(), 4);
        assert!(c.modeled_bytes() > 0);
    }
}
