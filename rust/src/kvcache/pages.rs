//! The global paged KV pool (DESIGN.md §Memory-Manager).
//!
//! Fixed `page_tokens`-token page frames, per-layer per-precision free
//! lists, and per-sequence page tables mapping each sequence's cache onto
//! the pool — the substrate the paper's Fig. 7/8 efficiency story assumes
//! (KV memory as the scarce serving resource) and that KVTuner-style
//! layer-wise allocation and query-aware schemes take for granted.
//!
//! **Division of labour.**  The per-sequence [`LayerKvCache`] stays the
//! *data plane*: it owns the fp windows and the packed blocks the
//! attention kernels read, and the decode fan-out keeps handing disjoint
//! `&mut` lanes to pool workers (DESIGN.md §Threading-Model) with no new
//! shared state.  The `PagePool` is the *control plane*: the allocator
//! and the accountant.  After every engine step — on the engine thread,
//! like vLLM's scheduler-side block manager — [`PagePool::sync`]
//! reconciles each sequence's page table against its cache and
//! [`crate::kvcache::MemoryBudget`] is charged `PagePool::modeled_bytes`,
//! i.e. at **page granularity**: a partially-filled page costs a whole
//! frame, which is exactly the fragmentation a real paged allocator pays
//! (and what the monolithic per-sequence accounting hides).
//!
//! A page frame covers `page_tokens` tokens of **one side** (K or V) of
//! **one layer** at **one precision class**: `16` (fp16 window pages) or
//! a packed bit width.  Freed frames park on a `(layer, precision)` free
//! list and are reused before the pool grows — observable via
//! [`PoolStats::reuses`].
//!
//! Not paged (charged by the monolithic path only, noted here so the
//! accounting difference is explicit): QJL's sign-bit JL key store, and
//! KVQuant's per-element outlier list.  Both are baseline-only details;
//! the KVmix policies the pool exists for use neither.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::quant::words_for;

use super::cache::LayerKvCache;
use super::SeqKvCache;

/// Default `--page-tokens` when paging is enabled (2 quant groups).
pub const DEFAULT_PAGE_TOKENS: usize = 64;

/// Which half of the KV cache a page holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvSide {
    Key,
    Value,
}

/// Both sides, in the fixed scan order used everywhere (K before V).
pub const KV_SIDES: [KvSide; 2] = [KvSide::Key, KvSide::Value];

/// Index of a page frame in the pool (stable across free + reuse).
pub type PageId = u32;

/// Metadata of one live page frame.
#[derive(Debug, Clone)]
pub struct Frame {
    pub layer: u16,
    pub side: KvSide,
    /// precision class: 16 = fp16 window page, else packed bit width
    pub bits: u8,
    /// request id of the mapping sequence
    pub owner: u64,
}

/// Allocation / lifecycle counters.
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub allocs: usize,
    /// allocs served from a free list instead of growing the pool
    pub reuses: usize,
    pub frees: usize,
    /// precision-class changes observed at sync time (pressure-driven
    /// requantization moved a page down the bit ladder)
    pub retags: usize,
}

/// One layer's slice of a sequence's page table.
#[derive(Debug, Clone, Default)]
struct LayerPages {
    k_fp: Vec<PageId>,
    v_fp: Vec<PageId>,
    k_q: Vec<PageId>,
    v_q: Vec<PageId>,
}

impl LayerPages {
    fn count(&self) -> usize {
        self.k_fp.len() + self.v_fp.len() + self.k_q.len() + self.v_q.len()
    }
}

/// A sequence's page table: frames per layer, per side, fp + quantized.
#[derive(Debug, Clone, Default)]
pub struct SeqPageTable {
    layers: Vec<LayerPages>,
}

impl SeqPageTable {
    /// Total frames mapped by this sequence.
    pub fn pages(&self) -> usize {
        self.layers.iter().map(LayerPages::count).sum()
    }
}

/// The global page allocator + per-sequence page tables.
pub struct PagePool {
    /// tokens per page frame (a multiple of the quant group)
    pub page_tokens: usize,
    kv_dim: usize,
    group: usize,
    /// slot map: `frames[id]` is `Some` while frame `id` is allocated
    frames: Vec<Option<Frame>>,
    /// free lists keyed by (layer, precision class)
    free: BTreeMap<(u16, u8), Vec<PageId>>,
    tables: BTreeMap<u64, SeqPageTable>,
    /// running page-granular byte total of all live frames — maintained
    /// by alloc/release/retag so [`PagePool::modeled_bytes`] is O(1)
    /// (the engine charges it once per admission and per relief round)
    bytes: usize,
    pub stats: PoolStats,
}

impl PagePool {
    pub fn new(page_tokens: usize, kv_dim: usize, group: usize) -> Result<Self> {
        if page_tokens == 0 || page_tokens % group != 0 {
            bail!("page_tokens {page_tokens} must be a positive multiple of \
                   the quant group ({group})");
        }
        Ok(PagePool {
            page_tokens,
            kv_dim,
            group,
            frames: Vec::new(),
            free: BTreeMap::new(),
            tables: BTreeMap::new(),
            bytes: 0,
            stats: PoolStats::default(),
        })
    }

    /// Modeled bytes of one page frame at precision class `bits`.
    pub fn page_bytes(&self, bits: u8) -> usize {
        page_frame_bytes(self.page_tokens, self.kv_dim, self.group, bits)
    }

    /// Frames currently mapped by some sequence.
    pub fn allocated_pages(&self) -> usize {
        self.frames.iter().filter(|f| f.is_some()).count()
    }

    /// Frames ever created (allocated + parked on free lists) — the
    /// pool's high-water mark.
    pub fn frame_count(&self) -> usize {
        self.frames.len()
    }

    /// Page-granular modeled KV bytes of everything currently mapped —
    /// what the engine charges against the memory budget.  O(1): a
    /// running counter maintained by every alloc/release/retag (debug
    /// builds cross-check it against a full frame scan).
    pub fn modeled_bytes(&self) -> usize {
        debug_assert_eq!(
            self.bytes,
            self.frames.iter().flatten().map(|f| self.page_bytes(f.bits)).sum::<usize>(),
            "page byte counter out of sync with the frame table");
        self.bytes
    }

    /// Frames mapped by one sequence (0 if it has no table).
    pub fn owner_pages(&self, owner: u64) -> usize {
        self.tables.get(&owner).map(SeqPageTable::pages).unwrap_or(0)
    }

    /// Reconcile `owner`'s page table with the current contents of its
    /// cache: grow/shrink fp-window pages, append quantized pages as
    /// blocks overflow the window, and retag pages whose blocks a
    /// pressure downshift moved to a narrower precision class.
    ///
    /// Engine-thread only (the data plane mutates during the decode
    /// fan-out; the table catches up here, after the step).
    pub fn sync(&mut self, owner: u64, cache: &SeqKvCache) {
        let mut table = self.tables.remove(&owner).unwrap_or_default();
        if table.layers.len() < cache.layers.len() {
            table.layers.resize_with(cache.layers.len(), LayerPages::default);
        }
        for (li, layer) in cache.layers.iter().enumerate() {
            // move the id vecs out so `self` stays free for alloc/release
            let mut lp = std::mem::take(&mut table.layers[li]);
            let pt = self.page_tokens;
            self.sync_fp(&mut lp.k_fp, li as u16, KvSide::Key, owner,
                         layer.fp_pages(KvSide::Key, pt));
            self.sync_fp(&mut lp.v_fp, li as u16, KvSide::Value, owner,
                         layer.fp_pages(KvSide::Value, pt));
            self.sync_quant(&mut lp.k_q, li as u16, KvSide::Key, owner, layer);
            self.sync_quant(&mut lp.v_q, li as u16, KvSide::Value, owner, layer);
            table.layers[li] = lp;
        }
        self.tables.insert(owner, table);
    }

    fn sync_fp(&mut self, ids: &mut Vec<PageId>, layer: u16, side: KvSide,
               owner: u64, n_pages: usize) {
        while ids.len() < n_pages {
            ids.push(self.alloc(layer, side, 16, owner));
        }
        while ids.len() > n_pages {
            let id = ids.pop().unwrap();
            self.release(id);
        }
    }

    fn sync_quant(&mut self, ids: &mut Vec<PageId>, layer: u16, side: KvSide,
                  owner: u64, cache: &LayerKvCache) {
        let n = cache.quant_pages(side, self.page_tokens);
        for j in 0..n {
            let bits = cache.quant_page_bits(side, j, self.page_tokens);
            if let Some(&id) = ids.get(j) {
                let old = self.frames[id as usize].as_ref().expect("live frame").bits;
                if old != bits {
                    // precision-class change (pressure downshift): retag
                    // the frame and move the byte counter between classes
                    let (ob, nb) = (self.page_bytes(old), self.page_bytes(bits));
                    self.frames[id as usize].as_mut().unwrap().bits = bits;
                    self.bytes = self.bytes - ob + nb;
                    self.stats.retags += 1;
                }
            } else {
                ids.push(self.alloc(layer, side, bits, owner));
            }
        }
        while ids.len() > n {
            let id = ids.pop().unwrap();
            self.release(id);
        }
    }

    /// Release every frame mapped by `owner` (retire or preemption).
    pub fn free_owner(&mut self, owner: u64) {
        let Some(table) = self.tables.remove(&owner) else { return };
        for lp in table.layers {
            for id in lp.k_fp.into_iter().chain(lp.v_fp).chain(lp.k_q).chain(lp.v_q) {
                self.release(id);
            }
        }
    }

    fn alloc(&mut self, layer: u16, side: KvSide, bits: u8, owner: u64) -> PageId {
        self.stats.allocs += 1;
        self.bytes += self.page_bytes(bits);
        let frame = Frame { layer, side, bits, owner };
        if let Some(id) = self.free.get_mut(&(layer, bits)).and_then(Vec::pop) {
            self.stats.reuses += 1;
            self.frames[id as usize] = Some(frame);
            return id;
        }
        let id = self.frames.len() as PageId;
        self.frames.push(Some(frame));
        id
    }

    fn release(&mut self, id: PageId) {
        let f = self.frames[id as usize].take().expect("double free of page frame");
        self.bytes -= self.page_bytes(f.bits);
        self.stats.frees += 1;
        self.free.entry((f.layer, f.bits)).or_default().push(id);
    }
}

/// Modeled bytes of one page frame: `page_tokens × kv_dim` elements at
/// fp16 for `bits == 16`, else the packed-block accounting of the page's
/// `page_tokens / group` blocks — words plus an fp16 (scale, min) pair
/// per group, the same model as `PackedBlock::modeled_bytes` (without
/// per-element outliers, which stay a monolithic-accounting detail).
pub fn page_frame_bytes(page_tokens: usize, kv_dim: usize, group: usize,
                        bits: u8) -> usize {
    let elems = page_tokens * kv_dim;
    if bits == 16 {
        return elems * 2;
    }
    let block_elems = group * kv_dim;
    let blocks = page_tokens / group;
    blocks * (words_for(block_elems, bits) * 4 + (block_elems / group) * 4)
}

#[cfg(test)]
mod tests {
    use super::super::testutil::filled_cache as filled;
    use super::*;
    use crate::config::{ModelConfig, QuantPlan};
    use crate::util::Rng;

    const PT: usize = 64;

    #[test]
    fn rejects_misaligned_page_size() {
        assert!(PagePool::new(0, 16, 32).is_err());
        assert!(PagePool::new(48, 16, 32).is_err()); // not a group multiple
        assert!(PagePool::new(64, 16, 32).is_ok());
    }

    #[test]
    fn partial_pages_charge_whole_frames() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let c = filled(&m, &plan, 96, 1); // 3 blocks/side: 2 pages, one partial
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.sync(7, &c);
        // 2 layers x 2 sides x 2 pages, no fp pages under WindowPolicy::None
        assert_eq!(pool.allocated_pages(), 8);
        assert_eq!(pool.owner_pages(7), 8);
        assert_eq!(pool.modeled_bytes(), 8 * pool.page_bytes(2));
        // page-granular charge strictly exceeds the exact modeled bytes:
        // the partial page's missing block is the fragmentation cost
        assert!(pool.modeled_bytes() > c.modeled_bytes(),
                "pool {} must exceed exact {}", pool.modeled_bytes(), c.modeled_bytes());
    }

    #[test]
    fn fp_window_pages_then_quant_pages() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2); // RPC window
        let mut c = SeqKvCache::new(&m, &plan);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        let kv = m.kv_dim();
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            for l in &mut c.layers {
                l.append(&rng.normal_vec(kv), &rng.normal_vec(kv), 1);
            }
        }
        pool.sync(1, &c);
        // 20 fp tokens: one fp page per side per layer, no quant pages yet
        assert_eq!(pool.allocated_pages(), m.n_layers * 2);
        assert_eq!(pool.modeled_bytes(), m.n_layers * 2 * pool.page_bytes(16));
        for _ in 0..180 {
            for l in &mut c.layers {
                l.append(&rng.normal_vec(kv), &rng.normal_vec(kv), 1);
            }
        }
        pool.sync(1, &c);
        let expect: usize = c.layers.iter().map(|l| {
            KV_SIDES.iter().map(|&s| l.fp_pages(s, PT) + l.quant_pages(s, PT))
                .sum::<usize>()
        }).sum();
        assert_eq!(pool.allocated_pages(), expect);
        assert!(c.layers[0].quant_pages(KvSide::Key, PT) > 0, "history must page");
    }

    #[test]
    fn free_lists_recycle_frames() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let c = filled(&m, &plan, 128, 2);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.sync(0, &c);
        let high_water = pool.frame_count();
        pool.free_owner(0);
        assert_eq!(pool.allocated_pages(), 0);
        assert_eq!(pool.modeled_bytes(), 0);
        let c2 = filled(&m, &plan, 128, 3);
        pool.sync(1, &c2);
        assert_eq!(pool.frame_count(), high_water, "frames must be reused, not regrown");
        assert!(pool.stats.reuses > 0);
        assert_eq!(pool.allocated_pages(), pool.owner_pages(1));
    }

    #[test]
    fn sync_retags_downshifted_pages() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let mut c = filled(&m, &plan, 128, 4);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.sync(0, &c);
        let before = pool.modeled_bytes();
        let saved = c.layers[0].requant_page(KvSide::Key, 0, PT, 2);
        assert!(saved > 0);
        pool.sync(0, &c);
        assert_eq!(pool.stats.retags, 1);
        assert_eq!(pool.modeled_bytes(),
                   before - (pool.page_bytes(4) - pool.page_bytes(2)));
    }

    #[test]
    fn page_frame_bytes_model() {
        // fp16: tokens x channels x 2B
        assert_eq!(page_frame_bytes(64, 16, 32, 16), 64 * 16 * 2);
        // 2-bit: 2 blocks of 512 elems -> 32 words + 16 groups each
        assert_eq!(page_frame_bytes(64, 16, 32, 2), 2 * (32 * 4 + 16 * 4));
        // narrower bits, smaller frames
        assert!(page_frame_bytes(64, 16, 32, 1) < page_frame_bytes(64, 16, 32, 2));
        assert!(page_frame_bytes(64, 16, 32, 2) < page_frame_bytes(64, 16, 32, 4));
        assert!(page_frame_bytes(64, 16, 32, 4) < page_frame_bytes(64, 16, 32, 8));
        assert!(page_frame_bytes(64, 16, 32, 8) < page_frame_bytes(64, 16, 32, 16));
    }
}
