//! The global paged KV pool (DESIGN.md §Memory-Manager) and its
//! shared-prefix index (DESIGN.md §Prefix-Sharing).
//!
//! Fixed `page_tokens`-token page frames, per-layer per-precision free
//! lists, and per-sequence page tables mapping each sequence's cache onto
//! the pool — the substrate the paper's Fig. 7/8 efficiency story assumes
//! (KV memory as the scarce serving resource) and that KVTuner-style
//! layer-wise allocation and query-aware schemes take for granted.
//!
//! **Division of labour.**  The per-sequence [`LayerKvCache`] stays the
//! *data plane*: it owns the fp windows and the packed blocks the
//! attention kernels read, and the decode fan-out keeps handing disjoint
//! `&mut` lanes to pool workers (DESIGN.md §Threading-Model) with no new
//! shared state.  The `PagePool` is the *control plane*: the allocator
//! and the accountant.  After every engine step — on the engine thread,
//! like vLLM's scheduler-side block manager — [`PagePool::sync`]
//! reconciles each sequence's page table against its cache and
//! [`crate::kvcache::MemoryBudget`] is charged `PagePool::modeled_bytes`,
//! i.e. at **page granularity**: a partially-filled page costs a whole
//! frame, which is exactly the fragmentation a real paged allocator pays
//! (and what the monolithic per-sequence accounting hides).
//!
//! A page frame covers `page_tokens` tokens of **one side** (K or V) of
//! **one layer** at **one precision class**: `16` (fp16 window pages) or
//! a packed bit width.  Freed frames park on a `(layer, precision)` free
//! list and are reused before the pool grows — observable via
//! [`PoolStats::reuses`].
//!
//! **Frame ownership is refcounted**, not exclusive: with the prefix
//! cache enabled ([`PagePool::enable_prefix_cache`]) the same quantized
//! prefix frame can be mapped by several sequences' page tables *and*
//! pinned by the prefix index, and [`PagePool::modeled_bytes`] charges it
//! **once** — that deduplication is the whole point.  A frame is freed
//! only when its last reference is released.  The data-plane counterpart
//! of a shared frame is an `Arc<PackedBlock>` with refcount > 1; the one
//! mutation path (a pressure downshift) copy-on-writes at the cache level
//! and [`PagePool::sync`] observes the split here, swapping the
//! sequence's mapping from the shared frame to a private one
//! ([`PoolStats::cow_splits`]).
//!
//! Not paged (charged by the monolithic path only, noted here so the
//! accounting difference is explicit): QJL's sign-bit JL key store, and
//! KVQuant's per-element outlier list.  Both are baseline-only details;
//! the KVmix policies the pool exists for use neither.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::quant::{words_for, PackedBlock};

use super::cache::LayerKvCache;
use super::spill::SpillTier;
use super::SeqKvCache;

/// Default `--page-tokens` when paging is enabled (2 quant groups).
pub const DEFAULT_PAGE_TOKENS: usize = 64;

/// Which half of the KV cache a page holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvSide {
    Key,
    Value,
}

/// Both sides, in the fixed scan order used everywhere (K before V).
pub const KV_SIDES: [KvSide; 2] = [KvSide::Key, KvSide::Value];

/// Index of a page frame in the pool (stable across free + reuse).
pub type PageId = u32;

/// Residency of one page frame (DESIGN.md §Spill-Tier).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameState {
    /// packed bytes live in the owning cache's blocks
    Resident,
    /// packed bytes live in the spill tier at this extent; the cache
    /// holds zero-byte stubs and the frame leaves `modeled_bytes` until
    /// it faults back
    Spilled { off: u64, len: u32 },
}

/// Metadata of one live page frame.
#[derive(Debug, Clone)]
pub struct Frame {
    pub layer: u16,
    pub side: KvSide,
    /// precision class: 16 = fp16 window page, else packed bit width
    pub bits: u8,
    /// mappings holding this frame: owning page tables + the prefix
    /// index.  1 = exclusively owned (the pre-prefix-sharing invariant);
    /// freed only when the count reaches 0.
    pub refs: u32,
    /// residency — spilled frames stay in the table (same id, same
    /// bits class) but are charged to the disk tier, not the budget
    pub state: FrameState,
}

/// Allocation / lifecycle counters.
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub allocs: usize,
    /// allocs served from a free list instead of growing the pool
    pub reuses: usize,
    pub frees: usize,
    /// precision-class changes observed at sync time (pressure-driven
    /// requantization moved a page down the bit ladder)
    pub retags: usize,
    /// copy-on-write splits observed at sync time: a sequence downshifted
    /// a *shared* page, so its mapping moved from the shared frame to a
    /// private frame at the new class (DESIGN.md §Prefix-Sharing)
    pub cow_splits: usize,
    /// prefix-index lookups that adopted shared pages
    pub prefix_hits: usize,
    /// prefixes registered into the index
    pub prefix_insertions: usize,
    /// LRU prefix entries evicted under memory pressure
    pub prefix_evictions: usize,
    /// sealed cold pages written to the spill tier
    pub spills: usize,
    /// spilled pages faulted back before an attend
    pub spill_faults: usize,
}

/// One layer's slice of a sequence's page table.
#[derive(Debug, Clone, Default)]
struct LayerPages {
    k_fp: Vec<PageId>,
    v_fp: Vec<PageId>,
    k_q: Vec<PageId>,
    v_q: Vec<PageId>,
}

impl LayerPages {
    fn count(&self) -> usize {
        self.k_fp.len() + self.v_fp.len() + self.k_q.len() + self.v_q.len()
    }
}

/// A sequence's page table: frames per layer, per side, fp + quantized.
#[derive(Debug, Clone, Default)]
pub struct SeqPageTable {
    layers: Vec<LayerPages>,
}

impl SeqPageTable {
    /// Total frames mapped by this sequence.
    pub fn pages(&self) -> usize {
        self.layers.iter().map(LayerPages::count).sum()
    }
}

/// One registered shareable prefix (DESIGN.md §Prefix-Sharing): the
/// quantized pages of a whole-page-aligned prompt prefix, pinned by the
/// index so later admissions can map them without re-quantizing.  The
/// entry holds both the frame references (accounting) and the
/// `Arc<PackedBlock>` handles (data) — dropping the entry releases both,
/// which is what makes index eviction a memory-relief rung.
struct PrefixEntry {
    /// frames in scan order: layer-major, all K pages then all V pages
    frames: Vec<PageId>,
    /// shared blocks per layer: (K blocks, V blocks), `pages·bpp` each
    blocks: Vec<(Vec<Arc<PackedBlock>>, Vec<Arc<PackedBlock>>)>,
    /// prefix length in tokens (== key length)
    tokens: usize,
    /// logical tick of the last registration/hit — LRU eviction order
    last_used: u64,
}

/// The global page allocator + per-sequence page tables + prefix index.
pub struct PagePool {
    /// tokens per page frame (a multiple of the quant group)
    pub page_tokens: usize,
    kv_dim: usize,
    group: usize,
    /// slot map: `frames[id]` is `Some` while frame `id` is allocated
    frames: Vec<Option<Frame>>,
    /// free lists keyed by (layer, precision class)
    free: BTreeMap<(u16, u8), Vec<PageId>>,
    tables: BTreeMap<u64, SeqPageTable>,
    /// shared-prefix index keyed by the exact prefix token ids (collision
    /// proof by construction); `None` = prefix cache disabled, in which
    /// case every prefix entry point below is a no-op — the
    /// `--prefix-cache`-off bit-compatibility guarantee
    prefix: Option<BTreeMap<Vec<i32>, PrefixEntry>>,
    /// logical clock for prefix LRU ordering
    tick: u64,
    /// disk tier for sealed cold pages (`--spill-dir`); `None` = the
    /// spill rung is inert (DESIGN.md §Spill-Tier)
    spill: Option<SpillTier>,
    /// live frames currently in `FrameState::Spilled` — the O(1) guard
    /// that lets `fault_back_owner` early-return on the hot path
    spilled_live: usize,
    /// running byte total of all live frames, each counted ONCE however
    /// many references it has — maintained by alloc/release/retag so
    /// [`PagePool::modeled_bytes`] is O(1) (the engine charges it once
    /// per admission and per relief round)
    bytes: usize,
    pub stats: PoolStats,
}

impl PagePool {
    pub fn new(page_tokens: usize, kv_dim: usize, group: usize) -> Result<Self> {
        if page_tokens == 0 || page_tokens % group != 0 {
            bail!("page_tokens {page_tokens} must be a positive multiple of \
                   the quant group ({group})");
        }
        Ok(PagePool {
            page_tokens,
            kv_dim,
            group,
            frames: Vec::new(),
            free: BTreeMap::new(),
            tables: BTreeMap::new(),
            prefix: None,
            tick: 0,
            spill: None,
            spilled_live: 0,
            bytes: 0,
            stats: PoolStats::default(),
        })
    }

    /// Turn on the disk spill tier (`--spill-dir`, `--spill-bytes`):
    /// sealed, exclusively-owned cold pages become spillable as the
    /// pressure ladder's rung below downshift/eviction
    /// (DESIGN.md §Spill-Tier).  `cap_bytes == 0` means uncapped.
    pub fn enable_spill(&mut self, dir: &Path, cap_bytes: usize) -> Result<()> {
        if self.spill.is_none() {
            self.spill = Some(SpillTier::new(dir, cap_bytes)?);
        }
        Ok(())
    }

    pub fn spill_enabled(&self) -> bool {
        self.spill.is_some()
    }

    /// Live frames currently parked in the spill tier.
    pub fn spilled_pages(&self) -> usize {
        self.spilled_live
    }

    /// Bytes of live spilled extents on disk (0 when disabled).
    pub fn spill_used_bytes(&self) -> usize {
        self.spill.as_ref().map(SpillTier::used).unwrap_or(0)
    }

    /// Turn on the shared-prefix index (`--prefix-cache`).  Off by
    /// default: without this call `adopt_prefix` / `register_prefix` /
    /// `evict_lru_prefix` are inert and the pool behaves exactly as the
    /// exclusive-ownership PR 3 allocator.
    pub fn enable_prefix_cache(&mut self) {
        if self.prefix.is_none() {
            self.prefix = Some(BTreeMap::new());
        }
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Registered prefix entries currently pinned by the index.
    pub fn prefix_entries(&self) -> usize {
        self.prefix.as_ref().map(BTreeMap::len).unwrap_or(0)
    }

    /// Modeled bytes of one page frame at precision class `bits`.
    pub fn page_bytes(&self, bits: u8) -> usize {
        page_frame_bytes(self.page_tokens, self.kv_dim, self.group, bits)
    }

    /// Frames currently live (mapped by a sequence or pinned by the
    /// prefix index) — each counted once regardless of reference count.
    pub fn allocated_pages(&self) -> usize {
        self.frames.iter().filter(|f| f.is_some()).count()
    }

    /// Frames ever created (allocated + parked on free lists) — the
    /// pool's high-water mark.
    pub fn frame_count(&self) -> usize {
        self.frames.len()
    }

    /// Page-granular modeled KV bytes of everything currently live —
    /// what the engine charges against the memory budget.  Shared frames
    /// count **once** (the prefix-sharing deduplication).  O(1): a
    /// running counter maintained by every alloc/release/retag (debug
    /// builds cross-check it against a full frame scan).
    pub fn modeled_bytes(&self) -> usize {
        debug_assert_eq!(
            self.bytes,
            self.frames.iter().flatten()
                .filter(|f| f.state == FrameState::Resident)
                .map(|f| self.page_bytes(f.bits)).sum::<usize>(),
            "page byte counter out of sync with the frame table");
        self.bytes
    }

    /// Frames mapped by one sequence (0 if it has no table).  Shared
    /// frames count toward every mapping sequence here — this is the
    /// *exclusive-cost* view; `modeled_bytes` is the deduplicated one.
    pub fn owner_pages(&self, owner: u64) -> usize {
        self.tables.get(&owner).map(SeqPageTable::pages).unwrap_or(0)
    }

    /// Reconcile `owner`'s page table with the current contents of its
    /// cache: grow/shrink fp-window pages, append quantized pages as
    /// blocks overflow the window, retag pages whose blocks a pressure
    /// downshift moved to a narrower precision class, and split mappings
    /// whose shared page the cache copy-on-wrote.
    ///
    /// Engine-thread only (the data plane mutates during the decode
    /// fan-out; the table catches up here, after the step).
    pub fn sync(&mut self, owner: u64, cache: &SeqKvCache) {
        let mut table = self.tables.remove(&owner).unwrap_or_default();
        if table.layers.len() < cache.layers.len() {
            table.layers.resize_with(cache.layers.len(), LayerPages::default);
        }
        for (li, layer) in cache.layers.iter().enumerate() {
            // move the id vecs out so `self` stays free for alloc/release
            let mut lp = std::mem::take(&mut table.layers[li]);
            let pt = self.page_tokens;
            self.sync_fp(&mut lp.k_fp, li as u16, KvSide::Key,
                         layer.fp_pages(KvSide::Key, pt));
            self.sync_fp(&mut lp.v_fp, li as u16, KvSide::Value,
                         layer.fp_pages(KvSide::Value, pt));
            self.sync_quant(&mut lp.k_q, li as u16, KvSide::Key, layer);
            self.sync_quant(&mut lp.v_q, li as u16, KvSide::Value, layer);
            table.layers[li] = lp;
        }
        self.tables.insert(owner, table);
    }

    fn sync_fp(&mut self, ids: &mut Vec<PageId>, layer: u16, side: KvSide,
               n_pages: usize) {
        while ids.len() < n_pages {
            ids.push(self.alloc(layer, side, 16));
        }
        while ids.len() > n_pages {
            let id = ids.pop().unwrap();
            self.release(id);
        }
    }

    fn sync_quant(&mut self, ids: &mut Vec<PageId>, layer: u16, side: KvSide,
                  cache: &LayerKvCache) {
        let n = cache.quant_pages(side, self.page_tokens);
        for j in 0..n {
            let bits = cache.quant_page_bits(side, j, self.page_tokens);
            if let Some(&id) = ids.get(j) {
                let f = self.frames[id as usize].as_ref().expect("live frame");
                if f.bits == bits {
                    continue;
                }
                if f.refs > 1 {
                    // the cache copy-on-wrote this shared page (shared
                    // frames are never mutated in place): drop this
                    // sequence's reference to the shared frame and map a
                    // private frame at the new class instead
                    self.release(id);
                    ids[j] = self.alloc(layer, side, bits);
                    self.stats.cow_splits += 1;
                } else {
                    // precision-class change (pressure downshift): retag
                    // the frame and move the byte counter between classes
                    let (ob, nb) = (self.page_bytes(f.bits), self.page_bytes(bits));
                    self.frames[id as usize].as_mut().unwrap().bits = bits;
                    self.bytes = self.bytes - ob + nb;
                    self.stats.retags += 1;
                }
            } else {
                ids.push(self.alloc(layer, side, bits));
            }
        }
        while ids.len() > n {
            let id = ids.pop().unwrap();
            self.release(id);
        }
    }

    /// Release every frame mapped by `owner` (retire or preemption).
    /// Frames shared with the prefix index or other sequences only lose
    /// one reference and stay live — preemption must not free shared
    /// frames (DESIGN.md §Prefix-Sharing).
    pub fn free_owner(&mut self, owner: u64) {
        let Some(table) = self.tables.remove(&owner) else { return };
        for lp in table.layers {
            for id in lp.k_fp.into_iter().chain(lp.v_fp).chain(lp.k_q).chain(lp.v_q) {
                self.release(id);
            }
        }
    }

    // ----------------- shared-prefix index -----------------

    /// Longest registered whole-page prefix of `prompt` (at most
    /// `cap_tokens`), in tokens — the read-only probe the batcher's
    /// admission projection uses to book only unshared suffix bytes.
    /// No LRU touch, no adoption; 0 = miss or disabled.
    pub fn probe_prefix(&self, prompt: &[i32], cap_tokens: usize) -> usize {
        let Some(index) = self.prefix.as_ref() else { return 0 };
        let pt = self.page_tokens;
        for pages in (1..=cap_tokens.min(prompt.len()) / pt).rev() {
            if index.contains_key(&prompt[..pages * pt]) {
                return pages * pt;
            }
        }
        0
    }

    /// Adopt the longest registered whole-page prefix of `prompt` (at
    /// most `cap_tokens`, the caller's `SeqKvCache::max_shareable_prefix`
    /// bound): clone the entry's shared blocks into `cache` as its oldest
    /// quantized history and map the shared frames into `owner`'s page
    /// table.  Returns the adopted token count (0 = miss or disabled).
    ///
    /// Must run on a fresh cache before prefill; the caller then prefills
    /// only the unshared suffix via `append_prefill_suffix`.
    pub fn adopt_prefix(&mut self, owner: u64, prompt: &[i32], cap_tokens: usize,
                        cache: &mut SeqKvCache) -> usize {
        let hit = self.probe_prefix(prompt, cap_tokens);
        if hit == 0 {
            return 0;
        }
        self.tick += 1;
        let tick = self.tick;
        let (frames, hit) = {
            let entry = self.prefix.as_mut().unwrap().get_mut(&prompt[..hit]).unwrap();
            entry.last_used = tick;
            for (li, (kb, vb)) in entry.blocks.iter().enumerate() {
                cache.layers[li].adopt_shared_blocks(KvSide::Key, kb);
                cache.layers[li].adopt_shared_blocks(KvSide::Value, vb);
            }
            (entry.frames.clone(), entry.tokens)
        };
        // map the shared frames into the owner's (fresh) page table, in
        // the entry's layer-major K-before-V page order
        let pages = hit / self.page_tokens;
        let n_layers = cache.layers.len();
        debug_assert_eq!(frames.len(), n_layers * 2 * pages);
        let mut table = self.tables.remove(&owner).unwrap_or_default();
        debug_assert_eq!(table.pages(), 0, "prefix adoption needs a fresh table");
        table.layers.resize_with(n_layers, LayerPages::default);
        for li in 0..n_layers {
            let base = li * 2 * pages;
            table.layers[li].k_q.extend_from_slice(&frames[base..base + pages]);
            table.layers[li].v_q.extend_from_slice(&frames[base + pages..base + 2 * pages]);
        }
        self.tables.insert(owner, table);
        for id in frames {
            self.retain(id);
        }
        self.stats.prefix_hits += 1;
        hit
    }

    /// Register `owner`'s whole-page-aligned prompt prefixes (at most
    /// `cap_tokens`) into the index, pinning their quantized pages: each
    /// entry clones the cache's block `Arc`s and takes a reference on
    /// each frame.  **Every** page-aligned sub-prefix gets an entry, not
    /// just the longest — a later request sharing only the system-prompt
    /// head must hit even when this donor's private tail crosses a page
    /// boundary.  Nested entries share the same frames/`Arc`s (extra
    /// references, no extra pages), at O(pages²) handle cost per donor —
    /// fine at system-prompt scale, and each sub-prefix is independently
    /// LRU-evictable.
    ///
    /// Must run right after the owner's post-prefill [`PagePool::sync`]
    /// — at that point every donated page is still at the plan's width,
    /// and the index references then keep it pristine (shared pages are
    /// downshift-exempt and copy-on-write).  Returns `false` on complete
    /// no-op (disabled, sub-page prefix, or everything already
    /// registered — which refreshes those entries' LRU stamps).
    pub fn register_prefix(&mut self, owner: u64, prompt: &[i32], cap_tokens: usize,
                           cache: &SeqKvCache) -> bool {
        if self.prefix.is_none() {
            return false;
        }
        let pt = self.page_tokens;
        let max_pages = cap_tokens.min(prompt.len()) / pt;
        let mut inserted = false;
        for pages in 1..=max_pages {
            inserted |= self.register_one_prefix(owner, prompt, pages, cache);
        }
        inserted
    }

    /// Register the exact `pages`-page prefix of `prompt` (one entry).
    fn register_one_prefix(&mut self, owner: u64, prompt: &[i32], pages: usize,
                           cache: &SeqKvCache) -> bool {
        let pt = self.page_tokens;
        let aligned = pages * pt;
        self.tick += 1;
        let tick = self.tick;
        if let Some(entry) = self.prefix.as_mut().unwrap().get_mut(&prompt[..aligned]) {
            entry.last_used = tick;
            return false;
        }
        let bpp = pt / self.group;
        let mut blocks = Vec::with_capacity(cache.layers.len());
        for l in &cache.layers {
            let (kb, vb) = (l.quant_blocks(KvSide::Key), l.quant_blocks(KvSide::Value));
            if kb.len() < pages * bpp || vb.len() < pages * bpp {
                return false; // cap should prevent this; stay safe
            }
            if kb[..pages * bpp].iter().chain(&vb[..pages * bpp])
                .any(|b| b.words.is_empty() && b.n > 0)
            {
                return false; // spilled stubs never register (no bytes to pin)
            }
            blocks.push((kb[..pages * bpp].to_vec(), vb[..pages * bpp].to_vec()));
        }
        let frames: Vec<PageId> = {
            let Some(table) = self.tables.get(&owner) else { return false };
            if table.layers.len() < cache.layers.len() {
                return false; // owner not synced yet
            }
            let mut frames = Vec::with_capacity(cache.layers.len() * 2 * pages);
            for li in 0..cache.layers.len() {
                let lp = &table.layers[li];
                if lp.k_q.len() < pages || lp.v_q.len() < pages {
                    return false;
                }
                frames.extend_from_slice(&lp.k_q[..pages]);
                frames.extend_from_slice(&lp.v_q[..pages]);
            }
            frames
        };
        for &id in &frames {
            self.retain(id);
        }
        self.prefix.as_mut().unwrap().insert(
            prompt[..aligned].to_vec(),
            PrefixEntry { frames, blocks, tokens: aligned, last_used: tick });
        self.stats.prefix_insertions += 1;
        true
    }

    /// Evict the least-recently-used prefix entry, releasing its frame
    /// references and dropping its block `Arc`s (which may turn the
    /// surviving holders into sole owners, making those pages
    /// downshiftable again).  Returns the bytes actually freed (0 when
    /// every frame is still mapped by an active sequence), or `None`
    /// when the index is empty/disabled.
    pub fn evict_lru_prefix(&mut self) -> Option<usize> {
        let index = self.prefix.as_mut()?;
        let key = index.iter().min_by_key(|(_, e)| e.last_used)?.0.clone();
        let entry = index.remove(&key).unwrap();
        let before = self.bytes;
        for id in entry.frames {
            self.release(id);
        }
        self.stats.prefix_evictions += 1;
        Some(before - self.bytes)
    }

    /// Bytes the index could free if *every* entry were evicted: frames
    /// whose only references come from prefix entries.  The engine adds
    /// this to the downshift bound when gating admission-time relief.
    pub fn prefix_reclaimable_bytes(&self) -> usize {
        let Some(index) = self.prefix.as_ref() else { return 0 };
        let mut index_refs: BTreeMap<PageId, u32> = BTreeMap::new();
        for entry in index.values() {
            for &id in &entry.frames {
                *index_refs.entry(id).or_default() += 1;
            }
        }
        index_refs.iter()
            .filter_map(|(&id, &n)| {
                let f = self.frames[id as usize].as_ref()?;
                (f.refs == n).then(|| self.page_bytes(f.bits))
            })
            .sum()
    }

    // ----------------- spill tier (DESIGN.md §Spill-Tier) -----------------

    /// Spill one sealed cold page of `owner` to the disk tier: serialize
    /// its packed blocks, park the bytes at an extent, and leave
    /// zero-byte stubs in the cache and a `Spilled` frame in the table.
    /// Returns the modeled bytes freed, or `None` when nothing is
    /// eligible (tier disabled/full, or every page is unsealed, shared,
    /// fp16, or already spilled).
    ///
    /// Eligibility is deliberately narrow — sealed + exclusively owned
    /// only (docs/adr/008-replica-router-and-spill-tier.md): a shared
    /// page's bytes are read by other sequences and the prefix index,
    /// and an unsealed page is still being appended into.  `newest_first`
    /// picks the scan direction within each (layer, side): parked
    /// sessions spill newest-first (their tail is what a resume replays
    /// anyway), active lanes oldest-first (the paper's cold-history
    /// shape).
    pub fn spill_one(&mut self, owner: u64, cache: &mut SeqKvCache,
                     newest_first: bool) -> Option<usize> {
        self.spill.as_ref()?;
        let pt = self.page_tokens;
        let bpp = pt / self.group;
        let table = self.tables.get(&owner)?;
        let mut pick: Option<(usize, KvSide, usize, PageId)> = None;
        'scan: for (li, lp) in table.layers.iter().enumerate() {
            let layer = &cache.layers[li];
            for &side in &KV_SIDES {
                let ids = match side {
                    KvSide::Key => &lp.k_q,
                    KvSide::Value => &lp.v_q,
                };
                let sealed = layer.sealed_quant_pages(side, pt).min(ids.len());
                let order: Box<dyn Iterator<Item = usize>> = if newest_first {
                    Box::new((0..sealed).rev())
                } else {
                    Box::new(0..sealed)
                };
                for p in order {
                    let f = self.frames[ids[p] as usize].as_ref().expect("live frame");
                    if f.refs != 1 || f.state != FrameState::Resident
                        || layer.quant_page_shared(side, p, pt)
                        || layer.quant_page_spilled(side, p, pt)
                    {
                        continue;
                    }
                    // exact serialized length without mutating: per block
                    // a 28-byte header + the payload vectors (spill.rs)
                    let len: usize = layer.quant_blocks(side)
                        [p * bpp..((p + 1) * bpp).min(layer.quant_blocks(side).len())]
                        .iter()
                        .map(|b| 28 + b.words.len() * 4 + b.scales.len() * 4
                                 + b.mins.len() * 4 + b.outliers.len() * 8)
                        .sum();
                    if !self.spill.as_ref().unwrap().fits(len) {
                        continue;
                    }
                    pick = Some((li, side, p, ids[p]));
                    break 'scan;
                }
            }
        }
        let (li, side, page, id) = pick?;
        let bytes = cache.layers[li].take_spill_page(side, page, pt);
        let tier = self.spill.as_mut().unwrap();
        let (off, len) = match tier.write(&bytes) {
            Ok(extent) => extent,
            Err(_) => {
                // I/O failure: undo the stub swap and report no relief
                cache.layers[li].restore_spill_page(side, page, pt, &bytes);
                return None;
            }
        };
        let f = self.frames[id as usize].as_mut().unwrap();
        debug_assert_eq!(f.state, FrameState::Resident);
        f.state = FrameState::Spilled { off, len };
        let freed = self.page_bytes(f.bits);
        self.bytes -= freed;
        self.spilled_live += 1;
        self.stats.spills += 1;
        Some(freed)
    }

    /// Fault every spilled page of `owner` back before an attend: read
    /// the extents, restore the packed blocks (fresh uids), re-charge
    /// the frames to `modeled_bytes`, and return the extents to the
    /// tier's free list.  Returns the number of pages faulted.  O(1)
    /// when nothing is spilled anywhere (the hot-path case).
    pub fn fault_back_owner(&mut self, owner: u64, cache: &mut SeqKvCache) -> usize {
        if self.spilled_live == 0 {
            return 0;
        }
        let Some(table) = self.tables.get(&owner) else { return 0 };
        let pt = self.page_tokens;
        let mut work: Vec<(usize, KvSide, usize, PageId, u64, u32)> = Vec::new();
        for (li, lp) in table.layers.iter().enumerate() {
            for &side in &KV_SIDES {
                let ids = match side {
                    KvSide::Key => &lp.k_q,
                    KvSide::Value => &lp.v_q,
                };
                for (p, &id) in ids.iter().enumerate() {
                    if let Some(f) = self.frames[id as usize].as_ref() {
                        if let FrameState::Spilled { off, len } = f.state {
                            work.push((li, side, p, id, off, len));
                        }
                    }
                }
            }
        }
        let mut buf = Vec::new();
        for &(li, side, page, id, off, len) in &work {
            self.spill.as_ref().expect("spilled frame without a tier")
                .read(off, len, &mut buf)
                .expect("spill tier read failed on fault-back");
            cache.layers[li].restore_spill_page(side, page, pt, &buf);
            let f = self.frames[id as usize].as_mut().unwrap();
            f.state = FrameState::Resident;
            self.bytes += self.page_bytes(f.bits);
            self.spill.as_mut().unwrap().release(off, len);
            self.spilled_live -= 1;
            self.stats.spill_faults += 1;
        }
        work.len()
    }

    // ------------- session adoption (DESIGN.md §Serving-Protocol) -------------

    /// Map the first `pages` quantized pages per layer/side of `donor`'s
    /// table into `owner`'s (fresh) table, taking a reference on each —
    /// the pool half of session resume: the engine adopts the parked
    /// cache's prefill-pure prefix blocks into a fresh cache
    /// (`adopt_shared_blocks`) and this mirrors the frames, exactly the
    /// `adopt_prefix` shape without going through the prefix index.
    /// The caller then `free_owner(donor)`s, leaving the adopted frames
    /// at refcount 1 under the new owner.  Returns `false` (no-op) when
    /// the donor is unknown, too short, or still has spilled pages
    /// (fault back first — stubs must never be adopted).
    pub fn adopt_owner_pages(&mut self, donor: u64, owner: u64, pages: usize) -> bool {
        if pages == 0 || donor == owner {
            return false;
        }
        let Some(dt) = self.tables.get(&donor) else { return false };
        let mut per_layer: Vec<(Vec<PageId>, Vec<PageId>)> = Vec::new();
        for lp in &dt.layers {
            if lp.k_q.len() < pages || lp.v_q.len() < pages {
                return false;
            }
            per_layer.push((lp.k_q[..pages].to_vec(), lp.v_q[..pages].to_vec()));
        }
        for (ks, vs) in &per_layer {
            for &id in ks.iter().chain(vs) {
                let f = self.frames[id as usize].as_ref().expect("live frame");
                if f.state != FrameState::Resident {
                    return false;
                }
            }
        }
        let n_layers = per_layer.len();
        let mut table = self.tables.remove(&owner).unwrap_or_default();
        debug_assert_eq!(table.pages(), 0, "session adoption needs a fresh table");
        table.layers.resize_with(n_layers, LayerPages::default);
        for (li, (ks, vs)) in per_layer.into_iter().enumerate() {
            for &id in ks.iter().chain(vs.iter()) {
                self.retain(id);
            }
            table.layers[li].k_q = ks;
            table.layers[li].v_q = vs;
        }
        self.tables.insert(owner, table);
        true
    }

    // ----------------- invariant checking (test support) -----------------

    /// Full-scan audit of the pool's internal invariants, for property
    /// tests (`rust/tests/props.rs`) — the O(1) counters the engine
    /// trusts, re-derived the slow way.  Checks:
    ///
    /// 1. the running `bytes` counter equals a fresh frame scan;
    /// 2. every live frame's `refs` equals its mapping count (page-table
    ///    entries + prefix-index pins) — so refcounts can never
    ///    underflow past a mapping, and no dead frame is still mapped;
    /// 3. free lists hold no duplicates, only dead (`None`) slots, and
    ///    park under the dead frame's own `(layer, bits)` key is not
    ///    checkable (the Frame is gone) — but every parked id must be
    ///    within the slot map.
    ///
    /// Returns a human-readable description of the first violation.
    pub fn verify_accounting(&self) -> Result<(), String> {
        let scanned: usize = self.frames.iter().flatten()
            .filter(|f| f.state == FrameState::Resident)
            .map(|f| self.page_bytes(f.bits)).sum();
        if scanned != self.bytes {
            return Err(format!("byte counter {} != resident frame scan {}",
                               self.bytes, scanned));
        }
        // spill-tier cross-checks: the live-frame view and the tier's
        // used counter must agree, spilled frames are exclusively owned,
        // and the fast-path counter matches a full scan
        let mut spilled = 0usize;
        let mut spilled_bytes = 0usize;
        for (id, f) in self.frames.iter().enumerate() {
            let Some(f) = f else { continue };
            if let FrameState::Spilled { len, .. } = f.state {
                spilled += 1;
                spilled_bytes += len as usize;
                if f.refs != 1 {
                    return Err(format!(
                        "spilled frame {id} has {} refs (must be exclusive)", f.refs));
                }
                if f.bits == 16 {
                    return Err(format!("spilled frame {id} is an fp16 window page"));
                }
            }
        }
        if spilled != self.spilled_live {
            return Err(format!("spilled_live {} != frame scan {spilled}",
                               self.spilled_live));
        }
        let tier_used = self.spill.as_ref().map(SpillTier::used).unwrap_or(0);
        if tier_used != spilled_bytes {
            return Err(format!(
                "spill tier used {tier_used} != live spilled extents {spilled_bytes}"));
        }
        if spilled > 0 && self.spill.is_none() {
            return Err("spilled frames without a spill tier".into());
        }
        let mut expected: BTreeMap<PageId, u32> = BTreeMap::new();
        for (owner, table) in &self.tables {
            for (li, lp) in table.layers.iter().enumerate() {
                for id in lp.k_fp.iter()
                    .chain(&lp.v_fp).chain(&lp.k_q).chain(&lp.v_q)
                {
                    if self.frames.get(*id as usize)
                        .and_then(Option::as_ref).is_none()
                    {
                        return Err(format!(
                            "owner {owner} layer {li} maps dead frame {id}"));
                    }
                    *expected.entry(*id).or_default() += 1;
                }
            }
        }
        for entry in self.prefix.iter().flat_map(BTreeMap::values) {
            for &id in &entry.frames {
                if self.frames.get(id as usize).and_then(Option::as_ref).is_none() {
                    return Err(format!("prefix entry pins dead frame {id}"));
                }
                *expected.entry(id).or_default() += 1;
            }
        }
        for (id, frame) in self.frames.iter().enumerate() {
            let Some(f) = frame else { continue };
            let want = expected.get(&(id as PageId)).copied().unwrap_or(0);
            if f.refs != want {
                return Err(format!(
                    "frame {id} refs {} != {} mappings (tables + prefix pins)",
                    f.refs, want));
            }
            if f.refs == 0 {
                return Err(format!("frame {id} live with zero references"));
            }
        }
        let mut parked: BTreeSet<PageId> = BTreeSet::new();
        for (key, list) in &self.free {
            for &id in list {
                if !parked.insert(id) {
                    return Err(format!("frame {id} parked on two free lists"));
                }
                match self.frames.get(id as usize) {
                    None => return Err(format!(
                        "free list {key:?} holds out-of-range id {id}")),
                    Some(Some(_)) => return Err(format!(
                        "free list {key:?} holds live frame {id}")),
                    Some(None) => {}
                }
            }
        }
        Ok(())
    }

    /// Modeled bytes `free_owner(owner)` would actually reclaim right
    /// now: the owner's mapped frames whose reference count is exactly 1
    /// (frames shared with the prefix index or other sequences survive
    /// the free and reclaim nothing).  Spilled frames count zero — their
    /// bytes already left `modeled_bytes` at spill time, and freeing
    /// them releases a disk extent, not modeled HBM
    /// (DESIGN.md §Spill-Tier).  Test support for the cancellation
    /// accounting property.
    pub fn owner_exclusive_bytes(&self, owner: u64) -> usize {
        let Some(table) = self.tables.get(&owner) else { return 0 };
        table.layers.iter()
            .flat_map(|lp| lp.k_fp.iter().chain(&lp.v_fp).chain(&lp.k_q).chain(&lp.v_q))
            .filter_map(|&id| self.frames[id as usize].as_ref())
            .filter(|f| f.refs == 1 && f.state == FrameState::Resident)
            .map(|f| self.page_bytes(f.bits))
            .sum()
    }

    // ----------------- frame lifecycle -----------------

    fn alloc(&mut self, layer: u16, side: KvSide, bits: u8) -> PageId {
        self.stats.allocs += 1;
        self.bytes += self.page_bytes(bits);
        let frame = Frame { layer, side, bits, refs: 1, state: FrameState::Resident };
        if let Some(id) = self.free.get_mut(&(layer, bits)).and_then(Vec::pop) {
            self.stats.reuses += 1;
            self.frames[id as usize] = Some(frame);
            return id;
        }
        let id = self.frames.len() as PageId;
        self.frames.push(Some(frame));
        id
    }

    fn retain(&mut self, id: PageId) {
        self.frames[id as usize].as_mut().expect("retain of dead frame").refs += 1;
    }

    fn release(&mut self, id: PageId) {
        let f = self.frames[id as usize].as_mut().expect("release of dead frame");
        debug_assert!(f.refs > 0);
        f.refs -= 1;
        if f.refs > 0 {
            return; // still mapped elsewhere (prefix sharing)
        }
        let f = self.frames[id as usize].take().unwrap();
        match f.state {
            FrameState::Resident => self.bytes -= self.page_bytes(f.bits),
            // a parked-session teardown can drop a spilled frame without
            // faulting it back: the extent returns to the tier, the
            // budget was never charged
            FrameState::Spilled { off, len } => {
                self.spill.as_mut().expect("spilled frame without a tier")
                    .release(off, len);
                self.spilled_live -= 1;
            }
        }
        self.stats.frees += 1;
        self.free.entry((f.layer, f.bits)).or_default().push(id);
    }
}

/// Modeled bytes of one page frame: `page_tokens × kv_dim` elements at
/// fp16 for `bits == 16`, else the packed-block accounting of the page's
/// `page_tokens / group` blocks — words plus an fp16 (scale, min) pair
/// per group, the same model as `PackedBlock::modeled_bytes` (without
/// per-element outliers, which stay a monolithic-accounting detail).
pub fn page_frame_bytes(page_tokens: usize, kv_dim: usize, group: usize,
                        bits: u8) -> usize {
    let elems = page_tokens * kv_dim;
    if bits == 16 {
        return elems * 2;
    }
    let block_elems = group * kv_dim;
    let blocks = page_tokens / group;
    blocks * (words_for(block_elems, bits) * 4 + (block_elems / group) * 4)
}

#[cfg(test)]
mod tests {
    use super::super::testutil::filled_cache as filled;
    use super::*;
    use crate::config::{ModelConfig, QuantPlan};
    use crate::util::Rng;

    const PT: usize = 64;

    #[test]
    fn rejects_misaligned_page_size() {
        assert!(PagePool::new(0, 16, 32).is_err());
        assert!(PagePool::new(48, 16, 32).is_err()); // not a group multiple
        assert!(PagePool::new(64, 16, 32).is_ok());
    }

    #[test]
    fn partial_pages_charge_whole_frames() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let c = filled(&m, &plan, 96, 1); // 3 blocks/side: 2 pages, one partial
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.sync(7, &c);
        // 2 layers x 2 sides x 2 pages, no fp pages under WindowPolicy::None
        assert_eq!(pool.allocated_pages(), 8);
        assert_eq!(pool.owner_pages(7), 8);
        assert_eq!(pool.modeled_bytes(), 8 * pool.page_bytes(2));
        // page-granular charge strictly exceeds the exact modeled bytes:
        // the partial page's missing block is the fragmentation cost
        assert!(pool.modeled_bytes() > c.modeled_bytes(),
                "pool {} must exceed exact {}", pool.modeled_bytes(), c.modeled_bytes());
    }

    #[test]
    fn fp_window_pages_then_quant_pages() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2); // RPC window
        let mut c = SeqKvCache::new(&m, &plan);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        let kv = m.kv_dim();
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            for l in &mut c.layers {
                l.append(&rng.normal_vec(kv), &rng.normal_vec(kv), 1);
            }
        }
        pool.sync(1, &c);
        // 20 fp tokens: one fp page per side per layer, no quant pages yet
        assert_eq!(pool.allocated_pages(), m.n_layers * 2);
        assert_eq!(pool.modeled_bytes(), m.n_layers * 2 * pool.page_bytes(16));
        for _ in 0..180 {
            for l in &mut c.layers {
                l.append(&rng.normal_vec(kv), &rng.normal_vec(kv), 1);
            }
        }
        pool.sync(1, &c);
        let expect: usize = c.layers.iter().map(|l| {
            KV_SIDES.iter().map(|&s| l.fp_pages(s, PT) + l.quant_pages(s, PT))
                .sum::<usize>()
        }).sum();
        assert_eq!(pool.allocated_pages(), expect);
        assert!(c.layers[0].quant_pages(KvSide::Key, PT) > 0, "history must page");
    }

    #[test]
    fn free_lists_recycle_frames() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let c = filled(&m, &plan, 128, 2);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.sync(0, &c);
        let high_water = pool.frame_count();
        pool.free_owner(0);
        assert_eq!(pool.allocated_pages(), 0);
        assert_eq!(pool.modeled_bytes(), 0);
        let c2 = filled(&m, &plan, 128, 3);
        pool.sync(1, &c2);
        assert_eq!(pool.frame_count(), high_water, "frames must be reused, not regrown");
        assert!(pool.stats.reuses > 0);
        assert_eq!(pool.allocated_pages(), pool.owner_pages(1));
    }

    #[test]
    fn sync_retags_downshifted_pages() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let mut c = filled(&m, &plan, 128, 4);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.sync(0, &c);
        let before = pool.modeled_bytes();
        let saved = c.layers[0].requant_page(KvSide::Key, 0, PT, 2);
        assert!(saved > 0);
        pool.sync(0, &c);
        assert_eq!(pool.stats.retags, 1);
        assert_eq!(pool.stats.cow_splits, 0);
        assert_eq!(pool.modeled_bytes(),
                   before - (pool.page_bytes(4) - pool.page_bytes(2)));
    }

    #[test]
    fn page_frame_bytes_model() {
        // fp16: tokens x channels x 2B
        assert_eq!(page_frame_bytes(64, 16, 32, 16), 64 * 16 * 2);
        // 2-bit: 2 blocks of 512 elems -> 32 words + 16 groups each
        assert_eq!(page_frame_bytes(64, 16, 32, 2), 2 * (32 * 4 + 16 * 4));
        // narrower bits, smaller frames
        assert!(page_frame_bytes(64, 16, 32, 1) < page_frame_bytes(64, 16, 32, 2));
        assert!(page_frame_bytes(64, 16, 32, 2) < page_frame_bytes(64, 16, 32, 4));
        assert!(page_frame_bytes(64, 16, 32, 4) < page_frame_bytes(64, 16, 32, 8));
        assert!(page_frame_bytes(64, 16, 32, 8) < page_frame_bytes(64, 16, 32, 16));
    }

    // ----------------- spill tier -----------------

    fn spill_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("kvmix-pages-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn spill_then_fault_back_round_trips_exact_bytes() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let mut c = filled(&m, &plan, 128, 40);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        let dir = spill_dir("roundtrip");
        pool.enable_spill(&dir, 0).unwrap();
        pool.sync(5, &c);
        let before = pool.modeled_bytes();
        let orig_words: Vec<Vec<u32>> = c.layers[0].quant_blocks(KvSide::Key)
            .iter().map(|b| b.words.clone()).collect();

        let freed = pool.spill_one(5, &mut c, false).expect("a page must spill");
        assert_eq!(freed, pool.page_bytes(2));
        assert_eq!(pool.modeled_bytes(), before - freed,
                   "spilled bytes leave modeled_bytes exactly");
        assert_eq!(pool.spilled_pages(), 1);
        assert!(pool.spill_used_bytes() > 0);
        assert_eq!(pool.stats.spills, 1);
        // oldest-first scan: layer 0, K side, page 0 went first
        assert!(c.layers[0].quant_page_spilled(KvSide::Key, 0, PT));
        pool.verify_accounting().unwrap();
        // sync over the stubbed cache is a no-op (bits survive on stubs)
        pool.sync(5, &c);
        pool.verify_accounting().unwrap();
        assert_eq!(pool.modeled_bytes(), before - freed);

        assert_eq!(pool.fault_back_owner(5, &mut c), 1);
        assert_eq!(pool.modeled_bytes(), before);
        assert_eq!(pool.spilled_pages(), 0);
        assert_eq!(pool.spill_used_bytes(), 0);
        assert_eq!(pool.stats.spill_faults, 1);
        pool.verify_accounting().unwrap();
        for (b, w) in c.layers[0].quant_blocks(KvSide::Key).iter().zip(&orig_words) {
            assert_eq!(&b.words, w, "fault-back is byte-identical");
        }
        drop(pool);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shared_and_unsealed_pages_are_spill_exempt() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let prompt: Vec<i32> = (0..192).collect();
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.enable_prefix_cache();
        let dir = spill_dir("exempt");
        pool.enable_spill(&dir, 0).unwrap();
        let (mut donor, _rec) = share_fixture(&m, &plan, &mut pool, &prompt, 128);
        // pages 0..2 of every layer/side are shared (index + recipient);
        // only page 2 is exclusive, so the first spill must land there
        let freed = pool.spill_one(10, &mut donor, false).expect("exclusive page spills");
        assert!(freed > 0);
        assert!(!donor.layers[0].quant_page_spilled(KvSide::Key, 0, PT));
        assert!(!donor.layers[0].quant_page_spilled(KvSide::Key, 1, PT));
        assert!(donor.layers[0].quant_page_spilled(KvSide::Key, 2, PT));
        pool.verify_accounting().unwrap();
        // registering a prefix over a spilled page is refused
        assert!(!pool.register_prefix(10, &prompt, 192, &donor),
                "spilled pages must not register into the prefix index");
        drop(pool);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_cap_blocks_oversized_tier() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let mut c = filled(&m, &plan, 128, 41);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        let dir = spill_dir("cap");
        pool.enable_spill(&dir, 8).unwrap(); // 8 bytes: nothing fits
        pool.sync(5, &c);
        assert!(pool.spill_one(5, &mut c, false).is_none());
        assert_eq!(pool.spilled_pages(), 0);
        pool.verify_accounting().unwrap();
        drop(pool);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spilled_owner_frees_without_fault_back() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let mut c = filled(&m, &plan, 128, 42);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        let dir = spill_dir("teardown");
        pool.enable_spill(&dir, 0).unwrap();
        pool.sync(5, &c);
        while pool.spill_one(5, &mut c, false).is_some() {}
        assert!(pool.spilled_pages() > 0);
        pool.free_owner(5);
        assert_eq!(pool.modeled_bytes(), 0);
        assert_eq!(pool.spilled_pages(), 0);
        assert_eq!(pool.spill_used_bytes(), 0, "extents returned on teardown");
        pool.verify_accounting().unwrap();
        drop(pool);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn adopt_owner_pages_moves_frames_to_a_new_owner() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let c = filled(&m, &plan, 128, 43);
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.sync(20, &c);
        let before = pool.modeled_bytes();
        assert!(pool.adopt_owner_pages(20, 21, 2));
        pool.verify_accounting().unwrap();
        // shared while both tables exist, charged once
        assert_eq!(pool.modeled_bytes(), before);
        assert_eq!(pool.owner_pages(21), m.n_layers * 2 * 2);
        // the resume shape: donor frees, adopted frames survive at refs 1
        pool.free_owner(20);
        pool.verify_accounting().unwrap();
        assert_eq!(pool.modeled_bytes(),
                   m.n_layers * 2 * 2 * pool.page_bytes(2));
        assert_eq!(pool.owner_pages(21), m.n_layers * 2 * 2);
        // too-short donors and unknown donors are no-ops
        assert!(!pool.adopt_owner_pages(21, 22, 99));
        assert!(!pool.adopt_owner_pages(77, 22, 1));
        pool.verify_accounting().unwrap();
    }

    // ----------------- prefix-sharing lifecycle -----------------

    /// Donor prefill + register, then a recipient adopt + suffix append,
    /// mirroring the engine's admission sequence at the pool level.
    fn share_fixture(m: &ModelConfig, plan: &QuantPlan, pool: &mut PagePool,
                     prompt: &[i32], shared_tokens: usize)
                     -> (SeqKvCache, SeqKvCache) {
        let kv = m.kv_dim();
        let total = prompt.len();
        let mut rng = Rng::new(0xF00D);
        let k = rng.normal_vec(total * kv);
        let v = rng.normal_vec(total * kv);

        let mut donor = SeqKvCache::new(m, plan);
        for l in &mut donor.layers {
            l.append(&k, &v, total);
        }
        pool.sync(10, &donor);
        let cap = donor.max_shareable_prefix(total, pool.page_tokens);
        assert!(cap >= shared_tokens);
        assert!(pool.register_prefix(10, prompt, shared_tokens, &donor));

        let mut rec = SeqKvCache::new(m, plan);
        let adopted = pool.adopt_prefix(11, prompt, shared_tokens, &mut rec);
        assert_eq!(adopted, shared_tokens);
        for l in &mut rec.layers {
            l.append_prefill_suffix(&k[shared_tokens * kv..], &v[shared_tokens * kv..],
                                    total - shared_tokens, shared_tokens);
        }
        pool.sync(11, &rec);
        (donor, rec)
    }

    #[test]
    fn shared_pages_charge_once() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let prompt: Vec<i32> = (0..192).collect();
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.enable_prefix_cache();
        let (donor, rec) = share_fixture(&m, &plan, &mut pool, &prompt, 128);
        assert_eq!(pool.stats.prefix_hits, 1);
        // recipient state is bit-identical to an exclusive build
        assert_eq!(donor.modeled_bytes(), rec.modeled_bytes());
        // 128 shared tokens = 2 pages/side/layer charged once, not twice:
        // pool bytes < the exclusive sum by exactly the shared frames
        let shared_frames = m.n_layers * 2 * (128 / PT);
        let exclusive = 2 * pool.owner_pages(10) * pool.page_bytes(2);
        assert_eq!(pool.owner_pages(10), pool.owner_pages(11));
        assert_eq!(pool.modeled_bytes(),
                   exclusive - shared_frames * pool.page_bytes(2));
    }

    #[test]
    fn prefix_survives_donor_retirement_until_evicted() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let prompt: Vec<i32> = (100..292).collect();
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.enable_prefix_cache();
        let (_donor, _rec) = share_fixture(&m, &plan, &mut pool, &prompt, 128);
        let shared_frames = m.n_layers * 2 * (128 / PT);

        // donor retires: shared frames stay (index + recipient hold refs)
        pool.free_owner(10);
        let after_donor = pool.modeled_bytes();
        assert!(after_donor >= shared_frames * pool.page_bytes(2));
        // recipient retires too: only index-pinned frames remain (the
        // 128-token registration created nested 64- and 128-token
        // entries; frames shared between them still count once)
        pool.free_owner(11);
        assert_eq!(pool.modeled_bytes(), shared_frames * pool.page_bytes(2));
        assert_eq!(pool.prefix_reclaimable_bytes(), pool.modeled_bytes());
        assert_eq!(pool.prefix_entries(), 2, "nested sub-prefixes both register");
        // evicting the whole index frees everything, one LRU entry at a
        // time (the first eviction can free 0: the longer entry still
        // pins the shared head)
        let mut freed = 0usize;
        while let Some(f) = pool.evict_lru_prefix() {
            freed += f;
        }
        assert_eq!(freed, shared_frames * pool.page_bytes(2));
        assert_eq!(pool.modeled_bytes(), 0);
        assert_eq!(pool.prefix_entries(), 0);
        assert!(pool.evict_lru_prefix().is_none());
    }

    #[test]
    fn sync_observes_cow_split_on_shared_page() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let prompt: Vec<i32> = (0..128).collect();
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        pool.enable_prefix_cache();
        let (donor, mut rec) = share_fixture(&m, &plan, &mut pool, &prompt, 64);
        let before = pool.modeled_bytes();
        let donor_words = donor.layers[0].quant_blocks(KvSide::Key)[0].words.clone();

        // recipient downshifts its copy of the shared page -> CoW
        assert!(rec.layers[0].quant_page_shared(KvSide::Key, 0, PT));
        let saved = rec.layers[0].requant_page(KvSide::Key, 0, PT, 2);
        assert!(saved > 0);
        pool.sync(11, &rec);
        assert_eq!(pool.stats.cow_splits, 1);
        assert_eq!(pool.stats.retags, 0);
        // the donor's bytes are untouched, and the pool now carries the
        // shared 4-bit frame PLUS the recipient's private 2-bit frame
        assert_eq!(donor.layers[0].quant_blocks(KvSide::Key)[0].words, donor_words);
        assert_eq!(pool.modeled_bytes(), before + pool.page_bytes(2));
    }

    #[test]
    fn disabled_prefix_cache_is_inert() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2).without_rpc();
        let prompt: Vec<i32> = (0..128).collect();
        let mut pool = PagePool::new(PT, m.kv_dim(), m.group).unwrap();
        assert!(!pool.prefix_cache_enabled());
        let donor = filled(&m, &plan, 128, 9);
        pool.sync(10, &donor);
        assert!(!pool.register_prefix(10, &prompt, 128, &donor));
        let mut rec = SeqKvCache::new(&m, &plan);
        assert_eq!(pool.adopt_prefix(11, &prompt, 128, &mut rec), 0);
        assert!(rec.is_empty(), "miss must leave the cache untouched");
        assert!(pool.evict_lru_prefix().is_none());
        assert_eq!(pool.prefix_reclaimable_bytes(), 0);
        assert_eq!(pool.stats.prefix_hits + pool.stats.prefix_insertions, 0);
    }
}
