//! QJL baseline substrate: 1-bit Johnson–Lindenstrauss Key representation
//! (Zandieh, Daliri & Han, AAAI 2025 — the paper's strongest "zero
//! constant overhead" comparator).
//!
//! Keys are stored as the sign bits of `R·k` (R a fixed Gaussian JL matrix
//! of `jl_dim` rows) plus one fp16-modeled norm per token.  The inner
//! product is estimated by the QJL estimator
//!
//!   <q, k> ≈ ‖k‖ · (sqrt(π/2) / m) · Σ_j sign(Rk)_j · (Rq)_j ... up to the
//!   estimator's constant; we use the standard form
//!   <q, k> ≈ ‖q‖‖k‖·cos(π·(1 − hamming_agreement)) for sign-JL, which the
//!   QJL paper tightens to the one-sided quantized estimator below.

use crate::util::Rng;

/// Fixed JL projection for one layer (seeded so Rust/Python could agree).
pub struct JlProjector {
    /// [jl_dim, head_dim] row-major
    pub r: Vec<f32>,
    pub jl_dim: usize,
    pub head_dim: usize,
}

impl JlProjector {
    pub fn new(head_dim: usize, jl_dim: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x514e_0bad);
        JlProjector { r: rng.normal_vec(jl_dim * head_dim), jl_dim, head_dim }
    }

    /// Project a head_dim vector; returns jl_dim f32s into `out`.
    pub fn project(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.head_dim);
        debug_assert!(out.len() >= self.jl_dim);
        for j in 0..self.jl_dim {
            let row = &self.r[j * self.head_dim..(j + 1) * self.head_dim];
            let mut acc = 0f32;
            for d in 0..self.head_dim {
                acc += row[d] * x[d];
            }
            out[j] = acc;
        }
    }
}

/// Sign-bit store for one head's keys: packed sign words + per-token norm.
#[derive(Default)]
pub struct SignJlKeys {
    /// ceil(jl_dim/32) words per token, token-major
    pub words: Vec<u32>,
    pub norms: Vec<f32>,
    pub words_per_token: usize,
}

impl SignJlKeys {
    pub fn new(jl_dim: usize) -> Self {
        SignJlKeys { words: Vec::new(), norms: Vec::new(), words_per_token: jl_dim.div_ceil(32) }
    }

    pub fn len(&self) -> usize {
        self.norms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.norms.is_empty()
    }

    /// Append one key (already projected to jl_dim in `proj`), with its
    /// original L2 norm.
    pub fn push(&mut self, proj: &[f32], norm: f32) {
        let mut w = 0u32;
        let mut nbits = 0;
        for (j, &v) in proj.iter().enumerate() {
            if v >= 0.0 {
                w |= 1 << (j % 32);
            }
            nbits += 1;
            if nbits == 32 || j == proj.len() - 1 {
                self.words.push(w);
                w = 0;
                nbits = 0;
            }
        }
        self.norms.push(norm);
    }

    /// QJL inner-product estimates against a projected query `rq`:
    /// score[t] ≈ ‖k_t‖ · (sqrt(π/2)/m) · Σ_j sign_j(k_t)·rq_j
    pub fn scores(&self, rq: &[f32], out: &mut [f32]) {
        let m = rq.len();
        let c = (std::f32::consts::PI / 2.0).sqrt() / m as f32;
        for t in 0..self.len() {
            let words = &self.words[t * self.words_per_token..(t + 1) * self.words_per_token];
            let mut acc = 0f32;
            for (j, &q) in rq.iter().enumerate() {
                let bit = (words[j / 32] >> (j % 32)) & 1;
                acc += if bit == 1 { q } else { -q };
            }
            out[t] += self.norms[t] * c * acc;
        }
    }

    /// Modeled bytes: 1 bit/dim + fp16 norm per token (QJL's zero-constant
    /// claim: no scales/zero-points).
    pub fn modeled_bytes(&self) -> usize {
        self.words.len() * 4 + self.norms.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_correlates_with_true_dot() {
        let hd = 32;
        let jl = JlProjector::new(hd, 128, 7);
        let mut rng = Rng::new(1);
        let q: Vec<f32> = rng.normal_vec(hd);
        let mut rq = vec![0f32; 128];
        jl.project(&q, &mut rq);
        let mut store = SignJlKeys::new(128);
        let mut truth = Vec::new();
        let mut proj = vec![0f32; 128];
        for _ in 0..64 {
            let k: Vec<f32> = rng.normal_vec(hd);
            let dot: f32 = q.iter().zip(&k).map(|(a, b)| a * b).sum();
            truth.push(dot);
            let norm = k.iter().map(|x| x * x).sum::<f32>().sqrt();
            jl.project(&k, &mut proj);
            store.push(&proj, norm);
        }
        let mut est = vec![0f32; 64];
        store.scores(&rq, &mut est);
        // pearson correlation should be strongly positive
        let n = 64f32;
        let (mt, me) = (truth.iter().sum::<f32>() / n, est.iter().sum::<f32>() / n);
        let cov: f32 = truth.iter().zip(&est).map(|(a, b)| (a - mt) * (b - me)).sum();
        let vt: f32 = truth.iter().map(|a| (a - mt) * (a - mt)).sum();
        let ve: f32 = est.iter().map(|b| (b - me) * (b - me)).sum();
        let corr = cov / (vt.sqrt() * ve.sqrt());
        assert!(corr > 0.8, "JL estimator correlation {corr}");
    }

    #[test]
    fn bytes_model() {
        let mut s = SignJlKeys::new(64);
        s.push(&vec![1.0; 64], 1.0);
        assert_eq!(s.modeled_bytes(), 2 * 4 + 2);
    }
}
