//! Memory accounting + the GPU-HBM budget simulator.
//!
//! The paper's efficiency results (Fig. 7/8) are driven by KV-cache bytes
//! per token: the FP16 baseline OOMs at batch 4 on a 24 GB RTX 4090 while
//! KVmix reaches batch 30.  We reproduce the *mechanism* with a
//! configurable memory budget: model weights + per-sequence KV bytes are
//! charged against the budget and an allocation beyond it raises the same
//! admission failure a real allocator would.  Budgets are scaled to the
//! reproduction model (see harness/tables.rs: `--hbm-bytes`).
//!
//! With the paged pool enabled the engine charges
//! [`crate::kvcache::PagePool::modeled_bytes`] here instead of the summed
//! per-sequence bytes — page-granular accounting, see
//! DESIGN.md §Memory-Manager.  A failed [`MemoryBudget::set_kv`] is the
//! pressure controller's trigger; note it records the *attempted* peak
//! but leaves the standing charge untouched (tests below pin both).

use anyhow::{bail, Result};

/// Tracks modeled memory of a serving process.
#[derive(Debug, Clone)]
pub struct MemoryBudget {
    pub capacity: usize,
    pub static_bytes: usize,
    pub kv_bytes: usize,
    pub peak: usize,
}

impl MemoryBudget {
    /// `capacity` = total simulated HBM; `static_bytes` = weights + runtime
    /// overhead charged up-front.
    pub fn new(capacity: usize, static_bytes: usize) -> Result<Self> {
        if static_bytes > capacity {
            bail!("static allocation {static_bytes} exceeds capacity {capacity}");
        }
        Ok(MemoryBudget { capacity, static_bytes, kv_bytes: 0, peak: static_bytes })
    }

    pub fn used(&self) -> usize {
        self.static_bytes + self.kv_bytes
    }

    pub fn free(&self) -> usize {
        self.capacity - self.used()
    }

    /// Charge `bytes` of KV cache; errors (simulated OOM) if over budget.
    pub fn alloc(&mut self, bytes: usize) -> Result<()> {
        if self.used() + bytes > self.capacity {
            bail!("simulated OOM: used {} + {} > capacity {}", self.used(), bytes, self.capacity);
        }
        self.kv_bytes += bytes;
        self.peak = self.peak.max(self.used());
        Ok(())
    }

    pub fn release(&mut self, bytes: usize) {
        self.kv_bytes = self.kv_bytes.saturating_sub(bytes);
    }

    /// Replace the KV charge with a fresh measurement (the engine calls
    /// this after each step with the summed `modeled_bytes`).
    pub fn set_kv(&mut self, bytes: usize) -> Result<()> {
        if self.static_bytes + bytes > self.capacity {
            self.peak = self.peak.max(self.static_bytes + bytes);
            bail!("simulated OOM: kv {} + static {} > capacity {}",
                  bytes, self.static_bytes, self.capacity);
        }
        self.kv_bytes = bytes;
        self.peak = self.peak.max(self.used());
        Ok(())
    }
}

/// fp16-modeled bytes for an unquantized cache of `tokens` tokens
/// (per layer: K and V, `kv_dim` channels, 2 bytes each).
pub fn fp16_kv_bytes(tokens: usize, kv_dim: usize, n_layers: usize) -> usize {
    tokens * kv_dim * 2 * 2 * n_layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oom_at_capacity() {
        let mut m = MemoryBudget::new(1000, 400).unwrap();
        m.alloc(500).unwrap();
        assert!(m.alloc(200).is_err());
        assert_eq!(m.peak, 900);
        m.release(500);
        m.alloc(600).unwrap();
        assert_eq!(m.used(), 1000);
    }

    #[test]
    fn static_over_capacity_rejected() {
        assert!(MemoryBudget::new(100, 200).is_err());
    }

    #[test]
    fn fp16_model() {
        // 100 tokens, kv_dim 64, 8 layers: 100*64*2*2*8
        assert_eq!(fp16_kv_bytes(100, 64, 8), 204_800);
    }

    #[test]
    fn set_kv_failure_keeps_charge_and_records_attempted_peak() {
        let mut m = MemoryBudget::new(1_000, 100).unwrap();
        m.set_kv(500).unwrap();
        assert_eq!(m.peak, 600);
        // over-capacity set_kv: error, standing charge untouched, but the
        // attempted footprint still registers as the peak (the paper's
        // "would have OOMed here" marker)
        assert!(m.set_kv(950).is_err());
        assert_eq!(m.kv_bytes, 500, "failed set_kv must not change the charge");
        assert_eq!(m.used(), 600);
        assert_eq!(m.free(), 400);
        assert_eq!(m.peak, 1_050);
        // recovery: a smaller footprint still lands
        m.set_kv(300).unwrap();
        assert_eq!(m.used(), 400);
        assert_eq!(m.peak, 1_050, "peak is monotone");
    }

    #[test]
    fn release_below_zero_saturates() {
        let mut m = MemoryBudget::new(1_000, 0).unwrap();
        m.alloc(300).unwrap();
        m.release(500); // over-release: saturate at zero, don't wrap
        assert_eq!(m.kv_bytes, 0);
        assert_eq!(m.free(), 1_000);
        m.alloc(1_000).unwrap(); // the full capacity is usable again
        assert_eq!(m.used(), 1_000);
    }

    #[test]
    fn set_kv_zero_clears_charge() {
        let mut m = MemoryBudget::new(1_000, 250).unwrap();
        m.set_kv(700).unwrap();
        m.set_kv(0).unwrap();
        assert_eq!(m.used(), 250);
        assert_eq!(m.peak, 950);
    }
}
