//! Disk spill tier for sealed cold KV pages (DESIGN.md §Spill-Tier).
//!
//! The pressure ladder's new bottom rung before preemption: a sealed,
//! unshared quantized page serializes its packed blocks into one
//! append-mostly file under `--spill-dir`, the in-memory frames shrink
//! to stubs, and the page faults back on the next attend.  I/O is plain
//! positioned pread/pwrite (`std::os::unix::fs::FileExt`) — no mmap, no
//! new dependencies; docs/adr/008-replica-router-and-spill-tier.md
//! records the trade.
//!
//! Extent management is exact-length free-listing: a faulted-back
//! extent is parked under its byte length and reused verbatim by the
//! next spill of an identically-sized page (the common case — pages at
//! one (bits, kv_dim, group) shape all serialize to the same length).
//! The file never shrinks while the tier lives; the whole directory
//! entry is unlinked on drop.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

use crate::quant::PackedBlock;

/// On-disk page store with a byte cap and exact-length extent reuse.
#[derive(Debug)]
pub struct SpillTier {
    file: File,
    path: PathBuf,
    /// cap on live spilled bytes (0 = unlimited)
    cap: usize,
    /// bytes currently holding live spilled pages
    used: usize,
    /// next append offset (monotone; freed extents are reused instead)
    next_off: u64,
    /// freed extents keyed by exact byte length
    free: BTreeMap<u32, Vec<u64>>,
}

impl SpillTier {
    /// Create (truncating) the backing file `kvspill.bin` inside `dir`.
    /// `cap_bytes == 0` means uncapped.
    pub fn new(dir: &Path, cap_bytes: usize) -> io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join("kvspill.bin");
        let file = OpenOptions::new()
            .read(true).write(true).create(true).truncate(true)
            .open(&path)?;
        Ok(SpillTier { file, path, cap: cap_bytes, used: 0, next_off: 0,
                       free: BTreeMap::new() })
    }

    /// Bytes of live spilled pages.
    pub fn used(&self) -> usize {
        self.used
    }

    /// Configured cap (0 = unlimited).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Would a `len`-byte write fit under the cap?
    pub fn fits(&self, len: usize) -> bool {
        self.cap == 0 || self.used + len <= self.cap
    }

    /// Write `bytes` to a free extent of exactly this length, or append.
    /// Returns `(offset, len)`; the caller records both in the frame.
    pub fn write(&mut self, bytes: &[u8]) -> io::Result<(u64, u32)> {
        let len = bytes.len() as u32;
        let off = match self.free.get_mut(&len).and_then(Vec::pop) {
            Some(off) => {
                if self.free.get(&len).is_some_and(Vec::is_empty) {
                    self.free.remove(&len);
                }
                off
            }
            None => {
                let off = self.next_off;
                self.next_off += len as u64;
                off
            }
        };
        self.file.write_all_at(bytes, off)?;
        self.used += bytes.len();
        Ok((off, len))
    }

    /// Read the extent back (fault path).
    pub fn read(&self, off: u64, len: u32, buf: &mut Vec<u8>) -> io::Result<()> {
        buf.clear();
        buf.resize(len as usize, 0);
        self.file.read_exact_at(buf, off)
    }

    /// Return an extent to the free list (fault-back or owner teardown).
    pub fn release(&mut self, off: u64, len: u32) {
        self.used -= len as usize;
        self.free.entry(len).or_default().push(off);
    }
}

impl Drop for SpillTier {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Serialize one packed block: a 7-field u32 LE header
/// (bits|flags, n, group, |words|, |scales|, |mins|, |outliers|) followed
/// by the payload vectors (floats as IEEE-754 bit patterns).  Field 0's
/// low byte is the bit width; bit 8 carries the interleaved-layout flag
/// so a faulted-back Key page keeps its word order.
pub fn encode_block(b: &PackedBlock, out: &mut Vec<u8>) {
    let header = [b.bits as u32 | (b.interleaved as u32) << 8, b.n as u32, b.group as u32,
                  b.words.len() as u32, b.scales.len() as u32,
                  b.mins.len() as u32, b.outliers.len() as u32];
    for w in header {
        out.extend_from_slice(&w.to_le_bytes());
    }
    for &w in &b.words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    for &s in &b.scales {
        out.extend_from_slice(&s.to_bits().to_le_bytes());
    }
    for &m in &b.mins {
        out.extend_from_slice(&m.to_bits().to_le_bytes());
    }
    for &(i, v) in &b.outliers {
        out.extend_from_slice(&i.to_le_bytes());
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Decode one block at `*pos`, advancing it.  Returns `None` on a
/// malformed buffer (truncation).  The restored block carries a fresh
/// uid ([`PackedBlock::from_parts`]) so the fused kernels' unpack cache
/// can never serve stale integers for it.
pub fn decode_block(bytes: &[u8], pos: &mut usize) -> Option<PackedBlock> {
    let u32_at = |bytes: &[u8], p: usize| -> Option<u32> {
        bytes.get(p..p + 4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    };
    let mut p = *pos;
    let mut header = [0u32; 7];
    for h in &mut header {
        *h = u32_at(bytes, p)?;
        p += 4;
    }
    let [bits_flags, n, group, n_words, n_scales, n_mins, n_outliers] = header;
    let bits = bits_flags & 0xFF;
    let interleaved = bits_flags & (1 << 8) != 0;
    let mut read_u32s = |count: u32| -> Option<Vec<u32>> {
        let mut v = Vec::with_capacity(count as usize);
        for _ in 0..count {
            v.push(u32_at(bytes, p)?);
            p += 4;
        }
        Some(v)
    };
    let words = read_u32s(n_words)?;
    let scales: Vec<f32> = read_u32s(n_scales)?.into_iter().map(f32::from_bits).collect();
    let mins: Vec<f32> = read_u32s(n_mins)?.into_iter().map(f32::from_bits).collect();
    let mut outliers = Vec::with_capacity(n_outliers as usize);
    for _ in 0..n_outliers {
        let i = u32_at(bytes, p)?;
        let v = f32::from_bits(u32_at(bytes, p + 4)?);
        outliers.push((i, v));
        p += 8;
    }
    *pos = p;
    Some(PackedBlock::from_parts(bits as u8, n as usize, group as usize, interleaved,
                                 words, scales, mins, outliers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("kvmix-spill-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn block_codec_round_trips_bit_exact() {
        let mut rng = Rng::new(31);
        for bits in [1u8, 2, 3, 4, 8] {
            let data = rng.normal_vec(192);
            let mut b = PackedBlock::default();
            b.quantize_outliers_into(&data, bits, 32, 0.03, &mut Vec::new());
            let mut buf = Vec::new();
            encode_block(&b, &mut buf);
            let mut pos = 0;
            let r = decode_block(&buf, &mut pos).unwrap();
            assert_eq!(pos, buf.len());
            assert_eq!((r.bits, r.n, r.group), (b.bits, b.n, b.group));
            assert_eq!(r.words, b.words);
            assert_eq!(r.scales.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                       b.scales.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
            assert_eq!(r.mins.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                       b.mins.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
            assert_eq!(r.outliers, b.outliers);
            assert_ne!(r.uid, b.uid, "restore must not alias the unpack cache");
        }
    }

    #[test]
    fn block_codec_preserves_interleaved_layout() {
        let mut rng = Rng::new(33);
        let data = rng.normal_vec(256);
        let mut b = PackedBlock::default();
        b.quantize_into_layout(&data, 4, 32, true, &mut Vec::new());
        assert!(b.interleaved);
        let mut buf = Vec::new();
        encode_block(&b, &mut buf);
        let r = decode_block(&buf, &mut 0).unwrap();
        assert!(r.interleaved, "interleave flag must round-trip");
        assert_eq!(r.words, b.words);
        assert_eq!((r.bits, r.n, r.group), (b.bits, b.n, b.group));
    }

    #[test]
    fn decode_rejects_truncation() {
        let b = PackedBlock::quantize(&vec![1.0; 64], 2, 32);
        let mut buf = Vec::new();
        encode_block(&b, &mut buf);
        for cut in [0, 3, 7, buf.len() - 1] {
            assert!(decode_block(&buf[..cut], &mut 0).is_none(), "cut {cut}");
        }
    }

    #[test]
    fn tier_write_read_release_reuses_extents() {
        let dir = tmpdir("extents");
        let mut t = SpillTier::new(&dir, 0).unwrap();
        let (o1, l1) = t.write(&[1u8; 100]).unwrap();
        let (o2, _l2) = t.write(&[2u8; 100]).unwrap();
        assert_ne!(o1, o2);
        assert_eq!(t.used(), 200);
        let mut buf = Vec::new();
        t.read(o1, l1, &mut buf).unwrap();
        assert_eq!(buf, vec![1u8; 100]);
        t.release(o1, l1);
        assert_eq!(t.used(), 100);
        // exact-length reuse: the freed extent is handed back verbatim
        let (o3, l3) = t.write(&[3u8; 100]).unwrap();
        assert_eq!((o3, l3), (o1, l1));
        t.read(o3, l3, &mut buf).unwrap();
        assert_eq!(buf, vec![3u8; 100]);
        // a different length appends instead
        let (o4, _) = t.write(&[4u8; 50]).unwrap();
        assert_eq!(o4, 200);
        drop(t);
        assert!(!dir.join("kvspill.bin").exists(), "backing file unlinked on drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tier_cap_enforced_via_fits() {
        let dir = tmpdir("cap");
        let mut t = SpillTier::new(&dir, 150).unwrap();
        assert!(t.fits(100));
        t.write(&[0u8; 100]).unwrap();
        assert!(!t.fits(100), "second 100B page exceeds the 150B cap");
        assert!(t.fits(50));
        drop(t);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
