//! The pressure controller (DESIGN.md §Memory-Manager): what happens when
//! the paged pool cannot satisfy a charge.
//!
//! On admission failure or simulated OOM the engine first **requantizes
//! the oldest out-of-window pages down the bit ladder** (8 → 4 → 2, with
//! a 3-bit entry rung for plans that start there), bounded below by
//! per-layer floors derived from the gradient-importance profile, and
//! only when every sealed page sits at its floor does it **preempt** the
//! lowest-priority sequence back to the batcher queue.  This makes the
//! paper's dynamic long-context policy — re-compress older tokens, keep
//! recent pivotal ones precise — an actual runtime mechanism instead of a
//! static window.
//!
//! Everything here runs on the engine thread between decode steps; the
//! decode fan-out never sees a page mid-downshift
//! (DESIGN.md §Threading-Model).
//!
//! **Shared pages** (prefix sharing, DESIGN.md §Prefix-Sharing) are
//! *exempt* from the ladder until they become sole-owned: mutating them
//! in place is forbidden, and downshifting through a copy-on-write split
//! *adds* a private frame instead of reclaiming one, so the controller
//! skips them ([`SharedDownshift::Exempt`]) and the engine instead evicts
//! prefix-index entries between the downshift and preempt rungs.  The
//! CoW path ([`SharedDownshift::CowSplit`]) exists as an explicit
//! de-sharing mechanism and is pinned never to mutate the other owner's
//! bytes (`rust/tests/prefix.rs`).

use crate::config::QuantPlan;

use super::pages::{page_frame_bytes, KvSide, KV_SIDES};
use super::SeqKvCache;

/// Per-layer requantization floors: the narrowest width the controller
/// may downshift each layer's pages to.
#[derive(Debug, Clone)]
pub struct PressureCfg {
    pub k_floor: Vec<u8>,
    pub v_floor: Vec<u8>,
}

impl PressureCfg {
    /// Floors derived from the gradient-importance plan: layers the
    /// profiler allocated high widths (> 2 bits — the important ones)
    /// never drop below 2 bits; low-importance layers may fall to 1 bit;
    /// fp16 layers have no quantized pages to downshift (floor 16).
    pub fn from_plan(plan: &QuantPlan) -> Self {
        let floor = |b: u8| match b {
            16 => 16,
            b if b > 2 => 2,
            _ => 1,
        };
        PressureCfg {
            k_floor: plan.k_bits.iter().map(|&b| floor(b)).collect(),
            v_floor: plan.v_bits.iter().map(|&b| floor(b)).collect(),
        }
    }

    /// The same floor for every layer (uniform baselines).
    pub fn uniform(n_layers: usize, floor: u8) -> Self {
        PressureCfg { k_floor: vec![floor; n_layers], v_floor: vec![floor; n_layers] }
    }

    pub fn floor(&self, layer: usize, side: KvSide) -> u8 {
        let floors = match side {
            KvSide::Key => &self.k_floor,
            KvSide::Value => &self.v_floor,
        };
        floors.get(layer).copied().unwrap_or(16)
    }
}

/// One rung down the requantization bit ladder.
pub fn ladder_down(bits: u8) -> u8 {
    match bits {
        16 => 8,
        8 => 4,
        4 => 2,
        3 => 2,
        2 => 1,
        b => b,
    }
}

/// How the downshift scan treats pages whose blocks are shared with the
/// prefix index or another sequence (DESIGN.md §Prefix-Sharing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SharedDownshift {
    /// Skip shared pages entirely — the engine's policy: shared bytes
    /// must stay pristine for the other owners, and a copy-on-write
    /// split *adds* a frame, so the ladder cannot reclaim bytes here.
    /// Shared pages become eligible again the moment they are sole-owned
    /// (prefix-entry eviction, co-owner retirement).
    Exempt,
    /// Downshift shared pages too, through the cache-level copy-on-write
    /// split: this sequence gets a private downshifted copy, the shared
    /// bytes are untouched.  Net pool bytes go *up* by one frame —
    /// explicit de-sharing, not memory relief.
    CowSplit,
}

/// A single pressure-controller downshift.
#[derive(Debug, Clone, Copy)]
pub struct Downshift {
    pub layer: usize,
    pub side: KvSide,
    pub page: usize,
    pub from_bits: u8,
    pub to_bits: u8,
    pub bytes_saved: usize,
    /// the page was shared and this downshift copy-on-write split it
    pub cow: bool,
}

/// Requantize the oldest sealed page still above its floor, one ladder
/// rung down, skipping shared pages ([`SharedDownshift::Exempt`]).  Scan
/// order is oldest-page-first, then layer order, K before V — so the
/// most recent context keeps its precision for as long as possible.
/// Returns `None` when every eligible sealed page sits at its floor (the
/// caller's cue to move on to prefix-entry eviction, then preemption).
pub fn downshift_one(cache: &mut SeqKvCache, page_tokens: usize,
                     cfg: &PressureCfg) -> Option<Downshift> {
    downshift_one_with(cache, page_tokens, cfg, SharedDownshift::Exempt)
}

/// [`downshift_one`] with an explicit shared-page policy.
pub fn downshift_one_with(cache: &mut SeqKvCache, page_tokens: usize,
                          cfg: &PressureCfg, shared: SharedDownshift)
                          -> Option<Downshift> {
    let max_pages = cache.layers.iter()
        .flat_map(|l| KV_SIDES.iter().map(move |&s| l.sealed_quant_pages(s, page_tokens)))
        .max()
        .unwrap_or(0);
    for page in 0..max_pages {
        for (li, layer) in cache.layers.iter_mut().enumerate() {
            for &side in &KV_SIDES {
                if page >= layer.sealed_quant_pages(side, page_tokens) {
                    continue;
                }
                let bits = layer.quant_page_bits(side, page, page_tokens);
                let floor = cfg.floor(li, side);
                if bits <= floor {
                    continue;
                }
                let to = ladder_down(bits).max(floor);
                if to >= bits {
                    continue;
                }
                let is_shared = layer.quant_page_shared(side, page, page_tokens);
                if is_shared && shared == SharedDownshift::Exempt {
                    continue;
                }
                let bytes_saved = layer.requant_page(side, page, page_tokens, to);
                return Some(Downshift {
                    layer: li, side, page, from_bits: bits, to_bits: to, bytes_saved,
                    cow: is_shared,
                });
            }
        }
    }
    None
}

/// Upper bound on page-accounting bytes the controller could still
/// reclaim from `cache` by downshifting every *eligible* (unshared)
/// sealed page to its floor — the engine's gate for admission-time
/// relief (don't grind pages for a request that can't fit even then).
/// Shared pages are excluded: the ladder exempts them
/// (DESIGN.md §Prefix-Sharing); the engine adds
/// `PagePool::prefix_reclaimable_bytes` for the index-eviction rung.
pub fn reclaimable_bytes(cache: &SeqKvCache, page_tokens: usize,
                         cfg: &PressureCfg) -> usize {
    let mut total = 0usize;
    for (li, layer) in cache.layers.iter().enumerate() {
        let (kv_dim, group) = (layer.cfg.kv_dim, layer.cfg.group);
        for &side in &KV_SIDES {
            let floor = cfg.floor(li, side);
            if floor >= 16 {
                continue;
            }
            for page in 0..layer.sealed_quant_pages(side, page_tokens) {
                let bits = layer.quant_page_bits(side, page, page_tokens);
                if bits > floor && !layer.quant_page_shared(side, page, page_tokens) {
                    total += page_frame_bytes(page_tokens, kv_dim, group, bits)
                        .saturating_sub(page_frame_bytes(page_tokens, kv_dim, group, floor));
                }
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::super::testutil::filled_cache as filled;
    use super::*;
    use crate::config::{ModelConfig, QuantPlan};

    const PT: usize = 64;

    #[test]
    fn ladder_rungs() {
        assert_eq!(ladder_down(16), 8);
        assert_eq!(ladder_down(8), 4);
        assert_eq!(ladder_down(4), 2);
        assert_eq!(ladder_down(3), 2);
        assert_eq!(ladder_down(2), 1);
        assert_eq!(ladder_down(1), 1); // bottom: no further rung
    }

    #[test]
    fn floors_follow_importance() {
        let mut plan = QuantPlan::uniform(4, 2);
        plan.k_bits[1] = 3; // "important" layer per the profiler
        plan.v_bits[2] = 4;
        plan.k_bits[3] = 16;
        let cfg = PressureCfg::from_plan(&plan);
        assert_eq!(cfg.floor(0, KvSide::Key), 1);
        assert_eq!(cfg.floor(1, KvSide::Key), 2);
        assert_eq!(cfg.floor(2, KvSide::Value), 2);
        assert_eq!(cfg.floor(3, KvSide::Key), 16);
        assert_eq!(cfg.floor(99, KvSide::Key), 16); // out of range: untouchable
    }

    #[test]
    fn downshift_is_oldest_first_and_floors_out() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan); // floor 2 everywhere
        let mut cache = filled(&m, &plan, 256, 1); // 8 blocks = 4 pages per side
        let first = downshift_one(&mut cache, PT, &cfg).expect("downshiftable");
        assert_eq!((first.layer, first.side, first.page), (0, KvSide::Key, 0));
        assert_eq!((first.from_bits, first.to_bits), (4, 2));
        assert!(first.bytes_saved > 0);
        let second = downshift_one(&mut cache, PT, &cfg).unwrap();
        assert_eq!((second.layer, second.side, second.page), (0, KvSide::Value, 0));
        // page 0 across all layers/sides drains before page 1 is touched
        let mut seen: usize = 2;
        while let Some(d) = downshift_one(&mut cache, PT, &cfg) {
            seen += 1;
            if seen <= m.n_layers * 2 {
                assert_eq!(d.page, 0, "downshift #{seen} must still be page 0");
            }
        }
        // 4 pages x 2 layers x 2 sides, one rung (4 -> 2) each
        assert_eq!(seen, 4 * m.n_layers * 2);
        for l in &cache.layers {
            for &s in &KV_SIDES {
                for p in 0..l.sealed_quant_pages(s, PT) {
                    assert_eq!(l.quant_page_bits(s, p, PT), 2, "all pages at floor");
                }
            }
        }
    }

    #[test]
    fn reclaimable_matches_actual_savings() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan);
        let mut cache = filled(&m, &plan, 256, 2);
        let claim = reclaimable_bytes(&cache, PT, &cfg);
        assert!(claim > 0);
        let mut actual = 0usize;
        while let Some(d) = downshift_one(&mut cache, PT, &cfg) {
            // page accounting, not exact block bytes: recompute per page
            let _ = d;
            actual += 1;
        }
        assert!(actual > 0);
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), 0, "nothing left at floor");
        // the page-accounting claim equals frames x (bytes(4) - bytes(2))
        let per_page = page_frame_bytes(PT, m.kv_dim(), m.group, 4)
            - page_frame_bytes(PT, m.kv_dim(), m.group, 2);
        assert_eq!(claim, actual * per_page);
    }

    #[test]
    fn shared_pages_are_exempt_until_sole_owner() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan);
        let mut cache = filled(&m, &plan, 64, 5); // exactly one page per side
        // pin every page as shared, the way the prefix index does
        let held: Vec<_> = cache.layers.iter()
            .flat_map(|l| KV_SIDES.iter()
                .flat_map(move |&s| l.quant_blocks(s).iter().cloned()))
            .collect();
        assert!(downshift_one(&mut cache, PT, &cfg).is_none(),
                "every page is shared: the exempt scan must find nothing");
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), 0);
        // the CoW policy still downshifts, without touching the shared bytes
        let words_before = held[0].words.clone();
        let d = downshift_one_with(&mut cache, PT, &cfg, SharedDownshift::CowSplit)
            .expect("CowSplit must proceed");
        assert!(d.cow && d.bytes_saved > 0);
        assert_eq!(held[0].words, words_before, "shared bytes must be untouched");
        // dropping the index's handles makes the rest sole-owned again
        drop(held);
        assert!(downshift_one(&mut cache, PT, &cfg).is_some());
        assert!(reclaimable_bytes(&cache, PT, &cfg) > 0);
    }

    #[test]
    fn fp16_plan_has_nothing_to_downshift() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::fp16(m.n_layers);
        let cfg = PressureCfg::from_plan(&plan);
        let mut cache = filled(&m, &plan, 128, 3);
        assert!(downshift_one(&mut cache, PT, &cfg).is_none());
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), 0);
    }
}
