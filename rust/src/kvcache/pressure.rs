//! The pressure controller (DESIGN.md §Memory-Manager,
//! §Pressure-Ladder): what happens when the paged pool cannot satisfy a
//! charge.
//!
//! On admission failure or simulated OOM the engine **requantizes sealed
//! out-of-window pages down per-side bit ladders** — keys step through a
//! 3-bit rung (8 → 4 → 3 → 2 → 1) that values skip (8 → 4 → 2 → 1) —
//! bounded below by per-layer per-side floors derived from the
//! gradient-importance profile.  The scan no longer drains the oldest
//! page on both sides in lockstep: each call picks the single
//! (layer, side, page) step with the **best predicted-loss-per-byte**,
//! folding the profiler's per-layer K/V importance weights with the
//! bytes each rung reclaims (DESIGN.md §Pressure-Ladder).  Only when
//! every eligible page sits at its side floor does the engine **preempt**
//! the lowest-priority sequence back to the batcher queue.  This makes
//! the paper's dynamic long-context policy — re-compress older tokens,
//! keep recent pivotal ones precise — an actual runtime mechanism instead
//! of a static window, and lets the ladder land on asymmetric K/V
//! operating points like the paper's headline K 2.19 / V 2.38
//! (docs/adr/007-asymmetric-bit-allocation.md).
//!
//! Everything here runs on the engine thread between decode steps; the
//! decode fan-out never sees a page mid-downshift
//! (DESIGN.md §Threading-Model).
//!
//! **Shared pages** (prefix sharing, DESIGN.md §Prefix-Sharing) are
//! *exempt* from the ladder until they become sole-owned: mutating them
//! in place is forbidden, and downshifting through a copy-on-write split
//! *adds* a private frame instead of reclaiming one, so the controller
//! skips them ([`SharedDownshift::Exempt`]) and the engine instead evicts
//! prefix-index entries between the downshift and preempt rungs.  The
//! CoW path ([`SharedDownshift::CowSplit`]) exists as an explicit
//! de-sharing mechanism and is pinned never to mutate the other owner's
//! bytes (`rust/tests/prefix.rs`).

use crate::config::QuantPlan;

use super::pages::{page_frame_bytes, KvSide, KV_SIDES};
use super::SeqKvCache;

/// Per-layer, per-side requantization floors plus the importance weights
/// that order the downshift scan (DESIGN.md §Pressure-Ladder).
#[derive(Debug, Clone)]
pub struct PressureCfg {
    pub k_floor: Vec<u8>,
    pub v_floor: Vec<u8>,
    /// Per-layer K-side importance weights for the loss-per-byte order:
    /// a larger weight means downshifting that layer's keys is predicted
    /// to cost more accuracy, so the scan defers it.  Uniform 1.0 when no
    /// profile is available.
    pub k_weight: Vec<f64>,
    pub v_weight: Vec<f64>,
}

impl PressureCfg {
    /// Floors derived from the gradient-importance plan: layers the
    /// profiler allocated high widths (> 2 bits — the important ones)
    /// never drop below 2 bits; low-importance layers may fall to 1 bit;
    /// fp16 layers have no quantized pages to downshift (floor 16).
    /// The plan's bit widths double as proxy importance weights — the
    /// profiler already folded the gradient norms into them — until
    /// [`PressureCfg::with_weights`] installs the raw scores.
    pub fn from_plan(plan: &QuantPlan) -> Self {
        let floor = |b: u8| match b {
            16 => 16,
            b if b > 2 => 2,
            _ => 1,
        };
        PressureCfg {
            k_floor: plan.k_bits.iter().map(|&b| floor(b)).collect(),
            v_floor: plan.v_bits.iter().map(|&b| floor(b)).collect(),
            k_weight: plan.k_bits.iter().map(|&b| b as f64).collect(),
            v_weight: plan.v_bits.iter().map(|&b| b as f64).collect(),
        }
    }

    /// The same floor for every layer (uniform baselines); unit weights.
    pub fn uniform(n_layers: usize, floor: u8) -> Self {
        PressureCfg {
            k_floor: vec![floor; n_layers],
            v_floor: vec![floor; n_layers],
            k_weight: vec![1.0; n_layers],
            v_weight: vec![1.0; n_layers],
        }
    }

    /// Install per-layer K/V importance weights (the profiler's averaged
    /// gradient norms, Eq. 10–11) in place of the plan-bit proxies.
    pub fn with_weights(mut self, k: Vec<f64>, v: Vec<f64>) -> Self {
        self.k_weight = k;
        self.v_weight = v;
        self
    }

    pub fn floor(&self, layer: usize, side: KvSide) -> u8 {
        let floors = match side {
            KvSide::Key => &self.k_floor,
            KvSide::Value => &self.v_floor,
        };
        floors.get(layer).copied().unwrap_or(16)
    }

    /// Importance weight for one (layer, side); out-of-range layers fall
    /// back to 1.0 so a short weight vector never panics the scan.
    pub fn weight(&self, layer: usize, side: KvSide) -> f64 {
        let w = match side {
            KvSide::Key => &self.k_weight,
            KvSide::Value => &self.v_weight,
        };
        w.get(layer).copied().unwrap_or(1.0)
    }
}

/// One rung down the side-blind requantization ladder — the pre-split
/// sequence, kept as the value-side track and for the uniform baselines'
/// docs/tests.  [`ladder_down_for`] is what the scan steps.
pub fn ladder_down(bits: u8) -> u8 {
    match bits {
        16 => 8,
        8 => 4,
        4 => 2,
        3 => 2,
        2 => 1,
        b => b,
    }
}

/// One rung down the per-side ladder (DESIGN.md §Pressure-Ladder).  Keys
/// get the denser track with a 3-bit rung (4 → 3 → 2) — KVmix's own
/// allocations put keys at 3 bits, so the ladder can rest there — while
/// values take the steeper 4 → 2 step.
pub fn ladder_down_for(side: KvSide, bits: u8) -> u8 {
    if side == KvSide::Key && bits == 4 {
        3
    } else {
        ladder_down(bits)
    }
}

/// Quantization-noise proxy for a packed width: a uniform quantizer's
/// MSE scales as `2^(-2b)`, and fp16 counts as noiseless.  Only *ratios*
/// of differences of this matter (the scan compares loss-per-byte), so
/// the constant factor is dropped.
pub fn quant_err_proxy(bits: u8) -> f64 {
    if bits >= 16 {
        0.0
    } else {
        0.25f64.powi(bits as i32)
    }
}

/// How the downshift scan treats pages whose blocks are shared with the
/// prefix index or another sequence (DESIGN.md §Prefix-Sharing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SharedDownshift {
    /// Skip shared pages entirely — the engine's policy: shared bytes
    /// must stay pristine for the other owners, and a copy-on-write
    /// split *adds* a frame, so the ladder cannot reclaim bytes here.
    /// Shared pages become eligible again the moment they are sole-owned
    /// (prefix-entry eviction, co-owner retirement).
    Exempt,
    /// Downshift shared pages too, through the cache-level copy-on-write
    /// split: this sequence gets a private downshifted copy, the shared
    /// bytes are untouched.  Net pool bytes go *up* by one frame —
    /// explicit de-sharing, not memory relief.
    CowSplit,
}

/// A single pressure-controller downshift.
#[derive(Debug, Clone, Copy)]
pub struct Downshift {
    pub layer: usize,
    pub side: KvSide,
    pub page: usize,
    pub from_bits: u8,
    pub to_bits: u8,
    pub bytes_saved: usize,
    /// the page was shared and this downshift copy-on-write split it
    pub cow: bool,
}

/// Take the single best downshift step, skipping shared pages
/// ([`SharedDownshift::Exempt`]).  "Best" is minimum predicted
/// loss-per-byte: `weight(layer, side) * Δ(quant_err_proxy)` divided by
/// the page-frame bytes the rung reclaims, ties broken oldest-page-first,
/// then layer order, K before V — so important layers and recent context
/// keep their precision for as long as possible.  Returns `None` when
/// every eligible sealed page sits at its side floor (the caller's cue
/// to move on to prefix-entry eviction, then preemption).
pub fn downshift_one(cache: &mut SeqKvCache, page_tokens: usize,
                     cfg: &PressureCfg) -> Option<Downshift> {
    downshift_one_with(cache, page_tokens, cfg, SharedDownshift::Exempt)
}

/// [`downshift_one`] with an explicit shared-page policy.
pub fn downshift_one_with(cache: &mut SeqKvCache, page_tokens: usize,
                          cfg: &PressureCfg, shared: SharedDownshift)
                          -> Option<Downshift> {
    downshift_best(cache, page_tokens, cfg, shared, None)
}

/// [`downshift_one`] restricted to one side of the cache — the
/// property-test wall's probe for per-side floor invariants
/// (`rust/tests/props.rs`), and the audit hook that proves a cache whose
/// K pages sit at floor still yields V-side relief.
pub fn downshift_one_side(cache: &mut SeqKvCache, page_tokens: usize,
                          cfg: &PressureCfg, side: KvSide)
                          -> Option<Downshift> {
    downshift_best(cache, page_tokens, cfg, SharedDownshift::Exempt, Some(side))
}

/// Candidate scan + apply.  Two passes: a read-only sweep scores every
/// eligible (layer, side, page) rung, then the winner is requantized.
/// The comparison key is lexicographic
/// `(loss_per_byte, page, layer, side)` — exact float ties (common:
/// identical widths and weights) fall back to the old
/// oldest-page-first / K-before-V order, keeping the scan deterministic.
fn downshift_best(cache: &mut SeqKvCache, page_tokens: usize,
                  cfg: &PressureCfg, shared: SharedDownshift,
                  only: Option<KvSide>) -> Option<Downshift> {
    let side_rank = |s: KvSide| match s {
        KvSide::Key => 0usize,
        KvSide::Value => 1,
    };
    let mut best: Option<((f64, usize, usize, usize), KvSide, u8, u8, bool)> = None;
    for (li, layer) in cache.layers.iter().enumerate() {
        let (kv_dim, group) = (layer.cfg.kv_dim, layer.cfg.group);
        for &side in &KV_SIDES {
            if only.is_some_and(|s| s != side) {
                continue;
            }
            let floor = cfg.floor(li, side);
            if floor >= 16 {
                continue;
            }
            let w = cfg.weight(li, side);
            for page in 0..layer.sealed_quant_pages(side, page_tokens) {
                let bits = layer.quant_page_bits(side, page, page_tokens);
                if bits <= floor {
                    continue;
                }
                let to = ladder_down_for(side, bits).max(floor);
                if to >= bits {
                    continue;
                }
                if layer.quant_page_spilled(side, page, page_tokens) {
                    continue; // spilled stubs hold no bytes to requantize
                }
                let is_shared = layer.quant_page_shared(side, page, page_tokens);
                if is_shared && shared == SharedDownshift::Exempt {
                    continue;
                }
                let saved = page_frame_bytes(page_tokens, kv_dim, group, bits)
                    .saturating_sub(page_frame_bytes(page_tokens, kv_dim, group, to));
                if saved == 0 {
                    continue;
                }
                let loss = w * (quant_err_proxy(to) - quant_err_proxy(bits));
                let key = (loss / saved as f64, page, li, side_rank(side));
                let better = match &best {
                    None => true,
                    Some((bk, ..)) => key.partial_cmp(bk) == Some(std::cmp::Ordering::Less),
                };
                if better {
                    best = Some((key, side, bits, to, is_shared));
                }
            }
        }
    }
    let ((_, page, li, _), side, from_bits, to_bits, cow) = best?;
    let bytes_saved = cache.layers[li].requant_page(side, page, page_tokens, to_bits);
    Some(Downshift { layer: li, side, page, from_bits, to_bits, bytes_saved, cow })
}

/// Upper bound on page-accounting bytes the controller could still
/// reclaim from `cache` by downshifting every *eligible* (unshared)
/// sealed page to its side floor — the engine's gate for admission-time
/// relief (don't grind pages for a request that can't fit even then).
/// Path-independent: every ladder telescopes from `bits` down to the
/// floor, so the bound is the same whichever per-side rungs get taken.
/// Shared pages are excluded: the ladder exempts them
/// (DESIGN.md §Prefix-Sharing); the engine adds
/// `PagePool::prefix_reclaimable_bytes` for the index-eviction rung.
pub fn reclaimable_bytes(cache: &SeqKvCache, page_tokens: usize,
                         cfg: &PressureCfg) -> usize {
    let mut total = 0usize;
    for (li, layer) in cache.layers.iter().enumerate() {
        let (kv_dim, group) = (layer.cfg.kv_dim, layer.cfg.group);
        for &side in &KV_SIDES {
            let floor = cfg.floor(li, side);
            if floor >= 16 {
                continue;
            }
            for page in 0..layer.sealed_quant_pages(side, page_tokens) {
                let bits = layer.quant_page_bits(side, page, page_tokens);
                if bits > floor
                    && !layer.quant_page_shared(side, page, page_tokens)
                    && !layer.quant_page_spilled(side, page, page_tokens)
                {
                    total += page_frame_bytes(page_tokens, kv_dim, group, bits)
                        .saturating_sub(page_frame_bytes(page_tokens, kv_dim, group, floor));
                }
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::super::testutil::filled_cache as filled;
    use super::*;
    use crate::config::{ModelConfig, QuantPlan};

    const PT: usize = 64;

    #[test]
    fn ladder_rungs() {
        assert_eq!(ladder_down(16), 8);
        assert_eq!(ladder_down(8), 4);
        assert_eq!(ladder_down(4), 2);
        assert_eq!(ladder_down(3), 2);
        assert_eq!(ladder_down(2), 1);
        assert_eq!(ladder_down(1), 1); // bottom: no further rung
        // keys get the denser track: the 3-bit rung values skip
        assert_eq!(ladder_down_for(KvSide::Key, 4), 3);
        assert_eq!(ladder_down_for(KvSide::Value, 4), 2);
        for b in [16u8, 8, 3, 2, 1] {
            assert_eq!(ladder_down_for(KvSide::Key, b), ladder_down(b));
            assert_eq!(ladder_down_for(KvSide::Value, b), ladder_down(b));
        }
    }

    #[test]
    fn err_proxy_is_monotone() {
        assert!(quant_err_proxy(1) > quant_err_proxy(2));
        assert!(quant_err_proxy(2) > quant_err_proxy(3));
        assert!(quant_err_proxy(3) > quant_err_proxy(4));
        assert!(quant_err_proxy(4) > quant_err_proxy(16));
        assert_eq!(quant_err_proxy(16), 0.0);
    }

    #[test]
    fn floors_follow_importance() {
        let mut plan = QuantPlan::uniform(4, 2);
        plan.k_bits[1] = 3; // "important" layer per the profiler
        plan.v_bits[2] = 4;
        plan.k_bits[3] = 16;
        let cfg = PressureCfg::from_plan(&plan);
        assert_eq!(cfg.floor(0, KvSide::Key), 1);
        assert_eq!(cfg.floor(1, KvSide::Key), 2);
        assert_eq!(cfg.floor(2, KvSide::Value), 2);
        assert_eq!(cfg.floor(3, KvSide::Key), 16);
        assert_eq!(cfg.floor(99, KvSide::Key), 16); // out of range: untouchable
        // plan bits double as proxy weights until raw scores arrive
        assert_eq!(cfg.weight(1, KvSide::Key), 3.0);
        assert_eq!(cfg.weight(2, KvSide::Value), 4.0);
        assert_eq!(cfg.weight(99, KvSide::Value), 1.0);
        let cfg = cfg.with_weights(vec![9.0; 4], vec![7.0; 4]);
        assert_eq!(cfg.weight(1, KvSide::Key), 9.0);
        assert_eq!(cfg.weight(2, KvSide::Value), 7.0);
    }

    /// The loss-per-byte order from a uniform 4-bit start: the gentle
    /// K 4→3 rung is the cheapest loss per byte, so every K page steps
    /// to 3 first (oldest page, then layer order), then K 3→2 still
    /// undercuts V 4→2, and only once all keys rest at floor do values
    /// move.  En route the cache passes through exactly the paper's
    /// K-below-V asymmetric shape.
    #[test]
    fn downshift_order_is_loss_per_byte() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan); // floor 2, equal weights
        let mut cache = filled(&m, &plan, 256, 1); // 8 blocks = 4 pages per side
        let first = downshift_one(&mut cache, PT, &cfg).expect("downshiftable");
        assert_eq!((first.layer, first.side, first.page), (0, KvSide::Key, 0));
        assert_eq!((first.from_bits, first.to_bits), (4, 3));
        assert!(first.bytes_saved > 0);
        let mut steps = vec![first];
        while let Some(d) = downshift_one(&mut cache, PT, &cfg) {
            steps.push(d);
        }
        let pages_per_side = 4 * m.n_layers;
        // K takes two rungs (4→3→2), V one (4→2)
        assert_eq!(steps.len(), pages_per_side * 2 + pages_per_side);
        let phase = |d: &Downshift| match (d.side, d.from_bits, d.to_bits) {
            (KvSide::Key, 4, 3) => 0,
            (KvSide::Key, 3, 2) => 1,
            (KvSide::Value, 4, 2) => 2,
            other => panic!("unexpected rung {other:?}"),
        };
        for w in steps.windows(2) {
            assert!(phase(&w[0]) <= phase(&w[1]),
                    "rungs must come in loss-per-byte phases: {:?} then {:?}", w[0], w[1]);
        }
        // within a phase, exact ties break oldest-page-first then layer
        for w in steps.windows(2) {
            if phase(&w[0]) == phase(&w[1]) {
                assert!((w[0].page, w[0].layer) < (w[1].page, w[1].layer));
            }
        }
        for l in &cache.layers {
            for &s in &KV_SIDES {
                for p in 0..l.sealed_quant_pages(s, PT) {
                    assert_eq!(l.quant_page_bits(s, p, PT), 2, "all pages at floor");
                }
            }
        }
    }

    /// Weights steer the scan: a layer whose keys carry overwhelming
    /// importance holds its K pages while everything else (including its
    /// own values) drains first.
    #[test]
    fn weights_defer_important_layers() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan)
            .with_weights(vec![1e6, 1.0], vec![1.0, 1.0]);
        let mut cache = filled(&m, &plan, 128, 9); // 2 pages per side
        let mut order = Vec::new();
        while let Some(d) = downshift_one(&mut cache, PT, &cfg) {
            order.push((d.layer, d.side));
        }
        let first_l0k = order.iter().position(|&x| x == (0, KvSide::Key)).unwrap();
        for (i, &(l, s)) in order.iter().enumerate() {
            if (l, s) != (0, KvSide::Key) {
                assert!(i < first_l0k,
                        "layer-0 keys (weight 1e6) must drain last, saw {l}/{s:?} at {i}");
            }
        }
    }

    /// Satellite audit regression: K already at floor must not starve
    /// V-side relief, and the reclaimable-bytes claim stays exact when
    /// only one side has headroom.
    #[test]
    fn k_at_floor_still_yields_v_relief() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        // custom floors: K pinned at its plan width, V may fall to 2
        let cfg = PressureCfg {
            k_floor: vec![4; m.n_layers],
            v_floor: vec![2; m.n_layers],
            k_weight: vec![1.0; m.n_layers],
            v_weight: vec![1.0; m.n_layers],
        };
        let mut cache = filled(&m, &plan, 192, 7); // 3 pages per side
        let pages_per_side = 3 * m.n_layers;
        let per_page = page_frame_bytes(PT, m.kv_dim(), m.group, 4)
            - page_frame_bytes(PT, m.kv_dim(), m.group, 2);
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), pages_per_side * per_page,
                   "claim must count only the V side");
        let mut n = 0usize;
        while let Some(d) = downshift_one(&mut cache, PT, &cfg) {
            assert_eq!(d.side, KvSide::Value, "K at floor: only V relief allowed");
            assert_eq!((d.from_bits, d.to_bits), (4, 2));
            n += 1;
        }
        assert_eq!(n, pages_per_side);
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), 0);
        for l in &cache.layers {
            for p in 0..l.sealed_quant_pages(KvSide::Key, PT) {
                assert_eq!(l.quant_page_bits(KvSide::Key, p, PT), 4, "K untouched");
            }
        }
    }

    #[test]
    fn downshift_one_side_respects_restriction() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan);
        let mut cache = filled(&m, &plan, 128, 11);
        while let Some(d) = downshift_one_side(&mut cache, PT, &cfg, KvSide::Value) {
            assert_eq!(d.side, KvSide::Value);
        }
        // values exhausted to floor; keys untouched and still eligible
        for l in &cache.layers {
            for p in 0..l.sealed_quant_pages(KvSide::Value, PT) {
                assert_eq!(l.quant_page_bits(KvSide::Value, p, PT), 2);
            }
            for p in 0..l.sealed_quant_pages(KvSide::Key, PT) {
                assert_eq!(l.quant_page_bits(KvSide::Key, p, PT), 4);
            }
        }
        let d = downshift_one_side(&mut cache, PT, &cfg, KvSide::Key).unwrap();
        assert_eq!(d.side, KvSide::Key);
    }

    #[test]
    fn reclaimable_matches_actual_savings() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan);
        let mut cache = filled(&m, &plan, 256, 2);
        let claim = reclaimable_bytes(&cache, PT, &cfg);
        assert!(claim > 0);
        // page accounting telescopes over the per-side rungs: sum the
        // frame delta of every step actually taken
        let mut actual = 0usize;
        while let Some(d) = downshift_one(&mut cache, PT, &cfg) {
            actual += page_frame_bytes(PT, m.kv_dim(), m.group, d.from_bits)
                - page_frame_bytes(PT, m.kv_dim(), m.group, d.to_bits);
        }
        assert!(actual > 0);
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), 0, "nothing left at floor");
        assert_eq!(claim, actual, "claim must telescope over the rungs taken");
    }

    #[test]
    fn shared_pages_are_exempt_until_sole_owner() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan);
        let mut cache = filled(&m, &plan, 64, 5); // exactly one page per side
        // pin every page as shared, the way the prefix index does
        let held: Vec<_> = cache.layers.iter()
            .flat_map(|l| KV_SIDES.iter()
                .flat_map(move |&s| l.quant_blocks(s).iter().cloned()))
            .collect();
        assert!(downshift_one(&mut cache, PT, &cfg).is_none(),
                "every page is shared: the exempt scan must find nothing");
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), 0);
        // the CoW policy still downshifts, without touching the shared bytes
        let words_before = held[0].words.clone();
        let d = downshift_one_with(&mut cache, PT, &cfg, SharedDownshift::CowSplit)
            .expect("CowSplit must proceed");
        assert!(d.cow && d.bytes_saved > 0);
        assert_eq!(held[0].words, words_before, "shared bytes must be untouched");
        // dropping the index's handles makes the rest sole-owned again
        drop(held);
        assert!(downshift_one(&mut cache, PT, &cfg).is_some());
        assert!(reclaimable_bytes(&cache, PT, &cfg) > 0);
    }

    #[test]
    fn spilled_pages_are_downshift_exempt() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 4).without_rpc();
        let cfg = PressureCfg::from_plan(&plan);
        let mut cache = filled(&m, &plan, 64, 13); // one page per side
        let before = reclaimable_bytes(&cache, PT, &cfg);
        let bytes = cache.layers[0].take_spill_page(KvSide::Key, 0, PT);
        let per_k = page_frame_bytes(PT, m.kv_dim(), m.group, 4)
            - page_frame_bytes(PT, m.kv_dim(), m.group, 2);
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), before - per_k,
                   "a spilled page leaves the reclaim claim");
        let mut n = 0;
        while let Some(d) = downshift_one(&mut cache, PT, &cfg) {
            assert!((d.layer, d.side) != (0, KvSide::Key),
                    "the scan must skip the spilled stub");
            n += 1;
        }
        assert!(n > 0, "other pages still drain");
        // fault-back restores eligibility
        cache.layers[0].restore_spill_page(KvSide::Key, 0, PT, &bytes);
        let d = downshift_one(&mut cache, PT, &cfg).expect("restored page eligible");
        assert_eq!((d.layer, d.side), (0, KvSide::Key));
    }

    #[test]
    fn fp16_plan_has_nothing_to_downshift() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::fp16(m.n_layers);
        let cfg = PressureCfg::from_plan(&plan);
        let mut cache = filled(&m, &plan, 128, 3);
        assert!(downshift_one(&mut cache, PT, &cfg).is_none());
        assert_eq!(reclaimable_bytes(&cache, PT, &cfg), 0);
    }
}
