//! The mixed-precision KV cache: full-precision RPC window + packed
//! quantized history, with fused quantize+append (paper §CUDA
//! Implementation ①) and per-layer K/V representations.
//!
//! Layouts (stream order of the packed blocks, see quant/groupq.rs):
//! * Key blocks   — channel-major `[kv_dim][group_tokens]` ⇒ per-channel
//!   groups (one group = one channel's `group` tokens).
//! * Value blocks — token-major `[group_tokens][kv_dim]` ⇒ per-token
//!   groups (`kv_dim/group` groups per token).
//!
//! Keys are cached *post-RoPE* (the L2 `pre` graph applies RoPE before the
//! cache sees them).  KVQuant quantizes pre-RoPE keys; DESIGN.md §5 notes
//! this substitution.
//!
//! Threading (DESIGN.md §Threading-Model): one `LayerKvCache` belongs to
//! one sequence, so the batched decode fan-out hands disjoint `&mut
//! LayerKvCache` lanes to different pool workers.  History blocks are
//! `Arc<PackedBlock>`: normally refcount 1 (plain owned state), but
//! prefix sharing (DESIGN.md §Prefix-Sharing) lets the same quantized
//! prefix blocks appear in several sequences' caches and in the pool's
//! prefix index.  Shared blocks are **read-only by convention** — the
//! decode fan-out only reads them — and the one mutation path,
//! [`LayerKvCache::requant_page`], goes through `Arc::make_mut`, which
//! copy-on-writes when the block is shared so another owner's bytes are
//! never touched.  `Send` is asserted at compile time below.

use std::sync::Arc;
use std::time::Instant;

use crate::quant::{key_scores_group_dispatch, value_accum_group_dispatch, FusedScratch,
                   PackedBlock, TileScratch};

use super::jl::{JlProjector, SignJlKeys};
use super::pages::KvSide;
use super::spill::{decode_block, encode_block};
use super::window::WindowPolicy;

/// Key representation for one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KeyRepr {
    /// no quantization (fp16-modeled)
    Fp,
    /// paper's per-channel asymmetric quantization
    PerChannel { bits: u8 },
    /// per-token (Atom / the k-T ablation rows of Table 3)
    PerToken { bits: u8 },
    /// QJL sign-bit JL transform
    SignJl { jl_dim: usize },
}

/// Value representation for one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ValueRepr {
    Fp,
    /// paper's per-token asymmetric quantization
    PerToken { bits: u8 },
}

/// Static configuration of one layer's cache.
#[derive(Debug, Clone, Copy)]
pub struct LayerCacheCfg {
    pub kv_dim: usize,
    pub head_dim: usize,
    /// quant group size (= tokens per history block; paper: 32)
    pub group: usize,
    pub key: KeyRepr,
    pub value: ValueRepr,
    pub k_window: WindowPolicy,
    pub v_window: WindowPolicy,
    /// KVQuant-style fp outlier fraction applied inside each block
    pub outlier_frac: f64,
    /// store Key blocks channel-interleaved (`PackedBlock::interleaved`)
    /// for sequential word loads in the tiled score kernel — a pure word
    /// permutation, so attend outputs are bit-identical to the linear
    /// layout (docs/adr/009-swar-and-interleaved-layout.md).  Only
    /// effective for `KeyRepr::PerChannel` at widths where
    /// `interleave_supported` holds; Value blocks always stay linear.
    pub k_interleave: bool,
}

impl LayerCacheCfg {
    pub fn n_kv_heads(&self) -> usize {
        self.kv_dim / self.head_dim
    }
}

/// One layer's cache for one sequence.
pub struct LayerKvCache {
    pub cfg: LayerCacheCfg,
    /// fp tail, token-major `[t][kv_dim]` — K and V windows shrink
    /// independently so each keeps its own buffer.
    k_fp: Vec<f32>,
    v_fp: Vec<f32>,
    /// quantized history; `Arc` so whole pages can be shared with other
    /// sequences / the prefix index (refcount 1 = plain exclusive state)
    pub k_blocks: Vec<Arc<PackedBlock>>,
    pub v_blocks: Vec<Arc<PackedBlock>>,
    /// QJL store (when cfg.key == SignJl)
    pub k_jl: Option<SignJlKeys>,
    jl_proj: Option<JlProjector>,
    /// tokens represented in quantized K history / V history
    pub k_hist: usize,
    pub v_hist: usize,
    /// scratch reused across appends
    qscratch: Vec<u32>,
    tscratch: Vec<f32>,
}

impl LayerKvCache {
    pub fn new(cfg: LayerCacheCfg) -> Self {
        let (k_jl, jl_proj) = if let KeyRepr::SignJl { jl_dim } = cfg.key {
            (Some(SignJlKeys::new(jl_dim)), Some(JlProjector::new(cfg.head_dim, jl_dim, 99)))
        } else {
            (None, None)
        };
        LayerKvCache {
            cfg,
            k_fp: Vec::new(),
            v_fp: Vec::new(),
            k_blocks: Vec::new(),
            v_blocks: Vec::new(),
            k_jl,
            jl_proj,
            k_hist: 0,
            v_hist: 0,
            qscratch: Vec::new(),
            tscratch: Vec::new(),
        }
    }

    /// Total tokens cached (same for K and V).
    pub fn len(&self) -> usize {
        self.k_hist + self.k_fp.len() / self.cfg.kv_dim
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn k_fp_tokens(&self) -> usize {
        self.k_fp.len() / self.cfg.kv_dim
    }

    pub fn v_fp_tokens(&self) -> usize {
        self.v_fp.len() / self.cfg.kv_dim
    }

    pub fn k_fp(&self) -> &[f32] {
        &self.k_fp
    }

    pub fn v_fp(&self) -> &[f32] {
        &self.v_fp
    }

    /// Fused quantize+append: push `n` new tokens (k/v row-major
    /// `[n][kv_dim]`, keys already RoPE'd), then enforce the window
    /// policies, quantizing overflowing whole blocks in place.
    pub fn append(&mut self, k: &[f32], v: &[f32], n: usize) {
        let kv = self.cfg.kv_dim;
        debug_assert_eq!(k.len(), n * kv);
        debug_assert_eq!(v.len(), n * kv);
        self.k_fp.extend_from_slice(k);
        self.v_fp.extend_from_slice(v);
        self.enforce_windows();
    }

    fn enforce_windows(&mut self) {
        let group = self.cfg.group;
        // Key side
        let k_quantize = match self.cfg.key {
            KeyRepr::Fp => 0,
            _ => self.cfg.k_window.blocks_to_quantize(self.k_fp_tokens(), group),
        };
        for _ in 0..k_quantize {
            self.quantize_oldest_k_block();
        }
        // Value side
        let v_quantize = match self.cfg.value {
            ValueRepr::Fp => 0,
            _ => self.cfg.v_window.blocks_to_quantize(self.v_fp_tokens(), group),
        };
        for _ in 0..v_quantize {
            self.quantize_oldest_v_block();
        }
    }

    fn quantize_oldest_k_block(&mut self) {
        let kv = self.cfg.kv_dim;
        let g = self.cfg.group;
        let rows = &self.k_fp[..g * kv];
        match self.cfg.key {
            KeyRepr::Fp => unreachable!(),
            KeyRepr::PerChannel { bits } => {
                // transpose token-major rows into channel-major stream
                self.tscratch.resize(g * kv, 0.0);
                for c in 0..kv {
                    for t in 0..g {
                        self.tscratch[c * g + t] = rows[t * kv + c];
                    }
                }
                let mut block = PackedBlock::default();
                if self.cfg.outlier_frac > 0.0 {
                    block.quantize_outliers_into_layout(&self.tscratch, bits, g,
                                                        self.cfg.outlier_frac,
                                                        self.cfg.k_interleave,
                                                        &mut self.qscratch);
                } else {
                    block.quantize_into_layout(&self.tscratch, bits, g,
                                               self.cfg.k_interleave, &mut self.qscratch);
                }
                self.k_blocks.push(Arc::new(block));
            }
            KeyRepr::PerToken { bits } => {
                // token-major stream, groups of `group` channels
                let mut block = PackedBlock::default();
                block.quantize_into(rows, bits, self.cfg.group, &mut self.qscratch);
                self.k_blocks.push(Arc::new(block));
            }
            KeyRepr::SignJl { jl_dim } => {
                let store = self.k_jl.as_mut().unwrap();
                let proj = self.jl_proj.as_ref().unwrap();
                let hd = self.cfg.head_dim;
                let mut rp = vec![0f32; jl_dim];
                for t in 0..g {
                    // each kv head's key is projected separately; store
                    // heads consecutively (len() counts per-head entries)
                    for h in 0..kv / hd {
                        let key = &rows[t * kv + h * hd..t * kv + (h + 1) * hd];
                        let norm = key.iter().map(|x| x * x).sum::<f32>().sqrt();
                        proj.project(key, &mut rp);
                        store.push(&rp, norm);
                    }
                }
            }
        }
        self.k_fp.drain(..g * kv);
        self.k_hist += g;
    }

    fn quantize_oldest_v_block(&mut self) {
        let kv = self.cfg.kv_dim;
        let g = self.cfg.group;
        let rows_len = g * kv;
        match self.cfg.value {
            ValueRepr::Fp => unreachable!(),
            ValueRepr::PerToken { bits } => {
                let mut block = PackedBlock::default();
                if self.cfg.outlier_frac > 0.0 {
                    let rows = self.v_fp[..rows_len].to_vec();
                    block.quantize_outliers_into(&rows, bits, self.cfg.group,
                                                 self.cfg.outlier_frac, &mut self.qscratch);
                } else {
                    block.quantize_into(&self.v_fp[..rows_len], bits, self.cfg.group,
                                        &mut self.qscratch);
                }
                self.v_blocks.push(Arc::new(block));
            }
        }
        self.v_fp.drain(..rows_len);
        self.v_hist += g;
    }

    /// Modeled bytes (fp elements at 2B as fp16, packed blocks per their
    /// own accounting) — the paper's Fig. 7 memory metric.
    pub fn modeled_bytes(&self) -> usize {
        let mut b = (self.k_fp.len() + self.v_fp.len()) * 2;
        b += self.k_blocks.iter().map(|x| x.modeled_bytes()).sum::<usize>();
        b += self.v_blocks.iter().map(|x| x.modeled_bytes()).sum::<usize>();
        if let Some(jl) = &self.k_jl {
            b += jl.modeled_bytes();
        }
        b
    }

    /// Actual resident bytes of the rust buffers.
    pub fn resident_bytes(&self) -> usize {
        let mut b = (self.k_fp.capacity() + self.v_fp.capacity()) * 4;
        b += self.k_blocks.iter().map(|x| x.resident_bytes()).sum::<usize>();
        b += self.v_blocks.iter().map(|x| x.resident_bytes()).sum::<usize>();
        b
    }

    // ------------- paged-pool views (DESIGN.md §Memory-Manager) -------------
    //
    // The page pool maps this cache at `page_tokens`-token granularity:
    // the fp window occupies fp16 pages, the quantized history occupies
    // packed pages of `page_tokens / group` blocks each.  Pages are
    // bit-uniform: appends always write the plan's width and the pressure
    // controller requantizes whole pages, so a page's class is its first
    // block's width.

    /// Quantized history blocks of one side.
    pub fn quant_blocks(&self, side: KvSide) -> &[Arc<PackedBlock>] {
        match side {
            KvSide::Key => &self.k_blocks,
            KvSide::Value => &self.v_blocks,
        }
    }

    /// Whether any block of quantized page `page` is shared (mapped by
    /// another sequence or pinned by the pool's prefix index).  Shared
    /// pages are downshift-exempt until sole-owner
    /// (DESIGN.md §Prefix-Sharing); `requant_page` on one copy-on-writes.
    pub fn quant_page_shared(&self, side: KvSide, page: usize, page_tokens: usize) -> bool {
        let bpp = page_tokens / self.cfg.group;
        let blocks = self.quant_blocks(side);
        let b1 = ((page + 1) * bpp).min(blocks.len());
        blocks[page * bpp..b1].iter().any(|b| Arc::strong_count(b) > 1)
    }

    /// Adopt shared quantized blocks as this side's *oldest* history
    /// (prefix sharing, DESIGN.md §Prefix-Sharing).  Must run on a fresh
    /// cache, before the first append; the blocks stay refcounted — the
    /// attention path reads them in place, and any later downshift goes
    /// through the `Arc::make_mut` copy-on-write in [`Self::requant_page`].
    pub fn adopt_shared_blocks(&mut self, side: KvSide, blocks: &[Arc<PackedBlock>]) {
        match side {
            KvSide::Key => {
                debug_assert!(self.k_blocks.is_empty() && self.k_fp.is_empty(),
                              "prefix adoption requires an empty K side");
                self.k_blocks.extend(blocks.iter().cloned());
                self.k_hist += blocks.len() * self.cfg.group;
            }
            KvSide::Value => {
                debug_assert!(self.v_blocks.is_empty() && self.v_fp.is_empty(),
                              "prefix adoption requires an empty V side");
                self.v_blocks.extend(blocks.iter().cloned());
                self.v_hist += blocks.len() * self.cfg.group;
            }
        }
    }

    /// Append the *unshared suffix* of a prefill whose first `adopted`
    /// tokens arrived as shared quantized pages: window decisions are
    /// computed as if all `adopted + n` tokens had been appended in one
    /// [`Self::append`] call, so the resulting cache state is
    /// bit-identical to a cold full-prompt prefill (pinned by
    /// `rust/tests/prefix.rs`).  `adopted` must be group-aligned and at
    /// most what the window policy would quantize for a prompt of
    /// `adopted + n` tokens — the engine's admission cap
    /// (`SeqKvCache::max_shareable_prefix`) guarantees both.
    ///
    /// `adopted == 0` is exactly [`Self::append`] (the `--prefix-cache`
    /// off path goes through here with 0).
    pub fn append_prefill_suffix(&mut self, k: &[f32], v: &[f32], n: usize,
                                 adopted: usize) {
        if adopted == 0 {
            return self.append(k, v, n);
        }
        let kv = self.cfg.kv_dim;
        let group = self.cfg.group;
        debug_assert_eq!(k.len(), n * kv);
        debug_assert_eq!(v.len(), n * kv);
        debug_assert_eq!(adopted % group, 0);
        debug_assert_eq!(self.k_hist, adopted, "suffix append must follow adoption");
        debug_assert_eq!(self.v_hist, adopted);
        debug_assert!(self.k_fp.is_empty() && self.v_fp.is_empty());
        self.k_fp.extend_from_slice(k);
        self.v_fp.extend_from_slice(v);
        let adopted_blocks = adopted / group;
        let k_quantize = match self.cfg.key {
            KeyRepr::Fp => 0,
            _ => {
                let full = self.cfg.k_window.blocks_to_quantize(adopted + n, group);
                debug_assert!(adopted_blocks <= full,
                              "adopted K prefix exceeds the window's quantizable run");
                full - adopted_blocks
            }
        };
        for _ in 0..k_quantize {
            self.quantize_oldest_k_block();
        }
        let v_quantize = match self.cfg.value {
            ValueRepr::Fp => 0,
            _ => {
                let full = self.cfg.v_window.blocks_to_quantize(adopted + n, group);
                debug_assert!(adopted_blocks <= full,
                              "adopted V prefix exceeds the window's quantizable run");
                full - adopted_blocks
            }
        };
        for _ in 0..v_quantize {
            self.quantize_oldest_v_block();
        }
    }

    /// Full-precision window tokens of one side.
    pub fn fp_tokens(&self, side: KvSide) -> usize {
        match side {
            KvSide::Key => self.k_fp_tokens(),
            KvSide::Value => self.v_fp_tokens(),
        }
    }

    /// Pages (rounded up) holding one side's fp window.
    pub fn fp_pages(&self, side: KvSide, page_tokens: usize) -> usize {
        self.fp_tokens(side).div_ceil(page_tokens)
    }

    /// Pages (rounded up) holding one side's quantized history.
    pub fn quant_pages(&self, side: KvSide, page_tokens: usize) -> usize {
        let bpp = page_tokens / self.cfg.group;
        self.quant_blocks(side).len().div_ceil(bpp)
    }

    /// Fully-populated ("sealed") quantized pages — the only pages the
    /// pressure controller may downshift; a partial page is still being
    /// appended into at the plan's width.
    pub fn sealed_quant_pages(&self, side: KvSide, page_tokens: usize) -> usize {
        let bpp = page_tokens / self.cfg.group;
        self.quant_blocks(side).len() / bpp
    }

    /// Precision class of quantized page `page` of one side.
    pub fn quant_page_bits(&self, side: KvSide, page: usize, page_tokens: usize) -> u8 {
        let bpp = page_tokens / self.cfg.group;
        self.quant_blocks(side)[page * bpp].bits
    }

    /// Requantize quantized page `page` of `side` to `to_bits` — the
    /// pressure controller's downshift, reusing the groupq packing via
    /// [`PackedBlock::requantize`].  Returns modeled bytes saved.
    ///
    /// When the page's blocks are shared (prefix sharing),
    /// `Arc::make_mut` copy-on-writes: this cache gets a private
    /// downshifted copy and the shared bytes — still read by the other
    /// owners and/or the prefix index — are untouched.  The page pool
    /// observes the split at the next `sync` (DESIGN.md §Prefix-Sharing).
    pub fn requant_page(&mut self, side: KvSide, page: usize, page_tokens: usize,
                        to_bits: u8) -> usize {
        let bpp = page_tokens / self.cfg.group;
        let blocks = match side {
            KvSide::Key => &mut self.k_blocks,
            KvSide::Value => &mut self.v_blocks,
        };
        let b1 = ((page + 1) * bpp).min(blocks.len());
        let mut saved = 0;
        for b in &mut blocks[page * bpp..b1] {
            if to_bits >= b.bits {
                continue; // no-op rung: don't unshare via make_mut for nothing
            }
            saved += Arc::make_mut(b)
                .requantize(to_bits, &mut self.tscratch, &mut self.qscratch);
        }
        saved
    }

    // ------------- spill tier (DESIGN.md §Spill-Tier) -------------

    /// Serialize quantized page `page` of `side` and replace its blocks
    /// with zero-byte stubs (bits/n/group kept, payload vectors empty,
    /// uid 0).  Stubs model 0 bytes, so `modeled_bytes` drops by exactly
    /// the page's footprint; `quant_page_bits` stays valid on a stub.
    /// The caller (the page pool's spill rung) owns the returned bytes
    /// and must [`Self::restore_spill_page`] before the next attend —
    /// a stub cannot be attended or requantized.
    pub fn take_spill_page(&mut self, side: KvSide, page: usize,
                           page_tokens: usize) -> Vec<u8> {
        let bpp = page_tokens / self.cfg.group;
        let blocks = match side {
            KvSide::Key => &mut self.k_blocks,
            KvSide::Value => &mut self.v_blocks,
        };
        let b1 = ((page + 1) * bpp).min(blocks.len());
        let mut out = Vec::new();
        for b in &mut blocks[page * bpp..b1] {
            debug_assert!(!b.words.is_empty() || b.n == 0, "page already spilled");
            debug_assert_eq!(Arc::strong_count(b), 1, "shared pages are spill-exempt");
            encode_block(b, &mut out);
            let stub = PackedBlock {
                bits: b.bits, n: b.n, group: b.group, interleaved: b.interleaved,
                words: Vec::new(), scales: Vec::new(), mins: Vec::new(),
                outliers: Vec::new(), uid: 0,
            };
            *b = Arc::new(stub);
        }
        out
    }

    /// Fault a spilled page back: decode `bytes` (produced by
    /// [`Self::take_spill_page`]) and replace the stubs.  The restored
    /// blocks are bit-identical to what was spilled but carry fresh uids
    /// ([`PackedBlock::from_parts`]), so the fused kernels' unpack cache
    /// can never serve stale integers.
    pub fn restore_spill_page(&mut self, side: KvSide, page: usize,
                              page_tokens: usize, bytes: &[u8]) {
        let bpp = page_tokens / self.cfg.group;
        let blocks = match side {
            KvSide::Key => &mut self.k_blocks,
            KvSide::Value => &mut self.v_blocks,
        };
        let b1 = ((page + 1) * bpp).min(blocks.len());
        let mut pos = 0;
        for b in &mut blocks[page * bpp..b1] {
            let restored = decode_block(bytes, &mut pos)
                .expect("truncated spill extent");
            debug_assert!(b.words.is_empty() && b.n > 0, "restore target must be a stub");
            debug_assert_eq!((restored.bits, restored.n, restored.group, restored.interleaved),
                             (b.bits, b.n, b.group, b.interleaved),
                             "spill extent does not match the stub's shape");
            *b = Arc::new(restored);
        }
        debug_assert_eq!(pos, bytes.len(), "trailing bytes in spill extent");
    }

    /// Whether quantized page `page` of `side` currently sits in the
    /// spill tier (its blocks are stubs).
    pub fn quant_page_spilled(&self, side: KvSide, page: usize,
                              page_tokens: usize) -> bool {
        let bpp = page_tokens / self.cfg.group;
        let blocks = self.quant_blocks(side);
        let b = &blocks[page * bpp];
        b.words.is_empty() && b.n > 0
    }

    /// Any page of this layer spilled? (fast pre-attend check)
    pub fn any_spilled(&self) -> bool {
        self.k_blocks.iter().chain(&self.v_blocks)
            .any(|b| b.words.is_empty() && b.n > 0)
    }

    // ---------------- attention ----------------

    /// Decode attention for a batchful of query heads against this cache.
    ///
    /// `q`: `[n_heads][head_dim]` (RoPE'd), `out`: `[n_heads][head_dim]`
    /// overwritten.  `n_heads` must be a multiple of the kv head count
    /// (GQA).  `scratch` carries reusable buffers.
    pub fn attend(&self, q: &[f32], n_heads: usize, out: &mut [f32],
                  scratch: &mut AttnScratch) {
        let hd = self.cfg.head_dim;
        let kv = self.cfg.kv_dim;
        let n_kv = self.cfg.n_kv_heads();
        let rep = n_heads / n_kv;
        let total = self.len();
        debug_assert!(total > 0);
        let scale = 1.0 / (hd as f32).sqrt();
        let g = self.cfg.group;

        // exact-size fast path: the steady decode state hits the same
        // (n_heads, total) shape every step once the window stabilizes —
        // skip the resize bookkeeping and just re-zero in place.  A grow
        // clears first so the old prefix isn't copied twice.
        if scratch.scores.len() != n_heads * total {
            scratch.scores.clear();
            scratch.scores.resize(n_heads * total, 0.0);
        } else {
            scratch.scores.fill(0.0);
        }

        // --- K scores ---
        match self.cfg.key {
            KeyRepr::SignJl { jl_dim } => {
                let store = self.k_jl.as_ref().unwrap();
                let proj = self.jl_proj.as_ref().unwrap();
                scratch.rq.resize(jl_dim, 0.0);
                // JL history scores per head; store entries are interleaved
                // [t][kv_head] — score rows select by head
                for h in 0..n_heads {
                    let kvh = h / rep;
                    proj.project(&q[h * hd..(h + 1) * hd], &mut scratch.rq);
                    let row = &mut scratch.scores[h * total..h * total + self.k_hist];
                    // compute per (token,kv_head) entries
                    if scratch.jl_tmp.len() != self.k_hist * n_kv {
                        scratch.jl_tmp.clear();
                        scratch.jl_tmp.resize(self.k_hist * n_kv, 0.0);
                    } else {
                        scratch.jl_tmp.fill(0.0);
                    }
                    store.scores(&scratch.rq, &mut scratch.jl_tmp);
                    for t in 0..self.k_hist {
                        row[t] = scratch.jl_tmp[t * n_kv + kvh];
                    }
                }
            }
            KeyRepr::PerChannel { .. } => {
                // head-tiled per-block dispatch (the pressure ladder
                // mixes widths): each block's fields decode once per KV
                // group and fan out across its `rep` query heads, with
                // per-(head, channel) q·scale precomputed per block.
                // Contiguous same-width runs share one timer read so the
                // per-width breakdown costs two clock calls per run.
                let mut bi = 0;
                while bi < self.k_blocks.len() {
                    let bits = self.k_blocks[bi].bits;
                    let end = self.k_blocks[bi..].iter().position(|b| b.bits != bits)
                        .map_or(self.k_blocks.len(), |p| bi + p);
                    let t0 = Instant::now();
                    for (i, block) in self.k_blocks[bi..end].iter().enumerate() {
                        let off = (bi + i) * g;
                        for kvh in 0..n_kv {
                            let h0 = kvh * rep;
                            let qg = &q[h0 * hd..(h0 + rep) * hd];
                            let rows = &mut scratch.scores[h0 * total + off..];
                            key_scores_group_dispatch(qg, rep, block, g, kvh * hd,
                                                      &mut scratch.fused, rows, total,
                                                      &mut scratch.tile);
                        }
                    }
                    scratch.kernel_ns[attn_width_bucket(bits)] +=
                        t0.elapsed().as_nanos() as u64;
                    bi = end;
                }
            }
            KeyRepr::PerToken { .. } => {
                let mut bi = 0;
                while bi < self.k_blocks.len() {
                    let bits = self.k_blocks[bi].bits;
                    let end = self.k_blocks[bi..].iter().position(|b| b.bits != bits)
                        .map_or(self.k_blocks.len(), |p| bi + p);
                    let t0 = Instant::now();
                    for (i, block) in self.k_blocks[bi..end].iter().enumerate() {
                        token_major_key_scores(block, q, n_heads, hd, kv, rep, g,
                                               (bi + i) * g, total, scratch);
                    }
                    scratch.kernel_ns[attn_width_bucket(bits)] +=
                        t0.elapsed().as_nanos() as u64;
                    bi = end;
                }
            }
            KeyRepr::Fp => {}
        }
        // fp K window
        let k_fp_tokens = self.k_fp_tokens();
        let k_fp_start = total - k_fp_tokens;
        if k_fp_tokens > 0 {
            let t0 = Instant::now();
            for h in 0..n_heads {
                let kvh = h / rep;
                let qh = &q[h * hd..(h + 1) * hd];
                let row = &mut scratch.scores[h * total..(h + 1) * total];
                for t in 0..k_fp_tokens {
                    let key = &self.k_fp[t * kv + kvh * hd..t * kv + kvh * hd + hd];
                    let mut acc = 0f32;
                    for d in 0..hd {
                        acc += qh[d] * key[d];
                    }
                    row[k_fp_start + t] += acc;
                }
            }
            scratch.kernel_ns[ATTN_FP_BUCKET] += t0.elapsed().as_nanos() as u64;
        }

        // --- softmax (scaled) per head ---
        for h in 0..n_heads {
            let row = &mut scratch.scores[h * total..(h + 1) * total];
            let mut mx = f32::NEG_INFINITY;
            for s in row.iter_mut() {
                *s *= scale;
                mx = mx.max(*s);
            }
            let mut sum = 0f32;
            for s in row.iter_mut() {
                *s = (*s - mx).exp();
                sum += *s;
            }
            let inv = 1.0 / sum;
            for s in row.iter_mut() {
                *s *= inv;
            }
        }

        // --- weighted values ---
        // overwrite semantic (not a fast-path candidate: skipping the
        // zero-fill when sizes match would accumulate across steps)
        out[..n_heads * hd].fill(0.0);
        match self.cfg.value {
            ValueRepr::PerToken { .. } => {
                let mut bi = 0;
                while bi < self.v_blocks.len() {
                    let bits = self.v_blocks[bi].bits;
                    let end = self.v_blocks[bi..].iter().position(|b| b.bits != bits)
                        .map_or(self.v_blocks.len(), |p| bi + p);
                    let t0 = Instant::now();
                    for (i, block) in self.v_blocks[bi..end].iter().enumerate() {
                        let off = (bi + i) * g;
                        for kvh in 0..n_kv {
                            let h0 = kvh * rep;
                            let p = &scratch.scores[h0 * total + off..];
                            let o = &mut out[h0 * hd..(h0 + rep) * hd];
                            value_accum_group_dispatch(p, total, rep, block, kv,
                                                       kvh * hd, hd, &mut scratch.fused,
                                                       o, &mut scratch.tile);
                        }
                    }
                    scratch.kernel_ns[attn_width_bucket(bits)] +=
                        t0.elapsed().as_nanos() as u64;
                    bi = end;
                }
            }
            ValueRepr::Fp => {}
        }
        // fp V window
        let v_fp_tokens = self.v_fp_tokens();
        let v_fp_start = total - v_fp_tokens;
        if v_fp_tokens > 0 {
            let t0 = Instant::now();
            for h in 0..n_heads {
                let kvh = h / rep;
                let row = &scratch.scores[h * total..(h + 1) * total];
                let o = &mut out[h * hd..(h + 1) * hd];
                for t in 0..v_fp_tokens {
                    let p = row[v_fp_start + t];
                    if p == 0.0 {
                        continue;
                    }
                    let val = &self.v_fp[t * kv + kvh * hd..t * kv + kvh * hd + hd];
                    for d in 0..hd {
                        o[d] += p * val[d];
                    }
                }
            }
            scratch.kernel_ns[ATTN_FP_BUCKET] += t0.elapsed().as_nanos() as u64;
        }
    }
}

/// Per-token-grouped Key scores (Atom / k-T rows): token-major stream.
fn token_major_key_scores(block: &PackedBlock, q: &[f32], n_heads: usize,
                          hd: usize, kv: usize, rep: usize, g: usize,
                          t_off: usize, total: usize, scratch: &mut AttnScratch) {
    // dequantize block once into f32 scratch (the per-token layout doesn't
    // admit the per-channel bias trick; this is still block-local)
    scratch.fused.f32s.resize(block.n, 0.0);
    let mut ints = std::mem::take(&mut scratch.fused.ints);
    block.dequantize_into(&mut scratch.fused.f32s, &mut ints);
    scratch.fused.ints = ints;
    scratch.fused.invalidate();
    // KV-group-outer tiling: each dequantized key row is loaded once and
    // dotted against all `rep` query heads of its group while hot.  The
    // per-(head, token) dot runs the same d-ascending local accumulator
    // as before, so scores are bit-identical to the head-outer loop.
    let n_kv = kv / hd;
    for kvh in 0..n_kv {
        for t in 0..g {
            let key = &scratch.fused.f32s[t * kv + kvh * hd..t * kv + kvh * hd + hd];
            for r in 0..rep {
                let h = kvh * rep + r;
                if h >= n_heads {
                    break;
                }
                let qh = &q[h * hd..(h + 1) * hd];
                let mut acc = 0f32;
                for d in 0..hd {
                    acc += qh[d] * key[d];
                }
                scratch.scores[h * total + t_off + t] += acc;
            }
        }
    }
}

/// Buckets of the per-bit-width attention-time breakdown: one per ladder
/// width (1/2/3/4/8/16-bit) plus the fp window tail.
pub const ATTN_WIDTH_BUCKETS: usize = 7;

/// Bucket holding the fp window's share.
pub const ATTN_FP_BUCKET: usize = ATTN_WIDTH_BUCKETS - 1;

/// Report labels, indexed like [`attn_width_bucket`].
pub const ATTN_WIDTH_LABELS: [&str; ATTN_WIDTH_BUCKETS] =
    ["1b", "2b", "3b", "4b", "8b", "16b", "fp"];

/// Breakdown bucket for a block width (unknown widths land in the fp
/// bucket alongside the un-quantized window).
#[inline]
pub fn attn_width_bucket(bits: u8) -> usize {
    match bits {
        1 => 0,
        2 => 1,
        3 => 2,
        4 => 3,
        8 => 4,
        16 => 5,
        _ => ATTN_FP_BUCKET,
    }
}

/// Reusable buffers for [`LayerKvCache::attend`].
///
/// Not shared between threads: the decode fan-out keeps one `AttnScratch`
/// per pool worker (`DecodeScratch::lanes`), sized once and reused every
/// step so the steady-state path does not allocate.  The `fused` unpack
/// scratch is a fallback-only buffer since the integer-domain packed
/// kernels took over every ladder width, 3-bit included (DESIGN.md
/// §Quantized-Kernels): its `ints` staging never allocates unless a
/// non-ladder width or the per-token key ablation path runs on this
/// worker.  `tile` carries the head-tiled kernels' per-(head, channel)
/// weight tables.
#[derive(Default)]
pub struct AttnScratch {
    pub scores: Vec<f32>,
    pub fused: FusedScratch,
    pub tile: TileScratch,
    pub rq: Vec<f32>,
    pub jl_tmp: Vec<f32>,
    /// accumulated attend kernel time per width bucket
    /// ([`attn_width_bucket`]); the model step drains this into
    /// `Metrics::attn_ns_by_width`
    pub kernel_ns: [u64; ATTN_WIDTH_BUCKETS],
}

// The decode fan-out sends per-lane caches and per-worker scratches to
// scoped pool workers; every field is owned Vec/Option/Arc state, so
// `Send` must (and does) hold for all of these.  Shared history blocks
// additionally need `Sync`: with prefix sharing the *same*
// `Arc<PackedBlock>` can sit in two lanes attended by two workers at
// once (read-only — the engine-thread pressure controller is the only
// mutator, via copy-on-write).
const _: () = {
    const fn assert_send<T: Send>() {}
    const fn assert_sync<T: Sync>() {}
    assert_send::<LayerKvCache>();
    assert_send::<AttnScratch>();
    assert_send::<PackedBlock>();
    assert_sync::<PackedBlock>();
    assert_send::<super::jl::JlProjector>();
    assert_send::<super::jl::SignJlKeys>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg(key: KeyRepr, value: ValueRepr, kw: WindowPolicy, vw: WindowPolicy) -> LayerCacheCfg {
        LayerCacheCfg { kv_dim: 64, head_dim: 32, group: 32, key, value,
                        k_window: kw, v_window: vw, outlier_frac: 0.0,
                        k_interleave: false }
    }

    #[test]
    fn append_and_window_dynamics() {
        let c = cfg(KeyRepr::PerChannel { bits: 2 }, ValueRepr::PerToken { bits: 2 },
                    WindowPolicy::Rpc { ratio: 0.1 }, WindowPolicy::Rpc { ratio: 0.1 });
        let mut cache = LayerKvCache::new(c);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let k = rng.normal_vec(64);
            let v = rng.normal_vec(64);
            cache.append(&k, &v, 1);
        }
        assert_eq!(cache.len(), 100);
        // rpc 10%: fp window stays small, most history quantized
        assert!(cache.k_hist >= 64, "k_hist={}", cache.k_hist);
        assert!(cache.k_fp_tokens() < 40);
        assert_eq!(cache.k_hist + cache.k_fp_tokens(), 100);
        assert_eq!(cache.v_hist + cache.v_fp_tokens(), 100);
    }

    #[test]
    fn fp16_never_quantizes() {
        let c = cfg(KeyRepr::Fp, ValueRepr::Fp, WindowPolicy::All, WindowPolicy::All);
        let mut cache = LayerKvCache::new(c);
        let mut rng = Rng::new(2);
        for _ in 0..80 {
            cache.append(&rng.normal_vec(64), &rng.normal_vec(64), 1);
        }
        assert_eq!(cache.k_hist, 0);
        assert_eq!(cache.k_fp_tokens(), 80);
    }

    #[test]
    fn attention_close_to_fp_reference() {
        // quantized at 4 bits should be very close to a pure-fp cache
        let mut rng = Rng::new(3);
        let n_tok = 96;
        let ks: Vec<f32> = rng.normal_vec(n_tok * 64);
        let vs: Vec<f32> = rng.normal_vec(n_tok * 64);
        let q: Vec<f32> = rng.normal_vec(4 * 32);

        let cfq = cfg(KeyRepr::PerChannel { bits: 4 }, ValueRepr::PerToken { bits: 4 },
                      WindowPolicy::None, WindowPolicy::None);
        let mut quant = LayerKvCache::new(cfq);
        quant.append(&ks, &vs, n_tok);
        assert_eq!(quant.k_hist, 96);

        let cff = cfg(KeyRepr::Fp, ValueRepr::Fp, WindowPolicy::All, WindowPolicy::All);
        let mut full = LayerKvCache::new(cff);
        full.append(&ks, &vs, n_tok);

        let mut o1 = vec![0f32; 4 * 32];
        let mut o2 = vec![0f32; 4 * 32];
        let mut s = AttnScratch::default();
        quant.attend(&q, 4, &mut o1, &mut s);
        full.attend(&q, 4, &mut o2, &mut s);
        let max_diff = o1.iter().zip(&o2).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_diff < 0.08, "4-bit cache drifted {max_diff}");
        // and 1-bit must drift strictly more than 4-bit
        let cf1 = cfg(KeyRepr::PerChannel { bits: 1 }, ValueRepr::PerToken { bits: 1 },
                      WindowPolicy::None, WindowPolicy::None);
        let mut one = LayerKvCache::new(cf1);
        one.append(&ks, &vs, n_tok);
        let mut o3 = vec![0f32; 4 * 32];
        one.attend(&q, 4, &mut o3, &mut s);
        let drift1 = o3.iter().zip(&o2).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(drift1 > max_diff, "1-bit ({drift1}) should drift more than 4-bit ({max_diff})");
    }

    #[test]
    fn memory_shrinks_with_bits() {
        let mut sizes = Vec::new();
        for bits in [4u8, 2] {
            let c = cfg(KeyRepr::PerChannel { bits }, ValueRepr::PerToken { bits },
                        WindowPolicy::None, WindowPolicy::None);
            let mut cache = LayerKvCache::new(c);
            let mut rng = Rng::new(4);
            cache.append(&rng.normal_vec(128 * 64), &rng.normal_vec(128 * 64), 128);
            sizes.push(cache.modeled_bytes());
        }
        assert!(sizes[1] < sizes[0]);
        // fp16 reference for 128 tokens: 128*64*2*2 bytes
        let fp = 128 * 64 * 2 * 2;
        assert!((fp as f64 / sizes[1] as f64) > 4.0, "2-bit compression {}", fp as f64 / sizes[1] as f64);
    }

    #[test]
    fn requant_page_downshifts_oldest_history_only() {
        let c = cfg(KeyRepr::PerChannel { bits: 4 }, ValueRepr::PerToken { bits: 4 },
                    WindowPolicy::None, WindowPolicy::None);
        let mut cache = LayerKvCache::new(c);
        let mut rng = Rng::new(17);
        let n_tok = 128; // 4 blocks per side = 2 pages at 64-token pages
        let ks = rng.normal_vec(n_tok * 64);
        let vs = rng.normal_vec(n_tok * 64);
        cache.append(&ks, &vs, n_tok);
        let pt = 64;
        assert_eq!(cache.quant_pages(KvSide::Key, pt), 2);
        assert_eq!(cache.sealed_quant_pages(KvSide::Key, pt), 2);
        let before = cache.modeled_bytes();

        // reference attention at the original 4-bit precision
        let q = rng.normal_vec(4 * 32);
        let mut s = AttnScratch::default();
        let mut o4 = vec![0f32; 4 * 32];
        cache.attend(&q, 4, &mut o4, &mut s);

        let saved = cache.requant_page(KvSide::Key, 0, pt, 2);
        assert!(saved > 0);
        assert_eq!(cache.modeled_bytes(), before - saved);
        assert_eq!(cache.quant_page_bits(KvSide::Key, 0, pt), 2, "oldest page downshifted");
        assert_eq!(cache.quant_page_bits(KvSide::Key, 1, pt), 4, "newest page untouched");

        // attention still runs over the mixed-precision pages, with a
        // bounded drift vs the pre-downshift output
        let mut o2 = vec![0f32; 4 * 32];
        cache.attend(&q, 4, &mut o2, &mut s);
        let drift = o2.iter().zip(&o4).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(drift > 0.0 && drift < 1.0, "drift {drift}");
    }

    #[test]
    fn adopted_suffix_append_matches_full_append() {
        // prefix sharing's core bit-identity claim at the layer level:
        // adopt page 0's blocks + append the suffix == one full append,
        // for both the eager and the dynamic-RPC window
        for (kw, vw) in [(WindowPolicy::None, WindowPolicy::None),
                         (WindowPolicy::Rpc { ratio: 0.1 }, WindowPolicy::Rpc { ratio: 0.2 })] {
            let c = cfg(KeyRepr::PerChannel { bits: 2 }, ValueRepr::PerToken { bits: 2 },
                        kw, vw);
            let mut rng = Rng::new(21);
            let n_tok = 192;
            let pt = 64; // one adopted page = 2 blocks
            let ks = rng.normal_vec(n_tok * 64);
            let vs = rng.normal_vec(n_tok * 64);

            let mut full = LayerKvCache::new(c);
            full.append(&ks, &vs, n_tok);
            assert!(full.k_hist >= pt && full.v_hist >= pt, "prefix must be quantized");

            let mut adopted = LayerKvCache::new(c);
            let bpp = pt / 32;
            adopted.adopt_shared_blocks(KvSide::Key, &full.k_blocks[..bpp]);
            adopted.adopt_shared_blocks(KvSide::Value, &full.v_blocks[..bpp]);
            adopted.append_prefill_suffix(&ks[pt * 64..], &vs[pt * 64..], n_tok - pt, pt);

            assert_eq!(adopted.len(), full.len());
            assert_eq!(adopted.k_hist, full.k_hist);
            assert_eq!(adopted.v_hist, full.v_hist);
            assert_eq!(adopted.k_fp(), full.k_fp(), "fp K window must match");
            assert_eq!(adopted.v_fp(), full.v_fp(), "fp V window must match");
            assert_eq!(adopted.k_blocks.len(), full.k_blocks.len());
            for (a, b) in adopted.k_blocks.iter().zip(&full.k_blocks)
                .chain(adopted.v_blocks.iter().zip(&full.v_blocks)) {
                assert_eq!(a.words, b.words, "packed words must be bit-identical");
                assert_eq!(a.scales, b.scales);
                assert_eq!(a.mins, b.mins);
                assert_eq!(a.bits, b.bits);
            }
        }
    }

    #[test]
    fn shared_page_requant_copy_on_writes() {
        let c = cfg(KeyRepr::PerChannel { bits: 4 }, ValueRepr::PerToken { bits: 4 },
                    WindowPolicy::None, WindowPolicy::None);
        let mut rng = Rng::new(22);
        let pt = 64;
        let mut donor = LayerKvCache::new(c);
        let ks = rng.normal_vec(128 * 64);
        let vs = rng.normal_vec(128 * 64);
        donor.append(&ks, &vs, 128);

        let mut other = LayerKvCache::new(c);
        other.adopt_shared_blocks(KvSide::Key, &donor.k_blocks[..2]);
        other.adopt_shared_blocks(KvSide::Value, &donor.v_blocks[..2]);
        assert!(donor.quant_page_shared(KvSide::Key, 0, pt));
        assert!(other.quant_page_shared(KvSide::Key, 0, pt));
        assert!(!donor.quant_page_shared(KvSide::Key, 1, pt), "page 1 is private");

        let donor_words = donor.k_blocks[0].words.clone();
        let saved = other.requant_page(KvSide::Key, 0, pt, 2);
        assert!(saved > 0);
        assert_eq!(other.quant_page_bits(KvSide::Key, 0, pt), 2);
        // CoW split: the donor's shared bytes are untouched and unshared now
        assert_eq!(donor.quant_page_bits(KvSide::Key, 0, pt), 4);
        assert_eq!(donor.k_blocks[0].words, donor_words);
        assert!(!other.quant_page_shared(KvSide::Key, 0, pt), "split made it private");
    }

    #[test]
    fn spill_page_round_trip_is_byte_identical() {
        let c = cfg(KeyRepr::PerChannel { bits: 2 }, ValueRepr::PerToken { bits: 2 },
                    WindowPolicy::None, WindowPolicy::None);
        let mut cache = LayerKvCache::new(c);
        let mut rng = Rng::new(33);
        let pt = 64;
        cache.append(&rng.normal_vec(128 * 64), &rng.normal_vec(128 * 64), 128);
        let before = cache.modeled_bytes();
        let orig: Vec<_> = cache.k_blocks[..2].iter().map(|b| (**b).clone()).collect();

        let bytes = cache.take_spill_page(KvSide::Key, 0, pt);
        assert!(cache.quant_page_spilled(KvSide::Key, 0, pt));
        assert!(!cache.quant_page_spilled(KvSide::Key, 1, pt));
        assert!(cache.any_spilled());
        assert_eq!(cache.quant_page_bits(KvSide::Key, 0, pt), 2, "bits survive on the stub");
        let spilled_bytes = before - cache.modeled_bytes();
        assert_eq!(spilled_bytes,
                   orig.iter().map(|b| b.modeled_bytes()).sum::<usize>(),
                   "spill removes exactly the page's modeled footprint");

        cache.restore_spill_page(KvSide::Key, 0, pt, &bytes);
        assert!(!cache.quant_page_spilled(KvSide::Key, 0, pt));
        assert!(!cache.any_spilled());
        assert_eq!(cache.modeled_bytes(), before);
        for (r, o) in cache.k_blocks[..2].iter().zip(&orig) {
            assert_eq!(r.words, o.words, "packed words byte-identical after fault-back");
            assert_eq!(r.scales.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                       o.scales.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
            assert_eq!(r.mins.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                       o.mins.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
            assert_eq!(r.outliers, o.outliers);
            assert_ne!(r.uid, o.uid, "restored blocks carry fresh uids");
        }
    }

    #[test]
    fn attend_bit_identical_across_k_layouts() {
        // the interleaved Key layout is a pure word permutation, and
        // attend is the only stage that reads the cache — so bit-equal
        // attend outputs pin generations bit-identical across layouts
        for bits in [2u8, 4] {
            let mut c = cfg(KeyRepr::PerChannel { bits }, ValueRepr::PerToken { bits },
                            WindowPolicy::Rpc { ratio: 0.2 },
                            WindowPolicy::Rpc { ratio: 0.2 });
            c.outlier_frac = 0.01;
            let mut rng = Rng::new(41);
            let n_tok = 160;
            let ks = rng.normal_vec(n_tok * 64);
            let vs = rng.normal_vec(n_tok * 64);
            let q = rng.normal_vec(4 * 32);

            let mut lin = LayerKvCache::new(c);
            lin.append(&ks, &vs, n_tok);
            c.k_interleave = true;
            let mut inter = LayerKvCache::new(c);
            inter.append(&ks, &vs, n_tok);
            assert!(lin.k_hist > 0);
            assert!(inter.k_blocks.iter().all(|b| b.interleaved));
            assert!(inter.v_blocks.iter().all(|b| !b.interleaved), "V stays linear");

            let mut s = AttnScratch::default();
            let mut ol = vec![0f32; 4 * 32];
            let mut oi = vec![0f32; 4 * 32];
            lin.attend(&q, 4, &mut ol, &mut s);
            inter.attend(&q, 4, &mut oi, &mut s);
            for (a, b) in ol.iter().zip(&oi) {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={bits}");
            }

            // the pressure downshift must preserve the equivalence too
            // (requantize re-applies the layout after re-encoding)
            if bits > 2 {
                lin.requant_page(KvSide::Key, 0, 64, 2);
                inter.requant_page(KvSide::Key, 0, 64, 2);
                assert!(inter.k_blocks[0].interleaved);
                lin.attend(&q, 4, &mut ol, &mut s);
                inter.attend(&q, 4, &mut oi, &mut s);
                for (a, b) in ol.iter().zip(&oi) {
                    assert_eq!(a.to_bits(), b.to_bits(), "post-downshift bits={bits}");
                }
            }
        }
    }

    #[test]
    fn attend_records_per_width_kernel_time() {
        let c = cfg(KeyRepr::PerChannel { bits: 2 }, ValueRepr::PerToken { bits: 2 },
                    WindowPolicy::Rpc { ratio: 0.2 }, WindowPolicy::Rpc { ratio: 0.2 });
        let mut cache = LayerKvCache::new(c);
        let mut rng = Rng::new(42);
        cache.append(&rng.normal_vec(128 * 64), &rng.normal_vec(128 * 64), 128);
        assert!(cache.k_hist > 0 && cache.k_fp_tokens() > 0);
        let q = rng.normal_vec(4 * 32);
        let mut s = AttnScratch::default();
        let mut o = vec![0f32; 4 * 32];
        cache.attend(&q, 4, &mut o, &mut s);
        assert!(s.kernel_ns[attn_width_bucket(2)] > 0, "2-bit bucket must accrue");
        assert!(s.kernel_ns[ATTN_FP_BUCKET] > 0, "fp window bucket must accrue");
        assert_eq!(s.kernel_ns[attn_width_bucket(4)], 0, "no 4-bit blocks attended");
    }

    #[test]
    fn kivi_fixed_residual_keeps_constant_window() {
        let c = cfg(KeyRepr::PerChannel { bits: 2 }, ValueRepr::PerToken { bits: 2 },
                    WindowPolicy::FixedResidual { tokens: 64 },
                    WindowPolicy::FixedResidual { tokens: 64 });
        let mut cache = LayerKvCache::new(c);
        let mut rng = Rng::new(5);
        for _ in 0..300 {
            cache.append(&rng.normal_vec(64), &rng.normal_vec(64), 1);
        }
        let fp = cache.k_fp_tokens();
        assert!((64..64 + 32).contains(&fp), "kivi window {fp}");
    }
}
