//! Token sampling: greedy / temperature / top-k over a logits row.

use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
pub enum Sampler {
    Greedy,
    /// softmax(logits / temperature) restricted to the top-k tokens
    TopK { k: usize, temperature: f32 },
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> usize {
        match *self {
            Sampler::Greedy => argmax(logits),
            Sampler::TopK { k, temperature } => {
                let k = k.max(1).min(logits.len());
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap()
                });
                idx.truncate(k);
                let mx = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f64> = idx.iter()
                    .map(|&i| (((logits[i] - mx) / temperature.max(1e-6)) as f64).exp())
                    .collect();
                idx[rng.choice_weighted(&weights)]
            }
        }
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// log-softmax probability of `target` under `logits` (for perplexity).
pub fn log_prob(logits: &[f32], target: usize) -> f64 {
    let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse: f64 = logits.iter().map(|&x| ((x - mx) as f64).exp()).sum::<f64>().ln() + mx as f64;
    logits[target] as f64 - lse
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let l = [0.1f32, 3.0, -1.0, 2.9];
        assert_eq!(Sampler::Greedy.sample(&l, &mut Rng::new(0)), 1);
    }

    #[test]
    fn topk_stays_in_topk() {
        let l = [0.0f32, 10.0, 9.0, -5.0, 8.0];
        let s = Sampler::TopK { k: 3, temperature: 1.0 };
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let t = s.sample(&l, &mut rng);
            assert!(matches!(t, 1 | 2 | 4), "sampled {t}");
        }
    }

    #[test]
    fn log_prob_normalized() {
        let l = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_prob(&l, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
