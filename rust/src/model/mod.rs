//! Model orchestration: drives the per-layer XLA executables + the
//! quantized-cache attention to implement prefill and batched decode.
//!
//! Threading (DESIGN.md §Threading-Model): the dense per-layer compute
//! (`pre`/`post`/`logits`) stays on the engine thread — the PJRT client is
//! driven from exactly one thread — while the quantized-cache attention,
//! which is embarrassingly parallel across batch lanes, fans out across a
//! [`WorkerPool`] when one is attached via [`Forward::with_pool`].

pub mod sampler;

use std::time::Instant;

use anyhow::Result;

use crate::attention::prefill_attention_with;
use crate::kvcache::cache::ATTN_WIDTH_BUCKETS;
use crate::kvcache::{AttnScratch, SeqKvCache};
use crate::runtime::Runtime;
use crate::util::WorkerPool;

pub use sampler::Sampler;

/// Stateless forward driver over a [`Runtime`].
pub struct Forward<'a> {
    pub rt: &'a Runtime,
    /// decode/prefill attention fan-out; `None` = sequential
    pool: Option<&'a WorkerPool>,
}

impl<'a> Forward<'a> {
    /// Sequential driver (no attention fan-out).
    pub fn new(rt: &'a Runtime) -> Self {
        Forward { rt, pool: None }
    }

    /// Driver whose per-lane attention fans out across `pool`
    /// (`None` behaves exactly like [`Forward::new`]).
    pub fn with_pool(rt: &'a Runtime, pool: Option<&'a WorkerPool>) -> Self {
        Forward { rt, pool }
    }

    /// Prefill `tokens` into `cache` (which must be empty); returns the
    /// full `[t][vocab]` logits of the prompt.
    ///
    /// Prompt self-attention is full precision; the K/V written to the
    /// cache are quantized per the cache's policy as the windows overflow
    /// (paper Fig. 4 prefill phase).
    pub fn prefill(&self, tokens: &[i32], cache: &mut SeqKvCache) -> Result<Vec<f32>> {
        self.prefill_from(tokens, cache, 0)
    }

    /// Prefill with the first `adopted` tokens' quantized pages already
    /// adopted from the pool's prefix index (DESIGN.md §Prefix-Sharing).
    /// The dense forward and the fp prompt attention still cover the
    /// **full** prompt — so the returned logits, and with them the first
    /// sampled token, are bit-identical to a cold prefill — but the
    /// cache append skips re-quantizing the adopted prefix and writes
    /// only the unshared suffix (`LayerKvCache::append_prefill_suffix`).
    /// `adopted` must be group-aligned and within the window policies'
    /// quantizable run; the engine's `SeqKvCache::max_shareable_prefix`
    /// cap guarantees both.  `adopted == 0` is exactly [`Self::prefill`].
    pub fn prefill_from(&self, tokens: &[i32], cache: &mut SeqKvCache,
                        adopted: usize) -> Result<Vec<f32>> {
        let m = &self.rt.model;
        let t = tokens.len();
        let kvd = m.kv_dim();
        debug_assert!(adopted <= t);
        debug_assert_eq!(cache.len(), adopted, "cache must hold exactly the adopted prefix");
        let mut h = self.rt.embed(tokens)?;
        let pos: Vec<i32> = (0..t as i32).collect();
        for layer in 0..m.n_layers {
            let (q, k, v) = self.rt.pre(layer, &h, &pos, t)?;
            let attn = prefill_attention_with(&q, &k, &v, t, m.n_heads, m.n_kv_heads,
                                              m.head_dim, self.pool);
            h = self.rt.post(layer, &attn, &h, t)?;
            cache.layers[layer].append_prefill_suffix(&k[adopted * kvd..],
                                                      &v[adopted * kvd..],
                                                      t - adopted, adopted);
        }
        self.rt.logits(&h, t)
    }

    /// Prefill one **chunk** of a prompt against a cache that already
    /// holds the `start` earlier prompt tokens (chunked prefill,
    /// DESIGN.md §Scheduler): returns the chunk's `[t][vocab]` logits.
    ///
    /// Unlike [`Self::prefill_from`], which replays the *whole* prompt
    /// densely, a chunk attends over the live cache — quantized history
    /// blocks, fp windows, and any prefix-adopted pages — exactly as a
    /// decode step would: per token, append its K/V (window policies
    /// quantize overflowing groups as usual) then attend causally over
    /// everything cached so far.  That is what bounds the step's work to
    /// the chunk size, and it is also why chunked generations are **not**
    /// bit-identical to the legacy dense prefill: earlier chunks are read
    /// back through their quantized representation
    /// (docs/adr/004-iteration-level-scheduling.md weighs this trade).
    ///
    /// `start` must be group-aligned (the scheduler's chunk grants
    /// guarantee it) so sealed pages stay bit-uniform; `tokens.len()`
    /// must fit a compiled bucket.  A chunk with `start == 0` on an empty
    /// cache is a complete-prompt prefill in one call — still through
    /// the cache-attention path, not the dense one.
    ///
    /// The attached worker pool is deliberately NOT used here: the pool
    /// fans decode attention out across *lanes*, and a chunk is a single
    /// lane whose tokens attend sequentially (token `i+1` needs token
    /// `i` appended first).  Head-parallel cache attention inside one
    /// lane would need an `attend` that takes a head sub-range (GQA
    /// indexing is absolute) — future work, tracked in
    /// docs/adr/004-iteration-level-scheduling.md.
    pub fn prefill_chunk(&self, tokens: &[i32], start: usize, cache: &mut SeqKvCache,
                         scratch: &mut DecodeScratch) -> Result<Vec<f32>> {
        let m = &self.rt.model;
        let t = tokens.len();
        let qd = m.q_dim();
        let kvd = m.kv_dim();
        debug_assert!(t > 0);
        debug_assert_eq!(cache.len(), start, "chunk must resume at the cache boundary");
        let mut h = self.rt.embed(tokens)?;
        let pos: Vec<i32> = (start..start + t).map(|p| p as i32).collect();
        scratch.attn.resize(t * qd, 0.0);
        scratch.attn_ns = 0;
        if scratch.lanes.is_empty() {
            scratch.lanes.push(AttnScratch::default());
        }
        scratch.reset_kernel_ns();
        for layer in 0..m.n_layers {
            let (q, k, v) = self.rt.pre(layer, &h, &pos, t)?;
            let t0 = Instant::now();
            let lc = &mut cache.layers[layer];
            let ws = &mut scratch.lanes[0];
            // append-then-attend per token: token i sees cached tokens
            // 0..start+i plus itself, never a later chunk token (causal)
            for i in 0..t {
                lc.append(&k[i * kvd..(i + 1) * kvd], &v[i * kvd..(i + 1) * kvd], 1);
                lc.attend(&q[i * qd..(i + 1) * qd], m.n_heads,
                          &mut scratch.attn[i * qd..(i + 1) * qd], ws);
            }
            scratch.attn_ns += t0.elapsed().as_nanos() as u64;
            h = self.rt.post(layer, &scratch.attn[..t * qd], &h, t)?;
        }
        scratch.gather_kernel_ns();
        self.rt.logits(&h, t)
    }

    /// One batched decode step: `tokens[b]` is the next input token of
    /// sequence `b`, `caches[b]` its cache.  Returns `[b][vocab]` logits.
    ///
    /// With a pool attached, each layer's per-lane quantized-cache
    /// attention (append + [`crate::kvcache::LayerKvCache::attend`]) runs
    /// on the workers, one contiguous lane range per worker with its own
    /// [`AttnScratch`]; per-lane arithmetic and lane order are identical
    /// to the sequential path, so logits are bit-identical for any thread
    /// count (see `rust/tests/threading.rs`).
    pub fn decode_step(&self, tokens: &[i32], caches: &mut [&mut SeqKvCache],
                       scratch: &mut DecodeScratch) -> Result<Vec<f32>> {
        let m = &self.rt.model;
        let bsz = tokens.len();
        debug_assert_eq!(caches.len(), bsz);
        let qd = m.q_dim();
        let kvd = m.kv_dim();
        let n_heads = m.n_heads;
        let mut h = self.rt.embed(tokens)?;
        let pos: Vec<i32> = caches.iter().map(|c| c.len() as i32).collect();
        scratch.attn.resize(bsz * qd, 0.0);
        scratch.attn_ns = 0;
        // one scratch per worker so the steady-state path never allocates
        let nw = match self.pool {
            Some(p) => p.threads().min(bsz).max(1),
            None => 1,
        };
        if scratch.lanes.len() < nw {
            scratch.lanes.resize_with(nw, AttnScratch::default);
        }
        scratch.reset_kernel_ns();
        for layer in 0..m.n_layers {
            let (q, k, v) = self.rt.pre(layer, &h, &pos, bsz)?;
            let t0 = Instant::now();
            match self.pool {
                Some(pool) if nw > 1 => {
                    let per = bsz.div_ceil(nw);
                    let chunks = caches
                        .chunks_mut(per)
                        .zip(scratch.attn.chunks_mut(per * qd))
                        .zip(scratch.lanes.iter_mut())
                        .enumerate()
                        .map(|(ci, ((lanes, out), ws))| {
                            LaneChunk { lane0: ci * per, lanes, out, ws }
                        });
                    pool.run_tasks(chunks, |_w, c| {
                        attend_lanes(c.lanes, layer, c.lane0, &q, &k, &v,
                                     qd, kvd, n_heads, c.out, c.ws);
                    });
                }
                _ => {
                    attend_lanes(caches, layer, 0, &q, &k, &v, qd, kvd, n_heads,
                                 &mut scratch.attn, &mut scratch.lanes[0]);
                }
            }
            scratch.attn_ns += t0.elapsed().as_nanos() as u64;
            h = self.rt.post(layer, &scratch.attn, &h, bsz)?;
        }
        scratch.gather_kernel_ns();
        self.rt.logits(&h, bsz)
    }
}

/// One worker's share of a layer's decode attention: a contiguous lane
/// range, its slice of the attention output, and a private scratch.
struct LaneChunk<'a, 'c> {
    lane0: usize,
    lanes: &'a mut [&'c mut SeqKvCache],
    out: &'a mut [f32],
    ws: &'a mut AttnScratch,
}

/// Append + attend for `lanes` (global lane ids `lane0..`) of `layer`.
/// Shared by the sequential and pooled paths so both execute identical
/// per-lane arithmetic.
fn attend_lanes(lanes: &mut [&mut SeqKvCache], layer: usize, lane0: usize,
                q: &[f32], k: &[f32], v: &[f32], qd: usize, kvd: usize,
                n_heads: usize, out: &mut [f32], ws: &mut AttnScratch) {
    for (i, cache) in lanes.iter_mut().enumerate() {
        let b = lane0 + i;
        let lc = &mut cache.layers[layer];
        lc.append(&k[b * kvd..(b + 1) * kvd], &v[b * kvd..(b + 1) * kvd], 1);
        lc.attend(&q[b * qd..(b + 1) * qd], n_heads,
                  &mut out[i * qd..(i + 1) * qd], ws);
    }
}

// The fan-out moves `&mut SeqKvCache` and the scratch buffers onto scoped
// worker threads; keep that requirement checked at compile time.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<SeqKvCache>();
    assert_send::<AttnScratch>();
};

/// Reusable buffers for decode steps.
#[derive(Default)]
pub struct DecodeScratch {
    /// `[bsz][q_dim]` attention output fed to the `post` executable
    pub attn: Vec<f32>,
    /// per-worker attention scratches (index = worker id; grown on demand,
    /// then reused every step)
    pub lanes: Vec<AttnScratch>,
    /// wall-clock nanoseconds the last `decode_step` spent in the
    /// append+attend fan-out, summed over layers (feeds
    /// `Metrics::attn_us` and the pool-utilization metric)
    pub attn_ns: u64,
    /// per-bit-width kernel nanoseconds of the last step, summed over
    /// layers and lanes ([`crate::kvcache::cache::attn_width_bucket`]
    /// order; feeds `Metrics::attn_ns_by_width`).  Unlike `attn_ns` this
    /// times only the inner score/value kernels, not append/softmax.
    pub kernel_ns: [u64; ATTN_WIDTH_BUCKETS],
}

impl DecodeScratch {
    /// Zero the per-width accrual in every lane scratch (step prologue).
    fn reset_kernel_ns(&mut self) {
        self.kernel_ns = [0; ATTN_WIDTH_BUCKETS];
        for ws in &mut self.lanes {
            ws.kernel_ns = [0; ATTN_WIDTH_BUCKETS];
        }
    }

    /// Sum the lanes' per-width accruals into `kernel_ns` (step epilogue).
    fn gather_kernel_ns(&mut self) {
        for ws in &self.lanes {
            for (acc, &ns) in self.kernel_ns.iter_mut().zip(&ws.kernel_ns) {
                *acc += ns;
            }
        }
    }
}
