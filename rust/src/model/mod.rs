//! Model orchestration: drives the per-layer XLA executables + the
//! quantized-cache attention to implement prefill and batched decode.

pub mod sampler;

use anyhow::Result;

use crate::attention::prefill_attention;
use crate::kvcache::{AttnScratch, SeqKvCache};
use crate::runtime::Runtime;

pub use sampler::Sampler;

/// Stateless forward driver over a [`Runtime`].
pub struct Forward<'a> {
    pub rt: &'a Runtime,
}

impl<'a> Forward<'a> {
    pub fn new(rt: &'a Runtime) -> Self {
        Forward { rt }
    }

    /// Prefill `tokens` into `cache` (which must be empty); returns the
    /// full `[t][vocab]` logits of the prompt.
    ///
    /// Prompt self-attention is full precision; the K/V written to the
    /// cache are quantized per the cache's policy as the windows overflow
    /// (paper Fig. 4 prefill phase).
    pub fn prefill(&self, tokens: &[i32], cache: &mut SeqKvCache) -> Result<Vec<f32>> {
        let m = &self.rt.model;
        let t = tokens.len();
        debug_assert!(cache.is_empty());
        let mut h = self.rt.embed(tokens)?;
        let pos: Vec<i32> = (0..t as i32).collect();
        for layer in 0..m.n_layers {
            let (q, k, v) = self.rt.pre(layer, &h, &pos, t)?;
            let attn = prefill_attention(&q, &k, &v, t, m.n_heads, m.n_kv_heads, m.head_dim);
            h = self.rt.post(layer, &attn, &h, t)?;
            cache.layers[layer].append(&k, &v, t);
        }
        self.rt.logits(&h, t)
    }

    /// One batched decode step: `tokens[b]` is the next input token of
    /// sequence `b`, `caches[b]` its cache.  Returns `[b][vocab]` logits.
    pub fn decode_step(&self, tokens: &[i32], caches: &mut [&mut SeqKvCache],
                       scratch: &mut DecodeScratch) -> Result<Vec<f32>> {
        let m = &self.rt.model;
        let bsz = tokens.len();
        debug_assert_eq!(caches.len(), bsz);
        let qd = m.q_dim();
        let kvd = m.kv_dim();
        let mut h = self.rt.embed(tokens)?;
        let pos: Vec<i32> = caches.iter().map(|c| c.len() as i32).collect();
        scratch.attn.resize(bsz * qd, 0.0);
        for layer in 0..m.n_layers {
            let (q, k, v) = self.rt.pre(layer, &h, &pos, bsz)?;
            for b in 0..bsz {
                let lc = &mut caches[b].layers[layer];
                lc.append(&k[b * kvd..(b + 1) * kvd], &v[b * kvd..(b + 1) * kvd], 1);
                lc.attend(&q[b * qd..(b + 1) * qd], m.n_heads,
                          &mut scratch.attn[b * qd..(b + 1) * qd], &mut scratch.attn_scratch);
            }
            h = self.rt.post(layer, &scratch.attn, &h, bsz)?;
        }
        self.rt.logits(&h, bsz)
    }
}

/// Reusable buffers for decode steps.
#[derive(Default)]
pub struct DecodeScratch {
    pub attn: Vec<f32>,
    pub attn_scratch: AttnScratch,
}
