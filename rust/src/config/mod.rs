//! Model / quantization / serving configuration.
//!
//! `ModelConfig` mirrors python/compile/model.py::ModelConfig and is read
//! from `artifacts/manifest.json`.  `QuantPlan` is the paper's per-layer
//! bit allocation + RPC ratios — produced by the profiler
//! ([`crate::profiler`]) or by the named preset constructors used in the
//! ablations (uniform 2-bit, random high-bit selection, w/oRPC, ...).

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::json::{parse_file, Json};
use crate::util::Rng;

/// Architecture of the reproduction model (must match the artifacts).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    /// KV quantization group size (paper: 32).
    pub group: usize,
}

impl ModelConfig {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelConfig {
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            group: j.get("group")?.as_usize()?,
        })
    }

    /// Tiny config for unit tests (no artifacts needed).
    pub fn test_small() -> Self {
        ModelConfig { vocab: 64, d_model: 32, n_layers: 2, n_heads: 2,
                      n_kv_heads: 1, head_dim: 16, d_ff: 64, group: 32 }
    }
}

/// Per-layer K/V bit widths + RPC (Recent Pivotal Context) ratios.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantPlan {
    pub name: String,
    pub k_bits: Vec<u8>,
    pub v_bits: Vec<u8>,
    /// Fraction of the current context kept full-precision, per layer.
    pub k_rpc: Vec<f64>,
    pub v_rpc: Vec<f64>,
}

impl QuantPlan {
    pub fn n_layers(&self) -> usize {
        self.k_bits.len()
    }

    pub fn avg_k_bits(&self) -> f64 {
        self.k_bits.iter().map(|&b| b as f64).sum::<f64>() / self.k_bits.len() as f64
    }

    pub fn avg_v_bits(&self) -> f64 {
        self.v_bits.iter().map(|&b| b as f64).sum::<f64>() / self.v_bits.len() as f64
    }

    pub fn validate(&self) -> Result<()> {
        let n = self.k_bits.len();
        if self.v_bits.len() != n || self.k_rpc.len() != n || self.v_rpc.len() != n {
            bail!("inconsistent plan lengths");
        }
        for &b in self.k_bits.iter().chain(self.v_bits.iter()) {
            if !matches!(b, 1 | 2 | 3 | 4 | 16) {
                bail!("unsupported bit width {b}");
            }
        }
        for &r in self.k_rpc.iter().chain(self.v_rpc.iter()) {
            if !(0.0..=1.0).contains(&r) {
                bail!("rpc ratio {r} out of range");
            }
        }
        Ok(())
    }

    /// Load the profiler's plan from artifacts/importance.json.
    pub fn from_importance_file(path: &Path) -> Result<Self> {
        let j = parse_file(path)?;
        Self::from_json(j.get("plan")?)
    }

    /// Parse a plan object (the `plan` node of importance.json, or one
    /// entry of a plan-search frontier file — see
    /// `rust/src/profiler/search.rs`).
    pub fn from_json(p: &Json) -> Result<Self> {
        Ok(QuantPlan {
            name: p.get("name")?.as_str()?.to_string(),
            k_bits: p.get("k_bits")?.usize_vec()?.iter().map(|&b| b as u8).collect(),
            v_bits: p.get("v_bits")?.usize_vec()?.iter().map(|&b| b as u8).collect(),
            k_rpc: p.get("k_rpc")?.f64_vec()?,
            v_rpc: p.get("v_rpc")?.f64_vec()?,
        })
    }

    /// Serialize in the importance.json `plan` schema (minus the
    /// profiler-only score fields) — `from_json` round-trips it.
    pub fn to_json(&self) -> Json {
        let bits = |b: &[u8]| Json::from_usizes(&b.iter().map(|&x| x as usize)
            .collect::<Vec<_>>());
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("k_bits", bits(&self.k_bits)),
            ("v_bits", bits(&self.v_bits)),
            ("k_rpc", Json::from_f64s(&self.k_rpc)),
            ("v_rpc", Json::from_f64s(&self.v_rpc)),
        ])
    }

    // ------------- presets used by the paper's ablations -------------

    /// The raw per-layer gradient scores the profiler folded into the
    /// plan (importance.json `plan.k_scores` / `plan.v_scores`).  The
    /// engine feeds them to the pressure controller's loss-per-byte
    /// downshift order (DESIGN.md §Pressure-Ladder); older artifacts
    /// without the fields return `None`.
    pub fn scores_from_importance_file(path: &Path)
                                       -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let j = parse_file(path)?;
        let p = j.get("plan")?;
        match (p.opt("k_scores"), p.opt("v_scores")) {
            (Some(k), Some(v)) => Ok(Some((k.f64_vec()?, v.f64_vec()?))),
            _ => Ok(None),
        }
    }

    /// FP16 baseline: 16 "bits", no quantization at all.
    pub fn fp16(n_layers: usize) -> Self {
        QuantPlan { name: "fp16".into(),
                    k_bits: vec![16; n_layers], v_bits: vec![16; n_layers],
                    k_rpc: vec![1.0; n_layers], v_rpc: vec![1.0; n_layers] }
    }

    /// Uniform asymmetric quantization at `bits` with the paper's default
    /// RPC ratio for that bit width (10% for 2-bit, 20% for >=3).
    pub fn uniform(n_layers: usize, bits: u8) -> Self {
        let rpc = if bits >= 3 { 0.2 } else { 0.1 };
        QuantPlan { name: format!("kvmix-{bits}bit"),
                    k_bits: vec![bits; n_layers], v_bits: vec![bits; n_layers],
                    k_rpc: vec![rpc; n_layers], v_rpc: vec![rpc; n_layers] }
    }

    /// Table 1's `random-k…v…`: same bit budget as the profiled plan but
    /// the high-bit layers are chosen uniformly at random.
    pub fn random_highbit(n_layers: usize, n_high: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let kh = rng.sample_distinct(n_layers, n_high);
        let vh = rng.sample_distinct(n_layers, n_high);
        let mut plan = QuantPlan {
            name: "random-mixed".into(),
            k_bits: vec![2; n_layers], v_bits: vec![2; n_layers],
            k_rpc: vec![0.1; n_layers], v_rpc: vec![0.1; n_layers],
        };
        for &i in &kh {
            plan.k_bits[i] = 3;
            plan.k_rpc[i] = 0.2;
        }
        for &i in &vh {
            plan.v_bits[i] = 4;
            plan.v_rpc[i] = 0.2;
        }
        plan.name = format!("random-k{:.2}v{:.2}", plan.avg_k_bits(), plan.avg_v_bits());
        plan
    }

    /// The same plan with RPC disabled (Table 1's `…w/oRPC`).
    pub fn without_rpc(&self) -> Self {
        QuantPlan {
            name: format!("{}w/oRPC", self.name),
            k_bits: self.k_bits.clone(),
            v_bits: self.v_bits.clone(),
            k_rpc: vec![0.0; self.k_bits.len()],
            v_rpc: vec![0.0; self.v_bits.len()],
        }
    }

    /// The same plan with every RPC ratio overridden (Table 4 / Fig 11).
    pub fn with_rpc(&self, rpc_high: f64, rpc_low: f64) -> Self {
        let mut p = self.clone();
        for i in 0..p.k_bits.len() {
            p.k_rpc[i] = if p.k_bits[i] > 2 { rpc_high } else { rpc_low };
            p.v_rpc[i] = if p.v_bits[i] > 2 { rpc_high } else { rpc_low };
        }
        p.name = format!("{}-rpc{:.0}%/{:.0}%", self.name, rpc_high * 100.0, rpc_low * 100.0);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_valid() {
        for plan in [QuantPlan::fp16(8), QuantPlan::uniform(8, 2),
                     QuantPlan::uniform(8, 4), QuantPlan::random_highbit(8, 2, 1)] {
            plan.validate().unwrap();
        }
    }

    #[test]
    fn random_highbit_budget() {
        let p = QuantPlan::random_highbit(8, 2, 42);
        assert_eq!(p.k_bits.iter().filter(|&&b| b == 3).count(), 2);
        assert_eq!(p.v_bits.iter().filter(|&&b| b == 4).count(), 2);
        assert!((p.avg_k_bits() - 2.25).abs() < 1e-9);
        assert!((p.avg_v_bits() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn without_rpc_zeroes() {
        let p = QuantPlan::uniform(4, 2).without_rpc();
        assert!(p.k_rpc.iter().all(|&r| r == 0.0));
        assert!(p.name.ends_with("w/oRPC"));
    }

    #[test]
    fn plan_json_round_trip() {
        let p = QuantPlan::random_highbit(6, 2, 9);
        let q = QuantPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(p, q);
        assert_eq!(p.to_json().to_string(), q.to_json().to_string());
    }

    #[test]
    fn rejects_bad_bits() {
        let mut p = QuantPlan::uniform(2, 2);
        p.k_bits[0] = 5;
        assert!(p.validate().is_err());
    }
}
