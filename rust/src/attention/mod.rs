//! Attention kernels outside the quantized-cache path.
//!
//! Decode attention over the mixed cache lives in
//! [`crate::kvcache::LayerKvCache::attend`]; this module provides the
//! full-precision causal attention used for prefill (the prompt's
//! self-attention is computed at full precision; the *cache* written from
//! it is then quantized per policy, matching KIVI/KVQuant practice).
//!
//! Prefill attention is head-parallel: heads are independent, so
//! [`prefill_attention_with`] fans them out across a
//! [`WorkerPool`] (DESIGN.md §Threading-Model).  Both
//! the sequential and pooled paths run the same per-head kernel in the
//! same order, so results are bit-identical for any thread count.

use crate::util::WorkerPool;

/// Causal GQA attention over `t` tokens (sequential; equivalent to
/// [`prefill_attention_with`] with no pool).
///
/// * `q` — `[t][n_heads*head_dim]` (RoPE'd)
/// * `k`, `v` — `[t][n_kv*head_dim]` (RoPE'd keys)
/// * returns `[t][n_heads*head_dim]`
pub fn prefill_attention(q: &[f32], k: &[f32], v: &[f32], t: usize,
                         n_heads: usize, n_kv: usize, head_dim: usize) -> Vec<f32> {
    prefill_attention_with(q, k, v, t, n_heads, n_kv, head_dim, None)
}

/// [`prefill_attention`] with the per-head loop fanned out across `pool`.
pub fn prefill_attention_with(q: &[f32], k: &[f32], v: &[f32], t: usize,
                              n_heads: usize, n_kv: usize, head_dim: usize,
                              pool: Option<&WorkerPool>) -> Vec<f32> {
    let qd = n_heads * head_dim;
    let kd = n_kv * head_dim;
    let rep = n_heads / n_kv;
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut out = vec![0f32; t * qd];

    match pool {
        Some(pool) if pool.threads() > 1 && n_heads > 1 => {
            // a head's output rows are strided in the `[t][qd]` layout, so
            // workers write into contiguous `[h][t][head_dim]` staging
            // chunks and the caller interleaves afterwards
            let head_span = t * head_dim;
            let mut heads = vec![0f32; n_heads * head_span];
            let nw = pool.threads().min(n_heads);
            let per = n_heads.div_ceil(nw);
            let chunks = heads
                .chunks_mut(per * head_span)
                .enumerate()
                .map(|(ci, c)| (ci * per, c));
            pool.run_tasks(chunks, |_w, (h0, chunk)| {
                let mut scores = vec![0f32; t];
                for (i, dst) in chunk.chunks_mut(head_span).enumerate() {
                    head_attention(h0 + i, q, k, v, t, qd, kd, head_dim, rep,
                                   scale, dst, head_dim, &mut scores);
                }
            });
            // interleave `[h][t][head_dim]` -> `[t][n_heads*head_dim]`
            for h in 0..n_heads {
                for qi in 0..t {
                    let src = &heads[(h * t + qi) * head_dim..(h * t + qi + 1) * head_dim];
                    out[qi * qd + h * head_dim..qi * qd + (h + 1) * head_dim]
                        .copy_from_slice(src);
                }
            }
        }
        _ => {
            // sequential: write each head's rows directly into `out` at
            // stride `qd` — no staging buffer, no interleave copy
            let mut scores = vec![0f32; t];
            for h in 0..n_heads {
                head_attention(h, q, k, v, t, qd, kd, head_dim, rep, scale,
                               &mut out[h * head_dim..], qd, &mut scores);
            }
        }
    }
    out
}

/// Causal attention of one query head over all `t` positions.
///
/// `dst` holds the head's output rows at pitch `stride`: row `qi` is
/// `dst[qi*stride .. qi*stride+head_dim]` (stride `qd` writes straight
/// into the interleaved output; stride `head_dim` fills a contiguous
/// staging chunk).  Arithmetic is identical either way, which is what
/// keeps pooled prefill bit-identical to sequential.
fn head_attention(h: usize, q: &[f32], k: &[f32], v: &[f32], t: usize,
                  qd: usize, kd: usize, head_dim: usize, rep: usize,
                  scale: f32, dst: &mut [f32], stride: usize,
                  scores: &mut [f32]) {
    let kvh = h / rep;
    for qi in 0..t {
        let qv = &q[qi * qd + h * head_dim..qi * qd + (h + 1) * head_dim];
        let n_ctx = qi + 1;
        let row = &mut scores[..n_ctx];
        let mut mx = f32::NEG_INFINITY;
        for (ki, s) in row.iter_mut().enumerate() {
            let kv = &k[ki * kd + kvh * head_dim..ki * kd + (kvh + 1) * head_dim];
            let mut acc = 0f32;
            for d in 0..head_dim {
                acc += qv[d] * kv[d];
            }
            *s = acc * scale;
            mx = mx.max(*s);
        }
        let mut sum = 0f32;
        for s in row.iter_mut() {
            *s = (*s - mx).exp();
            sum += *s;
        }
        let inv = 1.0 / sum;
        let o = &mut dst[qi * stride..qi * stride + head_dim];
        for (ki, s) in row.iter().enumerate() {
            let p = s * inv;
            let vv = &v[ki * kd + kvh * head_dim..ki * kd + (kvh + 1) * head_dim];
            for d in 0..head_dim {
                o[d] += p * vv[d];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn softmax_rows_sum_to_one_property() {
        // with v = all-ones, output must be exactly ones (convex combination)
        let t = 7;
        let (h, kv, hd) = (2, 1, 8);
        let mut rng = Rng::new(1);
        let q = rng.normal_vec(t * h * hd);
        let k = rng.normal_vec(t * kv * hd);
        let v = vec![1f32; t * kv * hd];
        let out = prefill_attention(&q, &k, &v, t, h, kv, hd);
        for x in out {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn causality() {
        // output at position i must not depend on k/v after i
        let t = 6;
        let (h, kv, hd) = (2, 2, 8);
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(t * h * hd);
        let mut k = rng.normal_vec(t * kv * hd);
        let mut v = rng.normal_vec(t * kv * hd);
        let out1 = prefill_attention(&q, &k, &v, t, h, kv, hd);
        // perturb the last token's k/v
        for x in &mut k[(t - 1) * kv * hd..] {
            *x += 5.0;
        }
        for x in &mut v[(t - 1) * kv * hd..] {
            *x -= 3.0;
        }
        let out2 = prefill_attention(&q, &k, &v, t, h, kv, hd);
        for i in 0..(t - 1) * h * hd {
            assert!((out1[i] - out2[i]).abs() < 1e-6);
        }
        let last_diff: f32 = out1[(t - 1) * h * hd..].iter()
            .zip(&out2[(t - 1) * h * hd..]).map(|(a, b)| (a - b).abs()).sum();
        assert!(last_diff > 1e-3);
    }

    #[test]
    fn matches_cache_attend_for_single_query() {
        // last-position prefill attention == decode attend on an fp cache
        use crate::kvcache::{AttnScratch, KeyRepr, LayerCacheCfg, LayerKvCache, ValueRepr, WindowPolicy};
        let t = 12;
        let (h, n_kv, hd) = (4, 2, 16);
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(t * h * hd);
        let k = rng.normal_vec(t * n_kv * hd);
        let v = rng.normal_vec(t * n_kv * hd);
        let full = prefill_attention(&q, &k, &v, t, h, n_kv, hd);

        let mut cache = LayerKvCache::new(LayerCacheCfg {
            kv_dim: n_kv * hd, head_dim: hd, group: 32,
            key: KeyRepr::Fp, value: ValueRepr::Fp,
            k_window: WindowPolicy::All, v_window: WindowPolicy::All,
            outlier_frac: 0.0, k_interleave: false,
        });
        cache.append(&k, &v, t);
        let mut out = vec![0f32; h * hd];
        let mut s = AttnScratch::default();
        cache.attend(&q[(t - 1) * h * hd..], h, &mut out, &mut s);
        for (a, b) in out.iter().zip(&full[(t - 1) * h * hd..]) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn pooled_prefill_bit_identical_to_sequential() {
        let t = 19;
        let (h, n_kv, hd) = (6, 3, 16);
        let mut rng = Rng::new(4);
        let q = rng.normal_vec(t * h * hd);
        let k = rng.normal_vec(t * n_kv * hd);
        let v = rng.normal_vec(t * n_kv * hd);
        let seq = prefill_attention(&q, &k, &v, t, h, n_kv, hd);
        for threads in [2usize, 3, 4, 8] {
            let par = WorkerPool::scoped(threads, |pool| {
                prefill_attention_with(&q, &k, &v, t, h, n_kv, hd, Some(pool))
            });
            assert!(seq == par, "threads={threads}: prefill attention diverged");
        }
    }
}
