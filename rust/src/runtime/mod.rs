//! PJRT runtime: loads `artifacts/*.hlo.txt` (HLO **text**, the 0.5.1-safe
//! interchange — see /opt/xla-example/README.md), compiles them on the CPU
//! PJRT client, keeps all model weights device-resident, and exposes the
//! typed `pre` / `post` / `logits` / `profiler_grads` entry points the
//! engine drives.  Shapes are bucketized (manifest `buckets`); inputs are
//! zero-padded up to the bucket and outputs truncated back.

pub mod weights;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::ModelConfig;
use crate::util::json::parse_file;
pub use weights::{Tensor, Weights};

pub struct Runtime {
    pub client: PjRtClient,
    pub model: ModelConfig,
    pub buckets: Vec<usize>,
    dir: PathBuf,
    /// compiled executables: ("pre"|"post"|"logits", bucket) -> exe
    exes: HashMap<(&'static str, usize), PjRtLoadedExecutable>,
    profiler_exe: Option<PjRtLoadedExecutable>,
    pub profile_seq_len: usize,
    /// device-resident weight buffers by canonical name
    wbuf: HashMap<String, PjRtBuffer>,
    pub weights: Weights,
}

impl Runtime {
    /// Load the manifest, weights and all bucketed executables (with the
    /// profiler graph).
    pub fn load(dir: &Path) -> Result<Self> {
        Self::load_with(dir, true)
    }

    /// `with_profiler=false` skips compiling the (large) gradient graph.
    pub fn load_with(dir: &Path, with_profiler: bool) -> Result<Self> {
        let manifest = parse_file(&dir.join("manifest.json"))
            .context("artifacts missing — run `make artifacts` first")?;
        let model = ModelConfig::from_json(manifest.get("model")?)?;
        let buckets = manifest.get("buckets")?.usize_vec()?;
        let weights = Weights::load(dir, &manifest)?;
        let client = PjRtClient::cpu()?;

        let mut exes = HashMap::new();
        let index = manifest.get("executables")?;
        for kind in ["pre", "post", "logits"] {
            let table = index.get(kind)?.as_obj()?;
            for (bucket, file) in table {
                let b: usize = bucket.parse()?;
                let exe = compile_hlo(&client, &dir.join(file.as_str()?))?;
                exes.insert((kind, b), exe);
            }
        }
        let profile_seq_len = index.get("profiler")?.get("seq_len")?.as_usize()?;
        let profiler_exe = if with_profiler {
            let f = index.get("profiler")?.get("file")?.as_str()?.to_string();
            Some(compile_hlo(&client, &dir.join(&f))?)
        } else {
            None
        };

        // device-resident weights
        let mut wbuf = HashMap::new();
        for t in &weights.tensors {
            let buf = client.buffer_from_host_buffer(&t.data, &t.shape, None)?;
            wbuf.insert(t.name.clone(), buf);
        }

        Ok(Runtime { client, model, buckets, dir: dir.to_path_buf(), exes,
                     profiler_exe, profile_seq_len, wbuf, weights })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Smallest bucket >= rows.
    pub fn bucket_for(&self, rows: usize) -> Result<usize> {
        self.buckets.iter().copied().filter(|&b| b >= rows).min()
            .ok_or_else(|| anyhow!("no bucket fits {rows} rows (buckets {:?})", self.buckets))
    }

    fn exe(&self, kind: &'static str, bucket: usize) -> Result<&PjRtLoadedExecutable> {
        self.exes.get(&(kind, bucket))
            .ok_or_else(|| anyhow!("no {kind} executable for bucket {bucket}"))
    }

    fn wb(&self, name: &str) -> Result<&PjRtBuffer> {
        self.wbuf.get(name).ok_or_else(|| anyhow!("no weight buffer {name}"))
    }

    fn layer_wb(&self, layer: usize, field: &str) -> Result<&PjRtBuffer> {
        self.wb(&format!("layers.{layer}.{field}"))
    }

    /// RMSNorm + QKV projection + RoPE for `rows` tokens of `layer`
    /// (Pallas kernel inside the lowered graph).
    ///
    /// `hidden`: `[rows][d_model]`, `pos`: `[rows]` absolute positions.
    /// Returns (q `[rows][q_dim]`, k `[rows][kv_dim]`, v `[rows][kv_dim]`).
    pub fn pre(&self, layer: usize, hidden: &[f32], pos: &[i32], rows: usize)
               -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.model.d_model;
        debug_assert_eq!(hidden.len(), rows * d);
        let b = self.bucket_for(rows)?;
        let hbuf = self.padded_f32(hidden, rows * d, b * d, &[b, d])?;
        let pbuf = self.padded_i32(pos, rows, b, &[b])?;
        let exe = self.exe("pre", b)?;
        let out = exe.execute_b::<&PjRtBuffer>(&[
            &hbuf, &pbuf,
            self.layer_wb(layer, "ln1")?, self.layer_wb(layer, "wq")?,
            self.layer_wb(layer, "wk")?, self.layer_wb(layer, "wv")?,
        ])?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != 3 {
            bail!("pre returned {} outputs", parts.len());
        }
        let mut q = parts[0].to_vec::<f32>()?;
        let mut k = parts[1].to_vec::<f32>()?;
        let mut v = parts[2].to_vec::<f32>()?;
        q.truncate(rows * self.model.q_dim());
        k.truncate(rows * self.model.kv_dim());
        v.truncate(rows * self.model.kv_dim());
        Ok((q, k, v))
    }

    /// Attention out-projection + residual + MLP for `rows` tokens.
    pub fn post(&self, layer: usize, attn: &[f32], resid: &[f32], rows: usize)
                -> Result<Vec<f32>> {
        let d = self.model.d_model;
        let qd = self.model.q_dim();
        let b = self.bucket_for(rows)?;
        let abuf = self.padded_f32(attn, rows * qd, b * qd, &[b, qd])?;
        let rbuf = self.padded_f32(resid, rows * d, b * d, &[b, d])?;
        let exe = self.exe("post", b)?;
        let out = exe.execute_b::<&PjRtBuffer>(&[
            &abuf, &rbuf,
            self.layer_wb(layer, "wo")?, self.layer_wb(layer, "ln2")?,
            self.layer_wb(layer, "wg")?, self.layer_wb(layer, "wu")?,
            self.layer_wb(layer, "wd")?,
        ])?;
        let lit = out[0][0].to_literal_sync()?.to_tuple1()?;
        let mut h = lit.to_vec::<f32>()?;
        h.truncate(rows * d);
        Ok(h)
    }

    /// Final RMSNorm + LM head.
    pub fn logits(&self, hidden: &[f32], rows: usize) -> Result<Vec<f32>> {
        let d = self.model.d_model;
        let b = self.bucket_for(rows)?;
        let hbuf = self.padded_f32(hidden, rows * d, b * d, &[b, d])?;
        let exe = self.exe("logits", b)?;
        let out = exe.execute_b::<&PjRtBuffer>(&[&hbuf, self.wb("lnf")?, self.wb("lm_head")?])?;
        let lit = out[0][0].to_literal_sync()?.to_tuple1()?;
        let mut l = lit.to_vec::<f32>()?;
        l.truncate(rows * self.model.vocab);
        Ok(l)
    }

    /// KVmix profiler graph: loss + per-layer L2 grad norms of W_k / W_v
    /// for one prompt (padded/truncated to `profile_seq_len`).
    pub fn profiler_grads(&self, tokens: &[i32], mask: &[f32])
                          -> Result<(f32, Vec<f32>, Vec<f32>)> {
        let t = self.profile_seq_len;
        let exe = self.profiler_exe.as_ref()
            .ok_or_else(|| anyhow!("runtime loaded without profiler"))?;
        let used = tokens.len().min(t);
        let tb = self.padded_i32(&tokens[..used], used, t, &[1, t])?;
        let mut m = mask[..mask.len().min(t)].to_vec();
        m.resize(t, 0.0);
        let mb = self.client.buffer_from_host_buffer(&m, &[1, t], None)?;
        let mut args: Vec<&PjRtBuffer> = vec![&tb, &mb];
        for tensor in &self.weights.tensors {
            args.push(self.wb(&tensor.name)?);
        }
        let out = exe.execute_b(&args)?;
        let parts = out[0][0].to_literal_sync()?.to_tuple()?;
        if parts.len() != 3 {
            bail!("profiler returned {} outputs", parts.len());
        }
        let loss = parts[0].to_vec::<f32>()?[0];
        Ok((loss, parts[1].to_vec::<f32>()?, parts[2].to_vec::<f32>()?))
    }

    /// Embedding lookup stays host-side (a row gather over the table).
    pub fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let e = self.weights.get("embed")?;
        let d = self.model.d_model;
        let mut out = vec![0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.model.vocab {
                bail!("token {t} out of vocab");
            }
            out[i * d..(i + 1) * d].copy_from_slice(&e.data[t * d..(t + 1) * d]);
        }
        Ok(out)
    }

    fn padded_f32(&self, data: &[f32], used: usize, padded: usize, dims: &[usize])
                  -> Result<PjRtBuffer> {
        debug_assert!(data.len() >= used);
        if used == padded {
            return Ok(self.client.buffer_from_host_buffer(&data[..used], dims, None)?);
        }
        let mut tmp = Vec::with_capacity(padded);
        tmp.extend_from_slice(&data[..used]);
        tmp.resize(padded, 0.0);
        Ok(self.client.buffer_from_host_buffer(&tmp, dims, None)?)
    }

    fn padded_i32(&self, data: &[i32], used: usize, padded: usize, dims: &[usize])
                  -> Result<PjRtBuffer> {
        if used == padded {
            return Ok(self.client.buffer_from_host_buffer(&data[..used], dims, None)?);
        }
        let mut tmp = Vec::with_capacity(padded);
        tmp.extend_from_slice(&data[..used]);
        tmp.resize(padded, 0);
        Ok(self.client.buffer_from_host_buffer(&tmp, dims, None)?)
    }
}

fn compile_hlo(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?)
        .with_context(|| format!("loading {}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Default artifacts directory: $KVMIX_ARTIFACTS, else walk up from cwd.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("KVMIX_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut d = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = d.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !d.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
