//! Checkpoint loader: `artifacts/weights.bin` (raw f32 LE, canonical
//! order) + the manifest's weight table.

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use byteorder::{ByteOrder, LittleEndian};

use crate::util::json::Json;

/// One named tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// All model weights, canonical order preserved.
pub struct Weights {
    pub tensors: Vec<Tensor>,
    by_name: HashMap<String, usize>,
}

impl Weights {
    pub fn load(dir: &Path, manifest: &Json) -> Result<Self> {
        let entries = manifest.get("weights")?.as_arr()?;
        let bin_path = dir.join("weights.bin");
        let mut raw = Vec::new();
        std::fs::File::open(&bin_path)
            .with_context(|| format!("opening {}", bin_path.display()))?
            .read_to_end(&mut raw)?;

        let mut tensors = Vec::with_capacity(entries.len());
        let mut by_name = HashMap::new();
        for e in entries {
            let name = e.get("name")?.as_str()?.to_string();
            let shape = e.get("shape")?.usize_vec()?;
            let offset = e.get("offset")?.as_usize()?;
            let numel = e.get("numel")?.as_usize()?;
            if shape.iter().product::<usize>() != numel {
                bail!("{name}: shape {shape:?} != numel {numel}");
            }
            let end = offset + numel * 4;
            if end > raw.len() {
                bail!("{name}: extends past weights.bin ({end} > {})", raw.len());
            }
            let mut data = vec![0f32; numel];
            LittleEndian::read_f32_into(&raw[offset..end], &mut data);
            by_name.insert(name.clone(), tensors.len());
            tensors.push(Tensor { name, shape, data });
        }
        Ok(Weights { tensors, by_name })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.by_name.get(name).map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow!("missing weight {name:?}"))
    }

    pub fn layer(&self, i: usize, field: &str) -> Result<&Tensor> {
        self.get(&format!("layers.{i}.{field}"))
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Modeled resident bytes of the weights at fp16 (Fig. 7's "model
    /// memory before inference" term).
    pub fn modeled_bytes_fp16(&self) -> usize {
        self.param_count() * 2
    }
}
