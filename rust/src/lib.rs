// `--features simd` vectorizes the packed decode kernels via
// `std::simd` (portable-simd, nightly only — DESIGN.md
// §Quantized-Kernels); the default build is stable scalar.
#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # KVmix — layer importance-aware mixed-precision KV-cache quantization
//!
//! Rust L3 coordinator of the three-layer reproduction of *KVmix:
//! Gradient-Based Layer Importance-Aware Mixed-Precision Quantization for
//! KV Cache* (AAAI 2026).  The request path is pure Rust: the transformer's
//! dense compute runs as AOT-compiled XLA executables (lowered once from
//! JAX/Pallas by `make artifacts`), while the paper's contribution — the
//! quantized KV cache, the dynamic Recent-Pivotal-Context windows, the
//! fused dequantize·matvec attention kernels and the gradient-based layer
//! profiler — lives in the modules below.
//!
//! Architecture map (see DESIGN.md):
//!
//! * [`config`]    — model / quantization / serving configuration
//! * [`runtime`]   — PJRT client, executable registry, weights loader
//! * [`quant`]     — bit packing (incl. the paper's 3-bit 11-per-u32
//!   scheme) + group-wise asymmetric quantization + fused kernels
//! * [`kvcache`]   — packed per-layer caches, RPC windows, memory
//!   accounting, and the paged KV pool + pressure controller +
//!   shared-prefix index (DESIGN.md §Memory-Manager, §Prefix-Sharing)
//! * [`attention`] — decode/prefill attention over the mixed cache
//! * [`model`]     — per-layer orchestration through the XLA executables
//! * [`profiler`]  — gradient-norm importance analysis + bit allocation
//! * [`baselines`] — KIVI / KVQuant / QJL / Atom / uniform cache policies
//! * [`coordinator`] — request router, continuous batcher, scheduler, engine
//! * [`harness`]   — synthetic workloads, evaluation metrics, paper tables
//! * [`util`]      — in-repo substrates (JSON, CLI, RNG, bench timing, and
//!   the scoped worker pool behind the decode fan-out — DESIGN.md
//!   §Threading-Model)

pub mod attention;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod kvcache;
pub mod model;
pub mod profiler;
pub mod quant;
pub mod runtime;
pub mod util;

pub use config::{ModelConfig, QuantPlan};
