//! Comparator cache policies (paper Tables 2–3, Figs. 7–8), each a
//! faithful reimplementation of the method's *cache policy* behind one
//! constructor (their CUDA kernels are out of scope — DESIGN.md §3/§5):
//!
//! * **KIVI-2bit-r64** — K per-channel / V per-token 2-bit, fixed
//!   full-precision residual of 64 tokens that never shrinks.
//! * **KVQuant-3bit-1%** — K per-channel / V per-token 3-bit with 1% of
//!   elements kept full precision as outliers (our K is post-RoPE).
//! * **QJL-3bit** — K as 1-bit sign-JL sketch (zero scale/zero-point
//!   constants) + per-token 3-bit V.
//! * **Atom-4bit** — K and V per-token 4-bit, no residual (Atom also
//!   quantizes weights/activations; only its KV policy is modeled here).
//! * **uniform k-T,v-T** — Table 3's symmetric per-token rows.
//! * **fp16** — no quantization (memory modeled at 2 B/element).

use crate::config::{ModelConfig, QuantPlan};
use crate::kvcache::{KeyRepr, LayerCacheCfg, PressureCfg, SeqKvCache, ValueRepr,
                     WindowPolicy};

/// A named KV-cache policy.
#[derive(Debug, Clone)]
pub enum Method {
    Fp16,
    Kivi { bits: u8, residual: usize },
    KvQuant { bits: u8, outlier_frac: f64 },
    Qjl { jl_dim_mult: usize, v_bits: u8 },
    Atom { bits: u8 },
    /// Table 3's symmetric per-token quantization for both K and V.
    UniformPerToken { bits: u8 },
    /// KVmix with an explicit plan (profiled, random, uniform, w/oRPC...).
    Kvmix(QuantPlan),
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::Kivi { bits, residual } => format!("KIVI-{bits}bit-r{residual}"),
            Method::KvQuant { bits, outlier_frac } =>
                format!("KVQuant-{bits}bit-{:.0}%", outlier_frac * 100.0),
            Method::Qjl { v_bits, .. } => format!("QJL-{v_bits}bit"),
            Method::Atom { bits } => format!("Atom-{bits}bit"),
            Method::UniformPerToken { bits } => format!("{bits}bit (k-T, v-T)"),
            Method::Kvmix(p) => p.name.clone(),
        }
    }

    /// Build a fresh per-sequence cache implementing this policy.
    pub fn make_cache(&self, m: &ModelConfig) -> SeqKvCache {
        match self {
            Method::Fp16 => SeqKvCache::new(m, &QuantPlan::fp16(m.n_layers)),
            Method::Kvmix(plan) => SeqKvCache::new(m, plan),
            Method::Kivi { bits, residual } => {
                let plan = QuantPlan::uniform(m.n_layers, *bits);
                SeqKvCache::with_policy(m, &plan, 0.0, Some(*residual))
            }
            Method::KvQuant { bits, outlier_frac } => {
                let plan = QuantPlan::uniform(m.n_layers, *bits).without_rpc();
                SeqKvCache::with_policy(m, &plan, *outlier_frac, None)
            }
            Method::Qjl { jl_dim_mult, v_bits } => {
                let cfgs = (0..m.n_layers).map(|_| LayerCacheCfg {
                    kv_dim: m.kv_dim(),
                    head_dim: m.head_dim,
                    group: m.group,
                    key: KeyRepr::SignJl { jl_dim: jl_dim_mult * m.head_dim },
                    value: ValueRepr::PerToken { bits: *v_bits },
                    k_window: WindowPolicy::None,
                    v_window: WindowPolicy::None,
                    outlier_frac: 0.0,
                    k_interleave: false,
                }).collect();
                SeqKvCache::from_cfgs(cfgs)
            }
            Method::Atom { bits } | Method::UniformPerToken { bits } => {
                let cfgs = (0..m.n_layers).map(|_| LayerCacheCfg {
                    kv_dim: m.kv_dim(),
                    head_dim: m.head_dim,
                    group: m.group,
                    key: KeyRepr::PerToken { bits: *bits },
                    value: ValueRepr::PerToken { bits: *bits },
                    k_window: WindowPolicy::None,
                    v_window: WindowPolicy::None,
                    outlier_frac: 0.0,
                    k_interleave: false,
                }).collect();
                SeqKvCache::from_cfgs(cfgs)
            }
        }
    }

    /// Requantization floors for the paged pool's pressure controller
    /// (DESIGN.md §Memory-Manager).  KVmix floors derive from the
    /// gradient-importance plan; uniform baselines floor at 2 bits when
    /// their plan sits above 2 bits, else 1; fp16 has nothing to
    /// downshift, and QJL's sign-JL keys are not requantizable (only its
    /// value pages move down the ladder).
    pub fn pressure_floors(&self, n_layers: usize) -> PressureCfg {
        let unif = |b: u8| if b > 2 { 2 } else { 1 };
        match self {
            Method::Fp16 => PressureCfg::uniform(n_layers, 16),
            Method::Kvmix(plan) => PressureCfg::from_plan(plan),
            Method::Kivi { bits, .. }
            | Method::KvQuant { bits, .. }
            | Method::Atom { bits }
            | Method::UniformPerToken { bits } => PressureCfg::uniform(n_layers, unif(*bits)),
            Method::Qjl { v_bits, .. } => {
                let mut p = PressureCfg::uniform(n_layers, unif(*v_bits));
                p.k_floor = vec![16; n_layers];
                p
            }
        }
    }

    /// The paper's standard comparison set (Tables 2–3, Figs. 7–8).
    pub fn comparison_set(kvmix_plan: &QuantPlan) -> Vec<Method> {
        vec![
            Method::Fp16,
            Method::Kivi { bits: 2, residual: 64 },
            Method::Qjl { jl_dim_mult: 4, v_bits: 3 },
            Method::KvQuant { bits: 3, outlier_frac: 0.01 },
            Method::Atom { bits: 4 },
            Method::Kvmix(kvmix_plan.clone()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn all_methods_build_and_append() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2);
        let mut rng = Rng::new(1);
        for method in Method::comparison_set(&plan) {
            let mut cache = method.make_cache(&m);
            assert_eq!(cache.layers.len(), m.n_layers);
            let kv = m.kv_dim();
            for l in &mut cache.layers {
                l.append(&rng.normal_vec(kv * 64), &rng.normal_vec(kv * 64), 64);
            }
            assert_eq!(cache.len(), 64, "{}", method.name());
            assert!(cache.modeled_bytes() > 0);
        }
    }

    #[test]
    fn memory_ordering_fp16_worst() {
        let m = ModelConfig::test_small();
        let plan = QuantPlan::uniform(m.n_layers, 2);
        let mut sizes = Vec::new();
        for method in [Method::Fp16, Method::Kivi { bits: 2, residual: 64 },
                       Method::Kvmix(plan)] {
            let mut cache = method.make_cache(&m);
            let kv = m.kv_dim();
            let mut rng = Rng::new(2);
            for l in &mut cache.layers {
                l.append(&rng.normal_vec(kv * 256), &rng.normal_vec(kv * 256), 256);
            }
            sizes.push((method.name(), cache.modeled_bytes()));
        }
        assert!(sizes[0].1 > sizes[1].1, "{sizes:?}"); // fp16 > kivi
        assert!(sizes[1].1 > sizes[2].1, "{sizes:?}"); // kivi residual > kvmix rpc
    }

    #[test]
    fn pressure_floor_presets() {
        assert_eq!(Method::Fp16.pressure_floors(3).k_floor, vec![16, 16, 16]);
        let kivi = Method::Kivi { bits: 2, residual: 64 }.pressure_floors(2);
        assert_eq!(kivi.k_floor, vec![1, 1]);
        let atom = Method::Atom { bits: 4 }.pressure_floors(2);
        assert_eq!(atom.v_floor, vec![2, 2]);
        let qjl = Method::Qjl { jl_dim_mult: 4, v_bits: 3 }.pressure_floors(2);
        assert_eq!(qjl.k_floor, vec![16, 16], "sign-JL keys are not requantizable");
        assert_eq!(qjl.v_floor, vec![2, 2]);
    }

    #[test]
    fn names() {
        assert_eq!(Method::Kivi { bits: 2, residual: 64 }.name(), "KIVI-2bit-r64");
        assert_eq!(Method::KvQuant { bits: 3, outlier_frac: 0.01 }.name(), "KVQuant-3bit-1%");
        assert_eq!(Method::UniformPerToken { bits: 2 }.name(), "2bit (k-T, v-T)");
    }
}
