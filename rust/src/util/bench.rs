//! Micro-benchmark timing substrate (criterion is unavailable offline).
//!
//! `cargo bench` runs the harness=false binaries under rust/benches/, each
//! of which uses [`bench`] / [`Stats`] for warmup + repeated timing and
//! prints criterion-style lines.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    /// nanoseconds per iteration
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub iters: usize,
}

impl Stats {
    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>12}  p50 {:>12}  p95 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean),
            fmt_ns(self.p50),
            fmt_ns(self.p95),
            self.iters
        )
    }

    /// items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, auto-scaling iteration count to fill ~`budget_ms` per sample.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> Stats {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let per_sample = (budget_ms as f64 * 1e6 / 8.0).max(once);
    let inner = ((per_sample / once) as usize).clamp(1, 1_000_000);
    let samples = 10usize;

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..inner {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / inner as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Stats {
        name: name.to_string(),
        mean,
        p50: times[times.len() / 2],
        p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
        min: times[0],
        iters: inner * samples,
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
