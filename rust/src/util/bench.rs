//! Micro-benchmark timing substrate (criterion is unavailable offline).
//!
//! `cargo bench` runs the harness=false binaries under rust/benches/, each
//! of which uses [`bench`] / [`Stats`] for warmup + repeated timing and
//! prints criterion-style lines.
//!
//! Two environment knobs make the binaries CI-friendly (README.md
//! §Benchmarks, `.github/workflows/verify.yml` bench-smoke):
//!
//! * `KVMIX_BENCH_BUDGET_MS` — overrides every [`bench`] call's per-name
//!   sample budget, so a smoke run finishes in seconds.
//! * `KVMIX_BENCH_JSON` — a directory; each bench binary's [`JsonSink`]
//!   writes `<dir>/<bench>.json` with one entry per recorded [`Stats`].
//!   `scripts/bench_to_json.py` merges these into the tracked
//!   `BENCH_kernels.json` baseline and gates the packed-vs-fused
//!   speedup, so the perf trajectory survives ROADMAP re-anchors.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    /// nanoseconds per iteration
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub iters: usize,
}

impl Stats {
    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>12}  p50 {:>12}  p95 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean),
            fmt_ns(self.p50),
            fmt_ns(self.p95),
            self.iters
        )
    }

    /// items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, auto-scaling iteration count to fill ~`budget_ms` per sample.
/// `KVMIX_BENCH_BUDGET_MS` overrides the budget (CI smoke runs set it to
/// 1 so every bench binary completes in seconds).
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> Stats {
    let budget_ms = std::env::var("KVMIX_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(budget_ms);
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let per_sample = (budget_ms as f64 * 1e6 / 8.0).max(once);
    let inner = ((per_sample / once) as usize).clamp(1, 1_000_000);
    let samples = 10usize;

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..inner {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / inner as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Stats {
        name: name.to_string(),
        mean,
        p50: times[times.len() / 2],
        p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
        min: times[0],
        iters: inner * samples,
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects [`Stats`] rows and, when `KVMIX_BENCH_JSON=<dir>` is set,
/// writes them as `<dir>/<bench>.json`:
///
/// ```json
/// {"schema": 1, "bench": "quant_kernels", "entries": [
///   {"name": "...", "mean_ns": ..., "p50_ns": ..., "p95_ns": ...,
///    "min_ns": ..., "iters": ..., "per_s": ... | null}, ...]}
/// ```
///
/// `scripts/bench_to_json.py merge` folds these per-bench files into the
/// committed `BENCH_kernels.json` baseline; `--check` validates the
/// result and asserts the packed-vs-fused speedup multiple.  With the
/// env var unset the sink is a no-op, so the human-readable output is
/// unchanged.
pub struct JsonSink {
    bench: &'static str,
    path: Option<PathBuf>,
    rows: Vec<String>,
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

impl JsonSink {
    /// `bench` names the output file (`<KVMIX_BENCH_JSON>/<bench>.json`).
    pub fn from_env(bench: &'static str) -> Self {
        let path = std::env::var_os("KVMIX_BENCH_JSON")
            .map(|dir| PathBuf::from(dir).join(format!("{bench}.json")));
        JsonSink { bench, path, rows: Vec::new() }
    }

    /// Record one timed result; `items_per_iter` adds a derived
    /// items-per-second rate (tokens, elements, ... — whatever the
    /// bench's human-readable line reports).
    pub fn record(&mut self, s: &Stats, items_per_iter: Option<f64>) {
        let per_s = items_per_iter
            .map(|n| json_num(s.throughput(n)))
            .unwrap_or_else(|| "null".to_string());
        self.rows.push(format!(
            "{{\"name\":\"{}\",\"mean_ns\":{},\"p50_ns\":{},\"p95_ns\":{},\
             \"min_ns\":{},\"iters\":{},\"per_s\":{}}}",
            json_escape(&s.name), json_num(s.mean), json_num(s.p50), json_num(s.p95),
            json_num(s.min), s.iters, per_s));
    }

    /// Record an externally-timed row (the e2e bench's step loops time
    /// themselves rather than going through [`bench`]).
    pub fn record_value(&mut self, name: &str, mean_ns: f64, per_s: Option<f64>) {
        self.rows.push(format!(
            "{{\"name\":\"{}\",\"mean_ns\":{},\"p50_ns\":null,\"p95_ns\":null,\
             \"min_ns\":null,\"iters\":null,\"per_s\":{}}}",
            json_escape(name), json_num(mean_ns),
            per_s.map(json_num).unwrap_or_else(|| "null".to_string())));
    }

    /// Write the file (no-op when `KVMIX_BENCH_JSON` is unset).  An
    /// empty-entry file is still written so a skipped bench (e.g.
    /// e2e_decode without artifacts) is distinguishable from one that
    /// never ran.
    pub fn finish(&self) {
        let Some(path) = &self.path else { return };
        let body = format!("{{\"schema\":1,\"bench\":\"{}\",\"entries\":[\n{}\n]}}\n",
                           self.bench, self.rows.join(",\n"));
        let write = || -> std::io::Result<()> {
            if let Some(dir) = path.parent() {
                std::fs::create_dir_all(dir)?;
            }
            let mut f = std::fs::File::create(path)?;
            f.write_all(body.as_bytes())
        };
        match write() {
            Ok(()) => eprintln!("bench json -> {}", path.display()),
            Err(e) => eprintln!("bench json write failed ({}): {e}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_rows_are_valid_json_fragments() {
        let mut sink = JsonSink { bench: "t", path: None, rows: Vec::new() };
        let s = Stats { name: "key\"x/2bit".into(), mean: 12.345678, p50: 12.0,
                        p95: 13.0, min: 11.0, iters: 100 };
        sink.record(&s, Some(32.0));
        sink.record_value("e2e/decode", 1.5e6, None);
        assert!(sink.rows[0].contains("\\\""), "name must be escaped");
        assert!(sink.rows[0].contains("\"mean_ns\":12.346"));
        assert!(sink.rows[1].contains("\"per_s\":null"));
        // crude balance check on the assembled document shape
        let doc = format!("{{\"schema\":1,\"bench\":\"t\",\"entries\":[\n{}\n]}}",
                          sink.rows.join(",\n"));
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
    }

    #[test]
    fn empty_entries_render_when_no_rows() {
        let sink = JsonSink { bench: "t", path: None, rows: Vec::new() };
        assert!(sink.rows.is_empty());
        sink.finish(); // no path: must not panic or write
    }
}
