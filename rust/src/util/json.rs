//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar; numbers are kept as `f64`.  Good enough
//! for the artifact manifest, importance plans, goldens and report output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Result<_>>()?)
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        Ok(self.as_arr()?.iter().map(|v| v.as_usize()).collect::<Result<_>>()?)
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        Ok(self.as_arr()?.iter().map(|v| v.as_f64()).collect::<Result<_>>()?)
    }

    // ---------------- constructors ----------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f32s(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn from_usizes(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ---------------- serialization ----------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Escape `s` as a quoted JSON string literal.  Shared with the serving
/// protocol's hand-built response frames (`coordinator/proto.rs`), so
/// arbitrary error text can be embedded in a frame without breaking the
/// NDJSON framing (newlines become `\n`).
pub fn escape_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    write_escaped(s, &mut out);
    out
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------- parsing ----------------
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b.len() - self.i >= word.len() && &self.b[self.i..self.i + word.len()] == word.as_bytes() {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode utf8 sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let mut end = self.i;
                        while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                            end += 1;
                        }
                        out.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "3", "-2.5", "\"hi\\n\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn float_vec() {
        let v = parse("[1.5, 2, -0.25]").unwrap();
        assert_eq!(v.f32_vec().unwrap(), vec![1.5, 2.0, -0.25]);
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn escapes() {
        let v = parse(r#""aA\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aA\t");
        // serializer escapes control chars back out
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }
}
