//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! SplitMix64-seeded xoshiro256**, with helpers for uniform ints/floats,
//! normal deviates (Box–Muller), choices and shuffles.  Used by the
//! workload generators (rust/src/harness/workload.rs), the property-test
//! harness (rust/tests/props.rs) and the benchmarks.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller deviate.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)], spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) via Lemire's method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (u, v);
        loop {
            let cand = self.f64();
            if cand > 1e-300 {
                u = cand;
                v = self.f64();
                break;
            }
        }
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Weighted index choice (weights need not be normalized).
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices out of `n` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let s = r.sample_distinct(20, 10);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 10);
        }
    }
}
