//! Scoped worker pool for the decode hot path (DESIGN.md §Threading-Model,
//! docs/adr/001-scoped-threads-over-rayon.md).
//!
//! rayon is unavailable offline, and spawning OS threads per decode step
//! costs more than the per-layer attention fan-out it would parallelize.
//! [`WorkerPool::scoped`] therefore spawns **long-lived** workers once,
//! inside a [`std::thread::scope`], and [`WorkerPool::run`] dispatches one
//! parallel region at a time to them: the calling thread participates as
//! worker 0, the scoped threads are workers `1..threads`, and `run`
//! returns only after every worker has finished the region.
//!
//! That barrier is what makes the one `unsafe` block here sound: `run`
//! erases the lifetime of the job closure so it can sit in the shared
//! slot the long-lived workers poll, but the borrow it erases provably
//! outlives every use because `run` blocks until `remaining == 0`.
//!
//! Worker panics are caught, counted, and re-raised on the submitting
//! thread once the region completes, so a panicking lane cannot leave the
//! pool wedged (see the `panic_in_worker_propagates` test).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Instant;

/// A parallel region: invoked once per worker with the worker id in
/// `0..threads`. Workers with no work for their id must return promptly.
type Job<'a> = &'a (dyn Fn(usize) + Sync);

struct PoolState {
    /// bumped once per `run` call so workers run each region exactly once
    epoch: u64,
    /// the current region, lifetime-erased (see `WorkerPool::run`)
    job: Option<&'static (dyn Fn(usize) + Sync)>,
    /// scoped workers still inside the current region
    remaining: usize,
    /// workers that panicked inside the current region
    worker_panics: usize,
    shutdown: bool,
}

/// Reusable fork-join pool over `std::thread::scope` workers.
///
/// Construction is only possible through [`WorkerPool::scoped`], which
/// ties the workers' lifetime to a caller-provided closure — there is no
/// way to leak a running pool.
pub struct WorkerPool {
    threads: usize,
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// total nanoseconds all workers (incl. the caller) spent executing
    /// jobs — the numerator of the pool-utilization metric
    busy_ns: AtomicU64,
}

/// Resolve a `--threads` request: `0` means one worker per available core.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

impl WorkerPool {
    /// Run `f` with a pool of `threads` workers (`0` = one per core).
    ///
    /// Workers are spawned once, live for the whole closure, and are
    /// joined (via the enclosing [`std::thread::scope`]) before `scoped`
    /// returns — even if `f` panics.  `threads == 1` spawns nothing and
    /// every [`WorkerPool::run`] executes inline on the caller.
    pub fn scoped<R>(threads: usize, f: impl FnOnce(&WorkerPool) -> R) -> R {
        let threads = resolve_threads(threads).max(1);
        let pool = WorkerPool {
            threads,
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                remaining: 0,
                worker_panics: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            busy_ns: AtomicU64::new(0),
        };
        if threads == 1 {
            return f(&pool);
        }
        std::thread::scope(|s| {
            for id in 1..threads {
                let p = &pool;
                s.spawn(move || p.worker_loop(id));
            }
            // release the workers when `f` unwinds, or the scope's implicit
            // join would deadlock
            struct ShutdownOnDrop<'p>(&'p WorkerPool);
            impl Drop for ShutdownOnDrop<'_> {
                fn drop(&mut self) {
                    self.0.lock_state().shutdown = true;
                    self.0.work_cv.notify_all();
                }
            }
            let _shutdown = ShutdownOnDrop(&pool);
            f(&pool)
        })
    }

    /// Worker count, caller thread included (always >= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Cumulative time workers have spent executing jobs. Sample before
    /// and after a timed region to compute utilization:
    /// `(busy_after - busy_before) / (threads * wall_ns)`.
    pub fn busy_ns(&self) -> u64 {
        self.busy_ns.load(Ordering::Relaxed)
    }

    /// Execute one parallel region: `job(w)` runs once for every worker id
    /// `w` in `0..threads`, concurrently, with `job(0)` on the calling
    /// thread.  Blocks until all workers finish; re-raises any panic.
    ///
    /// Lane order guarantee: `run` adds no ordering of its own — callers
    /// partition work by id, and each partition executes exactly the
    /// statements the sequential path would, so a deterministic job is
    /// bit-identical to its `threads == 1` execution.
    pub fn run(&self, job: Job<'_>) {
        if self.threads == 1 {
            let t0 = Instant::now();
            let r = catch_unwind(AssertUnwindSafe(|| job(0)));
            self.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            if let Err(p) = r {
                resume_unwind(p);
            }
            return;
        }
        // SAFETY: the job slot outlives `'_` only inside this call — the
        // wait loop below does not return until every worker has both
        // finished executing the job and dropped its copy of the
        // reference (`remaining == 0`), after which the slot is cleared.
        let job_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(job) };
        {
            let mut st = self.lock_state();
            // hard assert (not debug_assert): a nested `run` from inside a
            // job would corrupt `remaining` and deadlock silently in
            // release builds — fail loudly instead
            assert!(
                st.job.is_none() && st.remaining == 0,
                "WorkerPool::run is not reentrant"
            );
            st.job = Some(job_static);
            st.remaining = self.threads - 1;
            st.epoch += 1;
            self.work_cv.notify_all();
        }
        let t0 = Instant::now();
        let caller = catch_unwind(AssertUnwindSafe(|| job(0)));
        self.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let mut st = self.lock_state();
        while st.remaining > 0 {
            st = self.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        let worker_panics = std::mem::take(&mut st.worker_panics);
        drop(st);
        if let Err(p) = caller {
            resume_unwind(p);
        }
        if worker_panics > 0 {
            panic!("{worker_panics} WorkerPool worker(s) panicked in a parallel region");
        }
    }

    /// Distribute owned `tasks` across the workers — task `i` runs as
    /// `f(i, task)` on worker `i` — and block until all finish.
    ///
    /// This is the pool's fork-join idiom for mutable work: callers chunk
    /// their `&mut` data into at most [`WorkerPool::threads`] disjoint
    /// task values (typically one contiguous chunk + one scratch per
    /// worker) and hand them over by value; ownership transfer through
    /// the id-indexed slots is what lets every worker mutate its chunk
    /// without contention.  Used by the decode fan-out, prefill
    /// attention, and the benches/tests, so all of them exercise the
    /// same dispatch path.
    pub fn run_tasks<T, I, F>(&self, tasks: I, f: F)
    where
        T: Send,
        I: IntoIterator<Item = T>,
        F: Fn(usize, T) + Sync,
    {
        let slots: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        assert!(slots.len() <= self.threads,
                "run_tasks: {} tasks for {} workers — excess tasks would be dropped",
                slots.len(), self.threads);
        if slots.is_empty() {
            return;
        }
        self.run(&|w| {
            if let Some(slot) = slots.get(w) {
                let t = slot.lock().unwrap_or_else(|e| e.into_inner()).take();
                if let Some(t) = t {
                    f(w, t);
                }
            }
        });
    }

    fn worker_loop(&self, id: usize) {
        let mut seen_epoch = 0u64;
        loop {
            let job = {
                let mut st = self.lock_state();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if st.epoch != seen_epoch {
                        if let Some(job) = st.job {
                            seen_epoch = st.epoch;
                            break job;
                        }
                    }
                    st = self.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
                }
            };
            let t0 = Instant::now();
            let result = catch_unwind(AssertUnwindSafe(|| job(id)));
            self.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let mut st = self.lock_state();
            if result.is_err() {
                st.worker_panics += 1;
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                self.done_cv.notify_all();
            }
        }
    }

    fn lock_state(&self) -> MutexGuard<'_, PoolState> {
        // a caught worker panic can poison the mutex between the catch and
        // the bookkeeping; the state itself stays consistent, so recover
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn parallel_matches_sequential() {
        let n = 1024usize;
        let data: Vec<u64> = (0..n as u64).map(|x| x * x + 1).collect();
        let seq: u64 = data.iter().sum();
        for threads in [1usize, 2, 4, 8] {
            let got = WorkerPool::scoped(threads, |pool| {
                let nw = pool.threads();
                let per = n.div_ceil(nw);
                let partials: Vec<AtomicU64> = (0..nw).map(|_| AtomicU64::new(0)).collect();
                pool.run(&|w| {
                    let lo = (w * per).min(n);
                    let hi = ((w + 1) * per).min(n);
                    let s: u64 = data[lo..hi].iter().sum();
                    partials[w].store(s, Ordering::Relaxed);
                });
                partials.iter().map(|p| p.load(Ordering::Relaxed)).sum::<u64>()
            });
            assert_eq!(got, seq, "threads={threads}");
        }
    }

    #[test]
    fn pool_is_reusable_across_runs() {
        WorkerPool::scoped(4, |pool| {
            let hits = AtomicUsize::new(0);
            for _ in 0..50 {
                pool.run(&|_w| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
            assert_eq!(hits.load(Ordering::Relaxed), 50 * pool.threads());
        });
    }

    #[test]
    fn run_tasks_mutable_chunks() {
        // the decode fan-out pattern: disjoint &mut chunks handed to
        // workers by value, for every thread count incl. sequential
        for threads in [1usize, 2, 4] {
            let mut out = vec![0u32; 37];
            WorkerPool::scoped(threads, |pool| {
                let nw = pool.threads();
                let per = out.len().div_ceil(nw);
                let chunks = out.chunks_mut(per).enumerate()
                    .map(|(ci, c)| (ci * per, c));
                pool.run_tasks(chunks, |_w, (base, chunk)| {
                    for (i, x) in chunk.iter_mut().enumerate() {
                        *x = (base + i) as u32;
                    }
                });
            });
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, i as u32, "threads={threads}");
            }
        }
    }

    #[test]
    fn run_tasks_rejects_more_tasks_than_workers() {
        WorkerPool::scoped(2, |pool| {
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.run_tasks(0..3usize, |_w, _t| {});
            }));
            assert!(r.is_err(), "3 tasks on 2 workers must panic, not drop work");
        });
    }

    #[test]
    fn panic_in_worker_propagates() {
        WorkerPool::scoped(4, |pool| {
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.run(&|w| {
                    if w == 1 {
                        panic!("boom in worker");
                    }
                });
            }));
            assert!(r.is_err(), "worker panic must surface on the caller");
            // the pool must stay usable after a propagated panic
            let hits = AtomicUsize::new(0);
            pool.run(&|_w| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), pool.threads());
        });
    }

    #[test]
    fn panic_on_caller_thread_propagates() {
        WorkerPool::scoped(2, |pool| {
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.run(&|w| {
                    if w == 0 {
                        panic!("boom on caller");
                    }
                });
            }));
            assert!(r.is_err());
        });
    }

    #[test]
    fn single_thread_runs_inline() {
        WorkerPool::scoped(1, |pool| {
            assert_eq!(pool.threads(), 1);
            let main_id = std::thread::current().id();
            pool.run(&|w| {
                assert_eq!(w, 0);
                assert_eq!(std::thread::current().id(), main_id);
            });
        });
    }

    #[test]
    fn busy_counter_advances() {
        WorkerPool::scoped(2, |pool| {
            let before = pool.busy_ns();
            pool.run(&|_w| {
                std::thread::sleep(std::time::Duration::from_millis(2));
            });
            assert!(pool.busy_ns() > before);
        });
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
