//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (not including argv[0]).  `flag_names` lists options
    /// that take no value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}={v}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}={v}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed() {
        let a = Args::parse(&s(&["serve", "--port", "8080", "--verbose", "--x=3"]), &["verbose"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("x", 0).unwrap(), 3);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&s(&["--fast"]), &[]);
        assert!(a.flag("fast"));
    }
}
