//! In-repo substrates for functionality whose usual crates are not
//! available in this offline environment (see Cargo.toml note).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;

pub use json::Json;
pub use pool::{resolve_threads, WorkerPool};
pub use rng::Rng;
