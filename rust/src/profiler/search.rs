//! Offline Pareto plan search (ROADMAP item 3,
//! docs/adr/007-asymmetric-bit-allocation.md): enumerate per-layer
//! `(k_bits, v_bits)` allocations under a modeled byte budget, score
//! each feasible candidate's perplexity, and keep the Pareto frontier —
//! the KVTuner recipe of deriving asymmetric K/V operating points from
//! search instead of hand-set fractions.
//!
//! Two-phase to keep measured evals affordable: a deterministic modeled
//! proxy ([`modeled_ppl`], the importance-weighted quantization-noise
//! model) prunes the candidate grid down to its frontier, then only the
//! survivors are re-scored against the teacher-forced eval harness
//! (`harness/eval.rs`).  The frontier serializes to JSON
//! (`--plan-out` / `--plan-in`, README.md §Plan search) so serve and
//! generate can load a searched [`QuantPlan`] instead of
//! `profiler::allocate`'s fixed `high_frac` split.
//!
//! Everything here is deterministic for a fixed seed + budget: candidate
//! enumeration follows importance rank order, the frontier sort is
//! total, and the JSON serializer is canonical (sorted keys), which is
//! what `rust/tests/plan_search.rs` pins.

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{bail, Result};

use crate::baselines::Method;
use crate::config::QuantPlan;
use crate::harness::eval::{evaluate, EvalCfg};
use crate::harness::workload::Task;
use crate::kvcache::pages::page_frame_bytes;
use crate::kvcache::pressure::quant_err_proxy;
use crate::runtime::Runtime;
use crate::util::json::{parse_file, Json};
use crate::util::Rng;

use super::Importance;

/// Search space + budget for one plan search.
#[derive(Debug, Clone)]
pub struct SearchCfg {
    /// Byte budget as a fraction of the fp16 modeled bytes/token
    /// (`4 * kv_dim`, both sides at 2 B/element).
    pub budget_frac: f64,
    /// Packed widths the enumeration may assign (16/fp is never a
    /// search candidate — it has no packed pages to manage).
    pub bit_choices: Vec<u8>,
    /// High-tier sizes as fractions of the layer count: for each, the
    /// top layers *by importance rank* (per side) get the high width.
    pub high_fracs: Vec<f64>,
    /// RPC ratio for high-tier / low-tier layers (mirrors
    /// `profiler::allocate_with`).  Setting them equal makes modeled
    /// bytes linear in total bits, which the budget-monotonicity
    /// property test relies on.
    pub rpc_high: f64,
    pub rpc_low: f64,
    /// Recorded in the emitted JSON; also seeds [`synthetic_importance`].
    pub seed: u64,
}

impl Default for SearchCfg {
    fn default() -> Self {
        SearchCfg {
            budget_frac: 0.30,
            bit_choices: vec![1, 2, 3, 4],
            high_fracs: vec![0.0, 0.25, 0.5],
            rpc_high: 0.2,
            rpc_low: 0.1,
            seed: 7,
        }
    }
}

impl SearchCfg {
    /// Smaller grid for eval-scored runs (each surviving candidate costs
    /// a full teacher-forced eval pass).
    pub fn coarse() -> Self {
        SearchCfg {
            bit_choices: vec![1, 2, 3, 4],
            high_fracs: vec![0.0, 0.25],
            ..SearchCfg::default()
        }
    }
}

/// One scored candidate on (or off) the frontier.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    pub plan: QuantPlan,
    pub bytes_per_token: f64,
    pub ppl: f64,
}

/// The outcome of one search: the Pareto frontier, bytes strictly
/// ascending and perplexity strictly descending, plus enough metadata to
/// reproduce and to sanity-check a loaded file against a model.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub n_layers: usize,
    pub kv_dim: usize,
    pub group: usize,
    pub seed: u64,
    pub budget_bytes_per_token: f64,
    pub frontier: Vec<PlanPoint>,
}

/// Modeled fp16 KV bytes per token, both sides (2 B/element).
pub fn fp16_bytes_per_token(kv_dim: usize) -> f64 {
    (4 * kv_dim) as f64
}

/// Steady-state modeled KV bytes per token of a plan: each side keeps an
/// `rpc` fraction of the context full-precision (the RPC window) and
/// holds the rest in packed pages at the plan's width, group-scale
/// overhead included, page rounding excluded.  Uses the same
/// `page_frame_bytes` arithmetic the pool charges, evaluated at one
/// group per page so no rounding slack enters.
pub fn plan_bytes_per_token(plan: &QuantPlan, kv_dim: usize, group: usize) -> f64 {
    let fp_side = (2 * kv_dim) as f64;
    let quant = |b: u8| page_frame_bytes(group, kv_dim, group, b) as f64 / group as f64;
    let side = |bits: &[u8], rpc: &[f64]| -> f64 {
        bits.iter().zip(rpc.iter()).map(|(&b, &r)| {
            if b == 16 { fp_side } else { (1.0 - r) * quant(b) + r * fp_side }
        }).sum()
    };
    side(&plan.k_bits, &plan.k_rpc) + side(&plan.v_bits, &plan.v_rpc)
}

/// Deterministic proxy perplexity: exp of the mean profiling loss plus
/// the importance-weighted quantization noise of the plan, with each
/// layer's RPC window discounting its noise (full-precision fraction).
/// Strictly decreasing in every bit width (positive scores assumed), so
/// it is a valid Pareto scorer even though its absolute scale is crude —
/// phase 2 replaces the values with measured eval perplexity.
pub fn modeled_ppl(imp: &Importance, plan: &QuantPlan) -> f64 {
    let mut noise = 0.0;
    for l in 0..plan.n_layers() {
        noise += imp.k[l] * (1.0 - plan.k_rpc[l]) * quant_err_proxy(plan.k_bits[l]);
        noise += imp.v[l] * (1.0 - plan.v_rpc[l]) * quant_err_proxy(plan.v_bits[l]);
    }
    (imp.mean_loss + noise).exp()
}

/// Artifact-free importance profile for CI smoke and the property tests:
/// seeded, loosely front-loaded (early layers matter more, like the
/// profiled models), strictly positive.
pub fn synthetic_importance(n_layers: usize, seed: u64) -> Importance {
    let mut rng = Rng::new(seed ^ 0xA11C_E5);
    let side = |rng: &mut Rng| -> Vec<f64> {
        (0..n_layers).map(|i| (1.0 + rng.f64()) / (1.0 + 0.35 * i as f64)).collect()
    };
    let k = side(&mut rng);
    let v = side(&mut rng);
    Importance { k, v, mean_loss: 1.0, n_prompts: 0 }
}

/// Layer indices sorted by descending score, index as the tie-break so
/// the order (and therefore the whole search) is deterministic.
fn ranked(scores: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    idx
}

/// All distinct (bits, rpc) assignments for one side: a low width
/// everywhere, a high width + `rpc_high` on the top `frac` of layers by
/// importance rank — the same two-tier shape `profiler::allocate_with`
/// emits, swept over the grid.
fn side_variants(scores: &[f64], cfg: &SearchCfg) -> Vec<(Vec<u8>, Vec<f64>)> {
    let n = scores.len();
    let order = ranked(scores);
    let mut seen: BTreeSet<(Vec<u8>, Vec<u64>)> = BTreeSet::new();
    let mut out = Vec::new();
    for &low in &cfg.bit_choices {
        for &high in &cfg.bit_choices {
            if high < low {
                continue;
            }
            for &frac in &cfg.high_fracs {
                let n_high = ((frac * n as f64).round() as usize).min(n);
                let mut bits = vec![low; n];
                let mut rpc = vec![cfg.rpc_low; n];
                for &i in order.iter().take(n_high) {
                    bits[i] = high;
                    rpc[i] = cfg.rpc_high;
                }
                let key = (bits.clone(), rpc.iter().map(|r| r.to_bits()).collect());
                if seen.insert(key) {
                    out.push((bits, rpc));
                }
            }
        }
    }
    out
}

/// The full candidate grid: the cross product of per-side variants.
pub fn enumerate_candidates(imp: &Importance, cfg: &SearchCfg) -> Vec<QuantPlan> {
    let ks = side_variants(&imp.k, cfg);
    let vs = side_variants(&imp.v, cfg);
    let mut out = Vec::with_capacity(ks.len() * vs.len());
    for (kb, kr) in &ks {
        for (vb, vr) in &vs {
            let mut p = QuantPlan {
                name: String::new(),
                k_bits: kb.clone(),
                v_bits: vb.clone(),
                k_rpc: kr.clone(),
                v_rpc: vr.clone(),
            };
            p.name = format!("searched-k{:.2}v{:.2}", p.avg_k_bits(), p.avg_v_bits());
            out.push(p);
        }
    }
    out
}

/// Total order over scored points: bytes, then perplexity, then the plan
/// itself — so sorting (and hence the surviving frontier) never depends
/// on enumeration order.
fn cmp_points(a: &PlanPoint, b: &PlanPoint) -> std::cmp::Ordering {
    let rpc_key = |p: &QuantPlan| -> Vec<u64> {
        p.k_rpc.iter().chain(p.v_rpc.iter()).map(|r| r.to_bits()).collect()
    };
    a.bytes_per_token.partial_cmp(&b.bytes_per_token).unwrap()
        .then(a.ppl.partial_cmp(&b.ppl).unwrap())
        .then_with(|| a.plan.k_bits.cmp(&b.plan.k_bits))
        .then_with(|| a.plan.v_bits.cmp(&b.plan.v_bits))
        .then_with(|| rpc_key(&a.plan).cmp(&rpc_key(&b.plan)))
}

/// Reduce scored candidates to the Pareto frontier: sorted by bytes
/// ascending, a point survives only if it strictly improves perplexity
/// over everything cheaper — so no survivor weakly dominates another on
/// both axes, and the last entry is the minimum-perplexity plan (with
/// minimum bytes among perplexity ties).
pub fn pareto_frontier(mut pts: Vec<PlanPoint>) -> Vec<PlanPoint> {
    pts.sort_by(cmp_points);
    let mut out: Vec<PlanPoint> = Vec::new();
    for p in pts {
        match out.last() {
            Some(last) if p.ppl >= last.ppl => {}
            _ => out.push(p),
        }
    }
    out
}

/// Search with an explicit absolute byte budget and a caller-supplied
/// scorer (modeled or measured).
pub fn search_plans_with_budget(
    imp: &Importance, cfg: &SearchCfg, kv_dim: usize, group: usize, budget: f64,
    scorer: &mut dyn FnMut(&QuantPlan) -> Result<f64>) -> Result<SearchResult> {
    let mut pts = Vec::new();
    for plan in enumerate_candidates(imp, cfg) {
        let bytes = plan_bytes_per_token(&plan, kv_dim, group);
        if bytes > budget + 1e-9 {
            continue;
        }
        let ppl = scorer(&plan)?;
        pts.push(PlanPoint { plan, bytes_per_token: bytes, ppl });
    }
    Ok(SearchResult {
        n_layers: imp.k.len(),
        kv_dim,
        group,
        seed: cfg.seed,
        budget_bytes_per_token: budget,
        frontier: pareto_frontier(pts),
    })
}

/// Search under `cfg.budget_frac` of the fp16 footprint.
pub fn search_plans(imp: &Importance, cfg: &SearchCfg, kv_dim: usize, group: usize,
                    scorer: &mut dyn FnMut(&QuantPlan) -> Result<f64>)
                    -> Result<SearchResult> {
    let budget = cfg.budget_frac * fp16_bytes_per_token(kv_dim);
    search_plans_with_budget(imp, cfg, kv_dim, group, budget, scorer)
}

/// Phase-1-only search: modeled proxy scores, no runtime needed.
pub fn search_modeled(imp: &Importance, cfg: &SearchCfg, kv_dim: usize,
                      group: usize) -> Result<SearchResult> {
    search_plans(imp, cfg, kv_dim, group, &mut |p| Ok(modeled_ppl(imp, p)))
}

/// The full two-phase search: modeled prune, then measured teacher-forced
/// perplexity (LM suite) on the surviving frontier only.
pub fn search_with_eval(rt: &Runtime, imp: &Importance, cfg: &SearchCfg,
                        ecfg: &EvalCfg) -> Result<SearchResult> {
    let (kv_dim, group) = (rt.model.kv_dim(), rt.model.group);
    let SearchResult { n_layers, seed, budget_bytes_per_token, frontier, .. } =
        search_plans(imp, cfg, kv_dim, group, &mut |p| Ok(modeled_ppl(imp, p)))?;
    let mut pts = Vec::with_capacity(frontier.len());
    for pt in frontier {
        let r = evaluate(rt, &Method::Kvmix(pt.plan.clone()), Task::Lm, ecfg)?;
        pts.push(PlanPoint { ppl: r.ppl(), ..pt });
    }
    Ok(SearchResult {
        n_layers,
        kv_dim,
        group,
        seed,
        budget_bytes_per_token,
        frontier: pareto_frontier(pts),
    })
}

impl SearchResult {
    /// The minimum-perplexity plan under the budget (frontier tail).
    pub fn best(&self) -> Option<&PlanPoint> {
        self.frontier.last()
    }

    pub fn to_json(&self) -> Json {
        let pts = self.frontier.iter().map(|p| Json::obj(vec![
            ("bytes_per_token", Json::Num(p.bytes_per_token)),
            ("plan", p.plan.to_json()),
            ("ppl", Json::Num(p.ppl)),
        ])).collect();
        Json::obj(vec![
            ("budget_bytes_per_token", Json::Num(self.budget_bytes_per_token)),
            ("frontier", Json::Arr(pts)),
            ("group", Json::Num(self.group as f64)),
            ("kv_dim", Json::Num(self.kv_dim as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let n_layers = j.get("n_layers")?.as_usize()?;
        let mut frontier = Vec::new();
        for pt in j.get("frontier")?.as_arr()? {
            let plan = QuantPlan::from_json(pt.get("plan")?)?;
            plan.validate()?;
            if plan.n_layers() != n_layers {
                bail!("frontier plan {:?} has {} layers, file says {n_layers}",
                      plan.name, plan.n_layers());
            }
            frontier.push(PlanPoint {
                plan,
                bytes_per_token: pt.get("bytes_per_token")?.as_f64()?,
                ppl: pt.get("ppl")?.as_f64()?,
            });
        }
        Ok(SearchResult {
            n_layers,
            kv_dim: j.get("kv_dim")?.as_usize()?,
            group: j.get("group")?.as_usize()?,
            seed: j.get("seed")?.as_f64()? as u64,
            budget_bytes_per_token: j.get("budget_bytes_per_token")?.as_f64()?,
            frontier,
        })
    }

    /// Canonical serialization (sorted keys, shortest-round-trip floats):
    /// `read_file` → `write_file` is byte-identical, which the CLI's
    /// `plan-search --check` and `rust/tests/plan_search.rs` pin.
    pub fn write_file(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")?;
        Ok(())
    }

    pub fn read_file(path: &Path) -> Result<Self> {
        Self::from_json(&parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KV_DIM: usize = 64;
    const GROUP: usize = 32;

    #[test]
    fn bytes_model_matches_hand_arithmetic() {
        // group=32: packed bytes/token = kv_dim*(b+1)/8, fp16 = 2*kv_dim
        let no_rpc = QuantPlan::uniform(4, 2).without_rpc();
        let expect = 2.0 * (KV_DIM as f64) * 3.0 / 8.0 * 4.0;
        assert!((plan_bytes_per_token(&no_rpc, KV_DIM, GROUP) - expect).abs() < 1e-9,
                "4 layers x 2 sides of 2-bit packed");
        let fp = QuantPlan::fp16(4);
        assert!((plan_bytes_per_token(&fp, KV_DIM, GROUP)
                 - 4.0 * fp16_bytes_per_token(KV_DIM)).abs() < 1e-9);
        // the RPC window adds (and never subtracts) bytes
        let rpc = QuantPlan::uniform(4, 2);
        assert!(plan_bytes_per_token(&rpc, KV_DIM, GROUP)
                > plan_bytes_per_token(&no_rpc, KV_DIM, GROUP));
    }

    #[test]
    fn modeled_ppl_rewards_bits_and_rpc() {
        let imp = synthetic_importance(4, 3);
        let p2 = modeled_ppl(&imp, &QuantPlan::uniform(4, 2));
        let p4 = modeled_ppl(&imp, &QuantPlan::uniform(4, 4));
        let p2n = modeled_ppl(&imp, &QuantPlan::uniform(4, 2).without_rpc());
        assert!(p4 < p2, "more bits must lower the proxy");
        assert!(p2 < p2n, "the RPC window must lower the proxy");
    }

    #[test]
    fn enumeration_is_deterministic_and_high_tier_follows_rank() {
        let imp = synthetic_importance(8, 11);
        let cfg = SearchCfg::default();
        let a = enumerate_candidates(&imp, &cfg);
        let b = enumerate_candidates(&imp, &cfg);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        let order = ranked(&imp.k);
        // any two-tier K variant puts its high bits exactly on the top-ranked prefix
        for p in &a {
            let hi: Vec<u8> = p.k_bits.iter().copied().collect::<BTreeSet<_>>()
                .into_iter().collect();
            if hi.len() == 2 {
                let n_high = p.k_bits.iter().filter(|&&b| b == hi[1]).count();
                for &i in order.iter().take(n_high) {
                    assert_eq!(p.k_bits[i], hi[1], "high tier must follow rank order");
                }
            }
        }
    }

    #[test]
    fn frontier_has_no_dominated_points() {
        let imp = synthetic_importance(6, 5);
        let res = search_modeled(&imp, &SearchCfg::default(), KV_DIM, GROUP).unwrap();
        assert!(!res.frontier.is_empty());
        for w in res.frontier.windows(2) {
            assert!(w[0].bytes_per_token < w[1].bytes_per_token);
            assert!(w[0].ppl > w[1].ppl);
        }
        for p in &res.frontier {
            assert!(p.bytes_per_token <= res.budget_bytes_per_token + 1e-9);
            p.plan.validate().unwrap();
        }
    }

    #[test]
    fn impossible_budget_yields_empty_frontier() {
        let imp = synthetic_importance(4, 1);
        let res = search_plans_with_budget(&imp, &SearchCfg::default(), KV_DIM, GROUP,
                                           1.0, &mut |p| Ok(modeled_ppl(&imp, p)))
            .unwrap();
        assert!(res.frontier.is_empty(), "1 B/token fits no plan");
    }

    #[test]
    fn json_round_trip_is_bit_exact() {
        let imp = synthetic_importance(4, 42);
        let res = search_modeled(&imp, &SearchCfg::default(), KV_DIM, GROUP).unwrap();
        let s = res.to_json().to_string();
        let back = SearchResult::from_json(&crate::util::json::parse(&s).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), s);
        assert_eq!(back.frontier.len(), res.frontier.len());
        assert_eq!(back.best().unwrap().plan, res.best().unwrap().plan);
    }
}
