//! The KVmix profiler on the Rust side (paper §KV Importance Analysis,
//! Algorithm 1): runs the AOT-lowered loss+gradient graph over a set of
//! prompts through PJRT, averages the per-layer L2 gradient norms of
//! W_k / W_v (Eq. 10–11), and allocates per-layer bit widths + RPC ratios
//! (top `high_frac` of layers → 3-bit K / 4-bit V, rest 2-bit).
//!
//! Python never runs on this path; `python/compile/profiler.py` is the
//! build-time reference the result is cross-checked against
//! (rust/tests/integration.rs).

pub mod search;

use anyhow::Result;

use crate::config::QuantPlan;
use crate::harness::workload::{self, Task};
use crate::runtime::Runtime;
use crate::util::Rng;

/// Averaged per-layer importance scores.
#[derive(Debug, Clone)]
pub struct Importance {
    pub k: Vec<f64>,
    pub v: Vec<f64>,
    pub mean_loss: f64,
    pub n_prompts: usize,
}

/// Run the gradient graph over `prompts` (tokens, mask) pairs.
pub fn importance_from_prompts(rt: &Runtime, prompts: &[(Vec<i32>, Vec<f32>)])
                               -> Result<Importance> {
    let l = rt.model.n_layers;
    let mut k = vec![0f64; l];
    let mut v = vec![0f64; l];
    let mut loss_acc = 0f64;
    for (toks, mask) in prompts {
        let (loss, kn, vn) = rt.profiler_grads(toks, mask)?;
        loss_acc += loss as f64;
        for i in 0..l {
            k[i] += kn[i] as f64;
            v[i] += vn[i] as f64;
        }
    }
    let n = prompts.len().max(1) as f64;
    for x in k.iter_mut().chain(v.iter_mut()) {
        *x /= n;
    }
    Ok(Importance { k, v, mean_loss: loss_acc / n, n_prompts: prompts.len() })
}

/// Sample `n` prompts from the synthetic task mixture and profile.
pub fn profile(rt: &Runtime, n_prompts: usize, seed: u64) -> Result<Importance> {
    let t = rt.profile_seq_len;
    let mut rng = Rng::new(seed);
    let prompts: Vec<(Vec<i32>, Vec<f32>)> = (0..n_prompts)
        .map(|_| workload::sample_mixture(&mut rng, t))
        .collect();
    importance_from_prompts(rt, &prompts)
}

/// Profile restricted to a single task (Fig. 10 robustness study).
pub fn profile_task(rt: &Runtime, task: Task, n_prompts: usize, seed: u64)
                    -> Result<Importance> {
    let t = rt.profile_seq_len;
    let mut rng = Rng::new(seed);
    let prompts: Vec<(Vec<i32>, Vec<f32>)> = (0..n_prompts)
        .map(|_| workload::generate(task, &mut rng, t))
        .collect();
    importance_from_prompts(rt, &prompts)
}

/// Rank layers and allocate bits (mirror of python profiler.allocate).
pub fn allocate(imp: &Importance, high_frac: f64) -> QuantPlan {
    allocate_with(imp, high_frac, 3, 4, 2, 0.2, 0.1)
}

pub fn allocate_with(imp: &Importance, high_frac: f64, k_high_bits: u8,
                     v_high_bits: u8, low_bits: u8, rpc_high: f64,
                     rpc_low: f64) -> QuantPlan {
    let n = imp.k.len();
    let n_high = ((high_frac * n as f64).round() as usize).min(n);
    let top = |scores: &[f64]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        idx.truncate(n_high);
        idx
    };
    let k_top = top(&imp.k);
    let v_top = top(&imp.v);
    let mut plan = QuantPlan {
        name: String::new(),
        k_bits: vec![low_bits; n],
        v_bits: vec![low_bits; n],
        k_rpc: vec![rpc_low; n],
        v_rpc: vec![rpc_low; n],
    };
    for &i in &k_top {
        plan.k_bits[i] = k_high_bits;
        plan.k_rpc[i] = rpc_high;
    }
    for &i in &v_top {
        plan.v_bits[i] = v_high_bits;
        plan.v_rpc[i] = rpc_high;
    }
    plan.name = format!("kvmix-k{:.2}v{:.2}", plan.avg_k_bits(), plan.avg_v_bits());
    plan
}

/// Spearman rank correlation between two importance orderings (Fig. 10's
/// consistency metric).
pub fn rank_correlation(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    assert_eq!(n, b.len());
    let rank = |xs: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
        let mut r = vec![0f64; xs.len()];
        for (rk, &i) in idx.iter().enumerate() {
            r[i] = rk as f64;
        }
        r
    };
    let (ra, rb) = (rank(a), rank(b));
    let d2: f64 = ra.iter().zip(&rb).map(|(x, y)| (x - y) * (x - y)).sum();
    1.0 - 6.0 * d2 / (n as f64 * ((n * n - 1) as f64))
}

/// Fig. 6-style report of a plan.
pub fn plan_report(imp: &Importance, plan: &QuantPlan) -> String {
    let mut s = String::new();
    s.push_str(&format!("plan: {}  (avg K {:.4} bits, avg V {:.4} bits)\n",
                        plan.name, plan.avg_k_bits(), plan.avg_v_bits()));
    s.push_str("layer |  s_k (grad norm) | k_bits | k_rpc |  s_v (grad norm) | v_bits | v_rpc\n");
    for i in 0..plan.n_layers() {
        s.push_str(&format!(
            "{:>5} | {:>16.6} | {:>6} | {:>4.0}% | {:>16.6} | {:>6} | {:>4.0}%\n",
            i, imp.k[i], plan.k_bits[i], plan.k_rpc[i] * 100.0,
            imp.v[i], plan.v_bits[i], plan.v_rpc[i] * 100.0));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn imp(k: Vec<f64>, v: Vec<f64>) -> Importance {
        Importance { k, v, mean_loss: 1.0, n_prompts: 4 }
    }

    #[test]
    fn allocation_top_frac() {
        let i = imp(vec![5.0, 1.0, 3.0, 2.0, 0.5, 0.1, 4.0, 0.2],
                    vec![0.1, 5.0, 0.2, 4.0, 3.0, 0.3, 0.4, 0.5]);
        let p = allocate(&i, 0.25);
        assert_eq!(p.k_bits.iter().filter(|&&b| b == 3).count(), 2);
        assert_eq!(p.k_bits[0], 3);
        assert_eq!(p.k_bits[6], 3);
        assert_eq!(p.v_bits[1], 4);
        assert_eq!(p.v_bits[3], 4);
        assert!((p.avg_k_bits() - 2.25).abs() < 1e-9);
        assert!((p.avg_v_bits() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn paper_headline_arithmetic() {
        // 32 layers, 6 high (18.75% ≈ paper's "20%") -> K 2.1875 / V 2.375
        let scores: Vec<f64> = (0..32).map(|x| x as f64).collect();
        let i = imp(scores.clone(), scores);
        let p = allocate(&i, 0.1875);
        assert!((p.avg_k_bits() - 2.1875).abs() < 1e-9);
        assert!((p.avg_v_bits() - 2.375).abs() < 1e-9);
        assert_eq!(p.name, "kvmix-k2.19v2.38");
    }

    #[test]
    fn rank_corr() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        assert!((rank_correlation(&a, &a) - 1.0).abs() < 1e-12);
        let rev: Vec<f64> = a.iter().rev().cloned().collect();
        assert!((rank_correlation(&a, &rev) + 1.0).abs() < 1e-12);
    }
}
