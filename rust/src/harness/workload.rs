//! Synthetic task generators — the Rust mirror of python/compile/corpus.py
//! (same token space and task structure; the model was trained on exactly
//! this distribution).  Deterministic in the seed via [`crate::util::Rng`].

use crate::util::Rng;

// token space — keep in sync with corpus.py
pub const VOCAB: usize = 512;
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const QRY: i32 = 4;
pub const ANS: i32 = 5;
pub const EQL: i32 = 6;
pub const NUM_BASE: i32 = 10;
pub const NUM_COUNT: usize = 16;
pub const KEY_BASE: i32 = 100;
pub const KEY_COUNT: usize = 48;
pub const VAL_BASE: i32 = 200;
pub const VAL_COUNT: usize = 48;
pub const LM_BASE: i32 = 300;
pub const LM_COUNT: usize = 212;
pub const LM_NOISE: f64 = 0.05;
pub const LM_MULT: i32 = 3;
pub const ANSWER_WEIGHT: f32 = 4.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Wikitext-2 analog: pseudo-language perplexity
    Lm,
    /// LongBench analog: key/value retrieval at distance
    Recall,
    /// GSM8K analog: local modular sums
    Chain,
}

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Lm => "lm",
            Task::Recall => "recall",
            Task::Chain => "chain",
        }
    }

    pub fn all() -> [Task; 3] {
        [Task::Lm, Task::Recall, Task::Chain]
    }
}

/// (tokens, loss_mask) — mask[t] weights the prediction made *at* t
/// (of tokens[t+1]); PAD-padded to `seq_len`.
pub fn generate(task: Task, rng: &mut Rng, seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    match task {
        Task::Lm => gen_lm(rng, seq_len),
        Task::Recall => gen_recall(rng, seq_len, None, 6),
        Task::Chain => gen_chain(rng, seq_len),
    }
}

/// Training-mixture sample (lm 20%, recall 40%, chain 40% — corpus.TRAIN_MIX).
pub fn sample_mixture(rng: &mut Rng, seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    let x = rng.f64();
    let task = if x < 0.2 {
        Task::Lm
    } else if x < 0.6 {
        Task::Recall
    } else {
        Task::Chain
    };
    generate(task, rng, seq_len)
}

fn pad(mut toks: Vec<i32>, mut mask: Vec<f32>, seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    toks.truncate(seq_len);
    mask.truncate(seq_len);
    toks.resize(seq_len, PAD);
    mask.resize(seq_len, 0.0);
    (toks, mask)
}

pub fn gen_lm(rng: &mut Rng, seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    let o = rng.range(1, 16) as i32;
    let mut x = rng.below(LM_COUNT) as i32;
    let mut toks = vec![BOS, LM_BASE + x];
    let mut mask = vec![0.0f32, 0.0];
    for _ in 0..seq_len.saturating_sub(3) {
        if rng.bool(LM_NOISE) {
            x = rng.below(LM_COUNT) as i32;
        } else {
            x = (LM_MULT * x + o).rem_euclid(LM_COUNT as i32);
        }
        toks.push(LM_BASE + x);
        *mask.last_mut().unwrap() = 1.0;
        mask.push(0.0);
    }
    toks.push(EOS);
    *mask.last_mut().unwrap() = 1.0;
    mask.push(0.0);
    pad(toks, mask, seq_len)
}

pub const N_DISTINCT_PAIRS: usize = 16;

/// In-context associative recall (induction-head format — corpus.gen_recall).
///
/// `query_offset`: Some(0) queries the key whose last binding is most
/// recent; larger = older (retrieval-distance stress).
pub fn gen_recall(rng: &mut Rng, seq_len: usize, query_offset: Option<usize>,
                  n_queries: usize) -> (Vec<i32>, Vec<f32>) {
    let n_distinct = N_DISTINCT_PAIRS.min(KEY_COUNT);
    let keys = rng.sample_distinct(KEY_COUNT, n_distinct);
    let vals: Vec<usize> = (0..n_distinct).map(|_| rng.below(VAL_COUNT)).collect();
    let budget = seq_len.saturating_sub(2 + 3 * n_queries + 1);
    let mut toks = vec![BOS];
    let mut mask = vec![0.0f32];
    let mut order: Vec<usize> = Vec::new();
    while toks.len() + 2 <= budget {
        if order.is_empty() {
            order = (0..n_distinct).collect();
            rng.shuffle(&mut order);
        }
        let i = order.pop().unwrap();
        toks.push(KEY_BASE + keys[i] as i32);
        toks.push(VAL_BASE + vals[i] as i32);
        mask.push(0.0);
        mask.push(0.0);
    }
    toks.push(SEP);
    mask.push(0.0);
    // last-occurrence recency ranking for query_offset targeting
    let mut last_pos: Vec<(usize, usize)> = Vec::new(); // (key idx, pos)
    for (i, &k) in keys.iter().enumerate() {
        if let Some(p) = toks.iter().rposition(|&t| t == KEY_BASE + k as i32) {
            last_pos.push((i, p));
        }
    }
    last_pos.sort_by_key(|&(_, p)| std::cmp::Reverse(p));
    for qn in 0..n_queries {
        if toks.len() + 3 > seq_len {
            break;
        }
        let qi = if qn == 0 && query_offset.is_some() && !last_pos.is_empty() {
            last_pos[query_offset.unwrap() % last_pos.len()].0
        } else {
            rng.below(n_distinct)
        };
        toks.push(QRY);
        toks.push(KEY_BASE + keys[qi] as i32);
        toks.push(VAL_BASE + vals[qi] as i32);
        mask.push(0.0);
        mask.push(ANSWER_WEIGHT); // key position predicts the value
        mask.push(0.0);
    }
    toks.push(EOS);
    mask.push(0.0);
    pad(toks, mask, seq_len)
}

/// Long-prompt-interference workload (DESIGN.md §Scheduler): `n_short`
/// short prompts that decode steadily, plus one `long_len`-token prompt
/// meant to arrive mid-stream.  Returns `(short_prompts, long_prompt)`;
/// the caller stages the arrival (submit the shorts, run a few engine
/// steps, then submit the long one — see the `interference` section of
/// `rust/benches/e2e_decode.rs`).  Under the legacy whole-prefill
/// engine the long arrival stalls every short decoder for its entire
/// prefill (a TBT spike); under `--step-tokens` it is chunked.
/// Deterministic in the seed.
pub fn interference_prompts(rng: &mut Rng, n_short: usize, short_len: usize,
                            long_len: usize) -> (Vec<Vec<i32>>, Vec<i32>) {
    let shorts = (0..n_short)
        .map(|_| sample_mixture(rng, short_len).0)
        .collect();
    // the LM task pads/extends to any length, so it makes the long
    // context; recall/chain budgets are tuned for short sequences
    let (long, _) = gen_lm(rng, long_len);
    (shorts, long)
}

/// One user turn of a [`multi_turn_chat`] conversation: the user's new
/// tokens (NOT the accumulated conversation) and the decode budget for
/// the reply.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChatTurn {
    /// this turn's user utterance — the caller builds turn *t*'s prompt
    /// as `prompt[t-1] + generated[t-1] + user[t]`, exactly the
    /// concatenation the engine's session park/resume adopts pages for
    /// (DESIGN.md §Serving-Protocol)
    pub user: Vec<i32>,
    pub max_new: usize,
}

/// Multi-turn conversation workload for session park/resume: `turns`
/// user utterances of `turn_len/2 ..= turn_len` LM-space tokens each
/// (turn 0 opens with BOS, later turns with SEP), replies capped at
/// `4 ..= max_new` tokens.  Deterministic in the seed —
/// `rust/tests/coordinator.rs` replays one conversation twice (resumed
/// vs. fresh-prefilled) and pins bit-identical generations.
pub fn multi_turn_chat(rng: &mut Rng, turns: usize, turn_len: usize,
                       max_new: usize) -> Vec<ChatTurn> {
    let lo = (turn_len / 2).max(1);
    (0..turns)
        .map(|t| {
            let len = rng.range(lo, turn_len + 1);
            let mut user = Vec::with_capacity(len + 1);
            user.push(if t == 0 { BOS } else { SEP });
            for _ in 0..len {
                user.push(LM_BASE + rng.below(LM_COUNT) as i32);
            }
            ChatTurn { user, max_new: rng.range(4.min(max_new), max_new + 1) }
        })
        .collect()
}

/// Bursty open-loop arrival process with heavy-tailed prompt lengths —
/// the router stress shape (DESIGN.md §Replication): base
/// exponential inter-arrival gaps at `rate_per_s`, compressed by
/// `burst`× for seeded burst windows of 2–5 requests, prompt lengths
/// Pareto(`alpha`) on `[min_len, max_len]` (LM-task content).  Returns
/// `(arrival_ns, prompt)` pairs with strictly increasing arrivals.
/// Deterministic in the seed.
pub fn bursty_poisson(rng: &mut Rng, n: usize, rate_per_s: f64, burst: f64,
                      alpha: f64, min_len: usize, max_len: usize)
                      -> Vec<(u64, Vec<i32>)> {
    assert!(rate_per_s > 0.0 && burst >= 1.0 && alpha > 0.0);
    assert!(0 < min_len && min_len <= max_len);
    let mut out = Vec::with_capacity(n);
    let mut now_ns = 0u64;
    let mut burst_left = 0usize;
    for _ in 0..n {
        if burst_left == 0 && rng.bool(0.2) {
            burst_left = rng.range(2, 6);
        }
        let mut gap_s = -(1.0 - rng.f64()).ln() / rate_per_s;
        if burst_left > 0 {
            burst_left -= 1;
            gap_s /= burst;
        }
        now_ns += (gap_s * 1e9) as u64 + 1; // +1: strictly increasing
        let len = ((min_len as f64 * (1.0 - rng.f64()).powf(-1.0 / alpha))
            as usize).clamp(min_len, max_len);
        out.push((now_ns, gen_lm(rng, len).0));
    }
    out
}

/// Long-generation "reasoning" workload: short chain-task prompts that
/// each decode for `min_new ..= max_new` tokens — decode-dominated
/// lanes that keep KV resident long enough for the pressure ladder's
/// spill rung to matter (DESIGN.md §Spill-Tier).  Returns
/// `(prompt, max_new)` pairs, deterministic in the seed.
pub fn reasoning_prompts(rng: &mut Rng, n: usize, prompt_len: usize,
                         min_new: usize, max_new: usize) -> Vec<(Vec<i32>, usize)> {
    assert!(0 < min_new && min_new <= max_new);
    (0..n)
        .map(|_| (gen_chain(rng, prompt_len).0, rng.range(min_new, max_new + 1)))
        .collect()
}

/// Exact-state selection (corpus.gen_chain): `n1 n2 n3 EQL max(n1,n2,n3)`.
pub fn gen_chain(rng: &mut Rng, seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    let mut toks = vec![BOS];
    let mut mask = vec![0.0f32];
    while toks.len() + 6 < seq_len {
        let ns: Vec<i32> = (0..3).map(|_| rng.below(NUM_COUNT) as i32).collect();
        for &n in &ns {
            toks.push(NUM_BASE + n);
            mask.push(0.0);
        }
        toks.push(EQL);
        mask.push(ANSWER_WEIGHT);
        toks.push(NUM_BASE + ns.iter().copied().max().unwrap());
        mask.push(0.0);
    }
    toks.push(EOS);
    mask.push(0.0);
    pad(toks, mask, seq_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(Task::Recall, &mut Rng::new(5), 96);
        let b = generate(Task::Recall, &mut Rng::new(5), 96);
        assert_eq!(a, b);
    }

    #[test]
    fn token_ranges() {
        let mut rng = Rng::new(1);
        for task in Task::all() {
            let (toks, mask) = generate(task, &mut rng, 128);
            assert_eq!(toks.len(), 128);
            assert_eq!(mask.len(), 128);
            assert!(toks.iter().all(|&t| t >= 0 && (t as usize) < VOCAB));
        }
    }

    #[test]
    fn recall_answers_consistent() {
        let mut rng = Rng::new(2);
        let (toks, mask) = gen_recall(&mut rng, 96, None, 6);
        let sep = toks.iter().position(|&t| t == SEP).unwrap();
        let mut found = 0;
        for t in 1..toks.len() - 1 {
            if mask[t] > 0.0 {
                assert_eq!(toks[t - 1], QRY);
                let key = toks[t];
                let val = toks[t + 1];
                // every context binding of the key carries the same value
                let mut bound = 0;
                for p in 0..sep {
                    if toks[p] == key {
                        assert_eq!(toks[p + 1], val);
                        bound += 1;
                    }
                }
                assert!(bound >= 1);
                found += 1;
            }
        }
        assert!(found >= 4);
    }

    #[test]
    fn chain_max() {
        let mut rng = Rng::new(3);
        let (toks, mask) = gen_chain(&mut rng, 96);
        for t in 3..toks.len() - 1 {
            if mask[t] > 0.0 {
                assert_eq!(toks[t], EQL);
                let m = (1..=3).map(|i| toks[t - i]).max().unwrap();
                assert_eq!(toks[t + 1], m);
            }
        }
    }

    #[test]
    fn interference_prompts_shapes() {
        let (shorts, long) = interference_prompts(&mut Rng::new(9), 4, 32, 256);
        assert_eq!(shorts.len(), 4);
        assert!(shorts.iter().all(|p| p.len() == 32));
        assert_eq!(long.len(), 256);
        assert!(long.iter().all(|&t| t >= 0 && (t as usize) < VOCAB));
        let (again, long2) = interference_prompts(&mut Rng::new(9), 4, 32, 256);
        assert_eq!((shorts, long), (again, long2), "seed-deterministic");
    }

    #[test]
    fn multi_turn_chat_shape_and_determinism() {
        let turns = multi_turn_chat(&mut Rng::new(21), 5, 24, 16);
        assert_eq!(turns.len(), 5);
        for (t, turn) in turns.iter().enumerate() {
            assert_eq!(turn.user[0], if t == 0 { BOS } else { SEP });
            let body = turn.user.len() - 1;
            assert!((12..=24).contains(&body), "turn body {body} outside band");
            assert!(turn.user[1..].iter()
                        .all(|&x| (LM_BASE..LM_BASE + LM_COUNT as i32).contains(&x)));
            assert!((4..=16).contains(&turn.max_new));
        }
        assert_eq!(turns, multi_turn_chat(&mut Rng::new(21), 5, 24, 16),
                   "seed-deterministic");
    }

    #[test]
    fn bursty_poisson_arrivals_and_tail() {
        let w = bursty_poisson(&mut Rng::new(33), 64, 100.0, 20.0, 1.2, 8, 256);
        assert_eq!(w.len(), 64);
        let times: Vec<u64> = w.iter().map(|&(t, _)| t).collect();
        assert!(times.windows(2).all(|p| p[0] < p[1]), "strictly increasing");
        for (_, p) in &w {
            assert!((8..=256).contains(&p.len()));
            assert!(p.iter().all(|&t| t >= 0 && (t as usize) < VOCAB));
        }
        // heavy tail: Pareto(1.2) puts mass well past min_len
        assert!(w.iter().any(|(_, p)| p.len() >= 16),
                "no prompt reached 2x min_len — tail lost");
        // burstiness: burst windows compress gaps 20x under the base
        // exponential, so the min/max gap spread is far past uniform
        let gaps: Vec<u64> = times.windows(2).map(|p| p[1] - p[0]).collect();
        let (min_g, max_g) = (gaps.iter().min().unwrap(), gaps.iter().max().unwrap());
        assert!(min_g * 4 < *max_g, "gap spread {min_g}..{max_g} too flat");
        assert_eq!(w, bursty_poisson(&mut Rng::new(33), 64, 100.0, 20.0, 1.2, 8, 256),
                   "seed-deterministic");
    }

    #[test]
    fn reasoning_prompts_decode_heavy() {
        let w = reasoning_prompts(&mut Rng::new(44), 64, 32, 48, 96);
        assert_eq!(w.len(), 64);
        for (p, max_new) in &w {
            assert_eq!(p.len(), 32);
            assert!((48..=96).contains(max_new));
            assert!(*max_new > p.len(), "decode-dominated by construction");
        }
        assert!(w.iter().any(|&(_, m)| m >= 72), "upper half of budget unused");
        assert_eq!(w, reasoning_prompts(&mut Rng::new(44), 64, 32, 48, 96),
                   "seed-deterministic");
    }

    #[test]
    fn query_offset_orders_distance() {
        let (t_recent, m_recent) = gen_recall(&mut Rng::new(7), 96, Some(0), 1);
        let (t_old, m_old) = gen_recall(&mut Rng::new(7), 96, Some(10), 1);
        // distance is measured to the key's *last* binding in the context
        let last_binding = |t: &[i32], m: &[f32]| {
            let a = m.iter().position(|&x| x > 0.0).unwrap();
            let key = t[a];
            let sep = t.iter().position(|&x| x == SEP).unwrap();
            t[..sep].iter().rposition(|&x| x == key).unwrap()
        };
        assert!(last_binding(&t_old, &m_old) < last_binding(&t_recent, &m_recent));
    }
}
