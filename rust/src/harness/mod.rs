//! Experiment harness: synthetic workloads (mirroring the training
//! corpus), teacher-forced evaluation, and the per-table/figure
//! reproduction drivers (DESIGN.md §6).

pub mod eval;
pub mod tables;
pub mod workload;

pub use eval::{evaluate, evaluate_all_tasks, EvalCfg, EvalResult};
pub use tables::{ReproCfg};
pub use workload::Task;
