//! Task evaluation harness: teacher-forced scoring of a model + cache
//! policy on the synthetic suites.
//!
//! Every scored prediction is produced by a *decode step over the
//! quantized cache* (the prompt prefix before `prefill_len` is prefilled
//! at full precision and excluded from scoring), so the metrics expose
//! exactly the cache-quantization error the paper's tables measure.

use anyhow::Result;

use crate::baselines::Method;
use crate::harness::workload::{self, Task};
use crate::kvcache::SeqKvCache;
use crate::model::sampler::{argmax, log_prob};
use crate::model::{DecodeScratch, Forward};
use crate::runtime::Runtime;
use crate::util::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct EvalResult {
    pub nll_sum: f64,
    pub weight: f64,
    pub correct: f64,
    pub n_predictions: usize,
    /// total KV bytes (modeled) at end of eval, summed over sequences
    pub kv_bytes: usize,
}

impl EvalResult {
    pub fn ppl(&self) -> f64 {
        (self.nll_sum / self.weight.max(1e-9)).exp()
    }

    pub fn acc(&self) -> f64 {
        self.correct / self.weight.max(1e-9)
    }

    /// Paper-style percentage score (accuracy * 100).
    pub fn score(&self) -> f64 {
        self.acc() * 100.0
    }
}

#[derive(Debug, Clone, Copy)]
pub struct EvalCfg {
    pub n_seqs: usize,
    pub seq_len: usize,
    pub prefill_len: usize,
    pub batch: usize,
    pub seed: u64,
    /// recall-task retrieval distance override (None = random)
    pub query_offset: Option<usize>,
}

impl Default for EvalCfg {
    fn default() -> Self {
        EvalCfg { n_seqs: 16, seq_len: 160, prefill_len: 32, batch: 16,
                  seed: 1234, query_offset: None }
    }
}

/// Score one teacher-forced prediction: `row` is the logits row,
/// `target` the reference token, `w` the mask weight.  Zero-weight
/// positions contribute nothing (they are not counted as predictions).
/// Pure accumulation — the unit under test in this module's `tests`;
/// `evaluate_seqs` drives it once per decode step, and the plan search
/// (`profiler/search.rs`) consumes the resulting [`EvalResult::ppl`].
pub fn score_prediction(result: &mut EvalResult, row: &[f32], target: usize, w: f64) {
    if w <= 0.0 {
        return;
    }
    result.nll_sum += w * -log_prob(row, target);
    result.correct += w * (argmax(row) == target) as u8 as f64;
    result.weight += w;
    result.n_predictions += 1;
}

/// Teacher-forced scoring of one whole sequence from precomputed
/// per-position logits (`vocab`-strided, row `p` = logits after reading
/// `toks[p]`), mirroring the decode loop in [`evaluate_seqs`]: position
/// `p` in `prefill_len .. len-1` scores `toks[p+1]` with weight
/// `mask[p]`.  Empty and length-1 sequences score nothing (there is no
/// next token to predict), as does a prefix covering the whole sequence.
pub fn score_sequence(result: &mut EvalResult, logits: &[f32], vocab: usize,
                      toks: &[i32], mask: &[f32], prefill_len: usize) {
    for p in prefill_len..toks.len().saturating_sub(1) {
        let row = &logits[p * vocab..(p + 1) * vocab];
        score_prediction(result, row, toks[p + 1] as usize, mask[p] as f64);
    }
}

/// Evaluate `method` on `task`; teacher-forced, batched decode.
pub fn evaluate(rt: &Runtime, method: &Method, task: Task, cfg: &EvalCfg)
                -> Result<EvalResult> {
    let mut rng = Rng::new(cfg.seed ^ (task.name().len() as u64) << 7);
    let seqs: Vec<(Vec<i32>, Vec<f32>)> = (0..cfg.n_seqs).map(|_| match task {
        Task::Recall => workload::gen_recall(&mut rng, cfg.seq_len, cfg.query_offset, 6),
        t => workload::generate(t, &mut rng, cfg.seq_len),
    }).collect();
    evaluate_seqs(rt, method, &seqs, cfg)
}

pub fn evaluate_seqs(rt: &Runtime, method: &Method,
                     seqs: &[(Vec<i32>, Vec<f32>)], cfg: &EvalCfg)
                     -> Result<EvalResult> {
    let fwd = Forward::new(rt);
    let vocab = rt.model.vocab;
    let mut result = EvalResult::default();
    let mut scratch = DecodeScratch::default();

    for chunk in seqs.chunks(cfg.batch) {
        // per-sequence prefill of the fixed prefix
        let mut caches: Vec<SeqKvCache> = Vec::with_capacity(chunk.len());
        for (toks, _) in chunk {
            let mut cache = method.make_cache(&rt.model);
            fwd.prefill(&toks[..cfg.prefill_len], &mut cache)?;
            caches.push(cache);
        }
        // teacher-forced batched decode over the rest (saturating_sub:
        // degenerate length-0/1 configs score nothing instead of
        // underflowing)
        for p in cfg.prefill_len..cfg.seq_len.saturating_sub(1) {
            let inputs: Vec<i32> = chunk.iter().map(|(t, _)| t[p]).collect();
            let mut refs: Vec<&mut SeqKvCache> = caches.iter_mut().collect();
            let logits = fwd.decode_step(&inputs, &mut refs, &mut scratch)?;
            for (b, (toks, mask)) in chunk.iter().enumerate() {
                let row = &logits[b * vocab..(b + 1) * vocab];
                score_prediction(&mut result, row, toks[p + 1] as usize,
                                 mask[p] as f64);
            }
        }
        result.kv_bytes += caches.iter().map(|c| c.modeled_bytes()).sum::<usize>();
    }
    Ok(result)
}

/// Average score across the three suites (the tables' "Average" column).
pub fn evaluate_all_tasks(rt: &Runtime, method: &Method, cfg: &EvalCfg)
                          -> Result<Vec<(Task, EvalResult)>> {
    Task::all().iter().map(|&t| Ok((t, evaluate(rt, method, t, cfg)?))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// vocab-2 logit row putting probability 3/4 on token 0:
    /// softmax([ln 3, 0]) = [3/4, 1/4].
    fn row_three_quarters() -> Vec<f32> {
        vec![3.0f32.ln(), 0.0]
    }

    #[test]
    fn closed_form_ppl() {
        // every prediction hits the 3/4 token: ppl = exp(ln(4/3)) = 4/3
        let mut r = EvalResult::default();
        for _ in 0..3 {
            score_prediction(&mut r, &row_three_quarters(), 0, 1.0);
        }
        assert_eq!(r.n_predictions, 3);
        assert!((r.ppl() - 4.0 / 3.0).abs() < 1e-6, "ppl {} != 4/3", r.ppl());
        assert!((r.acc() - 1.0).abs() < 1e-12, "argmax is token 0 every step");
        // the miss direction: target 1 holds 1/4 -> ppl = 4
        let mut miss = EvalResult::default();
        score_prediction(&mut miss, &row_three_quarters(), 1, 1.0);
        assert!((miss.ppl() - 4.0).abs() < 1e-5);
        assert_eq!(miss.score(), 0.0);
    }

    #[test]
    fn weights_scale_the_mean_not_the_count() {
        // same row at weights 1 and 3: ppl unchanged (weighted mean of a
        // constant), weight accumulates, both count as predictions
        let mut r = EvalResult::default();
        score_prediction(&mut r, &row_three_quarters(), 0, 1.0);
        score_prediction(&mut r, &row_three_quarters(), 0, 3.0);
        assert_eq!(r.n_predictions, 2);
        assert!((r.weight - 4.0).abs() < 1e-12);
        assert!((r.ppl() - 4.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_is_a_no_op() {
        let mut r = EvalResult::default();
        score_prediction(&mut r, &row_three_quarters(), 0, 0.0);
        assert_eq!(r.n_predictions, 0);
        assert_eq!(r.weight, 0.0);
        assert_eq!(r.ppl(), 1.0, "no predictions: exp(0 / eps) = 1");
        assert_eq!(r.acc(), 0.0);
    }

    #[test]
    fn sequence_scoring_matches_per_step() {
        let vocab = 2;
        // toks[p+1] scored from row p; mask weights position 2 double
        let toks = [0i32, 0, 1, 0];
        let mask = [1.0f32, 1.0, 2.0, 1.0];
        let logits: Vec<f32> = (0..toks.len()).flat_map(|_| row_three_quarters())
            .collect();
        let mut seq = EvalResult::default();
        score_sequence(&mut seq, &logits, vocab, &toks, &mask, 1);
        let mut step = EvalResult::default();
        score_prediction(&mut step, &row_three_quarters(), 1, 1.0); // p=1 -> toks[2]
        score_prediction(&mut step, &row_three_quarters(), 0, 2.0); // p=2 -> toks[3]
        assert_eq!(seq.n_predictions, step.n_predictions);
        assert!((seq.nll_sum - step.nll_sum).abs() < 1e-9);
        assert!((seq.weight - step.weight).abs() < 1e-12);
        assert!((seq.ppl() - step.ppl()).abs() < 1e-9);
    }

    #[test]
    fn degenerate_sequences_score_nothing() {
        let mut r = EvalResult::default();
        score_sequence(&mut r, &[], 2, &[], &[], 0); // empty
        score_sequence(&mut r, &row_three_quarters(), 2, &[0], &[1.0], 0); // length 1
        let toks = [0i32, 1, 0];
        let mask = [1.0f32; 3];
        let logits: Vec<f32> = (0..3).flat_map(|_| row_three_quarters()).collect();
        score_sequence(&mut r, &logits, 2, &toks, &mask, 2); // prefix covers all
        assert_eq!(r.n_predictions, 0);
        assert_eq!(r.ppl(), 1.0);
    }
}
