//! Task evaluation harness: teacher-forced scoring of a model + cache
//! policy on the synthetic suites.
//!
//! Every scored prediction is produced by a *decode step over the
//! quantized cache* (the prompt prefix before `prefill_len` is prefilled
//! at full precision and excluded from scoring), so the metrics expose
//! exactly the cache-quantization error the paper's tables measure.

use anyhow::Result;

use crate::baselines::Method;
use crate::harness::workload::{self, Task};
use crate::kvcache::SeqKvCache;
use crate::model::sampler::{argmax, log_prob};
use crate::model::{DecodeScratch, Forward};
use crate::runtime::Runtime;
use crate::util::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct EvalResult {
    pub nll_sum: f64,
    pub weight: f64,
    pub correct: f64,
    pub n_predictions: usize,
    /// total KV bytes (modeled) at end of eval, summed over sequences
    pub kv_bytes: usize,
}

impl EvalResult {
    pub fn ppl(&self) -> f64 {
        (self.nll_sum / self.weight.max(1e-9)).exp()
    }

    pub fn acc(&self) -> f64 {
        self.correct / self.weight.max(1e-9)
    }

    /// Paper-style percentage score (accuracy * 100).
    pub fn score(&self) -> f64 {
        self.acc() * 100.0
    }
}

#[derive(Debug, Clone, Copy)]
pub struct EvalCfg {
    pub n_seqs: usize,
    pub seq_len: usize,
    pub prefill_len: usize,
    pub batch: usize,
    pub seed: u64,
    /// recall-task retrieval distance override (None = random)
    pub query_offset: Option<usize>,
}

impl Default for EvalCfg {
    fn default() -> Self {
        EvalCfg { n_seqs: 16, seq_len: 160, prefill_len: 32, batch: 16,
                  seed: 1234, query_offset: None }
    }
}

/// Evaluate `method` on `task`; teacher-forced, batched decode.
pub fn evaluate(rt: &Runtime, method: &Method, task: Task, cfg: &EvalCfg)
                -> Result<EvalResult> {
    let mut rng = Rng::new(cfg.seed ^ (task.name().len() as u64) << 7);
    let seqs: Vec<(Vec<i32>, Vec<f32>)> = (0..cfg.n_seqs).map(|_| match task {
        Task::Recall => workload::gen_recall(&mut rng, cfg.seq_len, cfg.query_offset, 6),
        t => workload::generate(t, &mut rng, cfg.seq_len),
    }).collect();
    evaluate_seqs(rt, method, &seqs, cfg)
}

pub fn evaluate_seqs(rt: &Runtime, method: &Method,
                     seqs: &[(Vec<i32>, Vec<f32>)], cfg: &EvalCfg)
                     -> Result<EvalResult> {
    let fwd = Forward::new(rt);
    let vocab = rt.model.vocab;
    let mut result = EvalResult::default();
    let mut scratch = DecodeScratch::default();

    for chunk in seqs.chunks(cfg.batch) {
        // per-sequence prefill of the fixed prefix
        let mut caches: Vec<SeqKvCache> = Vec::with_capacity(chunk.len());
        for (toks, _) in chunk {
            let mut cache = method.make_cache(&rt.model);
            fwd.prefill(&toks[..cfg.prefill_len], &mut cache)?;
            caches.push(cache);
        }
        // teacher-forced batched decode over the rest
        for p in cfg.prefill_len..cfg.seq_len - 1 {
            let inputs: Vec<i32> = chunk.iter().map(|(t, _)| t[p]).collect();
            let mut refs: Vec<&mut SeqKvCache> = caches.iter_mut().collect();
            let logits = fwd.decode_step(&inputs, &mut refs, &mut scratch)?;
            for (b, (toks, mask)) in chunk.iter().enumerate() {
                let w = mask[p] as f64;
                if w > 0.0 {
                    let row = &logits[b * vocab..(b + 1) * vocab];
                    let target = toks[p + 1] as usize;
                    result.nll_sum += w * -log_prob(row, target);
                    result.correct += w * (argmax(row) == target) as u8 as f64;
                    result.weight += w;
                    result.n_predictions += 1;
                }
            }
        }
        result.kv_bytes += caches.iter().map(|c| c.modeled_bytes()).sum::<usize>();
    }
    Ok(result)
}

/// Average score across the three suites (the tables' "Average" column).
pub fn evaluate_all_tasks(rt: &Runtime, method: &Method, cfg: &EvalCfg)
                          -> Result<Vec<(Task, EvalResult)>> {
    Task::all().iter().map(|&t| Ok((t, evaluate(rt, method, t, cfg)?))).collect()
}
