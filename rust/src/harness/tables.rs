//! Reproduction harness for every table and figure in the paper's
//! evaluation (see DESIGN.md §6 for the experiment index).  Each function
//! prints the same rows/series the paper reports, measured on the
//! reproduction stack.  Invoked via `kvmix repro <id>`.

use anyhow::Result;

use crate::baselines::Method;
use crate::config::QuantPlan;
use crate::coordinator::{Engine, EngineCfg, Request};
use crate::harness::eval::{evaluate, evaluate_all_tasks, EvalCfg, EvalResult};
use crate::harness::workload::{self, Task};
use crate::kvcache::fp16_kv_bytes;
use crate::model::Sampler;
use crate::profiler::{self, search};
use crate::runtime::Runtime;
use crate::util::Rng;

/// Common knobs for the repro harness.
#[derive(Debug, Clone, Copy)]
pub struct ReproCfg {
    pub n_seqs: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_profile_prompts: usize,
    pub high_frac: f64,
    pub seed: u64,
    /// simulated HBM budget for fig8 (bytes of KV)
    pub hbm_bytes: usize,
}

impl Default for ReproCfg {
    fn default() -> Self {
        ReproCfg { n_seqs: 12, seq_len: 160, batch: 12, n_profile_prompts: 16,
                   high_frac: 0.25, seed: 42, hbm_bytes: 0 }
    }
}

impl ReproCfg {
    pub fn fast() -> Self {
        ReproCfg { n_seqs: 6, seq_len: 96, batch: 6, n_profile_prompts: 6, ..Default::default() }
    }

    fn eval_cfg(&self) -> EvalCfg {
        EvalCfg { n_seqs: self.n_seqs, seq_len: self.seq_len, prefill_len: 32,
                  batch: self.batch, seed: self.seed ^ 0x5EED, query_offset: None }
    }
}

fn profiled_plan(rt: &Runtime, cfg: &ReproCfg) -> Result<(profiler::Importance, QuantPlan)> {
    let imp = profiler::profile(rt, cfg.n_profile_prompts, cfg.seed)?;
    let plan = profiler::allocate(&imp, cfg.high_frac);
    Ok((imp, plan))
}

fn print_task_header() {
    println!("{:<28} {:>9} {:>9} {:>9} {:>9} {:>10} {:>9}",
             "method", "lm_ppl", "lm_acc%", "recall%", "chain%", "avg%", "kv_MiB");
}

fn print_task_row(name: &str, rows: &[(Task, EvalResult)]) {
    let get = |t: Task| rows.iter().find(|(x, _)| *x == t).map(|(_, r)| r).unwrap();
    let lm = get(Task::Lm);
    let rec = get(Task::Recall);
    let ch = get(Task::Chain);
    let avg = (lm.score() + rec.score() + ch.score()) / 3.0;
    let kv: usize = rows.iter().map(|(_, r)| r.kv_bytes).sum();
    println!("{:<28} {:>9.3} {:>9.2} {:>9.2} {:>9.2} {:>10.2} {:>9.3}",
             name, lm.ppl(), lm.score(), rec.score(), ch.score(), avg,
             kv as f64 / (1 << 20) as f64);
}

// ---------------------------------------------------------------------------
// Fig 1 — motivation: quantizing different layers hurts differently
// ---------------------------------------------------------------------------
pub fn fig1(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    // The reproduction model is robust to single-layer 2-bit per-channel
    // quantization (group 32), so the motivation study stresses with
    // 1-bit — same qualitative question: which layer's K/V hurts most?
    println!("# Fig 1 — per-layer 1-bit quantization impact");
    println!("{:<18} {:>10} {:>10} {:>10}", "quantized", "lm_ppl", "lm_acc%", "chain%");
    let ecfg = cfg.eval_cfg();
    let base_lm = evaluate(rt, &Method::Fp16, Task::Lm, &ecfg)?;
    let base_ch = evaluate(rt, &Method::Fp16, Task::Chain, &ecfg)?;
    println!("{:<18} {:>10.3} {:>10.2} {:>10.2}", "FP16 (none)",
             base_lm.ppl(), base_lm.score(), base_ch.score());
    let l = rt.model.n_layers;
    for side in ["K", "V"] {
        for i in 0..l {
            let mut plan = QuantPlan::fp16(l);
            if side == "K" {
                plan.k_bits[i] = 1;
                plan.k_rpc[i] = 0.0;
            } else {
                plan.v_bits[i] = 1;
                plan.v_rpc[i] = 0.0;
            }
            plan.name = format!("{side}{i}-1bit");
            let lm = evaluate(rt, &Method::Kvmix(plan.clone()), Task::Lm, &ecfg)?;
            let ch = evaluate(rt, &Method::Kvmix(plan), Task::Chain, &ecfg)?;
            println!("{:<18} {:>10.3} {:>10.2} {:>10.2}", format!("{side} layer {i}"),
                     lm.ppl(), lm.score(), ch.score());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 2 / Fig 9 — W_k / W_v norms and ranges per layer
// ---------------------------------------------------------------------------
pub fn fig2(rt: &Runtime, _cfg: &ReproCfg) -> Result<()> {
    println!("# Fig 2/9 — K/V projection weight statistics per layer");
    println!("{:<6} {:>12} {:>12} {:>12} {:>12}", "layer", "|Wk|2", "range(Wk)", "|Wv|2", "range(Wv)");
    for i in 0..rt.model.n_layers {
        let wk = rt.weights.layer(i, "wk")?;
        let wv = rt.weights.layer(i, "wv")?;
        let stats = |d: &[f32]| {
            let norm = (d.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()).sqrt();
            let mn = d.iter().cloned().fold(f32::INFINITY, f32::min);
            let mx = d.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            (norm, (mx - mn) as f64)
        };
        let (kn, kr) = stats(&wk.data);
        let (vn, vr) = stats(&wv.data);
        println!("{:<6} {:>12.4} {:>12.4} {:>12.4} {:>12.4}", i, kn, kr, vn, vr);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 4 — RPC dynamics during prefill + decode
// ---------------------------------------------------------------------------
pub fn fig4(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Fig 4 — dynamic RPC window during decode (layer 0)");
    let (_, plan) = profiled_plan(rt, cfg)?;
    let method = Method::Kvmix(plan);
    let mut cache = method.make_cache(&rt.model);
    let fwd = crate::model::Forward::new(rt);
    let mut rng = Rng::new(cfg.seed);
    let (toks, _) = workload::generate(Task::Lm, &mut rng, 64);
    fwd.prefill(&toks[..32], &mut cache)?;
    println!("{:>6} {:>10} {:>12} {:>12} {:>12}", "step", "total", "k_fp(RPC)", "k_quantized", "kv_KiB");
    let mut scratch = crate::model::DecodeScratch::default();
    let mut input = toks[32];
    for step in 0..96 {
        let l0 = &cache.layers[0];
        if step % 8 == 0 {
            println!("{:>6} {:>10} {:>12} {:>12} {:>12.2}", step, l0.len(),
                     l0.k_fp_tokens(), l0.k_hist,
                     cache.modeled_bytes() as f64 / 1024.0);
        }
        let mut refs = vec![&mut cache];
        let logits = fwd.decode_step(&[input], &mut refs, &mut scratch)?;
        input = crate::model::sampler::argmax(&logits[..rt.model.vocab]) as i32;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 5 — accuracy / memory / throughput vs % high-bit layers
// ---------------------------------------------------------------------------
pub fn fig5(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Fig 5 — sweep of high-bit layer fraction");
    let imp = profiler::profile(rt, cfg.n_profile_prompts, cfg.seed)?;
    println!("{:<8} {:>12} {:>9} {:>9} {:>12} {:>12}",
             "frac", "plan", "recall%", "chain%", "kv_MiB", "tok/s");
    let ecfg = cfg.eval_cfg();
    for pct in [0.0, 0.125, 0.25, 0.375, 0.5, 0.75, 1.0] {
        let plan = profiler::allocate(&imp, pct);
        let m = Method::Kvmix(plan.clone());
        let rec = evaluate(rt, &m, Task::Recall, &ecfg)?;
        let ch = evaluate(rt, &m, Task::Chain, &ecfg)?;
        let thr = quick_throughput(rt, &m, 8, 48, 32)?;
        println!("{:<8.3} {:>12} {:>9.2} {:>9.2} {:>12.3} {:>12.1}",
                 pct, plan.name, rec.score(), ch.score(),
                 (rec.kv_bytes + ch.kv_bytes) as f64 / (1 << 20) as f64, thr);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 6 / Fig 12 — detailed per-layer plan from the profiler
// ---------------------------------------------------------------------------
pub fn fig6(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Fig 6 — KVmix profiler plan (high_frac={})", cfg.high_frac);
    let (imp, plan) = profiled_plan(rt, cfg)?;
    print!("{}", profiler::plan_report(&imp, &plan));
    println!("\n# Fig 12 variant — high_frac=0.375 (paper's 30% config)");
    let plan30 = profiler::allocate(&imp, 0.375);
    print!("{}", profiler::plan_report(&imp, &plan30));
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 7 — peak KV memory by method (fixed batch)
// ---------------------------------------------------------------------------
pub fn fig7(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Fig 7 — peak KV memory during inference (batch=4, prompt 64, gen 192)");
    let (imp, plan) = profiled_plan(rt, cfg)?;
    println!("{:<22} {:>12} {:>12} {:>10} {:>9} {:>9} {:>9} {:>9}",
             "method", "peak_kv_KiB", "vs FP16", "tok/s",
             "ttft_p50", "ttft_p99", "tbt_p50", "tbt_p99");
    let mut fp16_peak = 0f64;
    for method in Method::comparison_set(&plan) {
        let s = run_serving(rt, &method, 4, 64, 192, None, 0)?;
        let kib = s.peak_kv_bytes as f64 / 1024.0;
        if matches!(method, Method::Fp16) {
            fp16_peak = kib;
        }
        println!("{:<22} {:>12.2} {:>11.2}x {:>10.1} {:>9.1} {:>9.1} {:>9.2} {:>9.2}",
                 method.name(), kib, fp16_peak / kib.max(1e-9), s.tok_per_s,
                 s.ttft_p50_ms, s.ttft_p99_ms, s.tbt_p50_ms, s.tbt_p99_ms);
    }
    // iteration-level scheduling row (DESIGN.md §Scheduler): the same
    // kvmix workload under a chunked step budget — memory is unchanged,
    // the serving-latency columns are what move (late admissions stop
    // waiting behind whole-batch inline prefills)
    let step = 2 * rt.model.group;
    let s = run_serving_chunked(rt, &Method::Kvmix(plan), 4, 64, 192, None, 0, step)?;
    let kib = s.peak_kv_bytes as f64 / 1024.0;
    println!("{:<22} {:>12.2} {:>11.2}x {:>10.1} {:>9.1} {:>9.1} {:>9.2} {:>9.2}",
             format!("kvmix +step{step}"), kib, fp16_peak / kib.max(1e-9),
             s.tok_per_s, s.ttft_p50_ms, s.ttft_p99_ms, s.tbt_p50_ms, s.tbt_p99_ms);

    // asymmetric allocation rows
    // (docs/adr/007-asymmetric-bit-allocation.md): a searched per-layer
    // (k_bits, v_bits) plan against the symmetric 2-bit ladder at the
    // same modeled byte budget.  The symmetric plan is itself a search
    // candidate (low=2, no high tier, same RPC), so the searched row can
    // only match or beat it on measured perplexity.
    let (kv_dim, group) = (rt.model.kv_dim(), rt.model.group);
    let ecfg = cfg.eval_cfg();
    let symmetric = QuantPlan::uniform(rt.model.n_layers, 2);
    let sym_bytes = search::plan_bytes_per_token(&symmetric, kv_dim, group);
    let sym_ppl = evaluate(rt, &Method::Kvmix(symmetric.clone()), Task::Lm, &ecfg)?.ppl();
    let scfg = search::SearchCfg { seed: cfg.seed, ..search::SearchCfg::coarse() };
    let res = search::search_plans_with_budget(
        &imp, &scfg, kv_dim, group, sym_bytes,
        &mut |p| Ok(evaluate(rt, &Method::Kvmix(p.clone()), Task::Lm, &ecfg)?.ppl()))?;
    println!();
    println!("asymmetric plan search at equal modeled bytes (budget {sym_bytes:.1} B/token):");
    println!("{:<24} {:>12} {:>10} {:>6} {:>6}",
             "plan", "bytes/token", "lm_ppl", "avg K", "avg V");
    println!("{:<24} {:>12.1} {:>10.3} {:>6.2} {:>6.2}",
             format!("{} (symmetric)", symmetric.name), sym_bytes, sym_ppl,
             symmetric.avg_k_bits(), symmetric.avg_v_bits());
    if let Some(best) = res.best() {
        println!("{:<24} {:>12.1} {:>10.3} {:>6.2} {:>6.2}",
                 best.plan.name, best.bytes_per_token, best.ppl,
                 best.plan.avg_k_bits(), best.plan.avg_v_bits());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 8 — throughput vs batch size under a simulated HBM budget
// ---------------------------------------------------------------------------
pub fn fig8(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    // budget default: fp16 OOMs between batch 4 and 8 at this workload
    let prompt_len = 64;
    let gen = 192;
    let budget = if cfg.hbm_bytes > 0 {
        cfg.hbm_bytes
    } else {
        6 * fp16_kv_bytes(prompt_len + gen, rt.model.kv_dim(), rt.model.n_layers)
    };
    println!("# Fig 8 — throughput vs batch size (simulated HBM budget {:.1} KiB)",
             budget as f64 / 1024.0);
    let (_, plan) = profiled_plan(rt, cfg)?;
    print!("{:<22}", "method");
    let batches = [1usize, 2, 4, 8, 16, 32];
    for b in batches {
        print!(" {:>9}", format!("b={b}"));
    }
    println!();
    for method in Method::comparison_set(&plan) {
        print!("{:<22}", method.name());
        for b in batches {
            match run_serving(rt, &method, b, prompt_len, gen, Some(budget), 0) {
                Ok(s) => print!(" {:>9.1}", s.tok_per_s),
                Err(_) => print!(" {:>9}", "OOM"),
            }
        }
        println!();
    }
    println!("(OOM = the batch could not be admitted within the budget)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 10 — profiler robustness across prompt sets
// ---------------------------------------------------------------------------
pub fn fig10(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Fig 10 — profiler consistency across prompt sources");
    let n = cfg.n_profile_prompts;
    let base = profiler::profile(rt, n, cfg.seed)?;
    let variants: Vec<(String, profiler::Importance)> = vec![
        (format!("mixture seed+1 (n={n})"), profiler::profile(rt, n, cfg.seed + 1)?),
        (format!("mixture n={}", n / 2), profiler::profile(rt, n / 2, cfg.seed + 2)?),
        ("recall-only".into(), profiler::profile_task(rt, Task::Recall, n, cfg.seed + 3)?),
        ("lm-only".into(), profiler::profile_task(rt, Task::Lm, n, cfg.seed + 4)?),
        ("chain-only".into(), profiler::profile_task(rt, Task::Chain, n, cfg.seed + 5)?),
    ];
    println!("{:<26} {:>12} {:>12} {:>14}", "prompt set", "rank_corr_K", "rank_corr_V", "same high-bit K");
    let base_plan = profiler::allocate(&base, cfg.high_frac);
    for (name, imp) in &variants {
        let ck = profiler::rank_correlation(&base.k, &imp.k);
        let cv = profiler::rank_correlation(&base.v, &imp.v);
        let plan = profiler::allocate(imp, cfg.high_frac);
        let same = plan.k_bits.iter().zip(&base_plan.k_bits)
            .filter(|(a, b)| a == b).count();
        println!("{:<26} {:>12.3} {:>12.3} {:>11}/{}", name, ck, cv, same,
                 plan.k_bits.len());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 11 / Table 4 — RPC ratio sweep
// ---------------------------------------------------------------------------
pub fn table4(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Table 4 / Fig 11 — RPC ratio ablation on kvmix plan");
    let (_, plan) = profiled_plan(rt, cfg)?;
    let ecfg = cfg.eval_cfg();
    print_task_header();
    let fp_rows = evaluate_all_tasks(rt, &Method::Fp16, &ecfg)?;
    print_task_row("FP16", &fp_rows);
    let fp16_kv: usize = fp_rows.iter().map(|(_, r)| r.kv_bytes).sum();
    for (name, hi, lo) in [("w/oRPC", 0.0, 0.0), ("10%/0%", 0.1, 0.0),
                           ("10%/10%", 0.1, 0.1), ("20%/10%", 0.2, 0.1),
                           ("20%/20%", 0.2, 0.2), ("30%/30%", 0.3, 0.3),
                           ("50%/50%", 0.5, 0.5)] {
        let p = if name == "w/oRPC" { plan.without_rpc() } else { plan.with_rpc(hi, lo) };
        let rows = evaluate_all_tasks(rt, &Method::Kvmix(p), &ecfg)?;
        print_task_row(name, &rows);
        let kv: usize = rows.iter().map(|(_, r)| r.kv_bytes).sum();
        println!("{:<28} compression vs fp16: {:.2}x", "", fp16_kv as f64 / kv as f64);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 — ablations of the importance-aware allocation
// ---------------------------------------------------------------------------
pub fn table1(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Table 1 — quantization configurations (suite scores)");
    let (_, plan) = profiled_plan(rt, cfg)?;
    let n = rt.model.n_layers;
    let n_high = plan.k_bits.iter().filter(|&&b| b > 2).count();
    let methods = vec![
        Method::Fp16,
        Method::Kvmix(QuantPlan::uniform(n, 2)),
        Method::Kvmix(QuantPlan::random_highbit(n, n_high, cfg.seed + 9)),
        Method::Kvmix(plan.without_rpc()),
        Method::Kvmix(plan.clone()),
    ];
    let ecfg = cfg.eval_cfg();
    print_task_header();
    for m in methods {
        let rows = evaluate_all_tasks(rt, &m, &ecfg)?;
        print_task_row(&m.name(), &rows);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2 — method comparison
// ---------------------------------------------------------------------------
pub fn table2(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Table 2 — SOTA method comparison (suite scores)");
    let (imp, plan) = profiled_plan(rt, cfg)?;
    let mut methods = Method::comparison_set(&plan);
    // the paper's kvmix-k2.28v2.56: high-bit fraction raised to 30%
    methods.push(Method::Kvmix(profiler::allocate(&imp, 0.375)));
    let ecfg = cfg.eval_cfg();
    print_task_header();
    for m in methods {
        let rows = evaluate_all_tasks(rt, &m, &ecfg)?;
        print_task_row(&m.name(), &rows);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 3 — GSM8K-analog accuracy + Wikitext-analog perplexity
// ---------------------------------------------------------------------------
pub fn table3(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Table 3 — chain accuracy (GSM8K analog) + lm perplexity (Wikitext analog)");
    let (_, plan) = profiled_plan(rt, cfg)?;
    let n = rt.model.n_layers;
    let n_high = plan.k_bits.iter().filter(|&&b| b > 2).count();
    let methods = vec![
        Method::Fp16,
        Method::UniformPerToken { bits: 2 },
        Method::UniformPerToken { bits: 4 },
        Method::Kvmix(QuantPlan::uniform(n, 2)),
        Method::Kvmix(QuantPlan::random_highbit(n, n_high, cfg.seed + 9)),
        Method::Atom { bits: 4 },
        Method::Kivi { bits: 2, residual: 64 },
        Method::Qjl { jl_dim_mult: 4, v_bits: 3 },
        Method::KvQuant { bits: 3, outlier_frac: 0.01 },
        Method::Kvmix(plan),
    ];
    println!("{:<28} {:>12} {:>14}", "method", "chain_acc%", "lm_ppl");
    let ecfg = cfg.eval_cfg();
    for m in methods {
        let ch = evaluate(rt, &m, Task::Chain, &ecfg)?;
        let lm = evaluate(rt, &m, Task::Lm, &ecfg)?;
        println!("{:<28} {:>12.2} {:>14.4}", m.name(), ch.score(), lm.ppl());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 5 — extended configurations
// ---------------------------------------------------------------------------
pub fn table5(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Table 5 — extended KVmix configurations");
    let (imp, plan) = profiled_plan(rt, cfg)?;
    let n = rt.model.n_layers;
    let n_high = plan.k_bits.iter().filter(|&&b| b > 2).count();
    let methods = vec![
        Method::Fp16,
        Method::Kvmix(QuantPlan::uniform(n, 4)),
        Method::Kvmix(QuantPlan::uniform(n, 2)),
        Method::Kvmix(QuantPlan::random_highbit(n, n_high, cfg.seed + 9)),
        Method::Kvmix(plan),
        Method::Kvmix(profiler::allocate(&imp, 0.375)),
    ];
    let ecfg = cfg.eval_cfg();
    print_task_header();
    for m in methods {
        let rows = evaluate_all_tasks(rt, &m, &ecfg)?;
        print_task_row(&m.name(), &rows);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Headline — the 4.9x memory / 5.3x throughput summary
// ---------------------------------------------------------------------------
pub fn headline(rt: &Runtime, cfg: &ReproCfg) -> Result<()> {
    println!("# Headline — memory compression + throughput gain vs FP16");
    let (_, plan) = profiled_plan(rt, cfg)?;
    let prompt_len = 64;
    let gen = 192;
    let fp_peak = run_serving(rt, &Method::Fp16, 4, prompt_len, gen, None, 0)?.peak_kv_bytes;
    let kv_peak = run_serving(rt, &Method::Kvmix(plan.clone()), 4, prompt_len, gen, None, 0)?
        .peak_kv_bytes;
    println!("KV memory (batch 4): fp16 {:.1} KiB -> kvmix {:.1} KiB = {:.2}x compression",
             fp_peak as f64 / 1024.0, kv_peak as f64 / 1024.0,
             fp_peak as f64 / kv_peak as f64);
    let budget = 6 * fp16_kv_bytes(prompt_len + gen, rt.model.kv_dim(), rt.model.n_layers);
    let mut best_fp = 0f64;
    let mut best_kv = 0f64;
    for b in [1usize, 2, 4, 8, 16, 32] {
        if let Ok(s) = run_serving(rt, &Method::Fp16, b, prompt_len, gen, Some(budget), 0) {
            best_fp = best_fp.max(s.tok_per_s);
        }
        if let Ok(s) = run_serving(rt, &Method::Kvmix(plan.clone()), b, prompt_len, gen,
                                   Some(budget), 0) {
            best_kv = best_kv.max(s.tok_per_s);
        }
    }
    println!("max throughput within budget: fp16 {best_fp:.1} tok/s -> kvmix {best_kv:.1} tok/s = {:.2}x",
             best_kv / best_fp.max(1e-9));
    println!("(paper on Llama-2-7B/RTX4090: 4.9x memory, 5.3x throughput)");
    Ok(())
}

// ---------------------------------------------------------------------------
// shared serving runners
// ---------------------------------------------------------------------------

/// Outcome of one [`run_serving`] / [`run_serving_prefixed`] pass.
#[derive(Debug, Clone, Copy)]
pub struct ServingStats {
    /// peak KV footprint — page-granular when `page_tokens > 0`
    pub peak_kv_bytes: usize,
    pub tok_per_s: f64,
    /// time-to-first-token quantiles over the run (ms)
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    /// time-between-tokens quantiles over the run (ms) — the serving
    /// latency chunked prefill protects (DESIGN.md §Scheduler)
    pub tbt_p50_ms: f64,
    pub tbt_p99_ms: f64,
    /// pressure-controller downshifts (paged mode only)
    pub pages_requantized: usize,
    /// preemptions after the downshift floors were exhausted (paged mode)
    pub preemptions: usize,
    /// prefix-cache adoptions (`--prefix-cache` runs only)
    pub prefix_hits: usize,
    /// prompt tokens whose quantized pages were adopted, not re-encoded
    pub prefix_tokens_reused: usize,
    /// copy-on-write splits on shared pages
    pub cow_splits: usize,
}

/// Serve `batch` synthetic requests to completion and report peak
/// memory + throughput.  `page_tokens > 0` runs the paged KV pool with
/// the downshift-then-preempt pressure controller; 0 keeps the
/// monolithic accounting, whose simulated OOM counts as failure here.
pub fn run_serving(rt: &Runtime, method: &Method, batch: usize, prompt_len: usize,
                   gen: usize, kv_budget: Option<usize>, page_tokens: usize)
                   -> Result<ServingStats> {
    run_serving_chunked(rt, method, batch, prompt_len, gen, kv_budget, page_tokens, 0)
}

/// [`run_serving`] under an iteration-level `--step-tokens` budget
/// (DESIGN.md §Scheduler): prompts prefill in group-aligned chunks
/// interleaved with decode instead of whole-prompt-at-admission.
/// `step_tokens == 0` is exactly [`run_serving`].
#[allow(clippy::too_many_arguments)]
pub fn run_serving_chunked(rt: &Runtime, method: &Method, batch: usize,
                           prompt_len: usize, gen: usize, kv_budget: Option<usize>,
                           page_tokens: usize, step_tokens: usize)
                           -> Result<ServingStats> {
    let mut rng = Rng::new(123);
    let reqs = (0..batch).map(|id| {
        let (toks, _) = workload::sample_mixture(&mut rng, prompt_len);
        Request { id: id as u64, prompt: toks, max_new_tokens: gen,
                  sampler: Sampler::Greedy, stop_token: None, priority: 0,
                  deadline_ms: None, submitted_ns: 0, session: None }
    }).collect();
    serve_requests_scheduled(rt, method, batch, reqs, kv_budget, page_tokens,
                             false, step_tokens)
}

/// [`run_serving`] over a workload whose prompts all share one
/// `shared_len`-token prefix (a common system prompt) followed by a
/// per-request `suffix_len`-token tail — the shared-prefix serving shape
/// (DESIGN.md §Prefix-Sharing).  `prefix_cache` toggles `--prefix-cache`
/// so on/off rows measure the deduplication directly.
pub fn run_serving_prefixed(rt: &Runtime, method: &Method, batch: usize,
                            shared_len: usize, suffix_len: usize, gen: usize,
                            kv_budget: Option<usize>, page_tokens: usize,
                            prefix_cache: bool) -> Result<ServingStats> {
    let mut rng = Rng::new(123);
    let (system, _) = workload::sample_mixture(&mut rng, shared_len);
    let reqs = (0..batch).map(|id| {
        let (tail, _) = workload::sample_mixture(&mut rng, suffix_len);
        let mut prompt = system.clone();
        prompt.extend_from_slice(&tail);
        Request { id: id as u64, prompt, max_new_tokens: gen,
                  sampler: Sampler::Greedy, stop_token: None, priority: 0,
                  deadline_ms: None, submitted_ns: 0, session: None }
    }).collect();
    serve_requests(rt, method, batch, reqs, kv_budget, page_tokens, prefix_cache)
}

fn serve_requests(rt: &Runtime, method: &Method, batch: usize, reqs: Vec<Request>,
                  kv_budget: Option<usize>, page_tokens: usize,
                  prefix_cache: bool) -> Result<ServingStats> {
    serve_requests_scheduled(rt, method, batch, reqs, kv_budget, page_tokens,
                             prefix_cache, 0)
}

/// [`serve_requests`] with an explicit `--step-tokens` budget — the
/// chunked-prefill serving runner (DESIGN.md §Scheduler).  All requests
/// are submitted up front; mid-stream arrival staging lives in the
/// long-prompt-interference bench (`rust/benches/e2e_decode.rs`).
#[allow(clippy::too_many_arguments)]
fn serve_requests_scheduled(rt: &Runtime, method: &Method, batch: usize,
                            reqs: Vec<Request>, kv_budget: Option<usize>,
                            page_tokens: usize, prefix_cache: bool,
                            step_tokens: usize) -> Result<ServingStats> {
    let mut engine = Engine::new(rt, EngineCfg {
        method: method.clone(), max_batch: batch, kv_budget, threads: 1, page_tokens,
        prefix_cache, step_tokens,
        pressure_weights: None, spill_dir: None, spill_bytes: 0,
    })?;
    let n = reqs.len();
    for req in reqs {
        engine.submit(req);
    }
    let t0 = std::time::Instant::now();
    let done = engine.run_to_completion()?;
    let secs = t0.elapsed().as_secs_f64();
    if done.len() < n || engine.metrics.oom_events > 0 {
        anyhow::bail!("OOM: {}/{} completed, {} oom events", done.len(), n,
                      engine.metrics.oom_events);
    }
    let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
    Ok(ServingStats {
        peak_kv_bytes: engine.metrics.peak_kv_bytes,
        tok_per_s: tokens as f64 / secs,
        ttft_p50_ms: engine.metrics.ttft_ms.quantile(0.5),
        ttft_p99_ms: engine.metrics.ttft_ms.quantile(0.99),
        tbt_p50_ms: engine.metrics.tbt_ms.quantile(0.5),
        tbt_p99_ms: engine.metrics.tbt_ms.quantile(0.99),
        pages_requantized: engine.metrics.pages_requantized,
        preemptions: engine.metrics.preemptions,
        prefix_hits: engine.metrics.prefix_hits,
        prefix_tokens_reused: engine.metrics.prefix_tokens_reused,
        cow_splits: engine.metrics.cow_splits,
    })
}

fn quick_throughput(rt: &Runtime, method: &Method, batch: usize,
                    prompt_len: usize, gen: usize) -> Result<f64> {
    Ok(run_serving(rt, method, batch, prompt_len, gen, None, 0)?.tok_per_s)
}
