//! `kvmix` CLI — the L3 leader entrypoint (full reference: README.md).
//!
//! Subcommands:
//!   generate  --prompt 1,2,3 --max-new 32 [--method kvmix|fp16|kivi|...]
//!             [--threads N] [--page-tokens N] [--prefix-cache]
//!             [--step-tokens N]
//!   serve     --addr 127.0.0.1:7979 [--method ...] [--max-batch N]
//!             [--kv-budget-kib K] [--threads N] [--page-tokens N]
//!             [--prefix-cache] [--step-tokens N] [--admit-queue N]
//!             [--legacy-proto] [--replicas N] [--spill-dir DIR]
//!             [--spill-bytes B] [--max-requests N]
//!   profile   [--prompts N] [--high-frac F]      run the KVmix profiler
//!             [--plan-search] [--budget-frac F] [--plan-out FILE]
//!   plan-search  [--budget-frac F] [--plan-out FILE] [--prompts N]
//!             [--seed N] [--synthetic-layers N] [--check FILE]
//!             offline Pareto plan search (README.md §Plan search)
//!   repro     <fig1|fig2|fig4|fig5|fig6|fig7|fig8|fig10|table1..table5|headline|all>
//!   inspect                                       artifact + weight summary
//!
//! Global flags: --artifacts DIR, --fast (smaller repro workloads).
//! --threads N sizes the decode attention worker pool (0 = one per core,
//! default 1 = sequential); results are bit-identical for any N.
//! --page-tokens N enables the paged KV pool with N-token pages (a
//! multiple of the quant group; 0 = monolithic accounting, the default)
//! and with it the downshift-then-preempt pressure controller
//! (DESIGN.md §Memory-Manager).
//! --prefix-cache (requires --page-tokens) deduplicates whole-page
//! prompt prefixes across sequences as refcounted copy-on-write frames;
//! generated tokens stay bit-identical on hits
//! (DESIGN.md §Prefix-Sharing).
//! --step-tokens N enables the iteration-level scheduler's per-step
//! token budget: prompts prefill in group-aligned chunks interleaved
//! with decode (decode-first), so one long arrival cannot stall running
//! sequences (DESIGN.md §Scheduler).  0 (the default) keeps the legacy
//! whole-prefill-at-admission behavior bit-for-bit.
//! --admit-queue N (serve; default 32) bounds the admission pipeline:
//! both the socket→engine channel and the waiting-queue gate — beyond
//! it requests are load-shed with a retry_after_ms rejection frame
//! (DESIGN.md §Serving-Protocol).
//! --legacy-proto (serve) speaks the deprecated pre-PR-7 `GEN`/`OK`
//! line protocol instead of the streaming NDJSON one.
//! --replicas N (serve; default 1) runs N independent engine replicas
//! behind the prefix-affinity router (DESIGN.md §Replication); 1 keeps
//! the single-engine path bit-for-bit.
//! --spill-dir DIR (serve/generate; requires --page-tokens) gives the
//! pressure ladder a disk spill rung between prefix eviction and
//! preemption: sealed cold pages serialize to a file tier and fault
//! back on demand (DESIGN.md §Spill-Tier).  --spill-bytes B caps live
//! spilled bytes per replica (0 = unlimited).
//! --max-requests N (serve) exits cleanly after N terminal frames —
//! what scripted smokes (CI's router+spill step) and drain-style
//! restarts use; unset = serve forever.
//! --plan-in FILE (generate/serve) loads a searched plan-search frontier
//! file and serves its minimum-perplexity plan instead of the profiled
//! `allocate` split (docs/adr/007-asymmetric-bit-allocation.md).
//! --synthetic-layers N (plan-search) searches a seeded synthetic
//! importance profile at a reference geometry — no artifacts needed
//! (what CI's plan-search-smoke step runs).
//! --check FILE (plan-search) re-parses an emitted frontier file and
//! verifies the canonical re-serialization is byte-identical, exiting
//! non-zero otherwise.

use anyhow::{anyhow, bail, Result};
use kvmix::baselines::Method;
use kvmix::config::QuantPlan;
use kvmix::coordinator::{server, EngineCfg, Engine, Request};
use kvmix::harness::tables::{self, ReproCfg};
use kvmix::model::Sampler;
use kvmix::harness::eval::EvalCfg;
use kvmix::profiler::{self, search};
use kvmix::runtime::{default_artifacts_dir, Runtime};
use kvmix::util::cli::Args;
use kvmix::util::{Rng, WorkerPool};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!("usage: kvmix <generate|serve|profile|plan-search|repro|inspect> [options]");
    eprintln!("  see rust/src/main.rs header or README.md for options");
    std::process::exit(2);
}

fn run() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["fast", "no-profiler", "help", "prefix-cache",
                                   "legacy-proto", "plan-search"]);
    if args.flag("help") || args.positional.is_empty() {
        usage();
    }
    let dir = args.get("artifacts").map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let cmd = args.positional[0].as_str();

    match cmd {
        "inspect" => {
            let rt = Runtime::load_with(&dir, false)?;
            println!("artifacts: {}", dir.display());
            println!("model: {:?}", rt.model);
            println!("buckets: {:?}", rt.buckets);
            println!("parameters: {}", rt.weights.param_count());
            println!("weights (fp16-modeled): {:.2} MiB",
                     rt.weights.modeled_bytes_fp16() as f64 / (1 << 20) as f64);
            Ok(())
        }
        "profile" => {
            let rt = Runtime::load(&dir)?;
            let n = args.usize_or("prompts", 16)?;
            let frac = args.f64_or("high-frac", 0.25)?;
            let seed = args.usize_or("seed", 42)? as u64;
            let imp = profiler::profile(&rt, n, seed)?;
            let plan = profiler::allocate(&imp, frac);
            print!("{}", profiler::plan_report(&imp, &plan));
            if args.flag("plan-search") {
                let res = run_plan_search(&rt, &imp, &args, seed)?;
                print_frontier(&res);
                write_plan_out(&res, &args)?;
            }
            Ok(())
        }
        "plan-search" => {
            if let Some(path) = args.get("check") {
                return check_plan_file(path);
            }
            let seed = args.usize_or("seed", 7)? as u64;
            let synth_layers = args.usize_or("synthetic-layers", 0)?;
            let res = if synth_layers > 0 {
                // artifact-free smoke path: seeded synthetic importance at
                // a reference geometry (kv_dim 64, group 32), modeled
                // scorer only
                let imp = search::synthetic_importance(synth_layers, seed);
                let mut cfg = search::SearchCfg { seed, ..Default::default() };
                cfg.budget_frac = args.f64_or("budget-frac", cfg.budget_frac)?;
                search::search_modeled(&imp, &cfg, 64, 32)?
            } else {
                let rt = Runtime::load(&dir)?;
                let imp = profiler::profile(&rt, args.usize_or("prompts", 16)?, seed)?;
                run_plan_search(&rt, &imp, &args, seed)?
            };
            if res.frontier.is_empty() {
                bail!("no feasible plan under budget {:.1} B/token — raise --budget-frac",
                      res.budget_bytes_per_token);
            }
            print_frontier(&res);
            write_plan_out(&res, &args)?;
            Ok(())
        }
        "generate" => {
            let rt = Runtime::load_with(&dir, false)?;
            let method = parse_method(&rt, &args)?;
            let prompt: Vec<i32> = match args.get("prompt") {
                Some(p) => p.split(',').map(|s| s.trim().parse::<i32>())
                    .collect::<std::result::Result<_, _>>()?,
                None => {
                    let mut rng = Rng::new(args.usize_or("seed", 1)? as u64);
                    kvmix::harness::workload::sample_mixture(&mut rng, 48).0
                }
            };
            let max_new = args.usize_or("max-new", 32)?;
            let threads = args.usize_or("threads", 1)?;
            let page_tokens = args.usize_or("page-tokens", 0)?;
            let prefix_cache = args.flag("prefix-cache");
            let step_tokens = args.usize_or("step-tokens", 0)?;
            let (spill_dir, spill_bytes) = spill_opts(&args)?;
            let pressure_weights = pressure_weights(&rt, &args);
            WorkerPool::scoped(threads, |pool| {
                let mut engine = Engine::with_pool(&rt, EngineCfg {
                    method, max_batch: 1, kv_budget: None, threads, page_tokens,
                    prefix_cache, step_tokens, pressure_weights,
                    spill_dir, spill_bytes,
                }, Some(pool))?;
                engine.submit(Request { id: 0, prompt: prompt.clone(), max_new_tokens: max_new,
                                        sampler: Sampler::Greedy, stop_token: None,
                                        priority: 0, deadline_ms: None, submitted_ns: 0, session: None });
                let done = engine.run_to_completion()?;
                println!("prompt ({} tokens): {:?}", prompt.len(), prompt);
                println!("generated: {:?}", done[0].tokens);
                println!("{}", engine.metrics.report());
                Ok(())
            })
        }
        "serve" => {
            let rt = Runtime::load_with(&dir, false)?;
            let method = parse_method(&rt, &args)?;
            let addr = args.get_or("addr", "127.0.0.1:7979");
            let max_batch = args.usize_or("max-batch", 16)?;
            let threads = args.usize_or("threads", 1)?;
            let page_tokens = args.usize_or("page-tokens", 0)?;
            let prefix_cache = args.flag("prefix-cache");
            let step_tokens = args.usize_or("step-tokens", 0)?;
            let kv_budget = args.get("kv-budget-kib")
                .map(|v| v.parse::<usize>().map(|k| k * 1024))
                .transpose()?;
            let mut scfg = server::ServeCfg::new(&addr);
            scfg.admit_queue = args.usize_or("admit-queue", 32)?;
            scfg.legacy = args.flag("legacy-proto");
            scfg.replicas = args.usize_or("replicas", 1)?.max(1);
            scfg.max_requests = args.get("max-requests")
                .map(|v| v.parse::<usize>()).transpose()?;
            let (spill_dir, spill_bytes) = spill_opts(&args)?;
            let pressure_weights = pressure_weights(&rt, &args);
            server::serve(&rt, EngineCfg { method, max_batch, kv_budget, threads,
                                           page_tokens, prefix_cache, step_tokens,
                                           pressure_weights, spill_dir, spill_bytes },
                          scfg)
        }
        "repro" => {
            let exp = args.positional.get(1)
                .ok_or_else(|| anyhow!("repro needs an experiment id (fig1..fig10, table1..table5, headline, all)"))?;
            let rt = Runtime::load(&dir)?;
            let mut cfg = if args.flag("fast") { ReproCfg::fast() } else { ReproCfg::default() };
            cfg.hbm_bytes = args.usize_or("hbm-bytes", 0)?;
            cfg.high_frac = args.f64_or("high-frac", cfg.high_frac)?;
            run_repro(&rt, &cfg, exp)
        }
        _ => bail!("unknown command {cmd:?}"),
    }
}

fn run_repro(rt: &Runtime, cfg: &ReproCfg, exp: &str) -> Result<()> {
    let all = ["fig1", "fig2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig10",
               "table1", "table2", "table3", "table4", "table5", "headline"];
    let run_one = |e: &str| -> Result<()> {
        match e {
            "fig1" => tables::fig1(rt, cfg),
            "fig2" | "fig9" => tables::fig2(rt, cfg),
            "fig4" => tables::fig4(rt, cfg),
            "fig5" => tables::fig5(rt, cfg),
            "fig6" | "fig12" => tables::fig6(rt, cfg),
            "fig7" => tables::fig7(rt, cfg),
            "fig8" => tables::fig8(rt, cfg),
            "fig10" => tables::fig10(rt, cfg),
            "table1" => tables::table1(rt, cfg),
            "table2" => tables::table2(rt, cfg),
            "table3" => tables::table3(rt, cfg),
            "table4" | "fig11" => tables::table4(rt, cfg),
            "table5" => tables::table5(rt, cfg),
            "headline" => tables::headline(rt, cfg),
            _ => bail!("unknown experiment {e:?} (options: {all:?} or 'all')"),
        }
    };
    if exp == "all" {
        for e in all {
            run_one(e)?;
            println!();
        }
        Ok(())
    } else {
        run_one(exp)
    }
}

/// Shared eval-scored search driver for `profile --plan-search` and the
/// artifact-backed `plan-search` subcommand: coarse grid, small LM eval
/// (each frontier survivor costs one teacher-forced pass).
fn run_plan_search(rt: &Runtime, imp: &profiler::Importance, args: &Args, seed: u64)
                   -> Result<search::SearchResult> {
    let mut cfg = search::SearchCfg { seed, ..search::SearchCfg::coarse() };
    cfg.budget_frac = args.f64_or("budget-frac", cfg.budget_frac)?;
    let ecfg = EvalCfg { n_seqs: 6, seq_len: 96, prefill_len: 32, batch: 6,
                         seed: seed ^ 0x5EED, query_offset: None };
    search::search_with_eval(rt, imp, &cfg, &ecfg)
}

fn print_frontier(res: &search::SearchResult) {
    println!("plan search: budget {:.1} B/token, {} frontier plan(s)",
             res.budget_bytes_per_token, res.frontier.len());
    println!("{:<24} | {:>12} | {:>10} | {:>6} | {:>6}",
             "plan", "bytes/token", "ppl", "avg K", "avg V");
    for p in &res.frontier {
        println!("{:<24} | {:>12.1} | {:>10.4} | {:>6.2} | {:>6.2}",
                 p.plan.name, p.bytes_per_token, p.ppl,
                 p.plan.avg_k_bits(), p.plan.avg_v_bits());
    }
    if let Some(best) = res.best() {
        println!("best: {}", best.plan.name);
    }
}

fn write_plan_out(res: &search::SearchResult, args: &Args) -> Result<()> {
    if let Some(path) = args.get("plan-out") {
        res.write_file(std::path::Path::new(path))?;
        println!("wrote frontier to {path}");
    }
    Ok(())
}

/// `plan-search --check FILE`: re-parse an emitted frontier file and
/// verify the canonical re-serialization is byte-identical (what CI's
/// plan-search-smoke step pins).
fn check_plan_file(path: &str) -> Result<()> {
    let res = search::SearchResult::read_file(std::path::Path::new(path))?;
    if res.frontier.is_empty() {
        bail!("{path}: frontier is empty");
    }
    let raw = std::fs::read_to_string(path)?;
    let canon = res.to_json().to_string() + "\n";
    if raw != canon {
        bail!("{path}: not in canonical form (re-serialization differs)");
    }
    println!("{path}: OK ({} frontier plan(s), {} layers)",
             res.frontier.len(), res.n_layers);
    Ok(())
}

/// `--spill-dir DIR [--spill-bytes B]` → the engine's spill-tier knobs
/// (DESIGN.md §Spill-Tier).  `--spill-bytes` without `--spill-dir` is a
/// misconfiguration worth failing loudly on.
fn spill_opts(args: &Args) -> Result<(Option<std::path::PathBuf>, usize)> {
    let dir = args.get("spill-dir").map(std::path::PathBuf::from);
    let bytes = args.usize_or("spill-bytes", 0)?;
    if dir.is_none() && bytes > 0 {
        bail!("--spill-bytes needs --spill-dir");
    }
    Ok((dir, bytes))
}

/// Per-layer downshift weights for the pressure controller: the raw
/// gradient scores the profiler recorded in importance.json, when
/// running the profiled kvmix method (DESIGN.md §Pressure-Ladder).
/// Anything else (uniform baselines, searched `--plan-in` plans, missing
/// or score-less artifact files) falls back to the plan-derived weights
/// inside `PressureCfg::from_plan`.
fn pressure_weights(rt: &Runtime, args: &Args) -> Option<(Vec<f64>, Vec<f64>)> {
    if args.get("plan-in").is_some() || args.get_or("method", "kvmix") != "kvmix" {
        return None;
    }
    QuantPlan::scores_from_importance_file(&rt.artifacts_dir().join("importance.json"))
        .ok().flatten()
}

fn parse_method(rt: &Runtime, args: &Args) -> Result<Method> {
    if let Some(path) = args.get("plan-in") {
        let res = search::SearchResult::read_file(std::path::Path::new(path))?;
        if res.n_layers != rt.model.n_layers {
            bail!("{path}: plan file has {} layers, model has {}",
                  res.n_layers, rt.model.n_layers);
        }
        let best = res.best().ok_or_else(|| anyhow!("{path}: frontier is empty"))?;
        return Ok(Method::Kvmix(best.plan.clone()));
    }
    let plan_path = rt.artifacts_dir().join("importance.json");
    let kvmix_plan = || -> Result<QuantPlan> {
        QuantPlan::from_importance_file(&plan_path)
    };
    Ok(match args.get_or("method", "kvmix").as_str() {
        "kvmix" => Method::Kvmix(kvmix_plan()?),
        "fp16" => Method::Fp16,
        "kvmix-2bit" => Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2)),
        "kvmix-4bit" => Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 4)),
        // eager variant (no RPC window): every full group quantizes at
        // append, which maximizes the page-shareable prompt prefix —
        // the README prefix-cache walkthrough uses this
        "kvmix-2bit-eager" =>
            Method::Kvmix(QuantPlan::uniform(rt.model.n_layers, 2).without_rpc()),
        "kivi" => Method::Kivi { bits: 2, residual: 64 },
        "kvquant" => Method::KvQuant { bits: 3, outlier_frac: 0.01 },
        "qjl" => Method::Qjl { jl_dim_mult: 4, v_bits: 3 },
        "atom" => Method::Atom { bits: 4 },
        other => bail!("unknown method {other:?}"),
    })
}
