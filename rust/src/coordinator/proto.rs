//! NDJSON serving-protocol frames + the zero-copy lazy scanner over
//! request bytes (DESIGN.md §Serving-Protocol,
//! docs/adr/006-streaming-json-protocol.md).
//!
//! One frame per line.  Client → server:
//!
//! ```text
//! {"id":7,"prompt":[1,2,3],"max_new":16,
//!  "priority":0,"deadline_ms":500,"temperature":0.8,"top_k":4,"stop":2}
//! {"cancel":7}
//! {"stats":true}
//! ```
//!
//! Server → client (encoders below; every frame is one line of JSON):
//!
//! ```text
//! {"id":7,"delta":[481,1292]}                       per engine step
//! {"id":7,"done":true,"finish":"length","n":16,
//!  "ttft_ms":41.3,"tbt_ms":5.2}                     terminal
//! {"id":8,"error":"admission queue full","retry_after_ms":120}
//! {"error":"parse error at byte 14: expected ':' after key"}
//! {"stats":{"queue_depth":3, …}}
//! ```
//!
//! The scanner is deliberately *not* a JSON-tree parser: it walks the
//! line bytes once, extracts only the keys a client frame can carry, and
//! validates-but-skips everything else (unknown keys forward-compatibly
//! ignored, depth-capped).  No allocation happens until a known key's
//! value is materialized (the prompt vector is the only unbounded one,
//! capped at [`MAX_PROMPT_TOKENS`]).  Acceptance is a strict subset of
//! [`crate::util::json::parse`] — anything the scanner admits, the tree
//! parser admits too (`rust/tests/proto.rs` pins this differentially,
//! plus the round-trip and byte-mutation properties).
//!
//! Errors are structured ([`ProtoError`]: byte offset + static message)
//! and never panic — the server answers them with an `{"error":…}` frame
//! and keeps the connection alive, resynchronizing on the next newline.

use std::fmt;
use std::fmt::Write as _;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::Completion;
use crate::util::json::{self, Json};

/// Hard per-line byte cap.  The server reads at most this many bytes of
/// a frame before load-shedding the line (`{"error":…}` + resync to the
/// next newline), so a client cannot balloon the reader's buffer.
pub const MAX_FRAME_BYTES: usize = 4 << 20;

/// Longest accepted `"prompt"` array (tokens).
pub const MAX_PROMPT_TOKENS: usize = 1 << 18;

/// Largest accepted `"max_new"` / `"top_k"` value.
pub const MAX_NEW_TOKENS: usize = 1 << 20;

/// Nesting cap while skipping unknown values: deeper frames are rejected
/// (recursion must stay bounded on adversarial input).
const MAX_DEPTH: usize = 32;

/// Structured scan failure: byte offset into the frame + static message.
/// `at` is always `<= line.len()` — the mutation harness in
/// `rust/tests/proto.rs` pins that no input moves it out of bounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProtoError {
    pub at: usize,
    pub msg: &'static str,
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ProtoError {}

/// A parsed generation request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct GenReq {
    /// client-chosen id, echoed on every response frame for this request
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// admission priority (default 0; higher admits sooner)
    pub priority: i32,
    /// serving deadline relative to submission (ms)
    pub deadline_ms: Option<u64>,
    /// `top_k`/`temperature` absent → greedy sampling
    pub temperature: Option<f64>,
    pub top_k: Option<usize>,
    /// stop token id
    pub stop: Option<i32>,
    /// session key for park/resume across conversation turns
    /// (DESIGN.md §Serving-Protocol): a finished request's KV pages park
    /// under this key; the next request naming it resumes without
    /// re-quantizing the shared prefix
    pub session: Option<u64>,
}

impl GenReq {
    /// Canonical NDJSON encoding (no trailing newline) — the round-trip
    /// partner of [`scan_client_frame`]: optional fields at their
    /// defaults are omitted, so `scan(encode(g)) == Gen(g)` exactly.
    pub fn encode(&self) -> String {
        let mut s = String::with_capacity(48 + self.prompt.len() * 6);
        let _ = write!(s, "{{\"id\":{},\"prompt\":[", self.id);
        for (i, t) in self.prompt.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{t}");
        }
        let _ = write!(s, "],\"max_new\":{}", self.max_new);
        if self.priority != 0 {
            let _ = write!(s, ",\"priority\":{}", self.priority);
        }
        if let Some(d) = self.deadline_ms {
            let _ = write!(s, ",\"deadline_ms\":{d}");
        }
        if let Some(t) = self.temperature {
            let _ = write!(s, ",\"temperature\":{t}");
        }
        if let Some(k) = self.top_k {
            let _ = write!(s, ",\"top_k\":{k}");
        }
        if let Some(t) = self.stop {
            let _ = write!(s, ",\"stop\":{t}");
        }
        if let Some(k) = self.session {
            let _ = write!(s, ",\"session\":{k}");
        }
        s.push('}');
        s
    }
}

/// One client frame: generation request, cancellation, or stats query.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientFrame {
    Gen(GenReq),
    /// `{"cancel":<id>}` — retire the named request (client-scoped id)
    Cancel { id: u64 },
    /// `{"stats":true}` — answer with a `{"stats":{…}}` snapshot
    Stats,
}

/// Scan one frame (a line *without* its terminating newline; a stray
/// `\r` or surrounding whitespace is tolerated).  Single pass, no tree.
pub fn scan_client_frame(line: &[u8]) -> Result<ClientFrame, ProtoError> {
    let mut s = Scan { b: line, i: 0 };
    let mut id: Option<u64> = None;
    let mut prompt: Option<Vec<i32>> = None;
    let mut max_new: Option<u64> = None;
    let mut priority: Option<i32> = None;
    let mut deadline_ms: Option<u64> = None;
    let mut temperature: Option<f64> = None;
    let mut top_k: Option<u64> = None;
    let mut stop: Option<i32> = None;
    let mut session: Option<u64> = None;
    let mut cancel: Option<u64> = None;
    let mut stats_seen = false;

    s.ws();
    s.expect(b'{', "expected '{'")?;
    s.ws();
    if s.peek() == Some(b'}') {
        s.i += 1;
    } else {
        loop {
            s.ws();
            let (ks, ke) = s.string_span()?;
            s.ws();
            s.expect(b':', "expected ':' after key")?;
            s.ws();
            match &s.b[ks..ke] {
                b"id" => put(&mut id, s.u64_value()?, ks)?,
                b"prompt" => put(&mut prompt, s.i32_array(MAX_PROMPT_TOKENS)?, ks)?,
                b"max_new" => put(&mut max_new, s.u64_value()?, ks)?,
                b"priority" => put(&mut priority, s.i32_value()?, ks)?,
                b"deadline_ms" => put(&mut deadline_ms, s.u64_value()?, ks)?,
                b"temperature" => put(&mut temperature, s.f64_value()?, ks)?,
                b"top_k" => put(&mut top_k, s.u64_value()?, ks)?,
                b"stop" => put(&mut stop, s.i32_value()?, ks)?,
                b"session" => put(&mut session, s.u64_value()?, ks)?,
                b"cancel" => put(&mut cancel, s.u64_value()?, ks)?,
                b"stats" => {
                    if stats_seen {
                        return Err(ProtoError { at: ks, msg: "duplicate key" });
                    }
                    stats_seen = true;
                    s.lit(b"true", "\"stats\" must be true")?;
                }
                // forward compatibility: validate-and-skip unknown values
                _ => s.skip_value(0)?,
            }
            s.ws();
            match s.peek() {
                Some(b',') => s.i += 1,
                Some(b'}') => {
                    s.i += 1;
                    break;
                }
                _ => return Err(s.err("expected ',' or '}'")),
            }
        }
    }
    s.ws();
    if s.i != s.b.len() {
        return Err(s.err("trailing bytes after frame"));
    }

    // ---- classification: the three frame kinds must not blend ----
    let gen_keys = id.is_some() || prompt.is_some() || max_new.is_some()
        || priority.is_some() || deadline_ms.is_some() || temperature.is_some()
        || top_k.is_some() || stop.is_some() || session.is_some();
    if let Some(cid) = cancel {
        if gen_keys || stats_seen {
            return Err(ProtoError { at: 0, msg: "cancel frame mixes other keys" });
        }
        return Ok(ClientFrame::Cancel { id: cid });
    }
    if stats_seen {
        if gen_keys {
            return Err(ProtoError { at: 0, msg: "stats frame mixes other keys" });
        }
        return Ok(ClientFrame::Stats);
    }
    let id = id.ok_or(ProtoError { at: 0, msg: "missing \"id\"" })?;
    let prompt = prompt.ok_or(ProtoError { at: 0, msg: "missing \"prompt\"" })?;
    if prompt.is_empty() {
        return Err(ProtoError { at: 0, msg: "empty prompt" });
    }
    let max_new = max_new.ok_or(ProtoError { at: 0, msg: "missing \"max_new\"" })?;
    if max_new == 0 {
        return Err(ProtoError { at: 0, msg: "max_new must be >= 1" });
    }
    if max_new > MAX_NEW_TOKENS as u64 {
        return Err(ProtoError { at: 0, msg: "max_new exceeds limit" });
    }
    if let Some(t) = temperature {
        if t <= 0.0 {
            return Err(ProtoError { at: 0, msg: "temperature must be > 0" });
        }
    }
    if let Some(k) = top_k {
        if k == 0 || k > MAX_NEW_TOKENS as u64 {
            return Err(ProtoError { at: 0, msg: "top_k out of range" });
        }
    }
    Ok(ClientFrame::Gen(GenReq {
        id,
        prompt,
        max_new: max_new as usize,
        priority: priority.unwrap_or(0),
        deadline_ms,
        temperature,
        top_k: top_k.map(|k| k as usize),
        stop,
        session,
    }))
}

/// Duplicate-key guard for the known-key slots.
fn put<T>(slot: &mut Option<T>, v: T, at: usize) -> Result<(), ProtoError> {
    if slot.is_some() {
        return Err(ProtoError { at, msg: "duplicate key" });
    }
    *slot = Some(v);
    Ok(())
}

// ---------------- server-side frame encoders ----------------

/// Per-step token delta for one streaming request.
pub fn delta_frame(id: u64, delta: &[i32]) -> String {
    let mut s = String::with_capacity(24 + delta.len() * 6);
    let _ = write!(s, "{{\"id\":{id},\"delta\":[");
    for (i, t) in delta.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{t}");
    }
    s.push_str("]}");
    s
}

/// Terminal frame: finish reason + per-request TTFT / mean-TBT stats.
/// `id` is the client-scoped id (the completion carries the engine's
/// global one); `ttft_ms`/`tbt_ms` are omitted when no token was ever
/// produced (cancelled or deadline-expired while still waiting).
pub fn final_frame(id: u64, c: &Completion) -> String {
    let mut s = String::with_capacity(80);
    let _ = write!(s, "{{\"id\":{id},\"done\":true,\"finish\":\"{}\",\"n\":{}",
                   c.finish.as_str(), c.tokens.len());
    if !c.tokens.is_empty() {
        let _ = write!(s, ",\"ttft_ms\":{:.3}", c.ttft_ms());
    }
    if let Some(t) = c.tbt_ms() {
        let _ = write!(s, ",\"tbt_ms\":{t:.3}");
    }
    s.push('}');
    s
}

/// Rejection / error frame.  With `retry_after_ms` it is a load-shed
/// (come back later); without, the rejection is terminal for that
/// request.  `error` is escaped, so arbitrary reason text cannot break
/// the NDJSON framing.
pub fn reject_frame(id: Option<u64>, error: &str, retry_after_ms: Option<u64>) -> String {
    let mut s = String::from("{");
    if let Some(id) = id {
        let _ = write!(s, "\"id\":{id},");
    }
    let _ = write!(s, "\"error\":{}", json::escape_str(error));
    if let Some(ra) = retry_after_ms {
        let _ = write!(s, ",\"retry_after_ms\":{ra}");
    }
    s.push('}');
    s
}

/// Connection-scoped error frame (no request id — e.g. a parse failure).
pub fn error_frame(msg: &str) -> String {
    reject_frame(None, msg, None)
}

/// Client-side encoder for `{"cancel":<id>}` (tests and examples).
pub fn cancel_frame(id: u64) -> String {
    format!("{{\"cancel\":{id}}}")
}

/// Client-side encoder for `{"stats":true}` (tests and examples).
pub fn stats_request_frame() -> String {
    "{\"stats\":true}".to_string()
}

/// `{"stats":{…}}` snapshot of the metrics registry plus the live serve
/// state the registry cannot see (queue depth, running lanes, load-sheds,
/// replica count).  With `--replicas N` the registry passed here is the
/// router's [`Metrics::merge`] aggregate over every replica
/// (DESIGN.md §Replication).
pub fn stats_frame(m: &mut Metrics, queue_depth: usize, active: usize,
                   shed: usize, replicas: usize) -> String {
    let u = |x: usize| Json::Num(x as f64);
    let inner = Json::obj(vec![
        ("queue_depth", u(queue_depth)),
        ("active", u(active)),
        ("shed", u(shed)),
        ("replicas", u(replicas)),
        ("completions", u(m.completions)),
        ("cancellations", u(m.cancellations)),
        ("deadline_hits", u(m.deadline_hits)),
        ("oom_events", u(m.oom_events)),
        ("preemptions", u(m.preemptions)),
        ("pages_requantized", u(m.pages_requantized)),
        ("prefix_hits", u(m.prefix_hits)),
        ("prefix_tokens_reused", u(m.prefix_tokens_reused)),
        ("cow_splits", u(m.cow_splits)),
        ("pages_spilled", u(m.pages_spilled)),
        ("spill_faults", u(m.spill_faults)),
        ("sessions_parked", u(m.sessions_parked)),
        ("sessions_resumed", u(m.sessions_resumed)),
        ("resume_tokens_reused", u(m.resume_tokens_reused)),
        ("prefill_tokens", u(m.prefill_tokens)),
        ("decode_tokens", u(m.decode_tokens)),
        ("peak_kv_bytes", u(m.peak_kv_bytes)),
        ("throughput_tok_s", Json::Num(m.throughput())),
        ("ttft_p50_ms", Json::Num(m.ttft_ms.quantile(0.5))),
        ("ttft_p95_ms", Json::Num(m.ttft_ms.quantile(0.95))),
        ("tbt_p50_ms", Json::Num(m.tbt_ms.quantile(0.5))),
        ("tbt_p99_ms", Json::Num(m.tbt_ms.quantile(0.99))),
    ]);
    Json::obj(vec![("stats", inner)]).to_string()
}

// ---------------- the scanner ----------------

struct Scan<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Scan<'a> {
    fn err(&self, msg: &'static str) -> ProtoError {
        ProtoError { at: self.i.min(self.b.len()), msg }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\r' | b'\n')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8, msg: &'static str) -> Result<(), ProtoError> {
        if self.peek() != Some(c) {
            return Err(self.err(msg));
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &'static [u8], msg: &'static str) -> Result<(), ProtoError> {
        if self.b.len() - self.i >= word.len()
            && &self.b[self.i..self.i + word.len()] == word
        {
            self.i += word.len();
            Ok(())
        } else {
            Err(self.err(msg))
        }
    }

    /// Validate a JSON string and return the raw inner byte span (no
    /// unescaping — known keys are matched on their literal spelling, so
    /// an escaped spelling of a known key lands in the skip path).
    fn string_span(&mut self) -> Result<(usize, usize), ProtoError> {
        self.expect(b'"', "expected string")?;
        let start = self.i;
        loop {
            let Some(c) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.i += 1;
            match c {
                b'"' => {
                    let end = self.i - 1;
                    if std::str::from_utf8(&self.b[start..end]).is_err() {
                        return Err(ProtoError { at: start, msg: "invalid utf-8 in string" });
                    }
                    return Ok((start, end));
                }
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err(self.err("truncated escape"));
                    };
                    self.i += 1;
                    match e {
                        b'"' | b'\\' | b'/' | b'n' | b't' | b'r' | b'b' | b'f' => {}
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            for _ in 0..4 {
                                if !self.b[self.i].is_ascii_hexdigit() {
                                    return Err(self.err("bad \\u escape"));
                                }
                                self.i += 1;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {}
            }
        }
    }

    /// Strict unsigned integer: digits only (no sign, fraction, exponent).
    fn u64_value(&mut self) -> Result<u64, ProtoError> {
        let start = self.i;
        let mut v: u64 = 0;
        let mut any = false;
        while let Some(c @ b'0'..=b'9') = self.peek() {
            any = true;
            v = v.checked_mul(10)
                .and_then(|v| v.checked_add((c - b'0') as u64))
                .ok_or(ProtoError { at: start, msg: "integer out of range" })?;
            self.i += 1;
        }
        if !any {
            return Err(self.err("expected unsigned integer"));
        }
        if matches!(self.peek(), Some(b'.' | b'e' | b'E')) {
            return Err(self.err("expected integer, found float"));
        }
        Ok(v)
    }

    /// Strict signed integer in i32 range.
    fn i32_value(&mut self) -> Result<i32, ProtoError> {
        let at = self.i;
        let neg = self.peek() == Some(b'-');
        if neg {
            self.i += 1;
        }
        let mag = self.u64_value()? as i128;
        let v = if neg { -mag } else { mag };
        i32::try_from(v).map_err(|_| ProtoError { at, msg: "integer out of i32 range" })
    }

    /// Finite JSON number as f64.
    fn f64_value(&mut self) -> Result<f64, ProtoError> {
        let start = self.i;
        while matches!(self.peek(),
                       Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.i += 1;
        }
        let raw = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| ProtoError { at: start, msg: "bad number" })?;
        let v: f64 = raw.parse()
            .map_err(|_| ProtoError { at: start, msg: "bad number" })?;
        if !v.is_finite() {
            return Err(ProtoError { at: start, msg: "non-finite number" });
        }
        Ok(v)
    }

    /// `[i32, …]` with a length cap — the only unbounded allocation a
    /// frame can request, so the cap is enforced mid-scan.
    fn i32_array(&mut self, cap: usize) -> Result<Vec<i32>, ProtoError> {
        self.expect(b'[', "expected array")?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(out);
        }
        loop {
            self.ws();
            out.push(self.i32_value()?);
            if out.len() > cap {
                return Err(self.err("prompt exceeds MAX_PROMPT_TOKENS"));
            }
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(out);
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    /// Unknown-number skip: same acceptance as the tree parser (consume
    /// the JSON number alphabet, then the f64 grammar decides).
    fn skip_number(&mut self) -> Result<(), ProtoError> {
        let start = self.i;
        while matches!(self.peek(),
                       Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.i += 1;
        }
        let raw = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| ProtoError { at: start, msg: "bad number" })?;
        if raw.parse::<f64>().is_err() {
            return Err(ProtoError { at: start, msg: "bad number" });
        }
        Ok(())
    }

    /// Validate-and-discard an arbitrary JSON value (unknown keys).
    /// Depth-capped so adversarial nesting cannot blow the stack.
    fn skip_value(&mut self, depth: usize) -> Result<(), ProtoError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => {
                self.i += 1;
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.string_span()?;
                    self.ws();
                    self.expect(b':', "expected ':' after key")?;
                    self.ws();
                    self.skip_value(depth + 1)?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected ',' or '}'")),
                    }
                }
            }
            Some(b'[') => {
                self.i += 1;
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.skip_value(depth + 1)?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected ',' or ']'")),
                    }
                }
            }
            Some(b'"') => self.string_span().map(|_| ()),
            Some(b't') => self.lit(b"true", "invalid literal"),
            Some(b'f') => self.lit(b"false", "invalid literal"),
            Some(b'n') => self.lit(b"null", "invalid literal"),
            Some(_) => self.skip_number(),
            None => Err(self.err("unexpected end of frame")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    fn gen(line: &str) -> GenReq {
        match scan_client_frame(line.as_bytes()).unwrap() {
            ClientFrame::Gen(g) => g,
            other => panic!("expected Gen, got {other:?}"),
        }
    }

    #[test]
    fn scans_minimal_gen_frame() {
        let g = gen(r#"{"id":7,"prompt":[1,2,3],"max_new":16}"#);
        assert_eq!(g.id, 7);
        assert_eq!(g.prompt, vec![1, 2, 3]);
        assert_eq!(g.max_new, 16);
        assert_eq!(g.priority, 0);
        assert_eq!((g.deadline_ms, g.temperature, g.top_k, g.stop, g.session),
                   (None, None, None, None, None));
    }

    #[test]
    fn scans_full_gen_frame_any_key_order() {
        let g = gen(concat!(
            r#" { "temperature" : 0.8 , "prompt":[ -5 , 0 ,7 ], "stop": 2,"#,
            r#" "top_k":4, "deadline_ms": 250, "max_new":8, "priority":-3,"#,
            r#" "session": 41, "id": 9 } "#));
        assert_eq!(g.id, 9);
        assert_eq!(g.prompt, vec![-5, 0, 7]);
        assert_eq!((g.max_new, g.priority), (8, -3));
        assert_eq!(g.deadline_ms, Some(250));
        assert_eq!(g.temperature, Some(0.8));
        assert_eq!((g.top_k, g.stop), (Some(4), Some(2)));
        assert_eq!(g.session, Some(41));
    }

    #[test]
    fn session_round_trips_and_classifies_as_gen() {
        let g = GenReq {
            id: 5, prompt: vec![1, 2], max_new: 4, priority: 0,
            deadline_ms: None, temperature: None, top_k: None, stop: None,
            session: Some(1234),
        };
        let line = g.encode();
        assert!(line.contains("\"session\":1234"), "{line}");
        assert_eq!(gen(&line), g);
        // a session key marks a gen frame — it must not blend with others
        assert!(scan_client_frame(br#"{"cancel":1,"session":2}"#).is_err());
        assert!(scan_client_frame(br#"{"stats":true,"session":2}"#).is_err());
    }

    #[test]
    fn unknown_keys_are_validated_and_skipped() {
        let g = gen(concat!(
            r#"{"id":1,"x":{"deep":[1,"s",null,{"y":true}]},"prompt":[4],"#,
            r#""future_knob":-1.5e3,"max_new":2}"#));
        assert_eq!((g.id, g.max_new), (1, 2));
        // …but a malformed unknown value still fails the whole frame
        let bad = r#"{"id":1,"x":[1,,2],"prompt":[4],"max_new":2}"#;
        assert!(scan_client_frame(bad.as_bytes()).is_err());
    }

    #[test]
    fn scans_cancel_and_stats_frames() {
        assert_eq!(scan_client_frame(br#"{"cancel":12}"#).unwrap(),
                   ClientFrame::Cancel { id: 12 });
        assert_eq!(scan_client_frame(br#"{"stats":true}"#).unwrap(),
                   ClientFrame::Stats);
        // frame kinds must not blend
        assert!(scan_client_frame(br#"{"cancel":12,"id":3}"#).is_err());
        assert!(scan_client_frame(br#"{"stats":true,"prompt":[1]}"#).is_err());
        assert!(scan_client_frame(br#"{"stats":false}"#).is_err());
    }

    #[test]
    fn rejects_structural_garbage_with_offsets() {
        for (line, _why) in [
            ("", "empty"),
            ("GEN 8 1,2,3", "legacy line"),
            ("{", "unterminated"),
            (r#"{"id":1"#, "no close"),
            (r#"{"id":1,}"#, "trailing comma"),
            (r#"{"id":1} x"#, "trailing bytes"),
            (r#"{"id":1,"id":2,"prompt":[1],"max_new":1}"#, "duplicate"),
            (r#"{"id":-1,"prompt":[1],"max_new":1}"#, "negative id"),
            (r#"{"id":1.5,"prompt":[1],"max_new":1}"#, "float id"),
            (r#"{"id":1,"prompt":[],"max_new":1}"#, "empty prompt"),
            (r#"{"id":1,"prompt":[1],"max_new":0}"#, "zero max_new"),
            (r#"{"id":1,"prompt":[99999999999],"max_new":1}"#, "i32 overflow"),
            (r#"{"id":1,"prompt":[1],"max_new":1,"temperature":0}"#, "temp 0"),
            (r#"{"id":1,"prompt":[1],"max_new":1,"top_k":0}"#, "top_k 0"),
            (r#"{"prompt":[1],"max_new":1}"#, "missing id"),
        ] {
            let e = scan_client_frame(line.as_bytes()).unwrap_err();
            assert!(e.at <= line.len(), "offset {} out of bounds for {line:?}", e.at);
        }
    }

    #[test]
    fn depth_cap_rejects_adversarial_nesting() {
        let mut line = String::from(r#"{"id":1,"x":"#);
        for _ in 0..64 {
            line.push('[');
        }
        // never closed — but the depth cap must fire before anything else
        let e = scan_client_frame(line.as_bytes()).unwrap_err();
        assert_eq!(e.msg, "nesting too deep");
    }

    #[test]
    fn encoders_emit_parseable_frames() {
        let c = Completion {
            id: 3, prompt_len: 4, tokens: vec![5, 6, 7],
            finish: FinishReason::Length,
            submitted_ns: 0, first_token_ns: 1_000_000, finished_ns: 5_000_000,
        };
        for frame in [
            delta_frame(9, &[1, -2, 3]),
            final_frame(9, &c),
            reject_frame(Some(9), "admission queue full", Some(120)),
            error_frame("parse error at byte 3: expected '{'\nnew\"line\""),
            cancel_frame(9),
            stats_request_frame(),
            stats_frame(&mut Metrics::default(), 3, 1, 2, 1),
        ] {
            let v = json::parse(&frame).expect(&frame);
            assert!(matches!(v, Json::Obj(_)), "{frame}");
            assert!(!frame.contains('\n'), "NDJSON frames must be one line: {frame}");
        }
        let f = json::parse(&final_frame(9, &c)).unwrap();
        assert_eq!(f.get("finish").unwrap().as_str().unwrap(), "length");
        assert_eq!(f.get("n").unwrap().as_usize().unwrap(), 3);
        assert!(f.get("tbt_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}
