//! Front-of-house multi-replica router (DESIGN.md §Replication,
//! docs/adr/008-replica-router-and-spill-tier.md).
//!
//! Owns N independent [`Engine`]s — each with its own page pool,
//! scheduler, pressure controller, and metrics — and dispatches admitted
//! requests by **shared-prefix affinity**: prompts sharing their first
//! whole KV page hash to the same replica (rendezvous hashing over an
//! FNV-1a digest of the page-aligned prompt head), so the per-replica
//! prefix index (DESIGN.md §Prefix-Sharing) keeps its hit rate instead
//! of seeing each prefix family diluted 1/N across replicas.  Affinity
//! is a throughput optimisation, never a correctness requirement: when
//! the affinity pick is loaded more than [`LOAD_SLACK`] requests past
//! the least-loaded replica, the request falls back to the least-loaded
//! one and simply re-quantizes its prefix there.
//!
//! Two dispatch rules outrank the hash:
//!
//! 1. **Session pinning** — a request naming a `"session"` key routes to
//!    the replica that parked that session's pages (park/resume lives in
//!    [`Engine`]); a resume anywhere else would always miss.
//! 2. **Sub-page prompts** — prompts shorter than one KV page can never
//!    share prefix pages (sealing is page-granular), so they go straight
//!    to the least-loaded replica.
//!
//! The router aggregates the per-replica [`Metrics`] for stats frames
//! with [`Metrics::merge`] — counters sum, histograms pool their
//! samples, `peak_kv_bytes` takes the max.  With one replica every
//! method degenerates to the single-engine call it wraps, keeping the
//! `--replicas 1` serving path bit-for-bit the pre-router one
//! (`rust/tests/coordinator.rs` pins the two-replica affinity split).

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ActiveRequest, Completion, Rejection, Request, RequestId};

/// Load-fallback slack: the affinity replica keeps a request until it is
/// loaded this many requests (active + waiting) past the least-loaded
/// replica.  Small enough that a hot prefix family cannot starve the
/// fleet, large enough that transient imbalance does not shatter
/// affinity (docs/adr/008-replica-router-and-spill-tier.md).
pub const LOAD_SLACK: usize = 8;

/// Distinct session keys remembered for pinning before the home map is
/// wholesale cleared (same bounded-memory idiom as the serve loop's
/// orphan-cancel set): losing a pin only costs a resume miss — the next
/// turn re-prefills on whatever replica the hash picks — never
/// correctness.
const SESSION_HOME_CAP: usize = 1 << 16;

/// FNV-1a over the little-endian bytes of a token slice.
fn fnv1a(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// splitmix64 finalizer — decorrelates the per-replica rendezvous scores
/// derived from one prompt digest.
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Pick a replica for a prompt — pure, so tests exercise the policy
/// without artifacts (DESIGN.md §Replication).
///
/// Precedence: a valid `session_home` pin wins outright; sub-page
/// prompts (nothing page-shareable) go least-loaded; otherwise the
/// first whole page of the prompt is FNV-1a-hashed and rendezvous
/// hashing (highest `mix(digest ^ replica)` score) names the affinity
/// primary, demoted to the least-loaded replica only when its load
/// exceeds the minimum by more than `slack`.  Rendezvous hashing keeps
/// the mapping stable under fleet resize: changing N remaps only the
/// families whose argmax moved, not a modulo-sized slice of all of them.
pub fn route_replica(n: usize, loads: &[usize], prompt: &[i32], page_tokens: usize,
                     session_home: Option<usize>, slack: usize) -> usize {
    debug_assert_eq!(loads.len(), n);
    if n <= 1 {
        return 0;
    }
    if let Some(r) = session_home {
        if r < n {
            return r;
        }
    }
    let least = (0..n).min_by_key(|&r| loads[r]).unwrap_or(0);
    if page_tokens == 0 || prompt.len() < page_tokens {
        return least;
    }
    let h = fnv1a(&prompt[..page_tokens]);
    let primary = (0..n).max_by_key(|&r| mix(h ^ r as u64)).unwrap_or(0);
    if loads[primary] > loads[least] + slack {
        least
    } else {
        primary
    }
}

/// N engines behind one dispatch policy.  The serve loop talks to this
/// instead of a bare [`Engine`]; every aggregate method is a plain fold
/// over the replicas so `--replicas 1` stays the single-engine path.
pub struct Router<'a> {
    engines: Vec<Engine<'a>>,
    page_tokens: usize,
    /// session key → replica holding its parked pages
    session_home: HashMap<u64, usize>,
}

impl<'a> Router<'a> {
    pub fn new(engines: Vec<Engine<'a>>, page_tokens: usize) -> Self {
        assert!(!engines.is_empty(), "router needs at least one replica");
        Router { engines, page_tokens, session_home: HashMap::new() }
    }

    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    pub fn engines(&self) -> &[Engine<'a>] {
        &self.engines
    }

    pub fn engines_mut(&mut self) -> &mut [Engine<'a>] {
        &mut self.engines
    }

    /// Per-replica load for routing: running lanes + waiting queue.
    fn loads(&self) -> Vec<usize> {
        self.engines.iter()
            .map(|e| e.active.len() + e.batcher.waiting())
            .collect()
    }

    /// Route and submit, returning the chosen replica.  A sessioned
    /// request records (or refreshes) its home so the next turn lands on
    /// the replica holding the parked pages.
    pub fn dispatch(&mut self, req: Request) -> usize {
        let loads = self.loads();
        let home = req.session.and_then(|k| self.session_home.get(&k).copied());
        let r = route_replica(self.engines.len(), &loads, &req.prompt,
                              self.page_tokens, home, LOAD_SLACK);
        if let Some(k) = req.session {
            if self.session_home.len() >= SESSION_HOME_CAP
                && !self.session_home.contains_key(&k)
            {
                self.session_home.clear();
            }
            self.session_home.insert(k, r);
        }
        self.engines[r].submit(req);
        r
    }

    /// Total waiting across all replica queues (admission gate).
    pub fn waiting(&self) -> usize {
        self.engines.iter().map(|e| e.batcher.waiting()).sum()
    }

    /// Total running lanes across replicas (stats frame).
    pub fn active(&self) -> usize {
        self.engines.iter().map(|e| e.active.len()).sum()
    }

    pub fn idle(&self) -> bool {
        self.engines.iter().all(Engine::idle)
    }

    pub fn take_rejections(&mut self) -> Vec<Rejection> {
        self.engines.iter_mut()
            .flat_map(Engine::take_rejections)
            .collect()
    }

    /// Cancel wherever the request lives — serve-loop gids are global,
    /// so at most one replica knows the id.
    pub fn cancel(&mut self, id: RequestId) -> Result<Option<Completion>> {
        for e in &mut self.engines {
            if let Some(c) = e.cancel(id)? {
                return Ok(Some(c));
            }
        }
        Ok(None)
    }

    /// Step every non-idle replica once, pooling completions.  Replicas
    /// are independent — an error from any aborts the serve loop, same
    /// as the single-engine path.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let mut done = Vec::new();
        for e in &mut self.engines {
            if !e.idle() {
                done.extend(e.step()?);
            }
        }
        Ok(done)
    }

    /// All running lanes across replicas (delta streaming walks this).
    pub fn active_lanes(&self) -> impl Iterator<Item = &ActiveRequest> {
        self.engines.iter().flat_map(|e| e.active.iter())
    }

    /// Cross-replica metrics snapshot for the stats frame
    /// (DESIGN.md §Replication): counters sum, histograms pool samples,
    /// `peak_kv_bytes` maxes.
    pub fn merged_metrics(&self) -> Metrics {
        let mut m = self.engines[0].metrics.clone();
        for e in &self.engines[1..] {
            m.merge(&e.metrics);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PT: usize = 16;

    fn prompt(seed: i32, len: usize) -> Vec<i32> {
        (0..len as i32).map(|i| seed * 1000 + i).collect()
    }

    #[test]
    fn single_replica_is_always_zero() {
        let p = prompt(7, 40);
        assert_eq!(route_replica(1, &[99], &p, PT, None, LOAD_SLACK), 0);
        assert_eq!(route_replica(1, &[99], &p, PT, Some(5), LOAD_SLACK), 0);
        assert_eq!(route_replica(1, &[99], &p, 0, None, LOAD_SLACK), 0);
    }

    #[test]
    fn same_first_page_routes_together_deterministically() {
        // two prompts sharing their first page but diverging after it
        // must land on the same replica, call after call
        let a = prompt(3, 64);
        let mut b = a.clone();
        for t in b.iter_mut().skip(PT) {
            *t += 9000;
        }
        let loads = [0usize; 4];
        let ra = route_replica(4, &loads, &a, PT, None, LOAD_SLACK);
        let rb = route_replica(4, &loads, &b, PT, None, LOAD_SLACK);
        assert_eq!(ra, rb, "shared first page must collocate");
        for _ in 0..8 {
            assert_eq!(route_replica(4, &loads, &a, PT, None, LOAD_SLACK), ra);
        }
    }

    #[test]
    fn distinct_prefix_families_spread_across_replicas() {
        let loads = [0usize; 4];
        let mut hit = [false; 4];
        let mut moved = 0;
        for f in 0..64 {
            let p = prompt(f, 2 * PT);
            let r = route_replica(4, &loads, &p, PT, None, LOAD_SLACK);
            hit[r] = true;
            // rendezvous stability: dropping to 3 replicas only remaps
            // families whose argmax was replica 3
            let r3 = route_replica(3, &loads[..3], &p, PT, None, LOAD_SLACK);
            if r < 3 && r3 != r {
                moved += 1;
            }
        }
        assert!(hit.iter().filter(|&&h| h).count() >= 2,
                "64 families all hashed to one replica");
        assert_eq!(moved, 0, "resize remapped families whose primary survived");
    }

    #[test]
    fn sub_page_prompts_go_least_loaded() {
        let p = prompt(5, PT - 1);
        assert_eq!(route_replica(3, &[4, 1, 2], &p, PT, None, LOAD_SLACK), 1);
        // page_tokens == 0 (monolithic mode): also pure least-loaded
        let long = prompt(5, 10 * PT);
        assert_eq!(route_replica(3, &[4, 1, 2], &long, 0, None, LOAD_SLACK), 1);
    }

    #[test]
    fn overloaded_primary_falls_back_to_least_loaded() {
        let p = prompt(11, 64);
        let even = [0usize; 4];
        let primary = route_replica(4, &even, &p, PT, None, LOAD_SLACK);
        // pile load onto the affinity pick until it crosses the slack
        let mut loads = [0usize; 4];
        loads[primary] = LOAD_SLACK; // at the boundary: still affine
        assert_eq!(route_replica(4, &loads, &p, PT, None, LOAD_SLACK), primary);
        loads[primary] = LOAD_SLACK + 1; // past it: demoted
        let r = route_replica(4, &loads, &p, PT, None, LOAD_SLACK);
        assert_ne!(r, primary);
        assert_eq!(loads[r], 0);
    }

    #[test]
    fn session_home_pin_beats_hash_and_load() {
        let p = prompt(2, 64);
        let loads = [0usize, 1000, 0, 0];
        assert_eq!(route_replica(4, &loads, &p, PT, Some(1), LOAD_SLACK), 1,
                   "pin wins even over a heavily loaded replica");
        // a stale pin from a larger fleet is ignored, not trusted
        let r = route_replica(4, &loads, &p, PT, Some(9), LOAD_SLACK);
        assert!(r < 4);
        assert_ne!(r, 1);
    }
}
