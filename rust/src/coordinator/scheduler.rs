//! Iteration-level scheduler: the per-step token budget and the
//! chunked-prefill planner (DESIGN.md §Scheduler).
//!
//! Each [`Engine::step`](crate::coordinator::Engine::step) asks the
//! scheduler to build a [`StepPlan`]: **one decode token per decoding
//! sequence** (decode-first, so time-between-tokens stays flat no matter
//! what arrives), then the remaining budget goes to prefill — group-
//! aligned chunks granted to the *oldest* partially-prefilled request
//! first, then to fresh admissions popped through the batcher's bounded
//! lookahead.  The scheduler owns every admission decision; the engine
//! owns execution (forward passes, memory charges, the pressure ladder).
//!
//! Budget semantics (`--step-tokens N`):
//!
//! * `N == 0` — **legacy mode, bit-for-bit**: no budget; an admission
//!   prefills its whole prompt inline (the pre-scheduler engine).  Every
//!   grant is a full-prompt completing grant.
//! * `N > 0` — **chunked**: planned work per step never exceeds `N`
//!   tokens, *except* that decode is never skipped — when the decoding
//!   lane count alone exceeds `N`, the step runs those lanes and grants
//!   no prefill.  A completing grant reserves one extra token for the
//!   promoted lane's same-step decode, so the invariant is exact:
//!   `prefill + decode ≤ max(N, decoding lanes at plan time)`.
//!   Sizing rule: `N ≥ max_batch + group + 1` guarantees the oldest
//!   prefill progresses every step — including the final group-sized
//!   remainder plus its reserved promotion token — even with a full
//!   decode batch; smaller budgets only progress as decoders retire.
//!
//! Chunk alignment: a request's prefill boundary always lands on a
//! quant-group boundary — partial grants are group multiples (adopted
//! prefix pages are page- hence group-aligned, so resumed chunks stay
//! aligned) — and only the final, completing grant may carry the
//! sub-group remainder.  This keeps every sealed page bit-uniform and
//! composes with the prefix-cache adoption path
//! (DESIGN.md §Prefix-Sharing).  Grants are additionally clamped to the
//! largest compiled bucket (`max_chunk`), which is what lets a chunked
//! engine prefill prompts *longer* than any bucket — the legacy path
//! cannot.

use anyhow::{bail, Result};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::request::Request;
use crate::kvcache::MemoryBudget;

/// The per-step budget policy.  Stateless between steps: all mutable
/// bookkeeping lives in the [`StepPlan`] the engine threads through one
/// `step()` call.
#[derive(Debug, Clone, Copy)]
pub struct Scheduler {
    /// per-step token budget (0 = legacy whole-prefill mode)
    step_tokens: usize,
    /// quant group size — the chunk alignment unit
    group: usize,
    /// largest prefill chunk the runtime can execute (largest compiled
    /// bucket, rounded down to a group multiple)
    max_chunk: usize,
}

/// What one engine step planned and executed, in tokens.  Built
/// incrementally: `begin_step` seeds the decode lanes, each admission and
/// chunk grant accumulates, and the engine reads the totals for the
/// budget-utilization gauge (`Metrics::budget_util`).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepPlan {
    /// decode tokens: one per lane decoding at plan time, plus one per
    /// completing grant (the promoted lane decodes this same step)
    pub decode_tokens: usize,
    /// prompt tokens granted to prefill chunks this step
    pub prefill_tokens: usize,
    /// requests admitted from the queue this step
    pub admissions: usize,
    /// chunk grants issued this step
    pub chunks: usize,
}

impl StepPlan {
    /// Total tokens this step will run.
    pub fn total_tokens(&self) -> usize {
        self.decode_tokens + self.prefill_tokens
    }
}

/// One prefill grant for one request.
#[derive(Debug, Clone, Copy)]
pub struct ChunkGrant {
    /// prompt tokens to prefill now (a group multiple unless `completes`)
    pub tokens: usize,
    /// this grant reaches the end of the prompt: sample the first token
    /// and promote the lane to `Decoding`
    pub completes: bool,
}

impl Scheduler {
    /// `step_tokens == 0` keeps the legacy whole-prefill behavior;
    /// otherwise the budget must *exceed* one quant group: a completing
    /// grant for a group-sized final remainder costs `group + 1` tokens
    /// (the remainder plus the reserved promotion decode), so a budget
    /// of exactly `group` could admit a group-aligned prompt it can
    /// never finish.  `max_chunk` is the largest row count the
    /// runtime's compiled buckets admit.
    pub fn new(step_tokens: usize, group: usize, max_chunk: usize) -> Result<Self> {
        if group == 0 {
            bail!("scheduler needs a positive quant group");
        }
        if step_tokens > 0 && step_tokens <= group {
            bail!("--step-tokens {step_tokens} must exceed the quant group {group}: \
                   a group-sized final remainder needs {} tokens (remainder + its \
                   promotion decode) to ever complete \
                   (use 0 for the unbudgeted legacy mode)", group + 1);
        }
        let max_chunk = max_chunk / group * group;
        if step_tokens > 0 && max_chunk == 0 {
            bail!("largest compiled bucket is smaller than the quant group {group}: \
                   no group-aligned chunk is executable (--step-tokens needs 0 here)");
        }
        Ok(Scheduler { step_tokens, group, max_chunk })
    }

    /// Chunked-prefill mode (`--step-tokens > 0`)?
    pub fn chunked(&self) -> bool {
        self.step_tokens > 0
    }

    /// Open a step's plan: decode-first, one token per decoding lane.
    ///
    /// Ordering contract with the engine's retire paths: the engine runs
    /// its deadline sweep *before* calling this, so `decoding_lanes`
    /// never counts a lane that expires this step — an expired or
    /// cancelled sequence is retired without ever reserving decode budget
    /// or receiving a prefill chunk (`Engine::cancel` runs between steps
    /// for the same reason; DESIGN.md §Serving-Protocol).
    pub fn begin_step(&self, decoding_lanes: usize) -> StepPlan {
        StepPlan { decode_tokens: decoding_lanes, ..StepPlan::default() }
    }

    /// Unspent budget available to prefill (`usize::MAX` in legacy mode —
    /// the legacy engine admits on slots and memory alone).
    pub fn remaining(&self, plan: &StepPlan) -> usize {
        if !self.chunked() {
            return usize::MAX;
        }
        self.step_tokens.saturating_sub(plan.total_tokens())
    }

    /// May the engine pop another admission this step?  Slots and memory
    /// are the batcher's business; the scheduler refuses unless the
    /// remaining budget guarantees the admitted request an immediate
    /// non-empty grant — an admission that received no chunk would hold
    /// a batch slot (and any adopted prefix pages) without progressing,
    /// when it should have stayed in the Waiting queue.
    ///
    /// `remaining > group` is exactly that guarantee: a remainder under
    /// one group completes within `group + 1` tokens (sub-group tokens
    /// plus the reserved promotion decode), and any larger remainder
    /// yields a partial grant of at least one group.
    pub fn can_admit(&self, plan: &StepPlan) -> bool {
        !self.chunked() || self.remaining(plan) > self.group
    }

    /// Pop the next admissible request through the batcher's bounded
    /// lookahead — the scheduler-owned admission decision.  `reuse`
    /// is the prefix-cache discount probe (DESIGN.md §Prefix-Sharing).
    pub fn admit(&self, plan: &mut StepPlan, batcher: &mut Batcher, active: usize,
                 budget: &MemoryBudget, reuse: &dyn Fn(&Request) -> usize)
                 -> Option<Request> {
        if !self.can_admit(plan) {
            return None;
        }
        let req = batcher.admit_with_reuse(active, budget, reuse)?;
        plan.admissions += 1;
        Some(req)
    }

    /// Grant the next prefill chunk to a request with `remaining_prompt`
    /// unprefilled tokens.  Legacy mode always grants the whole prompt.
    /// Chunked mode grants, in order of preference:
    ///
    /// 1. a **completing** grant — the whole remainder plus one reserved
    ///    decode token for the promotion, when both fit the budget and
    ///    the remainder fits one bucket;
    /// 2. a **partial** grant — the largest group multiple that fits the
    ///    remaining budget, the bucket clamp, and is strictly smaller
    ///    than the remainder (so completion always goes through rule 1
    ///    and its reserved decode token);
    /// 3. `None` — not even one group fits; the request stays
    ///    `Prefilling` and the next step's budget serves it first.
    pub fn grant_chunk(&self, plan: &mut StepPlan, remaining_prompt: usize)
                       -> Option<ChunkGrant> {
        debug_assert!(remaining_prompt > 0, "nothing left to prefill");
        if !self.chunked() {
            plan.prefill_tokens += remaining_prompt;
            plan.decode_tokens += 1;
            plan.chunks += 1;
            return Some(ChunkGrant { tokens: remaining_prompt, completes: true });
        }
        let rem = self.remaining(plan);
        if remaining_prompt <= self.max_chunk && remaining_prompt + 1 <= rem {
            plan.prefill_tokens += remaining_prompt;
            plan.decode_tokens += 1;
            plan.chunks += 1;
            return Some(ChunkGrant { tokens: remaining_prompt, completes: true });
        }
        // partial: group-aligned, under budget and bucket, strictly short
        // of the remainder
        let cap = rem.min(self.max_chunk).min(remaining_prompt.saturating_sub(1));
        let tokens = cap / self.group * self.group;
        if tokens == 0 {
            return None;
        }
        plan.prefill_tokens += tokens;
        plan.chunks += 1;
        Some(ChunkGrant { tokens, completes: false })
    }

    /// Fraction of the step budget actually planned (`None` in legacy
    /// mode).  Can exceed 1.0 when decode lanes alone exceed the budget —
    /// the overload signal the gauge exists to surface.
    pub fn utilization(&self, plan: &StepPlan) -> Option<f64> {
        self.chunked()
            .then(|| plan.total_tokens() as f64 / self.step_tokens as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const G: usize = 32;

    fn sched(step: usize) -> Scheduler {
        Scheduler::new(step, G, 256).unwrap()
    }

    #[test]
    fn rejects_sub_group_budget() {
        assert!(Scheduler::new(16, 32, 256).is_err());
        assert!(Scheduler::new(0, 32, 256).is_ok(), "0 = legacy mode");
        assert!(Scheduler::new(32, 32, 256).is_err(),
                "group-sized budget can never complete a group-aligned prompt");
        assert!(Scheduler::new(33, 32, 256).is_ok(), "group + 1 is the floor");
        assert!(Scheduler::new(64, 32, 16).is_err(), "bucket below group");
        assert!(Scheduler::new(0, 32, 16).is_ok(),
                "legacy mode never executes chunks, so the bucket is moot");
    }

    #[test]
    fn admission_gate_requires_a_grantable_budget() {
        let s = sched(64);
        // the gate opens only when the remaining budget guarantees the
        // admitted request an immediate non-empty grant (> one group)
        assert!(s.can_admit(&s.begin_step(0)));
        assert!(s.can_admit(&s.begin_step(31)), "remaining 33 > group");
        assert!(!s.can_admit(&s.begin_step(32)),
                "remaining 32 == group: a group-sized remainder could not be granted");
        assert!(!s.can_admit(&s.begin_step(64)));
        // legacy mode never gates
        assert!(sched(0).can_admit(&sched(0).begin_step(10_000)));
    }

    #[test]
    fn legacy_mode_grants_whole_prompt() {
        let s = sched(0);
        assert!(!s.chunked());
        let mut plan = s.begin_step(3);
        let g = s.grant_chunk(&mut plan, 517).unwrap();
        assert!(g.completes);
        assert_eq!(g.tokens, 517);
        assert_eq!(plan.decode_tokens, 4, "promotion decodes this step");
        assert!(s.can_admit(&plan));
        assert_eq!(s.utilization(&plan), None);
    }

    #[test]
    fn decode_first_prefill_gets_the_remainder() {
        let s = sched(100);
        let mut plan = s.begin_step(90);
        // 10 tokens left: one 32-token group does not fit -> no grant
        assert!(s.grant_chunk(&mut plan, 512).is_none());
        // a tiny completing remainder does fit (4 + 1 promotion <= 10)
        let g = s.grant_chunk(&mut plan, 4).unwrap();
        assert!(g.completes);
        assert_eq!(plan.total_tokens(), 95);
    }

    #[test]
    fn partial_grants_are_group_aligned_and_strictly_short() {
        let s = sched(128);
        let mut plan = s.begin_step(2);
        // remainder exactly fills the budget: must stay partial (no room
        // for the promotion token) and round down to a group multiple
        let g = s.grant_chunk(&mut plan, 126).unwrap();
        assert!(!g.completes);
        assert_eq!(g.tokens % G, 0);
        assert!(g.tokens < 126);
        assert_eq!(g.tokens, 96);
    }

    #[test]
    fn completing_grant_reserves_promotion_token() {
        let s = sched(64);
        let mut plan = s.begin_step(0);
        // 64 left, budget 64: 64+1 > 64 -> partial 32, not a completion
        let g = s.grant_chunk(&mut plan, 64).unwrap();
        assert!(!g.completes);
        assert_eq!(g.tokens, 32);
        // 63 left, budget still 32: 63 <= bucket but 63+1 > 32 -> partial
        let g2 = s.grant_chunk(&mut plan, 63).unwrap();
        assert!(!g2.completes);
        assert_eq!(g2.tokens, 32);
        assert_eq!(plan.total_tokens(), 64);
        assert_eq!(s.remaining(&plan), 0);
        assert!(!s.can_admit(&plan));
    }

    #[test]
    fn grants_clamp_to_the_bucket() {
        let s = Scheduler::new(4096, G, 200).unwrap(); // max_chunk -> 192
        let mut plan = s.begin_step(0);
        let g = s.grant_chunk(&mut plan, 4000).unwrap();
        assert!(!g.completes);
        assert_eq!(g.tokens, 192);
        // a remainder over the bucket can never complete in one grant
        let g2 = s.grant_chunk(&mut plan, 193).unwrap();
        assert!(!g2.completes);
    }

    #[test]
    fn budget_never_exceeded_randomized() {
        let mut rng = Rng::new(0x5CED);
        for case in 0..200 {
            let budget = G * rng.range(1, 9) + 1; // 33..257, always > group
            let s = Scheduler::new(budget, G, G * rng.range(1, 9)).unwrap();
            let d0 = rng.range(0, 2 * budget);
            let mut plan = s.begin_step(d0);
            let mut boundary = 0usize; // simulated prefill boundary
            for _ in 0..rng.range(1, 8) {
                let remaining = rng.range(1, 600);
                if let Some(g) = s.grant_chunk(&mut plan, remaining) {
                    assert!(g.tokens <= remaining, "case {case}");
                    if g.completes {
                        boundary = 0;
                    } else {
                        assert_eq!(g.tokens % G, 0, "case {case}: unaligned chunk");
                        boundary += g.tokens;
                        assert_eq!(boundary % G, 0, "case {case}");
                    }
                }
                assert!(plan.total_tokens() <= budget.max(d0),
                        "case {case}: {} tokens over budget {budget} (d0 {d0})",
                        plan.total_tokens());
            }
            if let Some(u) = s.utilization(&plan) {
                assert!(u <= (budget.max(d0) as f64 / budget as f64) + 1e-9);
            }
        }
    }

    #[test]
    fn no_starvation_under_sustained_decode_load() {
        // 4 decoders hold 4 budget tokens every step; the prefill still
        // receives (budget - decode) rounded to groups each step and a
        // 512-token prompt completes within the arithmetic bound
        let s = sched(4 + 2 * G);
        let mut remaining = 512usize;
        let mut steps = 0;
        while remaining > 0 {
            let mut plan = s.begin_step(4);
            if let Some(g) = s.grant_chunk(&mut plan, remaining) {
                remaining -= g.tokens;
            }
            steps += 1;
            assert!(steps <= 512 / G + 2, "prefill starved: {remaining} left");
        }
        assert!(steps >= 512 / (2 * G), "completed implausibly fast");
    }

    #[test]
    fn oldest_prefill_first_is_engine_ordering() {
        // the scheduler grants to whatever lane the engine offers first;
        // the engine offers lanes in admission order — pin the plan-level
        // consequence: a second prefill sees only what the first left
        let s = sched(128);
        let mut plan = s.begin_step(0);
        let g1 = s.grant_chunk(&mut plan, 512).unwrap(); // oldest
        assert_eq!(g1.tokens, 128, "oldest prefill takes the whole budget");
        assert!(s.grant_chunk(&mut plan, 512).is_none(),
                "a younger prefill gets nothing once the budget is spent");
    }
}
