//! Request types flowing through the serving coordinator.

use crate::kvcache::SeqKvCache;
use crate::model::Sampler;

pub type RequestId = u64;

/// An inference request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// stop decoding at this token (None = run to max_new_tokens)
    pub stop_token: Option<i32>,
    /// submission timestamp (engine clock, ns)
    pub submitted_ns: u64,
}

/// A request admitted into the running batch.
pub struct ActiveRequest {
    pub req: Request,
    pub cache: SeqKvCache,
    pub generated: Vec<i32>,
    /// next input token for the decode step
    pub next_input: i32,
    pub prefilled_ns: u64,
    pub first_token_ns: Option<u64>,
}

impl ActiveRequest {
    pub fn is_done(&self) -> bool {
        if self.generated.len() >= self.req.max_new_tokens {
            return true;
        }
        if let (Some(stop), Some(&last)) = (self.req.stop_token, self.generated.last()) {
            return last == stop;
        }
        false
    }
}

/// A finished request with its generation and timing.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub submitted_ns: u64,
    pub first_token_ns: u64,
    pub finished_ns: u64,
}

impl Completion {
    pub fn ttft_ms(&self) -> f64 {
        (self.first_token_ns - self.submitted_ns) as f64 / 1e6
    }

    pub fn total_ms(&self) -> f64 {
        (self.finished_ns - self.submitted_ns) as f64 / 1e6
    }
}
