//! Request types flowing through the serving coordinator, including the
//! explicit per-request lifecycle the iteration-level scheduler drives
//! (DESIGN.md §Scheduler).

use crate::kvcache::SeqKvCache;
use crate::model::Sampler;

pub type RequestId = u64;

/// An inference request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// stop decoding at this token (None = run to max_new_tokens)
    pub stop_token: Option<i32>,
    /// admission priority: higher admits sooner; equal priorities keep
    /// FIFO order, and the default 0 is bit-for-bit the pre-priority
    /// queue (`"priority"` on the wire — DESIGN.md §Serving-Protocol)
    pub priority: i32,
    /// serving deadline relative to submission (`"deadline_ms"` on the
    /// wire): the engine's deadline sweep retires the request — waiting
    /// or mid-decode — with [`FinishReason::Deadline`] once
    /// `now - submitted_ns` exceeds it.  None = no deadline.
    pub deadline_ms: Option<u64>,
    /// submission timestamp (engine clock, ns)
    pub submitted_ns: u64,
    /// session key for park/resume (`"session"` on the wire —
    /// DESIGN.md §Serving-Protocol): on a Length/Stop finish the
    /// request's KV pages park under this key instead of freeing, and a
    /// later request naming the same key whose prompt extends the parked
    /// conversation resumes from those pages without re-quantizing them.
    /// None = free on finish (the pre-session behaviour, bit-for-bit).
    pub session: Option<u64>,
}

/// Where a request sits in the scheduler's state machine
/// (DESIGN.md §Scheduler).  `Waiting` lives implicitly in the batcher
/// queue; the variants below describe an [`ActiveRequest`].  A preempted
/// request is requeued (back to `Waiting`) and restarted from scratch —
/// the preempt-restart policy — so `Preempted` is a transition, not a
/// resident state.
///
/// ```text
/// Waiting ──admit──▶ Prefilling{done} ──chunks──▶ Decoding ──▶ Done
///    ▲                    │                          │
///    └──────(preempt-restart: requeue front)─────────┘
///
/// any state ──cancel / deadline──▶ retired (terminal)
/// ```
///
/// Cancellation ([`crate::coordinator::Engine::cancel`]) and deadline
/// expiry are *terminal transitions out of any state*, not resident
/// states: the sequence is removed from the queue or the running batch
/// between steps, its pool pages are freed, and the client receives a
/// final frame whose finish reason is [`FinishReason::Cancelled`] /
/// [`FinishReason::Deadline`] with whatever tokens were generated so far.
///
/// With `--step-tokens 0` (the legacy whole-prefill path) an admission
/// jumps straight from `Waiting` to `Decoding`: the full prompt is
/// prefilled inline and `Prefilling` is never observable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// mid-prompt: `done` prompt tokens are already in the cache
    /// (prefix-adopted pages count as done); the scheduler grants this
    /// request group-aligned chunks until the prompt completes
    Prefilling { done: usize },
    /// prompt fully prefilled; one decode token per step
    Decoding,
}

/// A request admitted into the running batch.
pub struct ActiveRequest {
    pub req: Request,
    pub cache: SeqKvCache,
    pub state: Lifecycle,
    pub generated: Vec<i32>,
    /// next input token for the decode step (meaningful once `Decoding`)
    pub next_input: i32,
    pub prefilled_ns: u64,
    pub first_token_ns: Option<u64>,
    /// when this request's latest token was emitted (feeds the
    /// time-between-tokens histogram, `Metrics::tbt_ms`)
    pub last_token_ns: u64,
}

impl ActiveRequest {
    /// Prompt tokens already resident in the cache.
    pub fn prefilled(&self) -> usize {
        match self.state {
            Lifecycle::Prefilling { done } => done,
            Lifecycle::Decoding => self.req.prompt.len(),
        }
    }

    /// Prompt tokens still to prefill (0 once decoding).
    pub fn prompt_remaining(&self) -> usize {
        self.req.prompt.len() - self.prefilled()
    }

    pub fn is_decoding(&self) -> bool {
        self.state == Lifecycle::Decoding
    }

    pub fn is_done(&self) -> bool {
        if !self.is_decoding() {
            return false;
        }
        if self.generated.len() >= self.req.max_new_tokens {
            return true;
        }
        if let (Some(stop), Some(&last)) = (self.req.stop_token, self.generated.last()) {
            return last == stop;
        }
        false
    }
}

/// A request the engine determined can never be admitted (its projected
/// footprint exceeds what the budget could ever free).  The server maps
/// this to a terminal rejection frame (`{"id":…,"error":…}` — no
/// `retry_after_ms`, retrying cannot help) for the one offending client;
/// the engine keeps stepping for everyone else.
#[derive(Debug, Clone)]
pub struct Rejection {
    pub id: RequestId,
    pub reason: String,
}

/// Why a request stopped decoding — carried on every [`Completion`] and
/// serialized verbatim into the final response frame's `"finish"` field
/// (DESIGN.md §Serving-Protocol).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// generated `max_new_tokens`
    Length,
    /// emitted the request's stop token
    Stop,
    /// client cancel frame or disconnect ([`crate::coordinator::Engine::cancel`])
    Cancelled,
    /// per-request deadline expired before completion
    Deadline,
}

impl FinishReason {
    /// Wire spelling for the final frame's `"finish"` field.
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Deadline => "deadline",
        }
    }
}

/// A finished request with its generation, finish reason and timing.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    pub submitted_ns: u64,
    pub first_token_ns: u64,
    pub finished_ns: u64,
}

impl Completion {
    pub fn ttft_ms(&self) -> f64 {
        (self.first_token_ns - self.submitted_ns) as f64 / 1e6
    }

    pub fn total_ms(&self) -> f64 {
        (self.finished_ns - self.submitted_ns) as f64 / 1e6
    }

    /// Mean time between tokens of this request (ms) — `None` below two
    /// tokens, where the gap is undefined.  This per-request statistic
    /// rides the final response frame; the cross-request distribution
    /// (p50/p99) lives in `Metrics::tbt_ms`.
    pub fn tbt_ms(&self) -> Option<f64> {
        if self.tokens.len() < 2 {
            return None;
        }
        let span = (self.finished_ns - self.first_token_ns) as f64 / 1e6;
        Some(span / (self.tokens.len() - 1) as f64)
    }
}
