//! The serving engine: continuous-batching loop over the PJRT-backed
//! forward pass and the mixed-precision caches.
//!
//! One `step()` is a **plan → execute → charge/relieve → retire**
//! pipeline (DESIGN.md §Scheduler): the iteration-level
//! [`Scheduler`] builds a [`StepPlan`] — one decode token per decoding
//! sequence, the remaining `--step-tokens` budget as group-aligned
//! prefill chunks to the oldest mid-prompt request, then fresh
//! admissions — the engine executes the planned forward passes, charges
//! the memory budget (running the pressure ladder on overflow), and
//! retires completions.  `--step-tokens 0` disables the budget and keeps
//! the legacy shape bit-for-bit: an admission prefills its whole prompt
//! inline before the decode batch runs (`rust/tests/scheduler.rs` pins
//! the identity).
//!
//! Two memory regimes (DESIGN.md §Memory-Manager):
//!
//! * **Monolithic** (`page_tokens == 0`, the pre-pool behavior): each
//!   sequence is charged its exact modeled bytes; a simulated OOM evicts
//!   the *youngest* request back to the queue and counts an `oom_event`
//!   (preempt-restart, the usual vLLM recompute policy).
//! * **Paged** (`page_tokens > 0`): sequences map onto a global
//!   [`PagePool`] and the budget is charged at page granularity.  Under
//!   pressure — admission failure or simulated OOM — the engine first
//!   requantizes the oldest out-of-window *unshared* pages down the bit
//!   ladder (bounded by the per-layer gradient-importance floors), then
//!   evicts LRU prefix-index entries, then (with `--spill-dir`) spills
//!   sealed cold pages to the disk tier and drops parked sessions
//!   (DESIGN.md §Spill-Tier), and only when every rung is exhausted
//!   preempts the lowest-priority (youngest) sequence; `oom_events` then
//!   only counts the unrecoverable case.
//!
//! With a `"session"` key on the request (paged mode), a Length/Stop
//! finish *parks* the conversation's pages under that key instead of
//! freeing them, and the session's next turn — whose prompt must extend
//! the parked conversation exactly — *resumes* by adopting the parked
//! turn's page-aligned prompt-prefix pages, prefix-sharing style, so the
//! dense replay stays bit-identical to a cold prefill of the
//! concatenated conversation while skipping its prefix re-quantization
//! (DESIGN.md §Serving-Protocol).
//!
//! With `--prefix-cache` (paged mode only), admission additionally runs
//! the shared-prefix path (DESIGN.md §Prefix-Sharing): hash the longest
//! whole-page-aligned shareable prompt prefix, adopt a registered hit's
//! quantized pages into the new sequence as refcounted read-only frames
//! (charged once, skipping their re-quantization), prefill only the
//! unshared suffix into the cache — the dense forward still covers the
//! full prompt, so logits and sampled tokens stay bit-identical — and
//! register the new sequence's own aligned prefix once its prefill
//! completes.  Chunked prefills compose: adopted pages count as already-
//! prefilled tokens and the first chunk resumes at the (page- hence
//! group-aligned) adoption boundary.
//!
//! A request whose projected footprint can *never* be admitted no longer
//! tears the engine down: it is popped into [`Engine::take_rejections`]
//! (the server maps it to one terminal rejection frame) and stepping
//! continues for everyone else.
//!
//! Two early-retirement paths ride the same step loop (DESIGN.md
//! §Serving-Protocol): a **deadline sweep** at the top of [`Engine::step`]
//! retires every request whose `deadline_ms` expired — waiting or active —
//! before the scheduler plans (an expired lane gets no decode
//! reservation), and [`Engine::cancel`] retires one request by id
//! *between* steps (the serve loop calls it for client cancel frames and
//! disconnects).  Both free the sequence's pool pages immediately and
//! neither counts as a completion in the metrics.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::baselines::Method;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ActiveRequest, Completion, FinishReason, Lifecycle,
                                  Rejection, Request, RequestId};
use crate::coordinator::scheduler::{ChunkGrant, Scheduler, StepPlan};
use crate::kvcache::{pressure, KeyRepr, KvSide, MemoryBudget, PagePool, PressureCfg,
                     SeqKvCache, ValueRepr, KV_SIDES};
use crate::model::{DecodeScratch, Forward};
use crate::runtime::Runtime;
use crate::util::{Rng, WorkerPool};

#[derive(Clone)]
pub struct EngineCfg {
    pub method: Method,
    pub max_batch: usize,
    /// simulated HBM budget for KV (bytes); None = unlimited
    pub kv_budget: Option<usize>,
    /// worker threads for the decode attention fan-out
    /// (0 = one per available core, 1 = sequential).  The engine itself
    /// only *uses* a pool handed to [`Engine::with_pool`]; this knob is
    /// how `--threads` travels from the CLI to whoever builds the pool
    /// (see `server::serve` and `main.rs`).
    pub threads: usize,
    /// paged KV pool page size in tokens — must be a positive multiple of
    /// the quant group, or 0 to keep the monolithic per-sequence
    /// accounting (DESIGN.md §Memory-Manager; `--page-tokens` on the CLI).
    pub page_tokens: usize,
    /// shared-prefix KV reuse across sequences (`--prefix-cache`;
    /// requires `page_tokens > 0`).  Off = bit-for-bit the pre-sharing
    /// engine (DESIGN.md §Prefix-Sharing).
    pub prefix_cache: bool,
    /// iteration-level scheduler step budget in tokens (`--step-tokens`;
    /// DESIGN.md §Scheduler).  0 = the legacy whole-prefill-at-admission
    /// behavior, bit-for-bit; N > 0 bounds each step to ~N tokens by
    /// splitting prompts into group-aligned chunks (decode-first).
    pub step_tokens: usize,
    /// per-layer (K, V) gradient-importance weights for the pressure
    /// controller's loss-per-byte downshift order
    /// (DESIGN.md §Pressure-Ladder; importance.json `plan.k_scores` /
    /// `plan.v_scores` via `--method kvmix`).  None = the plan-bit proxy
    /// weights from [`PressureCfg::from_plan`].
    pub pressure_weights: Option<(Vec<f64>, Vec<f64>)>,
    /// disk spill directory (`--spill-dir`; requires `page_tokens > 0`):
    /// gives the pressure ladder a spill rung between prefix eviction and
    /// preemption (DESIGN.md §Spill-Tier).  None = no spill tier,
    /// bit-for-bit the pre-spill engine.
    pub spill_dir: Option<PathBuf>,
    /// cap on live spilled bytes (`--spill-bytes`; 0 = unlimited)
    pub spill_bytes: usize,
}

pub struct Engine<'a> {
    pub rt: &'a Runtime,
    cfg: EngineCfg,
    pub batcher: Batcher,
    pub active: Vec<ActiveRequest>,
    pub budget: MemoryBudget,
    pub metrics: Metrics,
    pub completions: Vec<Completion>,
    /// requests the engine determined can never be admitted; drained by
    /// [`Engine::take_rejections`] (the serve loop answers them with ERR
    /// and keeps going)
    pub rejections: Vec<Rejection>,
    scheduler: Scheduler,
    /// largest compiled bucket — the longest prompt the legacy
    /// whole-prefill path can execute (chunked mode is unbounded)
    max_prefill: usize,
    scratch: DecodeScratch,
    rng: Rng,
    /// attention fan-out workers (None = sequential decode)
    pool: Option<&'a WorkerPool>,
    /// paged KV pool (None = monolithic accounting)
    pages: Option<PagePool>,
    /// per-layer requantization floors for the pressure controller
    pressure: PressureCfg,
    /// template cache for prefix-sharing caps at admission time (None
    /// unless `--prefix-cache`): `max_shareable_prefix` only reads the
    /// per-layer window/representation config, so one never-filled
    /// instance serves every projection probe
    probe: Option<SeqKvCache>,
    /// finished conversations parked under their session key, keeping
    /// their pool pages for a next-turn resume (paged mode only;
    /// DESIGN.md §Serving-Protocol)
    parked: BTreeMap<u64, ParkedSession>,
}

/// A finished conversation whose KV pages stayed in the pool under its
/// session key for a next-turn resume without re-quantizing the shared
/// prefix (DESIGN.md §Serving-Protocol).  `gid` is the pool owner the
/// pages still sit under; `prompt_len` bounds resume adoption to
/// prefill-derived pages — decode-derived K/V differs from what a dense
/// prefill of the concatenated conversation produces at layers past the
/// first, so adopting those pages would break resume bit-identity.
struct ParkedSession {
    gid: RequestId,
    prompt_len: usize,
    /// the full conversation so far: prompt + generated tokens (the next
    /// turn's prompt must extend this exactly to resume)
    tokens: Vec<i32>,
    cache: SeqKvCache,
}

impl<'a> Engine<'a> {
    /// Sequential engine (no attention fan-out).
    pub fn new(rt: &'a Runtime, cfg: EngineCfg) -> Result<Self> {
        Self::with_pool(rt, cfg, None)
    }

    /// Engine whose decode/prefill attention fans out across `pool`
    /// (`None` behaves exactly like [`Engine::new`]).  Everything that
    /// touches the PJRT client stays on the thread calling
    /// [`Engine::step`]; only the pure-Rust cache attention is fanned out
    /// (DESIGN.md §Threading-Model).
    pub fn with_pool(rt: &'a Runtime, cfg: EngineCfg,
                     pool: Option<&'a WorkerPool>) -> Result<Self> {
        let max_bucket = rt.buckets.iter().copied().max().unwrap_or(1);
        let max_batch = cfg.max_batch.min(max_bucket);
        // bytes/token estimate for admission: steady-state modeled bytes of
        // the policy at a reference length
        let bpt = estimate_bytes_per_token(rt, &cfg.method);
        let capacity = cfg.kv_budget.unwrap_or(usize::MAX / 2);
        // the attached pool is the source of truth for parallelism; keep
        // the stored cfg consistent with it so the two can't diverge
        let threads = pool.map(|p| p.threads()).unwrap_or(1);
        let pages = if cfg.page_tokens > 0 {
            let mut pool = PagePool::new(cfg.page_tokens, rt.model.kv_dim(), rt.model.group)?;
            if cfg.prefix_cache {
                pool.enable_prefix_cache();
            }
            if let Some(dir) = &cfg.spill_dir {
                pool.enable_spill(dir, cfg.spill_bytes)?;
            }
            Some(pool)
        } else if cfg.prefix_cache {
            anyhow::bail!("--prefix-cache needs the paged KV pool: set --page-tokens N \
                           (prefix sharing is page-aligned — DESIGN.md §Prefix-Sharing)");
        } else if cfg.spill_dir.is_some() {
            anyhow::bail!("--spill-dir needs the paged KV pool: set --page-tokens N \
                           (spill is page-granular — DESIGN.md §Spill-Tier)");
        } else {
            None
        };
        let scheduler = Scheduler::new(cfg.step_tokens, rt.model.group, max_bucket)?;
        let pressure = match cfg.pressure_weights.clone() {
            Some((k, v)) => cfg.method.pressure_floors(rt.model.n_layers)
                .with_weights(k, v),
            None => cfg.method.pressure_floors(rt.model.n_layers),
        };
        let probe = cfg.prefix_cache.then(|| cfg.method.make_cache(&rt.model));
        Ok(Engine {
            rt,
            batcher: Batcher::new(max_batch, bpt),
            cfg: EngineCfg { max_batch, threads, ..cfg },
            active: Vec::new(),
            budget: MemoryBudget::new(capacity, 0)?,
            metrics: Metrics::default(),
            completions: Vec::new(),
            rejections: Vec::new(),
            scheduler,
            max_prefill: max_bucket,
            scratch: DecodeScratch::default(),
            rng: Rng::new(0xE161),
            pool,
            pages,
            pressure,
            probe,
            parked: BTreeMap::new(),
        })
    }

    pub fn method_name(&self) -> String {
        self.cfg.method.name()
    }

    pub fn submit(&mut self, mut req: Request) {
        req.submitted_ns = self.metrics.now_ns();
        // legacy prefill runs the whole prompt through one bucketized
        // executable: a prompt beyond the largest bucket would error out
        // of `Runtime::bucket_for` mid-step and (pre-PR 5) tear down the
        // serve loop.  Screen it here as a per-request rejection instead;
        // chunked mode has no such limit (chunks clamp to the bucket).
        if !self.scheduler.chunked() && req.prompt.len() > self.max_prefill {
            self.rejections.push(Rejection {
                id: req.id,
                reason: format!(
                    "cannot admit: prompt of {} tokens exceeds the largest compiled \
                     bucket ({}) — unservable without --step-tokens chunking",
                    req.prompt.len(), self.max_prefill),
            });
            return;
        }
        self.batcher.submit(req);
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.batcher.waiting() == 0
    }

    /// Drain the requests rejected as never-admittable.  Stall-path
    /// rejections (projected footprint beyond what relief could free)
    /// are counted as `oom_events`; submit-time over-bucket rejections
    /// are not memory events and only appear here.  The serve loop
    /// answers each with a terminal rejection frame;
    /// [`Engine::run_to_completion`] turns the first one into an error so
    /// one-shot harnesses keep their OOM semantics.
    pub fn take_rejections(&mut self) -> Vec<Rejection> {
        std::mem::take(&mut self.rejections)
    }

    /// One scheduler iteration — deadline sweep, then plan, execute,
    /// charge/relieve, retire; returns every request retired this step
    /// (normal completions *and* deadline expiries, distinguishable by
    /// [`Completion::finish`]).
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let t0 = std::time::Instant::now();
        let fwd = Forward::with_pool(self.rt, self.pool);

        // ---- deadline sweep (before planning: an expired lane must not
        //      receive a decode reservation or prefill chunk) ----
        let mut done = self.sweep_deadlines()?;

        // ---- plan + prefill execution ----
        let decoding = self.active.iter().filter(|a| a.is_decoding()).count();
        let mut plan = self.scheduler.begin_step(decoding);
        self.admit_and_prefill(&fwd, &mut plan)?;

        // ---- one batched decode step + charge/relieve ----
        self.decode_and_relieve(&fwd)?;

        // ---- retire ----
        done.extend(self.retire_done()?);
        if let Some(u) = self.scheduler.utilization(&plan) {
            self.metrics.budget_util.record(u);
        }
        self.metrics.step_us.record(t0.elapsed().as_micros() as f64);
        Ok(done)
    }

    /// Retire every request whose `deadline_ms` has expired: waiting
    /// requests leave the queue with zero tokens, active lanes leave the
    /// batch with their partial generation, and both free their pool
    /// pages.  Runs at the top of each step so expired lanes never plan
    /// (DESIGN.md §Serving-Protocol).
    fn sweep_deadlines(&mut self) -> Result<Vec<Completion>> {
        let now = self.metrics.now_ns();
        let expired = |r: &Request| match r.deadline_ms {
            Some(ms) => now.saturating_sub(r.submitted_ns) >= ms.saturating_mul(1_000_000),
            None => false,
        };
        let mut done = Vec::new();
        let waiting: Vec<RequestId> = self.batcher.queue.iter()
            .filter(|r| expired(r))
            .map(|r| r.id)
            .collect();
        for id in waiting {
            let req = self.batcher.remove(id).expect("id taken from the queue");
            self.metrics.deadline_hits += 1;
            done.push(Completion {
                id, prompt_len: req.prompt.len(), tokens: Vec::new(),
                finish: FinishReason::Deadline,
                submitted_ns: req.submitted_ns, first_token_ns: now, finished_ns: now,
            });
        }
        let mut i = 0;
        while i < self.active.len() {
            if expired(&self.active[i].req) {
                let mut ar = self.active.remove(i);
                if let Some(pool) = &mut self.pages {
                    pool.free_owner(ar.req.id);
                }
                self.metrics.deadline_hits += 1;
                done.push(ar_into_completion(&mut ar, now, FinishReason::Deadline));
            } else {
                i += 1;
            }
        }
        if !done.is_empty() {
            // freed lanes kept the pool counter consistent (free_owner);
            // monolithic mode just re-sums the survivors
            let _ = self.charge_current()?;
        }
        Ok(done)
    }

    /// Retire one request by id *between* steps — the serving protocol's
    /// cancellation hook (client `{"cancel":id}` frames and disconnects;
    /// DESIGN.md §Serving-Protocol).  A waiting request leaves the queue
    /// with zero tokens; an active lane leaves the batch with its partial
    /// generation and its pool pages freed before the next step charges.
    /// Returns `Ok(None)` when `id` is neither waiting nor active
    /// (already finished, or never submitted) — cancellation is then a
    /// no-op and nothing is counted.  An `Err` means the post-free
    /// budget recharge failed, exactly as in [`Engine::sweep_deadlines`].
    ///
    /// The completion is returned to the caller but *not* pushed onto
    /// [`Engine::completions`] and not counted in `metrics.completions`:
    /// a cancelled request is not a served one (it lands in
    /// `metrics.cancellations` instead), and harness transcripts stay
    /// clean of partial generations.
    pub fn cancel(&mut self, id: RequestId) -> Result<Option<Completion>> {
        let now = self.metrics.now_ns();
        if let Some(req) = self.batcher.remove(id) {
            self.metrics.cancellations += 1;
            return Ok(Some(Completion {
                id, prompt_len: req.prompt.len(), tokens: Vec::new(),
                finish: FinishReason::Cancelled,
                submitted_ns: req.submitted_ns, first_token_ns: now, finished_ns: now,
            }));
        }
        let Some(lane) = self.active.iter().position(|a| a.req.id == id) else {
            return Ok(None);
        };
        let mut ar = self.active.remove(lane);
        if let Some(pool) = &mut self.pages {
            pool.free_owner(ar.req.id);
        }
        self.metrics.cancellations += 1;
        let c = ar_into_completion(&mut ar, now, FinishReason::Cancelled);
        let _ = self.charge_current()?;
        Ok(Some(c))
    }

    /// Admission + prefill execution under the step plan.  Paged mode
    /// interleaves admission with pressure relief: when a waiting request
    /// is blocked on memory alone and the pool can still reclaim enough
    /// by downshifting old pages to their floors, requantize one page and
    /// retry (DESIGN.md §Memory-Manager).
    fn admit_and_prefill(&mut self, fwd: &Forward, plan: &mut StepPlan) -> Result<()> {
        let mut admitted_any = false;
        // all-floors reclaimable bound, computed at most once per relief
        // phase and decremented by each downshift's frame-accounting
        // delta.  Plain admissions can only make it underestimate (new
        // pages arrive; we break early instead of grinding too far), but
        // prefix-cache admissions can make it OVERestimate — adoption
        // turns index-only frames into mapped ones and registration makes
        // the donor's pages downshift-exempt — so any admission that ran
        // the prefix machinery invalidates the cache (recomputed on the
        // next relief round).
        let mut reclaim_cache: Option<usize> = None;

        // chunked mode: the budget serves carried-over prefills first,
        // oldest admitted lane first (decode-first already reserved its
        // tokens in `begin_step`)
        if self.scheduler.chunked() {
            for lane in 0..self.active.len() {
                if self.active[lane].is_decoding() {
                    continue;
                }
                let remaining = self.active[lane].prompt_remaining();
                debug_assert!(remaining > 0, "a fully-prefilled lane must be Decoding");
                let Some(grant) = self.scheduler.grant_chunk(plan, remaining) else {
                    continue; // budget-blocked; a smaller remainder may still fit
                };
                if self.execute_chunk(fwd, lane, grant)? {
                    reclaim_cache = None;
                }
            }
        }

        loop {
            while let Some(req) = {
                // admission projects only the *unshared* suffix bytes: a
                // read-only pool probe discounts prompt tokens whose
                // pages a prefix hit would adopt (DESIGN.md
                // §Prefix-Sharing; plain projection when the cache is off)
                let (pages, probe, pt) = (&self.pages, &self.probe, self.cfg.page_tokens);
                let chunked = self.scheduler.chunked();
                let reuse = move |r: &Request| reused_tokens(pages, probe, pt, chunked, r);
                self.scheduler.admit(plan, &mut self.batcher, self.active.len(),
                                     &self.budget, &reuse)
            } {
                admitted_any = true;
                let prefix_ran = if self.scheduler.chunked() {
                    let ran = self.admit_chunked(req)?;
                    let lane = self.active.len() - 1;
                    let remaining = self.active[lane].prompt_remaining();
                    if remaining > 0 {
                        if let Some(grant) = self.scheduler.grant_chunk(plan, remaining) {
                            if self.execute_chunk(fwd, lane, grant)? {
                                reclaim_cache = None;
                            }
                        }
                    }
                    ran
                } else {
                    self.admit_legacy(fwd, plan, req)?
                };
                if prefix_ran {
                    // adoption/registration shifts frames between the
                    // reclaimable categories: stale bound must not
                    // authorize further grinding (see reclaim_cache)
                    reclaim_cache = None;
                }
            }
            if self.pages.is_none()
                || self.active.len() >= self.batcher.max_batch
                || self.batcher.waiting() == 0
                || !self.scheduler.can_admit(plan) {
                break;
            }
            let need = {
                let (pages, probe, pt) = (&self.pages, &self.probe, self.cfg.page_tokens);
                let chunked = self.scheduler.chunked();
                let reuse = move |r: &Request| reused_tokens(pages, probe, pt, chunked, r);
                self.batcher.min_projected_in_lookahead_with(&reuse)
            };
            let Some(need) = need else { break };
            if need <= self.budget.free() {
                break; // nothing is memory-blocked (admit stopped on slots)
            }
            let reclaimable = match reclaim_cache {
                Some(r) => r,
                None => {
                    let page_tokens = self.cfg.page_tokens;
                    let mut r: usize = self.active.iter()
                        .map(|a| pressure::reclaimable_bytes(&a.cache, page_tokens,
                                                            &self.pressure))
                        .sum();
                    // plus what evicting the whole prefix index would free
                    r += self.pages.as_ref()
                        .map(PagePool::prefix_reclaimable_bytes)
                        .unwrap_or(0);
                    reclaim_cache = Some(r);
                    r
                }
            };
            if need > self.budget.free() + reclaimable {
                break; // even all-floors downshift + index eviction cannot fit it
            }
            match self.downshift_once() {
                Some(delta) => {
                    reclaim_cache = Some(reclaimable.saturating_sub(delta));
                }
                // downshift exhausted: evict an LRU prefix entry — it may
                // free index-only frames directly and it un-shares pages,
                // so the reclaimable bound must be recomputed.  Gated on
                // the blocked request fitting *without* its reuse
                // discount: eviction can destroy the very prefix that
                // discount depends on, and grinding the index for an
                // admission that eviction itself un-fits would erode the
                // pool for nothing (the precision-erosion invariant of
                // DESIGN.md §Memory-Manager).
                None => {
                    let fits_exclusive = self.batcher.min_projected_in_lookahead()
                        .map(|n| n <= self.budget.free() + reclaimable)
                        .unwrap_or(false);
                    if fits_exclusive && self.evict_prefix_once().is_some() {
                        reclaim_cache = None;
                    } else if self.spill_once().is_some() {
                        // spilled bytes were never part of the
                        // downshift/evict reclaimable bound — recompute
                        // it next round (DESIGN.md §Spill-Tier)
                        reclaim_cache = None;
                    } else {
                        break;
                    }
                }
            }
            // recharge (O(1): downshift_once reconciled the mutated
            // sequence's table itself, eviction kept the pool counter
            // consistent), then retry admission
            let _ = self.charge_current()?;
        }

        // stall detection: nothing running and no waiting request can
        // ever be admitted -> reject the head request (its projection
        // exceeds what relief could ever free) instead of spinning or
        // tearing the engine down.  The rest of the queue gets its chance
        // next step.
        if !admitted_any && self.active.is_empty() && self.batcher.waiting() > 0 {
            self.metrics.oom_events += 1;
            let req = self.batcher.queue.pop_front().expect("waiting > 0");
            let need = self.batcher.projected_bytes(&req);
            self.rejections.push(Rejection {
                id: req.id,
                reason: format!(
                    "cannot admit: projected footprint {} bytes > {} free (capacity {})",
                    need, self.budget.free(), self.budget.capacity),
            });
        }
        Ok(())
    }

    /// Legacy (`--step-tokens 0`) admission: adopt any shared prefix,
    /// prefill the **whole** prompt inline via the dense
    /// [`Forward::prefill_from`] replay, sample the first token, and join
    /// the decode batch — bit-for-bit the pre-scheduler engine.  Returns
    /// whether the prefix machinery ran (reclaim-bound invalidation).
    fn admit_legacy(&mut self, fwd: &Forward, plan: &mut StepPlan,
                    req: Request) -> Result<bool> {
        // plan bookkeeping only: legacy grants are always whole-prompt
        let _ = self.scheduler.grant_chunk(plan, req.prompt.len());
        let mut cache = self.cfg.method.make_cache(&self.rt.model);
        // session resume first (park/resume — DESIGN.md §Serving-Protocol):
        // a hit adopts the parked turn's pages exactly like a prefix hit
        // and skips the prefix-index lookup (adoption needs a fresh cache)
        let session_ran = req.session.is_some() && self.pages.is_some();
        let mut adopted = self.try_resume(&req, &mut cache, false);
        // shared-prefix lookup (DESIGN.md §Prefix-Sharing): adopt a
        // registered whole-page prefix's quantized pages as shared
        // read-only frames, capped by what this prompt's window
        // policies would quantize anyway (the bit-identity bound)
        if adopted == 0 {
            if let Some(pool) = &mut self.pages {
                if pool.prefix_cache_enabled() {
                    let cap = cache.max_shareable_prefix(req.prompt.len(),
                                                         self.cfg.page_tokens);
                    adopted = pool.adopt_prefix(req.id, &req.prompt, cap, &mut cache);
                    if adopted > 0 {
                        self.metrics.prefix_hits += 1;
                        self.metrics.prefix_tokens_reused += adopted;
                    }
                }
            }
        }
        // the dense forward covers the full prompt either way, so
        // these logits are bit-identical to a cold prefill; on a
        // hit only the unshared suffix is quantized into the cache
        let logits = fwd.prefill_from(&req.prompt, &mut cache, adopted)?;
        self.metrics.prefill_tokens += req.prompt.len();
        let vocab = self.rt.model.vocab;
        let last = &logits[(req.prompt.len() - 1) * vocab..req.prompt.len() * vocab];
        let first_tok = req.sampler.sample(last, &mut self.rng) as i32;
        let now = self.metrics.now_ns();
        let ar = ActiveRequest {
            req, cache, state: Lifecycle::Decoding,
            generated: vec![first_tok], next_input: first_tok,
            prefilled_ns: now, first_token_ns: Some(now), last_token_ns: now,
        };
        self.metrics.decode_tokens += 1;
        self.metrics.ttft_ms.record((now - ar.req.submitted_ns) as f64 / 1e6);
        self.active.push(ar);
        // post-prefill memory charge (admission already projected
        // it; the decode-step pressure loop handles any
        // shortfall).  Only the new sequence needs syncing — the
        // rest were reconciled by the last full charge.
        let _ = self.charge_lane(self.active.len() - 1)?;
        // register the new sequence's own aligned prefix while its
        // pages are provably still at the plan's width (right
        // after the post-prefill sync, before any relief round;
        // the index reference then keeps them pristine — shared
        // pages are downshift-exempt and copy-on-write)
        let mut prefix_ran = false;
        if let Some(pool) = &mut self.pages {
            if pool.prefix_cache_enabled() {
                let a = self.active.last().expect("just pushed");
                let cap = a.cache.max_shareable_prefix(a.req.prompt.len(),
                                                       self.cfg.page_tokens);
                pool.register_prefix(a.req.id, &a.req.prompt, cap, &a.cache);
                prefix_ran = true;
            }
        }
        Ok(prefix_ran || session_ran)
    }

    /// Chunked admission: adopt any shared prefix (clamped strictly below
    /// the prompt length — the final token must run through a chunk so
    /// its logits exist to sample the first output), then enter the batch
    /// as `Prefilling { done: adopted }`.  No forward pass here; chunks
    /// are granted by the step plan.  Returns whether the prefix
    /// machinery ran.
    fn admit_chunked(&mut self, req: Request) -> Result<bool> {
        let mut cache = self.cfg.method.make_cache(&self.rt.model);
        // session resume first, exactly as in legacy admission (the
        // chunked flag applies the leave-one-token clamp inside)
        let mut prefix_ran = req.session.is_some() && self.pages.is_some();
        let mut adopted = self.try_resume(&req, &mut cache, true);
        if adopted == 0 {
            if let Some(pool) = &mut self.pages {
                if pool.prefix_cache_enabled() {
                    // never adopt the whole prompt: leave >= 1 token for the
                    // first chunk's forward pass (reused_tokens projects with
                    // this same clamp)
                    let cap = cache.max_shareable_prefix(req.prompt.len(),
                                                         self.cfg.page_tokens)
                        .min(req.prompt.len().saturating_sub(1) / self.cfg.page_tokens
                             * self.cfg.page_tokens);
                    adopted = pool.adopt_prefix(req.id, &req.prompt, cap, &mut cache);
                    if adopted > 0 {
                        self.metrics.prefix_hits += 1;
                        self.metrics.prefix_tokens_reused += adopted;
                    }
                    prefix_ran = true;
                }
            }
        }
        self.active.push(ActiveRequest {
            req, cache, state: Lifecycle::Prefilling { done: adopted },
            generated: Vec::new(), next_input: 0,
            prefilled_ns: 0, first_token_ns: None, last_token_ns: 0,
        });
        let _ = self.charge_lane(self.active.len() - 1)?;
        Ok(prefix_ran)
    }

    /// Session resume (park/resume — DESIGN.md §Serving-Protocol): if
    /// `req` names a parked session whose conversation its prompt
    /// strictly extends, adopt the parked turn's page-aligned
    /// prompt-prefix pages into the fresh `cache` — the same shape as a
    /// prefix-cache hit, so the dense replay over the full prompt stays
    /// bit-identical to a cold prefill (pinned by
    /// `rust/tests/coordinator.rs`) — and return the adopted token count.
    /// The parked entry is consumed either way: a mismatched prompt (the
    /// client edited history) frees the parked pages and admits cold.
    ///
    /// The adoption boundary excludes decode-derived pages (capped at the
    /// parked turn's page-aligned *prompt* length — generated-token K/V
    /// differs from a dense prefill's at layers past the first) and any
    /// page the pressure controller downshifted off the plan width while
    /// the previous turn decoded.
    fn try_resume(&mut self, req: &Request, cache: &mut SeqKvCache,
                  chunked: bool) -> usize {
        let Some(key) = req.session else { return 0 };
        if self.pages.is_none() {
            return 0; // monolithic mode: sessions are ignored
        }
        let Some(mut p) = self.parked.remove(&key) else { return 0 };
        let pool = self.pages.as_mut().expect("checked above");
        if req.prompt.len() <= p.tokens.len()
            || req.prompt[..p.tokens.len()] != p.tokens[..] {
            pool.free_owner(p.gid);
            return 0;
        }
        // spilled pages must be resident before their blocks are adopted
        self.metrics.spill_faults += pool.fault_back_owner(p.gid, &mut p.cache);
        let pt = self.cfg.page_tokens;
        let group = self.rt.model.group;
        let mut cap = cache.max_shareable_prefix(req.prompt.len(), pt)
            .min(p.prompt_len / pt * pt);
        if chunked {
            // the final prompt token must forward through a chunk
            cap = cap.min(req.prompt.len().saturating_sub(1) / pt * pt);
        }
        let mut adopted = 0usize;
        'grow: while adopted + pt <= cap {
            let page = adopted / pt;
            for (li, fresh) in cache.layers.iter().enumerate() {
                let l = &p.cache.layers[li];
                for side in KV_SIDES {
                    if page >= l.sealed_quant_pages(side, pt) {
                        break 'grow;
                    }
                    let plan_bits = match side {
                        KvSide::Key => match fresh.cfg.key {
                            KeyRepr::PerChannel { bits }
                            | KeyRepr::PerToken { bits } => bits,
                            _ => break 'grow,
                        },
                        KvSide::Value => match fresh.cfg.value {
                            ValueRepr::PerToken { bits } => bits,
                            ValueRepr::Fp => break 'grow,
                        },
                    };
                    if l.quant_page_bits(side, page, pt) != plan_bits {
                        break 'grow; // downshifted while the last turn decoded
                    }
                }
            }
            adopted += pt;
        }
        if adopted > 0 && pool.adopt_owner_pages(p.gid, req.id, adopted / pt) {
            for (li, fresh) in cache.layers.iter_mut().enumerate() {
                let l = &p.cache.layers[li];
                for side in KV_SIDES {
                    fresh.adopt_shared_blocks(
                        side, &l.quant_blocks(side)[..adopted / group]);
                }
            }
            self.metrics.sessions_resumed += 1;
            self.metrics.resume_tokens_reused += adopted;
        } else {
            adopted = 0;
        }
        // the un-adopted remainder (decode-derived pages, downshifted
        // pages, the sub-page tail) frees here; adopted frames survive
        // at refs 1 under the new owner
        pool.free_owner(p.gid);
        adopted
    }

    /// Run one granted prefill chunk on `lane` (chunked mode only): the
    /// chunk attends over the lane's live cache ([`Forward::prefill_chunk`]),
    /// and a completing grant samples the first token, promotes the lane
    /// to `Decoding` (it joins this same step's decode batch — the token
    /// the grant reserved), and registers its shareable prefix.  Returns
    /// whether the prefix machinery ran.
    fn execute_chunk(&mut self, fwd: &Forward, lane: usize,
                     grant: ChunkGrant) -> Result<bool> {
        let Lifecycle::Prefilling { done } = self.active[lane].state else {
            unreachable!("chunk granted to a non-prefilling lane");
        };
        // the chunk attends over this lane's whole history — any spilled
        // page must be resident first (DESIGN.md §Spill-Tier)
        if let Some(pool) = &mut self.pages {
            let a = &mut self.active[lane];
            if a.cache.any_spilled() {
                self.metrics.spill_faults +=
                    pool.fault_back_owner(a.req.id, &mut a.cache);
            }
        }
        let a = &mut self.active[lane];
        debug_assert!(done + grant.tokens <= a.req.prompt.len());
        let chunk = &a.req.prompt[done..done + grant.tokens];
        let logits = fwd.prefill_chunk(chunk, done, &mut a.cache, &mut self.scratch)?;
        self.metrics.prefill_tokens += grant.tokens;
        // chunk attention time is NOT recorded into attn_us: that
        // histogram measures the batched decode fan-out (its rustdoc and
        // the e2e_decode threads rows depend on the unit staying pure);
        // chunk cost shows up in step_us and the TTFT it serializes
        if grant.completes {
            let vocab = self.rt.model.vocab;
            let last = &logits[(grant.tokens - 1) * vocab..grant.tokens * vocab];
            let first_tok = a.req.sampler.sample(last, &mut self.rng) as i32;
            let now = self.metrics.now_ns();
            a.generated.push(first_tok);
            a.next_input = first_tok;
            a.state = Lifecycle::Decoding;
            a.prefilled_ns = now;
            a.first_token_ns = Some(now);
            a.last_token_ns = now;
            let submitted = a.req.submitted_ns;
            self.metrics.decode_tokens += 1;
            self.metrics.ttft_ms.record((now - submitted) as f64 / 1e6);
        } else {
            a.state = Lifecycle::Prefilling { done: done + grant.tokens };
        }
        // the chunk's appends changed this lane's footprint; keep the
        // pool reconciled so the relief rounds' O(1) recharges stay valid
        let _ = self.charge_lane(lane)?;
        let mut prefix_ran = false;
        if grant.completes {
            if let Some(pool) = &mut self.pages {
                if pool.prefix_cache_enabled() {
                    let a = &self.active[lane];
                    let cap = a.cache.max_shareable_prefix(a.req.prompt.len(),
                                                           self.cfg.page_tokens);
                    pool.register_prefix(a.req.id, &a.req.prompt, cap, &a.cache);
                    prefix_ran = true;
                }
            }
        }
        Ok(prefix_ran)
    }

    /// One batched decode step over every `Decoding` lane, then the
    /// memory charge with the downshift → prefix-evict → preempt ladder
    /// on overflow (paged mode; the monolithic path keeps the original
    /// evict-youngest policy, counting each eviction as an oom_event).
    fn decode_and_relieve(&mut self, fwd: &Forward) -> Result<()> {
        let decoding: Vec<usize> = self.active.iter().enumerate()
            .filter(|(_, a)| a.is_decoding())
            .map(|(i, _)| i)
            .collect();
        if !decoding.is_empty() {
            // fault spilled pages back before the batched attend:
            // `LayerKvCache::attend` walks every history block, so a
            // spill stub must never reach it (DESIGN.md §Spill-Tier)
            if let Some(pool) = &mut self.pages {
                for &i in &decoding {
                    let a = &mut self.active[i];
                    if a.cache.any_spilled() {
                        self.metrics.spill_faults +=
                            pool.fault_back_owner(a.req.id, &mut a.cache);
                    }
                }
            }
            let inputs: Vec<i32> = decoding.iter()
                .map(|&i| self.active[i].next_input)
                .collect();
            let busy0 = self.pool.map(|p| p.busy_ns()).unwrap_or(0);
            let logits = {
                let mut caches: Vec<&mut SeqKvCache> = self.active.iter_mut()
                    .filter(|a| a.is_decoding())
                    .map(|a| &mut a.cache)
                    .collect();
                fwd.decode_step(&inputs, &mut caches, &mut self.scratch)?
            };
            self.metrics.attn_us.record(self.scratch.attn_ns as f64 / 1e3);
            for (acc, &ns) in self.metrics.attn_ns_by_width.iter_mut()
                .zip(&self.scratch.kernel_ns)
            {
                *acc += ns;
            }
            if let Some(p) = self.pool {
                if p.threads() > 1 && self.scratch.attn_ns > 0 {
                    let busy = (p.busy_ns() - busy0) as f64;
                    let denom = p.threads() as f64 * self.scratch.attn_ns as f64;
                    self.metrics.pool_util.record((busy / denom).min(1.0));
                }
            }
            let vocab = self.rt.model.vocab;
            let now = self.metrics.now_ns();
            for (b, &i) in decoding.iter().enumerate() {
                let ar = &mut self.active[i];
                let row = &logits[b * vocab..(b + 1) * vocab];
                let tok = ar.req.sampler.sample(row, &mut self.rng) as i32;
                ar.generated.push(tok);
                ar.next_input = tok;
                // time-between-tokens: gap since this lane's previous
                // token (the first decode token measures from TTFT)
                self.metrics.tbt_ms.record((now - ar.last_token_ns) as f64 / 1e6);
                ar.last_token_ns = now;
            }
            self.metrics.decode_tokens += decoding.len();
        }

        if !self.active.is_empty() {
            // memory charge; on simulated OOM the pressure controller
            // first downshifts the oldest out-of-window unshared pages
            // down the bit ladder, then evicts LRU prefix-index entries
            // (freeing index-only frames and un-sharing pages so the
            // ladder can resume), then spills sealed cold pages to the
            // disk tier and drops parked sessions (DESIGN.md
            // §Spill-Tier), and only past every rung preempts the
            // lowest-priority (youngest) sequence — which may be a
            // mid-prompt `Prefilling` lane; preempt-restart discards its
            // chunk progress.  One full page-table reconcile after the
            // decode/chunk mutations; the relief rounds keep the pool
            // consistent themselves (targeted sync in downshift_once,
            // free_owner on preempt) so each retry charge is the O(1)
            // counter, not a rescan of every sequence.
            let mut over = self.charge_memory()?.is_err();
            while over {
                if self.downshift_once().is_some() {
                    over = self.charge_current()?.is_err();
                    continue;
                }
                if self.evict_prefix_once().is_some() {
                    over = self.charge_current()?.is_err();
                    continue;
                }
                if self.spill_once().is_some() {
                    over = self.charge_current()?.is_err();
                    continue;
                }
                if self.drop_parked_once().is_some() {
                    over = self.charge_current()?.is_err();
                    continue;
                }
                if self.active.len() <= 1 {
                    // single request over budget: let it run (degraded)
                    self.metrics.oom_events += 1;
                    break;
                }
                if self.pages.is_some() {
                    self.metrics.preemptions += 1;
                } else {
                    self.metrics.oom_events += 1;
                }
                let mut victim = self.active.pop().unwrap();
                if let Some(pool) = &mut self.pages {
                    pool.free_owner(victim.req.id);
                }
                victim.generated.clear();
                self.batcher.queue.push_front(victim.req);
                over = self.charge_current()?.is_err();
            }
        }
        Ok(())
    }

    fn retire_done(&mut self) -> Result<Vec<Completion>> {
        let now = self.metrics.now_ns();
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].is_done() {
                let mut ar = self.active.remove(i);
                // is_done() fires on length or stop-token; length wins
                // the (length-cap AND stop-token) tie by convention
                let finish = if ar.generated.len() >= ar.req.max_new_tokens {
                    FinishReason::Length
                } else {
                    FinishReason::Stop
                };
                let c = ar_into_completion(&mut ar, now, finish);
                match (ar.req.session, &mut self.pages) {
                    // park instead of free (DESIGN.md §Serving-Protocol):
                    // the conversation's pages stay in the pool under
                    // this owner until the session's next turn resumes
                    // them (cancel/deadline retirements free as before —
                    // a truncated generation is not a resumable turn)
                    (Some(key), Some(pool)) => {
                        if let Some(old) = self.parked.remove(&key) {
                            // one parked turn per session: the newer
                            // conversation supersedes the older
                            pool.free_owner(old.gid);
                        }
                        let prompt_len = ar.req.prompt.len();
                        let mut tokens = std::mem::take(&mut ar.req.prompt);
                        tokens.extend_from_slice(&c.tokens);
                        self.parked.insert(key, ParkedSession {
                            gid: ar.req.id, prompt_len, tokens, cache: ar.cache,
                        });
                        self.metrics.sessions_parked += 1;
                    }
                    _ => {
                        if let Some(pool) = &mut self.pages {
                            pool.free_owner(ar.req.id);
                        }
                    }
                }
                done.push(self.retire(c));
            } else {
                i += 1;
            }
        }
        if !done.is_empty() {
            // release retired caches' memory so waiting requests can admit
            let _ = self.charge_memory()?;
        }
        Ok(done)
    }

    /// Run until all submitted requests complete; returns all completions.
    /// A rejected (never-admittable) request surfaces as an error here —
    /// including one left over from a caller-driven [`Engine::step`] that
    /// was never drained — preserving the one-shot harnesses' OOM
    /// semantics; the serve loop instead drains
    /// [`Engine::take_rejections`] and keeps stepping.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        loop {
            if !self.rejections.is_empty() {
                // consume the rejection while surfacing it — a stale,
                // already-reported entry must not poison later calls
                let r = self.rejections.remove(0);
                anyhow::bail!("request {} rejected: {}", r.id, r.reason);
            }
            if self.idle() {
                return Ok(all);
            }
            all.extend(self.step()?);
        }
    }

    /// Read-only view of the paged pool (None in monolithic mode) —
    /// benches and tests inspect allocator stats through this.
    pub fn page_pool(&self) -> Option<&PagePool> {
        self.pages.as_ref()
    }

    /// Conversations currently parked under a session key.
    pub fn parked_sessions(&self) -> usize {
        self.parked.len()
    }

    /// Charge the budget with the current KV footprint: page-granular via
    /// the pool when paged (every sequence's page table is reconciled
    /// here, on the engine thread — the decode fan-out never touches the
    /// pool), else the exact summed modeled bytes.
    fn charge_memory(&mut self) -> Result<std::result::Result<(), ()>> {
        self.charge_sync(None)
    }

    /// Cheaper variant for admission/chunk execution: only `lane`'s table
    /// needs reconciling — everyone else was synced by the previous full
    /// charge and hasn't mutated since.
    fn charge_lane(&mut self, lane: usize) -> Result<std::result::Result<(), ()>> {
        self.charge_sync(Some(lane))
    }

    /// Shared charge body: reconcile `lane`'s page table (or every
    /// lane's, for `None`), then charge the modeled bytes.
    fn charge_sync(&mut self, lane: Option<usize>) -> Result<std::result::Result<(), ()>> {
        let kv = match &mut self.pages {
            Some(pool) => {
                match lane {
                    None => {
                        for a in &self.active {
                            pool.sync(a.req.id, &a.cache);
                        }
                    }
                    Some(i) => {
                        let a = &self.active[i];
                        pool.sync(a.req.id, &a.cache);
                    }
                }
                // sync is where the pool observes copy-on-write splits
                self.metrics.cow_splits = pool.stats.cow_splits;
                pool.modeled_bytes()
            }
            None => self.active.iter().map(|a| a.cache.modeled_bytes()).sum(),
        };
        self.metrics.peak_kv_bytes = self.metrics.peak_kv_bytes.max(kv);
        Ok(self.budget.set_kv(kv).map_err(|_| ()))
    }

    /// Recharge from the current accounting without reconciling any page
    /// tables: valid whenever every mutation since the last full charge
    /// kept the pool consistent itself (downshift_once's targeted sync,
    /// free_owner).  O(1) in paged mode (the pool's running counter).
    fn charge_current(&mut self) -> Result<std::result::Result<(), ()>> {
        let kv = match &self.pages {
            Some(pool) => pool.modeled_bytes(),
            None => self.active.iter().map(|a| a.cache.modeled_bytes()).sum(),
        };
        self.metrics.peak_kv_bytes = self.metrics.peak_kv_bytes.max(kv);
        Ok(self.budget.set_kv(kv).map_err(|_| ()))
    }

    /// One pressure-controller downshift: take the best
    /// predicted-loss-per-byte (layer, side, page) rung still above its
    /// side floor (DESIGN.md §Pressure-Ladder), scanning the
    /// oldest-admitted sequence first, and reconcile that one sequence's
    /// page table immediately.  The page-frame delta below is per-side
    /// safe: frame bytes depend only on the width, never on which side
    /// the page holds, so a K-only or V-only rung charges correctly.
    /// Returns the frame-accounting bytes reclaimed, or `None` in
    /// monolithic mode / when every page across the batch already sits at
    /// its side floor (the caller then evicts prefix entries, then
    /// preempts).
    ///
    /// The underlying scan restarts from page 0 each call on purpose —
    /// it's O(1) field reads per already-floored entry, and admissions /
    /// preemptions change the page population between relief rounds, so
    /// a carried cursor would go stale.
    fn downshift_once(&mut self) -> Option<usize> {
        self.pages.as_ref()?;
        let page_tokens = self.cfg.page_tokens;
        for i in 0..self.active.len() {
            let ds = pressure::downshift_one(&mut self.active[i].cache, page_tokens,
                                             &self.pressure);
            if let Some(d) = ds {
                self.metrics.pages_requantized += 1;
                let pool = self.pages.as_mut().unwrap();
                let delta = pool.page_bytes(d.from_bits) - pool.page_bytes(d.to_bits);
                // only this sequence's table changed: reconcile it alone
                let a = &self.active[i];
                pool.sync(a.req.id, &a.cache);
                self.metrics.cow_splits = pool.stats.cow_splits;
                return Some(delta);
            }
        }
        None
    }

    /// One spill-rung relief step (DESIGN.md §Spill-Tier): write a single
    /// sealed, unshared, unspilled page to the disk tier, freeing its
    /// frame bytes from the budget.  Parked sessions spill first — nobody
    /// is attending over them, so they are the coldest pages in the
    /// system — newest pages first, keeping the oldest (prompt-prefix,
    /// resume-adoptable) pages resident longest; then active lanes,
    /// oldest-admitted first, oldest pages first.  Returns the frame
    /// bytes freed, or `None` when nothing is eligible (tier off, cap
    /// reached, or every sealed page shared or already spilled).
    fn spill_once(&mut self) -> Option<usize> {
        let pool = self.pages.as_mut()?;
        if !pool.spill_enabled() {
            return None;
        }
        for p in self.parked.values_mut() {
            if let Some(freed) = pool.spill_one(p.gid, &mut p.cache, true) {
                self.metrics.pages_spilled += 1;
                return Some(freed);
            }
        }
        for a in &mut self.active {
            if let Some(freed) = pool.spill_one(a.req.id, &mut a.cache, false) {
                self.metrics.pages_spilled += 1;
                return Some(freed);
            }
        }
        None
    }

    /// Evict one parked session outright (lowest key first —
    /// deterministic) — the last rung before preempting *live* work: a
    /// parked conversation is a convenience cache, a decoding lane is a
    /// served client.  Returns the pool bytes freed (0 when every page
    /// was already spilled), or `None` when nothing is parked.
    fn drop_parked_once(&mut self) -> Option<usize> {
        self.pages.as_ref()?;
        let key = *self.parked.keys().next()?;
        let p = self.parked.remove(&key).expect("key just read");
        let pool = self.pages.as_mut().expect("checked above");
        let before = pool.modeled_bytes();
        pool.free_owner(p.gid);
        Some(before - pool.modeled_bytes())
    }

    /// One prefix-index eviction: drop the LRU shared-prefix entry,
    /// freeing its index-only frames and un-sharing its pages so the
    /// downshift ladder can reach them again.  The rung between
    /// downshift-exhausted and preemption (DESIGN.md §Prefix-Sharing).
    /// Returns the bytes freed (possibly 0 when every frame is still
    /// mapped by an active sequence — still progress, because the
    /// un-shared pages become downshiftable), or `None` when the index
    /// is empty or the prefix cache is off.
    fn evict_prefix_once(&mut self) -> Option<usize> {
        self.pages.as_mut()?.evict_lru_prefix()
    }

    fn retire(&mut self, c: Completion) -> Completion {
        self.metrics.completions += 1;
        self.metrics.total_ms.record(c.total_ms());
        self.completions.push(c.clone());
        c
    }
}

/// Prompt tokens of `req` a prefix-cache hit would adopt right now —
/// the admission projection's reuse discount (0 when the cache is off).
/// Pure read: same lookup as `PagePool::adopt_prefix`, no LRU touch.
/// Sound because nothing can evict the probed entry between this probe
/// and the adoption in the same admission iteration (relief rounds run
/// between iterations, never inside one).
///
/// `chunked` must mirror the engine's mode: chunked admission clamps
/// adoption strictly below the prompt (the final token must forward
/// through a chunk), so the projection applies the same clamp — else a
/// fully-registered page-aligned prompt would be under-projected by the
/// one page `admit_chunked` declines to adopt.
fn reused_tokens(pages: &Option<PagePool>, probe: &Option<SeqKvCache>,
                 page_tokens: usize, chunked: bool, req: &Request) -> usize {
    match (pages, probe) {
        (Some(pool), Some(template)) => {
            let mut cap = template.max_shareable_prefix(req.prompt.len(), page_tokens);
            if chunked && page_tokens > 0 {
                cap = cap.min(req.prompt.len().saturating_sub(1)
                              / page_tokens * page_tokens);
            }
            pool.probe_prefix(&req.prompt, cap)
        }
        _ => 0,
    }
}

fn ar_into_completion(ar: &mut ActiveRequest, now: u64,
                      finish: FinishReason) -> Completion {
    Completion {
        id: ar.req.id,
        prompt_len: ar.req.prompt.len(),
        tokens: std::mem::take(&mut ar.generated),
        finish,
        submitted_ns: ar.req.submitted_ns,
        first_token_ns: ar.first_token_ns.unwrap_or(now),
        finished_ns: now,
    }
}

/// Modeled steady-state KV bytes/token for a policy (reference length
/// 256).  Admission projections use this exact (monolithic) rate in both
/// memory regimes; paged charging additionally pays page-rounding
/// fragmentation, which the decode-step pressure loop absorbs.
pub fn estimate_bytes_per_token(rt: &Runtime, method: &Method) -> f64 {
    let m = &rt.model;
    let mut cache = method.make_cache(m);
    let n = 256;
    let kv = m.kv_dim();
    let mut rng = Rng::new(7);
    let k = rng.normal_vec(n * kv);
    let v = rng.normal_vec(n * kv);
    for l in &mut cache.layers {
        l.append(&k, &v, n);
    }
    cache.modeled_bytes() as f64 / n as f64
}
