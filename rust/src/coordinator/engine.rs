//! The serving engine: continuous-batching loop over the PJRT-backed
//! forward pass and the mixed-precision caches.
//!
//! One `step()` = admit waiting requests (prefill them) + one batched
//! decode step for every active request + retire completions.  Memory is
//! charged against the [`MemoryBudget`] after each step; a simulated OOM
//! evicts the *youngest* request back to the queue (preempt-restart, the
//! usual vLLM recompute policy).

use anyhow::Result;

use crate::baselines::Method;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ActiveRequest, Completion, Request};
use crate::kvcache::MemoryBudget;
use crate::model::{DecodeScratch, Forward};
use crate::runtime::Runtime;
use crate::util::{Rng, WorkerPool};

pub struct EngineCfg {
    pub method: Method,
    pub max_batch: usize,
    /// simulated HBM budget for KV (bytes); None = unlimited
    pub kv_budget: Option<usize>,
    /// worker threads for the decode attention fan-out
    /// (0 = one per available core, 1 = sequential).  The engine itself
    /// only *uses* a pool handed to [`Engine::with_pool`]; this knob is
    /// how `--threads` travels from the CLI to whoever builds the pool
    /// (see `server::serve` and `main.rs`).
    pub threads: usize,
}

pub struct Engine<'a> {
    pub rt: &'a Runtime,
    cfg: EngineCfg,
    pub batcher: Batcher,
    pub active: Vec<ActiveRequest>,
    pub budget: MemoryBudget,
    pub metrics: Metrics,
    pub completions: Vec<Completion>,
    scratch: DecodeScratch,
    rng: Rng,
    /// attention fan-out workers (None = sequential decode)
    pool: Option<&'a WorkerPool>,
}

impl<'a> Engine<'a> {
    /// Sequential engine (no attention fan-out).
    pub fn new(rt: &'a Runtime, cfg: EngineCfg) -> Result<Self> {
        Self::with_pool(rt, cfg, None)
    }

    /// Engine whose decode/prefill attention fans out across `pool`
    /// (`None` behaves exactly like [`Engine::new`]).  Everything that
    /// touches the PJRT client stays on the thread calling
    /// [`Engine::step`]; only the pure-Rust cache attention is fanned out
    /// (DESIGN.md §Threading-Model).
    pub fn with_pool(rt: &'a Runtime, cfg: EngineCfg,
                     pool: Option<&'a WorkerPool>) -> Result<Self> {
        let max_bucket = rt.buckets.iter().copied().max().unwrap_or(1);
        let max_batch = cfg.max_batch.min(max_bucket);
        // bytes/token estimate for admission: steady-state modeled bytes of
        // the policy at a reference length
        let bpt = estimate_bytes_per_token(rt, &cfg.method);
        let capacity = cfg.kv_budget.unwrap_or(usize::MAX / 2);
        // the attached pool is the source of truth for parallelism; keep
        // the stored cfg consistent with it so the two can't diverge
        let threads = pool.map(|p| p.threads()).unwrap_or(1);
        Ok(Engine {
            rt,
            batcher: Batcher::new(max_batch, bpt),
            cfg: EngineCfg { max_batch, threads, ..cfg },
            active: Vec::new(),
            budget: MemoryBudget::new(capacity, 0)?,
            metrics: Metrics::default(),
            completions: Vec::new(),
            scratch: DecodeScratch::default(),
            rng: Rng::new(0xE161),
            pool,
        })
    }

    pub fn method_name(&self) -> String {
        self.cfg.method.name()
    }

    pub fn submit(&mut self, mut req: Request) {
        req.submitted_ns = self.metrics.now_ns();
        self.batcher.submit(req);
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.batcher.waiting() == 0
    }

    /// One scheduler iteration; returns completions retired this step.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let t0 = std::time::Instant::now();
        let fwd = Forward::with_pool(self.rt, self.pool);

        // ---- admission + prefill ----
        let mut admitted_any = false;
        while let Some(req) = self.batcher.admit(self.active.len(), &self.budget) {
            admitted_any = true;
            let mut cache = self.cfg.method.make_cache(&self.rt.model);
            let logits = fwd.prefill(&req.prompt, &mut cache)?;
            self.metrics.prefill_tokens += req.prompt.len();
            let vocab = self.rt.model.vocab;
            let last = &logits[(req.prompt.len() - 1) * vocab..req.prompt.len() * vocab];
            let first_tok = req.sampler.sample(last, &mut self.rng) as i32;
            let now = self.metrics.now_ns();
            let ar = ActiveRequest {
                req, cache, generated: vec![first_tok], next_input: first_tok,
                prefilled_ns: now, first_token_ns: Some(now),
            };
            self.metrics.decode_tokens += 1;
            self.metrics.ttft_ms.record((now - ar.req.submitted_ns) as f64 / 1e6);
            self.active.push(ar);
            // post-prefill memory charge (admission already projected it;
            // the decode-step OOM loop below handles any shortfall)
            let _ = self.charge_memory()?;
        }

        // stall detection: nothing running and the head request can never
        // be admitted -> surface the simulated OOM instead of spinning
        if !admitted_any && self.active.is_empty() && self.batcher.waiting() > 0 {
            self.metrics.oom_events += 1;
            let head = self.batcher.queue.front().unwrap();
            anyhow::bail!(
                "request {} cannot be admitted: projected {} bytes > {} free (capacity {})",
                head.id, self.batcher.projected_bytes(head), self.budget.free(),
                self.budget.capacity);
        }

        // ---- one batched decode step ----
        if !self.active.is_empty() {
            let inputs: Vec<i32> = self.active.iter().map(|a| a.next_input).collect();
            let mut caches: Vec<&mut crate::kvcache::SeqKvCache> =
                self.active.iter_mut().map(|a| &mut a.cache).collect();
            let busy0 = self.pool.map(|p| p.busy_ns()).unwrap_or(0);
            let logits = fwd.decode_step(&inputs, &mut caches, &mut self.scratch)?;
            self.metrics.attn_us.record(self.scratch.attn_ns as f64 / 1e3);
            if let Some(p) = self.pool {
                if p.threads() > 1 && self.scratch.attn_ns > 0 {
                    let busy = (p.busy_ns() - busy0) as f64;
                    let denom = p.threads() as f64 * self.scratch.attn_ns as f64;
                    self.metrics.pool_util.record((busy / denom).min(1.0));
                }
            }
            let vocab = self.rt.model.vocab;
            for (b, ar) in self.active.iter_mut().enumerate() {
                let row = &logits[b * vocab..(b + 1) * vocab];
                let tok = ar.req.sampler.sample(row, &mut self.rng) as i32;
                ar.generated.push(tok);
                ar.next_input = tok;
            }
            self.metrics.decode_tokens += self.active.len();

            // memory charge; simulated OOM evicts the youngest request
            while self.charge_memory()?.is_err() {
                self.metrics.oom_events += 1;
                if self.active.len() <= 1 {
                    break; // single request over budget: let it run (degraded)
                }
                let mut victim = self.active.pop().unwrap();
                victim.generated.clear();
                self.batcher.queue.push_front(victim.req);
            }
        }

        // ---- retire ----
        let now = self.metrics.now_ns();
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].is_done() {
                let mut ar = self.active.remove(i);
                done.push(self.retire(ar_into_completion(&mut ar, now)));
            } else {
                i += 1;
            }
        }
        if !done.is_empty() {
            // release retired caches' memory so waiting requests can admit
            let _ = self.charge_memory()?;
        }
        self.metrics.step_us.record(t0.elapsed().as_micros() as f64);
        Ok(done)
    }

    /// Run until all submitted requests complete; returns all completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        while !self.idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    fn charge_memory(&mut self) -> Result<std::result::Result<(), ()>> {
        let kv: usize = self.active.iter().map(|a| a.cache.modeled_bytes()).sum();
        self.metrics.peak_kv_bytes = self.metrics.peak_kv_bytes.max(kv);
        Ok(self.budget.set_kv(kv).map_err(|_| ()))
    }

    fn retire(&mut self, c: Completion) -> Completion {
        self.metrics.completions += 1;
        self.metrics.total_ms.record(c.total_ms());
        self.completions.push(c.clone());
        c
    }
}

fn ar_into_completion(ar: &mut ActiveRequest, now: u64) -> Completion {
    Completion {
        id: ar.req.id,
        prompt_len: ar.req.prompt.len(),
        tokens: std::mem::take(&mut ar.generated),
        submitted_ns: ar.req.submitted_ns,
        first_token_ns: ar.first_token_ns.unwrap_or(now),
        finished_ns: now,
    }
}

/// Modeled steady-state KV bytes/token for a policy (reference length 256).
pub fn estimate_bytes_per_token(rt: &Runtime, method: &Method) -> f64 {
    let m = &rt.model;
    let mut cache = method.make_cache(m);
    let n = 256;
    let kv = m.kv_dim();
    let mut rng = Rng::new(7);
    let k = rng.normal_vec(n * kv);
    let v = rng.normal_vec(n * kv);
    for l in &mut cache.layers {
        l.append(&k, &v, n);
    }
    cache.modeled_bytes() as f64 / n as f64
}
