//! Streaming NDJSON TCP server (std::net — tokio is unavailable
//! offline; DESIGN.md §Serving-Protocol,
//! docs/adr/006-streaming-json-protocol.md).
//!
//! One JSON frame per line, both directions (`coordinator/proto.rs`).  A
//! generation request streams back one `{"id":…,"delta":[…]}` frame per
//! engine step it produced tokens in, then a terminal
//! `{"id":…,"done":true,"finish":…,"n":…,"ttft_ms":…,"tbt_ms":…}` frame
//! — so for any generation of ≥ 2 tokens the client observes at least
//! one delta strictly before the final frame (`rust/tests/coordinator.rs`
//! pins this at the socket).
//!
//! **Backpressure** is two bounded stages, never an unbounded channel:
//! the reader thread `try_send`s into a `sync_channel(admit_queue)`, and
//! the serve loop only drains it while the engine-side batcher queue
//! holds fewer than `admit_queue` waiting requests.  A full channel
//! load-sheds immediately on the reader thread with
//! `{"id":…,"error":"admission queue full","retry_after_ms":…}` — the
//! hint is the serve loop's running estimate of queue drain time.
//!
//! **Cancellation**: a `{"cancel":id}` frame — or the connection
//! dropping — routes through the control channel to
//! [`Engine::cancel`] between steps, retiring the sequence and freeing
//! its pool pages before the next decode.  A cancel that beats its
//! target through the admission channel is remembered and honoured when
//! the request drains; a request reusing a live in-flight `id` on the
//! same connection gets a terminal reject (one stream per id).
//! Per-request deadlines ride the request frame (`deadline_ms`) and are
//! enforced by the engine's own sweep.  `{"stats":true}` answers with a
//! metrics snapshot frame.
//!
//! **Replication** (`--replicas N`, DESIGN.md §Replication): the serve
//! loop drives a [`Router`] over N independent engines, dispatching each
//! admission by shared-prefix affinity so one replica's prefix index
//! accumulates each prefix family, and pinning sessioned requests to the
//! replica holding their parked pages.  The admission gate and
//! `max_requests` accounting are fleet-wide; stats frames report merged
//! metrics plus a `"replicas"` field.  `--replicas 1` (the default) is
//! bit-for-bit the single-engine serving path.
//!
//! The pre-PR-7 `GEN …`/`OK …` line protocol survives behind
//! `--legacy-proto` ([`serve_legacy`]) for old harnesses, with its
//! error leak fixed: internal failures now log server-side and answer a
//! generic `ERR`.  It is deprecated and will be removed.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Read as _, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::engine::{Engine, EngineCfg};
use crate::coordinator::proto::{self, ClientFrame, GenReq};
use crate::coordinator::request::{Completion, FinishReason, Request};
use crate::coordinator::router::Router;
use crate::model::Sampler;
use crate::runtime::Runtime;
use crate::util::pool::{resolve_threads, WorkerPool};

/// Server-side knobs (the engine's own knobs live in [`EngineCfg`]).
pub struct ServeCfg {
    pub addr: String,
    /// exit once this many requests reached a terminal outcome —
    /// completion, rejection, cancellation, *or* load-shed (so bounded
    /// test runs can't hang on a shed request); None = serve forever
    pub max_requests: Option<usize>,
    /// admission bound: capacity of the reader→engine channel AND the
    /// engine-side waiting-queue gate (total buffering ≈ 2× this before
    /// load-shedding starts)
    pub admit_queue: usize,
    /// speak the deprecated `GEN …` line protocol instead
    /// (`--legacy-proto`)
    pub legacy: bool,
    /// independent engine replicas behind the prefix-affinity router
    /// (`--replicas`; DESIGN.md §Replication).  Each replica gets its own
    /// page pool, scheduler, and metrics; 1 (the default) keeps the
    /// single-engine serving path bit-for-bit.
    pub replicas: usize,
}

impl ServeCfg {
    pub fn new(addr: &str) -> Self {
        ServeCfg { addr: addr.to_string(), max_requests: None,
                   admit_queue: 32, legacy: false, replicas: 1 }
    }
}

/// A generation request travelling reader → serve loop.
struct NewMsg {
    conn: u64,
    client_id: u64,
    req: GenReq,
    out: Sender<String>,
}

/// Control events (unbounded channel: each is O(1) and client-paced).
enum Ctl {
    /// client sent `{"cancel":id}` — ids are client-scoped, so the route
    /// is (conn, client_id)
    Cancel { conn: u64, client_id: u64 },
    /// connection closed or write failed: cancel everything it owns
    Gone { conn: u64 },
    /// client sent `{"stats":true}`
    Stats { out: Sender<String> },
}

/// Where a live request's frames go, and how many of its tokens have
/// been streamed already.
struct Route {
    conn: u64,
    client_id: u64,
    out: Sender<String>,
    /// delta watermark: tokens already sent.  Deliberately *not* reset
    /// on preempt-restart — the regenerated prefix is suppressed up to
    /// the watermark so the client never sees duplicate positions (with
    /// non-greedy sampling the replayed tokens may differ; the stream
    /// keeps the first emission).
    sent: usize,
}

/// Bind `cfg.addr` and serve (see [`serve_on`]).
pub fn serve(rt: &Runtime, cfg: EngineCfg, scfg: ServeCfg) -> Result<()> {
    let listener = TcpListener::bind(&scfg.addr)?;
    serve_on(rt, cfg, listener, scfg)
}

/// Serve on an already-bound listener — tests bind port 0 themselves and
/// read the ephemeral `local_addr` back.  `cfg.threads` sizes the decode
/// attention worker pool; the engine loop (and every PJRT call) stays on
/// the calling thread.
pub fn serve_on(rt: &Runtime, cfg: EngineCfg, listener: TcpListener,
                scfg: ServeCfg) -> Result<()> {
    if scfg.legacy {
        return serve_legacy(rt, cfg, listener, scfg.max_requests);
    }
    let paging = if cfg.page_tokens > 0 {
        let prefix = if cfg.prefix_cache { " + prefix cache" } else { "" };
        format!(", {}-token KV pages{prefix}", cfg.page_tokens)
    } else {
        String::new()
    };
    let replicas = scfg.replicas.max(1);
    println!("kvmix serving NDJSON on {} (policy {}, {} replica(s), \
              {} attention worker(s){paging}, admit queue {})",
             listener.local_addr()?, cfg.method.name(), replicas,
             resolve_threads(cfg.threads), scfg.admit_queue);

    let admit_cap = scfg.admit_queue.max(1);
    let (new_tx, new_rx): (SyncSender<NewMsg>, Receiver<NewMsg>) = sync_channel(admit_cap);
    let (ctl_tx, ctl_rx): (Sender<Ctl>, Receiver<Ctl>) = channel();
    // reader-thread view of serve-loop state: the load-shed retry hint
    // and the shed counter (terminal outcomes for `max_requests`)
    let retry_hint = Arc::new(AtomicU64::new(50));
    let shed = Arc::new(AtomicU64::new(0));

    let accept = {
        let (new_tx, ctl_tx) = (new_tx.clone(), ctl_tx.clone());
        let (retry_hint, shed) = (retry_hint.clone(), shed.clone());
        std::thread::spawn(move || {
            let mut next_conn = 0u64;
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                next_conn += 1;
                let conn = next_conn;
                let (new_tx, ctl_tx) = (new_tx.clone(), ctl_tx.clone());
                let (retry_hint, shed) = (retry_hint.clone(), shed.clone());
                std::thread::spawn(move || {
                    handle_conn(stream, conn, new_tx, ctl_tx, retry_hint, shed);
                });
            }
        })
    };

    // engine loop (current thread — PJRT client is not Sync-shared here;
    // only the cache attention fans out across the scoped pool)
    let threads = cfg.threads;
    WorkerPool::scoped(threads, |pool| {
        // N independent replicas sharing one attention worker pool; each
        // spills into its own subdirectory so the per-replica spill
        // files never collide (DESIGN.md §Spill-Tier)
        let mut engines = Vec::with_capacity(replicas);
        for r in 0..replicas {
            let mut ecfg = cfg.clone();
            if replicas > 1 {
                ecfg.spill_dir =
                    cfg.spill_dir.as_ref().map(|d| d.join(format!("r{r}")));
            }
            engines.push(Engine::with_pool(rt, ecfg, Some(pool))?);
        }
        let mut router = Router::new(engines, cfg.page_tokens);
        let mut pending: HashMap<u64, Route> = HashMap::new();
        // cancels that matched no live route: the target may still be
        // buffered in the admission sync_channel (sent but not yet
        // drained), so remember the (conn, client_id) pair and honour it
        // at admission time.  Bounded — a flood of bogus cancel ids must
        // not grow memory, so past the cap a cancel for a still-buffered
        // request degrades to the pre-fix no-op; entries die with their
        // connection, and the whole set clears whenever the channel
        // drains empty (every buffered target has been checked by then).
        let mut orphan_cancels: HashSet<(u64, u64)> = HashSet::new();
        let orphan_cap = admit_cap * 4;
        let mut next_global: u64 = 0;
        let mut served = 0usize;
        loop {
            // control first: a cancel or disconnect must beat the next step
            while let Ok(ctl) = ctl_rx.try_recv() {
                match ctl {
                    Ctl::Cancel { conn, client_id } => {
                        let gid = pending.iter()
                            .find(|(_, r)| r.conn == conn && r.client_id == client_id)
                            .map(|(&g, _)| g);
                        if let Some(gid) = gid {
                            let route = pending.remove(&gid).expect("gid from pending");
                            if let Some(c) = router.cancel(gid)? {
                                let _ = route.out.send(
                                    proto::final_frame(route.client_id, &c));
                            }
                            served += 1;
                        } else if orphan_cancels.len() < orphan_cap {
                            // not routed: either already terminal / never
                            // existed (entry cleared next full drain) or
                            // still in the admission channel (caught on
                            // drain)
                            orphan_cancels.insert((conn, client_id));
                        }
                    }
                    Ctl::Gone { conn } => {
                        let gids: Vec<u64> = pending.iter()
                            .filter(|(_, r)| r.conn == conn)
                            .map(|(&g, _)| g)
                            .collect();
                        for gid in gids {
                            router.cancel(gid)?;
                            pending.remove(&gid);
                            served += 1; // terminal for this request; no frames
                        }
                        orphan_cancels.retain(|&(c, _)| c != conn);
                    }
                    Ctl::Stats { out } => {
                        let mut merged = router.merged_metrics();
                        let frame = proto::stats_frame(
                            &mut merged, router.waiting(), router.active(),
                            shed.load(Ordering::Relaxed) as usize,
                            router.replicas());
                        let _ = out.send(frame);
                    }
                }
            }
            // admissions, gated on the fleet-wide queue depth — the
            // second bounded stage of the backpressure state machine
            // (total buffering stays ≈ 2×admit_queue at any replica count)
            while router.waiting() < admit_cap {
                let Ok(m) = new_rx.try_recv() else {
                    // channel drained: the reader sends a request before
                    // its cancel, so any orphan whose target was buffered
                    // has been matched by now — surviving entries are
                    // stale (already-terminal or never-existed ids) and
                    // must not shoot down a future reuse of the id
                    orphan_cancels.clear();
                    break;
                };
                if orphan_cancels.remove(&(m.conn, m.client_id)) {
                    // the cancel overtook its target in the admission
                    // channel: retire it here, before any engine ever
                    // sees the request
                    let e0 = &mut router.engines_mut()[0];
                    e0.metrics.cancellations += 1;
                    let now = e0.metrics.now_ns();
                    let c = Completion {
                        id: 0, prompt_len: m.req.prompt.len(), tokens: Vec::new(),
                        finish: FinishReason::Cancelled,
                        submitted_ns: now, first_token_ns: now, finished_ns: now,
                    };
                    let _ = m.out.send(proto::final_frame(m.client_id, &c));
                    served += 1;
                    continue;
                }
                if pending.values()
                    .any(|r| r.conn == m.conn && r.client_id == m.client_id)
                {
                    // duplicate in-flight id on this connection: the
                    // client could not demultiplex two streams sharing
                    // one "id", and a later cancel would retire an
                    // arbitrary match — terminal reject instead
                    let _ = m.out.send(proto::reject_frame(
                        Some(m.client_id), "duplicate in-flight id", None));
                    served += 1;
                    continue;
                }
                next_global += 1;
                let gid = next_global;
                pending.insert(gid, Route { conn: m.conn, client_id: m.client_id,
                                            out: m.out, sent: 0 });
                router.dispatch(build_request(gid, m.req));
            }
            // submit-time rejections can leave the fleet idle: drain
            // them (terminal — no retry_after_ms) before the idle check
            for r in router.take_rejections() {
                if let Some(route) = pending.remove(&r.id) {
                    let _ = route.out.send(
                        proto::reject_frame(Some(route.client_id), &r.reason, None));
                }
                served += 1;
            }
            // a shed request would re-enter through routing, so hint
            // with the most optimistic (least-loaded) replica's drain time
            let hint = router.engines_mut().iter_mut()
                .map(retry_hint_ms)
                .min()
                .unwrap_or(50);
            retry_hint.store(hint, Ordering::Relaxed);
            if router.idle() {
                if let Some(max) = scfg.max_requests {
                    if served + shed.load(Ordering::Relaxed) as usize >= max {
                        drop(accept);
                        println!("{}", router.merged_metrics().report());
                        return Ok(());
                    }
                }
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            let done = router.step()?;
            // stream per-step deltas for still-running lanes first, so a
            // ≥2-token generation always sees a delta before its final
            for a in router.active_lanes() {
                if let Some(route) = pending.get_mut(&a.req.id) {
                    if a.generated.len() > route.sent {
                        let _ = route.out.send(proto::delta_frame(
                            route.client_id, &a.generated[route.sent..]));
                        route.sent = a.generated.len();
                    }
                }
            }
            for c in done {
                if let Some(route) = pending.remove(&c.id) {
                    if c.tokens.len() > route.sent {
                        let _ = route.out.send(proto::delta_frame(
                            route.client_id, &c.tokens[route.sent..]));
                    }
                    let _ = route.out.send(proto::final_frame(route.client_id, &c));
                }
                served += 1;
            }
        }
    })
}

/// Map a scanned frame onto an engine [`Request`] under the serve loop's
/// global id.  `top_k`/`temperature` absent → greedy; a lone
/// `temperature` without `top_k` degenerates to top-1 (greedy).
fn build_request(gid: u64, g: GenReq) -> Request {
    let sampler = match (g.top_k, g.temperature) {
        (None, None) => Sampler::Greedy,
        (k, t) => Sampler::TopK { k: k.unwrap_or(1).max(1),
                                  temperature: t.unwrap_or(1.0) as f32 },
    };
    Request { id: gid, prompt: g.prompt, max_new_tokens: g.max_new, sampler,
              stop_token: g.stop, priority: g.priority,
              deadline_ms: g.deadline_ms, submitted_ns: 0, session: g.session }
}

/// Load-shed hint: projected queue drain time from the e2e p50, clamped
/// to a sane band.  Cold-start (no completions yet) assumes 20 ms/request.
fn retry_hint_ms(engine: &mut Engine) -> u64 {
    let waiting = engine.batcher.waiting() as f64;
    let per_req = engine.metrics.total_ms.quantile(0.5).max(20.0);
    let lanes = engine.batcher.max_batch.max(1) as f64;
    ((per_req * (waiting + 1.0) / lanes).ceil() as u64).clamp(25, 5_000)
}

/// Per-connection reader: parse frames, shed on a full admission
/// channel, and report EOF / write failure as `Ctl::Gone` so the serve
/// loop cancels everything this connection owns.  A dedicated writer
/// thread serializes response frames — the serve loop never blocks on a
/// slow client socket, and deltas/finals/stats interleave per line.
fn handle_conn(stream: TcpStream, conn: u64, new_tx: SyncSender<NewMsg>,
               ctl_tx: Sender<Ctl>, retry_hint: Arc<AtomicU64>,
               shed: Arc<AtomicU64>) {
    let _ = stream.set_nodelay(true);
    let Ok(mut wr) = stream.try_clone() else {
        let _ = ctl_tx.send(Ctl::Gone { conn });
        return;
    };
    let (out_tx, out_rx): (Sender<String>, Receiver<String>) = channel();
    let writer_ctl = ctl_tx.clone();
    let writer = std::thread::spawn(move || {
        for frame in out_rx {
            if wr.write_all(frame.as_bytes())
                .and_then(|_| wr.write_all(b"\n"))
                .is_err()
            {
                let _ = writer_ctl.send(Ctl::Gone { conn });
                break;
            }
        }
    });

    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        // bounded read: one frame may occupy at most MAX_FRAME_BYTES
        let n = match (&mut reader)
            .take(proto::MAX_FRAME_BYTES as u64 + 1)
            .read_until(b'\n', &mut buf)
        {
            Ok(0) => break, // EOF
            Ok(n) => n,
            Err(_) => break,
        };
        if n > proto::MAX_FRAME_BYTES && buf.last() != Some(&b'\n') {
            // overlong frame: structured shed, then resync to the next
            // newline without buffering the remainder
            let _ = out_tx.send(proto::error_frame("frame exceeds MAX_FRAME_BYTES"));
            if skip_to_newline(&mut reader).is_err() {
                break;
            }
            continue;
        }
        let line = match buf.last() {
            Some(&b'\n') => &buf[..buf.len() - 1],
            _ => &buf[..],
        };
        if line.iter().all(|b| b.is_ascii_whitespace()) {
            continue; // blank keepalive line
        }
        match proto::scan_client_frame(line) {
            Err(e) => {
                let _ = out_tx.send(proto::error_frame(&e.to_string()));
            }
            Ok(ClientFrame::Stats) => {
                let _ = ctl_tx.send(Ctl::Stats { out: out_tx.clone() });
            }
            Ok(ClientFrame::Cancel { id }) => {
                let _ = ctl_tx.send(Ctl::Cancel { conn, client_id: id });
            }
            Ok(ClientFrame::Gen(g)) => {
                let client_id = g.id;
                let msg = NewMsg { conn, client_id, req: g, out: out_tx.clone() };
                match new_tx.try_send(msg) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        // first backpressure stage: shed here, on the
                        // reader thread, so the serve loop never learns
                        // about load it could not admit
                        shed.fetch_add(1, Ordering::Relaxed);
                        let ra = retry_hint.load(Ordering::Relaxed);
                        let _ = out_tx.send(proto::reject_frame(
                            Some(client_id), "admission queue full", Some(ra)));
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        let _ = out_tx.send(proto::error_frame("server shutting down"));
                        break;
                    }
                }
            }
        }
    }
    let _ = ctl_tx.send(Ctl::Gone { conn });
    drop(out_tx);
    let _ = writer.join();
}

/// Discard bytes up to and including the next newline using the reader's
/// own buffer — O(1) memory even for a gigabyte-long poison line.
fn skip_to_newline(r: &mut impl BufRead) -> std::io::Result<()> {
    loop {
        let (done, used) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                return Ok(()); // EOF
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => (true, pos + 1),
                None => (false, chunk.len()),
            }
        };
        r.consume(used);
        if done {
            return Ok(());
        }
    }
}

// ---------------- deprecated GEN line protocol ----------------

/// Per-request outcome routed back to the owning client thread
/// (legacy path).
type Outcome = std::result::Result<Completion, String>;

/// **Deprecated** `GEN …`/`OK …` line protocol (`--legacy-proto`): one
/// buffered response per request, no streaming, no backpressure, no
/// cancellation.  Kept only so pre-PR-7 harnesses keep working; new
/// clients speak the NDJSON protocol above.  Unlike the original, an
/// internal routing failure now logs server-side and answers a generic
/// `ERR internal error` — engine internals never leak to the socket.
fn serve_legacy(rt: &Runtime, cfg: EngineCfg, listener: TcpListener,
                max_requests: Option<usize>) -> Result<()> {
    println!("kvmix serving LEGACY line protocol on {} (policy {}) — \
              deprecated, migrate to the NDJSON protocol \
              (DESIGN.md §Serving-Protocol)",
             listener.local_addr()?, cfg.method.name());
    let (tx, rx): (Sender<(Request, Sender<Outcome>)>, Receiver<_>) = channel();
    let next_id = Arc::new(Mutex::new(0u64));

    let accept_handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let ids = next_id.clone();
            std::thread::spawn(move || {
                let _ = handle_legacy_client(stream, tx, ids);
            });
        }
    });

    let threads = cfg.threads;
    WorkerPool::scoped(threads, |pool| {
        let mut engine = Engine::with_pool(rt, cfg, Some(pool))?;
        let mut pending: HashMap<u64, Sender<Outcome>> = HashMap::new();
        let mut served = 0usize;
        loop {
            while let Ok((req, done_tx)) = rx.try_recv() {
                pending.insert(req.id, done_tx);
                engine.submit(req);
            }
            // drained BEFORE the idle check — submit-time rejections
            // (over-bucket prompts) can leave the engine idle
            for r in engine.take_rejections() {
                if let Some(done_tx) = pending.remove(&r.id) {
                    let _ = done_tx.send(Err(r.reason));
                }
                served += 1;
            }
            if engine.idle() {
                std::thread::sleep(Duration::from_millis(2));
                if let Some(max) = max_requests {
                    if served >= max {
                        drop(accept_handle);
                        println!("{}", engine.metrics.report());
                        return Ok(());
                    }
                }
                continue;
            }
            for c in engine.step()? {
                if let Some(done_tx) = pending.remove(&c.id) {
                    let _ = done_tx.send(Ok(c));
                }
                served += 1;
            }
        }
    })
}

fn handle_legacy_client(stream: TcpStream, tx: Sender<(Request, Sender<Outcome>)>,
                        ids: Arc<Mutex<u64>>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // disconnected
        }
        match parse_gen_line(line.trim()) {
            Err(e) => {
                writeln!(out, "ERR {e}")?;
            }
            Ok((max_new, prompt)) => {
                let id = {
                    let mut g = ids.lock().unwrap();
                    *g += 1;
                    *g
                };
                let (done_tx, done_rx) = channel();
                let req = Request { id, prompt, max_new_tokens: max_new,
                                    sampler: Sampler::Greedy, stop_token: None,
                                    priority: 0, deadline_ms: None,
                                    submitted_ns: 0, session: None };
                tx.send((req, done_tx)).map_err(|_| anyhow!("engine gone"))?;
                match done_rx.recv() {
                    Ok(Ok(c)) => {
                        let toks: Vec<String> =
                            c.tokens.iter().map(|t| t.to_string()).collect();
                        writeln!(out, "OK {}", toks.join(","))?;
                    }
                    Ok(Err(reason)) => writeln!(out, "ERR {reason}")?,
                    Err(_) => {
                        // the leak fix: channel internals stay server-side
                        eprintln!("legacy request {id} from {peer}: \
                                   engine dropped the response channel");
                        writeln!(out, "ERR internal error")?;
                    }
                }
            }
        }
    }
}

/// Parse "GEN <n> <t0,t1,...>" (legacy protocol only).
pub fn parse_gen_line(line: &str) -> Result<(usize, Vec<i32>)> {
    let mut parts = line.splitn(3, ' ');
    let cmd = parts.next().unwrap_or("");
    if cmd != "GEN" {
        return Err(anyhow!("unknown command {cmd:?}"));
    }
    let n: usize = parts.next().ok_or_else(|| anyhow!("missing max_new_tokens"))?.parse()?;
    let toks = parts.next().ok_or_else(|| anyhow!("missing prompt"))?;
    let prompt: Vec<i32> = toks.split(',')
        .map(|s| s.trim().parse::<i32>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow!("bad token list: {e}"))?;
    if prompt.is_empty() {
        return Err(anyhow!("empty prompt"));
    }
    Ok((n, prompt))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_valid_line() {
        let (n, p) = parse_gen_line("GEN 8 1,5,9").unwrap();
        assert_eq!(n, 8);
        assert_eq!(p, vec![1, 5, 9]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_gen_line("NOPE 1 2").is_err());
        assert!(parse_gen_line("GEN x 1").is_err());
        assert!(parse_gen_line("GEN 5").is_err());
        assert!(parse_gen_line("GEN 5 1,a").is_err());
    }

    #[test]
    fn rejects_empty_prompt_forms() {
        // a bare command, a trailing space, and a lone comma all decode
        // to an empty/invalid prompt, never a zero-length request
        assert!(parse_gen_line("").is_err());
        assert!(parse_gen_line("GEN").is_err());
        assert!(parse_gen_line("GEN 5 ").is_err());
        assert!(parse_gen_line("GEN 5 ,").is_err());
        assert!(parse_gen_line("GEN 5 1,").is_err());
        assert!(parse_gen_line("GEN 5 ,1").is_err());
    }

    #[test]
    fn rejects_malformed_ids() {
        assert!(parse_gen_line("GEN 8 1,,2").is_err());
        assert!(parse_gen_line("GEN 8 1.5,2").is_err());
        assert!(parse_gen_line("GEN 8 0x1f").is_err());
        assert!(parse_gen_line("GEN 8 9999999999999").is_err(), "i32 overflow");
        assert!(parse_gen_line("GEN -1 1,2").is_err(), "negative max_new");
    }

    #[test]
    fn rejects_trailing_junk() {
        // the third splitn field is the whole remainder: junk after the
        // token list must fail the i32 parse, not be silently dropped
        assert!(parse_gen_line("GEN 8 1,2,3 junk").is_err());
        assert!(parse_gen_line("GEN 8 1,2,3;DROP").is_err());
        // interior whitespace around commas is tolerated by design
        let (n, p) = parse_gen_line("GEN 8 1, 2 ,3").unwrap();
        assert_eq!((n, p), (8, vec![1, 2, 3]));
    }

    #[test]
    fn build_request_maps_sampler_and_lifecycle_fields() {
        let g = GenReq { id: 4, prompt: vec![1, 2], max_new: 8, priority: 2,
                         deadline_ms: Some(100), temperature: Some(0.5),
                         top_k: Some(3), stop: Some(2), session: Some(7) };
        let r = build_request(99, g);
        assert_eq!(r.id, 99, "engine id is the serve loop's global one");
        assert_eq!(r.priority, 2);
        assert_eq!(r.deadline_ms, Some(100));
        assert_eq!(r.stop_token, Some(2));
        assert_eq!(r.session, Some(7), "session key rides through to the engine");
        match r.sampler {
            Sampler::TopK { k, temperature } => {
                assert_eq!(k, 3);
                assert!((temperature - 0.5).abs() < 1e-6);
            }
            s => panic!("expected TopK, got {s:?}"),
        }
        let plain = GenReq { id: 4, prompt: vec![1], max_new: 1, priority: 0,
                             deadline_ms: None, temperature: None, top_k: None,
                             stop: None, session: None };
        assert!(matches!(build_request(1, plain).sampler, Sampler::Greedy));
    }
}
