//! Line-protocol TCP server (std::net — tokio is unavailable offline).
//!
//! Protocol (one request per line):
//!     GEN <max_new_tokens> <comma-separated prompt token ids>\n
//! Response:
//!     OK <comma-separated generated ids>\n   |   ERR <message>\n
//!
//! A client thread parses requests into the shared queue; the engine
//! thread runs the continuous-batching loop and routes completions back
//! over per-request channels.
//!
//! A request the engine can *never* admit (projected footprint beyond
//! the KV budget) is answered with an `ERR` line on its own connection —
//! the engine keeps stepping and every other client is unaffected
//! ([`Engine::take_rejections`]).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::coordinator::engine::{Engine, EngineCfg};
use crate::coordinator::request::{Completion, Request};
use crate::model::Sampler;
use crate::runtime::Runtime;
use crate::util::pool::{resolve_threads, WorkerPool};

/// Per-request outcome routed back to the owning client thread.
type Outcome = std::result::Result<Completion, String>;

enum Msg {
    New(Request, Sender<Outcome>),
    Shutdown,
}

/// Serve until `max_requests` have completed (None = forever).
///
/// `cfg.threads` sizes the decode attention worker pool (0 = one per
/// core); the engine loop itself — and with it every PJRT call — stays on
/// the calling thread.
pub fn serve(rt: &Runtime, cfg: EngineCfg, addr: &str,
             max_requests: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    let paging = if cfg.page_tokens > 0 {
        let prefix = if cfg.prefix_cache { " + prefix cache" } else { "" };
        format!(", {}-token KV pages{prefix}", cfg.page_tokens)
    } else {
        String::new()
    };
    println!("kvmix serving on {addr} (policy {}, {} attention worker(s){paging})",
             cfg.method.name(), resolve_threads(cfg.threads));
    let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
    let next_id = Arc::new(Mutex::new(0u64));

    // acceptor thread
    let tx_accept = tx.clone();
    let accept_handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx_accept.clone();
            let ids = next_id.clone();
            std::thread::spawn(move || {
                let _ = handle_client(stream, tx, ids);
            });
        }
    });

    // engine loop (current thread — PJRT client is not Sync-shared here;
    // only the cache attention fans out across the scoped pool)
    let threads = cfg.threads;
    WorkerPool::scoped(threads, |pool| {
        let mut engine = Engine::with_pool(rt, cfg, Some(pool))?;
        let mut pending: HashMap<u64, Sender<Outcome>> = HashMap::new();
        let mut served = 0usize;
        loop {
            // drain incoming
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Msg::New(req, done_tx) => {
                        pending.insert(req.id, done_tx);
                        engine.submit(req);
                    }
                    Msg::Shutdown => return Ok(()),
                }
            }
            // a never-admittable request fails alone: ERR to its own
            // client, the engine keeps stepping for everyone else.
            // Drained BEFORE the idle check — submit-time rejections
            // (over-bucket prompts) can leave the engine idle, and
            // step-produced ones land here on the next loop pass.
            for r in engine.take_rejections() {
                if let Some(done_tx) = pending.remove(&r.id) {
                    let _ = done_tx.send(Err(r.reason));
                }
                served += 1;
            }
            if engine.idle() {
                std::thread::sleep(std::time::Duration::from_millis(2));
                // nothing to do; check for exit condition
                if let Some(max) = max_requests {
                    if served >= max {
                        drop(accept_handle);
                        println!("{}", engine.metrics.report());
                        return Ok(());
                    }
                }
                continue;
            }
            for c in engine.step()? {
                if let Some(done_tx) = pending.remove(&c.id) {
                    let _ = done_tx.send(Ok(c));
                }
                served += 1;
            }
        }
    })
}

fn handle_client(stream: TcpStream, tx: Sender<Msg>,
                 ids: Arc<Mutex<u64>>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // disconnected
        }
        match parse_gen_line(line.trim()) {
            Err(e) => {
                writeln!(out, "ERR {e}")?;
            }
            Ok((max_new, prompt)) => {
                let id = {
                    let mut g = ids.lock().unwrap();
                    *g += 1;
                    *g
                };
                let (done_tx, done_rx) = channel();
                let req = Request { id, prompt, max_new_tokens: max_new,
                                    sampler: Sampler::Greedy, stop_token: None,
                                    submitted_ns: 0 };
                tx.send(Msg::New(req, done_tx)).map_err(|_| anyhow!("engine gone"))?;
                match done_rx.recv() {
                    Ok(Ok(c)) => {
                        let toks: Vec<String> = c.tokens.iter().map(|t| t.to_string()).collect();
                        writeln!(out, "OK {}", toks.join(","))?;
                    }
                    Ok(Err(reason)) => writeln!(out, "ERR {reason}")?,
                    Err(_) => writeln!(out, "ERR engine dropped request from {peer}")?,
                }
            }
        }
    }
}

/// Parse "GEN <n> <t0,t1,...>".
pub fn parse_gen_line(line: &str) -> Result<(usize, Vec<i32>)> {
    let mut parts = line.splitn(3, ' ');
    let cmd = parts.next().unwrap_or("");
    if cmd != "GEN" {
        return Err(anyhow!("unknown command {cmd:?}"));
    }
    let n: usize = parts.next().ok_or_else(|| anyhow!("missing max_new_tokens"))?.parse()?;
    let toks = parts.next().ok_or_else(|| anyhow!("missing prompt"))?;
    let prompt: Vec<i32> = toks.split(',')
        .map(|s| s.trim().parse::<i32>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow!("bad token list: {e}"))?;
    if prompt.is_empty() {
        return Err(anyhow!("empty prompt"));
    }
    Ok((n, prompt))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_valid_line() {
        let (n, p) = parse_gen_line("GEN 8 1,5,9").unwrap();
        assert_eq!(n, 8);
        assert_eq!(p, vec![1, 5, 9]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_gen_line("NOPE 1 2").is_err());
        assert!(parse_gen_line("GEN x 1").is_err());
        assert!(parse_gen_line("GEN 5").is_err());
        assert!(parse_gen_line("GEN 5 1,a").is_err());
    }

    #[test]
    fn rejects_empty_prompt_forms() {
        // a bare command, a trailing space, and a lone comma all decode
        // to an empty/invalid prompt, never a zero-length request
        assert!(parse_gen_line("").is_err());
        assert!(parse_gen_line("GEN").is_err());
        assert!(parse_gen_line("GEN 5 ").is_err());
        assert!(parse_gen_line("GEN 5 ,").is_err());
        assert!(parse_gen_line("GEN 5 1,").is_err());
        assert!(parse_gen_line("GEN 5 ,1").is_err());
    }

    #[test]
    fn rejects_malformed_ids() {
        assert!(parse_gen_line("GEN 8 1,,2").is_err());
        assert!(parse_gen_line("GEN 8 1.5,2").is_err());
        assert!(parse_gen_line("GEN 8 0x1f").is_err());
        assert!(parse_gen_line("GEN 8 9999999999999").is_err(), "i32 overflow");
        assert!(parse_gen_line("GEN -1 1,2").is_err(), "negative max_new");
    }

    #[test]
    fn rejects_trailing_junk() {
        // the third splitn field is the whole remainder: junk after the
        // token list must fail the i32 parse, not be silently dropped
        assert!(parse_gen_line("GEN 8 1,2,3 junk").is_err());
        assert!(parse_gen_line("GEN 8 1,2,3;DROP").is_err());
        // interior whitespace around commas is tolerated by design
        let (n, p) = parse_gen_line("GEN 8 1, 2 ,3").unwrap();
        assert_eq!((n, p), (8, vec![1, 2, 3]));
    }
}
