//! Serving metrics: counters + latency histogram + throughput window.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Metrics {
    started: Instant,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub completions: usize,
    pub oom_events: usize,
    pub ttft_ms: Histogram,
    pub total_ms: Histogram,
    pub step_us: Histogram,
    pub peak_kv_bytes: usize,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics { started: Instant::now(), prefill_tokens: 0, decode_tokens: 0,
                  completions: 0, oom_events: 0, ttft_ms: Histogram::default(),
                  total_ms: Histogram::default(), step_us: Histogram::default(),
                  peak_kv_bytes: 0 }
    }
}

impl Metrics {
    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// decode tokens per second since start
    pub fn throughput(&self) -> f64 {
        self.decode_tokens as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn now_ns(&self) -> u64 {
        self.started.elapsed().as_nanos() as u64
    }

    pub fn report(&mut self) -> String {
        format!(
            "tokens: prefill {} decode {} | completions {} | throughput {:.1} tok/s | \
             ttft p50 {:.1} ms p95 {:.1} ms | e2e p50 {:.1} ms | step p50 {:.0} µs | \
             peak kv {:.2} MiB | oom {}",
            self.prefill_tokens, self.decode_tokens, self.completions,
            self.throughput(), self.ttft_ms.quantile(0.5), self.ttft_ms.quantile(0.95),
            self.total_ms.quantile(0.5), self.step_us.quantile(0.5),
            self.peak_kv_bytes as f64 / (1 << 20) as f64, self.oom_events)
    }
}

/// Simple exact histogram (stores samples; fine at serving-bench scale).
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let idx = ((self.samples.len() - 1) as f64 * q).round() as usize;
        self.samples[idx]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(1.0), 100.0);
        assert!((h.quantile(0.5) - 50.0).abs() <= 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }
}
